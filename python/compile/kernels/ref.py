"""Bit-exact NumPy reference semantics for PQS dot products.

This module is the *authoritative specification* of the integer arithmetic in
the PQS reproduction. Three implementations must match it bit-for-bit:

  1. the Pallas kernel (`pqs_matmul.py`, interpret=True),
  2. the Rust engine (`rust/src/dot/`, checked against exported goldens),
  3. itself (property tests in `python/tests/`).

Terminology follows the paper (Natesh & Kung 2025):

  * products  p_k = w_q[k] * x_q[k]           (exact int32)
  * a p-bit accumulator holds values in [-2^(p-1), 2^(p-1) - 1]
  * an *overflow event* occurs when `acc + v` leaves that range before the
    policy (clip/wrap) is applied
  * an overflow is *persistent* when the exact final sum leaves the range,
    *transient* when only intermediate partial sums do (Section 3.1)

Sorted dot product (Section 3.2, Algorithm 1):

  * `sorted1` — the single-round variant used by the Pallas kernel: split
    the products into positives (sorted descending, zero padded) and
    negatives (sorted ascending, zero padded), pair them elementwise, then
    push the paired sums through the p-bit accumulator in order.
    Pairing additions happen in exact temporary storage (they are bounded by
    max(|pos|, |neg|)); only the running accumulation is width-limited.
  * `sorted_full` — Algorithm 1 verbatim: repeat split/sort/pair rounds in
    exact temporaries until a single sign remains, then accumulate the
    remaining (monotone) sequence through the p-bit accumulator.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "acc_range",
    "clamp",
    "clip_accumulate",
    "wrap_accumulate",
    "exact_dot",
    "sorted1_pair",
    "sorted1_dot",
    "sorted_full_dot",
    "classify_overflow",
    "dot_with_policy",
    "qmatmul_ref",
    "POLICIES",
]

POLICIES = ("exact", "clip", "wrap", "sorted1", "sorted", "oracle")


def acc_range(p: int) -> tuple[int, int]:
    """Inclusive [lo, hi] range of a signed p-bit accumulator."""
    return -(1 << (p - 1)), (1 << (p - 1)) - 1


def clamp(v: int, p: int) -> int:
    lo, hi = acc_range(p)
    return min(max(int(v), lo), hi)


def exact_dot(prods: np.ndarray) -> int:
    """Exact (wide) sum of partial products."""
    return int(np.asarray(prods, dtype=np.int64).sum())


def clip_accumulate(prods: np.ndarray, p: int) -> tuple[int, int]:
    """Sequential saturating accumulation in index order.

    Returns (final value, number of overflow events)."""
    lo, hi = acc_range(p)
    acc = 0
    ovf = 0
    for v in np.asarray(prods, dtype=np.int64):
        t = acc + int(v)
        if t < lo or t > hi:
            ovf += 1
            t = lo if t < lo else hi
        acc = t
    return acc, ovf


def wrap_accumulate(prods: np.ndarray, p: int) -> tuple[int, int]:
    """Sequential two's-complement wraparound accumulation in index order."""
    lo, hi = acc_range(p)
    span = 1 << p
    acc = 0
    ovf = 0
    for v in np.asarray(prods, dtype=np.int64):
        t = acc + int(v)
        if t < lo or t > hi:
            ovf += 1
            t = ((t - lo) % span) + lo
        acc = t
    return acc, ovf


def sorted1_pair(prods: np.ndarray) -> np.ndarray:
    """One PQS sorting round: pair largest positives with most-negative values.

    Returns the K paired sums s where s[i] = pos_desc[i] + neg_asc[i] with
    zero padding, so sum(s) == sum(prods) exactly. Pairing arithmetic is
    exact (int64 temporaries)."""
    p = np.asarray(prods, dtype=np.int64)
    pos = np.sort(np.where(p > 0, p, 0))[::-1]  # descending, zeros pad tail
    neg = np.sort(np.where(p < 0, p, 0))        # ascending, zeros pad tail
    return pos + neg


def sorted1_dot(prods: np.ndarray, p: int) -> tuple[int, int]:
    """Single-round sorted dot product through a p-bit clipping accumulator."""
    return clip_accumulate(sorted1_pair(prods), p)


def sorted_full_dot(prods: np.ndarray, p: int) -> tuple[int, int]:
    """Algorithm 1 (multi-round) through a p-bit clipping accumulator.

    Rounds of split/sort/pairwise-add run in exact temporaries; when only a
    single sign remains the (monotone) remainder is accumulated with
    clipping. Returns (value, overflow events in the accumulation phase)."""
    cur = np.asarray(prods, dtype=np.int64)
    cur = cur[cur != 0]
    while len(cur) > 1:
        pos = np.sort(cur[cur > 0])[::-1]
        neg = np.sort(cur[cur < 0])
        m = min(len(pos), len(neg))
        if m == 0:
            # Single sign: monotone accumulation through the accumulator.
            return clip_accumulate(cur, p)
        paired = pos[:m] + neg[:m]
        leftover = pos[m:] if len(pos) > len(neg) else neg[m:]
        cur = np.concatenate([paired, leftover])
        cur = cur[cur != 0]
    if len(cur) == 0:
        return 0, 0
    return clip_accumulate(cur, p)


def classify_overflow(prods: np.ndarray, p: int) -> dict:
    """Classify a dot product per Section 3.1.

    Returns dict with keys: exact, persistent (bool), naive_events (int),
    transient (bool) — transient means naive-order accumulation overflowed
    but the exact final result fits."""
    lo, hi = acc_range(p)
    exact = exact_dot(prods)
    _, events = clip_accumulate(prods, p)
    persistent = exact < lo or exact > hi
    return {
        "exact": exact,
        "persistent": persistent,
        "naive_events": events,
        "transient": (events > 0) and not persistent,
    }


def dot_with_policy(prods: np.ndarray, p: int, policy: str) -> tuple[int, int]:
    """Evaluate one dot product under an accumulation policy.

    Policies: exact | clip | wrap | sorted1 | sorted | oracle.
    `oracle` resolves transient overflows perfectly (Fig. 2b red line): it
    returns the exact value unless the overflow is persistent, in which case
    it returns the clipped exact value."""
    if policy == "exact":
        return exact_dot(prods), 0
    if policy == "clip":
        return clip_accumulate(prods, p)
    if policy == "wrap":
        return wrap_accumulate(prods, p)
    if policy == "sorted1":
        return sorted1_dot(prods, p)
    if policy == "sorted":
        return sorted_full_dot(prods, p)
    if policy == "oracle":
        exact = exact_dot(prods)
        lo, hi = acc_range(p)
        if lo <= exact <= hi:
            return exact, 0
        return clamp(exact, p), 1
    raise ValueError(f"unknown policy {policy!r}")


def qmatmul_ref(
    xq: np.ndarray, wq: np.ndarray, p: int, policy: str
) -> tuple[np.ndarray, np.ndarray]:
    """Reference quantized matmul: xq [M,K] @ wq [K,N] integer values.

    Every output element is an independent length-K dot product pushed
    through the policy. Returns (y int64 [M,N], overflow events int64 [M,N]).
    """
    xq = np.asarray(xq, dtype=np.int64)
    wq = np.asarray(wq, dtype=np.int64)
    M, K = xq.shape
    K2, N = wq.shape
    assert K == K2, (xq.shape, wq.shape)
    y = np.zeros((M, N), dtype=np.int64)
    ev = np.zeros((M, N), dtype=np.int64)
    for i in range(M):
        for j in range(N):
            prods = xq[i, :] * wq[:, j]
            v, e = dot_with_policy(prods, p, policy)
            y[i, j] = v
            ev[i, j] = e
    return y, ev
