"""Layer-1 Pallas kernel: quantized matmul with low-bitwidth accumulation.

Implements the PQS sorted dot product (paper Section 3.2, single sorting
round) plus the clip / wrap / exact baselines as a Pallas kernel. The kernel
is bit-exact against `ref.py` (`qmatmul_ref`) — this is enforced by
`python/tests/test_kernel.py` with hypothesis sweeps over shapes, bitwidths
and policies.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation): the grid tiles output
rows/columns, but the contraction dimension K is kept whole inside one block
because the sorting round needs *all* partial products of a dot product
(paper §6, Software Scheduling). Products are computed as int32 element-wise
multiplies in VMEM; `jnp.sort` lowers to an XLA sort — the software analogue
of the bitonic sorting networks the paper proposes for hardware. Kernels run
with interpret=True: the CPU PJRT plugin cannot execute Mosaic custom-calls.

The k-tiled variant of the paper's §6 study lives in the Rust engine
(`rust/src/dot/tiled.rs`); at the kernel level tiling K would split the sort.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

POLICIES = ("exact", "clip", "wrap", "sorted1")


def _acc_range(p: int) -> tuple[int, int]:
    return -(1 << (p - 1)), (1 << (p - 1)) - 1


def _sorted1_pair(prods: jnp.ndarray) -> jnp.ndarray:
    """Single PQS sorting round along axis 1 of a (bm, K, bn) product block.

    pos: positives sorted descending (zeros pad the tail);
    neg: negatives sorted ascending (zeros pad the tail).
    Elementwise pairing cancels the largest positive against the most
    negative product; the sum over K is preserved exactly.
    """
    pos = jnp.where(prods > 0, prods, 0)
    neg = jnp.where(prods < 0, prods, 0)
    pos = jnp.flip(jnp.sort(pos, axis=1), axis=1)  # descending
    neg = jnp.sort(neg, axis=1)                    # ascending
    return pos + neg


def _accumulate_seq(seq: jnp.ndarray, acc_bits: int, policy: str):
    """Sequential width-limited accumulation of seq (bm, K, bn) along axis 1.

    Mirrors ref.clip_accumulate / ref.wrap_accumulate element-by-element.
    Returns (acc (bm, bn) int32, overflow event counts (bm, bn) int32).
    """
    lo, hi = _acc_range(acc_bits)
    bm, K, bn = seq.shape
    span = 1 << acc_bits

    def body(k, carry):
        acc, ovf = carry
        t = acc + seq[:, k, :]
        over = (t < lo) | (t > hi)
        ovf = ovf + over.astype(jnp.int32)
        if policy == "clip":
            t = jnp.clip(t, lo, hi)
        else:  # wrap (two's complement)
            t = jnp.where(over, ((t - lo) % span) + lo, t)
        return t, ovf

    init = (jnp.zeros((bm, bn), jnp.int32), jnp.zeros((bm, bn), jnp.int32))
    return jax.lax.fori_loop(0, K, body, init)


def _kernel(x_ref, w_ref, y_ref, ovf_ref, *, acc_bits: int, policy: str):
    x = x_ref[...].astype(jnp.int32)  # (bm, K)
    w = w_ref[...].astype(jnp.int32)  # (K, bn)
    prods = x[:, :, None] * w[None, :, :]  # (bm, K, bn) exact int32

    if policy == "exact":
        y_ref[...] = jnp.sum(prods, axis=1, dtype=jnp.int32)
        ovf_ref[...] = jnp.zeros(y_ref.shape, jnp.int32)
        return

    seq = _sorted1_pair(prods) if policy == "sorted1" else prods
    acc_policy = "clip" if policy in ("clip", "sorted1") else "wrap"
    acc, ovf = _accumulate_seq(seq, acc_bits, acc_policy)
    y_ref[...] = acc
    ovf_ref[...] = ovf


def _pad_to(a: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = a.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, rem)
    return jnp.pad(a, pad)


@functools.partial(
    jax.jit,
    static_argnames=("acc_bits", "policy", "block_m", "block_n", "interpret"),
)
def pqs_matmul(
    xq: jnp.ndarray,
    wq: jnp.ndarray,
    *,
    acc_bits: int = 16,
    policy: str = "sorted1",
    block_m: int = 8,
    block_n: int = 8,
    interpret: bool = True,
):
    """Quantized matmul y[i,j] = sum_k xq[i,k] * wq[k,j] with a p-bit
    accumulator under `policy` (exact | clip | wrap | sorted1).

    xq: (M, K) integer values (any int dtype), wq: (K, N).
    Returns (y, ovf): int32 results and per-element overflow event counts.
    M and N are zero-padded to block multiples (zero products are sign-less,
    so padding never changes results); K stays whole per the sorting rule.
    """
    if policy not in POLICIES:
        raise ValueError(f"policy must be one of {POLICIES}")
    M, K = xq.shape
    K2, N = wq.shape
    if K != K2:
        raise ValueError(f"shape mismatch {xq.shape} @ {wq.shape}")

    x = _pad_to(xq.astype(jnp.int32), 0, block_m)
    w = _pad_to(wq.astype(jnp.int32), 1, block_n)
    Mp, Np = x.shape[0], w.shape[1]
    bm, bn = min(block_m, Mp), min(block_n, Np)

    grid = (Mp // bm, Np // bn)
    out_shape = [
        jax.ShapeDtypeStruct((Mp, Np), jnp.int32),
        jax.ShapeDtypeStruct((Mp, Np), jnp.int32),
    ]
    y, ovf = pl.pallas_call(
        functools.partial(_kernel, acc_bits=acc_bits, policy=policy),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(x, w)
    return y[:M, :N], ovf[:M, :N]
