"""Training schedules for PQS: P->Q, Q->P, A2Q, filter pruning, low-rank.

Implements the paper's training pipeline (Sections 4 and 5.0.2):

  * iterative N:M magnitude pruning — every `prune_every` epochs the target
    sparsity ramps linearly; the smallest round(s * group) values within each
    consecutive group of M weights (along the dot-product/contraction axis)
    are set to zero. Pruned weights stay pruned (their magnitude is 0).
  * P->Q  — FP32 training with the pruning ramp, followed by QAT epochs.
  * Q->P  — QAT from the start; the pruning signal is the *quantized*
    weight magnitude (paper §4 shows this is the inferior signal).
  * A2Q   — QAT with per-output-row L1-norm projection
    sum_k |w_q| <= (2^(p-1)-1) / 2^(b-1), the accumulator-aware bound of
    Colbert et al. (paper §3.1) which guarantees overflow-free p-bit
    accumulation. No explicit pruning (the bound induces unstructured
    sparsity by itself).
  * filter — structured filter pruning baseline (Fig. 4 magenta): entire
    output channels with the smallest L1 norms are removed.
  * low-rank — before each pruning event the target matrix is replaced by
    its rank-k SVD approximation (Fig. 3 study, MLP hidden layer only).

Everything runs on CPU JAX; the per-epoch batch loop is a `lax.scan` inside
one jit so single-core dispatch overhead stays negligible.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M


@dataclass
class TrainCfg:
    arch: str = "mlp1"
    schedule: str = "pq"  # fp32 | pq | qp | a2q | filter
    epochs: int = 10
    qat_epochs: int = 3  # trailing QAT epochs for pq/filter; ignored for qp/a2q
    wbits: int = 8
    abits: int = 8
    sparsity: float = 0.0
    nm_m: int = 16
    acc_bits: int | None = None  # A2Q accumulator target p
    lowrank_k: int | None = None  # Fig. 3: SVD rank before prune events
    lr: float = 2e-3
    bs: int = 128
    seed: int = 0
    arch_kw: dict = field(default_factory=dict)


# ---------------------------------------------------------------------------
# hand-rolled Adam (no optax in this environment)
# ---------------------------------------------------------------------------

def adam_init(params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    tf = t.astype(jnp.float32)
    new = {}
    for k in params:
        mhat = m[k] / (1 - b1**tf)
        vhat = v[k] / (1 - b2**tf)
        new[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# pruning (numpy, between epochs — exact and easy to audit)
# ---------------------------------------------------------------------------

def nm_prune_mask(w: np.ndarray, sparsity: float, m: int) -> np.ndarray:
    """N:M mask along the contraction axis. w is (out, K) after flattening.

    Within each consecutive group of `m` (ragged tail allowed) the
    round(sparsity * group_len) smallest |w| are zeroed."""
    out, K = w.shape
    mask = np.ones_like(w, dtype=np.float32)
    for g0 in range(0, K, m):
        g1 = min(g0 + m, K)
        glen = g1 - g0
        nprune = int(round(sparsity * glen))
        if nprune <= 0:
            continue
        seg = np.abs(w[:, g0:g1])
        idx = np.argsort(seg, axis=1, kind="stable")[:, :nprune]
        rows = np.repeat(np.arange(out)[:, None], nprune, axis=1)
        mask[rows, g0 + idx] = 0.0
    return mask


def filter_prune_mask(w: np.ndarray, sparsity: float) -> np.ndarray:
    """Structured baseline: zero whole output rows with smallest L1 norm."""
    out = w.shape[0]
    nprune = int(round(sparsity * out))
    mask = np.ones_like(w, dtype=np.float32)
    if nprune <= 0:
        return mask
    nprune = min(nprune, out - 1)  # keep at least one filter
    norms = np.abs(w).reshape(out, -1).sum(axis=1)
    mask[np.argsort(norms, kind="stable")[:nprune]] = 0.0
    return mask


def lowrank_approx(w: np.ndarray, k: int) -> np.ndarray:
    u, s, vt = np.linalg.svd(w, full_matrices=False)
    k = min(k, len(s))
    return (u[:, :k] * s[:k]) @ vt[:k]


def _flat2(w: np.ndarray) -> np.ndarray:
    return w.reshape(w.shape[0], -1)


def prune_event(
    graph, params, masks, cfg: TrainCfg, target: float, *, quant_signal: bool
):
    """Apply one pruning event at cumulative sparsity `target`.

    quant_signal=True prunes on |w_q| (Q->P); otherwise on FP32 |w| (P->Q).
    Returns updated (params, masks) with pruned weights zeroed."""
    params = dict(params)
    masks = dict(masks)
    for n in M.q_layers(graph):
        if not n.get("prune", False):
            continue
        key = f"w{n['id']}"
        w = np.asarray(params[key])
        shape = w.shape
        wf = _flat2(w).copy()
        if cfg.lowrank_k is not None and n.get("name") == "hidden":
            wf = lowrank_approx(wf, cfg.lowrank_k)
        sig = wf
        if quant_signal:
            qmax = (1 << (cfg.wbits - 1)) - 1
            s = np.abs(wf).max() / qmax if np.abs(wf).max() > 0 else 1.0
            sig = np.round(wf / s)  # quantized-magnitude signal
        if cfg.schedule == "filter":
            mk = filter_prune_mask(sig, target)
        else:
            mk = nm_prune_mask(sig, target, cfg.nm_m)
        wf = wf * mk
        params[key] = jnp.asarray(wf.reshape(shape))
        masks[key] = jnp.asarray(mk.reshape(shape))
    return params, masks


# ---------------------------------------------------------------------------
# A2Q projection
# ---------------------------------------------------------------------------

def _l1_ball_project_rows(wf: jnp.ndarray, radius: jnp.ndarray) -> jnp.ndarray:
    """Euclidean projection of each row of wf onto the L1 ball of `radius`
    (Duchi et al. 2008, sort-based soft thresholding). Rows already inside
    the ball are untouched."""
    radius = jnp.broadcast_to(jnp.asarray(radius, wf.dtype), (wf.shape[0],))
    a = jnp.sort(jnp.abs(wf), axis=1)[:, ::-1]  # descending magnitudes
    css = jnp.cumsum(a, axis=1)
    j = jnp.arange(1, wf.shape[1] + 1, dtype=wf.dtype)
    cond = a - (css - radius[:, None]) / j > 0
    rho = jnp.maximum(jnp.sum(cond, axis=1) - 1, 0)
    css_rho = jnp.take_along_axis(css, rho[:, None], axis=1)[:, 0]
    tau = jnp.maximum((css_rho - radius) / (rho + 1).astype(wf.dtype), 0.0)
    inside = jnp.sum(jnp.abs(wf), axis=1) <= radius
    tau = jnp.where(inside, 0.0, tau)
    return jnp.sign(wf) * jnp.maximum(jnp.abs(wf) - tau[:, None], 0.0)


def a2q_project(params, graph, wbits: int, acc_bits: int, shrink: float = 0.0):
    """A2Q accumulator-aware bound: per-output-row sum|w_q| <= L with
    L = (2^(p-1)-1)/2^(b-1) (paper §3.1). With a per-tensor max-derived
    scale s_w a multiplicative rescale is scale-invariant, so we project
    rows onto the L1 ball of radius L*s_w (soft threshold); the threshold
    shrinks small weights toward zero — exactly the unstructured sparsity
    the paper attributes to A2Q — and the bound converges over steps."""
    limit = float((1 << (acc_bits - 1)) - 1) / float(1 << (wbits - 1))
    qmax = (1 << (wbits - 1)) - 1
    out = dict(params)
    for n in M.q_layers(graph):
        key = f"w{n['id']}"
        w = out[key]
        wf = w.reshape(w.shape[0], -1)
        skey = f"s{n['id']}"
        if skey in out:  # learned, decoupled scale (the A2Q way)
            s = jax.lax.stop_gradient(jnp.exp(out[skey]))
        else:
            s = jnp.maximum(jnp.max(jnp.abs(wf)), 1e-8) / qmax
        # Anneal: early epochs only shrink each row's L1 mass by a fraction
        # per step (so the optimizer keeps learning); late epochs project
        # hard onto the bound (shrink=0) so the export satisfies it.
        l1 = jnp.sum(jnp.abs(wf), axis=1)
        radius = jnp.maximum(limit * s, shrink * l1)
        wf = _l1_ball_project_rows(wf, radius)
        out[key] = wf.reshape(w.shape)
    return out


# ---------------------------------------------------------------------------
# loss / steps
# ---------------------------------------------------------------------------

def _loss_fn(params, masks, qstate, graph, x, y, qat, wbits, abits):
    logits, new_state = M.forward(
        graph, params, masks, qstate, x, qat=qat, wbits=wbits, abits=abits, track=True
    )
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    return loss, new_state


@functools.partial(jax.jit, static_argnames=("graph_key", "qat", "wbits", "abits", "lr", "a2q_p", "a2q_shrink"))
def _train_epoch(
    params, masks, qstate, opt, xb, yb, *, graph_key, qat, wbits, abits, lr, a2q_p,
    a2q_shrink=0.0,
):
    graph = _GRAPH_CACHE[graph_key]

    def step(carry, batch):
        params, qstate, opt = carry
        x, y = batch
        (loss, new_state), grads = jax.value_and_grad(_loss_fn, has_aux=True)(
            params, masks, qstate, graph, x, y, qat, wbits, abits
        )
        params, opt = adam_update(params, grads, opt, lr)
        if a2q_p is not None:
            params = a2q_project(params, graph, wbits, a2q_p, a2q_shrink)
        return (params, new_state, opt), loss

    (params, qstate, opt), losses = jax.lax.scan(step, (params, qstate, opt), (xb, yb))
    return params, qstate, opt, jnp.mean(losses)


@functools.partial(jax.jit, static_argnames=("graph_key", "qat", "wbits", "abits"))
def _eval_batched(params, masks, qstate, xb, yb, *, graph_key, qat, wbits, abits):
    graph = _GRAPH_CACHE[graph_key]

    def step(_, batch):
        x, y = batch
        logits, _ = M.forward(
            graph, params, masks, qstate, x, qat=qat, wbits=wbits, abits=abits, track=False
        )
        return None, jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
    _, accs = jax.lax.scan(step, None, (xb, yb))
    return jnp.mean(accs)


# Graphs are lists of dicts (unhashable); key them by (arch, kwargs) string so
# jit static args work.
_GRAPH_CACHE: dict[str, list] = {}


def _graph_for(cfg: TrainCfg) -> tuple[str, list]:
    key = f"{cfg.arch}:{sorted(cfg.arch_kw.items())}"
    if key not in _GRAPH_CACHE:
        _GRAPH_CACHE[key] = M.ARCHS[cfg.arch](**cfg.arch_kw)
    return key, _GRAPH_CACHE[key]


def _batchify(x: np.ndarray, y: np.ndarray, bs: int):
    nb = len(x) // bs
    xb = jnp.asarray(x[: nb * bs].reshape(nb, bs, *x.shape[1:]))
    yb = jnp.asarray(y[: nb * bs].reshape(nb, bs).astype(np.int32))
    return xb, yb


@dataclass
class TrainResult:
    graph: list
    params: dict
    masks: dict
    qstate: dict
    acc_q: float    # fake-quant eval accuracy (wide accumulator)
    acc_fp32: float # plain f32 eval accuracy
    losses: list
    sparsity: float # achieved fraction of zero weights in pruned layers


def achieved_sparsity(graph, params, masks) -> float:
    tot = nz = 0
    for n in M.q_layers(graph):
        if not n.get("prune", False):
            continue
        w = np.asarray(params[f"w{n['id']}"])
        mk = masks.get(f"w{n['id']}")
        if mk is not None:
            w = w * np.asarray(mk)
        tot += w.size
        nz += int((w == 0).sum())
    return nz / tot if tot else 0.0


def train(cfg: TrainCfg, data) -> TrainResult:
    """Run one schedule. `data` = (x_train, y_train, x_test, y_test)."""
    x_tr, y_tr, x_te, y_te = data
    gkey, graph = _graph_for(cfg)
    params = M.init_params(graph, cfg.seed)
    if cfg.schedule == "a2q":
        # learned per-tensor weight scales, initialised from the data range
        qmax = (1 << (cfg.wbits - 1)) - 1
        for n in M.q_layers(graph):
            w = params[f"w{n['id']}"]
            params[f"s{n['id']}"] = jnp.log(jnp.max(jnp.abs(w)) / qmax)
    masks = M.ones_masks(params)
    qstate = M.init_qstate(graph)
    opt = adam_init(params)
    xb, yb = _batchify(x_tr, y_tr, cfg.bs)
    xe, ye = _batchify(x_te, y_te, min(cfg.bs, 256))

    sched = cfg.schedule
    qat_from = {
        "fp32": cfg.epochs + 1,     # never
        "pq": cfg.epochs - cfg.qat_epochs,
        "filter": cfg.epochs - cfg.qat_epochs,
        "qp": 0,
        "a2q": 0,
    }[sched]
    # pruning ramp: events at the end of epochs 0..ramp_end-1
    ramp_end = max(1, (cfg.epochs - cfg.qat_epochs - 1) if sched in ("pq", "filter") else cfg.epochs - 2)
    do_prune = sched in ("pq", "qp", "filter") and cfg.sparsity > 0

    losses = []
    rng = np.random.default_rng(cfg.seed + 1)
    n_batches = xb.shape[0]
    for epoch in range(cfg.epochs):
        qat = epoch >= qat_from
        perm = rng.permutation(n_batches)
        # A2Q: soft L1 annealing for the first half, then hard projection
        # with a lowered learning rate so the network recovers under the
        # (now exact) accumulator bound.
        a2q_hard = sched == "a2q" and epoch >= 0.5 * cfg.epochs
        params, qstate, opt, loss = _train_epoch(
            params, masks, qstate, opt, xb[perm], yb[perm],
            graph_key=gkey, qat=qat, wbits=cfg.wbits, abits=cfg.abits,
            lr=cfg.lr * (0.3 if a2q_hard else 1.0),
            a2q_p=cfg.acc_bits if sched == "a2q" else None,
            a2q_shrink=0.0 if a2q_hard else 0.9,
        )
        losses.append(float(loss))
        if do_prune and epoch < ramp_end:
            target = cfg.sparsity * (epoch + 1) / ramp_end
            params, masks = prune_event(
                graph, params, masks, cfg, target, quant_signal=(sched == "qp")
            )

    acc_q = float(
        _eval_batched(params, masks, qstate, xe, ye, graph_key=gkey, qat=True,
                      wbits=cfg.wbits, abits=cfg.abits)
    )
    acc_fp = float(
        _eval_batched(params, masks, qstate, xe, ye, graph_key=gkey, qat=False,
                      wbits=cfg.wbits, abits=cfg.abits)
    )
    return TrainResult(
        graph=graph, params=params, masks=masks, qstate=qstate,
        acc_q=acc_q, acc_fp32=acc_fp, losses=losses,
        sparsity=achieved_sparsity(graph, params, masks),
    )
