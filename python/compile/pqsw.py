"""PQSW model container: serialize trained quantized models for the Rust engine.

Layout (little-endian; parsed by `rust/src/formats/pqsw.rs`):

    bytes 0..8    magic  b"PQSW1\\0\\0\\0"
    bytes 8..12   u32    header_len (JSON bytes)
    bytes 12..    header JSON, then zero padding to an 8-byte boundary
    ...           blob section; every blob starts 8-byte aligned

Header JSON schema:
    {
      "name": str, "arch": str, "schedule": str,
      "wbits": int, "abits": int, "nm_m": int,
      "target_sparsity": float, "achieved_sparsity": float,
      "acc_bits_trained": int | null,       # A2Q accumulator target
      "lowrank_k": int | null,
      "acc_q": float, "acc_fp32": float,    # python-side eval accuracies
      "input_shape": [c, h, w] | [dim],
      "graph": [node...],                   # model.py IR; q-layers extended:
          "w_scale": float, "x_scale": float, "x_offset": int,
          "wq_blob": int, "bias_blob": int
      "blobs": [{"offset": int, "len": int, "dtype": "i8"|"f32"|"i32"}]
      "format_version": 2,
      "sections": [{"tag": "checksums", "algo": "fnv1a64",
                    "layers": ["%016x" FNV-1a per q-layer, graph order]}]
    }

Weights are exported as int8 in (O, K) row-major layout where K is the
contraction length the accumulator sees (I*kh*kw for conv via im2col,
kh*kw for depthwise, in_features for linear). Quantization uses numpy
`round` (half-to-even) — the Rust side mirrors this exactly.
"""

from __future__ import annotations

import json
import struct

import numpy as np

from . import model as M
from . import quantize as Q

MAGIC = b"PQSW1\x00\x00\x00"

_FNV_OFFSET = 0xcbf29ce484222325
_FNV_PRIME = 0x100000001b3
_U64 = 0xFFFFFFFFFFFFFFFF


def _align8(n: int) -> int:
    return (n + 7) & ~7


def _fnv1a64(h: int, data: bytes) -> int:
    for b in data:
        h = ((h ^ b) * _FNV_PRIME) & _U64
    return h


def _layer_checksum(oc: int, k: int, wq: np.ndarray, bias: np.ndarray) -> int:
    """FNV-1a digest of one q-layer's shape + weights + bias.

    Mirrors `layer_checksum` in rust/src/formats/pqsw.rs exactly: oc and k
    as u64 little-endian, then the int8 weight bytes in (O, K) row-major
    order, then each bias value as f32 little-endian.
    """
    h = _FNV_OFFSET
    h = _fnv1a64(h, struct.pack("<Q", oc))
    h = _fnv1a64(h, struct.pack("<Q", k))
    h = _fnv1a64(h, np.ascontiguousarray(wq, dtype=np.int8).tobytes())
    h = _fnv1a64(h, np.ascontiguousarray(bias, dtype="<f4").tobytes())
    return h


def export_pqsw(
    path: str,
    name: str,
    result,
    cfg,
    input_shape: list[int],
) -> dict:
    """Write a TrainResult to a .pqsw file; returns the manifest entry."""
    graph_out = []
    blobs_meta: list[dict] = []
    blob_data: list[bytes] = []
    layer_sums: list[str] = []

    def add_blob(arr: np.ndarray, dtype: str) -> int:
        raw = arr.tobytes()
        blobs_meta.append({"dtype": dtype, "len": len(raw)})
        blob_data.append(raw)
        return len(blob_data) - 1

    for n in result.graph:
        node = dict(n)
        if n["op"] in ("qlinear", "qconv", "qdwconv"):
            nid = n["id"]
            w = np.asarray(result.params[f"w{nid}"], dtype=np.float64)
            mk = result.masks.get(f"w{nid}")
            if mk is not None:
                w = w * np.asarray(mk)
            wf = w.reshape(w.shape[0], -1)  # (O, K)
            if f"s{nid}" in result.params:  # learned scale (A2Q schedule)
                s = float(np.exp(np.asarray(result.params[f"s{nid}"])))
                qp_w = Q.QParams(scale=s, offset=0, bits=cfg.wbits)
            else:
                qp_w = Q.weight_qparams_np(wf, cfg.wbits)
            wq = Q.quantize_np(wf, qp_w).astype(np.int8)
            bias = np.asarray(result.params[f"b{nid}"], dtype=np.float32)
            lo, hi = [float(v) for v in np.asarray(result.qstate[f"a{nid}"])]
            qp_x = Q.act_qparams_np(lo, hi, cfg.abits)
            node["w_scale"] = qp_w.scale
            node["x_scale"] = qp_x.scale
            node["x_offset"] = qp_x.offset
            node["wq_blob"] = add_blob(wq, "i8")
            node["bias_blob"] = add_blob(bias, "f32")
            oc, k = wq.shape
            layer_sums.append("%016x" % _layer_checksum(oc, k, wq, bias))
        graph_out.append(node)

    header = {
        "name": name,
        "arch": cfg.arch,
        "schedule": cfg.schedule,
        "wbits": cfg.wbits,
        "abits": cfg.abits,
        "nm_m": cfg.nm_m,
        "target_sparsity": cfg.sparsity,
        "achieved_sparsity": result.sparsity,
        "acc_bits_trained": cfg.acc_bits,
        "lowrank_k": cfg.lowrank_k,
        "acc_q": result.acc_q,
        "acc_fp32": result.acc_fp32,
        "input_shape": input_shape,
        "graph": graph_out,
        "blobs": blobs_meta,
        # end-to-end integrity: the Rust loader recomputes these digests
        # from the live bytes and quarantines the model on any mismatch
        "format_version": 2,
        "sections": [
            {"tag": "checksums", "algo": "fnv1a64", "layers": layer_sums}
        ],
    }

    # lay out blob offsets relative to blob-section start
    off = 0
    for bm in blobs_meta:
        bm["offset"] = off
        off = _align8(off + bm["len"])

    hdr = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(hdr)))
        f.write(hdr)
        pad = _align8(12 + len(hdr)) - (12 + len(hdr))
        f.write(b"\x00" * pad)
        pos = 0
        for bm2, raw in zip(blobs_meta, blob_data):
            assert bm2["offset"] == pos, (bm2, pos)
            f.write(raw)
            pos += len(raw)
            apad = _align8(pos) - pos
            f.write(b"\x00" * apad)
            pos += apad

    return {
        "name": name,
        "file": path.split("/")[-1],
        "arch": cfg.arch,
        "schedule": cfg.schedule,
        "wbits": cfg.wbits,
        "abits": cfg.abits,
        "nm_m": cfg.nm_m,
        "target_sparsity": cfg.sparsity,
        "achieved_sparsity": result.sparsity,
        "acc_bits_trained": cfg.acc_bits,
        "lowrank_k": cfg.lowrank_k,
        "acc_q": result.acc_q,
        "acc_fp32": result.acc_fp32,
    }
