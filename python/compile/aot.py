"""AOT build orchestrator: datasets -> trainings -> PQSW models -> HLO text.

`make artifacts` runs `python -m compile.aot --out ../artifacts` once; Rust is
self-contained afterwards. Outputs:

  artifacts/datasets/*.bin            PQSD datasets (identical bytes for rust)
  artifacts/models/*.pqsw             trained quantized models (PQSW)
  artifacts/goldens/*.json            bit-exact dot-product / model goldens
  artifacts/model.hlo.txt             mlp1 quantized fwd via the Pallas kernel
  artifacts/hlo/*.hlo.txt             FP32 forwards for the PJRT fast path
  artifacts/manifest.json             experiment index consumed by the figures

HLO is exported as *text* (not serialized proto): jax >= 0.5 emits protos
with 64-bit instruction ids which xla_extension 0.5.1 rejects; the HLO text
parser reassigns ids (see /opt/xla-example/README.md).

Set PQS_QUICK=1 for a reduced matrix during development.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import datasets as D
from . import model as M
from . import quantize as Q
from . import train as T
from .kernels import ref
from .kernels.pqs_matmul import pqs_matmul
from .pqsw import export_pqsw

QUICK = os.environ.get("PQS_QUICK", "") not in ("", "0")

# dataset sizes (DESIGN.md §4: miniaturized substitutes)
MNIST_TRAIN, MNIST_TEST = 2560, 1024
CIFAR_TRAIN, CIFAR_TEST, CIFAR_SIZE = 1024, 512, 20

MLP_EPOCHS = 4 if QUICK else 12
CNN_EPOCHS = 2 if QUICK else 6


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is ESSENTIAL: the default printer elides
    # big weight arrays as `constant({...})`, which xla_extension 0.5.1's
    # text parser silently mis-parses into garbage values instead of
    # erroring. (Bug found the hard way — see EXPERIMENTS.md.)
    return comp.as_hlo_text(print_large_constants=True)


# ---------------------------------------------------------------------------
# experiment matrix
# ---------------------------------------------------------------------------

def mlp_cfg(**kw):
    base = dict(epochs=MLP_EPOCHS, qat_epochs=3 if not QUICK else 1, lr=5e-3, bs=128)
    base.update(kw)
    return T.TrainCfg(**base)


def cnn_cfg(**kw):
    base = dict(epochs=CNN_EPOCHS, qat_epochs=2 if not QUICK else 1, lr=4e-3, bs=128)
    base.update(kw)
    return T.TrainCfg(**base)


def build_matrix() -> dict[str, list[T.TrainCfg]]:
    """Experiment id -> list of training configs (see DESIGN.md §3)."""
    exps: dict[str, list[T.TrainCfg]] = {}

    # Fig. 2: 1-layer MLP, 8/8, dense — the overflow-profile workhorse.
    exps["fig2"] = [mlp_cfg(arch="mlp1", schedule="pq")]

    # Fig. 3: P->Q vs Q->P under low-rank approximation (hidden layer, M=32).
    ranks = [None, 10] if QUICK else [None, 64, 10, 5]
    spars = [0.5] if QUICK else [0.25, 0.5, 0.75, 0.9]
    exps["fig3"] = [
        mlp_cfg(arch="mlp2", schedule=s, sparsity=sp, nm_m=32, lowrank_k=k,
                arch_kw={"hidden": 256})
        for s in ("pq", "qp") for k in ranks for sp in spars
    ]

    # Fig. 4: CNN schedules (N:M with M=16 vs structured filter pruning).
    archs = ["resnet_tiny", "mbv2_tiny"]
    spars4 = [0.5] if QUICK else [0.25, 0.5, 0.75]
    scheds = ["pq", "qp"] if QUICK else ["pq", "qp", "filter"]
    exps["fig4"] = [
        cnn_cfg(arch=a, schedule=s, sparsity=sp, nm_m=16)
        for a in archs for s in scheds for sp in spars4
    ]

    # Fig. 5 extras: PQS pareto sweep (bitwidths x sparsity) + A2Q baseline.
    if QUICK:
        exps["fig5"] = [cnn_cfg(arch="resnet_tiny", schedule="a2q", acc_bits=16)]
    else:
        # A2Q pareto co-tunes weight bitwidth with the accumulator target, as
        # in the paper's Fig. 5 frontier (8-bit weights need p >= ~16; lower
        # p is reachable only with narrower weights).
        a2q_pts = [(8, 16), (6, 14), (5, 13), (4, 12)]
        exps["fig5"] = (
            [cnn_cfg(arch=a, schedule="pq", sparsity=0.875, nm_m=16) for a in archs]
            + [cnn_cfg(arch=a, schedule="pq", sparsity=sp, nm_m=16, wbits=6, abits=6)
               for a in archs for sp in (0.5, 0.75)]
            + [cnn_cfg(arch=a, schedule="a2q", wbits=w, abits=w, acc_bits=p,
                       epochs=CNN_EPOCHS + 2)
               for a in archs for (w, p) in a2q_pts]
            + [mlp_cfg(arch="mlp2", schedule="pq", sparsity=sp, nm_m=16,
                       wbits=w, abits=w, arch_kw={"hidden": 256})
               for w in (5, 6, 8) for sp in (0.75, 0.875)]
            + [mlp_cfg(arch="mlp2", schedule="a2q", wbits=w, abits=w, acc_bits=p,
                       epochs=MLP_EPOCHS + 4, arch_kw={"hidden": 256})
               for (w, p) in a2q_pts]
        )

    # FP32 baselines (accuracy reference lines in Figs. 2b/4/5).
    exps["fp32"] = [
        mlp_cfg(arch="mlp1", schedule="fp32"),
        mlp_cfg(arch="mlp2", schedule="fp32", arch_kw={"hidden": 256}),
    ] + [cnn_cfg(arch=a, schedule="fp32") for a in archs]
    return exps


def cfg_name(cfg: T.TrainCfg) -> str:
    parts = [cfg.arch, cfg.schedule, f"s{int(round(cfg.sparsity * 1000)):03d}",
             f"w{cfg.wbits}a{cfg.abits}"]
    if cfg.acc_bits is not None:
        parts.append(f"p{cfg.acc_bits}")
    if cfg.lowrank_k is not None:
        parts.append(f"k{cfg.lowrank_k}")
    if cfg.lowrank_k is None and cfg.arch == "mlp2":
        parts.append("kfull")
    return "_".join(parts)


# ---------------------------------------------------------------------------
# goldens
# ---------------------------------------------------------------------------

def export_dot_goldens(path: str, seed: int = 7) -> None:
    """Random dot products + expected results for every policy/bitwidth —
    the bit-exactness contract for rust/src/dot."""
    rng = np.random.default_rng(seed)
    cases = []
    for K in (8, 33, 256, 784):
        for bits in (4, 8):
            lim = 1 << (bits - 1)
            w = rng.integers(-(lim - 1), lim, K)
            x = rng.integers(-lim, lim, K)
            prods = (w * x).astype(np.int64)
            entry = {"w": w.tolist(), "x": x.tolist(), "results": {}}
            for p in (12, 14, 16, 20, 24):
                res = {}
                for pol in ref.POLICIES:
                    v, e = ref.dot_with_policy(prods, p, pol)
                    res[pol] = [int(v), int(e)]
                cls = ref.classify_overflow(prods, p)
                res["classify"] = [
                    int(cls["exact"]),
                    int(cls["persistent"]),
                    int(cls["naive_events"]),
                    int(cls["transient"]),
                ]
                entry["results"][str(p)] = res
            cases.append(entry)
    with open(path, "w") as f:
        json.dump({"cases": cases}, f)


def export_matmul_goldens(path: str, seed: int = 11) -> None:
    """Kernel-vs-rust matmul contract (the pallas kernel already equals ref)."""
    rng = np.random.default_rng(seed)
    cases = []
    for (m, k, n) in ((3, 17, 5), (4, 64, 8)):
        xq = rng.integers(-128, 128, (m, k)).astype(np.int32)
        wq = rng.integers(-127, 128, (k, n)).astype(np.int32)
        for p in (13, 16):
            for pol in ("exact", "clip", "wrap", "sorted1"):
                y, ev = pqs_matmul(xq, wq, acc_bits=p, policy=pol)
                cases.append({
                    "m": m, "k": k, "n": n, "p": p, "policy": pol,
                    "x": xq.flatten().tolist(), "w": wq.flatten().tolist(),
                    "y": np.asarray(y).flatten().tolist(),
                    "ovf": np.asarray(ev).flatten().tolist(),
                })
    with open(path, "w") as f:
        json.dump({"cases": cases}, f)


def export_model_golden(path: str, pqsw_path: str, x_test: np.ndarray) -> None:
    """End-to-end integer contract for the mlp1 model: quantized inputs,
    exact integer accumulators, and dequantized logits for 8 test images.

    Activations are quantized into the *offset-free* domain the accumulator
    sees: q~ = clamp(round(x/s), qlo - o, qhi - o) — the TFLite/CMSIS
    formulation when o_w = 0, mirrored by rust `quant::quantize_centered_*`.
    Dequantization is then z = s_w*s_x*acc + bias."""
    import struct

    with open(pqsw_path, "rb") as f:
        raw = f.read()
    hlen = struct.unpack("<I", raw[8:12])[0]
    hdr = json.loads(raw[12 : 12 + hlen])
    blob_base = (12 + hlen + 7) & ~7
    fc = [n for n in hdr["graph"] if n["op"] == "qlinear"][0]
    wb = hdr["blobs"][fc["wq_blob"]]
    bb = hdr["blobs"][fc["bias_blob"]]
    wq = np.frombuffer(
        raw[blob_base + wb["offset"] : blob_base + wb["offset"] + wb["len"]],
        dtype=np.int8,
    ).reshape(fc["oc"], fc["ic"]).astype(np.int64)
    bias = np.frombuffer(
        raw[blob_base + bb["offset"] : blob_base + bb["offset"] + bb["len"]],
        dtype=np.float32,
    )
    abits = hdr["abits"]
    s_x, o_x = fc["x_scale"], fc["x_offset"]
    qlo, qhi = -(1 << (abits - 1)), (1 << (abits - 1)) - 1
    xs = x_test[:8].reshape(8, -1).astype(np.float32)
    # f32 division + round-half-even, matching rust bit-for-bit
    xq = np.clip(
        np.round(xs / np.float32(s_x)).astype(np.int64), qlo - o_x, qhi - o_x
    )
    acc = xq @ wq.T  # exact integer accumulators (8, oc)
    logits = (fc["w_scale"] * s_x) * acc + bias[None, :]
    logits = np.maximum(logits, 0.0)  # mlp1 has trailing relu
    with open(path, "w") as f:
        json.dump({
            "model": os.path.basename(pqsw_path),
            "xq": xq.flatten().tolist(),
            "acc_exact": acc.flatten().tolist(),
            "logits": logits.flatten().tolist(),
            "shape": [8, int(fc["ic"]), int(fc["oc"])],
        }, f)


# ---------------------------------------------------------------------------
# HLO exports
# ---------------------------------------------------------------------------

def export_fp32_hlo(path: str, result, input_shape, batch: int = 8) -> None:
    """FP32 (fake-quant-weights) forward with baked weights, for the PJRT
    fast path in rust/src/runtime."""
    graph, params, masks, qstate = (
        result.graph, result.params, result.masks, result.qstate,
    )

    def fwd(x):
        logits, _ = M.forward(
            graph, params, masks, qstate, x,
            qat=False, wbits=8, abits=8, track=False,
        )
        return (logits,)

    spec = jax.ShapeDtypeStruct((batch, *input_shape), jnp.float32)
    lowered = jax.jit(fwd).lower(spec)
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))


def export_pqs_kernel_hlo(path: str, pqsw_path: str, batch: int = 8,
                          acc_bits: int = 16, policy: str = "sorted1") -> None:
    """The headline AOT artifact: mlp1 quantized forward built around the
    Layer-1 Pallas kernel (sorted low-bitwidth accumulation), lowered to HLO
    text and executed from Rust via PJRT. Outputs (logits f32[b,10],
    overflow_events i32[] total)."""
    import struct

    with open(pqsw_path, "rb") as f:
        raw = f.read()
    hlen = struct.unpack("<I", raw[8:12])[0]
    hdr = json.loads(raw[12 : 12 + hlen])
    blob_base = (12 + hlen + 7) & ~7
    fc = [n for n in hdr["graph"] if n["op"] == "qlinear"][0]
    wb = hdr["blobs"][fc["wq_blob"]]
    bb = hdr["blobs"][fc["bias_blob"]]
    wq = np.frombuffer(
        raw[blob_base + wb["offset"] : blob_base + wb["offset"] + wb["len"]],
        dtype=np.int8,
    ).reshape(fc["oc"], fc["ic"])
    bias = np.frombuffer(
        raw[blob_base + bb["offset"] : blob_base + bb["offset"] + bb["len"]],
        dtype=np.float32,
    )
    s_x, o_x, s_w = fc["x_scale"], fc["x_offset"], fc["w_scale"]
    abits = hdr["abits"]
    qlo, qhi = -(1 << (abits - 1)), (1 << (abits - 1)) - 1
    wq_t = jnp.asarray(wq.T.astype(np.int32))          # (K, N)
    bias_j = jnp.asarray(bias)

    def fwd(x):
        # offset-free activation quantization (matches the rust engine and
        # the model golden): q~ in [qlo - o_x, qhi - o_x]
        xf = x.reshape(batch, -1)
        xq = jnp.clip(jnp.round(xf / s_x), qlo - o_x, qhi - o_x).astype(jnp.int32)
        y, ovf = pqs_matmul(xq, wq_t, acc_bits=acc_bits, policy=policy)
        z = (s_w * s_x) * y.astype(jnp.float32) + bias_j[None, :]
        return (jax.nn.relu(z), jnp.sum(ovf))

    spec = jax.ShapeDtypeStruct((batch, 1, 28, 28), jnp.float32)
    lowered = jax.jit(fwd).lower(spec)
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    out = args.out
    for sub in ("datasets", "models", "goldens", "hlo"):
        os.makedirs(os.path.join(out, sub), exist_ok=True)

    t_start = time.time()
    print(f"[aot] QUICK={QUICK}")

    # 1. datasets ------------------------------------------------------------
    xm, ym = D.synth_mnist(MNIST_TRAIN, seed=1)
    xmt, ymt = D.synth_mnist(MNIST_TEST, seed=2)
    xc, yc = D.synth_cifar(CIFAR_TRAIN, seed=3, size=CIFAR_SIZE)
    xct, yct = D.synth_cifar(CIFAR_TEST, seed=4, size=CIFAR_SIZE)
    D.save_dataset(os.path.join(out, "datasets/synth_mnist_train.bin"), xm, ym)
    D.save_dataset(os.path.join(out, "datasets/synth_mnist_test.bin"), xmt, ymt)
    D.save_dataset(os.path.join(out, "datasets/synth_cifar_train.bin"), xc, yc)
    D.save_dataset(os.path.join(out, "datasets/synth_cifar_test.bin"), xct, yct)
    # reload so training sees the exact u8-rounded pixels rust will see
    xm, ym = D.load_dataset(os.path.join(out, "datasets/synth_mnist_train.bin"))
    xmt, ymt = D.load_dataset(os.path.join(out, "datasets/synth_mnist_test.bin"))
    xc, yc = D.load_dataset(os.path.join(out, "datasets/synth_cifar_train.bin"))
    xct, yct = D.load_dataset(os.path.join(out, "datasets/synth_cifar_test.bin"))
    mnist_data = (xm, ym, xmt, ymt)
    cifar_data = (xc, yc, xct, yct)
    print(f"[aot] datasets done {time.time()-t_start:.0f}s")

    # 2. trainings -----------------------------------------------------------
    exps = build_matrix()
    manifest = {"experiments": {}, "models": [], "datasets": {
        "mnist": {"train": "synth_mnist_train.bin", "test": "synth_mnist_test.bin",
                   "shape": [1, 28, 28]},
        "cifar": {"train": "synth_cifar_train.bin", "test": "synth_cifar_test.bin",
                   "shape": [3, CIFAR_SIZE, CIFAR_SIZE]},
    }, "quick": QUICK}
    seen: dict[str, dict] = {}
    results: dict[str, T.TrainResult] = {}
    for exp, cfgs in exps.items():
        names = []
        for cfg in cfgs:
            name = cfg_name(cfg)
            names.append(name)
            if name in seen:
                continue
            data = mnist_data if cfg.arch.startswith("mlp") else cifar_data
            in_shape = [1, 28, 28] if cfg.arch.startswith("mlp") else [3, CIFAR_SIZE, CIFAR_SIZE]
            t0 = time.time()
            res = T.train(cfg, data)
            entry = export_pqsw(
                os.path.join(out, f"models/{name}.pqsw"), name, res, cfg, in_shape
            )
            seen[name] = entry
            results[name] = res
            manifest["models"].append(entry)
            print(f"[aot] {exp:5s} {name:48s} acc_q={res.acc_q:.3f} "
                  f"fp32={res.acc_fp32:.3f} sp={res.sparsity:.2f} "
                  f"{time.time()-t0:.0f}s (total {time.time()-t_start:.0f}s)", flush=True)
        manifest["experiments"][exp] = names

    # 3. goldens ---------------------------------------------------------------
    export_dot_goldens(os.path.join(out, "goldens/dot_goldens.json"))
    export_matmul_goldens(os.path.join(out, "goldens/matmul_goldens.json"))
    mlp1_name = manifest["experiments"]["fig2"][0]
    export_model_golden(
        os.path.join(out, "goldens/model_golden.json"),
        os.path.join(out, f"models/{mlp1_name}.pqsw"),
        xmt,
    )
    print(f"[aot] goldens done {time.time()-t_start:.0f}s")

    # 4. HLO ---------------------------------------------------------------
    export_pqs_kernel_hlo(
        os.path.join(out, "model.hlo.txt"),
        os.path.join(out, f"models/{mlp1_name}.pqsw"),
    )
    hlo_index = {"model.hlo.txt": {"model": mlp1_name, "batch": 8,
                                    "acc_bits": 16, "policy": "sorted1",
                                    "outputs": ["logits", "ovf_total"]}}
    # FP32 fast-path graphs (PJRT baseline logits in rust/src/runtime).
    fp32_targets = [(mlp1_name, [1, 28, 28])]
    for nm in manifest["experiments"].get("fp32", []):
        shape = [1, 28, 28] if nm.startswith("mlp") else [3, CIFAR_SIZE, CIFAR_SIZE]
        fp32_targets.append((nm, shape))
    for nm, shape in fp32_targets:
        fname = f"hlo/{nm}_fp32.hlo.txt"
        export_fp32_hlo(os.path.join(out, fname), results[nm], shape)
        hlo_index[fname] = {"model": nm, "batch": 8, "outputs": ["logits"]}
    print(f"[aot] HLO artifacts done {time.time()-t_start:.0f}s")

    with open(os.path.join(out, "hlo/index.json"), "w") as f:
        json.dump(hlo_index, f, indent=1)
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] DONE in {time.time()-t_start:.0f}s — "
          f"{len(manifest['models'])} models")


if __name__ == "__main__":
    main()
