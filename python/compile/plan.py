"""Accumulator-budget planning + projection, Python side.

The missing half of the cross-language pipeline: `rust/src/plan/analytic.rs`
computes per-layer accumulator bounds and `rust/src/sweep/` projects
weights to a width budget; this module mirrors both **bit-for-bit** so a
training run can export already-projected, already-planned `.pqsw` files
that the Rust serving path enforces without recomputation. Parity is
pinned by known-answer tests on both sides
(`python/tests/test_plan.py` and `rust/tests/sweep.rs` share the same
constants, PR 8 checksum-KAT style).

Math recap (see `pqs::sweep` module docs for the derivation):

* The analytic bound treats every centered input coordinate
  ``x ∈ [xlo, xhi]`` adversarially: weight ``w`` contributes
  ``[min(w*xlo, w*xhi), max(w*xlo, w*xhi)]`` to the running sum. The
  final-sum interval bounds the sorting/exact policies; ``clip``/``wrap``
  accumulate in index order, so their interval tracks prefix extremes.
* Projection makes ``layer_bits(wq) <= budget`` true: optional N:M
  pruning first (keep the N largest-|w| per group of M, ties to the
  lower index — NumPy's stable argsort of descending magnitudes), then
  per-row integer soft-thresholding ``w' = sign(w) * max(|w| - tau, 0)``
  with the smallest ``tau`` whose shrunk row fits ``acc_range(budget)``.
  Every magnitude is non-increasing in ``tau``, so the fit predicate is
  monotone and the minimal ``tau`` is unique — the linear scan here and
  the binary search in Rust find the same value.

The exporter writes the projected weights with the plan embedded as a
format-version-2 ``"plan"`` section (schema = `AccumPlan::to_json`) next
to the ``"checksums"`` section, loadable by the Rust router unchanged.
"""

from __future__ import annotations

import json
import struct

import numpy as np

from .pqsw import MAGIC, _align8, _layer_checksum

SEQUENTIAL_POLICIES = ("clip", "wrap")
POLICIES = ("exact", "clip", "wrap", "sorted1", "sorted", "oracle")

# accum::acc_range shifts 1i64 by budget-1; mirror the Rust-side cap
MAX_BUDGET_BITS = 62


# ---- analytic bound (mirrors rust/src/accum + rust/src/plan/analytic.rs) --


def bits_for_value(v: int) -> int:
    """Smallest signed width holding ``v`` (two's complement, floor 2)."""
    v = int(v)
    mag = v if v >= 0 else ~v
    return max(mag.bit_length() + 1, 2)


def bits_for_range(lo: int, hi: int) -> int:
    return max(bits_for_value(lo), bits_for_value(hi))


def acc_range(bits: int) -> tuple[int, int]:
    return (-(1 << (bits - 1)), (1 << (bits - 1)) - 1)


def qrange(bits: int, offset: int) -> tuple[int, int]:
    """Quantized-domain range: symmetric without an offset (signed
    weights), full two's-complement with one (activations)."""
    if offset == 0:
        m = (1 << (bits - 1)) - 1
        return (-m, m)
    return (-(1 << (bits - 1)), (1 << (bits - 1)) - 1)


def centered_window(x_offset: int, abits: int) -> tuple[int, int]:
    """The centered integer window ``[qlo - o, qhi - o]`` the accumulator
    sees (always contains 0)."""
    qlo, qhi = qrange(abits, x_offset)
    return (qlo - x_offset, qhi - x_offset)


def row_range(row, window: tuple[int, int], policy: str) -> tuple[int, int]:
    """Worst-case accumulator interval of one weight row (mirrors
    ``pqs::plan::row_range``): final-sum interval for the sorting
    policies, index-order prefix interval for ``clip``/``wrap``."""
    xlo, xhi = window
    sequential = policy in SEQUENTIAL_POLICIES
    lo = hi = 0
    row_lo = row_hi = 0
    for v in np.asarray(row).ravel():
        v = int(v)
        a, b = v * xlo, v * xhi
        hi += max(a, b)
        lo += min(a, b)
        if sequential:
            row_hi = max(row_hi, hi)
            row_lo = min(row_lo, lo)
    if not sequential:
        row_lo = min(lo, 0)
        row_hi = max(hi, 0)
    return (row_lo, row_hi)


def row_bits(row, window: tuple[int, int], policy: str) -> int:
    return bits_for_range(*row_range(row, window, policy))


def layer_bits(wq, window: tuple[int, int], policy: str) -> int:
    """Minimal width with the per-policy overflow guarantee for every
    output row of a (O, K) weight matrix (``analytic_layer_bits``)."""
    wq = np.asarray(wq)
    lo = hi = 0
    for r in range(wq.shape[0]):
        rlo, rhi = row_range(wq[r], window, policy)
        lo, hi = min(lo, rlo), max(hi, rhi)
    return bits_for_range(lo, hi)


# ---- projection (mirrors rust/src/sweep/mod.rs) ---------------------------


def nm_prune(wq, keep: int, m: int):
    """Keep the ``keep`` largest-|w| per group of ``m`` consecutive
    weights along the contraction axis; ties keep the lower index (stable
    argsort). Returns (pruned_wq, zeroed_count)."""
    wq = np.array(wq, dtype=np.int8, copy=True)
    if m <= 0 or keep >= m:
        return wq, 0
    zeroed = 0
    for r in range(wq.shape[0]):
        row = wq[r]
        for g0 in range(0, row.shape[0], m):
            g = row[g0 : g0 + m]
            order = np.argsort(-np.abs(g.astype(np.int32)), kind="stable")
            for i in order[keep:]:
                if g[i] != 0:
                    g[i] = 0
                    zeroed += 1
    return wq, zeroed


def soft_threshold(row, tau: int):
    """``sign(w) * max(|w| - tau, 0)`` — the ℓ1-projection shrink step."""
    r = np.asarray(row, dtype=np.int32)
    out = np.sign(r) * np.maximum(np.abs(r) - int(tau), 0)
    return out.astype(np.int8)


def smallest_tau(row, window, policy: str, budget: int) -> int:
    """Smallest integer ``tau`` whose soft-thresholded row fits
    ``acc_range(budget)``. Monotone predicate ⇒ unique minimum; Rust
    binary-searches, this scans — same answer. ``tau = 128`` zeroes any
    int8 row, so a result always exists for ``budget >= 2``."""
    blo, bhi = acc_range(budget)
    for tau in range(0, 129):
        lo, hi = row_range(soft_threshold(row, tau), window, policy)
        if lo >= blo and hi <= bhi:
            return tau
    raise AssertionError("tau=128 zeroes the row; unreachable for budget >= 2")


def project_matrix(wq, window, policy: str, budget: int, nm=None):
    """Project one (O, K) int8 weight matrix so ``layer_bits <= budget``.

    Returns ``(projected, report)`` where report carries
    ``tau_max/pruned/clipped`` (the same counters Rust's
    ``LayerProjection`` reports).
    """
    if not 2 <= budget <= MAX_BUDGET_BITS:
        raise ValueError(f"budget {budget} out of range 2..={MAX_BUDGET_BITS}")
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}")
    wq = np.array(wq, dtype=np.int8, copy=True)
    pruned = 0
    if nm is not None:
        keep, m = nm
        if not 1 <= keep <= m:
            raise ValueError(f"N:M spec {keep}:{m}: need 1 <= N <= M")
        wq, pruned = nm_prune(wq, keep, m)
    tau_max = 0
    clipped = 0
    for r in range(wq.shape[0]):
        tau = smallest_tau(wq[r], window, policy, budget)
        if tau > 0:
            tau_max = max(tau_max, tau)
            shrunk = soft_threshold(wq[r], tau)
            clipped += int(np.count_nonzero(shrunk != wq[r]))
            wq[r] = shrunk
    got = layer_bits(wq, window, policy)
    assert got <= budget, f"projected to {got} bits > budget {budget}"
    return wq, {"tau_max": tau_max, "pruned": pruned, "clipped": clipped}


# ---- plan section + projected-model exporter ------------------------------


def plan_section(policy: str, layers: list[dict]) -> dict:
    """The ``"plan"`` section dict (schema = ``AccumPlan::to_json`` in
    rust/src/plan/mod.rs; planner ``analytic``, projection-style plans
    carry no calibration)."""
    return {
        "tag": "plan",
        "v": 1,
        "policy": policy,
        "planner": "analytic",
        "budget": 0.0,
        "margin": 0,
        "samples": 0,
        "layers": [
            {
                "name": l["name"],
                "k": l["k"],
                "nnz_max": l["nnz_max"],
                "analytic_bits": l["analytic_bits"],
                "calibrated_bits": None,
                "acc_bits": l["acc_bits"],
            }
            for l in layers
        ],
    }


def synthetic_linear(dim: int, classes: int) -> dict:
    """The Rust ``models::synthetic_linear`` fixture, reproduced exactly —
    the shared model the cross-language known-answer tests pin."""
    o = np.arange(classes)[:, None]
    k = np.arange(dim)[None, :]
    wq = ((o * 31 + k * 7) % 11 - 5).astype(np.int8)
    return {
        "name": f"synthetic_linear_{dim}x{classes}",
        "arch": "mlp1",
        "schedule": "pq",
        "wbits": 8,
        "abits": 8,
        "nm_m": 0,
        "input_shape": [1, dim, 1],
        "layers": [
            {
                "op": "qlinear",
                "name": "fc",
                "oc": classes,
                "ic": dim,
                "kh": 1,
                "kw": 1,
                "stride": 1,
                "pad": 0,
                "prune": False,
                "w_scale": 0.05,
                "x_scale": 1.0 / 255.0,
                "x_offset": -128,
                "wq": wq,
                "bias": np.zeros(classes, dtype=np.float32),
            }
        ],
    }


def project_model(model: dict, budget: int, policy: str = "sorted", nm=None) -> dict:
    """Project every q-layer of a ``synthetic_linear``-style model dict in
    place (wq arrays replaced) and attach the resulting plan section as
    ``model["plan"]``. Returns a per-layer projection report."""
    abits = model["abits"]
    plan_rows = []
    report = {}
    for layer in model["layers"]:
        window = centered_window(layer["x_offset"], abits)
        wq, rep = project_matrix(layer["wq"], window, policy, budget, nm=nm)
        layer["wq"] = wq
        if nm is not None:
            layer["prune"] = True
        bits = layer_bits(wq, window, policy)
        plan_rows.append(
            {
                "name": layer["name"],
                "k": int(wq.shape[1]),
                "nnz_max": int(max(np.count_nonzero(wq[r]) for r in range(wq.shape[0]))),
                "analytic_bits": bits,
                "acc_bits": bits,
            }
        )
        report[layer["name"]] = dict(rep, bits=bits)
    if nm is not None:
        model["nm_m"] = nm[1]
    total = sum(int(np.asarray(l["wq"]).size) for l in model["layers"])
    zeros = sum(int(np.sum(np.asarray(l["wq"]) == 0)) for l in model["layers"])
    model["achieved_sparsity"] = zeros / total if total else 0.0
    model["plan"] = plan_section(policy, plan_rows)
    return report


def export_projected_pqsw(path: str, model: dict) -> None:
    """Write a projected model dict as a format-version-2 ``.pqsw`` with
    ``plan`` + ``checksums`` sections (the layout `export_pqsw` uses; the
    Rust loader verifies the digests and enforces the plan as-is)."""
    blobs_meta: list[dict] = []
    blob_data: list[bytes] = []
    layer_sums: list[str] = []

    def add_blob(arr: np.ndarray, dtype: str) -> int:
        raw = arr.tobytes()
        blobs_meta.append({"dtype": dtype, "len": len(raw)})
        blob_data.append(raw)
        return len(blob_data) - 1

    graph_out = [
        {"id": 0, "op": "input", "inputs": []},
        {"id": 1, "op": "flatten", "inputs": [0]},
    ]
    for layer in model["layers"]:
        wq = np.ascontiguousarray(layer["wq"], dtype=np.int8)
        bias = np.ascontiguousarray(layer["bias"], dtype="<f4")
        node = {
            "id": len(graph_out),
            "op": layer["op"],
            "inputs": [len(graph_out) - 1],
            "name": layer["name"],
            "oc": layer["oc"],
            "ic": layer["ic"],
            "kh": layer["kh"],
            "kw": layer["kw"],
            "stride": layer["stride"],
            "pad": layer["pad"],
            "prune": layer["prune"],
            "w_scale": layer["w_scale"],
            "x_scale": layer["x_scale"],
            "x_offset": layer["x_offset"],
            "wq_blob": add_blob(wq, "i8"),
            "bias_blob": add_blob(bias, "f32"),
        }
        oc, k = wq.shape
        layer_sums.append("%016x" % _layer_checksum(oc, k, wq, bias))
        graph_out.append(node)

    header = {
        "name": model["name"],
        "arch": model["arch"],
        "schedule": model["schedule"],
        "wbits": model["wbits"],
        "abits": model["abits"],
        "nm_m": model.get("nm_m", 0),
        "target_sparsity": model.get("target_sparsity", 0.0),
        "achieved_sparsity": model.get("achieved_sparsity", 0.0),
        "acc_bits_trained": None,
        "lowrank_k": None,
        "acc_q": 0.0,
        "acc_fp32": 0.0,
        "input_shape": model["input_shape"],
        "graph": graph_out,
        "blobs": blobs_meta,
        "format_version": 2,
        "sections": [
            model["plan"],
            {"tag": "checksums", "algo": "fnv1a64", "layers": layer_sums},
        ],
    }

    off = 0
    for bm in blobs_meta:
        bm["offset"] = off
        off = _align8(off + bm["len"])

    hdr = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(hdr)))
        f.write(hdr)
        pad = _align8(12 + len(hdr)) - (12 + len(hdr))
        f.write(b"\x00" * pad)
        pos = 0
        for bm2, raw in zip(blobs_meta, blob_data):
            assert bm2["offset"] == pos, (bm2, pos)
            f.write(raw)
            pos += len(raw)
            apad = _align8(pos) - pos
            f.write(b"\x00" * apad)
            pos += apad
