"""Deterministic synthetic datasets substituting MNIST / CIFAR-10.

This environment has no network access, so the paper's datasets are replaced
by procedurally generated equivalents (DESIGN.md §4 documents the
substitution argument):

  * `synth_mnist`  — 28x28 grayscale digit glyphs (hand-drawn 7x5 bitmaps,
    upscaled) with random translation, thickness jitter, contrast scaling
    and Gaussian noise. 10 classes; learnable to >95% by a small MLP.
  * `synth_cifar`  — `size` x `size` RGB images; class = (shape, hue) combo
    out of 5 shapes x 2 hue families, with textured backgrounds, random
    placement and noise. Learnable by a small CNN; activations after ReLU
    are half-normal-ish, matching the overflow statistics that matter.

Everything is generated from a fixed seed; `aot.py` exports the raw bytes to
`artifacts/datasets/` so the Rust engine evaluates *identical* inputs.

Binary format (read by `rust/src/data/loader.rs`):
  magic  b"PQSD1\\0\\0\\0"
  u32le  n, c, h, w
  u8     images  [n*c*h*w]   (pixel value 0..255; engine maps to f32/255)
  u8     labels  [n]
"""

from __future__ import annotations

import json
import os
import struct

import numpy as np

# 7x5 digit glyphs (classic seven-segment-ish bitmaps).
_GLYPHS = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00110", "01000", "10000", "11111"],
    3: ["11110", "00001", "00001", "01110", "00001", "00001", "11110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}


def _glyph_array(d: int) -> np.ndarray:
    return np.array([[int(c) for c in row] for row in _GLYPHS[d]], dtype=np.float32)


def synth_mnist(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Returns (images f32 [n,1,28,28] in [0,1], labels u8 [n])."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, n).astype(np.uint8)
    imgs = np.zeros((n, 1, 28, 28), dtype=np.float32)
    for i in range(n):
        g = _glyph_array(int(labels[i]))
        # upscale 7x5 -> (7*sy)x(5*sx) with random stroke scale 2..3
        sy = int(rng.integers(2, 4))
        sx = int(rng.integers(2, 4))
        big = np.kron(g, np.ones((sy, sx), dtype=np.float32))
        hh, ww = big.shape
        # near-centered placement (+-2 px): keeps a linear classifier viable,
        # like MNIST itself, while still providing positional variation.
        cy0, cx0 = (28 - hh) // 2, (28 - ww) // 2
        oy = int(np.clip(cy0 + rng.integers(-2, 3), 0, 28 - hh))
        ox = int(np.clip(cx0 + rng.integers(-2, 3), 0, 28 - ww))
        canvas = np.zeros((28, 28), dtype=np.float32)
        canvas[oy : oy + hh, ox : ox + ww] = big
        contrast = 0.6 + 0.4 * rng.random()
        canvas = canvas * contrast + rng.normal(0, 0.08, (28, 28)).astype(np.float32)
        imgs[i, 0] = np.clip(canvas, 0.0, 1.0)
    return imgs, labels


_HUES = [  # (r, g, b) base colors: two clearly separated hue families
    (0.95, 0.35, 0.10),
    (0.10, 0.40, 0.95),
]


def _shape_mask(shape_id: int, size: int, cy: float, cx: float, r: float) -> np.ndarray:
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32)
    dy, dx = yy - cy, xx - cx
    if shape_id == 0:  # disk
        return ((dy**2 + dx**2) <= r * r).astype(np.float32)
    if shape_id == 1:  # square
        return ((np.abs(dy) <= r) & (np.abs(dx) <= r)).astype(np.float32)
    if shape_id == 2:  # cross
        return ((np.abs(dy) <= r / 2.5) | (np.abs(dx) <= r / 2.5)).astype(
            np.float32
        ) * ((np.abs(dy) <= r) & (np.abs(dx) <= r))
    if shape_id == 3:  # horizontal stripes
        return (((yy // max(2, int(r / 2))) % 2 == 0) & (dy**2 + dx**2 <= (1.4 * r) ** 2)).astype(np.float32)
    # vertical stripes
    return (((xx // max(2, int(r / 2))) % 2 == 0) & (dy**2 + dx**2 <= (1.4 * r) ** 2)).astype(np.float32)


def synth_cifar(n: int, seed: int, size: int = 24) -> tuple[np.ndarray, np.ndarray]:
    """Returns (images f32 [n,3,size,size] in [0,1], labels u8 [n]).

    Class c in 0..9 maps to shape = c % 5, hue family = c // 5."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, n).astype(np.uint8)
    imgs = np.zeros((n, 3, size, size), dtype=np.float32)
    for i in range(n):
        c = int(labels[i])
        shape_id, hue_id = c % 5, c // 5
        base = np.array(_HUES[hue_id], dtype=np.float32)
        # textured background
        bg = rng.normal(0.32, 0.10, (3, size, size)).astype(np.float32)
        cy = size / 2 + rng.uniform(-size / 8, size / 8)
        cx = size / 2 + rng.uniform(-size / 8, size / 8)
        r = size * (0.22 + 0.14 * rng.random())
        mask = _shape_mask(shape_id, size, cy, cx, r)
        jitter = rng.normal(0, 0.06, 3).astype(np.float32)
        color = np.clip(base + jitter, 0.05, 1.0)
        img = bg * (1 - mask)[None] + (color[:, None, None] * (0.8 + 0.2 * rng.random())) * mask[None]
        img += rng.normal(0, 0.03, (3, size, size)).astype(np.float32)
        imgs[i] = np.clip(img, 0.0, 1.0)
    return imgs, labels


MAGIC = b"PQSD1\x00\x00\x00"


def save_dataset(path: str, imgs: np.ndarray, labels: np.ndarray) -> None:
    """Write the PQSD binary + sidecar meta JSON (see module docstring)."""
    n, c, h, w = imgs.shape
    u8 = np.clip(np.round(imgs * 255.0), 0, 255).astype(np.uint8)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<IIII", n, c, h, w))
        f.write(u8.tobytes())
        f.write(labels.astype(np.uint8).tobytes())
    with open(os.path.splitext(path)[0] + ".meta.json", "w") as f:
        json.dump({"n": n, "c": c, "h": h, "w": w, "classes": 10}, f)


def load_dataset(path: str) -> tuple[np.ndarray, np.ndarray]:
    """Round-trip reader (used by tests and by training after export, so the
    *quantized-to-u8* pixels seen by python training match rust exactly)."""
    with open(path, "rb") as f:
        assert f.read(8) == MAGIC, "bad PQSD magic"
        n, c, h, w = struct.unpack("<IIII", f.read(16))
        imgs = np.frombuffer(f.read(n * c * h * w), dtype=np.uint8)
        labels = np.frombuffer(f.read(n), dtype=np.uint8)
    return (
        imgs.reshape(n, c, h, w).astype(np.float32) / 255.0,
        labels.copy(),
    )
