"""Uniform per-tensor quantization (paper Section 2.1) + QAT fake-quant.

Conventions (mirrored bit-exactly by `rust/src/quant`):

  * Weights: symmetric signed b-bit, offset o_w = 0 (paper §2.1 follows
    common practice), scale s_w = max|W| / (2^(b-1) - 1), values clamped to
    [-(2^(b-1)-1), 2^(b-1)-1].
  * Activations: affine b-bit per Eq. (1): s_x = R / (2^b - 1),
    o_x = -2^(b-1) - round(min/s_x), q = clamp(round(x/s_x) + o_x,
    -2^(b-1), 2^(b-1)-1). Ranges come from EMA min/max statistics collected
    during QAT (the `QState` carried through training).
  * Rounding is round-half-away-from-zero? No — we standardise on
    numpy/jax `round` (banker's rounding, round-half-to-even) in BOTH
    layers so integer parity holds.

The integer inference identity used by the Rust engine:

    z_f = s_w * s_x * (sum_k w_q x_q  -  o_x * sum_k w_q) + bias

where `sum_k w_q x_q` is the width-limited accumulation the paper studies
and `o_x * sum_k w_q` is a per-output constant (the activation-offset
correction) computed outside the accumulator.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class QParams(NamedTuple):
    scale: float
    offset: int  # 0 for weights (symmetric)
    bits: int


# ---------------------------------------------------------------------------
# numpy side (export / bit-exact helpers)
# ---------------------------------------------------------------------------

def weight_qparams_np(w: np.ndarray, bits: int) -> QParams:
    """Symmetric per-tensor weight quantization parameters."""
    amax = float(np.max(np.abs(w))) if w.size else 0.0
    qmax = (1 << (bits - 1)) - 1
    scale = amax / qmax if amax > 0 else 1.0
    return QParams(scale=scale, offset=0, bits=bits)


def act_qparams_np(lo: float, hi: float, bits: int) -> QParams:
    """Affine activation quantization parameters per Eq. (1)."""
    lo = min(lo, 0.0)  # always representable zero
    hi = max(hi, lo + 1e-8)
    scale = (hi - lo) / ((1 << bits) - 1)
    offset = int(-(1 << (bits - 1)) - np.round(lo / scale))
    return QParams(scale=scale, offset=offset, bits=bits)


def quantize_np(x: np.ndarray, qp: QParams) -> np.ndarray:
    """f32 -> integer values (int32 carrier) with clamping."""
    if qp.offset == 0:
        qmax = (1 << (qp.bits - 1)) - 1
        q = np.round(x / qp.scale).astype(np.int64)
        return np.clip(q, -qmax, qmax).astype(np.int32)
    lo, hi = -(1 << (qp.bits - 1)), (1 << (qp.bits - 1)) - 1
    q = np.round(x / qp.scale).astype(np.int64) + qp.offset
    return np.clip(q, lo, hi).astype(np.int32)


def dequantize_np(q: np.ndarray, qp: QParams) -> np.ndarray:
    return (q.astype(np.float64) - qp.offset).astype(np.float32) * np.float32(
        qp.scale
    )


# ---------------------------------------------------------------------------
# jax side (QAT fake-quant with straight-through estimator)
# ---------------------------------------------------------------------------

def fake_quant_weight(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Symmetric fake-quant with STE: forward quantize/dequantize, identity
    gradient. Scale is derived from the live tensor (per-tensor max)."""
    qmax = (1 << (bits - 1)) - 1
    amax = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)
    scale = amax / qmax
    wq = jnp.clip(jnp.round(w / scale), -qmax, qmax) * scale
    return w + jax.lax.stop_gradient(wq - w)


def fake_quant_weight_lsq(w: jnp.ndarray, log_s: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Symmetric fake-quant against a *learned* per-tensor scale exp(log_s)
    (LSQ-style, used by the A2Q schedule). Forward: s * clip(round(w/s));
    backward: through the soft clip, so gradients reach both w and log_s.
    Decoupling the scale from max|w| is what makes the A2Q L1 projection a
    genuine convex projection instead of a max-chasing spiral."""
    qmax = (1 << (bits - 1)) - 1
    s = jnp.exp(log_s)
    hard = jnp.clip(jnp.round(w / s), -qmax, qmax) * s
    soft = jnp.clip(w, -qmax * s, qmax * s)
    return soft + jax.lax.stop_gradient(hard - soft)


def fake_quant_act(x: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Affine fake-quant of activations against an externally tracked
    (lo, hi) range (EMA statistics), with STE."""
    lo = jnp.minimum(lo, 0.0)
    hi = jnp.maximum(hi, lo + 1e-8)
    scale = (hi - lo) / ((1 << bits) - 1)
    qlo, qhi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    offset = -(1 << (bits - 1)) - jnp.round(lo / scale)
    q = jnp.clip(jnp.round(x / scale) + offset, qlo, qhi)
    xq = (q - offset) * scale
    return x + jax.lax.stop_gradient(xq - x)


def ema_update(stat: jnp.ndarray, new: jnp.ndarray, decay: float = 0.9) -> jnp.ndarray:
    return decay * stat + (1.0 - decay) * new
