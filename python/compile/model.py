"""Layer-2: JAX model definitions + QAT forward for the PQS reproduction.

Models are described by a small graph IR (list of node dicts) shared across
the whole stack: python trains/ exports it, `pqsw.py` serializes it, and the
Rust engine (`rust/src/nn/graph.rs`) interprets the very same structure for
bit-accurate integer inference.

Node schema:
  {"id": int, "op": str, "inputs": [int], ...}
  ops: input | relu | add | gap | flatten | qlinear | qconv | qdwconv
  q-layers carry: name, oc, ic, kh, kw, stride, pad, prune (bool)

Architectures (CIFAR-substitute sizes; DESIGN.md §4 records the paper->here
miniaturization):
  mlp1        — paper §3.1 Fig. 2: 1-layer MLP (linear 784->10 + ReLU)
  mlp2        — paper §4 Fig. 3: hidden linear + classifier head
  resnet_tiny — paper §5 ResNet-18 stand-in: 3 residual stages, no BN
  mbv2_tiny   — paper §5 MobileNetV2 stand-in: inverted residual blocks
                (expand 1x1 -> depthwise 3x3 -> project 1x1, skip on same
                shape), no BN

The first conv and the final classifier are never pruned (paper §5.0.2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize as Q

# ---------------------------------------------------------------------------
# graph builders
# ---------------------------------------------------------------------------

def _node(nid, op, inputs, **kw):
    d = {"id": nid, "op": op, "inputs": inputs}
    d.update(kw)
    return d


def mlp1(in_dim: int = 784, classes: int = 10) -> list[dict]:
    return [
        _node(0, "input", []),
        _node(1, "flatten", [0]),
        _node(2, "qlinear", [1], name="fc", oc=classes, ic=in_dim, prune=True),
        _node(3, "relu", [2]),
    ]


def mlp2(in_dim: int = 784, hidden: int = 256, classes: int = 10) -> list[dict]:
    return [
        _node(0, "input", []),
        _node(1, "flatten", [0]),
        _node(2, "qlinear", [1], name="hidden", oc=hidden, ic=in_dim, prune=True),
        _node(3, "relu", [2]),
        _node(4, "qlinear", [3], name="head", oc=classes, ic=hidden, prune=False),
    ]


def _conv(nid, src, name, ic, oc, k=3, stride=1, pad=1, prune=True, dw=False):
    return _node(
        nid,
        "qdwconv" if dw else "qconv",
        [src],
        name=name,
        oc=oc,
        ic=ic,
        kh=k,
        kw=k,
        stride=stride,
        pad=pad,
        prune=prune,
    )


def resnet_tiny(classes: int = 10, w0: int = 8, w1: int = 16, w2: int = 32) -> list[dict]:
    g = []
    nid = 0

    def nxt():
        nonlocal nid
        nid += 1
        return nid

    g.append(_node(0, "input", []))
    c0 = nxt(); g.append(_conv(c0, 0, "conv0", 3, w0, prune=False))
    r0 = nxt(); g.append(_node(r0, "relu", [c0]))

    def basic_block(src, ic, oc, stride, tag):
        a = nxt(); g.append(_conv(a, src, f"{tag}_a", ic, oc, stride=stride))
        ra = nxt(); g.append(_node(ra, "relu", [a]))
        b = nxt(); g.append(_conv(b, ra, f"{tag}_b", oc, oc))
        if stride != 1 or ic != oc:
            s = nxt(); g.append(_conv(s, src, f"{tag}_skip", ic, oc, k=1, stride=stride, pad=0))
            skip = s
        else:
            skip = src
        ad = nxt(); g.append(_node(ad, "add", [b, skip]))
        r = nxt(); g.append(_node(r, "relu", [ad]))
        return r

    x = basic_block(r0, w0, w0, 1, "s1b1")
    x = basic_block(x, w0, w1, 2, "s2b1")
    x = basic_block(x, w1, w2, 2, "s3b1")
    gp = nxt(); g.append(_node(gp, "gap", [x]))
    fc = nxt(); g.append(_node(fc, "qlinear", [gp], name="head", oc=classes, ic=w2, prune=False))
    return g


def mbv2_tiny(classes: int = 10, c0: int = 8, c1: int = 16, c2: int = 24, t: int = 2) -> list[dict]:
    g = []
    nid = 0

    def nxt():
        nonlocal nid
        nid += 1
        return nid

    g.append(_node(0, "input", []))
    cv = nxt(); g.append(_conv(cv, 0, "conv0", 3, c0, prune=False))
    rv = nxt(); g.append(_node(rv, "relu", [cv]))
    x, xc = rv, c0

    def inverted_residual(src, ic, oc, stride, tag):
        mid = ic * t
        e = nxt(); g.append(_conv(e, src, f"{tag}_exp", ic, mid, k=1, pad=0))
        re_ = nxt(); g.append(_node(re_, "relu", [e]))
        d = nxt(); g.append(_conv(d, re_, f"{tag}_dw", mid, mid, stride=stride, dw=True))
        rd = nxt(); g.append(_node(rd, "relu", [d]))
        p = nxt(); g.append(_conv(p, rd, f"{tag}_proj", mid, oc, k=1, pad=0))
        if stride == 1 and ic == oc:
            a = nxt(); g.append(_node(a, "add", [p, src]))
            return a
        return p

    x = inverted_residual(x, xc, c0, 1, "ir1"); xc = c0
    x = inverted_residual(x, xc, c1, 2, "ir2"); xc = c1
    x = inverted_residual(x, xc, c1, 1, "ir3")
    x = inverted_residual(x, xc, c2, 2, "ir4"); xc = c2
    gp = nxt(); g.append(_node(gp, "gap", [x]))
    fc = nxt(); g.append(_node(fc, "qlinear", [gp], name="head", oc=classes, ic=xc, prune=False))
    return g


ARCHS = {
    "mlp1": mlp1,
    "mlp2": mlp2,
    "resnet_tiny": resnet_tiny,
    "mbv2_tiny": mbv2_tiny,
}


def q_layers(graph: list[dict]) -> list[dict]:
    return [n for n in graph if n["op"] in ("qlinear", "qconv", "qdwconv")]


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_params(graph: list[dict], seed: int) -> dict[str, jnp.ndarray]:
    rng = np.random.default_rng(seed)
    params = {}
    for n in q_layers(graph):
        nid = n["id"]
        if n["op"] == "qlinear":
            fan_in = n["ic"]
            shape = (n["oc"], n["ic"])
        elif n["op"] == "qconv":
            fan_in = n["ic"] * n["kh"] * n["kw"]
            shape = (n["oc"], n["ic"], n["kh"], n["kw"])
        else:  # qdwconv: oc == ic, one filter per channel
            fan_in = n["kh"] * n["kw"]
            shape = (n["oc"], 1, n["kh"], n["kw"])
        std = float(np.sqrt(2.0 / fan_in))
        params[f"w{nid}"] = jnp.asarray(
            rng.normal(0, std, shape).astype(np.float32)
        )
        params[f"b{nid}"] = jnp.zeros((n["oc"],), jnp.float32)
    return params


def init_masks(graph: list[dict]) -> dict[str, jnp.ndarray]:
    return {
        f"w{n['id']}": jnp.ones_like(jnp.zeros(1))  # placeholder replaced below
        for n in ()
    }


def ones_masks(params: dict) -> dict:
    return {k: jnp.ones_like(v) for k, v in params.items() if k.startswith("w")}


def init_qstate(graph: list[dict]) -> dict[str, jnp.ndarray]:
    """Per-q-layer EMA (lo, hi) of the layer-*input* activation range."""
    return {f"a{n['id']}": jnp.array([0.0, 1.0], jnp.float32) for n in q_layers(graph)}


# ---------------------------------------------------------------------------
# forward interpreter
# ---------------------------------------------------------------------------

def _conv2d(x, w, stride, pad, groups=1):
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups,
    )


def forward(
    graph: list[dict],
    params: dict,
    masks: dict,
    qstate: dict,
    x: jnp.ndarray,
    *,
    qat: bool,
    wbits: int,
    abits: int,
    track: bool,
    ema_decay: float = 0.95,
):
    """Run the graph. Returns (logits, new_qstate).

    qat=True inserts fake-quant (STE) on every q-layer's input activations
    and weights; track=True updates the EMA activation-range statistics.
    """
    vals: dict[int, jnp.ndarray] = {}
    new_state = dict(qstate)
    out_id = graph[-1]["id"]
    for n in graph:
        op, nid = n["op"], n["id"]
        ins = [vals[i] for i in n["inputs"]]
        if op == "input":
            v = x
        elif op == "relu":
            v = jax.nn.relu(ins[0])
        elif op == "add":
            v = ins[0] + ins[1]
        elif op == "gap":
            v = jnp.mean(ins[0], axis=(2, 3))
        elif op == "flatten":
            v = ins[0].reshape(ins[0].shape[0], -1)
        else:  # q-layer
            xin = ins[0]
            if track:
                key = f"a{nid}"
                lo, hi = new_state[key][0], new_state[key][1]
                blo = jnp.minimum(jnp.min(xin), 0.0)
                bhi = jnp.max(xin)
                new_state[key] = jnp.stack(
                    [Q.ema_update(lo, blo, ema_decay), Q.ema_update(hi, bhi, ema_decay)]
                )
            w = params[f"w{nid}"]
            mk = masks.get(f"w{nid}")
            if mk is not None:
                w = w * mk
            b = params[f"b{nid}"]
            if qat:
                key = f"a{nid}"
                xin = Q.fake_quant_act(xin, qstate[key][0], qstate[key][1], abits)
                if f"s{nid}" in params:  # learned scale (A2Q schedule)
                    w = Q.fake_quant_weight_lsq(w, params[f"s{nid}"], wbits)
                else:
                    w = Q.fake_quant_weight(w, wbits)
            if op == "qlinear":
                v = xin @ w.T + b
            elif op == "qconv":
                v = _conv2d(xin, w, n["stride"], n["pad"]) + b[None, :, None, None]
            else:  # qdwconv
                v = _conv2d(xin, w, n["stride"], n["pad"], groups=n["oc"]) + b[
                    None, :, None, None
                ]
        vals[nid] = v
    return vals[out_id], new_state
