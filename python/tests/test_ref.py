"""Property tests for the bit-exact reference semantics (`kernels/ref.py`).

These are the invariants the paper's analysis rests on (Section 3):
sorting never changes the exact sum, resolves transient overflows, and a
persistent overflow clips to the saturation boundary.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def prods_strategy(max_len=200, bits=8):
    lim = 1 << (bits - 1)
    return st.lists(
        st.integers(min_value=-(lim - 1) * lim, max_value=(lim - 1) * lim),
        min_size=0,
        max_size=max_len,
    )


@given(prods_strategy(), st.integers(min_value=10, max_value=28))
@settings(max_examples=200, deadline=None)
def test_sorted1_pair_preserves_sum(prods, p):
    prods = np.array(prods, dtype=np.int64)
    s = ref.sorted1_pair(prods)
    assert s.sum() == prods.sum()


@given(prods_strategy(), st.integers(min_value=12, max_value=28))
@settings(max_examples=200, deadline=None)
def test_exact_policy_matches_sum(prods, p):
    prods = np.array(prods, dtype=np.int64)
    v, e = ref.dot_with_policy(prods, p, "exact")
    assert v == prods.sum() and e == 0


@given(prods_strategy(), st.integers(min_value=12, max_value=28))
@settings(max_examples=200, deadline=None)
def test_clip_no_overflow_is_exact(prods, p):
    prods = np.array(prods, dtype=np.int64)
    v, e = ref.clip_accumulate(prods, p)
    if e == 0:
        assert v == prods.sum()


@given(prods_strategy(), st.integers(min_value=12, max_value=28))
@settings(max_examples=300, deadline=None)
def test_sorted_full_resolves_all_transients(prods, p):
    """Algorithm 1's guarantee: if the final result fits, there is an
    ordering with no intermediate overflow — and the multi-round sorted
    accumulation finds it."""
    prods = np.array(prods, dtype=np.int64)
    cls = ref.classify_overflow(prods, p)
    v, e = ref.sorted_full_dot(prods, p)
    if not cls["persistent"]:
        assert e == 0, (prods, p)
        assert v == cls["exact"]
    else:
        # persistent: monotone accumulation clips at the boundary
        lo, hi = ref.acc_range(p)
        assert v == (hi if cls["exact"] > hi else lo)


@given(prods_strategy(), st.integers(min_value=12, max_value=28))
@settings(max_examples=200, deadline=None)
def test_sorted1_no_events_means_exact(prods, p):
    prods = np.array(prods, dtype=np.int64)
    v, e = ref.sorted1_dot(prods, p)
    if e == 0:
        assert v == prods.sum()


@given(prods_strategy())
@settings(max_examples=100, deadline=None)
def test_wide_accumulator_never_overflows(prods):
    prods = np.array(prods, dtype=np.int64)
    v, e = ref.clip_accumulate(prods, 48)
    assert e == 0 and v == prods.sum()


@given(prods_strategy(), st.integers(min_value=12, max_value=24))
@settings(max_examples=200, deadline=None)
def test_transient_persistent_partition(prods, p):
    prods = np.array(prods, dtype=np.int64)
    cls = ref.classify_overflow(prods, p)
    # transient and persistent are mutually exclusive; transient requires
    # a naive-order event
    assert not (cls["transient"] and cls["persistent"])
    if cls["transient"]:
        assert cls["naive_events"] > 0


def test_wrap_matches_twos_complement():
    # -overflow wraps to positive and vice versa
    v, e = ref.wrap_accumulate(np.array([120, 10], dtype=np.int64), 8)
    assert e == 1 and v == 130 - 256
    v, e = ref.wrap_accumulate(np.array([-120, -10], dtype=np.int64), 8)
    assert e == 1 and v == -130 + 256


def test_clip_saturates():
    v, e = ref.clip_accumulate(np.array([120, 10, 5], dtype=np.int64), 8)
    assert v == 127 and e == 2
    v, e = ref.clip_accumulate(np.array([-120, -10, -5], dtype=np.int64), 8)
    assert v == -128 and e == 2


def test_sorted_full_zero_and_singletons():
    assert ref.sorted_full_dot(np.array([], dtype=np.int64), 12) == (0, 0)
    assert ref.sorted_full_dot(np.array([5], dtype=np.int64), 12) == (5, 0)
    assert ref.sorted_full_dot(np.array([0, 0], dtype=np.int64), 12) == (0, 0)


def test_classify_example_from_paper():
    # K >= 2^(p-2b) threshold: 8-bit values, p=16 accumulator can overflow
    # after summing only a few maximal products
    prods = np.array([127 * 127] * 3, dtype=np.int64)
    cls = ref.classify_overflow(prods, 16)
    assert cls["persistent"]  # 48387 > 32767
    prods = np.array([127 * 127] * 3 + [-127 * 127] * 2, dtype=np.int64)
    cls = ref.classify_overflow(prods, 16)
    assert cls["transient"] and not cls["persistent"]
