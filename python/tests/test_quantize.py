"""Tests for uniform quantization (Eq. 1-4 semantics)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import quantize as Q


@given(
    st.lists(st.floats(-10, 10, allow_nan=False), min_size=1, max_size=64),
    st.sampled_from([4, 5, 6, 8]),
)
@settings(max_examples=100, deadline=None)
def test_weight_roundtrip_error_bounded(vals, bits):
    w = np.array(vals, dtype=np.float32)
    qp = Q.weight_qparams_np(w, bits)
    q = Q.quantize_np(w, qp)
    back = Q.dequantize_np(q, qp)
    # quantization error is at most half a step
    assert np.all(np.abs(back - w) <= qp.scale * 0.5 + 1e-5)


@given(
    st.floats(-5, 0), st.floats(0.1, 8), st.sampled_from([4, 6, 8]),
)
@settings(max_examples=100, deadline=None)
def test_act_zero_maps_exactly(lo, hi, bits):
    """Eq. (1) guarantees the FP32 value 0 maps to an integer exactly."""
    qp = Q.act_qparams_np(lo, hi, bits)
    q0 = Q.quantize_np(np.zeros(1, dtype=np.float32), qp)
    back = Q.dequantize_np(q0, qp)
    assert abs(float(back[0])) <= qp.scale * 0.51


@given(st.floats(-5, 0), st.floats(0.1, 8), st.sampled_from([4, 6, 8]))
@settings(max_examples=100, deadline=None)
def test_act_values_in_signed_range(lo, hi, bits):
    qp = Q.act_qparams_np(lo, hi, bits)
    x = np.linspace(lo, hi, 100, dtype=np.float32)
    q = Q.quantize_np(x, qp)
    assert q.min() >= -(1 << (bits - 1))
    assert q.max() <= (1 << (bits - 1)) - 1


def test_weight_symmetric_range():
    w = np.array([-1.0, 0.5, 1.0], dtype=np.float32)
    qp = Q.weight_qparams_np(w, 8)
    q = Q.quantize_np(w, qp)
    assert list(q) == [-127, 64, 127]  # 0.5/ (1/127) = 63.5 -> round-even 64
    assert qp.offset == 0


def test_fake_quant_weight_idempotent_on_grid():
    import jax.numpy as jnp

    w = jnp.array([-1.0, 0.0, 0.5, 1.0])
    fq = Q.fake_quant_weight(w, 8)
    fq2 = Q.fake_quant_weight(fq, 8)
    np.testing.assert_allclose(np.asarray(fq), np.asarray(fq2), atol=1e-6)


def test_fake_quant_act_matches_np():
    import jax.numpy as jnp

    x = np.linspace(-0.3, 2.1, 57, dtype=np.float32)
    lo, hi = -0.3, 2.1
    fq = np.asarray(Q.fake_quant_act(jnp.asarray(x), jnp.float32(lo), jnp.float32(hi), 8))
    qp = Q.act_qparams_np(lo, hi, 8)
    back = Q.dequantize_np(Q.quantize_np(x, qp), qp)
    np.testing.assert_allclose(fq, back, atol=1e-5)
