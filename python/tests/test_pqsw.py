"""PQSW container + experiment-matrix tests."""

import json
import struct

import numpy as np
import pytest

from compile import datasets as D
from compile.aot import build_matrix, cfg_name
from compile.pqsw import export_pqsw
from compile.train import TrainCfg, train


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    x, y = D.synth_mnist(256, seed=31)
    xt, yt = D.synth_mnist(128, seed=32)
    cfg = TrainCfg(arch="mlp2", schedule="pq", epochs=3, qat_epochs=1,
                   sparsity=0.5, nm_m=16, lr=5e-3, bs=64,
                   arch_kw={"hidden": 32})
    res = train(cfg, (x, y, xt, yt))
    path = str(tmp_path_factory.mktemp("pqsw") / "m.pqsw")
    entry = export_pqsw(path, "m", res, cfg, [1, 28, 28])
    return path, entry, res, cfg


def _parse(path):
    raw = open(path, "rb").read()
    assert raw[:8] == b"PQSW1\x00\x00\x00"
    hlen = struct.unpack("<I", raw[8:12])[0]
    hdr = json.loads(raw[12:12 + hlen])
    base = (12 + hlen + 7) & ~7
    return raw, hdr, base


def test_header_fields(trained):
    path, entry, res, cfg = trained
    _, hdr, _ = _parse(path)
    assert hdr["arch"] == "mlp2"
    assert hdr["wbits"] == 8
    assert hdr["nm_m"] == 16
    assert abs(hdr["achieved_sparsity"] - res.sparsity) < 1e-9
    assert entry["file"] == "m.pqsw"


def test_blobs_are_aligned_and_in_bounds(trained):
    path, _, _, _ = trained
    raw, hdr, base = _parse(path)
    for b in hdr["blobs"]:
        assert b["offset"] % 8 == 0
        assert base + b["offset"] + b["len"] <= len(raw)


def test_weight_blob_roundtrip(trained):
    """int8 weights in the container dequantize back to ~the fp32 weights."""
    path, _, res, cfg = trained
    raw, hdr, base = _parse(path)
    hidden = [n for n in hdr["graph"] if n.get("name") == "hidden"][0]
    wb = hdr["blobs"][hidden["wq_blob"]]
    wq = np.frombuffer(raw[base + wb["offset"]: base + wb["offset"] + wb["len"]],
                       dtype=np.int8).reshape(hidden["oc"], hidden["ic"])
    w = np.asarray(res.params["w2"]) * np.asarray(res.masks["w2"])
    back = wq.astype(np.float64) * hidden["w_scale"]
    assert np.abs(back - w).max() <= hidden["w_scale"] * 0.5 + 1e-6
    # pruned zeros stay zero in the quantized container
    assert np.all(wq[np.asarray(res.masks["w2"]) == 0] == 0)


def test_sparsity_survives_quantization(trained):
    path, _, res, _ = trained
    raw, hdr, base = _parse(path)
    hidden = [n for n in hdr["graph"] if n.get("name") == "hidden"][0]
    wb = hdr["blobs"][hidden["wq_blob"]]
    wq = np.frombuffer(raw[base + wb["offset"]: base + wb["offset"] + wb["len"]],
                       dtype=np.int8)
    frac_zero = (wq == 0).mean()
    assert frac_zero >= res.sparsity - 1e-9  # quantization only adds zeros


def test_cfg_names_unique_in_matrix(monkeypatch):
    exps = build_matrix()
    seen = {}
    for exp, cfgs in exps.items():
        for cfg in cfgs:
            name = cfg_name(cfg)
            if name in seen:
                # duplicates across experiments must be identical configs
                assert seen[name] == (cfg.arch, cfg.schedule, cfg.sparsity,
                                      cfg.wbits, cfg.acc_bits, cfg.lowrank_k)
            seen[name] = (cfg.arch, cfg.schedule, cfg.sparsity, cfg.wbits,
                          cfg.acc_bits, cfg.lowrank_k)
    assert len(seen) >= 10


def test_matrix_covers_all_figures():
    exps = build_matrix()
    for k in ("fig2", "fig3", "fig4", "fig5", "fp32"):
        assert exps[k], f"experiment {k} empty"
    # fig4 must include the filter-pruning baseline unless quick mode
    import os
    if os.environ.get("PQS_QUICK", "") in ("", "0"):
        assert any(c.schedule == "filter" for c in exps["fig4"])
        assert any(c.schedule == "a2q" for c in exps["fig5"])
