"""Pallas kernel vs NumPy reference — the core L1 correctness signal.

Bit-exact integer equality is required (both sides are exact integer
semantics); hypothesis sweeps shapes, bitwidths, accumulator widths,
policies and block sizes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.pqs_matmul import pqs_matmul, POLICIES


def _check(xq, wq, p, policy, **kw):
    y, ovf = pqs_matmul(xq, wq, acc_bits=p, policy=policy, **kw)
    yr, er = ref.qmatmul_ref(xq, wq, p, policy)
    np.testing.assert_array_equal(np.asarray(y, dtype=np.int64), yr)
    np.testing.assert_array_equal(np.asarray(ovf, dtype=np.int64), er)


@given(
    m=st.integers(1, 9),
    k=st.integers(1, 48),
    n=st.integers(1, 9),
    bits=st.sampled_from([4, 8]),
    p=st.sampled_from([12, 14, 16, 20]),
    policy=st.sampled_from(POLICIES),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_kernel_matches_ref_random(m, k, n, bits, p, policy, seed):
    rng = np.random.default_rng(seed)
    lim = 1 << (bits - 1)
    xq = rng.integers(-lim, lim, (m, k)).astype(np.int32)
    wq = rng.integers(-(lim - 1), lim, (k, n)).astype(np.int32)
    _check(xq, wq, p, policy)


@pytest.mark.parametrize("policy", POLICIES)
def test_kernel_mlp_shape(policy):
    """The shape the AOT artifact uses (batch x 784 x 10)."""
    rng = np.random.default_rng(3)
    xq = rng.integers(-128, 128, (4, 784)).astype(np.int32)
    wq = rng.integers(-127, 128, (784, 10)).astype(np.int32)
    _check(xq, wq, 16, policy)


@pytest.mark.parametrize("bm,bn", [(1, 1), (2, 8), (8, 2), (16, 16)])
def test_kernel_block_shapes_do_not_change_results(bm, bn):
    rng = np.random.default_rng(5)
    xq = rng.integers(-128, 128, (7, 33)).astype(np.int32)
    wq = rng.integers(-127, 128, (33, 5)).astype(np.int32)
    _check(xq, wq, 14, "sorted1", block_m=bm, block_n=bn)


def test_kernel_all_zero():
    xq = np.zeros((3, 16), dtype=np.int32)
    wq = np.zeros((16, 3), dtype=np.int32)
    y, ovf = pqs_matmul(xq, wq, acc_bits=12, policy="sorted1")
    assert np.all(np.asarray(y) == 0) and np.all(np.asarray(ovf) == 0)


def test_kernel_single_product_overflow():
    """p < 2b: one product alone overflows; clip and sorted1 must both
    register events."""
    xq = np.full((1, 4), 127, dtype=np.int32)
    wq = np.full((4, 1), 127, dtype=np.int32)
    for pol in ("clip", "sorted1"):
        y, ovf = pqs_matmul(xq, wq, acc_bits=12, policy=pol)
        assert int(np.asarray(ovf)[0, 0]) >= 1
        assert int(np.asarray(y)[0, 0]) == (1 << 11) - 1  # saturated


def test_sorted1_beats_clip_on_transient():
    """A vector engineered so naive order overflows but the true sum fits:
    sorted1 must return the exact value with zero events."""
    xq = np.array([[127, 127, 127, -127, -127, -127]], dtype=np.int32)
    wq = np.full((6, 1), 127, dtype=np.int32)
    wq[3:] = -127  # products: 3x +16129, then 3x +16129? no — make mixed
    xq = np.array([[127, 127, 127, 127, 127, 127]], dtype=np.int32)
    wq = np.array([[127], [127], [127], [-127], [-127], [-127]], dtype=np.int32)
    # exact sum = 0; naive order: +3*16129 = 48387 overflows p=16
    y_c, e_c = pqs_matmul(xq, wq, acc_bits=16, policy="clip")
    y_s, e_s = pqs_matmul(xq, wq, acc_bits=16, policy="sorted1")
    assert int(np.asarray(e_c)[0, 0]) > 0
    assert int(np.asarray(e_s)[0, 0]) == 0
    assert int(np.asarray(y_s)[0, 0]) == 0
