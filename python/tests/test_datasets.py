"""Tests for the synthetic dataset generators + PQSD container round-trip."""

import os

import numpy as np

from compile import datasets as D


def test_mnist_deterministic():
    a, la = D.synth_mnist(32, seed=9)
    b, lb = D.synth_mnist(32, seed=9)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(la, lb)


def test_mnist_shapes_and_range():
    x, y = D.synth_mnist(16, seed=0)
    assert x.shape == (16, 1, 28, 28)
    assert y.shape == (16,)
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert set(np.unique(y)).issubset(set(range(10)))


def test_cifar_shapes_and_range():
    x, y = D.synth_cifar(16, seed=0, size=20)
    assert x.shape == (16, 3, 20, 20)
    assert x.min() >= 0.0 and x.max() <= 1.0


def test_all_classes_reachable():
    _, y = D.synth_mnist(500, seed=1)
    assert len(np.unique(y)) == 10
    _, y = D.synth_cifar(500, seed=1, size=20)
    assert len(np.unique(y)) == 10


def test_pqsd_roundtrip(tmp_path):
    x, y = D.synth_cifar(8, seed=5, size=20)
    p = str(tmp_path / "d.bin")
    D.save_dataset(p, x, y)
    x2, y2 = D.load_dataset(p)
    np.testing.assert_array_equal(y, y2)
    # u8 quantization: within 1/255 of original
    assert np.max(np.abs(x - x2)) <= (1.0 / 255.0) + 1e-6
    assert os.path.exists(str(tmp_path / "d.meta.json"))


def test_classes_distinguishable_by_mean_pixel():
    """Sanity: per-class mean images differ (the task is learnable)."""
    x, y = D.synth_mnist(400, seed=3)
    means = np.stack([x[y == c].mean(axis=0) for c in range(10)])
    d = np.abs(means[:, None] - means[None, :]).sum(axis=(2, 3, 4))
    off_diag = d[~np.eye(10, dtype=bool)]
    assert off_diag.min() > 1.0
