"""Budget projection + plan export tests.

The known-answer constants here are pinned on the Rust side too
(`rust/tests/sweep.rs::projection_matches_python_kat`): both languages
project `synthetic_linear(6, 3)` and must land on byte-identical weights,
the same FNV-1a layer checksum, and the same plan widths. Change either
implementation and both tests tell you which side moved.
"""

import json
import struct

import numpy as np
import pytest

from compile import plan as P
from compile.pqsw import _layer_checksum

WINDOW = P.centered_window(-128, 8)  # (0, 255): uint8-style activations

# synthetic_linear(6, 3) raw weights: wq[o][k] = (o*31 + k*7) % 11 - 5
RAW_WQ = [
    [-5, 2, -2, 5, 1, -3],
    [4, 0, -4, 3, -1, -5],
    [2, -2, 5, 1, -3, 4],
]

# pinned cross-language KAT: sorted policy, budget 12, dense (tau = 1)
DENSE_B12_WQ = [
    [-4, 1, -1, 4, 0, -2],
    [3, 0, -3, 2, 0, -4],
    [1, -1, 4, 0, -2, 3],
]
DENSE_B12_CHECKSUM = 0x19F8CD528591AC91

# pinned cross-language KAT: sorted policy, budget 10, 2:3 sparsity
NM23_B10_WQ = [
    [-2, 0, 0, 2, 0, 0],
    [0, 0, 0, 0, 0, -1],
    [0, 0, 1, 0, 0, 0],
]
NM23_B10_CHECKSUM = 0x2F62B1939D3E5FFC


def test_bits_for_value_matches_rust_accum():
    # mirrors rust/src/accum bits_for_value: two's-complement width, floor 2
    assert P.bits_for_value(0) == 2
    assert P.bits_for_value(1) == 2
    assert P.bits_for_value(-1) == 2
    assert P.bits_for_value(127) == 8
    assert P.bits_for_value(-128) == 8
    assert P.bits_for_value(128) == 9
    assert P.bits_for_value(2040) == 12
    assert P.bits_for_value(-510) == 10


def test_row_range_hand_values():
    # final-sum interval: hi = (3+5)*255, lo = -2*255
    assert P.row_range([3, -2, 0, 5], WINDOW, "sorted") == (-510, 2040)
    assert P.row_bits([3, -2, 0, 5], WINDOW, "sorted") == 12
    # a centered window always contains 0, so every prefix extreme is
    # monotone and the sequential (clip/wrap) interval coincides
    for pol in P.POLICIES:
        assert P.row_range([3, -2, 0, 5], WINDOW, pol) == (-510, 2040)
    # zeros are no-ops; the empty row is exactly zero
    assert P.row_range([], WINDOW, "sorted") == (0, 0)
    assert P.row_range([0, 0], WINDOW, "clip") == (0, 0)


def test_synthetic_linear_mirrors_rust_fixture():
    m = P.synthetic_linear(6, 3)
    assert m["name"] == "synthetic_linear_6x3"
    assert m["layers"][0]["wq"].tolist() == RAW_WQ
    assert m["layers"][0]["x_offset"] == -128
    assert P.layer_bits(m["layers"][0]["wq"], WINDOW, "sorted") == 13


def test_projection_kat_dense_budget12():
    m = P.synthetic_linear(6, 3)
    rep = P.project_model(m, 12, policy="sorted")
    l = m["layers"][0]
    assert l["wq"].tolist() == DENSE_B12_WQ
    assert rep["fc"] == {"tau_max": 1, "pruned": 0, "clipped": 17, "bits": 12}
    plan = m["plan"]
    assert plan["tag"] == "plan" and plan["v"] == 1
    assert plan["policy"] == "sorted" and plan["planner"] == "analytic"
    assert plan["layers"] == [
        {
            "name": "fc",
            "k": 6,
            "nnz_max": 5,
            "analytic_bits": 12,
            "calibrated_bits": None,
            "acc_bits": 12,
        }
    ]
    wq = np.ascontiguousarray(l["wq"], dtype=np.int8)
    bias = np.ascontiguousarray(l["bias"], dtype="<f4")
    assert _layer_checksum(3, 6, wq, bias) == DENSE_B12_CHECKSUM


def test_projection_kat_nm23_budget10():
    m = P.synthetic_linear(6, 3)
    rep = P.project_model(m, 10, policy="sorted", nm=(2, 3))
    l = m["layers"][0]
    assert l["wq"].tolist() == NM23_B10_WQ
    assert l["prune"] is True
    assert m["nm_m"] == 3
    assert rep["fc"] == {"tau_max": 4, "pruned": 5, "clipped": 12, "bits": 10}
    assert m["plan"]["layers"][0]["nnz_max"] == 2
    assert m["plan"]["layers"][0]["acc_bits"] == 10
    wq = np.ascontiguousarray(l["wq"], dtype=np.int8)
    bias = np.ascontiguousarray(l["bias"], dtype="<f4")
    assert _layer_checksum(3, 6, wq, bias) == NM23_B10_CHECKSUM


def test_nm_prune_stable_ties():
    wq, zeroed = P.nm_prune([[3, -5, 5, 1]], 2, 4)
    assert wq.tolist() == [[0, -5, 5, 0]] and zeroed == 2
    # tie at the keep boundary: equal magnitudes keep the lower index
    wq, zeroed = P.nm_prune([[-2, 2, 1, 0]], 1, 4)
    assert wq.tolist() == [[-2, 0, 0, 0]] and zeroed == 2
    # trailing short group prunes too; pre-existing zeros don't count
    wq, zeroed = P.nm_prune([[4, 0, -1, 7, 6]], 1, 3)
    assert wq.tolist() == [[4, 0, 0, 7, 0]] and zeroed == 2


@pytest.mark.parametrize("policy", P.POLICIES)
@pytest.mark.parametrize("budget", [13, 12, 10, 8, 6, 2])
def test_projection_meets_budget_and_is_idempotent(policy, budget):
    wq = np.asarray(RAW_WQ, dtype=np.int8)
    once, rep1 = P.project_matrix(wq, WINDOW, policy, budget)
    assert P.layer_bits(once, WINDOW, policy) <= budget
    twice, rep2 = P.project_matrix(once, WINDOW, policy, budget)
    assert np.array_equal(once, twice), "projection must be idempotent"
    assert rep2 == {"tau_max": 0, "pruned": 0, "clipped": 0}
    # monotone: a looser budget never needs a larger threshold
    loose, rep_loose = P.project_matrix(wq, WINDOW, policy, min(budget + 2, 62))
    assert rep_loose["tau_max"] <= rep1["tau_max"]


def test_projection_rejects_bad_budgets():
    wq = np.asarray(RAW_WQ, dtype=np.int8)
    for budget in (0, 1, 63):
        with pytest.raises(ValueError):
            P.project_matrix(wq, WINDOW, "sorted", budget)
    with pytest.raises(ValueError):
        P.project_matrix(wq, WINDOW, "sorted", 10, nm=(0, 4))


def _parse(path):
    raw = open(path, "rb").read()
    assert raw[:8] == b"PQSW1\x00\x00\x00"
    (hlen,) = struct.unpack("<I", raw[8:12])
    hdr = json.loads(raw[12 : 12 + hlen])
    blob_base = (12 + hlen + 7) & ~7
    return raw, hdr, blob_base


def test_export_projected_pqsw_roundtrip(tmp_path):
    m = P.synthetic_linear(6, 3)
    P.project_model(m, 12, policy="sorted")
    path = str(tmp_path / "proj.pqsw")
    P.export_projected_pqsw(path, m)
    raw, hdr, blob_base = _parse(path)
    assert hdr["format_version"] == 2
    assert [s["tag"] for s in hdr["sections"]] == ["plan", "checksums"]
    assert hdr["sections"][0] == m["plan"]
    assert hdr["sections"][1]["algo"] == "fnv1a64"
    assert hdr["sections"][1]["layers"] == ["%016x" % DENSE_B12_CHECKSUM]
    assert hdr["nm_m"] == 0 and hdr["abits"] == 8
    node = hdr["graph"][2]
    assert node["op"] == "qlinear" and node["name"] == "fc"
    b = hdr["blobs"][node["wq_blob"]]
    assert b["dtype"] == "i8"
    wbytes = raw[blob_base + b["offset"] : blob_base + b["offset"] + b["len"]]
    assert wbytes == np.asarray(DENSE_B12_WQ, dtype=np.int8).tobytes()
    bb = hdr["blobs"][node["bias_blob"]]
    assert bb["dtype"] == "f32" and bb["len"] == 12
