"""Smoke + invariant tests for the training schedules (tiny budgets)."""

import numpy as np
import pytest

from compile import datasets as D
from compile import model as M
from compile.train import (
    TrainCfg,
    filter_prune_mask,
    lowrank_approx,
    nm_prune_mask,
    train,
)


@pytest.fixture(scope="module")
def tiny_mnist():
    x, y = D.synth_mnist(512, seed=21)
    xt, yt = D.synth_mnist(256, seed=22)
    return (x, y, xt, yt)


def test_nm_prune_mask_exact_fraction():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(8, 64))
    mk = nm_prune_mask(w, 0.5, 16)
    # every group of 16 has exactly 8 zeros
    for g in range(0, 64, 16):
        assert (mk[:, g : g + 16] == 0).sum(axis=1).tolist() == [8] * 8


def test_nm_prune_ragged_tail():
    rng = np.random.default_rng(1)
    w = rng.normal(size=(4, 37))  # 2 groups of 16 + tail of 5
    mk = nm_prune_mask(w, 0.5, 16)
    tail = mk[:, 32:]
    assert ((tail == 0).sum(axis=1) == round(0.5 * 5)).all()


def test_nm_prune_removes_smallest():
    w = np.array([[0.1, -5.0, 0.2, 4.0]])
    mk = nm_prune_mask(w, 0.5, 4)
    np.testing.assert_array_equal(mk, [[0.0, 1.0, 0.0, 1.0]])


def test_nm_prune_monotone():
    """Already-zeroed weights stay pruned as sparsity ramps."""
    rng = np.random.default_rng(2)
    w = rng.normal(size=(4, 32))
    m1 = nm_prune_mask(w, 0.25, 16)
    w2 = w * m1
    m2 = nm_prune_mask(w2, 0.5, 16)
    assert np.all(m2 <= m1 + 1e-9)  # zeros only grow


def test_filter_prune_whole_rows():
    rng = np.random.default_rng(3)
    w = rng.normal(size=(8, 10))
    mk = filter_prune_mask(w, 0.5)
    rowz = (mk == 0).all(axis=1)
    assert rowz.sum() == 4
    # smallest-norm rows die first
    norms = np.abs(w).sum(axis=1)
    assert set(np.argsort(norms)[:4]) == set(np.where(rowz)[0])


def test_lowrank_rank():
    rng = np.random.default_rng(4)
    w = rng.normal(size=(20, 30))
    a = lowrank_approx(w, 5)
    assert np.linalg.matrix_rank(a, tol=1e-6) == 5


def test_pq_training_learns_and_prunes(tiny_mnist):
    cfg = TrainCfg(arch="mlp2", schedule="pq", epochs=5, qat_epochs=2,
                   sparsity=0.5, nm_m=32, lr=5e-3, bs=64,
                   arch_kw={"hidden": 64})
    r = train(cfg, tiny_mnist)
    assert r.acc_q > 0.5  # far above 10% chance
    assert abs(r.sparsity - 0.5) < 0.05


def test_qp_training_runs(tiny_mnist):
    cfg = TrainCfg(arch="mlp2", schedule="qp", epochs=4, qat_epochs=0,
                   sparsity=0.5, nm_m=32, lr=5e-3, bs=64,
                   arch_kw={"hidden": 64})
    r = train(cfg, tiny_mnist)
    assert r.acc_q > 0.3
    assert abs(r.sparsity - 0.5) < 0.05


def test_a2q_bound_enforced(tiny_mnist):
    cfg = TrainCfg(arch="mlp2", schedule="a2q", epochs=6, qat_epochs=2,
                   wbits=5, abits=5, acc_bits=13, lr=5e-3, bs=64,
                   arch_kw={"hidden": 64})
    r = train(cfg, tiny_mnist)
    limit = ((1 << 12) - 1) / (1 << 4)
    qmax = 15
    for n in M.q_layers(r.graph):
        w = np.asarray(r.params[f"w{n['id']}"]).reshape(n["oc"], -1)
        s = float(np.exp(np.asarray(r.params[f"s{n['id']}"])))
        wq = np.clip(np.round(w / s), -qmax, qmax)
        # small rounding slack allowed (round-to-nearest after projection)
        assert np.abs(wq).sum(axis=1).max() <= limit * 1.1 + 1


def test_fp32_baseline(tiny_mnist):
    cfg = TrainCfg(arch="mlp1", schedule="fp32", epochs=5, lr=5e-3, bs=64)
    r = train(cfg, tiny_mnist)
    assert r.acc_fp32 > 0.5
