//! PJRT runtime benchmarks: AOT-compiled HLO (fused XLA, Pallas sorted1
//! kernel) vs the bit-accurate interpreting engine — the "fast path vs
//! analysis path" trade of the three-layer architecture.
//!
//!     cargo bench --offline --bench bench_runtime

use pqs::accum::Policy;
use pqs::data::Dataset;
use pqs::formats::manifest::Manifest;
use pqs::models;
use pqs::nn::engine::{Engine, EngineConfig};
use pqs::runtime::Runtime;
use pqs::util::bench::{bench_cfg, black_box};

fn main() -> anyhow::Result<()> {
    if !Runtime::available() {
        println!("bench_runtime skipped: built without the `pjrt` feature");
        return Ok(());
    }
    let man = match Manifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            println!("bench_runtime skipped: artifacts not built ({e:#})");
            return Ok(());
        }
    };
    let rt = Runtime::cpu()?;
    println!("# bench_runtime — PJRT vs engine (mlp1, batch 8)\n");

    let name = man.experiments["fig2"][0].clone();
    let model = models::load(&man, &name)?;
    let ds = Dataset::load(man.dataset_path(&man.test_dataset_for(&model.arch)?.test))?;
    let imgs = ds.images_f32(0, 8);

    let exe = rt.load_hlo(man.dir.join("model.hlo.txt"))?;
    bench_cfg("pjrt pallas-sorted1 p=16 (quantized)", 2, 8, &mut || {
        black_box(exe.run_f32(black_box(&imgs), &[8, 1, 28, 28]).unwrap());
    })
    .print_throughput(8.0, "img/s");

    let fp32 = rt.load_hlo(man.dir.join(format!("hlo/{name}_fp32.hlo.txt")))?;
    bench_cfg("pjrt fp32 fused", 2, 8, &mut || {
        black_box(fp32.run_f32(black_box(&imgs), &[8, 1, 28, 28]).unwrap());
    })
    .print_throughput(8.0, "img/s");

    for policy in [Policy::Sorted, Policy::Sorted1, Policy::Clip] {
        let mut eng = Engine::new(
            &model,
            EngineConfig { policy, acc_bits: 16, ..Default::default() },
        );
        bench_cfg(&format!("engine {} p=16", policy.name()), 1, 5, &mut || {
            black_box(eng.forward(black_box(&imgs), 8).unwrap());
        })
        .print_throughput(8.0, "img/s");
    }
    Ok(())
}
