//! End-to-end engine throughput per policy/accumulator configuration, on
//! the real artifacts (paper §5 evaluation workloads).
//!
//!     cargo bench --offline --bench bench_engine

use pqs::accum::Policy;
use pqs::data::Dataset;
use pqs::formats::manifest::Manifest;
use pqs::models;
use pqs::nn::engine::{Engine, EngineConfig};
use pqs::util::bench::{bench_cfg, black_box};

fn main() -> anyhow::Result<()> {
    let man = Manifest::load_default()?;
    println!("# bench_engine — images/s through the bit-accurate engine\n");

    for (model_name, batch) in [
        ("mlp1_pq_s000_w8a8", 64usize),
        ("mlp2_pq_s875_w8a8_kfull", 64),
    ] {
        let model = models::load(&man, model_name)?;
        let ds = Dataset::load(man.dataset_path(&man.test_dataset_for(&model.arch)?.test))?;
        let imgs = ds.images_f32(0, batch);
        for (policy, stats) in [
            (Policy::Exact, false),
            (Policy::Clip, false),
            (Policy::Sorted, false),
            (Policy::Sorted1, false),
            (Policy::Clip, true),
        ] {
            let mut eng = Engine::new(
                &model,
                EngineConfig { policy, acc_bits: 16, tile: 0, collect_stats: stats },
            );
            let label = format!(
                "{model_name} {}{}",
                policy.name(),
                if stats { "+stats" } else { "" }
            );
            bench_cfg(&label, 1, 5, &mut || {
                black_box(eng.forward(black_box(&imgs), batch).unwrap());
            })
            .print_throughput(batch as f64, "img/s");
        }
        println!();
    }

    // CNN engine (heavier): one config each
    if let Some(e) = man
        .experiment_models("fig4")
        .into_iter()
        .find(|e| e.arch == "resnet_tiny" && e.schedule == "pq" && e.target_sparsity == 0.75)
    {
        let model = models::load(&man, &e.name)?;
        let ds = Dataset::load(man.dataset_path(&man.test_dataset_for(&model.arch)?.test))?;
        let batch = 8;
        let imgs = ds.images_f32(0, batch);
        for policy in [Policy::Sorted, Policy::Clip, Policy::Sorted1] {
            let mut eng = Engine::new(
                &model,
                EngineConfig { policy, acc_bits: 16, ..Default::default() },
            );
            bench_cfg(&format!("{} {}", e.name, policy.name()), 1, 3, &mut || {
                black_box(eng.forward(black_box(&imgs), batch).unwrap());
            })
            .print_throughput(batch as f64, "img/s");
        }
    }
    Ok(())
}
