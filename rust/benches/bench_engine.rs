//! End-to-end engine throughput per policy/accumulator configuration, on
//! the real artifacts (paper §5 evaluation workloads) when present, plus a
//! multi-thread forward-scaling section that runs on a synthetic model so
//! the serving-path speedup is measurable on any checkout.
//!
//!     cargo bench --offline --bench bench_engine

use std::sync::Arc;

use pqs::accum::Policy;
use pqs::data::Dataset;
use pqs::formats::manifest::Manifest;
use pqs::models;
use pqs::nn::engine::{Engine, EngineConfig};
use pqs::util::bench::{bench_cfg, black_box};
use pqs::util::pool::{self, ComputePool};
use pqs::util::rng::Pcg32;

fn real_model_benches(man: &Manifest) -> anyhow::Result<()> {
    for (model_name, batch) in [
        ("mlp1_pq_s000_w8a8", 64usize),
        ("mlp2_pq_s875_w8a8_kfull", 64),
    ] {
        let model = models::load(man, model_name)?;
        let ds = Dataset::load(man.dataset_path(&man.test_dataset_for(&model.arch)?.test))?;
        let imgs = ds.images_f32(0, batch);
        for (policy, stats) in [
            (Policy::Exact, false),
            (Policy::Clip, false),
            (Policy::Sorted, false),
            (Policy::Sorted1, false),
            (Policy::Clip, true),
        ] {
            let mut eng = Engine::new(
                &model,
                EngineConfig { policy, acc_bits: 16, tile: 0, collect_stats: stats },
            );
            let label = format!(
                "{model_name} {}{}",
                policy.name(),
                if stats { "+stats" } else { "" }
            );
            bench_cfg(&label, 1, 5, &mut || {
                black_box(eng.forward(black_box(&imgs), batch).unwrap());
            })
            .print_throughput(batch as f64, "img/s");
        }
        println!();

        // multi-thread forward on the real model
        threads_sweep(&model, &imgs, batch, Policy::Sorted1);
        println!();
    }

    // CNN engine (heavier): one config each
    if let Some(e) = man
        .experiment_models("fig4")
        .into_iter()
        .find(|e| e.arch == "resnet_tiny" && e.schedule == "pq" && e.target_sparsity == 0.75)
    {
        let model = models::load(man, &e.name)?;
        let ds = Dataset::load(man.dataset_path(&man.test_dataset_for(&model.arch)?.test))?;
        let batch = 8;
        let imgs = ds.images_f32(0, batch);
        for policy in [Policy::Sorted, Policy::Clip, Policy::Sorted1] {
            let mut eng = Engine::new(
                &model,
                EngineConfig { policy, acc_bits: 16, ..Default::default() },
            );
            bench_cfg(&format!("{} {}", e.name, policy.name()), 1, 3, &mut || {
                black_box(eng.forward(black_box(&imgs), batch).unwrap());
            })
            .print_throughput(batch as f64, "img/s");
        }
        println!();
        threads_sweep(&model, &imgs, batch, Policy::Sorted1);
    }
    Ok(())
}

/// Forward throughput vs intra-forward thread count (target: >=1.5x at
/// T >= 4 over T = 1 on multi-core hosts).
fn threads_sweep(
    model: &pqs::formats::pqsw::PqswModel,
    imgs: &[f32],
    batch: usize,
    policy: Policy,
) {
    println!("# multi-thread forward scaling ({}, {})", model.name, policy.name());
    let hw = pool::default_threads();
    let mut sweep = vec![1usize, 2, 4];
    if !sweep.contains(&hw) {
        sweep.push(hw);
    }
    let mut base_ns = 0.0f64;
    for &t in sweep.iter().filter(|&&t| t <= hw.max(4)) {
        let mut eng = Engine::new(
            model,
            EngineConfig { policy, acc_bits: 16, ..Default::default() },
        )
        .with_threads(t);
        let r = bench_cfg(&format!("forward {} T={t}", model.name), 1, 5, &mut || {
            black_box(eng.forward(black_box(imgs), batch).unwrap());
        });
        if t == 1 {
            base_ns = r.mean_ns;
        }
        let speedup = if r.mean_ns > 0.0 { base_ns / r.mean_ns } else { 0.0 };
        println!(
            "{:<48} {:>10.2} img/s   speedup vs T=1: {speedup:.2}x",
            format!("forward T={t}"),
            batch as f64 / (r.mean_ns / 1e9),
        );
    }
}

/// Batch-1 forward latency: serial vs scoped spawns vs the persistent
/// shared [`ComputePool`] (the serving hot path this repo optimizes for).
fn batch1_pool_sweep(model: &pqs::formats::pqsw::PqswModel, policy: Policy) {
    println!("# batch-1 forward: serial vs scoped vs persistent pool ({})", model.name);
    let dim: usize = model.input_shape.iter().product();
    let mut rng = Pcg32::new(0xB1);
    let img: Vec<f32> = (0..dim).map(|_| rng.f32()).collect();
    let cfg = EngineConfig { policy, acc_bits: 16, ..Default::default() };
    let mut serial = Engine::new(model, cfg);
    let base = bench_cfg("batch1 serial", 1, 5, &mut || {
        black_box(serial.forward(black_box(&img), 1).unwrap());
    });
    println!("{:<48} {:>10.1} us", "batch-1 serial", base.mean_ns / 1e3);
    let hw = pool::default_threads();
    let mut sweep = vec![2usize, 4];
    if !sweep.contains(&hw) {
        sweep.push(hw);
    }
    for &t in &sweep {
        let mut scoped = Engine::new(model, cfg).with_threads(t);
        let r = bench_cfg("batch1 scoped", 1, 5, &mut || {
            black_box(scoped.forward(black_box(&img), 1).unwrap());
        });
        println!(
            "{:<48} {:>10.1} us   speedup {:.2}x",
            format!("batch-1 scoped spawns T={t}"),
            r.mean_ns / 1e3,
            base.mean_ns / r.mean_ns.max(1.0),
        );
        let mut pooled = Engine::new(model, cfg).with_pool(Arc::new(ComputePool::new(t)));
        let r = bench_cfg("batch1 pooled", 1, 5, &mut || {
            black_box(pooled.forward(black_box(&img), 1).unwrap());
        });
        println!(
            "{:<48} {:>10.1} us   speedup {:.2}x",
            format!("batch-1 persistent pool T={t}"),
            r.mean_ns / 1e3,
            base.mean_ns / r.mean_ns.max(1.0),
        );
    }
}

fn main() -> anyhow::Result<()> {
    println!("# bench_engine — images/s through the bit-accurate engine\n");
    match Manifest::load_default() {
        Ok(man) => real_model_benches(&man)?,
        Err(_) => {
            println!("(artifacts not found — running the synthetic-model sections only)\n");
        }
    }

    // synthetic model: always available, sized like mlp1 but wider so the
    // parallel path has work per row
    let model = models::synthetic_linear(784, 128);
    let batch = 64;
    let mut rng = Pcg32::new(0xBE7C);
    let imgs: Vec<f32> = (0..batch * 784).map(|_| rng.f32()).collect();
    for policy in [Policy::Sorted, Policy::Sorted1, Policy::Clip] {
        let mut eng = Engine::new(
            &model,
            EngineConfig { policy, acc_bits: 16, ..Default::default() },
        );
        bench_cfg(&format!("synthetic {}", policy.name()), 1, 5, &mut || {
            black_box(eng.forward(black_box(&imgs), batch).unwrap());
        })
        .print_throughput(batch as f64, "img/s");
    }
    println!();
    threads_sweep(&model, &imgs, batch, Policy::Sorted1);

    // batch-1 serving hot path: position-parallel conv + oc-parallel linear
    // over the persistent pool (vs per-layer scoped spawns)
    println!();
    batch1_pool_sweep(&model, Policy::Sorted1);
    println!();
    batch1_pool_sweep(&models::synthetic_conv(3, 28, 28, 8, 10), Policy::Sorted1);
    Ok(())
}
