//! Sparse-format benchmarks reproducing the paper's §2.2 argument: N:M
//! semi-structured storage is lighter and faster to traverse than
//! unstructured CSR at equal nnz, and pruning shortens the dot products the
//! accumulator sees.
//!
//!     cargo bench --offline --bench bench_sparse

use pqs::sparse::{CsrMatrix, NmMatrix};
use pqs::util::bench::{bench, black_box};
use pqs::util::rng::Pcg32;

fn random_nm_dense(rng: &mut Pcg32, rows: usize, cols: usize, m: usize, keep: usize) -> Vec<i8> {
    let mut dense = vec![0i8; rows * cols];
    for r in 0..rows {
        for g0 in (0..cols).step_by(m) {
            let glen = m.min(cols - g0);
            let mut pos: Vec<usize> = (0..glen).collect();
            rng.shuffle(&mut pos);
            for &p in pos.iter().take(keep.min(glen)) {
                let mut v = rng.range_i64(-127, 127) as i8;
                if v == 0 {
                    v = 1;
                }
                dense[r * cols + g0 + p] = v;
            }
        }
    }
    dense
}

fn main() {
    let mut rng = Pcg32::new(0x5BA5);
    println!("# bench_sparse — N:M vs CSR vs dense (256 rows x 784 cols)\n");
    for &(m, keep, label) in &[(16usize, 16usize, "dense(16:16)"), (16, 8, "8:16"), (16, 4, "4:16"), (16, 2, "2:16")] {
        let dense = random_nm_dense(&mut rng, 256, 784, m, keep);
        let x = rng.ivec(784, 0, 255);
        let nm = NmMatrix::from_dense(&dense, 256, 784, m);
        let csr = CsrMatrix::from_dense(&dense, 256, 784);
        println!(
            "{label}: nnz={} nm_bytes={} csr_bytes={} dense_bytes={}",
            nm.nnz(),
            nm.footprint_bytes(),
            csr.footprint_bytes(),
            dense.len()
        );

        let mut prods = Vec::new();
        bench(&format!("nm  row-products {label}"), || {
            for r in 0..256 {
                nm.dot_products_into(r, black_box(&x), &mut prods);
                black_box(&prods);
            }
        })
        .print_throughput(nm.nnz() as f64, "prod/s");

        let mut y = Vec::new();
        bench(&format!("csr spmv         {label}"), || {
            csr.spmv_exact(black_box(&x), &mut y);
            black_box(&y);
        })
        .print_throughput(csr.nnz() as f64, "prod/s");

        // dense baseline: multiply everything, including zeros
        bench(&format!("dense matvec     {label}"), || {
            let mut out = [0i64; 256];
            for r in 0..256 {
                let row = &dense[r * 784..(r + 1) * 784];
                let mut acc = 0i64;
                for c in 0..784 {
                    acc += row[c] as i64 * x[c] as i64;
                }
                out[r] = acc;
            }
            black_box(out);
        })
        .print_throughput((256 * 784) as f64, "prod/s");
        println!();
    }
}
