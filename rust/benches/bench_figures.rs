//! Times the figure-regeneration harnesses (one per paper table/figure) on
//! reduced sample budgets, and prints their headline rows — `cargo bench`
//! therefore regenerates the *shape* of every result in the paper's
//! evaluation section.
//!
//!     cargo bench --offline --bench bench_figures

use std::time::Instant;

use pqs::figures::{fig2, fig3, fig4, fig5, sec6};
use pqs::formats::manifest::Manifest;

fn timed<T>(name: &str, f: impl FnOnce() -> anyhow::Result<T>) -> anyhow::Result<T> {
    let t0 = Instant::now();
    let r = f()?;
    println!("[{name}] completed in {:.1} s", t0.elapsed().as_secs_f64());
    Ok(r)
}

fn main() -> anyhow::Result<()> {
    let man = match Manifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            println!("bench_figures skipped: artifacts not built ({e:#})");
            return Ok(());
        }
    };
    println!("# bench_figures — regenerate every paper figure (reduced budgets)\n");

    let r2 = timed("fig2", || fig2::run(&man, 192, 13..=20))?;
    fig2::print(&r2);
    println!();

    let r3 = timed("fig3", || fig3::run(&man, 256, 8))?;
    println!("fig3: {} rows (P->Q vs Q->P x rank x sparsity)", r3.len());

    let r4 = timed("fig4", || fig4::run(&man, 64, 8))?;
    println!("fig4: {} rows (arch x schedule x sparsity)", r4.len());

    let pts = timed("fig5", || fig5::run(&man, 96, &[13, 14, 16, 20], Some("mlp2")))?;
    println!("fig5 (mlp2 subset): {} pareto points", pts.len());
    for arch in ["mlp2"] {
        if let Some((p, acc, base)) = fig5::min_width_within(&pts, arch, 0.02) {
            println!(
                "  headline {arch}: min width {p} bits (acc {acc:.3} vs fp32 {base:.3}) = {:.1}x vs 32b",
                32.0 / p as f64
            );
        }
    }

    if let Some(name) = sec6::default_model(&man) {
        let r6 = timed("sec6", || sec6::run(&man, &name, 16, &[64, 256, 0], 24))?;
        sec6::print(&r6);
    }
    Ok(())
}
