//! Microbenchmarks of the dot-product engines (the hot path of the whole
//! library): naive clip vs one-round sorted vs full Algorithm 1 vs the
//! engine's O(K) sorted fast path, across dot lengths and sparsities.
//!
//!     cargo bench --offline --bench bench_dot

use pqs::accum;
use pqs::dot::{sorted_full_dot, sorted1_dot, tiled_sorted_dot, DotEngine};
use pqs::util::bench::{bench, black_box};
use pqs::util::rng::Pcg32;

fn gen_products(rng: &mut Pcg32, k: usize, sparsity: f64) -> Vec<i32> {
    (0..k)
        .map(|_| {
            if rng.f64() < sparsity {
                0
            } else {
                (rng.range_i64(-127, 127) * rng.range_i64(0, 255)) as i32
            }
        })
        .filter(|&v| v != 0)
        .collect()
}

fn main() {
    println!("# bench_dot — per-dot-product cost (paper hot path)\n");
    let mut rng = Pcg32::new(0xD07);
    for &k in &[64usize, 256, 784, 4096] {
        let prods = gen_products(&mut rng, k, 0.0);
        let mut e = DotEngine::new();
        let p = 16;

        bench(&format!("exact            K={k}"), || {
            black_box(accum::exact_dot(black_box(&prods)));
        })
        .print_throughput(prods.len() as f64, "prod/s");

        bench(&format!("clip             K={k}"), || {
            black_box(accum::clip_accumulate(black_box(&prods), p));
        })
        .print_throughput(prods.len() as f64, "prod/s");

        bench(&format!("sorted1 (1 round) K={k}"), || {
            black_box(sorted1_dot(&mut e, black_box(&prods), p));
        })
        .print_throughput(prods.len() as f64, "prod/s");

        bench(&format!("sorted full alg1 K={k}"), || {
            black_box(sorted_full_dot(&mut e, black_box(&prods), p));
        })
        .print_throughput(prods.len() as f64, "prod/s");

        bench(&format!("tiled t=256      K={k}"), || {
            black_box(tiled_sorted_dot(&mut e, black_box(&prods), p, 256));
        })
        .print_throughput(prods.len() as f64, "prod/s");
        println!();
    }

    // the engine's provable O(K) fast path for full Algorithm 1
    println!("# engine Sorted fast path vs real multi-round algorithm (K=784)");
    let prods = gen_products(&mut rng, 784, 0.0);
    let mut e = DotEngine::new();
    bench("engine-sorted-fastpath  K=784", || {
        let exact = accum::exact_dot(black_box(&prods));
        black_box(accum::clamp(exact, 16));
    })
    .print_throughput(prods.len() as f64, "prod/s");
    bench("sorted-full-real        K=784", || {
        black_box(sorted_full_dot(&mut e, black_box(&prods), 16));
    })
    .print_throughput(prods.len() as f64, "prod/s");

    // pruning shortens dots (paper §3.1): cost at N:M sparsities
    println!("\n# effect of pruning on sorted dot cost (K=784 nominal)");
    for &s in &[0.0, 0.5, 0.75, 0.875] {
        let prods = gen_products(&mut rng, 784, s);
        let mut e = DotEngine::new();
        bench(&format!("sorted1 sparsity={s}"), || {
            black_box(sorted1_dot(&mut e, black_box(&prods), 16));
        })
        .print();
    }

    // counting/radix pairing fast path vs the seed's comparison sorts
    // (acceptance: adaptive pairing no slower than comparison at K <= 1024)
    println!("\n# sorted1 pairing: adaptive counting/radix vs comparison sorts");
    for &(k, lo, hi, label) in &[
        (256usize, -50i64, 50i64, "narrow (counting)"),
        (1024, -50, 50, "narrow (counting)"),
        (256, -32385, 32385, "wide 15-bit (radix)"),
        (1024, -32385, 32385, "wide 15-bit (radix)"),
        (4096, -32385, 32385, "wide 15-bit (radix)"),
    ] {
        let prods: Vec<i32> =
            (0..k).map(|_| rng.range_i64(lo, hi) as i32).collect();
        let mut e = DotEngine::new();
        bench(&format!("sorted1 adaptive   K={k} {label}"), || {
            black_box(sorted1_dot(&mut e, black_box(&prods), 16));
        })
        .print_throughput(k as f64, "prod/s");
        bench(&format!("sorted1 comparison K={k} {label}"), || {
            black_box(comparison_sorted1(black_box(&prods), 16));
        })
        .print_throughput(k as f64, "prod/s");
    }
}

/// The seed implementation: comparison-sort pairing + clipped accumulation
/// (kept here as the baseline the adaptive fast path is measured against).
fn comparison_sorted1(prods: &[i32], p: u32) -> (i64, u32) {
    let mut pos: Vec<i32> = prods.iter().copied().filter(|&v| v > 0).collect();
    let mut neg: Vec<i32> = prods.iter().copied().filter(|&v| v < 0).collect();
    pos.sort_unstable_by(|a, b| b.cmp(a));
    neg.sort_unstable();
    let m = pos.len().min(neg.len());
    let mut seq: Vec<i32> = (0..m).map(|i| pos[i] + neg[i]).collect();
    if pos.len() > m {
        seq.extend_from_slice(&pos[m..]);
    } else {
        seq.extend_from_slice(&neg[m..]);
    }
    accum::clip_accumulate(&seq, p)
}
