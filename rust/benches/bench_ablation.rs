//! Ablation bench for the design choices DESIGN.md calls out:
//!
//! 1. sorting rounds — none (clip) vs one round (sorted1) vs full
//!    Algorithm 1: how many transient overflows does each leave, and what
//!    does each cost? (paper §3.2: one round suffices for ~99%+)
//! 2. early persistent-overflow exit (§6): how many accumulation steps
//!    does the monotone phase skip once clipped?
//! 3. pairing order — PQS pairing (largest pos + most-negative) vs a
//!    naive interleave of sorted positives/negatives: shows *why* the
//!    pairing is the right order.
//!
//!     cargo bench --offline --bench bench_ablation

use pqs::accum;
use pqs::dot::{classify, sorted_full_dot, sorted1_dot, DotEngine};
use pqs::dot::sorted::sorted_full_dot_early_exit;
use pqs::util::bench::{bench, black_box};
use pqs::util::rng::Pcg32;

/// Products with a controllable transient profile: balanced heavy tails.
fn gen(rng: &mut Pcg32, k: usize) -> Vec<i32> {
    (0..k)
        .map(|_| (rng.range_i64(-127, 127) * rng.range_i64(0, 255)) as i32)
        .collect()
}

/// Naive interleave ablation: alternate sorted positives and negatives
/// without magnitude pairing.
fn interleave_dot(prods: &[i32], p: u32) -> (i64, u32) {
    let mut pos: Vec<i32> = prods.iter().copied().filter(|&v| v > 0).collect();
    let mut neg: Vec<i32> = prods.iter().copied().filter(|&v| v < 0).collect();
    pos.sort_unstable_by(|a, b| b.cmp(a));
    neg.sort_unstable();
    let mut seq = Vec::with_capacity(pos.len() + neg.len());
    let m = pos.len().max(neg.len());
    for i in 0..m {
        if i < pos.len() {
            seq.push(pos[i]);
        }
        if i < neg.len() {
            seq.push(neg[i]);
        }
    }
    accum::clip_accumulate(&seq, p)
}

fn main() {
    let mut rng = Pcg32::new(0xAB1A);
    let p = 16;
    let n_dots = 2000;
    let k = 784;
    let cases: Vec<Vec<i32>> = (0..n_dots).map(|_| gen(&mut rng, k)).collect();

    // ---- 1. rounds ablation: residual unresolved transients ------------
    let mut transients = 0u64;
    let mut unresolved = [0u64; 3]; // clip, sorted1, interleave
    let mut e = DotEngine::new();
    for prods in &cases {
        let cls = classify(prods, p);
        if !cls.transient {
            continue;
        }
        transients += 1;
        if accum::clip_accumulate(prods, p).1 > 0 {
            unresolved[0] += 1;
        }
        if sorted1_dot(&mut e, prods, p).1 > 0 {
            unresolved[1] += 1;
        }
        if interleave_dot(prods, p).1 > 0 {
            unresolved[2] += 1;
        }
        // full Algorithm 1 provably resolves all (property-tested)
    }
    println!("# ablation 1 — transient resolution over {n_dots} random dots (K={k}, p={p})");
    println!("transient dots: {transients}");
    println!(
        "unresolved: clip {} ({:.1}%) | interleave {} ({:.1}%) | sorted1 {} ({:.1}%) | full-alg1 0 (0.0%)",
        unresolved[0], 100.0 * unresolved[0] as f64 / transients.max(1) as f64,
        unresolved[2], 100.0 * unresolved[2] as f64 / transients.max(1) as f64,
        unresolved[1], 100.0 * unresolved[1] as f64 / transients.max(1) as f64,
    );

    // ---- 2. cost ablation ----------------------------------------------
    println!("\n# ablation 2 — cost per policy (K={k})");
    let prods = &cases[0];
    bench("clip (0 rounds)", || {
        black_box(accum::clip_accumulate(black_box(prods), p));
    })
    .print();
    let mut e1 = DotEngine::new();
    bench("sorted1 (1 round)", || {
        black_box(sorted1_dot(&mut e1, black_box(prods), p));
    })
    .print();
    let mut e2 = DotEngine::new();
    bench("full Algorithm 1", || {
        black_box(sorted_full_dot(&mut e2, black_box(prods), p));
    })
    .print();
    bench("engine fast path (clamp(exact))", || {
        let v = accum::exact_dot(black_box(prods));
        black_box(accum::clamp(v, p));
    })
    .print();

    // ---- 3. early-exit ablation (paper §6) ------------------------------
    println!("\n# ablation 3 — early persistent-overflow exit");
    let mut skipped_total = 0usize;
    let mut persistent = 0u64;
    let mut e3 = DotEngine::new();
    // heavy positive skew -> persistent overflows
    let skewed: Vec<Vec<i32>> = (0..500)
        .map(|i| {
            let mut v = gen(&mut Pcg32::new(i), 784);
            for x in v.iter_mut() {
                *x = x.abs();
            }
            v
        })
        .collect();
    for prods in &skewed {
        let (_, _, skipped) = sorted_full_dot_early_exit(&mut e3, prods, p);
        if skipped > 0 {
            persistent += 1;
            skipped_total += skipped;
        }
    }
    println!(
        "persistent dots: {persistent}/500; mean adds skipped when persistent: {:.0}/{k}",
        skipped_total as f64 / persistent.max(1) as f64
    );
    let mut e4 = DotEngine::new();
    bench("alg1 without early exit (persistent)", || {
        black_box(sorted_full_dot(&mut e4, black_box(&skewed[0]), p));
    })
    .print();
    let mut e5 = DotEngine::new();
    bench("alg1 with early exit    (persistent)", || {
        black_box(sorted_full_dot_early_exit(&mut e5, black_box(&skewed[0]), p));
    })
    .print();
}
