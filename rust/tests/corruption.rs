//! Corruption corpus over the `.pqsw` loader: ~1k seeded bit-flips and
//! truncations of a saved model, every one of which must come back as a
//! clean `Err` (quarantine material) — never a panic, and never a
//! "successful" load whose weights differ from what was written.
//!
//! The checksummed corpus is the integrity contract: a file that loads
//! AND verifies must carry byte-identical q-layer digests. The
//! version-1 corpus (no checksums) only pins panic-freedom — without
//! digests a flipped weight bit is undetectable by design, which is
//! exactly why the exporters now write the checksums section.

mod common;

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;

use pqs::formats::pqsw::PqswModel;
use pqs::util::prop;
use pqs::util::rng::Pcg32;

/// One seeded mutation of the pristine byte image.
#[derive(Debug)]
enum Mutation {
    /// flip these bit positions (bit i = byte i/8, bit i%8)
    FlipBits(Vec<usize>),
    /// keep only the first n bytes
    Truncate(usize),
    /// zero a run of bytes at (start, len)
    ZeroRun(usize, usize),
}

impl Mutation {
    fn gen(rng: &mut Pcg32, len: usize) -> Mutation {
        match rng.below(4) {
            0 => Mutation::FlipBits(vec![rng.below((len * 8) as u32) as usize]),
            1 => {
                let n = 1 + rng.below(8) as usize;
                Mutation::FlipBits(
                    (0..n).map(|_| rng.below((len * 8) as u32) as usize).collect(),
                )
            }
            2 => Mutation::Truncate(rng.below(len as u32) as usize),
            _ => {
                let start = rng.below(len as u32) as usize;
                let run = 1 + rng.below(32) as usize;
                Mutation::ZeroRun(start, run.min(len - start))
            }
        }
    }

    fn apply(&self, pristine: &[u8]) -> Vec<u8> {
        let mut bytes = pristine.to_vec();
        match self {
            Mutation::FlipBits(bits) => {
                for &b in bits {
                    bytes[b / 8] ^= 1 << (b % 8);
                }
            }
            Mutation::Truncate(n) => bytes.truncate(*n),
            Mutation::ZeroRun(start, run) => {
                for b in &mut bytes[*start..*start + *run] {
                    *b = 0;
                }
            }
        }
        bytes
    }
}

fn corpus_dir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pqs_corruption_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("corpus dir");
    dir
}

/// The shared property: write the mutated bytes, load under
/// `catch_unwind`, and demand Err-or-faithful. `pristine_sums` is
/// `Some(layer digests)` for the checksummed corpus — a load that
/// succeeds there must reproduce the exact weights it was saved with.
fn check_mutation(
    path: &std::path::Path,
    pristine: &[u8],
    pristine_sums: Option<&[u64]>,
    m: &Mutation,
) -> Result<(), String> {
    let bytes = m.apply(pristine);
    std::fs::write(path, &bytes).map_err(|e| format!("writing corpus file: {e}"))?;
    for eager in [false, true] {
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            if eager {
                PqswModel::load_eager(path)
            } else {
                PqswModel::load(path)
            }
        }));
        let loaded = match outcome {
            Ok(r) => r,
            Err(_) => return Err(format!("loader PANICKED (eager={eager})")),
        };
        if let Ok(model) = loaded {
            // the mutation may have missed anything load-bearing (padding,
            // a metadata string) — but if checksums were written, a load
            // that passed them must hold the exact original weights
            if let Some(sums) = pristine_sums {
                if model.layer_checksums() != sums {
                    return Err(format!(
                        "accepted altered weights (eager={eager}): a verified load must \
                         be byte-faithful"
                    ));
                }
            }
        }
    }
    Ok(())
}

#[test]
fn checksummed_corpus_errs_or_stays_faithful_never_panics() {
    let dir = corpus_dir();
    let path = dir.join("checksummed.pqsw");
    let mut model = pqs::models::synthetic_conv(2, 6, 6, 4, 10);
    model.attach_checksums();
    model.save(&path).expect("save pristine");
    let pristine = std::fs::read(&path).expect("read pristine back");
    let sums = model.layer_checksums();
    // the pristine image itself must round-trip before we corrupt it
    assert_eq!(PqswModel::load(&path).expect("pristine loads").layer_checksums(), sums);

    prop::check(
        "pqsw-corruption-checksummed",
        768,
        |rng| Mutation::gen(rng, pristine.len()),
        |m| check_mutation(&path, &pristine, Some(&sums), m),
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn version1_corpus_never_panics() {
    // no checksums: silent weight damage is undetectable by design, but
    // the loader must still never panic on arbitrary damage
    let dir = corpus_dir();
    let path = dir.join("v1.pqsw");
    let model = pqs::models::synthetic_conv(2, 6, 6, 4, 10);
    model.save(&path).expect("save pristine");
    let pristine = std::fs::read(&path).expect("read pristine back");

    prop::check(
        "pqsw-corruption-v1",
        256,
        |rng| Mutation::gen(rng, pristine.len()),
        |m| check_mutation(&path, &pristine, None, m),
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn every_single_weight_bit_flip_is_caught() {
    // exhaustive over the weight blob of a tiny checksummed model: flip
    // each bit of each weight byte in place — the loader must reject
    // every single one (this is the integrity guarantee quarantine
    // relies on, so it gets the exhaustive treatment, not sampling)
    let dir = corpus_dir();
    let path = dir.join("weights.pqsw");
    let mut model = pqs::models::synthetic_linear(8, 3);
    model.attach_checksums();
    model.save(&path).expect("save pristine");
    let pristine = std::fs::read(&path).expect("read pristine back");

    // locate the weight bytes: the first blob starts at the 8-aligned
    // end of the 12-byte magic+length prefix plus the JSON header
    let hlen = u32::from_le_bytes(pristine[8..12].try_into().unwrap()) as usize;
    let blob_base = (12 + hlen + 7) & !7;
    let wq_len = 8 * 3; // dim * classes int8 weights, the first blob
    assert!(blob_base + wq_len <= pristine.len());

    for byte in blob_base..blob_base + wq_len {
        for bit in 0..8 {
            let mut bytes = pristine.clone();
            bytes[byte] ^= 1 << bit;
            std::fs::write(&path, &bytes).expect("write corpus file");
            let err = PqswModel::load(&path).expect_err("flipped weight bit must not load");
            let msg = format!("{err:#}");
            assert!(
                pqs::formats::pqsw::is_integrity_error(&err),
                "classified as integrity damage: {msg}"
            );
            assert!(msg.contains("checksum mismatch"), "names the failure: {msg}");
        }
    }
    std::fs::remove_file(&path).ok();
}
