//! Coordinator invariants: sharding must not change results; the serving
//! front-end must conserve requests and answer deterministically.

use pqs::accum::Policy;
use pqs::coordinator::{serve_requests, EvalService, Request};
use pqs::data::Dataset;
use pqs::formats::manifest::Manifest;
use pqs::models;
use pqs::nn::engine::EngineConfig;

fn setup() -> (Manifest, Dataset, pqs::formats::pqsw::PqswModel) {
    let man = Manifest::load_default().expect("run `make artifacts` first");
    let entry = man.test_dataset_for("mlp1").unwrap();
    let ds = Dataset::load(man.dataset_path(&entry.test)).unwrap();
    let name = man.experiments["fig2"][0].clone();
    let model = models::load(&man, &name).unwrap();
    (man, ds, model)
}

#[test]
fn sharding_invariance() {
    let (_man, ds, model) = setup();
    let cfg = EngineConfig { policy: Policy::Sorted, acc_bits: 14, collect_stats: true, tile: 0 };
    let a = EvalService::new(&model, cfg).with_threads(1).with_batch(64)
        .evaluate(&ds, Some(256)).unwrap();
    let b = EvalService::new(&model, cfg).with_threads(4).with_batch(32)
        .evaluate(&ds, Some(256)).unwrap();
    assert_eq!(a.samples, b.samples);
    assert!((a.accuracy - b.accuracy).abs() < 1e-12);
    // overflow totals are per-dot counts: independent of sharding
    assert_eq!(a.report.total(), b.report.total());
}

#[test]
fn limit_truncates_exactly() {
    let (_man, ds, model) = setup();
    let cfg = EngineConfig::default();
    let out = EvalService::new(&model, cfg).with_batch(50).evaluate(&ds, Some(123)).unwrap();
    assert_eq!(out.samples, 123);
}

#[test]
fn serve_conserves_and_orders_responses() {
    let (_man, ds, model) = setup();
    let dim = ds.dim();
    let n = 100;
    let imgs = ds.images_f32(0, n);
    let requests: Vec<Request> = (0..n)
        .map(|i| Request { id: i as u64, image: imgs[i * dim..(i + 1) * dim].to_vec() })
        .collect();
    let cfg = EngineConfig::default();
    let (resp, metrics) = serve_requests(&model, cfg, requests, 16, 2).unwrap();
    assert_eq!(resp.len(), n);
    assert_eq!(metrics.requests, n);
    for (i, r) in resp.iter().enumerate() {
        assert_eq!(r.id, i as u64, "responses must be ordered by id");
        assert!(r.latency_us > 0.0);
    }
    assert!(metrics.throughput_rps > 0.0);
    // predictions must match the offline engine
    let mut eng = pqs::nn::engine::Engine::new(&model, cfg);
    let out = eng.forward(&imgs, n).unwrap();
    for i in 0..n {
        assert_eq!(resp[i].class, out.argmax(i), "request {i}");
    }
}

#[test]
fn serve_single_thread_matches_parallel() {
    let (_man, ds, model) = setup();
    let dim = ds.dim();
    let n = 40;
    let imgs = ds.images_f32(0, n);
    let make_reqs = || -> Vec<Request> {
        (0..n).map(|i| Request { id: i as u64, image: imgs[i * dim..(i + 1) * dim].to_vec() }).collect()
    };
    let cfg = EngineConfig { policy: Policy::Clip, acc_bits: 13, ..Default::default() };
    let (a, _) = serve_requests(&model, cfg, make_reqs(), 8, 1).unwrap();
    let (b, _) = serve_requests(&model, cfg, make_reqs(), 8, 4).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.class, y.class);
    }
}
