//! Coordinator invariants: sharding must not change results; the serving
//! front-end must conserve requests and answer deterministically.
//! Each test skips (with a notice) when artifacts are not built; the
//! artifact-free serving tests live in rust/tests/server.rs.

mod common;

use pqs::accum::Policy;
use pqs::coordinator::{serve_requests, EvalService, Request};
use pqs::data::Dataset;
use pqs::formats::manifest::Manifest;
use pqs::models;
use pqs::nn::engine::EngineConfig;

fn setup(test: &str) -> Option<(Manifest, Dataset, pqs::formats::pqsw::PqswModel)> {
    let man = common::manifest_or_skip(test)?;
    let entry = man.test_dataset_for("mlp1").unwrap();
    let ds = Dataset::load(man.dataset_path(&entry.test)).unwrap();
    let name = man.experiments["fig2"][0].clone();
    let model = models::load(&man, &name).unwrap();
    Some((man, ds, model))
}

#[test]
fn sharding_invariance() {
    let Some((_man, ds, model)) = setup("sharding_invariance") else { return };
    let cfg = EngineConfig { policy: Policy::Sorted, acc_bits: 14, collect_stats: true, tile: 0 };
    let a = EvalService::new(&model, cfg).with_threads(1).with_batch(64)
        .evaluate(&ds, Some(256)).unwrap();
    let b = EvalService::new(&model, cfg).with_threads(4).with_batch(32)
        .evaluate(&ds, Some(256)).unwrap();
    assert_eq!(a.samples, b.samples);
    assert!((a.accuracy - b.accuracy).abs() < 1e-12);
    // overflow totals are per-dot counts: independent of sharding
    assert_eq!(a.report.total(), b.report.total());
}

#[test]
fn limit_truncates_exactly() {
    let Some((_man, ds, model)) = setup("limit_truncates_exactly") else { return };
    let cfg = EngineConfig::default();
    let out = EvalService::new(&model, cfg).with_batch(50).evaluate(&ds, Some(123)).unwrap();
    assert_eq!(out.samples, 123);
}

#[test]
fn engine_evaluate_limit_matches_service() {
    // Engine::evaluate must also truncate exactly (it used to overshoot by
    // counting the full final batch)
    let Some((_man, ds, model)) = setup("engine_evaluate_limit_matches_service") else { return };
    let cfg = EngineConfig::default();
    let mut eng = pqs::nn::engine::Engine::new(&model, cfg);
    let (acc_eng, _) = eng.evaluate(&ds, 50, Some(123)).unwrap();
    let svc = EvalService::new(&model, cfg).with_batch(50).evaluate(&ds, Some(123)).unwrap();
    assert_eq!(svc.samples, 123);
    assert!((acc_eng - svc.accuracy).abs() < 1e-12, "{acc_eng} vs {}", svc.accuracy);
}

#[test]
fn serve_conserves_and_orders_responses() {
    let Some((_man, ds, model)) = setup("serve_conserves_and_orders_responses") else { return };
    let dim = ds.dim();
    let n = 100;
    let imgs = ds.images_f32(0, n);
    let requests: Vec<Request> = (0..n)
        .map(|i| Request { id: i as u64, image: imgs[i * dim..(i + 1) * dim].to_vec() })
        .collect();
    let cfg = EngineConfig::default();
    let (resp, metrics) = serve_requests(&model, cfg, requests, 16, 2).unwrap();
    assert_eq!(resp.len(), n);
    assert_eq!(metrics.requests, n);
    assert_eq!(metrics.errors, 0);
    for (i, r) in resp.iter().enumerate() {
        assert_eq!(r.id, i as u64, "responses must be ordered by id");
        assert!(r.latency_us > 0.0);
        assert!(r.error.is_none());
    }
    assert!(metrics.throughput_rps > 0.0);
    // latency percentiles are per-request (one sample per request)
    assert_eq!(metrics.latency.count(), n);
    assert_eq!(metrics.queue.count(), n);
    assert_eq!(metrics.compute.count(), n);
    // predictions must match the offline engine
    let mut eng = pqs::nn::engine::Engine::new(&model, cfg);
    let out = eng.forward(&imgs, n).unwrap();
    for i in 0..n {
        assert_eq!(resp[i].class, out.argmax(i), "request {i}");
    }
}

#[test]
fn serve_single_thread_matches_parallel() {
    let Some((_man, ds, model)) = setup("serve_single_thread_matches_parallel") else { return };
    let dim = ds.dim();
    let n = 40;
    let imgs = ds.images_f32(0, n);
    let make_reqs = || -> Vec<Request> {
        (0..n).map(|i| Request { id: i as u64, image: imgs[i * dim..(i + 1) * dim].to_vec() }).collect()
    };
    let cfg = EngineConfig { policy: Policy::Clip, acc_bits: 13, ..Default::default() };
    let (a, _) = serve_requests(&model, cfg, make_reqs(), 8, 1).unwrap();
    let (b, _) = serve_requests(&model, cfg, make_reqs(), 8, 4).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.class, y.class);
    }
}

#[test]
fn serve_bad_request_is_isolated() {
    // a wrong-sized image yields an error response; batch-mates still get
    // correct answers and nothing panics
    let Some((_man, ds, model)) = setup("serve_bad_request_is_isolated") else { return };
    let dim = ds.dim();
    let n = 10;
    let imgs = ds.images_f32(0, n);
    let mut requests: Vec<Request> = (0..n)
        .map(|i| Request { id: i as u64, image: imgs[i * dim..(i + 1) * dim].to_vec() })
        .collect();
    requests.push(Request { id: n as u64, image: vec![0.5; dim / 2] });
    let cfg = EngineConfig::default();
    let (resp, metrics) = serve_requests(&model, cfg, requests, 4, 2).unwrap();
    assert_eq!(resp.len(), n + 1);
    assert_eq!(metrics.errors, 1);
    let mut eng = pqs::nn::engine::Engine::new(&model, cfg);
    let out = eng.forward(&imgs, n).unwrap();
    for (i, r) in resp.iter().enumerate() {
        if i < n {
            assert!(r.error.is_none(), "request {i} unexpectedly errored");
            assert_eq!(r.class, out.argmax(i), "request {i}");
        } else {
            assert!(r.error.is_some(), "bad request must error");
        }
    }
}
