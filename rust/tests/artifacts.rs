//! Artifact-integrity integration tests: every exported model parses, its
//! metadata is self-consistent, N:M structure holds, and datasets load.
//! Each test skips (with a notice) when artifacts are not built.

mod common;

use pqs::formats::pqsw::{Op, PqswModel};
use pqs::sparse::NmMatrix;

#[test]
fn all_models_parse_and_are_consistent() {
    let Some(man) = common::manifest_or_skip("all_models_parse_and_are_consistent") else {
        return;
    };
    assert!(man.models.len() >= 10, "suspiciously few models");
    for (name, entry) in &man.models {
        let m = PqswModel::load(man.model_path(name)).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(&m.name, name);
        assert_eq!(m.arch, entry.arch);
        // sparsity recomputed from the *quantized* weights: quantization
        // only adds zeros on top of pruning (paper §6, "quantization itself
        // induces additional sparsity"), so int8 sparsity >= fp32 sparsity
        let sp = m.weight_sparsity();
        assert!(
            sp + 0.02 >= entry.achieved_sparsity,
            "{name}: int8 sparsity {sp} below manifest fp32 sparsity {}",
            entry.achieved_sparsity
        );
        // graph sanity: exactly one input, last node produces the logits
        let inputs = m.graph.iter().filter(|n| n.op == Op::Input).count();
        assert_eq!(inputs, 1, "{name}");
        for n in &m.graph {
            for &i in &n.inputs {
                assert!(m.graph.iter().any(|o| o.id == i), "{name}: dangling input {i}");
            }
        }
    }
}

#[test]
fn nm_structure_holds_for_pq_models() {
    let Some(man) = common::manifest_or_skip("nm_structure_holds_for_pq_models") else {
        return;
    };
    let mut checked = 0;
    for (name, entry) in &man.models {
        if entry.schedule != "pq" || entry.target_sparsity == 0.0 {
            continue;
        }
        let m = PqswModel::load(man.model_path(name)).unwrap();
        for (node, q) in m.q_layers() {
            if !q.prune {
                continue;
            }
            let nm = NmMatrix::from_dense(&q.wq, q.oc, q.k, m.nm_m);
            // with target sparsity s, each group of M keeps at most
            // M - round(s*M) nonzeros (quantization can only add zeros)
            let keep = m.nm_m - (entry.target_sparsity * m.nm_m as f64).round() as usize;
            let worst = nm
                .check_group_bound(keep)
                .unwrap_or_else(|e| panic!("{name}/{:?}: {e}", node.id));
            assert!(worst <= keep);
            checked += 1;
        }
    }
    assert!(checked > 5, "checked only {checked} layers");
}

#[test]
fn datasets_load_and_match_manifest_shapes() {
    let Some(man) = common::manifest_or_skip("datasets_load_and_match_manifest_shapes") else {
        return;
    };
    for (key, entry) in &man.datasets {
        for file in [&entry.train, &entry.test] {
            let ds = pqs::data::Dataset::load(man.dataset_path(file)).expect("dataset");
            assert_eq!(
                vec![ds.c, ds.h, ds.w],
                entry.shape,
                "{key}/{file} shape mismatch"
            );
            assert_eq!(ds.labels.len(), ds.n);
            let hist = ds.class_histogram();
            assert_eq!(hist.len(), 10, "{key} classes");
            assert!(hist.iter().all(|&c| c > 0), "{key} has empty classes");
        }
    }
}

#[test]
fn a2q_models_respect_l1_bound() {
    // sum_k |w_q| <= (2^(p-1)-1) / 2^(b-1), with small rounding slack
    let Some(man) = common::manifest_or_skip("a2q_models_respect_l1_bound") else {
        return;
    };
    let mut checked = 0;
    for (name, entry) in &man.models {
        let Some(p) = entry.acc_bits_trained else { continue };
        let m = PqswModel::load(man.model_path(name)).unwrap();
        let limit = ((1i64 << (p - 1)) - 1) as f64 / (1i64 << (m.wbits - 1)) as f64;
        for (_, q) in m.q_layers() {
            for o in 0..q.oc {
                let l1: i64 = q.wq[o * q.k..(o + 1) * q.k].iter().map(|&v| (v as i64).abs()).sum();
                assert!(
                    l1 as f64 <= limit * 1.15 + 2.0,
                    "{name} layer {} row {o}: sum|w_q| = {l1} > limit {limit}",
                    q.name
                );
            }
        }
        checked += 1;
    }
    assert!(checked >= 4, "checked only {checked} a2q models");
}

#[test]
fn fig_experiments_present() {
    let Some(man) = common::manifest_or_skip("fig_experiments_present") else {
        return;
    };
    for exp in ["fig2", "fig3", "fig4", "fig5", "fp32"] {
        assert!(
            !man.experiment_models(exp).is_empty(),
            "experiment {exp} has no models"
        );
    }
}
