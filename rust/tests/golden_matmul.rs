//! Pallas-kernel parity: the matmul goldens were produced *by the Layer-1
//! Pallas kernel* (`pqs_matmul.py`, interpret=True); the Rust engine must
//! match them element-for-element, proving L1 and L3 implement identical
//! integer semantics. Skips (with a notice) when the goldens are not built.

mod common;

use pqs::accum::Policy;
use pqs::dot::DotEngine;
use pqs::formats::goldens::load_matmul_goldens;

#[test]
fn matmul_goldens_bit_exact() {
    let Some(path) = common::golden_or_skip("matmul_goldens_bit_exact", "matmul_goldens.json")
    else {
        return;
    };
    let cases = load_matmul_goldens(path).expect("parse matmul goldens");
    assert!(!cases.is_empty());
    let mut eng = DotEngine::new();
    for (ci, c) in cases.iter().enumerate() {
        let policy = Policy::from_name(&c.policy).expect("policy");
        for i in 0..c.m {
            for j in 0..c.n {
                let prods: Vec<i32> =
                    (0..c.k).map(|kk| c.x[i * c.k + kk] * c.w[kk * c.n + j]).collect();
                let (v, e) = eng.dot(&prods, c.p, policy);
                assert_eq!(
                    v,
                    c.y[i * c.n + j],
                    "case {ci} ({},{}) policy {} p {}",
                    i, j, c.policy, c.p
                );
                assert_eq!(
                    e as i64,
                    c.ovf[i * c.n + j],
                    "case {ci} events ({},{}) policy {} p {}",
                    i, j, c.policy, c.p
                );
            }
        }
    }
}
