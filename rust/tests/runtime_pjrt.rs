//! PJRT runtime integration: load the AOT HLO artifacts (lowered from JAX +
//! the Pallas kernel by `python/compile/aot.py`) and check their numerics
//! against the bit-accurate Rust engine.
//!
//! Skips (with a notice) when the build has no PJRT backend (offline
//! default: the `pjrt` cargo feature is off) or when artifacts are absent.

mod common;

use pqs::accum::Policy;
use pqs::data::Dataset;
use pqs::formats::manifest::Manifest;
use pqs::models;
use pqs::nn::engine::{Engine, EngineConfig};
use pqs::runtime::Runtime;

fn setup(test: &str) -> Option<(Manifest, Runtime)> {
    if !Runtime::available() {
        eprintln!("SKIP {test}: built without the `pjrt` feature");
        return None;
    }
    let man = common::manifest_or_skip(test)?;
    let rt = Runtime::cpu().expect("pjrt client");
    Some((man, rt))
}

#[test]
fn pallas_kernel_hlo_matches_engine() {
    let Some((man, rt)) = setup("pallas_kernel_hlo_matches_engine") else { return };
    let exe = rt.load_hlo(man.dir.join("model.hlo.txt")).expect("compile model.hlo.txt");

    let entry = man.test_dataset_for("mlp1").unwrap();
    let ds = Dataset::load(man.dataset_path(&entry.test)).unwrap();
    let imgs = ds.images_f32(0, 8);
    let outs = exe.run_f32(&imgs, &[8, 1, 28, 28]).expect("execute");
    assert_eq!(outs.len(), 2, "expected (logits, ovf_total)");
    let logits_hlo = &outs[0];
    assert_eq!(logits_hlo.len(), 80);

    // engine reference: sorted1, p=16 (the configuration baked by aot.py)
    let name = &man.experiments["fig2"][0];
    let model = models::load(&man, name).unwrap();
    let mut eng = Engine::new(
        &model,
        EngineConfig { policy: Policy::Sorted1, acc_bits: 16, ..Default::default() },
    );
    let out = eng.forward(&imgs, 8).unwrap();
    for i in 0..80 {
        let (a, b) = (logits_hlo[i], out.logits[i]);
        assert!(
            (a - b).abs() <= 1e-3 * a.abs().max(1.0),
            "logit {i}: hlo {a} vs engine {b}"
        );
    }
    // same top-1 predictions
    for i in 0..8 {
        let row = &logits_hlo[i * 10..(i + 1) * 10];
        let top_hlo = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(top_hlo, out.argmax(i), "sample {i}");
    }
}

#[test]
fn fp32_hlo_baseline_matches_engine_exact() {
    let Some((man, rt)) = setup("fp32_hlo_baseline_matches_engine_exact") else { return };
    // mlp1 fp32 graph exported per hlo/index.json
    let name = &man.experiments["fig2"][0];
    let hlo = man.dir.join(format!("hlo/{name}_fp32.hlo.txt"));
    let exe = rt.load_hlo(&hlo).expect("compile fp32 hlo");

    let entry = man.test_dataset_for("mlp1").unwrap();
    let ds = Dataset::load(man.dataset_path(&entry.test)).unwrap();
    let imgs = ds.images_f32(0, 8);
    let outs = exe.run_f32(&imgs, &[8, 1, 28, 28]).expect("execute");
    let logits_hlo = &outs[0];

    // The fp32 HLO runs the model without fake-quant; the engine's Exact
    // path runs the quantized model, so only top-1 agreement is expected.
    let model = models::load(&man, name).unwrap();
    let mut eng = Engine::new(
        &model,
        EngineConfig { policy: Policy::Exact, acc_bits: 32, ..Default::default() },
    );
    let out = eng.forward(&imgs, 8).unwrap();
    let mut agree = 0;
    for i in 0..8 {
        let row = &logits_hlo[i * 10..(i + 1) * 10];
        let top = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if top == out.argmax(i) {
            agree += 1;
        }
    }
    assert!(agree >= 6, "only {agree}/8 top-1 agreements between fp32 HLO and engine");
}

#[test]
fn cnn_fp32_hlo_runs() {
    let Some((man, rt)) = setup("cnn_fp32_hlo_runs") else { return };
    let cnns: Vec<&String> = man.experiments["fp32"]
        .iter()
        .filter(|n| !n.starts_with("mlp"))
        .collect();
    assert!(!cnns.is_empty());
    let name = cnns[0];
    let hlo = man.dir.join(format!("hlo/{name}_fp32.hlo.txt"));
    let exe = rt.load_hlo(&hlo).expect("compile cnn hlo");
    let entry = man.test_dataset_for("resnet_tiny").unwrap();
    let ds = Dataset::load(man.dataset_path(&entry.test)).unwrap();
    let imgs = ds.images_f32(0, 8);
    let outs = exe
        .run_f32(&imgs, &[8, ds.c, ds.h, ds.w])
        .expect("execute cnn");
    assert_eq!(outs[0].len(), 80);
    assert!(outs[0].iter().all(|v| v.is_finite()));
}
