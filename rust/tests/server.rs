//! Serving-runtime tests that run WITHOUT artifacts: tiny synthetic
//! `PqswModel`s exercise the persistent `Server` (backpressure, per-request
//! errors, deadlines/cancellation, draining shutdown), the multi-model
//! `Router` (lazy loads, LRU eviction with metrics continuity, unknown-name
//! fleet listings, two models bit-identical over one shared compute pool),
//! the engine's parallel forward path, the exact `limit` semantics, and the
//! sorted1 counting/radix pairing contract.
//!
//! Every blocking receive goes through `wait()` below (a bounded
//! `wait_timeout`), so a queue-logic regression fails the suite fast
//! instead of hanging it.

mod common;

use std::time::Duration;

use pqs::accum::{self, Policy};
use pqs::coordinator::{
    serve_requests, BreakerConfig, ClassifyRequest, EvalService, ModelRegistry, ModelSource,
    PendingResponse, Request, RouteError, Router, RouterConfig, ServeError, ServeResponse, Server,
    ServerConfig, SubmitError, SyntheticSpec,
};
use pqs::data::Dataset;
use pqs::dot::DotEngine;
use pqs::nn::engine::{Engine, EngineConfig};
use pqs::util::rng::Pcg32;

const DIM: usize = 64;
const CLASSES: usize = 10;

fn scfg(threads: usize, max_batch: usize, queue_cap: usize) -> ServerConfig {
    ServerConfig {
        threads,
        max_batch,
        queue_cap,
        linger: Duration::from_micros(50),
        engine_threads: 1,
        default_deadline: None,
    }
}

fn img(seed: u64) -> Vec<f32> {
    common::synth_images(1, DIM, seed)
}

/// Bounded wait: a response must arrive within 60s or the test fails fast
/// (instead of `PendingResponse::wait` hanging the whole suite).
fn wait(p: PendingResponse) -> ServeResponse {
    p.wait_timeout(Duration::from_secs(60)).expect("response within 60s (queue regression?)")
}

#[test]
fn server_serves_and_matches_offline_engine() {
    let model = common::tiny_linear_model(DIM, CLASSES);
    let cfg = EngineConfig { policy: Policy::Sorted, acc_bits: 20, ..Default::default() };
    let srv = Server::start(&model, cfg, scfg(2, 8, 64));
    let n = 100;
    let mut pending = Vec::new();
    for i in 0..n {
        pending.push(srv.submit(i as u64, img(i as u64), None).expect("submit"));
    }
    let mut eng = Engine::new(&model, cfg);
    for p in pending {
        let r = wait(p);
        let want = eng.forward(&img(r.id), 1).unwrap().argmax(0);
        assert_eq!(r.result, Ok(want), "request {}", r.id);
        assert!(r.latency_us > 0.0);
        assert!(r.compute_us > 0.0);
        assert!(r.queue_us >= 0.0);
        assert!(r.batch_size >= 1);
        // e2e latency covers queue wait + compute (within timing noise)
        assert!(r.latency_us + 1.0 >= r.compute_us);
    }
    let m = srv.shutdown();
    assert_eq!(m.requests, n);
    assert_eq!(m.errors, 0);
    assert_eq!(m.expired, 0);
    assert_eq!(m.latency.count(), n);
    assert!(m.batches >= 1);
    assert!(m.mean_batch >= 1.0);
}

#[test]
fn bad_size_request_yields_error_response_not_panic() {
    let model = common::tiny_linear_model(DIM, CLASSES);
    let cfg = EngineConfig::default();
    let srv = Server::start(&model, cfg, scfg(2, 4, 64));
    // interleave good and malformed requests
    let good1 = srv.submit(1, img(1), None).unwrap();
    let bad = srv.submit(2, vec![0.25; DIM / 2], None).unwrap();
    let bad_empty = srv.submit(3, Vec::new(), None).unwrap();
    let good2 = srv.submit(4, img(4), None).unwrap();
    assert!(wait(good1).result.is_ok());
    match wait(bad).result {
        Err(ServeError::BadRequest(msg)) => assert!(msg.contains("32"), "msg: {msg}"),
        other => panic!("expected BadRequest, got {other:?}"),
    }
    assert!(matches!(wait(bad_empty).result, Err(ServeError::BadRequest(_))));
    // the service survived and still answers correctly
    assert!(wait(good2).result.is_ok());
    let m = srv.shutdown();
    assert_eq!(m.requests, 4);
    assert_eq!(m.errors, 2);
}

#[test]
fn backpressure_bound_is_respected() {
    // a deliberately slow model (long sorted1 dots) pins the single worker
    // while the producer floods the bounded queue
    let model = common::tiny_linear_model(2048, 64);
    let cfg = EngineConfig { policy: Policy::Sorted1, acc_bits: 16, ..Default::default() };
    let cap = 4;
    let srv = Server::start(&model, cfg, scfg(1, 1, cap));
    let image: Vec<f32> = common::synth_images(1, 2048, 7);
    let mut accepted = Vec::new();
    let mut fulls = 0usize;
    for i in 0..(cap + 12) as u64 {
        match srv.try_submit(i, image.clone(), None) {
            Ok(p) => accepted.push(p),
            Err(SubmitError::Full(returned)) => {
                fulls += 1;
                // the image is handed back intact for retry/load-shedding
                assert_eq!(returned.len(), 2048);
            }
            Err(SubmitError::Closed(_)) => panic!("server is not closed"),
        }
        assert!(srv.queue_len() <= cap, "queue grew past its bound");
    }
    assert!(fulls > 0, "queue never filled: backpressure untested");
    // every accepted request still completes
    for p in accepted {
        assert!(wait(p).result.is_ok());
    }
    srv.shutdown();
}

#[test]
fn shutdown_drains_the_queue() {
    let model = common::tiny_linear_model(DIM, CLASSES);
    let cfg = EngineConfig::default();
    let srv = Server::start(&model, cfg, scfg(2, 8, 256));
    let n = 200;
    let pending: Vec<_> =
        (0..n).map(|i| srv.submit(i as u64, img(i as u64), None).expect("submit")).collect();
    // close immediately: every queued request must still be answered
    let m = srv.shutdown();
    assert_eq!(m.requests, n);
    assert_eq!(m.errors, 0);
    for p in pending {
        assert!(wait(p).result.is_ok());
    }
}

#[test]
fn metrics_snapshot_and_server_restart() {
    let model = common::tiny_linear_model(DIM, CLASSES);
    let srv = Server::start(&model, EngineConfig::default(), scfg(1, 4, 16));
    let metrics_before = srv.metrics();
    assert_eq!(metrics_before.requests, 0);
    let probe = srv.submit(0, img(0), None).unwrap();
    assert!(wait(probe).result.is_ok());
    let m = srv.shutdown();
    assert_eq!(m.requests, 1);
    // the server is gone; a fresh one still works (no global state)
    let model2 = common::tiny_linear_model(DIM, CLASSES);
    let srv2 = Server::start(&model2, EngineConfig::default(), scfg(1, 4, 16));
    assert!(wait(srv2.submit(9, img(9), None).unwrap()).result.is_ok());
    srv2.shutdown();
}

#[test]
fn expired_request_answers_without_touching_an_engine() {
    let model = common::tiny_linear_model(DIM, CLASSES);
    let srv = Server::start(&model, EngineConfig::default(), scfg(1, 4, 16));
    // a zero deadline is already expired when the worker assembles it
    let p = srv.submit(7, img(7), Some(Duration::ZERO)).unwrap();
    let r = wait(p);
    match r.result {
        Err(ServeError::Expired { .. }) => {}
        other => panic!("expected Expired, got {other:?}"),
    }
    assert_eq!(r.batch_size, 0, "expired requests must never ride an engine batch");
    assert_eq!(r.compute_us, 0.0, "expired requests must never touch an engine");
    let m = srv.shutdown();
    assert_eq!(m.expired, 1, "expired counter must increment");
    assert_eq!(m.errors, 0, "expiry is accounted separately from errors");
    assert_eq!(m.requests, 1);
}

#[test]
fn default_deadline_from_config_applies_and_is_overridable() {
    let model = common::tiny_linear_model(DIM, CLASSES);
    let mut cfg = scfg(1, 4, 16);
    cfg.default_deadline = Some(Duration::ZERO);
    let srv = Server::start(&model, EngineConfig::default(), cfg);
    // no explicit deadline: the config default (already expired) applies
    let expired = srv.submit(1, img(1), None).unwrap();
    assert!(matches!(wait(expired).result, Err(ServeError::Expired { .. })));
    // an explicit generous deadline overrides the default
    let alive = srv.submit(2, img(2), Some(Duration::from_secs(60))).unwrap();
    assert!(wait(alive).result.is_ok());
    let m = srv.shutdown();
    assert_eq!(m.expired, 1);
    assert_eq!(m.requests, 2);
}

#[test]
fn expired_requests_do_not_poison_batchmates() {
    let model = common::tiny_linear_model(DIM, CLASSES);
    let srv = Server::start(&model, EngineConfig::default(), scfg(1, 8, 32));
    let e1 = srv.submit(1, img(1), Some(Duration::ZERO)).unwrap();
    let g1 = srv.submit(2, img(2), None).unwrap();
    let e2 = srv.submit(3, img(3), Some(Duration::ZERO)).unwrap();
    let g2 = srv.submit(4, img(4), Some(Duration::from_secs(60))).unwrap();
    assert!(matches!(wait(e1).result, Err(ServeError::Expired { .. })));
    assert!(wait(g1).result.is_ok(), "live batch-mate must still classify");
    assert!(matches!(wait(e2).result, Err(ServeError::Expired { .. })));
    assert!(wait(g2).result.is_ok(), "live batch-mate must still classify");
    let m = srv.shutdown();
    assert_eq!(m.expired, 2);
    assert_eq!(m.requests, 4);
}

#[test]
fn inflight_requests_with_deadlines_complete_during_shutdown_drain() {
    let model = common::tiny_linear_model(DIM, CLASSES);
    let srv = Server::start(&model, EngineConfig::default(), scfg(2, 8, 256));
    let n = 100;
    // generous deadlines: the drain must answer them all, not expire them
    let pending: Vec<_> = (0..n)
        .map(|i| {
            srv.submit(i as u64, img(i as u64), Some(Duration::from_secs(60))).expect("submit")
        })
        .collect();
    let m = srv.shutdown();
    assert_eq!(m.requests, n);
    assert_eq!(m.expired, 0, "draining shutdown must not expire generous deadlines");
    assert_eq!(m.errors, 0);
    for p in pending {
        assert!(wait(p).result.is_ok());
    }
}

#[test]
fn serve_requests_shim_over_synthetic_model() {
    let model = common::tiny_linear_model(DIM, CLASSES);
    let cfg = EngineConfig { policy: Policy::Sorted, acc_bits: 20, ..Default::default() };
    let n = 50;
    let mut requests: Vec<Request> = (0..n)
        .map(|i| Request { id: i as u64, image: img(i as u64) })
        .collect();
    requests.push(Request { id: n as u64, image: vec![0.0; 3] }); // malformed
    let (resp, metrics) = serve_requests(&model, cfg, requests, 8, 2).unwrap();
    assert_eq!(resp.len(), n + 1);
    assert_eq!(metrics.requests, n + 1);
    assert_eq!(metrics.errors, 1);
    let mut eng = Engine::new(&model, cfg);
    for (i, r) in resp.iter().enumerate() {
        assert_eq!(r.id, i as u64, "sorted by id");
        if i < n {
            assert!(r.error.is_none());
            let want = eng.forward(&img(r.id), 1).unwrap().argmax(0);
            assert_eq!(r.class, want);
            assert!(r.latency_us > 0.0, "per-request latency must be positive");
        } else {
            assert!(r.error.is_some(), "malformed request must carry an error");
        }
    }
}

#[test]
fn parallel_forward_bit_identical_on_synthetic_model() {
    let model = common::tiny_linear_model(DIM, CLASSES);
    for policy in [Policy::Exact, Policy::Clip, Policy::Sorted, Policy::Sorted1] {
        let cfg = EngineConfig { policy, acc_bits: 14, collect_stats: true, tile: 0 };
        let imgs = common::synth_images(32, DIM, 99);
        let mut serial = Engine::new(&model, cfg);
        let mut parallel = Engine::new(&model, cfg).with_threads(4);
        let a = serial.forward(&imgs, 32).unwrap();
        let b = parallel.forward(&imgs, 32).unwrap();
        assert_eq!(a.logits, b.logits, "{policy:?}");
        assert_eq!(a.report.total(), b.report.total(), "{policy:?}");
    }
}

#[test]
fn pooled_forward_bit_identical_across_thread_counts() {
    // the ISSUE contract: ComputePool-backed forwards produce bit-identical
    // logits AND overflow counters vs the serial path for threads in
    // {1, 2, 8}, across batch sizes (batch-1 takes the position/channel/
    // row-parallel splits; larger batches the image/row-parallel ones),
    // on both a linear model and a CNN with conv + depthwise layers
    let models: Vec<pqs::formats::pqsw::PqswModel> = vec![
        common::tiny_linear_model(DIM, CLASSES),
        pqs::models::synthetic_conv(2, 9, 9, 4, CLASSES),
    ];
    for model in &models {
        let dim: usize = model.input_shape.iter().product();
        for policy in [Policy::Exact, Policy::Clip, Policy::Sorted, Policy::Sorted1] {
            let cfg = EngineConfig { policy, acc_bits: 14, collect_stats: true, tile: 0 };
            for batch in [1usize, 3, 16] {
                let imgs = common::synth_images(batch, dim, 42 + batch as u64);
                let mut serial = Engine::new(model, cfg);
                let a = serial.forward(&imgs, batch).unwrap();
                for threads in [1usize, 2, 8] {
                    let pool = std::sync::Arc::new(pqs::util::pool::ComputePool::new(threads));
                    let mut pooled = Engine::new(model, cfg).with_pool(pool);
                    let b = pooled.forward(&imgs, batch).unwrap();
                    let ctx = format!("{} {policy:?} batch={batch} threads={threads}", model.name);
                    assert_eq!(a.logits, b.logits, "logits diverged: {ctx}");
                    assert_eq!(a.report.total(), b.report.total(), "stats diverged: {ctx}");
                    for i in 0..batch {
                        assert_eq!(a.argmax(i), b.argmax(i), "class diverged: {ctx}");
                    }
                    // scoped-thread fallback agrees too
                    let mut scoped = Engine::new(model, cfg).with_threads(threads);
                    let c = scoped.forward(&imgs, batch).unwrap();
                    assert_eq!(a.logits, c.logits, "scoped diverged: {ctx}");
                    assert_eq!(a.report.total(), c.report.total(), "scoped stats: {ctx}");
                }
            }
        }
    }
}

#[test]
fn one_pool_shared_by_many_engines_stays_bit_identical() {
    // N engines over ONE pool (the Server topology): concurrent forwards
    // through the shared pool must all match the serial reference
    let model = pqs::models::synthetic_conv(2, 8, 8, 4, CLASSES);
    let dim: usize = model.input_shape.iter().product();
    let cfg = EngineConfig { policy: Policy::Sorted1, acc_bits: 14, collect_stats: true, tile: 0 };
    let imgs = common::synth_images(1, dim, 7);
    let mut serial = Engine::new(&model, cfg);
    let want = serial.forward(&imgs, 1).unwrap();
    let pool = std::sync::Arc::new(pqs::util::pool::ComputePool::new(4));
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let pool = std::sync::Arc::clone(&pool);
            let (model, imgs, want_logits, want_total) =
                (&model, &imgs, &want.logits, want.report.total());
            scope.spawn(move || {
                let mut eng = Engine::new(model, cfg).with_pool(pool);
                for _ in 0..10 {
                    let got = eng.forward(imgs, 1).unwrap();
                    assert_eq!(&got.logits, want_logits);
                    assert_eq!(got.report.total(), want_total);
                }
            });
        }
    });
    let s = pool.stats();
    assert!(s.jobs > 0, "shared pool must have served jobs");
}

#[test]
fn server_with_shared_engine_pool_matches_single_threaded_server() {
    // end-to-end: a Server with engine_threads > 1 (one shared ComputePool
    // across workers) classifies exactly like the engine_threads = 1 one,
    // and its metrics expose the pool utilization
    let model = pqs::models::synthetic_conv(2, 8, 8, 4, CLASSES);
    let dim: usize = model.input_shape.iter().product();
    let cfg = EngineConfig { policy: Policy::Sorted1, acc_bits: 16, ..Default::default() };
    let mut pooled_cfg = scfg(2, 4, 64);
    pooled_cfg.engine_threads = 4;
    let srv = Server::start(&model, cfg, pooled_cfg);
    let mut eng = Engine::new(&model, cfg);
    let n = 40;
    let pending: Vec<_> = (0..n)
        .map(|i| {
            srv.submit(i as u64, common::synth_images(1, dim, i as u64), None).expect("submit")
        })
        .collect();
    for p in pending {
        let r = wait(p);
        let want = eng.forward(&common::synth_images(1, dim, r.id), 1).unwrap().argmax(0);
        assert_eq!(r.result, Ok(want), "request {}", r.id);
    }
    let m = srv.shutdown();
    assert_eq!(m.requests, n);
    assert_eq!(m.errors, 0);
    let pool = m.pool.expect("engine_threads > 1 must expose pool stats");
    assert_eq!(pool.threads, 4);
    assert!(pool.jobs > 0, "batch-1 conv requests must dispatch pool jobs");
    assert!(pool.chunks >= pool.jobs + pool.inline_jobs, "every job claims at least one chunk");
    // engine_threads = 1 exposes no pool
    let srv1 = Server::start(&model, cfg, scfg(1, 4, 16));
    assert!(wait(srv1.submit(0, common::synth_images(1, dim, 0), None).unwrap()).result.is_ok());
    assert!(srv1.shutdown().pool.is_none());
}

// ---- multi-model router ---------------------------------------------------

fn req(id: u64, model: Option<&str>, image: Vec<f32>) -> ClassifyRequest {
    ClassifyRequest {
        id,
        model: model.map(String::from),
        image,
        deadline: None,
        acc_bits: None,
        trace: None,
    }
}

fn three_model_registry() -> ModelRegistry {
    let mut registry = ModelRegistry::new();
    registry.register("m1", ModelSource::Memory(common::tiny_linear_model(DIM, CLASSES)));
    registry.register(
        "m2",
        ModelSource::Synthetic(SyntheticSpec::Linear { dim: DIM * 2, classes: CLASSES }),
    );
    registry.register(
        "m3",
        ModelSource::Synthetic(SyntheticSpec::Conv { c: 2, h: 5, w: 5, oc: 4, classes: CLASSES }),
    );
    registry
}

#[test]
fn router_loads_lazily_and_routes_to_the_default() {
    let registry = three_model_registry();
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: EngineConfig::default(),
        server: scfg(1, 4, 16),
        preload: Vec::new(),
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).unwrap();
    assert_eq!(router.default_model(), "m1");
    // registration loads nothing
    let m = router.metrics();
    assert_eq!(m.loads, 0);
    assert!(m.models.iter().all(|s| !s.loaded), "lazy: no model loads at startup");
    assert_eq!(m.models.len(), 3);
    // in-memory and synthetic sources know their shapes without loading
    assert_eq!(m.models[0].input_shape.as_deref(), Some(&[1, DIM, 1][..]));
    assert_eq!(m.models[2].input_shape.as_deref(), Some(&[2, 5, 5][..]));
    // the first request loads exactly the default model
    let r = wait(router.submit(req(1, None, img(1))).expect("routes to default"));
    let mut eng = Engine::new(&common::tiny_linear_model(DIM, CLASSES), EngineConfig::default());
    let want = eng.forward(&img(1), 1).unwrap().argmax(0);
    assert_eq!(r.result, Ok(want));
    let m = router.metrics();
    assert_eq!(m.loads, 1);
    assert_eq!(m.routed, 1);
    assert_eq!(m.load_latency.count, 1);
    let loaded: Vec<&str> =
        m.models.iter().filter(|s| s.loaded).map(|s| s.name.as_str()).collect();
    assert_eq!(loaded, vec!["m1"], "only the requested model loads");
    let final_m = router.shutdown();
    assert_eq!(final_m.model("m1").unwrap().metrics.requests, 1);
    assert_eq!(final_m.model("m2").unwrap().metrics.requests, 0);
}

#[test]
fn router_unknown_model_fails_fast_with_fleet_listing() {
    let registry = three_model_registry();
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: EngineConfig::default(),
        server: scfg(1, 4, 16),
        preload: Vec::new(),
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).unwrap();
    match router.submit(req(1, Some("m9"), img(1))) {
        Err(RouteError::UnknownModel(msg)) => {
            assert!(msg.contains("m9"), "names the miss: {msg}");
            for name in ["m1", "m2", "m3"] {
                assert!(msg.contains(name), "lists {name}: {msg}");
            }
        }
        Err(other) => panic!("expected UnknownModel, got {other:?}"),
        Ok(_) => panic!("expected UnknownModel, got an accepted submission"),
    }
    let m = router.shutdown();
    assert_eq!(m.unknown_model, 1);
    assert_eq!(m.routed, 0);
    assert_eq!(m.loads, 0, "an unknown name must not trigger a load");
}

#[test]
fn router_lru_eviction_under_max_loaded_preserves_metrics() {
    let registry = three_model_registry();
    let rcfg = RouterConfig {
        max_loaded: 2,
        max_bytes: 0,
        engine: EngineConfig::default(),
        server: scfg(1, 4, 16),
        preload: Vec::new(),
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).unwrap();
    let dim2 = DIM * 2;
    let img2 = common::synth_images(1, dim2, 2);
    let img3 = common::synth_images(1, 2 * 5 * 5, 3);
    // load m1 then m2 (cap 2: both stay)
    assert!(wait(router.submit(req(1, Some("m1"), img(1))).unwrap()).result.is_ok());
    assert!(wait(router.submit(req(2, Some("m2"), img2.clone())).unwrap()).result.is_ok());
    let m = router.metrics();
    assert_eq!(m.loads, 2);
    assert_eq!(m.evictions, 0);
    // touch m1 so m2 becomes the LRU, then load m3: m2 must be evicted
    assert!(wait(router.submit(req(3, Some("m1"), img(3))).unwrap()).result.is_ok());
    assert!(wait(router.submit(req(4, Some("m3"), img3)).unwrap()).result.is_ok());
    let m = router.metrics();
    assert_eq!(m.loads, 3);
    assert_eq!(m.evictions, 1);
    let loaded: Vec<&str> =
        m.models.iter().filter(|s| s.loaded).map(|s| s.name.as_str()).collect();
    assert_eq!(loaded, vec!["m1", "m3"], "the LRU model (m2) is evicted");
    // m2's history survived eviction
    assert_eq!(m.model("m2").unwrap().metrics.requests, 1);
    // requesting m2 again reloads it and evicts m1 (LRU now)
    assert!(wait(router.submit(req(5, Some("m2"), img2)).unwrap()).result.is_ok());
    let m = router.metrics();
    assert_eq!(m.loads, 4);
    assert_eq!(m.evictions, 2);
    let loaded: Vec<&str> =
        m.models.iter().filter(|s| s.loaded).map(|s| s.name.as_str()).collect();
    assert_eq!(loaded, vec!["m2", "m3"]);
    // lifetime metrics: m2 across two incarnations
    let final_m = router.shutdown();
    assert_eq!(final_m.model("m1").unwrap().metrics.requests, 2);
    assert_eq!(final_m.model("m2").unwrap().metrics.requests, 2);
    assert_eq!(final_m.model("m3").unwrap().metrics.requests, 1);
    assert_eq!(final_m.routed, 5);
}

#[test]
fn router_two_models_one_pool_bit_identical_to_dedicated_servers() {
    // the ISSUE acceptance contract: two models served concurrently from
    // ONE shared ComputePool classify exactly like two dedicated
    // single-model servers fed the same requests
    let linear = common::tiny_linear_model(DIM, CLASSES);
    let conv = pqs::models::synthetic_conv(2, 8, 8, 4, CLASSES);
    let conv_dim: usize = conv.input_shape.iter().product();
    let cfg = EngineConfig { policy: Policy::Sorted1, acc_bits: 16, ..Default::default() };
    let mut sc = scfg(2, 4, 64);
    sc.engine_threads = 4; // ONE pool of 4, shared by both models' engines
    let n = 30u64;

    // dedicated single-model reference servers
    let ded_lin = Server::start(&linear, cfg, sc);
    let ded_conv = Server::start(&conv, cfg, sc);
    let mut want_lin = Vec::new();
    let mut want_conv = Vec::new();
    for i in 0..n {
        let p = ded_lin.submit(i, img(i), None).unwrap();
        want_lin.push(wait(p).result.expect("dedicated linear serves"));
        let p = ded_conv.submit(i, common::synth_images(1, conv_dim, i), None).unwrap();
        want_conv.push(wait(p).result.expect("dedicated conv serves"));
    }
    ded_lin.shutdown();
    ded_conv.shutdown();

    // the same requests through one router, interleaved from two threads
    let mut registry = ModelRegistry::new();
    registry.register("lin", ModelSource::Memory(linear));
    registry.register("conv", ModelSource::Memory(conv));
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: cfg,
        server: sc,
        preload: Vec::new(),
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).unwrap();
    std::thread::scope(|scope| {
        let router = &router;
        let want_lin = &want_lin;
        let want_conv = &want_conv;
        scope.spawn(move || {
            for i in 0..n {
                let p = router.submit(req(i, Some("lin"), img(i))).expect("routes");
                assert_eq!(wait(p).result, Ok(want_lin[i as usize]), "lin request {i}");
            }
        });
        scope.spawn(move || {
            for i in 0..n {
                let image = common::synth_images(1, conv_dim, i);
                let p = router.submit(req(i, Some("conv"), image)).expect("routes");
                assert_eq!(wait(p).result, Ok(want_conv[i as usize]), "conv request {i}");
            }
        });
    });
    let m = router.shutdown();
    assert_eq!(m.routed, 2 * n);
    assert_eq!(m.model("lin").unwrap().metrics.requests, n as usize);
    assert_eq!(m.model("conv").unwrap().metrics.requests, n as usize);
    let pool = m.pool.expect("engine_threads > 1 must expose the shared pool");
    assert_eq!(pool.threads, 4);
    assert!(pool.jobs + pool.inline_jobs > 0, "conv forwards must dispatch pool jobs");
}

#[test]
fn router_preload_loads_eagerly_and_counts() {
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: EngineConfig::default(),
        server: scfg(1, 4, 16),
        preload: vec!["m2".to_string(), "m3".to_string()],
        ..Default::default()
    };
    let router = Router::new(three_model_registry(), rcfg).unwrap();
    let m = router.metrics();
    assert_eq!(m.loads, 2, "each preload counts as a load");
    assert_eq!(m.routed, 0, "preloads are not routed requests");
    assert_eq!(m.load_latency.count, 2);
    let loaded: Vec<&str> =
        m.models.iter().filter(|s| s.loaded).map(|s| s.name.as_str()).collect();
    assert_eq!(loaded, vec!["m2", "m3"], "exactly the preloaded models are live");
    // a request to a preloaded model rides the live server (no new load)
    let r = wait(router.submit(req(1, Some("m2"), common::synth_images(1, DIM * 2, 1))).unwrap());
    assert!(r.result.is_ok());
    let m = router.shutdown();
    assert_eq!(m.loads, 2, "serving a preloaded model must not reload it");
    assert_eq!(m.routed, 1);
    assert_eq!(m.model("m2").unwrap().metrics.requests, 1);
    // an unknown preload name fails router construction, naming the miss
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: EngineConfig::default(),
        server: scfg(1, 4, 16),
        preload: vec!["m9".to_string()],
        ..Default::default()
    };
    let err = Router::new(three_model_registry(), rcfg).unwrap_err();
    assert!(format!("{err:#}").contains("m9"), "err: {err:#}");
}

#[test]
fn metrics_scrape_does_not_serialize_behind_a_blocked_load() {
    // the cheap-snapshot contract: a /v1/metrics-style scrape must
    // complete while a model load is in flight (loads run outside the
    // router lock; snapshots take it only for counters + Copy summaries).
    // A Factory source blocks its load on a barrier, deterministically
    // pinning the load mid-flight while the scrape runs.
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Barrier};
    let gate = Arc::new(Barrier::new(2));
    let started = Arc::new(AtomicBool::new(false));
    let mut registry = ModelRegistry::new();
    registry.register("fast", ModelSource::Memory(common::tiny_linear_model(DIM, CLASSES)));
    let (g, st) = (Arc::clone(&gate), Arc::clone(&started));
    registry.register(
        "slow",
        ModelSource::factory(move || {
            st.store(true, Ordering::Release);
            g.wait(); // held here until the test releases the load
            Ok(pqs::models::synthetic_linear(DIM, CLASSES))
        }),
    );
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: EngineConfig::default(),
        server: scfg(1, 4, 16),
        preload: Vec::new(),
        ..Default::default()
    };
    let router = Arc::new(Router::new(registry, rcfg).unwrap());
    // kick the slow load off and wait until it is genuinely in flight
    let r2 = Arc::clone(&router);
    let loader = std::thread::spawn(move || {
        let p = r2.submit(req(1, Some("slow"), img(1))).expect("routes once loaded");
        wait(p)
    });
    while !started.load(Ordering::Acquire) {
        std::thread::yield_now();
    }
    // the scrape must return NOW, with the load still blocked on the
    // barrier; a bounded wait turns a serialization regression into a
    // fast failure instead of a suite deadlock
    let r3 = Arc::clone(&router);
    let (tx, rx) = std::sync::mpsc::channel();
    let scraper = std::thread::spawn(move || {
        let _ = tx.send(r3.metrics());
    });
    let m = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("metrics scrape must not wait for an in-flight load");
    assert_eq!(m.loads, 0, "the blocked load has not completed yet");
    assert!(!m.model("slow").unwrap().loaded);
    // routing to the OTHER model also proceeds during the blocked load
    let p = router.submit(req(2, Some("fast"), img(2))).expect("fast model routes");
    assert!(wait(p).result.is_ok());
    // release the load: the blocked request completes normally
    gate.wait();
    let r = loader.join().expect("loader thread");
    assert!(r.result.is_ok());
    scraper.join().expect("scraper thread");
    let router = Arc::try_unwrap(router).ok().expect("threads joined; sole owner");
    let m = router.shutdown();
    assert_eq!(m.loads, 2, "fast + slow both loaded in the end");
    assert_eq!(m.model("slow").unwrap().metrics.requests, 1);
    assert_eq!(m.model("fast").unwrap().metrics.requests, 1);
}

#[test]
fn server_drain_via_shared_handle_is_final_and_idempotent() {
    // the router's eviction path: close + drain a Server through an Arc
    // (&self), no ownership needed; afterwards submits are refused and a
    // second drain observes the same final counters
    let model = common::tiny_linear_model(DIM, CLASSES);
    let srv =
        std::sync::Arc::new(Server::start(&model, EngineConfig::default(), scfg(2, 4, 32)));
    let pending: Vec<_> =
        (0..20u64).map(|i| srv.submit(i, img(i), None).expect("submit while open")).collect();
    let m1 = srv.drain();
    assert_eq!(m1.requests, 20, "drain answers every queued request first");
    assert_eq!(m1.errors, 0);
    for p in pending {
        assert!(wait(p).result.is_ok());
    }
    assert!(
        matches!(srv.try_submit(99, img(0), None), Err(SubmitError::Closed(_))),
        "post-drain submissions are refused"
    );
    let m2 = srv.drain();
    assert_eq!(m2.requests, 20, "a second drain is a no-op with final counters");
}

#[test]
fn router_default_and_wrong_size_semantics() {
    let registry = three_model_registry();
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: EngineConfig::default(),
        server: scfg(1, 4, 16),
        preload: Vec::new(),
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).unwrap();
    // wrong-sized image for the ROUTED model is a per-request BadRequest
    // from that model's server (never a panic, never misrouted)
    let r = wait(router.submit(req(1, Some("m2"), img(1))).unwrap());
    match r.result {
        Err(ServeError::BadRequest(msg)) => {
            assert!(msg.contains(&(DIM * 2).to_string()), "names m2's dim: {msg}")
        }
        other => panic!("expected BadRequest, got {other:?}"),
    }
    // try_submit routes too
    let r = wait(router.try_submit(req(2, None, img(2))).unwrap());
    assert!(r.result.is_ok());
    router.shutdown();
}

#[test]
fn forward_rejects_wrong_size_without_panic() {
    let model = common::tiny_linear_model(DIM, CLASSES);
    let mut eng = Engine::new(&model, EngineConfig::default());
    let err = eng.forward(&[0.5; 10], 1).unwrap_err();
    assert!(format!("{err:#}").contains("input size"));
}

#[test]
fn evaluate_limit_is_exact_on_synthetic_dataset() {
    let model = common::tiny_linear_model(DIM, CLASSES);
    let n = 10;
    let ds = Dataset {
        n,
        c: 1,
        h: DIM,
        w: 1,
        pixels: (0..n * DIM).map(|i| (i * 37 % 251) as u8).collect(),
        labels: (0..n).map(|i| (i % CLASSES) as u8).collect(),
    };
    // EvalService reports samples == limit even when it splits mid-batch
    let cfg = EngineConfig { collect_stats: true, ..Default::default() };
    let out = EvalService::new(&model, cfg).with_batch(4).evaluate(&ds, Some(7)).unwrap();
    assert_eq!(out.samples, 7);
    assert_eq!(out.report.total().dots, (7 * CLASSES) as u64);
    // Engine::evaluate must truncate identically (it used to overshoot)
    let mut eng = Engine::new(&model, cfg);
    let (_, report) = eng.evaluate(&ds, 4, Some(7)).unwrap();
    assert_eq!(report.total().dots, (7 * CLASSES) as u64);
    // limit of 0 evaluates nothing
    let (_, report0) = eng.evaluate(&ds, 4, Some(0)).unwrap();
    assert_eq!(report0.total().dots, 0);
}

#[test]
fn sorted1_fast_pairing_matches_reference_end_to_end() {
    // ISSUE contract via the public API: the adaptive counting/radix
    // pairing inside Policy::Sorted1 must be bit-identical (value AND
    // event count) to a reference comparison-sort pairing
    fn reference_sorted1(prods: &[i32], p: u32) -> (i64, u32) {
        let mut pos: Vec<i32> = prods.iter().copied().filter(|&v| v > 0).collect();
        let mut neg: Vec<i32> = prods.iter().copied().filter(|&v| v < 0).collect();
        pos.sort_unstable_by(|a, b| b.cmp(a));
        neg.sort_unstable();
        let m = pos.len().min(neg.len());
        let mut seq: Vec<i32> = (0..m).map(|i| pos[i] + neg[i]).collect();
        if pos.len() > m {
            seq.extend_from_slice(&pos[m..]);
        } else {
            seq.extend_from_slice(&neg[m..]);
        }
        accum::clip_accumulate(&seq, p)
    }

    let mut rng = Pcg32::new(0x50F7);
    let mut eng = DotEngine::new();
    for case in 0..400 {
        // mix of lengths and value ranges so every sort strategy fires
        let len = (rng.below(1500)) as usize;
        let bound = [30i32, 500, 32385][rng.below(3) as usize];
        let prods = rng.ivec(len, -bound, bound);
        let p = 12 + rng.below(10);
        let got = eng.dot(&prods, p, Policy::Sorted1);
        let want = reference_sorted1(&prods, p);
        assert_eq!(got, want, "case {case}: len {len} bound {bound} p {p}");
    }
}

// ---- self-healing: panic isolation, circuit breaker, quarantine -----------

#[test]
fn worker_survives_forward_panics_and_answers_riders_internal() {
    // regression for the worker-loop panic path: a panic inside a batch
    // forward must answer that batch's riders with `Internal`, rebuild
    // the engine, and leave the worker alive for every later request —
    // it must never take the queue (or its senders) down with it
    use pqs::faults::{FaultPlan, FaultSpec};
    use std::sync::Arc;
    let plan = Arc::new(FaultPlan::new(FaultSpec { panic_every: 3, ..Default::default() }));
    let mut registry = ModelRegistry::new();
    registry.register("m", ModelSource::Memory(common::tiny_linear_model(DIM, CLASSES)));
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: EngineConfig::default(),
        server: scfg(1, 1, 16), // max_batch 1: each request is its own batch
        preload: Vec::new(),
        faults: Some(Arc::clone(&plan)),
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).unwrap();
    let (mut ok, mut panicked) = (0u64, 0u64);
    for i in 0..12u64 {
        let r = wait(router.submit(req(i, Some("m"), img(i))).expect("routes"));
        match r.result {
            Ok(_) => ok += 1,
            Err(ServeError::Internal(msg)) => {
                assert!(msg.contains("panicked"), "names the panic: {msg}");
                panicked += 1;
            }
            other => panic!("request {i}: expected Ok or Internal, got {other:?}"),
        }
    }
    // every 3rd forward panics: 12 sequential one-request batches → 4
    assert_eq!((ok, panicked), (8, 4));
    assert_eq!(plan.counts().panics, 4);
    // disarmed, the same worker keeps serving on its rebuilt engine
    plan.disarm();
    assert!(wait(router.submit(req(99, Some("m"), img(99))).unwrap()).result.is_ok());
    let m = router.shutdown();
    let s = m.model("m").unwrap();
    assert_eq!(s.metrics.requests, 13, "panicked riders still count as answered requests");
    assert_eq!(s.metrics.errors, 4);
}

#[test]
fn load_breaker_opens_fast_fails_then_probe_closes_it() {
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::Arc;
    let fails = Arc::new(AtomicU32::new(2));
    let mut registry = ModelRegistry::new();
    let f = Arc::clone(&fails);
    registry.register(
        "flaky",
        ModelSource::factory(move || {
            if f.load(Ordering::SeqCst) > 0 {
                f.fetch_sub(1, Ordering::SeqCst);
                return Err(anyhow::anyhow!("flaky: injected load failure"));
            }
            Ok(common::tiny_linear_model(DIM, CLASSES))
        }),
    );
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: EngineConfig::default(),
        server: scfg(1, 4, 16),
        preload: Vec::new(),
        breaker: BreakerConfig {
            threshold: 2,
            base_backoff: Duration::from_millis(300),
            max_backoff: Duration::from_millis(900),
            ..Default::default()
        },
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).unwrap();
    // failure 1: below threshold — plain LoadFailed, breaker still Closed
    match router.submit(req(1, Some("flaky"), img(1))) {
        Err(RouteError::LoadFailed(msg)) => assert!(msg.contains("flaky"), "msg: {msg}"),
        other => panic!("expected LoadFailed, got {other:?}"),
    }
    let h = router.health("flaky").expect("failure recorded");
    assert_eq!(h.breaker.as_str(), "closed");
    assert_eq!(h.consecutive_failures, 1);
    // failure 2: hits the threshold — the breaker trips Open
    assert!(matches!(
        router.submit(req(2, Some("flaky"), img(2))),
        Err(RouteError::LoadFailed(_))
    ));
    let h = router.health("flaky").unwrap();
    assert_eq!(h.breaker.as_str(), "open");
    assert_eq!(h.breaker_opens, 1);
    assert!(h.retry_after_s > 0.0, "an Open breaker advertises its backoff");
    // while Open: requests fast-fail with the time remaining, the source
    // is never touched, and the default-model readiness probe goes false
    match router.submit(req(3, Some("flaky"), img(3))) {
        Err(RouteError::BreakerOpen { model, retry_after }) => {
            assert_eq!(model, "flaky");
            assert!(retry_after > Duration::ZERO);
        }
        other => panic!("expected BreakerOpen, got {other:?}"),
    }
    assert_eq!(fails.load(Ordering::SeqCst), 0, "fast-fails never touch the source");
    assert_eq!(router.health("flaky").unwrap().fast_fails, 1);
    assert!(!router.ready(), "Open breaker on the default model → not ready");
    // past the backoff ceiling the next request IS the Half-Open probe;
    // the source now succeeds, so the probe closes the breaker
    std::thread::sleep(Duration::from_millis(950));
    let r = wait(router.submit(req(4, Some("flaky"), img(4))).expect("probe load succeeds"));
    assert!(r.result.is_ok());
    let h = router.health("flaky").unwrap();
    assert_eq!(h.breaker.as_str(), "closed");
    assert_eq!(h.consecutive_failures, 0, "a successful load resets the streak");
    assert_eq!(h.breaker_opens, 1);
    assert_eq!(h.load_retries, 2);
    assert_eq!(h.fast_fails, 1);
    assert!(router.ready());
    // the fleet snapshot carries the same health row
    let m = router.shutdown();
    assert_eq!(m.model("flaky").unwrap().health, h);
}

#[test]
fn integrity_failure_quarantines_until_explicit_reload() {
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::Arc;
    // the FIRST incarnation carries a flipped weight bit under its
    // stamped digests; a reload rebuilds from the (now clean) source
    let builds = Arc::new(AtomicU32::new(0));
    let mut registry = ModelRegistry::new();
    let b = Arc::clone(&builds);
    registry.register(
        "rotten",
        ModelSource::factory(move || {
            let corrupt = b.fetch_add(1, Ordering::SeqCst) == 0;
            let mut m = pqs::models::synthetic_linear(DIM, CLASSES);
            m.attach_checksums();
            if corrupt {
                let q = m.graph.iter_mut().find_map(|n| n.q.as_mut()).expect("a q-layer");
                let mut w = q.wq.as_slice().to_vec();
                w[0] ^= 1;
                q.wq = w.into();
            }
            Ok(m)
        }),
    );
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: EngineConfig::default(),
        server: scfg(1, 4, 16),
        preload: Vec::new(),
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).unwrap();
    // first touch loads, fails verification, quarantines
    match router.submit(req(1, Some("rotten"), img(1))) {
        Err(RouteError::Quarantined { model, reason }) => {
            assert_eq!(model, "rotten");
            assert!(reason.contains("checksum mismatch"), "reason: {reason}");
        }
        other => panic!("expected Quarantined, got {other:?}"),
    }
    let h = router.health("rotten").expect("quarantine recorded");
    assert!(h.quarantined.is_some());
    assert_eq!(h.breaker.as_str(), "closed", "quarantine is not a breaker trip");
    assert!(!router.ready());
    // later requests fast-fail without reloading, and time does not heal
    std::thread::sleep(Duration::from_millis(50));
    assert!(matches!(
        router.submit(req(2, Some("rotten"), img(2))),
        Err(RouteError::Quarantined { .. })
    ));
    assert_eq!(builds.load(Ordering::SeqCst), 1, "a quarantined source is never reloaded");
    assert_eq!(router.health("rotten").unwrap().fast_fails, 1);
    // the explicit operator action: reload clears the quarantine and
    // hosts the fresh (clean) incarnation
    router.reload("rotten").expect("reload hosts the clean incarnation");
    assert_eq!(builds.load(Ordering::SeqCst), 2);
    assert!(router.health("rotten").is_none(), "reload wipes the health record");
    assert!(router.ready());
    let r = wait(router.submit(req(3, Some("rotten"), img(3))).expect("routes after reload"));
    assert!(r.result.is_ok());
    // reload of an unknown name reports the miss like any route would
    assert!(matches!(router.reload("nope"), Err(RouteError::UnknownModel(_))));
    router.shutdown();
}

// ---- observability: headroom telemetry, trace-attachment neutrality --------

#[test]
fn headroom_telemetry_tracks_required_bits_and_near_saturation() {
    // serve a fixed request set at an accumulator width, then read the
    // per-layer headroom rows off the fleet snapshot
    let run = |acc_bits: u32| {
        let mut registry = ModelRegistry::new();
        registry.register("m", ModelSource::Memory(common::tiny_linear_model(DIM, CLASSES)));
        let rcfg = RouterConfig {
            max_loaded: 0,
            max_bytes: 0,
            engine: EngineConfig { policy: Policy::Sorted, acc_bits, ..Default::default() },
            server: scfg(1, 4, 16),
            preload: Vec::new(),
            ..Default::default()
        };
        let router = Router::new(registry, rcfg).unwrap();
        let mut classes = Vec::new();
        for i in 0..8u64 {
            let r = wait(router.submit(req(i, None, img(i))).expect("routes"));
            classes.push(r.result.expect("serves"));
        }
        let rows = router
            .metrics()
            .model("m")
            .unwrap()
            .headroom
            .clone()
            .expect("a loaded model reports headroom");
        router.shutdown();
        (classes, rows)
    };

    // wide observation pass: learn the widest per-dot requirement
    let (wide_classes, wide_rows) = run(24);
    assert!(!wide_rows.is_empty(), "served batches must produce headroom rows");
    let mut required = 0u32;
    for row in &wide_rows {
        assert_eq!(row.planned_bits, 24);
        assert!(row.dots > 0, "{}: dots counted", row.layer);
        assert_eq!(row.overflow_dots, 0, "{}: 24 bits is comfortably wide", row.layer);
        assert!(row.max_required_bits <= 24, "{}", row.layer);
        assert_eq!(
            row.min_headroom_bits,
            24 - row.max_required_bits as i64,
            "{}: constant width → headroom is plan minus requirement",
            row.layer
        );
        required = required.max(row.max_required_bits);
    }
    assert!(required >= 2, "synthetic dots must need a non-trivial width (got {required})");

    // near-budget pass: one spare bit. The headroom gauges must flag it
    // (min headroom 1, near-saturation dots counted) while the served
    // classes stay bit-identical — nothing actually clipped
    let (near_classes, near_rows) = run(required + 1);
    assert_eq!(near_classes, wide_classes, "one spare bit must not change any answer");
    let min_headroom = near_rows.iter().map(|r| r.min_headroom_bits).min().unwrap();
    assert_eq!(min_headroom, 1, "the widest dot sits one bit under the plan");
    let near: u64 = near_rows.iter().map(|r| r.near_saturation_dots).sum();
    assert!(near > 0, "dots within one bit of the plan must be counted");
    assert_eq!(near_rows.iter().map(|r| r.overflow_dots).sum::<u64>(), 0);
}

#[test]
fn trace_attachment_never_perturbs_results() {
    // ClassifyRequest.trace is observability-only: attaching a span
    // context must not change classes or overflow accounting (the HTTP
    // layer relies on this to keep tracing on/off bit-identical)
    use pqs::trace::RequestTrace;
    use std::time::Instant;
    let run = |traced: bool| {
        let mut registry = ModelRegistry::new();
        registry.register("m", ModelSource::Memory(common::tiny_linear_model(DIM, CLASSES)));
        let rcfg = RouterConfig {
            max_loaded: 0,
            max_bytes: 0,
            engine: EngineConfig { policy: Policy::Sorted1, acc_bits: 14, ..Default::default() },
            server: scfg(1, 4, 16),
            preload: Vec::new(),
            ..Default::default()
        };
        let router = Router::new(registry, rcfg).unwrap();
        let mut classes = Vec::new();
        for i in 0..16u64 {
            let trace = traced.then(|| RequestTrace {
                id: format!("t-{i}"),
                sampled: true,
                start: Instant::now(),
                parse_us: 0.0,
            });
            let r = wait(
                router
                    .submit(ClassifyRequest {
                        id: i,
                        model: None,
                        image: img(i),
                        deadline: None,
                        acc_bits: None,
                        trace,
                    })
                    .expect("routes"),
            );
            classes.push(r.result.expect("serves"));
        }
        let rows = router.metrics().model("m").unwrap().headroom.clone().unwrap_or_default();
        router.shutdown();
        (classes, rows)
    };
    let (with, rows_with) = run(true);
    let (without, rows_without) = run(false);
    assert_eq!(with, without, "classes must be bit-identical tracing on vs off");
    assert_eq!(rows_with.len(), rows_without.len(), "same layers observed");
    for (a, b) in rows_with.iter().zip(&rows_without) {
        assert_eq!(
            (a.dots, a.overflow_dots, a.max_required_bits, a.min_headroom_bits),
            (b.dots, b.overflow_dots, b.max_required_bits, b.min_headroom_bits),
            "overflow accounting diverged on layer {}",
            a.layer
        );
    }
}
