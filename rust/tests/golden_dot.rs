//! Cross-layer bit-exactness: the Rust dot-product engine must reproduce
//! the NumPy reference (`ref.py`) on every exported golden case, for every
//! policy and accumulator width. This is the L1<->L3 numeric contract.
//! Skips (with a notice) when the goldens are not built.

mod common;

use pqs::accum::Policy;
use pqs::dot::{classify, DotEngine};
use pqs::formats::goldens::load_dot_goldens;

#[test]
fn dot_goldens_bit_exact() {
    let Some(path) = common::golden_or_skip("dot_goldens_bit_exact", "dot_goldens.json") else {
        return;
    };
    let cases = load_dot_goldens(path).expect("parse dot goldens");
    assert!(!cases.is_empty());
    let mut eng = DotEngine::new();
    let mut checked = 0usize;
    for (ci, c) in cases.iter().enumerate() {
        let prods: Vec<i32> = c.w.iter().zip(&c.x).map(|(&w, &x)| w * x).collect();
        for (p, table) in &c.results {
            for (policy_name, want_v, want_e) in table {
                let policy = Policy::from_name(policy_name).expect("policy name");
                let (v, e) = eng.dot(&prods, *p, policy);
                assert_eq!(
                    (v, e as i64),
                    (*want_v, *want_e),
                    "case {ci} policy {policy_name} p={p}"
                );
                checked += 1;
            }
        }
    }
    assert!(checked > 100, "only {checked} golden checks ran");
}

#[test]
fn classification_goldens_bit_exact() {
    let Some(path) = common::golden_or_skip("classification_goldens_bit_exact", "dot_goldens.json")
    else {
        return;
    };
    let cases = load_dot_goldens(path).expect("parse dot goldens");
    for (ci, c) in cases.iter().enumerate() {
        let prods: Vec<i32> = c.w.iter().zip(&c.x).map(|(&w, &x)| w * x).collect();
        for (p, (exact, persistent, naive_events, transient)) in &c.classify {
            let cls = classify(&prods, *p);
            assert_eq!(cls.exact, *exact, "case {ci} p={p} exact");
            assert_eq!(cls.persistent, *persistent, "case {ci} p={p} persistent");
            assert_eq!(cls.naive_events as i64, *naive_events, "case {ci} p={p} events");
            assert_eq!(cls.transient, *transient, "case {ci} p={p} transient");
        }
    }
}
