//! Budget-projection + Pareto-sweep acceptance suite (artifact-free).
//!
//! The ISSUE 9 contract, end to end on the synthetic models:
//! projection is idempotent and meets the analytic budget for every layer
//! under all six policies; a projected model serves a 1k-input sweep at
//! the budget width with ZERO persistent overflows while the unprojected
//! control at the same width does overflow (proving the zero comes from
//! the projection); projected models round-trip through `.pqsw`
//! byte-identically with the plan embedded; the pool-backed and scoped
//! [`EvalService`] paths are bit-identical; and the Rust projection lands
//! on the exact constants the Python exporter pins
//! (`python/tests/test_plan.py` — same weights, same FNV-1a checksum).

use std::sync::Arc;

use pqs::accum::Policy;
use pqs::coordinator::EvalService;
use pqs::formats::pqsw::PqswModel;
use pqs::nn::engine::{Engine, EngineConfig};
use pqs::overflow::OverflowStats;
use pqs::sweep::{self, NmSpec, ProjectConfig, SweepConfig};
use pqs::util::pool::ComputePool;
use pqs::util::rng::Pcg32;

/// The 1k-input sweep of the acceptance criterion, batched.
fn serve_sweep(eng: &mut Engine, dim: usize, inputs: usize, seed: u64) -> OverflowStats {
    let mut rng = Pcg32::new(seed);
    let batch = 50;
    let mut total = OverflowStats::default();
    let mut done = 0;
    while done < inputs {
        let n = batch.min(inputs - done);
        let imgs: Vec<f32> = (0..n * dim).map(|_| rng.f32()).collect();
        let out = eng.forward(&imgs, n).expect("forward");
        total.merge(&out.report.total());
        done += n;
    }
    total
}

fn q_weights(model: &PqswModel) -> Vec<Vec<i8>> {
    model.q_layers().map(|(_, q)| q.wq.to_owned_vec()).collect()
}

/// Cross-language KAT: these constants are pinned verbatim in
/// `python/tests/test_plan.py` — both implementations must project
/// `synthetic_linear(6, 3)` to byte-identical weights and checksums.
#[test]
fn projection_matches_python_kat() {
    // dense, budget 12, sorted: every row takes tau = 1
    let mut m = pqs::models::synthetic_linear(6, 3);
    let cfg = ProjectConfig { policy: Policy::Sorted, budget: 12, nm: None };
    let rep = sweep::project(&mut m, &cfg).unwrap();
    let wq = q_weights(&m);
    assert_eq!(wq[0], vec![-4, 1, -1, 4, 0, -2, 3, 0, -3, 2, 0, -4, 1, -1, 4, 0, -2, 3]);
    assert_eq!((rep.tau_max(), rep.pruned(), rep.clipped()), (1, 0, 17));
    let plan = m.plan.as_ref().unwrap();
    assert_eq!(plan.per_layer[0].analytic_bits, 12);
    assert_eq!(plan.per_layer[0].acc_bits, 12);
    assert_eq!(plan.per_layer[0].nnz_max, 5);
    assert_eq!(m.layer_checksums(), vec![0x19f8cd528591ac91]);

    // 2:3 sparsity, budget 10, sorted: prune first, then tau up to 4
    let mut m = pqs::models::synthetic_linear(6, 3);
    let cfg = ProjectConfig {
        policy: Policy::Sorted,
        budget: 10,
        nm: Some(NmSpec { keep: 2, m: 3 }),
    };
    let rep = sweep::project(&mut m, &cfg).unwrap();
    let wq = q_weights(&m);
    assert_eq!(wq[0], vec![-2, 0, 0, 2, 0, 0, 0, 0, 0, 0, 0, -1, 0, 0, 1, 0, 0, 0]);
    assert_eq!((rep.tau_max(), rep.pruned(), rep.clipped()), (4, 5, 12));
    assert_eq!(m.nm_m, 3);
    let plan = m.plan.as_ref().unwrap();
    assert_eq!(plan.per_layer[0].acc_bits, 10);
    assert_eq!(plan.per_layer[0].nnz_max, 2);
    assert_eq!(m.layer_checksums(), vec![0x2f62b1939d3e5ffc]);
}

#[test]
fn projection_is_idempotent_and_meets_every_budget_and_policy() {
    let base = pqs::models::synthetic_conv(2, 8, 8, 4, 10);
    for policy in Policy::ALL {
        for budget in [12u32, 10, 8, 6, 4] {
            for nm in [None, Some(NmSpec { keep: 2, m: 4 })] {
                let cfg = ProjectConfig { policy, budget, nm };
                let mut once = base.clone();
                let rep1 = sweep::project(&mut once, &cfg).unwrap();
                let plan = once.plan.as_ref().expect("plan embedded");
                for l in &plan.per_layer {
                    assert!(
                        l.analytic_bits <= budget,
                        "{} @ {budget} ({:?}): layer {} projected to {}",
                        policy.name(),
                        nm,
                        l.name,
                        l.analytic_bits
                    );
                }
                assert!(sweep::max_analytic_bits(&once, policy).unwrap() <= budget);
                assert!(rep1.sparsity_after >= rep1.sparsity_before);

                let mut twice = once.clone();
                let rep2 = sweep::project(&mut twice, &cfg).unwrap();
                assert_eq!(q_weights(&once), q_weights(&twice), "idempotent weights");
                assert_eq!(once.plan, twice.plan, "idempotent plan");
                assert!(!rep2.changed(), "second projection must be a no-op");
            }
        }
    }
}

#[test]
fn acceptance_projected_model_serves_1k_inputs_overflow_free_where_control_overflows() {
    let model = pqs::models::synthetic_conv(2, 8, 8, 4, 10);
    let dim: usize = model.input_shape.iter().product();
    let budget = 6u32;

    // control FIRST: the unprojected model at the same global width must
    // persistently overflow, or the zero below would prove nothing
    let ecfg = EngineConfig {
        policy: Policy::Sorted,
        acc_bits: budget,
        collect_stats: true,
        ..Default::default()
    };
    let mut control = Engine::new(&model, ecfg);
    let control_total = serve_sweep(&mut control, dim, 200, 0x5EE9);
    assert!(
        control_total.persistent_dots > 0,
        "a {budget}-bit accumulator must persistently overflow without projection"
    );

    // candidate: projected to the budget, plan embedded, served at the
    // budget width — zero persistent overflows across the 1k-input sweep
    let mut cand = model.clone();
    let cfg = ProjectConfig { policy: Policy::Sorted, budget, nm: None };
    let rep = sweep::project(&mut cand, &cfg).unwrap();
    assert!(rep.changed(), "budget {budget} must actually tighten this model");
    let mut eng = Engine::new(&cand, ecfg);
    let total = serve_sweep(&mut eng, dim, 1000, 0x5EE9);
    assert!(total.dots >= 1000, "the sweep really ran");
    assert_eq!(
        total.persistent_dots, 0,
        "zero persistent overflows at the projected {budget}-bit width over 1k inputs"
    );
}

#[test]
fn projected_pqsw_roundtrips_with_plan_and_checksums() {
    let dir = std::env::temp_dir().join("pqs_test_sweep_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("projected_conv.pqsw");

    let mut model = pqs::models::synthetic_conv(2, 8, 8, 4, 10);
    let cfg = ProjectConfig {
        policy: Policy::Sorted,
        budget: 8,
        nm: Some(NmSpec { keep: 2, m: 4 }),
    };
    sweep::project(&mut model, &cfg).unwrap();
    model.verify_integrity().expect("digests re-stamped after projection");
    model.save(&path).unwrap();

    let loaded = PqswModel::load(&path).unwrap();
    loaded.verify_integrity().expect("saved digests match saved bytes");
    assert_eq!(q_weights(&loaded), q_weights(&model), "byte-identical weights");
    assert_eq!(loaded.plan, model.plan, "plan survives the round-trip");
    assert_eq!(loaded.nm_m, model.nm_m);
    assert_eq!(loaded.layer_checksums(), model.layer_checksums());
    std::fs::remove_file(&path).ok();
}

#[test]
fn eval_service_pool_and_scoped_paths_are_bit_identical() {
    let model = pqs::models::synthetic_conv(2, 8, 8, 4, 10);
    let ds = sweep::reference_dataset(&model, 96, 0xDA7A).unwrap();
    let ecfg = EngineConfig {
        policy: Policy::Sorted,
        acc_bits: 10,
        collect_stats: true,
        ..Default::default()
    };
    let scoped = EvalService::new(&model, ecfg).with_threads(4).with_batch(16);
    let a = scoped.evaluate(&ds, None).unwrap();

    let pool = Arc::new(ComputePool::new(4));
    let pooled = EvalService::new(&model, ecfg)
        .with_threads(4)
        .with_batch(16)
        .with_pool(Arc::clone(&pool));
    let b = pooled.evaluate(&ds, None).unwrap();

    let serial = EvalService::new(&model, ecfg).with_threads(1).with_batch(16);
    let c = serial.evaluate(&ds, None).unwrap();

    for out in [&b, &c] {
        assert_eq!(a.accuracy, out.accuracy, "accuracy must be bit-identical");
        assert_eq!(a.samples, out.samples);
        assert_eq!(a.report.total(), out.report.total(), "overflow stats must match");
    }
    assert_eq!(a.samples, 96);
}

#[test]
fn pareto_sweep_meets_every_gate_on_the_reference_dataset() {
    let model = pqs::models::synthetic_conv(2, 8, 8, 4, 10);
    let ds = sweep::reference_dataset(&model, 48, 0x5EE9).unwrap();
    let max = sweep::max_analytic_bits(&model, Policy::Sorted).unwrap();
    let cfg = SweepConfig {
        policy: Policy::Sorted,
        budgets: vec![max, max - 1],
        nm: vec![None, Some(NmSpec { keep: 3, m: 4 })],
        batch: 16,
        threads: 2,
        tolerance: 0.9,
        limit: None,
    };
    let res = sweep::pareto(&model, &ds, &cfg).unwrap();

    // the reference set is labeled by the model itself at exact/32-bit,
    // so the unprojected baseline is perfect by construction
    assert_eq!(res.baseline_accuracy, 1.0);
    assert_eq!(res.samples, 48);
    assert_eq!(res.points.len(), 4);
    for p in &res.points {
        assert!(p.budget_ok, "width {} > budget {}", p.width_bits, p.budget);
        assert!(p.width_bits <= p.budget && p.budget <= max);
        assert_eq!(p.persistent_dots, 0, "budget {} ({:?})", p.budget, p.nm);
        assert!(p.accuracy_ok);
    }
    // the (budget = analytic max, dense) point is a no-op projection:
    // sorted at the analytic width is exact, so accuracy is EXACTLY 1.0
    let noop = res
        .points
        .iter()
        .find(|p| p.budget == max && p.nm.is_none())
        .expect("no-op grid point present");
    assert_eq!((noop.pruned, noop.clipped), (0, 0));
    assert_eq!(noop.accuracy, 1.0, "no-op point must agree with the 32-bit reference exactly");
    assert!(!noop.dominated, "the exact point is always on the frontier");
    assert!(!res.frontier().is_empty());
    assert!(res.all_ok());

    // the sweep JSON round-trips through the parser with the right tag
    let j = pqs::util::json::Json::parse(&res.to_json().to_string()).unwrap();
    assert_eq!(j.get("tag").and_then(pqs::util::json::Json::as_str), Some("sweep"));
    assert_eq!(j.get("points").and_then(pqs::util::json::Json::as_arr).unwrap().len(), 4);
}
