//! Engine end-to-end accuracy: the Rust integer engine with a wide
//! accumulator must reproduce the python fake-quant eval accuracy of the
//! exported models (they implement the same math), and the paper's
//! qualitative orderings must hold (sorted >= clip at narrow widths, etc.).
//! Each test skips (with a notice) when artifacts are not built.

mod common;

use pqs::accum::Policy;
use pqs::coordinator::EvalService;
use pqs::data::Dataset;
use pqs::formats::manifest::Manifest;
use pqs::models;
use pqs::nn::engine::EngineConfig;

fn setup(test: &str) -> Option<(Manifest, Dataset)> {
    let man = common::manifest_or_skip(test)?;
    let entry = man.test_dataset_for("mlp1").unwrap();
    let ds = Dataset::load(man.dataset_path(&entry.test)).unwrap();
    Some((man, ds))
}

#[test]
fn engine_matches_python_accuracy_mlp() {
    let Some((man, ds)) = setup("engine_matches_python_accuracy_mlp") else { return };
    for exp in ["fig2", "fig3"] {
        // check up to 3 models per experiment (full eval over 1024 images)
        for e in man.experiment_models(exp).iter().take(3) {
            let model = models::load(&man, &e.name).unwrap();
            let svc = EvalService::new(
                &model,
                EngineConfig { policy: Policy::Exact, acc_bits: 32, ..Default::default() },
            );
            let out = svc.evaluate(&ds, None).unwrap();
            assert!(
                (out.accuracy - e.acc_q).abs() < 0.03,
                "{}: rust {} vs python {}",
                e.name,
                out.accuracy,
                e.acc_q
            );
        }
    }
}

#[test]
fn sorted_beats_clip_at_narrow_widths() {
    let Some((man, ds)) = setup("sorted_beats_clip_at_narrow_widths") else { return };
    let name = &man.experiments["fig2"][0];
    let model = models::load(&man, name).unwrap();
    let limit = Some(256);
    let mut found_gap = false;
    for p in [14u32, 15, 16] {
        let acc_sorted = EvalService::new(
            &model,
            EngineConfig { policy: Policy::Sorted, acc_bits: p, ..Default::default() },
        )
        .evaluate(&ds, limit)
        .unwrap()
        .accuracy;
        let acc_clip = EvalService::new(
            &model,
            EngineConfig { policy: Policy::Clip, acc_bits: p, ..Default::default() },
        )
        .evaluate(&ds, limit)
        .unwrap()
        .accuracy;
        assert!(
            acc_sorted >= acc_clip - 0.02,
            "p={p}: sorted {acc_sorted} << clip {acc_clip}"
        );
        if acc_sorted > acc_clip + 0.05 {
            found_gap = true;
        }
    }
    assert!(found_gap, "sorting never helped — suspicious");
}

#[test]
fn wide_accumulator_policies_all_agree() {
    let Some((man, ds)) = setup("wide_accumulator_policies_all_agree") else { return };
    let name = &man.experiments["fig2"][0];
    let model = models::load(&man, name).unwrap();
    let mut accs = Vec::new();
    for policy in [Policy::Exact, Policy::Clip, Policy::Sorted, Policy::Sorted1, Policy::Wrap] {
        let acc = EvalService::new(
            &model,
            EngineConfig { policy, acc_bits: 32, ..Default::default() },
        )
        .evaluate(&ds, Some(256))
        .unwrap()
        .accuracy;
        accs.push((policy, acc));
    }
    let first = accs[0].1;
    for (p, a) in &accs {
        assert!((a - first).abs() < 1e-9, "{p:?}: {a} vs {first}");
    }
}

#[test]
fn stats_consistency_transient_plus_persistent_le_naive() {
    let Some((man, ds)) = setup("stats_consistency_transient_plus_persistent_le_naive") else {
        return;
    };
    let name = &man.experiments["fig2"][0];
    let model = models::load(&man, name).unwrap();
    for p in [13u32, 15, 17] {
        let out = EvalService::new(
            &model,
            EngineConfig { policy: Policy::Clip, acc_bits: p, collect_stats: true, tile: 0 },
        )
        .evaluate(&ds, Some(128))
        .unwrap();
        let st = out.report.total();
        assert!(st.transient_dots <= st.naive_event_dots);
        // every transient dot has naive events by definition; persistent
        // dots may or may not (they can overflow only at the very end)
        assert!(st.dots > 0);
        assert_eq!(st.dots % 10, 0, "mlp1 emits 10 dots per sample");
    }
}

#[test]
fn cnn_engine_smoke() {
    let Some(man) = common::manifest_or_skip("cnn_engine_smoke") else { return };
    let entry = man.test_dataset_for("resnet_tiny").unwrap();
    let ds = Dataset::load(man.dataset_path(&entry.test)).unwrap();
    let e = man
        .experiment_models("fig4")
        .into_iter()
        .find(|e| e.arch == "resnet_tiny" && e.schedule == "pq")
        .expect("resnet pq model");
    let model = models::load(&man, &e.name).unwrap();
    let svc = EvalService::new(
        &model,
        EngineConfig { policy: Policy::Exact, acc_bits: 32, ..Default::default() },
    );
    let out = svc.evaluate(&ds, Some(64)).unwrap();
    // must be far above chance and near the python accuracy
    assert!(out.accuracy > 0.3, "cnn accuracy {}", out.accuracy);
    assert!((out.accuracy - e.acc_q).abs() < 0.15, "rust {} python {}", out.accuracy, e.acc_q);
}

#[test]
fn multithreaded_forward_is_bit_identical() {
    // the intra-forward parallel path must reproduce the serial path
    // exactly, including overflow statistics
    let Some((man, ds)) = setup("multithreaded_forward_is_bit_identical") else { return };
    let name = &man.experiments["fig2"][0];
    let model = models::load(&man, name).unwrap();
    let cfg = EngineConfig { policy: Policy::Clip, acc_bits: 14, collect_stats: true, tile: 0 };
    let imgs = ds.images_f32(0, 32);
    let mut serial = pqs::nn::engine::Engine::new(&model, cfg);
    let mut parallel = pqs::nn::engine::Engine::new(&model, cfg).with_threads(4);
    let a = serial.forward(&imgs, 32).unwrap();
    let b = parallel.forward(&imgs, 32).unwrap();
    assert_eq!(a.logits, b.logits);
    assert_eq!(a.report.total(), b.report.total());
}
