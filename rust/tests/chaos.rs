//! Seeded chaos soak: a three-model router behind the real HTTP
//! front-end while a [`pqs::faults::FaultPlan`] fires — injected load
//! delays, engine panics, and accept resets — alongside a flaky source
//! (fails its first N loads) and a corrupt source (checksum mismatch).
//!
//! The soak gates the self-healing invariants end to end:
//!
//! * the process never dies and EVERY request gets exactly one response
//!   (the client resends only when a connection is reset before any
//!   response byte — injected accept resets happen at accept time,
//!   before the request is read, so a resend never double-executes);
//! * the flaky model drives the load circuit breaker through its full
//!   Open (fast-fail 503 + `Retry-After`) → Half-Open (probe) → Closed
//!   round trip and ends the soak serving 200s;
//! * the corrupt model is quarantined on first touch (503, no
//!   `Retry-After`) and STAYS quarantined after the faults are disarmed
//!   — only an explicit reload ends quarantine, and waiting cannot fix
//!   corrupt bytes;
//! * injected engine panics answer their riders 500 and the worker
//!   survives to serve the next request;
//! * counts conserve: every response that reached a server is accounted
//!   in exactly one per-model `requests` counter.
//!
//! Everything is seeded (`FaultSpec::seed`, the image generator) so a
//! failure reproduces from the same build.

mod common;

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::anyhow;
use pqs::coordinator::{
    BreakerConfig, ModelRegistry, ModelSource, Router, RouterConfig, ServerConfig,
};
use pqs::faults::{FaultPlan, FaultSpec};
use pqs::http::{HttpConfig, HttpServer};
use pqs::util::json::Json;

const DIM: usize = 16;
const CLASSES: usize = 4;
/// How many times the "flaky" source fails before loading cleanly. With
/// `threshold: 2` the breaker opens after the second failure, re-opens
/// off the failed half-open probe (the third), then closes on the next
/// probe — the full round trip inside one soak.
const FLAKY_FAILS: u32 = 3;

// ---- chaos-tolerant raw-TCP client ----------------------------------------

struct Resp {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

impl Resp {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    fn closes(&self) -> bool {
        self.header("connection").is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }

    fn json(&self) -> Json {
        Json::parse(&self.body).expect("json body")
    }
}

/// Blocking HTTP/1.1 client that survives injected accept resets: when
/// the connection dies before ANY response byte arrives, it reconnects
/// and resends. Resets fire at accept time — before the server reads the
/// request — so a resend can never execute a request twice.
struct ChaosClient {
    addr: SocketAddr,
    stream: Option<TcpStream>,
    resends: u64,
}

impl ChaosClient {
    fn new(srv: &HttpServer) -> ChaosClient {
        ChaosClient { addr: srv.local_addr(), stream: None, resends: 0 }
    }

    /// One request, exactly one response — retrying internally.
    fn request(&mut self, raw: &[u8]) -> Resp {
        for attempt in 0..200 {
            if attempt > 0 {
                self.resends += 1;
                std::thread::sleep(Duration::from_millis(2));
            }
            if self.stream.is_none() {
                match TcpStream::connect(self.addr) {
                    Ok(s) => {
                        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
                        s.set_nodelay(true).ok();
                        self.stream = Some(s);
                    }
                    Err(_) => continue,
                }
            }
            let s = self.stream.as_mut().unwrap();
            if s.write_all(raw).is_err() {
                self.stream = None;
                continue;
            }
            match read_one_response(s) {
                Some(resp) => {
                    if resp.closes() {
                        self.stream = None;
                    }
                    return resp;
                }
                None => {
                    // connection died before a single response byte:
                    // the request was never read — safe to resend
                    self.stream = None;
                }
            }
        }
        panic!("no response after 200 attempts — the front-end is gone");
    }

    /// Drop the kept-alive connection so the next request re-accepts —
    /// without this, one lucky initial accept would dodge the injected
    /// accept resets for the entire soak.
    fn fresh_connection(&mut self) {
        self.stream = None;
    }

    fn post_classify(&mut self, model: &str, seed: u64) -> Resp {
        let img = common::synth_images(1, DIM, seed);
        let nums: Vec<String> = img.iter().map(|v| format!("{v}")).collect();
        let body = format!("{{\"model\":\"{model}\",\"image\":[{}]}}", nums.join(","));
        let raw = format!(
            "POST /v1/classify HTTP/1.1\r\nHost: chaos\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        self.request(raw.as_bytes())
    }

    fn get(&mut self, path: &str) -> Resp {
        self.request(format!("GET {path} HTTP/1.1\r\nHost: chaos\r\n\r\n").as_bytes())
    }
}

/// `None` when the connection dies before any response byte.
fn read_one_response(s: &mut TcpStream) -> Option<Resp> {
    let mut buf = Vec::new();
    let mut tmp = [0u8; 4096];
    loop {
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            let head_end = pos + 4;
            let head = String::from_utf8(buf[..head_end].to_vec()).expect("utf8 head");
            let status: u16 =
                head.split(' ').nth(1).expect("status line").parse().expect("numeric status");
            let mut headers = Vec::new();
            for line in head.lines().skip(1) {
                if let Some((k, v)) = line.split_once(':') {
                    headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
                }
            }
            let body_len: usize = headers
                .iter()
                .find(|(k, _)| k == "content-length")
                .map(|(_, v)| v.parse().expect("content-length"))
                .unwrap_or(0);
            while buf.len() < head_end + body_len {
                match s.read(&mut tmp) {
                    Ok(0) => panic!("eof mid-body"),
                    Ok(n) => buf.extend_from_slice(&tmp[..n]),
                    Err(e) => panic!("read mid-body: {e}"),
                }
            }
            let body = String::from_utf8(buf[head_end..head_end + body_len].to_vec())
                .expect("utf8 body");
            return Some(Resp { status, headers, body });
        }
        match s.read(&mut tmp) {
            Ok(0) if buf.is_empty() => return None,
            Ok(0) => panic!("eof mid-head"),
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(_) if buf.is_empty() => return None,
            Err(e) => panic!("read mid-head: {e}"),
        }
    }
}

// ---- fixture --------------------------------------------------------------

/// good: always loads. flaky: fails its first [`FLAKY_FAILS`] loads.
/// rotten: loads "successfully" but with a flipped weight bit under its
/// embedded checksums — integrity verification quarantines it.
fn chaos_registry() -> ModelRegistry {
    let mut registry = ModelRegistry::new();
    registry.register("good", ModelSource::Memory(common::tiny_linear_model(DIM, CLASSES)));
    let fails = Arc::new(AtomicU32::new(0));
    registry.register(
        "flaky",
        ModelSource::factory(move || {
            if fails.fetch_add(1, Ordering::SeqCst) < FLAKY_FAILS {
                Err(anyhow!("flaky: injected load failure"))
            } else {
                Ok(pqs::models::synthetic_linear(DIM, CLASSES))
            }
        }),
    );
    registry.register(
        "rotten",
        ModelSource::factory(|| {
            let mut m = pqs::models::synthetic_linear(DIM, CLASSES);
            m.attach_checksums();
            let q = m.graph.iter_mut().find_map(|n| n.q.as_mut()).expect("a q-layer");
            let mut w = q.wq.as_slice().to_vec();
            w[0] ^= 1; // one flipped bit under the stamped digests
            q.wq = w.into();
            Ok(m)
        }),
    );
    registry
}

// ---- the soak -------------------------------------------------------------

#[test]
fn chaos_soak_multi_model_router_self_heals() {
    let plan = Arc::new(FaultPlan::new(FaultSpec {
        seed: 0xC4A0_55EE,
        slow_load: 1.0, // every load sleeps: breaker windows stay busy
        load_delay: Duration::from_millis(2),
        panic_every: 7,
        accept_reset: 0.25,
        ..Default::default()
    }));
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: Default::default(),
        server: ServerConfig {
            threads: 2,
            max_batch: 4,
            queue_cap: 64,
            linger: Duration::from_micros(50),
            engine_threads: 1,
            default_deadline: None,
        },
        preload: Vec::new(),
        breaker: BreakerConfig {
            threshold: 2,
            base_backoff: Duration::from_millis(30),
            max_backoff: Duration::from_millis(120),
            ..Default::default()
        },
        faults: Some(Arc::clone(&plan)),
    };
    let router = Router::new(chaos_registry(), rcfg).expect("registry is non-empty");
    let http = HttpServer::start(
        router,
        "127.0.0.1:0",
        HttpConfig { keep_alive_timeout: Duration::from_secs(5), ..HttpConfig::default() },
    )
    .expect("bind loopback");
    let mut client = ChaosClient::new(&http);

    let (mut sent, mut answered) = (0u64, 0u64);
    let (mut ok_200, mut panic_500, mut load_500) = (0u64, 0u64, 0u64);
    let (mut breaker_503, mut rotten_503) = (0u64, 0u64);

    for round in 0..40u64 {
        client.fresh_connection(); // re-accept: give the reset fault a shot
        for model in ["good", "flaky", "rotten"] {
            sent += 1;
            let r = client.post_classify(model, round);
            answered += 1;
            match (model, r.status) {
                (_, 200) => {
                    ok_200 += 1;
                    assert!(
                        r.json().get("class").and_then(Json::as_usize).is_some(),
                        "200 carries a class: {}",
                        r.body
                    );
                }
                (_, 500) if r.body.contains("panicked") => panic_500 += 1,
                ("flaky", 500) => {
                    assert!(r.body.contains("flaky"), "names the failed load: {}", r.body);
                    load_500 += 1;
                }
                ("flaky", 503) => {
                    assert!(
                        r.body.contains("circuit breaker"),
                        "flaky 503s come from the breaker: {}",
                        r.body
                    );
                    assert!(
                        r.header("retry-after").is_some(),
                        "breaker-open 503 carries Retry-After"
                    );
                    breaker_503 += 1;
                }
                ("rotten", 503) => {
                    assert!(r.body.contains("quarantined"), "body: {}", r.body);
                    assert!(
                        r.header("retry-after").is_none(),
                        "waiting cannot fix corrupt bytes: no Retry-After"
                    );
                    rotten_503 += 1;
                }
                (m, s) => panic!("unexpected {s} from {m}: {}", r.body),
            }
        }
        std::thread::sleep(Duration::from_millis(3));
    }

    // every request answered exactly once, and every phase of the chaos
    // actually fired under this seed
    assert_eq!(sent, answered, "exactly one response per request");
    assert_eq!(rotten_503, 40, "the corrupt model never serves");
    assert!(breaker_503 >= 1, "the breaker opened and fast-failed");
    assert!(load_500 >= 2, "the flaky loads surfaced as 500s");
    assert!(panic_500 >= 1, "injected engine panics answered their riders 500");
    assert!(ok_200 >= 40, "the healthy model kept serving through the chaos");
    let counts = plan.counts();
    assert!(counts.panics >= 1 && counts.slow_loads >= 1, "injected: {counts:?}");

    // disarm: the fleet must return to fully healthy — except quarantine,
    // which no amount of waiting may clear
    plan.disarm();
    let mut recovered = false;
    for seed in 0..200u64 {
        let r = client.post_classify("flaky", seed);
        sent += 1;
        answered += 1;
        match r.status {
            200 => {
                ok_200 += 1;
                recovered = true;
            }
            503 => breaker_503 += 1, // backoff from the last armed failure
            // a leftover injected failure: the source fails a fixed number
            // of loads, and the last may land after disarm
            500 => load_500 += 1,
            other => panic!("recovery: unexpected {other}: {}", r.body),
        }
        if recovered {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(recovered, "flaky model serves after faults are disarmed");
    for seed in 0..5u64 {
        let r = client.post_classify("good", seed);
        sent += 1;
        answered += 1;
        assert_eq!(r.status, 200, "no faults, no failures: {}", r.body);
        ok_200 += 1;
        let r = client.post_classify("rotten", seed);
        sent += 1;
        answered += 1;
        assert_eq!(r.status, 503, "quarantine survives disarm");
        rotten_503 += 1;
        assert!(r.body.contains("quarantined"), "body: {}", r.body);
    }

    // the control plane agrees with what the wire saw
    let ready = client.get("/readyz");
    assert_eq!(ready.status, 200, "default model healthy => ready: {}", ready.body);
    let models = client.get("/v1/models").json();
    let rotten_health = models
        .get("models")
        .and_then(Json::as_arr)
        .and_then(|rows| {
            rows.iter().find(|r| r.get("name").and_then(Json::as_str) == Some("rotten"))
        })
        .and_then(|r| r.get("health"))
        .expect("rotten row carries health")
        .clone();
    assert!(
        rotten_health.get("quarantined").and_then(Json::as_str).is_some(),
        "quarantine reason on the wire: {rotten_health:?}"
    );
    let metrics = client.get("/v1/metrics").json();
    let router_sec = metrics.get("router").expect("router section");
    assert_eq!(router_sec.get("quarantined").and_then(Json::as_usize), Some(1));
    assert!(router_sec.get("breaker_opens").and_then(Json::as_usize).unwrap_or(0) >= 1);
    assert_eq!(
        router_sec.get("breaker_fast_fails").and_then(Json::as_usize),
        Some((breaker_503 + rotten_503 - 1) as usize),
        "every fast-fail 503 counted (the first rotten hit is a load, not a fast-fail)"
    );
    let flaky_health = metrics
        .get("models")
        .and_then(|m| m.get("flaky"))
        .and_then(|m| m.get("health"))
        .expect("flaky health section")
        .clone();
    assert_eq!(
        flaky_health.get("breaker").and_then(Json::as_str),
        Some("closed"),
        "round trip complete: {flaky_health:?}"
    );
    assert!(metrics.get("panics").and_then(Json::as_usize).unwrap_or(0) >= 1);

    // conservation: every response that reached a server is accounted in
    // exactly one per-model requests counter (200s + panic-500s; load
    // failures and fast-fails never touch a server)
    let served: usize = ["good", "flaky"]
        .iter()
        .filter_map(|n| {
            metrics.get("models").and_then(|m| m.get(n)).and_then(|m| m.get("requests"))
        })
        .filter_map(|v| v.as_usize())
        .sum();
    assert_eq!(served as u64, ok_200 + panic_500, "server-side requests conserve");

    let report = http.shutdown();
    assert_eq!(report.router.quarantined, 1);
    assert!(report.router.breaker_opens >= 1);
    assert!(counts.resets >= 1, "accept resets fired under this seed: {counts:?}");
    assert!(client.resends >= counts.resets, "every reset forced a resend");
}
