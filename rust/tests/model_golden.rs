//! End-to-end model contract: quantization of real test images, exact
//! integer accumulators, offset corrections and final logits of the mlp1
//! model must match the python export bit-for-bit (integers) / closely
//! (floats). Skips (with a notice) when artifacts are not built.

mod common;

use pqs::data::Dataset;
use pqs::formats::goldens::load_model_golden;
use pqs::formats::pqsw::PqswModel;
use pqs::quant::{quantize_centered_slice_into, QParams};

#[test]
fn model_golden_quantization_and_accumulators() {
    let Some(path) =
        common::golden_or_skip("model_golden_quantization_and_accumulators", "model_golden.json")
    else {
        return;
    };
    let Some(man) = common::manifest_or_skip("model_golden_quantization_and_accumulators") else {
        return;
    };
    let g = load_model_golden(path).expect("model golden");
    let model_name = g.model.trim_end_matches(".pqsw");
    let model = PqswModel::load(man.model_path(model_name)).expect("model");
    let (_, fc) = model.q_layers().next().expect("q layer");

    // 1. input quantization must be bit-exact vs numpy
    let entry = man.test_dataset_for(&model.arch).unwrap();
    let ds = Dataset::load(man.dataset_path(&entry.test)).unwrap();
    let imgs = ds.images_f32(0, g.batch);
    let qp = QParams { scale: fc.x_scale, offset: fc.x_offset, bits: model.abits };
    let mut xq = Vec::new();
    quantize_centered_slice_into(&imgs, &qp, &mut xq);
    assert_eq!(xq.len(), g.xq.len());
    let mismatches = xq.iter().zip(&g.xq).filter(|(a, b)| a != b).count();
    assert_eq!(mismatches, 0, "quantized inputs differ from numpy in {mismatches} places");

    // 2. exact integer accumulators
    for b in 0..g.batch {
        for o in 0..g.oc {
            let acc: i64 = (0..g.ic)
                .map(|k| xq[b * g.ic + k] as i64 * fc.wq[o * g.ic + k] as i64)
                .sum();
            assert_eq!(acc, g.acc_exact[b * g.oc + o], "acc ({b},{o})");
        }
    }

    // 3. final logits via the engine (wide accumulator)
    use pqs::accum::Policy;
    use pqs::nn::engine::{Engine, EngineConfig};
    let mut eng = Engine::new(
        &model,
        EngineConfig { policy: Policy::Exact, acc_bits: 32, ..Default::default() },
    );
    let out = eng.forward(&imgs, g.batch).unwrap();
    // mlp1 graph ends with relu(logits); golden applied relu too
    for i in 0..g.batch * g.oc {
        let want = g.logits[i] as f32;
        let got = out.logits[i];
        assert!(
            (want - got).abs() <= 1e-4 * want.abs().max(1.0),
            "logit {i}: {got} vs {want}"
        );
    }
}
