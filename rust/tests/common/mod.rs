//! Shared helpers for the integration suite: artifact gating and tiny
//! synthetic models that run without `make artifacts`.
#![allow(dead_code)]

use pqs::formats::manifest::Manifest;
use pqs::formats::pqsw::PqswModel;

/// Load the artifacts manifest, or skip the calling test (returns `None`,
/// printing why) when artifacts are not built in this checkout. Keeps the
/// tier-1 suite green on a fresh clone; the full contract still runs
/// whenever `make artifacts` has produced the files.
pub fn manifest_or_skip(test: &str) -> Option<Manifest> {
    match Manifest::load_default() {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP {test}: artifacts not available ({e:#})");
            None
        }
    }
}

/// Resolve one golden file, or skip when absent.
pub fn golden_or_skip(test: &str, file: &str) -> Option<std::path::PathBuf> {
    let p = pqs::artifacts_dir().join("goldens").join(file);
    if p.is_file() {
        Some(p)
    } else {
        eprintln!("SKIP {test}: golden {p:?} not present");
        None
    }
}

/// Tiny synthetic one-layer linear model (`dim -> classes`) — enough to
/// exercise the engine and the serving runtime without artifacts.
pub fn tiny_linear_model(dim: usize, classes: usize) -> PqswModel {
    pqs::models::synthetic_linear(dim, classes)
}

/// Deterministic synthetic image batch in [0, 1].
pub fn synth_images(n: usize, dim: usize, seed: u64) -> Vec<f32> {
    let mut rng = pqs::util::rng::Pcg32::new(seed);
    (0..n * dim).map(|_| rng.f32()).collect()
}
