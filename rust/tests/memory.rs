//! Zero-copy loading + fleet-memory acceptance suite (artifact-free).
//!
//! The ISSUE 6 contract, end to end over synthetic models:
//! lazy `.pqsw` loads are bit-identical to eager ones (logits AND
//! overflow counters), a byte-budgeted router evicts LRU-first and never
//! holds more than `max_bytes` resident, two fleet entries with
//! byte-identical weights share ONE backing blob, and one resident
//! planned model answers requests at several accumulator operating
//! points (wide = overflow headroom, under the plan's safe minimum =
//! refused, plan-free override = refused).

mod common;

use pqs::accum::Policy;
use pqs::coordinator::{
    ClassifyRequest, ModelRegistry, ModelSource, RouteError, Router, RouterConfig, ServeError,
    ServerConfig,
};
use pqs::formats::pqsw::PqswModel;
use pqs::nn::engine::{Engine, EngineConfig};
use pqs::plan::{plan_model, PlannerConfig};
use std::time::Duration;

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn server_cfg() -> ServerConfig {
    ServerConfig {
        threads: 1,
        max_batch: 4,
        queue_cap: 16,
        linger: Duration::from_micros(50),
        engine_threads: 1,
        default_deadline: None,
    }
}

fn req(id: u64, model: &str, image: Vec<f32>, acc_bits: Option<u32>) -> ClassifyRequest {
    ClassifyRequest {
        id,
        model: Some(model.to_string()),
        image,
        deadline: None,
        acc_bits,
        trace: None,
    }
}

/// Route one request and wait for its response.
fn ask(router: &Router, r: ClassifyRequest) -> pqs::coordinator::ServeResponse {
    router.submit(r).expect("routes").wait_timeout(Duration::from_secs(60)).expect("response")
}

#[test]
fn lazy_loads_serve_bit_identically_to_eager_loads() {
    let dir = tmp_dir("pqs_test_mem_identity");
    let cases = vec![
        ("linear.pqsw", pqs::models::synthetic_linear(96, 10)),
        ("conv.pqsw", pqs::models::synthetic_conv(2, 8, 8, 4, 10)),
    ];
    for (file, model) in cases {
        let path = dir.join(file);
        model.save(&path).unwrap();
        let lazy = PqswModel::load(&path).unwrap();
        let eager = PqswModel::load_eager(&path).unwrap();
        assert!(lazy.backing_blob().is_some(), "{file}: lazy load borrows");
        assert!(eager.backing_blob().is_none(), "{file}: eager load owns");
        assert_eq!(lazy.content_hash(), eager.content_hash());
        // a deliberately narrow accumulator makes the overflow machinery
        // fire, so the counter comparison is not vacuous
        let ecfg = EngineConfig {
            policy: Policy::Sorted,
            acc_bits: 8,
            tile: 0,
            collect_stats: true,
        };
        let dim: usize = model.input_shape.iter().product();
        let imgs = common::synth_images(8, dim, 0xC0DE);
        let ra = Engine::new(&eager, ecfg).forward(&imgs, 8).unwrap();
        let rb = Engine::new(&lazy, ecfg).forward(&imgs, 8).unwrap();
        assert_eq!(ra.logits, rb.logits, "{file}: logits bit-identical");
        assert_eq!(ra.report.total(), rb.report.total(), "{file}: overflow counters identical");
    }
}

#[test]
fn byte_budget_evicts_lru_first_and_is_never_exceeded() {
    let dir = tmp_dir("pqs_test_mem_budget");
    // three models with pairwise-different weights (no dedup in this test)
    let specs = [("a", 64usize), ("b", 80), ("c", 96)];
    let mut bytes = std::collections::BTreeMap::new();
    let mut dims = std::collections::BTreeMap::new();
    for (name, dim) in specs {
        let path = dir.join(format!("{name}.pqsw"));
        pqs::models::synthetic_linear(dim, 10).save(&path).unwrap();
        bytes.insert(name, PqswModel::load(&path).unwrap().resident_bytes());
        dims.insert(name, dim);
    }
    let (ba, bb, bc) = (bytes["a"], bytes["b"], bytes["c"]);
    // room for any two of the three, never all three
    let budget = ba + bb + bc - 1;

    let mut registry = ModelRegistry::new();
    for (name, _) in specs {
        registry.register(name, ModelSource::Path(dir.join(format!("{name}.pqsw"))));
    }
    let ecfg = EngineConfig { policy: Policy::Sorted, acc_bits: 16, tile: 0, collect_stats: false };
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: budget,
        engine: ecfg,
        server: server_cfg(),
        preload: Vec::new(),
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).unwrap();

    let mut id = 0;
    let mut touch = |name: &str| {
        id += 1;
        let image = common::synth_images(1, dims[name], id);
        let r = ask(&router, req(id, name, image, None));
        assert!(r.result.is_ok(), "{name}: {:?}", r.result);
        let m = router.metrics();
        assert!(
            m.resident_bytes <= budget,
            "resident {} exceeds the budget {budget}",
            m.resident_bytes
        );
        m
    };
    let m = touch("a");
    assert_eq!(m.resident_bytes, ba);
    let m = touch("b");
    assert_eq!(m.resident_bytes, ba + bb);
    assert_eq!(m.evictions, 0, "two models fit");
    touch("a"); // refresh: "b" becomes the LRU victim
    let m = touch("c");
    assert_eq!(m.evictions, 1, "loading c had to evict exactly one model");
    assert_eq!(m.resident_bytes, ba + bc);
    let row = |m: &pqs::coordinator::RouterMetrics, n: &str| m.model(n).unwrap().loaded;
    assert!(row(&m, "a"), "a was refreshed, so it survives");
    assert!(!row(&m, "b"), "b was least-recently-used, so it went");
    assert!(row(&m, "c"));
    assert_eq!(m.budget, budget);

    // a reload after eviction works and stays within the budget
    let m = touch("b");
    assert_eq!(m.evictions, 2);
    assert!(row(&m, "b"));
    router.shutdown();

    // a model that cannot fit even an empty fleet is refused outright
    let mut registry = ModelRegistry::new();
    registry.register("big", ModelSource::Path(dir.join("c.pqsw")));
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: bc - 1,
        engine: ecfg,
        server: server_cfg(),
        preload: Vec::new(),
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).unwrap();
    let image = common::synth_images(1, dims["c"], 99);
    match router.submit(req(99, "big", image, None)) {
        Err(RouteError::LoadFailed(msg)) => {
            assert!(msg.contains("max-bytes"), "names the budget flag: {msg}");
        }
        Err(e) => panic!("want LoadFailed, got {e:?}"),
        Ok(_) => panic!("an over-budget model must be refused"),
    }
    let m = router.shutdown();
    assert_eq!(m.loads, 0, "the refused load is not counted as a load");
}

#[test]
fn byte_identical_fleet_entries_share_one_resident_blob() {
    let dir = tmp_dir("pqs_test_mem_dedup");
    // two DIFFERENT files with byte-identical weights: dedup must work by
    // content, not by path
    let model = pqs::models::synthetic_linear(128, 10);
    let (p1, p2) = (dir.join("first.pqsw"), dir.join("second.pqsw"));
    model.save(&p1).unwrap();
    model.save(&p2).unwrap();
    let single = PqswModel::load(&p1).unwrap();
    let blob_len = single.backing_blob().unwrap().len() as u64;
    let own = single.resident_bytes() - blob_len;

    let mut registry = ModelRegistry::new();
    registry.register("first", ModelSource::Path(p1));
    registry.register("second", ModelSource::Path(p2));
    let ecfg = EngineConfig { policy: Policy::Sorted, acc_bits: 16, tile: 0, collect_stats: false };
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: ecfg,
        server: server_cfg(),
        preload: vec!["first".into(), "second".into()],
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).unwrap();
    let m = router.metrics();
    assert_eq!(m.loads, 2);
    assert_eq!(m.dedup_hits, 1, "the second load rehosts onto the first's blob");
    assert_eq!(
        m.resident_bytes,
        blob_len + 2 * own,
        "one shared blob, two sets of owned bytes"
    );
    for name in ["first", "second"] {
        let image = common::synth_images(1, 128, 7);
        let r = ask(&router, req(1, name, image, None));
        assert!(r.result.is_ok(), "{name} serves from the shared blob");
    }
    let m = router.shutdown();
    assert_eq!(m.resident_bytes, 0, "shutdown drains every incarnation");
}

#[test]
fn one_resident_model_serves_multiple_operating_points() {
    let dir = tmp_dir("pqs_test_mem_opoints");
    let model = pqs::models::synthetic_conv(2, 8, 8, 4, 10);
    let dim: usize = model.input_shape.iter().product();
    let plan = plan_model(&model, &PlannerConfig { calibrate_samples: 64, ..Default::default() })
        .unwrap();
    let min_safe = plan.min_safe_bits();
    assert!(min_safe > 2, "the synthetic conv plan is not trivially narrow");
    let mut planned = model.clone();
    planned.plan = Some(plan.clone());
    let planned_path = dir.join("planned.pqsw");
    planned.save(&planned_path).unwrap();
    let planfree_path = dir.join("planfree.pqsw");
    model.save(&planfree_path).unwrap();

    let mut registry = ModelRegistry::new();
    registry.register("planned", ModelSource::Path(planned_path.clone()));
    registry.register("planfree", ModelSource::Path(planfree_path));
    let ecfg = EngineConfig { policy: Policy::Sorted, acc_bits: 16, tile: 0, collect_stats: false };
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: ecfg,
        server: server_cfg(),
        preload: Vec::new(),
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).unwrap();

    let loaded = PqswModel::load(&planned_path).unwrap();
    let image = common::synth_images(1, dim, 0x0B17);
    // expected classes at the plan's own widths and at the wide point
    let mut strict = Engine::new(&loaded, ecfg);
    let want_strict = strict.forward(&image, 1).unwrap().argmax(0);
    let mut wide = Engine::new(&loaded, ecfg);
    wide.apply_layer_bits(&plan.operating_point(32));
    let want_wide = wide.forward(&image, 1).unwrap().argmax(0);

    // the wide point clamps at each layer's analytic bound, so a sweep
    // there is persistent-overflow-free by construction
    let wcfg = EngineConfig { policy: Policy::Sorted, acc_bits: 16, tile: 0, collect_stats: true };
    let mut sweep = Engine::new(&loaded, wcfg);
    sweep.apply_layer_bits(&plan.operating_point(32));
    let imgs = common::synth_images(50, dim, 0x5EED);
    let out = sweep.forward(&imgs, 50).unwrap();
    assert_eq!(out.report.total().persistent_dots, 0, "wide point never overflows persistently");

    // one resident model, several widths — interleaved, over one server
    let r = ask(&router, req(1, "planned", image.clone(), None));
    assert_eq!(r.result, Ok(want_strict), "strict width");
    let r = ask(&router, req(2, "planned", image.clone(), Some(32)));
    assert_eq!(r.result, Ok(want_wide), "wide operating point");
    let r = ask(&router, req(3, "planned", image.clone(), None));
    assert_eq!(r.result, Ok(want_strict), "the override is undone after its batch");

    // under the plan's safe minimum: refused per-request, service intact
    let r = ask(&router, req(4, "planned", image.clone(), Some(min_safe - 1)));
    match r.result {
        Err(ServeError::BadRequest(msg)) => {
            assert!(msg.contains("safe minimum"), "{msg}");
        }
        other => panic!("want BadRequest, got {other:?}"),
    }

    // a plan-free model has no operating points to offer
    let r = ask(&router, req(5, "planfree", image.clone(), Some(24)));
    match r.result {
        Err(ServeError::BadRequest(msg)) => {
            assert!(msg.contains("plan"), "{msg}");
        }
        other => panic!("want BadRequest, got {other:?}"),
    }
    let r = ask(&router, req(6, "planned", image, Some(32)));
    assert_eq!(r.result, Ok(want_wide), "bad requests never poison the engines");

    let m = router.shutdown();
    assert_eq!(m.model("planned").unwrap().metrics.requests, 5);
    assert_eq!(m.loads, 2, "every width was served by the SAME resident incarnations");
}
