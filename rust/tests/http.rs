//! HTTP/1.1 protocol-conformance suite for the hand-rolled front-end —
//! all on loopback TCP against tiny synthetic models, fully offline.
//!
//! Covers: a table-driven torture corpus of valid/malformed raw byte
//! requests (exact status codes, listener survival), keep-alive and
//! pipelined sequences, `Transfer-Encoding: chunked` request bodies
//! (valid + malformed framing), a chunking property test that splits
//! request bytes across arbitrary write boundaries, the deadline path
//! (`deadline_ms: 0` → 504 + the `expired` metric), and the multi-model
//! surface: `"model"`-routed classification, `GET /v1/models`, nested
//! per-model `GET /v1/metrics` sections, unknown-model 404s, per-request
//! `"acc_bits"` operating-point overrides (valid, under-bound, plan-free,
//! malformed), the fleet-memory counters on the wire, and the front-end's
//! own `http` counters.
//!
//! Self-healing on the wire: `GET /readyz` (readiness gates, the drain
//! flip, HEAD mirror, 405 + `Allow`), `Retry-After` on breaker-open 503s
//! and expired 504s but never on quarantine 503s, and breaker/quarantine
//! health riding the `GET /v1/models` fleet rows.
//!
//! On Linux the suite runs against the epoll event loop (the default
//! backend); backend-sensitive cases — HEAD-mirrors-GET, chunked response
//! framing, mid-pipeline `Connection: close` ordering — additionally run
//! against the blocking fallback (`event_loop: false`), and a 10k idle
//! keep-alive soak pins the event loop's no-shedding guarantee.

mod common;

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use pqs::coordinator::{
    ModelRegistry, ModelSource, Router, RouterConfig, ServerConfig, SyntheticSpec,
};
use pqs::http::{HttpConfig, HttpServer};
use pqs::nn::engine::{Engine, EngineConfig};
use pqs::trace::{validate_exposition, TraceConfig};
use pqs::util::json::Json;
use pqs::util::prop;
use pqs::util::rng::Pcg32;

const DIM: usize = 16;
const CLASSES: usize = 4;

/// Conv dims of the second registered model (input 2*6*6 = 72 != DIM, so
/// a misrouted request cannot accidentally classify).
const AUX_DIM: usize = 2 * 6 * 6;

fn scfg() -> ServerConfig {
    ServerConfig {
        threads: 2,
        max_batch: 8,
        queue_cap: 64,
        linger: Duration::from_micros(50),
        engine_threads: 1,
        default_deadline: None,
    }
}

fn hcfg() -> HttpConfig {
    HttpConfig {
        conn_threads: 4,
        conn_backlog: 16,
        keep_alive_timeout: Duration::from_millis(500),
        ..HttpConfig::default()
    }
}

fn start_http() -> HttpServer {
    start_http_with(hcfg())
}

fn start_http_with(cfg: HttpConfig) -> HttpServer {
    let model = common::tiny_linear_model(DIM, CLASSES);
    let router = Router::single("tiny", &model, EngineConfig::default(), scfg());
    HttpServer::start(router, "127.0.0.1:0", cfg).expect("bind loopback")
}

fn aux_model() -> pqs::formats::pqsw::PqswModel {
    pqs::models::synthetic_conv(2, 6, 6, 4, CLASSES)
}

/// Two registered models: "tiny" (default, in-memory) and "aux" (a
/// synthetic-source CNN, lazily loaded on first request).
fn start_http_multi() -> HttpServer {
    start_http_multi_with(hcfg())
}

fn start_http_multi_with(cfg: HttpConfig) -> HttpServer {
    let model = common::tiny_linear_model(DIM, CLASSES);
    let mut registry = ModelRegistry::new();
    registry.register("tiny", ModelSource::Memory(model));
    registry.register(
        "aux",
        ModelSource::Synthetic(SyntheticSpec::Conv { c: 2, h: 6, w: 6, oc: 4, classes: CLASSES }),
    );
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: EngineConfig::default(),
        server: scfg(),
        preload: Vec::new(),
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).expect("registry is non-empty");
    HttpServer::start(router, "127.0.0.1:0", cfg).expect("bind loopback")
}

// ---- tiny raw-TCP client --------------------------------------------------

struct Resp {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

impl Resp {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    fn json(&self) -> Json {
        Json::parse(&self.body).expect("json body")
    }
}

struct Client {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl Client {
    fn connect(srv: &HttpServer) -> Client {
        let stream = TcpStream::connect(srv.local_addr()).expect("connect loopback");
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        Client { stream, buf: Vec::new() }
    }

    fn send(&mut self, bytes: &[u8]) {
        self.stream.write_all(bytes).expect("write request");
    }

    fn read_response(&mut self) -> Resp {
        self.try_read(false).expect("a response before timeout/eof")
    }

    /// Read a response to a `HEAD` request: the head is parsed and
    /// consumed, and NO body bytes are read regardless of what
    /// `Content-Length` advertises. If the server wrongly sent a body,
    /// its bytes stay buffered and poison the next parse — which the
    /// tests exploit by always following a HEAD with another request.
    fn read_head_response(&mut self) -> Resp {
        self.try_read(true).expect("a response before timeout/eof")
    }

    /// `None` on clean EOF before any response bytes (server closed).
    fn try_read_response(&mut self) -> Option<Resp> {
        self.try_read(false)
    }

    fn try_read(&mut self, head_only: bool) -> Option<Resp> {
        let mut tmp = [0u8; 4096];
        loop {
            if let Some(pos) = self.buf.windows(4).position(|w| w == b"\r\n\r\n") {
                let head_end = pos + 4;
                let head = String::from_utf8(self.buf[..head_end].to_vec()).expect("utf8 head");
                let status: u16 = head
                    .split(' ')
                    .nth(1)
                    .expect("status line")
                    .parse()
                    .expect("numeric status");
                let mut headers = Vec::new();
                for line in head.lines().skip(1) {
                    if let Some((k, v)) = line.split_once(':') {
                        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
                    }
                }
                if head_only {
                    self.buf.drain(..head_end);
                    return Some(Resp { status, headers, body: String::new() });
                }
                if headers.iter().any(|(k, v)| k == "transfer-encoding" && v == "chunked") {
                    loop {
                        if let Some((decoded, used)) = decode_chunked(&self.buf[head_end..]) {
                            let body = String::from_utf8(decoded).expect("utf8 chunked body");
                            self.buf.drain(..head_end + used);
                            return Some(Resp { status, headers, body });
                        }
                        match self.stream.read(&mut tmp) {
                            Ok(0) => panic!("eof mid-chunked-body"),
                            Ok(n) => self.buf.extend_from_slice(&tmp[..n]),
                            Err(e) => panic!("read mid-chunked-body: {e}"),
                        }
                    }
                }
                let body_len: usize = headers
                    .iter()
                    .find(|(k, _)| k == "content-length")
                    .map(|(_, v)| v.parse().expect("content-length"))
                    .unwrap_or(0);
                while self.buf.len() < head_end + body_len {
                    match self.stream.read(&mut tmp) {
                        Ok(0) => panic!("eof mid-body"),
                        Ok(n) => self.buf.extend_from_slice(&tmp[..n]),
                        Err(e) => panic!("read mid-body: {e}"),
                    }
                }
                let body =
                    String::from_utf8(self.buf[head_end..head_end + body_len].to_vec())
                        .expect("utf8 body");
                self.buf.drain(..head_end + body_len);
                return Some(Resp { status, headers, body });
            }
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    assert!(self.buf.is_empty(), "eof mid-head");
                    return None;
                }
                Ok(n) => self.buf.extend_from_slice(&tmp[..n]),
                Err(e) => panic!("read: {e}"),
            }
        }
    }

    fn assert_server_closed(&mut self) {
        assert!(self.try_read_response().is_none(), "expected the server to close");
    }
}

/// Decode a `Transfer-Encoding: chunked` body from the front of `buf`:
/// `Some((decoded_bytes, bytes_consumed))` once the terminal chunk and
/// its blank trailer section are complete, `None` while incomplete.
/// Panics on malformed framing — the server under test wrote it.
fn decode_chunked(buf: &[u8]) -> Option<(Vec<u8>, usize)> {
    let mut out = Vec::new();
    let mut pos = 0usize;
    loop {
        let line_end = pos + buf[pos..].windows(2).position(|w| w == b"\r\n")?;
        let size_line = std::str::from_utf8(&buf[pos..line_end]).expect("utf8 chunk size");
        let size = usize::from_str_radix(size_line.trim(), 16)
            .unwrap_or_else(|_| panic!("hex chunk size, got {size_line:?}"));
        pos = line_end + 2;
        if size == 0 {
            // the server sends no trailers: the blank line follows directly
            if buf.len() < pos + 2 {
                return None;
            }
            assert_eq!(&buf[pos..pos + 2], b"\r\n", "trailer-free terminal chunk");
            return Some((out, pos + 2));
        }
        if buf.len() < pos + size + 2 {
            return None;
        }
        out.extend_from_slice(&buf[pos..pos + size]);
        assert_eq!(&buf[pos + size..pos + size + 2], b"\r\n", "chunk data terminator");
        pos += size + 2;
    }
}

// ---- request builders -----------------------------------------------------

fn image_json(dim: usize, seed: u64) -> String {
    let img = common::synth_images(1, dim, seed);
    let nums: Vec<String> = img.iter().map(|v| format!("{v}")).collect();
    format!("[{}]", nums.join(","))
}

fn classify_body(dim: usize, seed: u64, id: u64, deadline_ms: Option<f64>) -> String {
    let deadline = deadline_ms.map(|d| format!(",\"deadline_ms\":{d}")).unwrap_or_default();
    format!("{{\"id\":{id},\"image\":{}{deadline}}}", image_json(dim, seed))
}

fn classify_body_for(dim: usize, seed: u64, id: u64, model: &str) -> String {
    format!("{{\"id\":{id},\"model\":\"{model}\",\"image\":{}}}", image_json(dim, seed))
}

fn post_classify(body: &str) -> Vec<u8> {
    format!(
        "POST /v1/classify HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// The same classify POST framed as a chunked body split at `split`.
fn post_classify_chunked(body: &str, split: usize) -> Vec<u8> {
    let split = split.min(body.len());
    let (a, b) = body.split_at(split);
    let mut chunks = String::new();
    for part in [a, b] {
        if !part.is_empty() {
            chunks.push_str(&format!("{:x}\r\n{part}\r\n", part.len()));
        }
    }
    chunks.push_str("0\r\nX-Checksum: none\r\n\r\n");
    format!("POST /v1/classify HTTP/1.1\r\nHost: t\r\nTransfer-Encoding: chunked\r\n\r\n{chunks}")
        .into_bytes()
}

/// The same classify POST carrying an `X-Request-Id` header.
fn post_classify_with_id(body: &str, id: &str) -> Vec<u8> {
    format!(
        "POST /v1/classify HTTP/1.1\r\nHost: t\r\nX-Request-Id: {id}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// The same classify POST asking the server to close after answering.
fn post_classify_close(body: &str) -> Vec<u8> {
    format!(
        "POST /v1/classify HTTP/1.1\r\nHost: t\r\nConnection: close\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

fn expected_class(seed: u64) -> usize {
    let model = common::tiny_linear_model(DIM, CLASSES);
    let mut eng = Engine::new(&model, EngineConfig::default());
    eng.forward(&common::synth_images(1, DIM, seed), 1).expect("forward").argmax(0)
}

// ---- tests ----------------------------------------------------------------

#[test]
fn healthz_and_classify_end_to_end() {
    let http = start_http();
    let mut c = Client::connect(&http);
    c.send(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
    let r = c.read_response();
    assert_eq!(r.status, 200);
    assert_eq!(r.json().get("status").and_then(Json::as_str), Some("ok"));

    c.send(&post_classify(&classify_body(DIM, 3, 42, None)));
    let r = c.read_response();
    assert_eq!(r.status, 200, "body: {}", r.body);
    let j = r.json();
    assert_eq!(j.get("id").and_then(Json::as_usize), Some(42));
    assert_eq!(j.get("class").and_then(Json::as_usize), Some(expected_class(3)));
    assert!(j.get("latency_us").and_then(Json::as_f64).unwrap_or(-1.0) >= 0.0);
    assert!(j.get("batch_size").and_then(Json::as_usize).unwrap_or(0) >= 1);
    http.shutdown();
}

#[test]
fn conformance_corpus_exact_statuses() {
    // (name, raw request bytes, expected status)
    let corpus: Vec<(&str, Vec<u8>, u16)> = vec![
        ("health ok", b"GET /healthz HTTP/1.1\r\n\r\n".to_vec(), 200),
        ("metrics ok", b"GET /v1/metrics HTTP/1.1\r\nHost: t\r\n\r\n".to_vec(), 200),
        ("unknown path", b"GET /nope HTTP/1.1\r\nHost: t\r\n\r\n".to_vec(), 404),
        ("get on classify", b"GET /v1/classify HTTP/1.1\r\n\r\n".to_vec(), 405),
        (
            "delete on classify",
            b"DELETE /v1/classify HTTP/1.1\r\nContent-Length: 0\r\n\r\n".to_vec(),
            405,
        ),
        ("post on metrics", b"POST /v1/metrics HTTP/1.1\r\nContent-Length: 0\r\n\r\n".to_vec(), 405),
        ("bad version", b"GET / HTTP/2.0\r\n\r\n".to_vec(), 400),
        ("not http", b"GET / FTP/1.1\r\n\r\n".to_vec(), 400),
        ("request line extra parts", b"GET /a b HTTP/1.1\r\n\r\n".to_vec(), 400),
        ("header without colon", b"GET /healthz HTTP/1.1\r\nBadHeader\r\n\r\n".to_vec(), 400),
        ("space before colon", b"GET /healthz HTTP/1.1\r\nHost : x\r\n\r\n".to_vec(), 400),
        ("obsolete folding", b"GET /healthz HTTP/1.1\r\nA: b\r\n c\r\n\r\n".to_vec(), 400),
        (
            "garbage content-length",
            b"POST /v1/classify HTTP/1.1\r\nContent-Length: abc\r\n\r\n".to_vec(),
            400,
        ),
        (
            "negative content-length",
            b"POST /v1/classify HTTP/1.1\r\nContent-Length: -1\r\n\r\n".to_vec(),
            400,
        ),
        (
            "conflicting content-lengths",
            b"POST /v1/classify HTTP/1.1\r\nContent-Length: 1\r\nContent-Length: 2\r\n\r\nx"
                .to_vec(),
            400,
        ),
        (
            // valid chunked framing, but the decoded (empty) body is not JSON
            "chunked empty body invalid json",
            b"POST /v1/classify HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n"
                .to_vec(),
            400,
        ),
        (
            "unsupported transfer coding",
            b"POST /v1/classify HTTP/1.1\r\nTransfer-Encoding: gzip\r\n\r\n".to_vec(),
            400,
        ),
        (
            "chunked with content-length",
            b"POST /v1/classify HTTP/1.1\r\nTransfer-Encoding: chunked\r\nContent-Length: 3\r\n\r\n0\r\n\r\n"
                .to_vec(),
            400,
        ),
        (
            "malformed chunk size",
            b"POST /v1/classify HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nzz\r\nab\r\n0\r\n\r\n"
                .to_vec(),
            400,
        ),
        (
            "chunk data without terminator",
            b"POST /v1/classify HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n2\r\nabXX0\r\n\r\n"
                .to_vec(),
            400,
        ),
        (
            "oversized decoded chunked body",
            b"POST /v1/classify HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\nffffffff\r\n"
                .to_vec(),
            413,
        ),
        (
            "oversized declared body",
            b"POST /v1/classify HTTP/1.1\r\nContent-Length: 999999999\r\n\r\n".to_vec(),
            413,
        ),
        ("invalid json body", post_classify("{not json"), 400),
        ("json without image", post_classify("{\"id\":1}"), 400),
        ("wrong image size", post_classify(&classify_body(DIM / 2, 1, 2, None)), 400),
        ("empty body post", post_classify(""), 400),
    ];

    let http = start_http();
    for (name, raw, want) in &corpus {
        let mut c = Client::connect(&http);
        c.send(raw);
        let r = c.read_response();
        assert_eq!(r.status, *want, "case '{name}': body {}", r.body);
    }
    // the listener survived the whole torture corpus: a fresh, well-formed
    // request still classifies
    let mut c = Client::connect(&http);
    c.send(&post_classify(&classify_body(DIM, 5, 1, None)));
    assert_eq!(c.read_response().status, 200);
    http.shutdown();
}

#[test]
fn keep_alive_connection_survives_mixed_sequence() {
    let http = start_http();
    let mut c = Client::connect(&http);
    // several requests over ONE connection, including semantic errors —
    // the connection must stay open throughout
    c.send(b"GET /healthz HTTP/1.1\r\n\r\n");
    assert_eq!(c.read_response().status, 200);
    c.send(&post_classify(&classify_body(DIM, 1, 1, None)));
    let r = c.read_response();
    assert_eq!(r.status, 200);
    assert_eq!(r.header("connection"), Some("keep-alive"));
    c.send(b"GET /missing HTTP/1.1\r\n\r\n");
    assert_eq!(c.read_response().status, 404);
    c.send(&post_classify("{\"id\":1}"));
    assert_eq!(c.read_response().status, 400, "semantic 400 keeps the connection");
    c.send(&post_classify(&classify_body(DIM, 2, 2, None)));
    assert_eq!(c.read_response().status, 200);
    c.send(b"GET /v1/metrics HTTP/1.1\r\nConnection: close\r\n\r\n");
    let r = c.read_response();
    assert_eq!(r.status, 200);
    assert_eq!(r.header("connection"), Some("close"));
    c.assert_server_closed();
    http.shutdown();
}

#[test]
fn pipelined_requests_answered_in_order() {
    let http = start_http();
    let mut c = Client::connect(&http);
    // three classify POSTs and a metrics GET written back-to-back in one
    // burst; responses must come back in order on the same connection
    let mut burst = Vec::new();
    for (id, seed) in [(10u64, 7u64), (11, 8), (12, 9)] {
        burst.extend_from_slice(&post_classify(&classify_body(DIM, seed, id, None)));
    }
    burst.extend_from_slice(b"GET /v1/metrics HTTP/1.1\r\n\r\n");
    c.send(&burst);
    for (id, seed) in [(10u64, 7u64), (11, 8), (12, 9)] {
        let r = c.read_response();
        assert_eq!(r.status, 200, "pipelined response body: {}", r.body);
        let j = r.json();
        assert_eq!(j.get("id").and_then(Json::as_usize), Some(id as usize));
        assert_eq!(j.get("class").and_then(Json::as_usize), Some(expected_class(seed)));
    }
    let r = c.read_response();
    assert_eq!(r.status, 200);
    assert!(r.json().get("requests").and_then(Json::as_usize).unwrap_or(0) >= 3);
    http.shutdown();
}

#[test]
fn requests_survive_arbitrary_write_boundaries() {
    // chunking property: a pipelined healthz + classify byte stream split
    // at arbitrary boundaries (flushed with small delays so the server
    // sees multiple reads) must parse identically to one contiguous write
    let http = start_http();
    let mut stream_bytes = b"GET /healthz HTTP/1.1\r\n\r\n".to_vec();
    stream_bytes.extend_from_slice(&post_classify(&classify_body(DIM, 4, 77, None)));
    let want_class = expected_class(4);
    let total = stream_bytes.len();
    prop::check(
        "http-read-boundary-chunking",
        10,
        |r: &mut Pcg32| {
            let mut cuts: Vec<usize> =
                (0..3).map(|_| 1 + r.below(total as u32 - 1) as usize).collect();
            cuts.sort_unstable();
            cuts.dedup();
            cuts
        },
        |cuts| {
            let mut c = Client::connect(&http);
            let mut start = 0usize;
            for &cut in cuts.iter().chain(std::iter::once(&total)) {
                c.send(&stream_bytes[start..cut]);
                std::thread::sleep(Duration::from_millis(3));
                start = cut;
            }
            let r = c.read_response();
            if r.status != 200 {
                return Err(format!("healthz got {} (cuts {cuts:?})", r.status));
            }
            let r = c.read_response();
            if r.status != 200 {
                return Err(format!("classify got {} (cuts {cuts:?})", r.status));
            }
            let class = r.json().get("class").and_then(Json::as_usize);
            if class != Some(want_class) {
                return Err(format!("class {class:?} != {want_class} (cuts {cuts:?})"));
            }
            Ok(())
        },
    );
    http.shutdown();
}

#[test]
fn expired_deadline_maps_to_504_and_counts() {
    let http = start_http();
    let mut c = Client::connect(&http);
    c.send(&post_classify(&classify_body(DIM, 1, 5, Some(0.0))));
    let r = c.read_response();
    assert_eq!(r.status, 504, "body: {}", r.body);
    assert!(r.body.contains("deadline"), "body: {}", r.body);
    // a queue-starved request is worth retrying after the linger window
    assert_eq!(r.header("retry-after"), Some("1"));
    // the expired counter is visible both in-process and over the wire
    assert_eq!(http.metrics().aggregate().expired, 1);
    c.send(b"GET /v1/metrics HTTP/1.1\r\n\r\n");
    let r = c.read_response();
    assert_eq!(r.status, 200);
    assert_eq!(r.json().get("expired").and_then(Json::as_usize), Some(1));
    // the connection still serves fresh work after a 504
    c.send(&post_classify(&classify_body(DIM, 6, 6, None)));
    assert_eq!(c.read_response().status, 200);
    let report = http.shutdown();
    assert_eq!(report.router.aggregate().expired, 1);
}

#[test]
fn chunked_classify_end_to_end_matches_content_length_framing() {
    // the same JSON body framed chunked (split at several points, with an
    // extension-free terminal chunk and a trailer) must classify exactly
    // like Content-Length framing, on a keep-alive connection
    let http = start_http();
    let mut c = Client::connect(&http);
    let body = classify_body(DIM, 11, 70, None);
    c.send(&post_classify(&body));
    let want = c.read_response();
    assert_eq!(want.status, 200, "reference: {}", want.body);
    let want_class = want.json().get("class").and_then(Json::as_usize);
    assert_eq!(want_class, Some(expected_class(11)));
    for split in [0, 1, body.len() / 2, body.len()] {
        c.send(&post_classify_chunked(&body, split));
        let r = c.read_response();
        assert_eq!(r.status, 200, "chunked split {split}: {}", r.body);
        assert_eq!(
            r.json().get("class").and_then(Json::as_usize),
            want_class,
            "chunked split {split} must classify identically"
        );
    }
    // a malformed chunked request on a FRESH connection answers 400 and
    // the listener survives
    let mut bad = Client::connect(&http);
    bad.send(
        b"POST /v1/classify HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n3\r\nab\rX0\r\n\r\n",
    );
    assert_eq!(bad.read_response().status, 400);
    c.send(&post_classify(&body));
    assert_eq!(c.read_response().status, 200, "listener survives malformed chunking");
    http.shutdown();
}

#[test]
fn model_field_routes_and_unknown_model_is_404() {
    let http = start_http_multi();
    let mut c = Client::connect(&http);
    // no model field: the default ("tiny") serves it
    c.send(&post_classify(&classify_body(DIM, 3, 1, None)));
    let r = c.read_response();
    assert_eq!(r.status, 200, "default-model request: {}", r.body);
    assert_eq!(r.json().get("class").and_then(Json::as_usize), Some(expected_class(3)));
    // explicit default name routes identically
    c.send(&post_classify(&classify_body_for(DIM, 3, 2, "tiny")));
    let r = c.read_response();
    assert_eq!(r.status, 200);
    assert_eq!(r.json().get("class").and_then(Json::as_usize), Some(expected_class(3)));
    // "aux" routes to the CNN (different input dim proves the routing: the
    // same payload would be a 400 size mismatch on "tiny")
    let aux = aux_model();
    let img = common::synth_images(1, AUX_DIM, 9);
    let mut eng = Engine::new(&aux, EngineConfig::default());
    let want = eng.forward(&img, 1).expect("forward").argmax(0);
    c.send(&post_classify(&classify_body_for(AUX_DIM, 9, 3, "aux")));
    let r = c.read_response();
    assert_eq!(r.status, 200, "aux-routed request: {}", r.body);
    assert_eq!(r.json().get("class").and_then(Json::as_usize), Some(want));
    // unknown model: 404, JSON error listing the registered fleet, and
    // the keep-alive connection stays usable
    c.send(&post_classify(&classify_body_for(DIM, 1, 4, "nope")));
    let r = c.read_response();
    assert_eq!(r.status, 404, "unknown model: {}", r.body);
    let msg = r.json().get("error").and_then(Json::as_str).unwrap_or("").to_string();
    assert!(msg.contains("nope"), "404 names the miss: {msg}");
    assert!(msg.contains("tiny") && msg.contains("aux"), "404 lists the fleet: {msg}");
    // a non-string model is a 400, not a silent fallthrough to the default
    c.send(&post_classify(&format!("{{\"model\":7,\"image\":{}}}", image_json(DIM, 1))));
    assert_eq!(c.read_response().status, 400);
    c.send(&post_classify(&classify_body(DIM, 5, 5, None)));
    assert_eq!(c.read_response().status, 200, "connection survives the 404/400s");
    let report = http.shutdown();
    assert_eq!(report.router.unknown_model, 1);
    let tiny = report.router.model("tiny").expect("tiny is registered");
    assert_eq!(tiny.metrics.requests, 3);
    let aux = report.router.model("aux").expect("aux is registered");
    assert_eq!(aux.metrics.requests, 1);
}

#[test]
fn models_endpoint_reflects_lazy_load_state() {
    let http = start_http_multi();
    let mut c = Client::connect(&http);
    let models_of = |c: &mut Client| -> Vec<(String, bool, bool)> {
        c.send(b"GET /v1/models HTTP/1.1\r\n\r\n");
        let r = c.read_response();
        assert_eq!(r.status, 200);
        let j = r.json();
        assert_eq!(j.get("default").and_then(Json::as_str), Some("tiny"));
        j.get("models")
            .and_then(Json::as_arr)
            .expect("models array")
            .iter()
            .map(|m| {
                (
                    m.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                    m.get("loaded").and_then(Json::as_bool).unwrap_or(false),
                    m.get("default").and_then(Json::as_bool).unwrap_or(false),
                )
            })
            .collect()
    };
    // nothing loaded before the first request; both rows listed anyway
    let rows = models_of(&mut c);
    assert_eq!(rows.len(), 2);
    assert!(rows.iter().all(|(_, loaded, _)| !loaded), "lazy: nothing loads at startup");
    assert_eq!(rows.iter().filter(|(_, _, default)| *default).count(), 1);
    // hit the default model only: tiny loads, aux stays cold
    c.send(&post_classify(&classify_body(DIM, 2, 1, None)));
    assert_eq!(c.read_response().status, 200);
    let rows = models_of(&mut c);
    let loaded: Vec<&str> =
        rows.iter().filter(|(_, l, _)| *l).map(|(n, _, _)| n.as_str()).collect();
    assert_eq!(loaded, vec!["tiny"], "only the requested model loads");
    // per-model metrics ride the same payload
    c.send(b"GET /v1/models HTTP/1.1\r\n\r\n");
    let j = c.read_response().json();
    let tiny = j
        .get("models")
        .and_then(Json::as_arr)
        .and_then(|a| {
            a.iter().find(|m| m.get("name").and_then(Json::as_str) == Some("tiny"))
        })
        .expect("tiny row")
        .clone();
    assert_eq!(
        tiny.get("metrics").and_then(|m| m.get("requests")).and_then(Json::as_usize),
        Some(1)
    );
    assert_eq!(
        tiny.get("input_shape").and_then(Json::as_arr).map(|a| a.len()),
        Some(3),
        "loaded model reports its input shape"
    );
    http.shutdown();
}

#[test]
fn metrics_endpoint_nests_router_models_and_http_sections() {
    let http = start_http_multi();
    let mut c = Client::connect(&http);
    c.send(&post_classify(&classify_body(DIM, 1, 1, None)));
    assert_eq!(c.read_response().status, 200);
    c.send(&post_classify(&classify_body_for(AUX_DIM, 2, 2, "aux")));
    assert_eq!(c.read_response().status, 200);
    c.send(&post_classify(&classify_body_for(DIM, 1, 3, "ghost")));
    assert_eq!(c.read_response().status, 404);
    c.send(b"GET /v1/metrics HTTP/1.1\r\n\r\n");
    let r = c.read_response();
    assert_eq!(r.status, 200);
    let j = r.json();
    // aggregate counters stay at the top level (old single-model clients)
    assert_eq!(j.get("requests").and_then(Json::as_usize), Some(2));
    // router section
    let router = j.get("router").expect("router section");
    assert_eq!(router.get("routed").and_then(Json::as_usize), Some(2));
    assert_eq!(router.get("unknown_model").and_then(Json::as_usize), Some(1));
    assert_eq!(router.get("loads").and_then(Json::as_usize), Some(2));
    assert_eq!(router.get("evictions").and_then(Json::as_usize), Some(0));
    assert!(
        router.get("load_latency").and_then(|l| l.get("count")).and_then(Json::as_usize)
            == Some(2),
        "both lazy loads timed"
    );
    // per-model sections keyed by name
    let models = j.get("models").expect("models section");
    for name in ["tiny", "aux"] {
        let m = models.get(name).unwrap_or_else(|| panic!("missing section {name}"));
        assert_eq!(m.get("requests").and_then(Json::as_usize), Some(1), "{name}");
        assert_eq!(m.get("loaded").and_then(Json::as_bool), Some(true), "{name}");
        assert!(m.get("latency").and_then(|l| l.get("count")).is_some(), "{name}");
    }
    let tiny_default = models.get("tiny").and_then(|m| m.get("default"));
    assert_eq!(tiny_default.and_then(Json::as_bool), Some(true));
    // http section: this one connection was accepted, nothing shed
    let http_section = j.get("http").expect("http section");
    assert_eq!(http_section.get("accepted").and_then(Json::as_usize), Some(1));
    assert_eq!(http_section.get("shed").and_then(Json::as_usize), Some(0));
    assert_eq!(http_section.get("read_timeouts").and_then(Json::as_usize), Some(0));
    http.shutdown();
}

#[test]
fn models_endpoint_reports_the_embedded_plan() {
    // a Memory-source model with an embedded accumulator plan reports its
    // summary in GET /v1/models (pre-load for in-memory sources); a
    // plan-free model reports null
    let mut model = common::tiny_linear_model(DIM, CLASSES);
    let plan = pqs::plan::plan_model(&model, &pqs::plan::PlannerConfig::default())
        .expect("planner runs on the synthetic model");
    model.plan = Some(plan.clone());
    let mut registry = ModelRegistry::new();
    registry.register("planned", ModelSource::Memory(model));
    registry.register("planfree", ModelSource::Memory(common::tiny_linear_model(DIM, CLASSES)));
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: EngineConfig::default(),
        server: scfg(),
        preload: Vec::new(),
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).expect("registry is non-empty");
    let http = HttpServer::start(router, "127.0.0.1:0", hcfg()).expect("bind loopback");
    let mut c = Client::connect(&http);
    let fetch_plan = |c: &mut Client, name: &str| -> Json {
        c.send(b"GET /v1/models HTTP/1.1\r\n\r\n");
        let r = c.read_response();
        assert_eq!(r.status, 200);
        r.json()
            .get("models")
            .and_then(Json::as_arr)
            .expect("models array")
            .iter()
            .find(|m| m.get("name").and_then(Json::as_str) == Some(name))
            .unwrap_or_else(|| panic!("{name} row missing"))
            .get("plan")
            .expect("plan field present on every row")
            .clone()
    };
    let want = plan.summary();
    let pj = fetch_plan(&mut c, "planned");
    assert_eq!(pj.get("planner").and_then(Json::as_str), Some("analytic"));
    assert_eq!(pj.get("layers").and_then(Json::as_usize), Some(want.layers));
    assert_eq!(
        pj.get("min_bits").and_then(Json::as_usize),
        Some(want.min_bits as usize)
    );
    assert_eq!(
        pj.get("max_bits").and_then(Json::as_usize),
        Some(want.max_bits as usize)
    );
    assert!(fetch_plan(&mut c, "planfree").is_null(), "plan-free models report null");
    // serve one routed request so "planned" loads, then re-fetch: the
    // live incarnation reports the same summary
    c.send(&post_classify(&classify_body_for(DIM, 1, 1, "planned")));
    assert_eq!(c.read_response().status, 200);
    let pj = fetch_plan(&mut c, "planned");
    assert_eq!(pj.get("layers").and_then(Json::as_usize), Some(want.layers));
    assert_eq!(
        pj.get("min_bits").and_then(Json::as_usize),
        Some(want.min_bits as usize)
    );
    http.shutdown();
}

#[test]
fn acc_bits_override_serves_and_validates_over_http() {
    // one resident planned model answering at several accumulator widths,
    // plus every 400 path of the override field — all on one keep-alive
    // connection that must survive each rejection
    let mut model = common::tiny_linear_model(DIM, CLASSES);
    let plan = pqs::plan::plan_model(
        &model,
        &pqs::plan::PlannerConfig { calibrate_samples: 64, ..Default::default() },
    )
    .expect("planner runs");
    let min_safe = plan.min_safe_bits();
    model.plan = Some(plan.clone());
    let mut registry = ModelRegistry::new();
    registry.register("planned", ModelSource::Memory(model.clone()));
    registry.register("planfree", ModelSource::Memory(common::tiny_linear_model(DIM, CLASSES)));
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: EngineConfig::default(),
        server: scfg(),
        preload: Vec::new(),
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).expect("registry is non-empty");
    let http = HttpServer::start(router, "127.0.0.1:0", hcfg()).expect("bind loopback");
    let mut c = Client::connect(&http);

    let img = image_json(DIM, 21);
    let offline = |widths: Option<&[(String, u32)]>| -> usize {
        let mut eng = Engine::new(&model, EngineConfig::default());
        if let Some(w) = widths {
            eng.apply_layer_bits(w);
        }
        eng.forward(&common::synth_images(1, DIM, 21), 1).expect("forward").argmax(0)
    };
    let want_strict = offline(None);
    let want_wide = offline(Some(&plan.operating_point(32)));

    let classify = |c: &mut Client, extra: &str| -> Resp {
        c.send(&post_classify(&format!("{{\"id\":1,\"model\":\"planned\",\"image\":{img}{extra}")));
        c.read_response()
    };
    // strict width (no override), then the wide point, then the alias
    let r = classify(&mut c, "}");
    assert_eq!(r.status, 200, "strict: {}", r.body);
    assert_eq!(r.json().get("class").and_then(Json::as_usize), Some(want_strict));
    let r = classify(&mut c, ",\"acc_bits\":32}");
    assert_eq!(r.status, 200, "wide: {}", r.body);
    assert_eq!(r.json().get("class").and_then(Json::as_usize), Some(want_wide));
    let r = classify(&mut c, ",\"operating_point\":32}");
    assert_eq!(r.status, 200, "alias: {}", r.body);
    assert_eq!(r.json().get("class").and_then(Json::as_usize), Some(want_wide));

    // malformed override shapes: rejected before routing
    let r = classify(&mut c, ",\"acc_bits\":32,\"operating_point\":32}");
    assert_eq!(r.status, 400, "both fields: {}", r.body);
    assert!(r.body.contains("not both"), "{}", r.body);
    let r = classify(&mut c, ",\"acc_bits\":0}");
    assert_eq!(r.status, 400, "zero width: {}", r.body);
    let r = classify(&mut c, ",\"acc_bits\":\"wide\"}");
    assert_eq!(r.status, 400, "non-numeric width: {}", r.body);

    // an under-bound width is refused by the model's own server
    let r = classify(&mut c, &format!(",\"acc_bits\":{}}}", min_safe - 1));
    assert_eq!(r.status, 400, "under-bound: {}", r.body);
    assert!(r.body.contains("safe minimum"), "{}", r.body);

    // a plan-free model has no operating points to offer
    c.send(&post_classify(&format!(
        "{{\"id\":2,\"model\":\"planfree\",\"image\":{img},\"acc_bits\":24}}"
    )));
    let r = c.read_response();
    assert_eq!(r.status, 400, "plan-free: {}", r.body);
    assert!(r.body.contains("plan"), "{}", r.body);

    // the rejections poisoned nothing: strict still answers identically
    let r = classify(&mut c, "}");
    assert_eq!(r.status, 200);
    assert_eq!(r.json().get("class").and_then(Json::as_usize), Some(want_strict));
    http.shutdown();
}

#[test]
fn wire_surfaces_report_fleet_memory_counters() {
    let http = start_http_multi();
    let mut c = Client::connect(&http);
    // before any load: rows exist, nothing resident
    c.send(b"GET /v1/models HTTP/1.1\r\n\r\n");
    let j = c.read_response().json();
    for m in j.get("models").and_then(Json::as_arr).expect("models array") {
        assert!(
            m.get("resident_bytes").expect("field present").is_null(),
            "unloaded models report null resident_bytes"
        );
    }
    c.send(b"GET /v1/metrics HTTP/1.1\r\n\r\n");
    let j = c.read_response().json();
    let router = j.get("router").expect("router section");
    assert_eq!(router.get("resident_bytes").and_then(Json::as_usize), Some(0));
    assert_eq!(router.get("budget").and_then(Json::as_usize), Some(0));
    assert_eq!(router.get("dedup_hits").and_then(Json::as_usize), Some(0));
    // load "tiny" and the measured bytes appear on both surfaces
    c.send(&post_classify(&classify_body(DIM, 2, 1, None)));
    assert_eq!(c.read_response().status, 200);
    c.send(b"GET /v1/models HTTP/1.1\r\n\r\n");
    let j = c.read_response().json();
    let tiny = j
        .get("models")
        .and_then(Json::as_arr)
        .and_then(|a| {
            a.iter().find(|m| m.get("name").and_then(Json::as_str) == Some("tiny"))
        })
        .expect("tiny row")
        .clone();
    let row_bytes = tiny.get("resident_bytes").and_then(Json::as_usize);
    assert!(row_bytes.unwrap_or(0) > 0, "loaded model reports measured bytes: {tiny:?}");
    c.send(b"GET /v1/metrics HTTP/1.1\r\n\r\n");
    let j = c.read_response().json();
    let fleet = j
        .get("router")
        .and_then(|r| r.get("resident_bytes"))
        .and_then(Json::as_usize);
    assert_eq!(fleet, row_bytes, "one loaded model: fleet bytes == its row");
    http.shutdown();
}

#[test]
fn stalled_partial_request_answers_408_and_counts_read_timeout() {
    let http = start_http();
    let mut c = Client::connect(&http);
    // half a request, then silence: the keep-alive budget (500ms in this
    // suite) expires and the server answers 408
    c.send(b"POST /v1/classify HTTP/1.1\r\nContent-Le");
    let r = c.read_response();
    assert_eq!(r.status, 408, "body: {}", r.body);
    let report = http.shutdown();
    assert_eq!(report.http.read_timeouts, 1);
    assert_eq!(report.http.accepted, 1);
}

/// RFC 9110 §9.3.2 conformance, shared by both backends: every GET
/// endpoint answers HEAD with GET's exact status, Content-Length, and
/// Content-Type — and no body. A leaked HEAD body would sit buffered in
/// the client and corrupt the next parse, which the trailing requests
/// deliberately exercise.
fn assert_head_mirrors_get(http: &HttpServer) {
    let mut c = Client::connect(http);
    for path in ["/healthz", "/v1/models", "/v1/metrics", "/v1/trace", "/metrics", "/nope"] {
        c.send(format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n").as_bytes());
        let get = c.read_response();
        c.send(format!("HEAD {path} HTTP/1.1\r\nHost: t\r\n\r\n").as_bytes());
        let head = c.read_head_response();
        assert_eq!(head.status, get.status, "{path}: HEAD mirrors GET's status");
        assert_eq!(
            head.header("content-length"),
            Some(get.body.len().to_string().as_str()),
            "{path}: HEAD advertises the GET body's exact length"
        );
        assert_eq!(head.header("content-type"), get.header("content-type"), "{path}");
    }
    // wrong-method 405s name the allowed methods; HEAD's no-body rule
    // holds even for error statuses
    c.send(b"PUT /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
    let r = c.read_response();
    assert_eq!(r.status, 405);
    assert_eq!(r.header("allow"), Some("GET, HEAD"));
    c.send(b"HEAD /v1/classify HTTP/1.1\r\nHost: t\r\n\r\n");
    let r = c.read_head_response();
    assert_eq!(r.status, 405);
    assert_eq!(r.header("allow"), Some("POST"));
    // the canary: any stray HEAD body bytes would break this parse
    c.send(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
    let r = c.read_response();
    assert_eq!(r.status, 200);
    assert_eq!(r.json().get("status").and_then(Json::as_str), Some("ok"));
}

#[test]
fn head_mirrors_get_on_every_endpoint() {
    let http = start_http_multi();
    assert_head_mirrors_get(&http);
    http.shutdown();
}

#[test]
fn chunked_response_decodes_byte_identical_to_buffered() {
    // the same /v1/models payload served by a default-threshold server
    // (buffered) and a threshold-1 server (chunked) must decode to
    // identical bytes, with the framing each config promises
    let buffered_srv = start_http_multi();
    let mut bc = Client::connect(&buffered_srv);
    bc.send(b"GET /v1/models HTTP/1.1\r\nHost: t\r\n\r\n");
    let buffered = bc.read_response();
    assert_eq!(buffered.status, 200);
    assert!(buffered.header("content-length").is_some(), "under threshold: Content-Length");
    assert!(buffered.header("transfer-encoding").is_none());

    let chunked_srv = start_http_multi_with(HttpConfig { stream_threshold: 1, ..hcfg() });
    let mut cc = Client::connect(&chunked_srv);
    cc.send(b"GET /v1/models HTTP/1.1\r\nHost: t\r\n\r\n");
    let chunked = cc.read_response();
    assert_eq!(chunked.status, 200);
    assert_eq!(chunked.header("transfer-encoding"), Some("chunked"));
    assert!(chunked.header("content-length").is_none(), "chunked responses carry no length");
    assert_eq!(chunked.body, buffered.body, "decoded chunked payload is byte-identical");

    // HEAD never streams: it advertises the exact buffered length instead
    cc.send(b"HEAD /v1/models HTTP/1.1\r\nHost: t\r\n\r\n");
    let head = cc.read_head_response();
    assert_eq!(head.status, 200);
    assert_eq!(
        head.header("content-length"),
        Some(buffered.body.len().to_string().as_str())
    );
    assert!(head.header("transfer-encoding").is_none());

    // HTTP/1.0 clients never get chunked framing either
    cc.send(b"GET /v1/models HTTP/1.0\r\nHost: t\r\nConnection: keep-alive\r\n\r\n");
    let old = cc.read_response();
    assert_eq!(old.status, 200);
    assert!(old.header("transfer-encoding").is_none());
    assert_eq!(old.body, buffered.body);

    // keep-alive survives streamed responses: classify still answers (and
    // its own body, over the 1-byte threshold, streams and decodes too)
    cc.send(&post_classify(&classify_body(DIM, 3, 9, None)));
    let r = cc.read_response();
    assert_eq!(r.status, 200, "after chunked responses: {}", r.body);
    assert_eq!(r.json().get("class").and_then(Json::as_usize), Some(expected_class(3)));
    chunked_srv.shutdown();
    buffered_srv.shutdown();
}

#[test]
fn blocking_fallback_matches_event_loop_semantics() {
    // the fallback backend honours the same HEAD and framing contracts
    // (on non-Linux hosts the suite's default IS this backend; on Linux
    // this pins the path the other tests no longer take)
    let srv = start_http_multi_with(HttpConfig { event_loop: false, ..hcfg() });
    assert_head_mirrors_get(&srv);
    let chunked_srv = start_http_multi_with(HttpConfig {
        event_loop: false,
        stream_threshold: 1,
        ..hcfg()
    });
    let mut bc = Client::connect(&srv);
    bc.send(b"GET /v1/models HTTP/1.1\r\nHost: t\r\n\r\n");
    let buffered = bc.read_response();
    let mut cc = Client::connect(&chunked_srv);
    cc.send(b"GET /v1/models HTTP/1.1\r\nHost: t\r\n\r\n");
    let chunked = cc.read_response();
    assert_eq!(chunked.header("transfer-encoding"), Some("chunked"));
    assert_eq!(chunked.body, buffered.body, "fallback streams byte-identically");
    chunked_srv.shutdown();
    srv.shutdown();
}

/// A pipelined burst where the SECOND request carries
/// `Connection: close`: both answered in order, the close honoured after
/// the second response, and the third (already-buffered) request never
/// dispatched.
fn assert_mid_pipeline_close_ordering(http: HttpServer) {
    let mut c = Client::connect(&http);
    let mut burst = Vec::new();
    burst.extend_from_slice(&post_classify(&classify_body(DIM, 1, 1, None)));
    burst.extend_from_slice(&post_classify_close(&classify_body(DIM, 2, 2, None)));
    burst.extend_from_slice(&post_classify(&classify_body(DIM, 3, 3, None)));
    c.send(&burst);
    let r = c.read_response();
    assert_eq!(r.status, 200, "first pipelined response: {}", r.body);
    assert_eq!(r.json().get("id").and_then(Json::as_usize), Some(1));
    assert_eq!(r.header("connection"), Some("keep-alive"));
    let r = c.read_response();
    assert_eq!(r.status, 200, "response carrying the close: {}", r.body);
    assert_eq!(r.json().get("id").and_then(Json::as_usize), Some(2));
    assert_eq!(r.header("connection"), Some("close"));
    c.assert_server_closed();
    let report = http.shutdown();
    assert_eq!(report.router.aggregate().requests, 2, "request 3 never reached a model");
}

#[test]
fn mid_pipeline_connection_close_answers_in_order_then_closes() {
    assert_mid_pipeline_close_ordering(start_http());
}

#[test]
fn mid_pipeline_connection_close_on_the_blocking_fallback() {
    assert_mid_pipeline_close_ordering(start_http_with(HttpConfig {
        event_loop: false,
        ..hcfg()
    }));
}

/// The tentpole gate: the event loop holds a 10k idle keep-alive fleet on
/// one loop thread without shedding a single connection, and still
/// answers classify probes while the fleet sits open.
#[cfg(target_os = "linux")]
#[test]
fn idle_keep_alive_fleet_of_ten_thousand_is_not_shed() {
    let want = 10_000usize;
    // client and server ends both live in this process: 2 fds per
    // connection, plus headroom for the suite's own files and sockets
    let limit = pqs::http::server::raise_nofile_limit(2 * want as u64 + 1024);
    let fleet = want.min((limit.saturating_sub(1024) / 2) as usize);
    if fleet < want {
        eprintln!("fd limit {limit}: scaling the idle soak down to {fleet} connections");
    }
    if fleet < 1024 {
        // a host this constrained can't host a meaningful soak; the
        // connections bench section still covers the no-shed guarantee
        eprintln!("fd soft limit {limit} too low for the idle soak; skipping");
        return;
    }
    let http = start_http_with(HttpConfig {
        event_loop: true,
        max_connections: fleet + 64,
        keep_alive_timeout: Duration::from_secs(60),
        ..hcfg()
    });
    let addr = http.local_addr();
    let mut idle = Vec::with_capacity(fleet);
    for i in 0..fleet {
        match TcpStream::connect(addr) {
            Ok(s) => idle.push(s),
            Err(e) => panic!("connect {i}/{fleet}: {e}"),
        }
    }
    // the loop still serves real work while every idle socket stays open
    let mut c = Client::connect(&http);
    for i in 0..5u64 {
        c.send(&post_classify(&classify_body(DIM, i, i, None)));
        let r = c.read_response();
        assert_eq!(r.status, 200, "probe {i} with {fleet} idle connections: {}", r.body);
    }
    drop(idle);
    let report = http.shutdown();
    assert_eq!(report.http.shed, 0, "no connection below the cap may be shed");
    assert!(
        report.http.accepted as usize >= fleet + 1,
        "every socket accepted: {} < {}",
        report.http.accepted,
        fleet + 1
    );
}

#[test]
fn concurrent_connections_all_served() {
    let http = start_http();
    std::thread::scope(|scope| {
        for t in 0..4u64 {
            let http = &http;
            scope.spawn(move || {
                let mut c = Client::connect(http);
                for i in 0..10u64 {
                    let seed = t * 100 + i;
                    c.send(&post_classify(&classify_body(DIM, seed, seed, None)));
                    let r = c.read_response();
                    assert_eq!(r.status, 200, "thread {t} req {i}: {}", r.body);
                }
            });
        }
    });
    let report = http.shutdown();
    let total = report.router.aggregate();
    assert_eq!(total.requests, 40);
    assert_eq!(total.errors, 0);
    assert_eq!(total.expired, 0);
    // every connection was accepted, none shed, none timed out
    assert_eq!(report.http.accepted, 4);
    assert_eq!(report.http.shed, 0);
    assert_eq!(report.http.read_timeouts, 0);
}

// ---- request tracing + Prometheus exposition -------------------------------

fn trace_hcfg(sample_rate: f64, ring: usize) -> HttpConfig {
    HttpConfig { trace: TraceConfig { enabled: true, sample_rate, ring }, ..hcfg() }
}

#[test]
fn x_request_id_echo_provided_generated_and_invalid() {
    // the default config (sample rate 0) still echoes ids — sampling
    // gates the ring, never the id contract
    let http = start_http();
    let mut c = Client::connect(&http);
    // provided: echoed verbatim on the 200
    c.send(&post_classify_with_id(&classify_body(DIM, 1, 1, None), "req-A.1_z"));
    let r = c.read_response();
    assert_eq!(r.status, 200, "body: {}", r.body);
    assert_eq!(r.header("x-request-id"), Some("req-A.1_z"));
    // absent: a generated pqs-<16 hex> id is echoed
    c.send(&post_classify(&classify_body(DIM, 2, 2, None)));
    let r = c.read_response();
    assert_eq!(r.status, 200);
    let id = r.header("x-request-id").expect("generated id echoed").to_string();
    assert!(id.starts_with("pqs-") && id.len() == 20, "generated id shape: {id}");
    assert!(id[4..].bytes().all(|b| b.is_ascii_hexdigit()), "hex suffix: {id}");
    // two requests never share a generated id
    c.send(&post_classify(&classify_body(DIM, 3, 3, None)));
    let r = c.read_response();
    assert_eq!(r.status, 200);
    assert_ne!(r.header("x-request-id"), Some(id.as_str()));
    // prepare-stage 400s still echo a provided id
    c.send(&post_classify_with_id("{not json", "bad-body-1"));
    let r = c.read_response();
    assert_eq!(r.status, 400);
    assert_eq!(r.header("x-request-id"), Some("bad-body-1"));
    // an invalid id is rejected outright — never echoed, never replaced
    c.send(&post_classify_with_id(&classify_body(DIM, 4, 4, None), "bad id"));
    let r = c.read_response();
    assert_eq!(r.status, 400, "body: {}", r.body);
    assert!(r.body.contains("X-Request-Id"), "names the header: {}", r.body);
    assert!(r.header("x-request-id").is_none(), "an invalid id must not be echoed");
    let long = "a".repeat(129);
    c.send(&post_classify_with_id(&classify_body(DIM, 5, 5, None), &long));
    assert_eq!(c.read_response().status, 400, "over-length id rejected");
    // non-classify endpoints do not echo
    c.send(b"GET /v1/metrics HTTP/1.1\r\nHost: t\r\n\r\n");
    let r = c.read_response();
    assert_eq!(r.status, 200);
    assert!(r.header("x-request-id").is_none());
    // the connection survived every rejection
    c.send(&post_classify(&classify_body(DIM, 6, 6, None)));
    assert_eq!(c.read_response().status, 200);
    http.shutdown();
}

#[test]
fn trace_endpoint_reports_spans_and_evicts_oldest() {
    let http = start_http_with(trace_hcfg(1.0, 4));
    let mut c = Client::connect(&http);
    for i in 0..6u64 {
        c.send(&post_classify_with_id(&classify_body(DIM, i, i, None), &format!("t-{i}")));
        assert_eq!(c.read_response().status, 200);
    }
    c.send(b"GET /v1/trace HTTP/1.1\r\nHost: t\r\n\r\n");
    let r = c.read_response();
    assert_eq!(r.status, 200);
    let j = r.json();
    assert_eq!(j.get("enabled"), Some(&Json::Bool(true)));
    assert_eq!(j.get("sample_rate").and_then(Json::as_f64), Some(1.0));
    assert_eq!(j.get("capacity").and_then(Json::as_usize), Some(4));
    assert_eq!(j.get("recorded").and_then(Json::as_usize), Some(6));
    let spans = j.get("spans").and_then(Json::as_arr).expect("spans array");
    let ids: Vec<&str> = spans.iter().filter_map(|s| s.get("id").and_then(Json::as_str)).collect();
    assert_eq!(ids, vec!["t-2", "t-3", "t-4", "t-5"], "ring keeps the newest, oldest first");
    for s in spans {
        assert_eq!(s.get("status").and_then(Json::as_usize), Some(200));
        assert_eq!(s.get("model").and_then(Json::as_str), Some("tiny"));
        let total = s.get("total_us").and_then(Json::as_f64).expect("total_us");
        assert!(total > 0.0);
        let stages = s.get("stages").expect("stages object");
        let mut sum = 0.0;
        for name in ["parse", "route", "queue", "batch", "forward", "respond"] {
            let us = stages
                .get(name)
                .and_then(Json::as_f64)
                .unwrap_or_else(|| panic!("stage {name} missing"));
            assert!(us >= 0.0, "{name}: {us}");
            sum += us;
        }
        assert!(sum <= total * (1.0 + 1e-9), "stage sum {sum} past the total {total}");
    }
    // ?n=2 returns just the newest two, still oldest first
    c.send(b"GET /v1/trace?n=2 HTTP/1.1\r\nHost: t\r\n\r\n");
    let j = c.read_response().json();
    let ids: Vec<String> = j
        .get("spans")
        .and_then(Json::as_arr)
        .expect("spans array")
        .iter()
        .filter_map(|s| s.get("id").and_then(Json::as_str).map(String::from))
        .collect();
    assert_eq!(ids, vec!["t-4", "t-5"]);
    http.shutdown();
}

#[test]
fn prometheus_scrape_parses_and_carries_headroom_gauges() {
    // the acceptance drive: ≥100 classifies at sampling 1.0, every
    // response echoing its id, then the scrape must obey the text
    // exposition grammar and carry per-layer headroom gauges
    let http = start_http_with(trace_hcfg(1.0, 512));
    let mut c = Client::connect(&http);
    for i in 0..100u64 {
        let id = format!("acc-{i}");
        c.send(&post_classify_with_id(&classify_body(DIM, i, i, None), &id));
        let r = c.read_response();
        assert_eq!(r.status, 200, "drive {i}: {}", r.body);
        assert_eq!(r.header("x-request-id"), Some(id.as_str()), "drive {i}");
    }
    c.send(b"GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n");
    let r = c.read_response();
    assert_eq!(r.status, 200);
    assert_eq!(r.header("content-type"), Some("text/plain; version=0.0.4"));
    validate_exposition(&r.body).expect("scrape obeys the text exposition grammar");
    for needle in [
        "# TYPE pqs_requests_total counter",
        "# TYPE pqs_models_loaded gauge",
        "# TYPE pqs_latency_us summary",
        "pqs_latency_us{quantile=\"0.99\"}",
        "# TYPE pqs_trace_stage_us histogram",
        "pqs_trace_stage_us_bucket{stage=\"forward\",le=\"+Inf\"}",
        "pqs_http_shed_total{reason=\"queue_full\"}",
        "# TYPE pqs_headroom_min_bits gauge",
        "pqs_headroom_min_bits{model=\"tiny\",layer=",
    ] {
        assert!(r.body.contains(needle), "scrape missing {needle:?}:\n{}", r.body);
    }
    // the /v1/metrics trace section carries the same per-stage breakdown
    c.send(b"GET /v1/metrics HTTP/1.1\r\nHost: t\r\n\r\n");
    let j = c.read_response().json();
    let tr = j.get("trace").expect("trace section");
    assert_eq!(tr.get("recorded").and_then(Json::as_usize), Some(100));
    let stages = tr.get("stages").expect("stages");
    for name in ["parse", "route", "queue", "batch", "forward", "respond"] {
        let st = stages.get(name).unwrap_or_else(|| panic!("stage {name} missing"));
        assert_eq!(st.get("count").and_then(Json::as_usize), Some(100), "{name}");
        assert!(st.get("p50_us").and_then(Json::as_f64).unwrap_or(-1.0) >= 0.0, "{name}");
    }
    // per-model headroom rows ride GET /v1/models once batches have run
    c.send(b"GET /v1/models HTTP/1.1\r\nHost: t\r\n\r\n");
    let j = c.read_response().json();
    let rows = j.get("models").and_then(Json::as_arr).expect("models array");
    let tiny = rows
        .iter()
        .find(|m| m.get("name").and_then(Json::as_str) == Some("tiny"))
        .expect("tiny row");
    let hr = tiny.get("headroom").and_then(Json::as_arr).expect("headroom rows");
    assert!(!hr.is_empty(), "served batches must produce headroom rows");
    for l in hr {
        assert!(l.get("layer").and_then(Json::as_str).is_some());
        let planned = l.get("planned_bits").and_then(Json::as_f64).expect("planned_bits");
        let required = l.get("max_required_bits").and_then(Json::as_f64).expect("required");
        let min_h = l.get("min_headroom_bits").and_then(Json::as_f64).expect("min headroom");
        assert_eq!(min_h, planned - required, "constant width: headroom is plan minus need");
        assert!(l.get("dots").and_then(Json::as_f64).unwrap_or(0.0) > 0.0);
    }
    http.shutdown();
}

// ---- self-healing on the wire: /readyz, Retry-After, quarantine -----------

#[test]
fn readyz_is_distinct_from_healthz_and_gates_on_drain() {
    let http = start_http();
    let mut c = Client::connect(&http);
    // healthy + not draining: both probes answer 200, but readyz carries
    // the individual gates so an operator can see WHY it is (not) ready
    c.send(b"GET /readyz HTTP/1.1\r\nHost: t\r\n\r\n");
    let r = c.read_response();
    assert_eq!(r.status, 200, "body: {}", r.body);
    let j = r.json();
    assert_eq!(j.get("ready"), Some(&Json::Bool(true)));
    assert_eq!(j.get("draining"), Some(&Json::Bool(false)));
    assert_eq!(j.get("default_model_ok"), Some(&Json::Bool(true)));
    assert!(j.get("queue_cap").and_then(Json::as_usize).is_some());
    // HEAD mirrors GET's status with no body (probes often use HEAD); the
    // follow-up request would choke on any stray body bytes
    c.send(b"HEAD /readyz HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(c.read_head_response().status, 200);
    // only GET/HEAD are allowed, and the 405 names them
    c.send(b"POST /readyz HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\r\n");
    let r = c.read_response();
    assert_eq!(r.status, 405);
    assert_eq!(r.header("allow"), Some("GET, HEAD"));
    // draining: readiness drops (503 + Retry-After) while LIVENESS and
    // the already-open connection keep working — that split is the whole
    // point of having two probes
    http.set_draining();
    c.send(b"GET /readyz HTTP/1.1\r\nHost: t\r\n\r\n");
    let r = c.read_response();
    assert_eq!(r.status, 503, "body: {}", r.body);
    assert_eq!(r.header("retry-after"), Some("1"));
    let j = r.json();
    assert_eq!(j.get("ready"), Some(&Json::Bool(false)));
    assert_eq!(j.get("draining"), Some(&Json::Bool(true)));
    c.send(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(c.read_response().status, 200, "draining is not dead");
    c.send(&post_classify(&classify_body(DIM, 2, 9, None)));
    assert_eq!(c.read_response().status, 200, "in-flight traffic still serves while draining");
    http.shutdown();
}

#[test]
fn breaker_503_carries_retry_after_quarantine_503_does_not() {
    use pqs::coordinator::BreakerConfig;
    // default model "bad": every load fails; threshold 1 trips the
    // breaker on the first touch. "rotten": checksummed weights with a
    // flipped bit — the integrity gate quarantines it.
    let mut registry = ModelRegistry::new();
    registry.register(
        "bad",
        ModelSource::factory(|| Err(anyhow::anyhow!("bad: injected load failure"))),
    );
    registry.register(
        "rotten",
        ModelSource::factory(|| {
            let mut m = common::tiny_linear_model(DIM, CLASSES);
            m.attach_checksums();
            let q = m.graph.iter_mut().find_map(|n| n.q.as_mut()).expect("a q-layer");
            let mut w = q.wq.as_slice().to_vec();
            w[0] ^= 1;
            q.wq = w.into();
            Ok(m)
        }),
    );
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: EngineConfig::default(),
        server: scfg(),
        preload: Vec::new(),
        breaker: BreakerConfig {
            threshold: 1,
            base_backoff: Duration::from_secs(30),
            max_backoff: Duration::from_secs(30),
            ..Default::default()
        },
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).expect("registry is non-empty");
    let http = HttpServer::start(router, "127.0.0.1:0", hcfg()).expect("bind loopback");
    let mut c = Client::connect(&http);
    // touch 1: the load itself fails → 500, and the breaker trips Open
    c.send(&post_classify(&classify_body_for(DIM, 1, 1, "bad")));
    let r = c.read_response();
    assert_eq!(r.status, 500, "body: {}", r.body);
    assert!(r.body.contains("bad"), "names the model: {}", r.body);
    // touch 2: fast-fail with the remaining backoff as Retry-After
    c.send(&post_classify(&classify_body_for(DIM, 1, 2, "bad")));
    let r = c.read_response();
    assert_eq!(r.status, 503, "body: {}", r.body);
    assert!(r.body.contains("circuit breaker"), "body: {}", r.body);
    let after: u64 = r
        .header("retry-after")
        .expect("a breaker 503 advertises when to come back")
        .parse()
        .expect("delta-seconds");
    assert!((1..=30).contains(&after), "ceil of the remaining backoff, got {after}");
    // the Open breaker sits on the DEFAULT model, so readiness drops too
    c.send(b"GET /readyz HTTP/1.1\r\nHost: t\r\n\r\n");
    let r = c.read_response();
    assert_eq!(r.status, 503);
    assert_eq!(r.json().get("default_model_ok"), Some(&Json::Bool(false)));
    // quarantine: same status, but NO Retry-After — waiting cannot fix
    // corrupt bytes, only an operator reload can
    c.send(&post_classify(&classify_body_for(DIM, 1, 3, "rotten")));
    let r = c.read_response();
    assert_eq!(r.status, 503, "body: {}", r.body);
    assert!(r.body.contains("quarantined"), "body: {}", r.body);
    assert!(r.body.contains("checksum mismatch"), "body: {}", r.body);
    assert!(r.header("retry-after").is_none(), "no Retry-After on a quarantine");
    // both states are visible in the fleet listing
    c.send(b"GET /v1/models HTTP/1.1\r\nHost: t\r\n\r\n");
    let r = c.read_response();
    assert_eq!(r.status, 200);
    let j = r.json();
    let rows = j.get("models").and_then(Json::as_arr).expect("fleet rows");
    let health = |name: &str| -> &Json {
        rows.iter()
            .find(|m| m.get("name").and_then(Json::as_str) == Some(name))
            .and_then(|m| m.get("health"))
            .unwrap_or_else(|| panic!("row for {name}"))
    };
    assert_eq!(health("bad").get("breaker").and_then(Json::as_str), Some("open"));
    assert!(health("bad").get("retry_after_s").and_then(Json::as_f64).unwrap_or(0.0) > 0.0);
    assert!(
        health("rotten").get("quarantined").and_then(Json::as_str).is_some(),
        "the quarantine reason rides the fleet row"
    );
    assert_eq!(health("rotten").get("breaker").and_then(Json::as_str), Some("closed"));
    http.shutdown();
}
