//! Accumulator-bitwidth planner acceptance suite (artifact-free).
//!
//! The ISSUE 5 contract, end to end on `models::synthetic_conv`:
//! every layer's analytic width is <= 32, the calibrated width is <= the
//! analytic width, an engine forward at the planned widths reports ZERO
//! persistent overflows across a 1k-input sweep, a `.pqsw` round-trip
//! (save with plan -> load -> serve via Router) applies the plan and
//! reports it in the fleet listing, and plan-free `.pqsw` files remain
//! bit-identical to the unplanned engine.

mod common;

use pqs::accum::Policy;
use pqs::coordinator::{
    ClassifyRequest, ModelRegistry, ModelSource, Router, RouterConfig, ServerConfig,
};
use pqs::formats::pqsw::PqswModel;
use pqs::nn::engine::{Engine, EngineConfig};
use pqs::plan::{plan_model, PlannerConfig, PlannerKind};
use pqs::util::rng::Pcg32;
use std::time::Duration;

/// The 1k-input sweep of the acceptance criterion, batched.
fn sweep(eng: &mut Engine, dim: usize, inputs: usize, seed: u64) -> pqs::overflow::OverflowStats {
    let mut rng = Pcg32::new(seed);
    let batch = 50;
    let mut total = pqs::overflow::OverflowStats::default();
    let mut done = 0;
    while done < inputs {
        let n = batch.min(inputs - done);
        let imgs: Vec<f32> = (0..n * dim).map(|_| rng.f32()).collect();
        let out = eng.forward(&imgs, n).expect("forward");
        total.merge(&out.report.total());
        done += n;
    }
    total
}

#[test]
fn acceptance_planned_synthetic_conv_has_zero_persistent_overflows() {
    let model = pqs::models::synthetic_conv(2, 8, 8, 4, 10);
    let dim: usize = model.input_shape.iter().product();
    let cfg = PlannerConfig {
        policy: Policy::Sorted,
        calibrate_samples: 256,
        ..Default::default()
    };
    let plan = plan_model(&model, &cfg).expect("planner runs");
    assert_eq!(plan.planner, PlannerKind::Calibrated);
    assert_eq!(plan.per_layer.len(), 3);
    for l in &plan.per_layer {
        assert!(l.analytic_bits <= 32, "layer {}: analytic {} > 32", l.name, l.analytic_bits);
        let cal = l.calibrated_bits.expect("calibration ran");
        assert!(
            cal <= l.analytic_bits,
            "layer {}: calibrated {cal} > analytic {}",
            l.name,
            l.analytic_bits
        );
        assert_eq!(l.acc_bits, cal);
    }
    assert!(plan.total_bits() < plan.baseline_bits(), "plan must beat the 32-bit baseline");

    // enforcement: run the planned model with a deliberately absurd
    // GLOBAL width (6 bits). If the per-layer overrides are applied, the
    // global never matters and the 1k-input sweep stays persistent-free.
    let mut planned = model.clone();
    planned.plan = Some(plan.clone());
    let ecfg = EngineConfig {
        policy: Policy::Sorted,
        acc_bits: 6,
        collect_stats: true,
        ..Default::default()
    };
    let mut eng = Engine::new(&planned, ecfg);
    for (name, bits) in eng.effective_layer_bits() {
        assert_eq!(Some(bits), plan.bits_for_layer(&name), "layer {name} enforced");
    }
    let total = sweep(&mut eng, dim, 1000, 0xACC);
    assert!(total.dots >= 1000, "the sweep really ran");
    assert_eq!(
        total.persistent_dots, 0,
        "zero persistent overflows at the planned widths over 1k inputs"
    );

    // control: the SAME global 6-bit config without a plan must overflow
    // persistently — proving the zero above comes from the plan, not from
    // the model being trivially narrow
    let mut control = Engine::new(&model, ecfg);
    let control_total = sweep(&mut control, dim, 50, 0xACC);
    assert!(
        control_total.persistent_dots > 0,
        "a 6-bit global accumulator must persistently overflow without the plan"
    );
}

#[test]
fn analytic_only_plan_also_guarantees_the_sweep() {
    // without calibration the enforced widths are the analytic bounds;
    // the guarantee is unconditional, so the sweep must be event-free for
    // the sequential policies too
    let model = pqs::models::synthetic_conv(2, 8, 8, 4, 10);
    let dim: usize = model.input_shape.iter().product();
    for policy in [Policy::Clip, Policy::Sorted1] {
        let plan =
            plan_model(&model, &PlannerConfig { policy, ..Default::default() }).unwrap();
        let mut planned = model.clone();
        planned.plan = Some(plan);
        let ecfg = EngineConfig { policy, acc_bits: 8, collect_stats: true, ..Default::default() };
        let mut eng = Engine::new(&planned, ecfg);
        let total = sweep(&mut eng, dim, 200, 0xA11);
        assert_eq!(total.persistent_dots, 0, "{}: persistent at analytic width", policy.name());
        if policy == Policy::Clip {
            // Clip's analytic bound is the prefix bound: zero EVENTS, so
            // the clipped values are exact
            assert_eq!(total.policy_event_dots, 0, "clip events at the prefix bound");
        }
    }
}

#[test]
fn calibrated_clip_plan_replays_the_calibration_set_event_free() {
    // Clip's saturation is order-dependent, so its calibrated widths come
    // from index-order prefix extremes, not final values. With a zero
    // budget, replaying the exact calibration input stream at the
    // calibrated widths must therefore produce ZERO events (values stay
    // exact layer by layer, so the replay is self-consistent end to end).
    let model = pqs::models::synthetic_conv(2, 8, 8, 4, 10);
    let dim: usize = model.input_shape.iter().product();
    let cfg = PlannerConfig {
        policy: Policy::Clip,
        calibrate_samples: 192,
        budget: 0.0,
        margin: 0, // no slack: the guarantee must come from the histogram
        ..Default::default()
    };
    let plan = plan_model(&model, &cfg).unwrap();
    let mut planned = model.clone();
    planned.plan = Some(plan);
    let ecfg = EngineConfig {
        policy: Policy::Clip,
        acc_bits: 6,
        collect_stats: true,
        ..Default::default()
    };
    let mut eng = Engine::new(&planned, ecfg);
    // regenerate the identical input stream the planner observed (same
    // seed, same batch size => same Pcg32 draws in the same order)
    let mut rng = Pcg32::new(cfg.seed);
    let mut total = pqs::overflow::OverflowStats::default();
    let mut done = 0;
    while done < cfg.calibrate_samples {
        let n = cfg.batch.min(cfg.calibrate_samples - done);
        let imgs: Vec<f32> = (0..n * dim).map(|_| rng.f32()).collect();
        total.merge(&eng.forward(&imgs, n).unwrap().report.total());
        done += n;
    }
    assert!(total.dots > 0);
    assert_eq!(total.policy_event_dots, 0, "replayed calibration inputs must be event-free");
    assert_eq!(total.persistent_dots, 0);
}

#[test]
fn pqsw_roundtrip_applies_and_reports_the_plan_via_the_router() {
    let dir = std::env::temp_dir().join("pqs_test_plan_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("planned_conv.pqsw");

    let model = pqs::models::synthetic_conv(2, 8, 8, 4, 10);
    let dim: usize = model.input_shape.iter().product();
    let cfg = PlannerConfig { calibrate_samples: 64, ..Default::default() };
    let plan = plan_model(&model, &cfg).unwrap();
    let mut planned = model.clone();
    planned.plan = Some(plan.clone());
    planned.save(&path).expect("save planned .pqsw");

    // load -> the plan rides along and the engine enforces it
    let loaded = PqswModel::load(&path).expect("load planned .pqsw");
    assert_eq!(loaded.plan.as_ref(), Some(&plan));
    let ecfg = EngineConfig { policy: Policy::Sorted, acc_bits: 16, ..Default::default() };
    let eng = Engine::new(&loaded, ecfg);
    for (name, bits) in eng.effective_layer_bits() {
        assert_eq!(Some(bits), plan.bits_for_layer(&name), "layer {name}");
    }

    // serve the FILE via the router (a Path source, loaded lazily) and
    // check the fleet row reports the plan summary
    let mut registry = ModelRegistry::new();
    registry.register("planned", ModelSource::Path(path.clone()));
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: ecfg,
        server: ServerConfig {
            threads: 1,
            max_batch: 4,
            queue_cap: 16,
            linger: Duration::from_micros(50),
            engine_threads: 1,
            default_deadline: None,
        },
        preload: Vec::new(),
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).unwrap();
    // before the lazy load a Path source cannot know the plan
    assert_eq!(router.metrics().model("planned").unwrap().plan, None);
    let image = common::synth_images(1, dim, 42);
    let p = router
        .submit(ClassifyRequest {
            id: 1,
            model: None,
            image: image.clone(),
            deadline: None,
            acc_bits: None,
            trace: None,
        })
        .expect("routes");
    let r = p.wait_timeout(Duration::from_secs(60)).expect("response");
    // the routed class matches a dedicated engine over the planned model
    let mut offline = Engine::new(&loaded, ecfg);
    let want = offline.forward(&image, 1).unwrap().argmax(0);
    assert_eq!(r.result, Ok(want));
    // after the load the live incarnation reports the summary
    let m = router.shutdown();
    let row = m.model("planned").unwrap();
    let got = row.plan.expect("loaded model reports its plan");
    let want_sum = plan.summary();
    assert_eq!(got.layers, want_sum.layers);
    assert_eq!(got.min_bits, want_sum.min_bits);
    assert_eq!(got.max_bits, want_sum.max_bits);
    assert_eq!(got.planner, want_sum.planner);
    assert_eq!(row.metrics.requests, 1);
}

#[test]
fn planfree_pqsw_files_stay_bit_identical() {
    // a model saved WITHOUT a plan must load into an engine whose logits
    // and overflow stats equal the never-serialized original exactly
    let dir = std::env::temp_dir().join("pqs_test_plan_free");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("planfree_conv.pqsw");
    let model = pqs::models::synthetic_conv(2, 8, 8, 4, 10);
    let dim: usize = model.input_shape.iter().product();
    model.save(&path).unwrap();
    let loaded = PqswModel::load(&path).unwrap();
    assert_eq!(loaded.plan, None);
    let ecfg = EngineConfig {
        policy: Policy::Sorted1,
        acc_bits: 14,
        collect_stats: true,
        ..Default::default()
    };
    let mut a = Engine::new(&model, ecfg);
    let mut b = Engine::new(&loaded, ecfg);
    let mut rng = Pcg32::new(0xF2EE);
    let imgs: Vec<f32> = (0..4 * dim).map(|_| rng.f32()).collect();
    let ra = a.forward(&imgs, 4).unwrap();
    let rb = b.forward(&imgs, 4).unwrap();
    assert_eq!(ra.logits, rb.logits, "logits bit-identical through the container");
    assert_eq!(ra.report.total(), rb.report.total(), "stats bit-identical");
}
