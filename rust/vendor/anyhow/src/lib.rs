//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment is fully offline (no crates.io), so this vendored
//! shim provides the subset of the real API that `pqs` uses:
//!
//! * `anyhow::Error` — a context chain of messages; `{e}` shows the
//!   outermost context, `{e:#}` the full `outer: inner: root` chain
//!   (matching the real crate's alternate Display);
//! * `anyhow::Result<T>` — `Result<T, Error>`;
//! * `?`-conversion from any `std::error::Error + Send + Sync + 'static`
//!   (the source chain is flattened into the context chain);
//! * the `Context` extension trait (`.context(..)` / `.with_context(..)`)
//!   on both `Result` and `Option`;
//! * the `anyhow!`, `bail!` and `ensure!` macros.
//!
//! Not implemented: downcasting, backtraces.

use std::fmt;

/// Error: an ordered chain of context messages, outermost first.
pub struct Error {
    msgs: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msgs: vec![m.to_string()] }
    }

    /// Prepend an outer context message.
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Error {
        self.msgs.insert(0, ctx.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.msgs.iter().map(|s| s.as_str())
    }

    /// Root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.msgs.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, like the real anyhow
            write!(f, "{}", self.msgs.join(": "))
        } else {
            write!(f, "{}", self.msgs.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.msgs.split_first() {
            None => Ok(()),
            Some((first, rest)) => {
                write!(f, "{first}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for m in rest {
                        write!(f, "\n    {m}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes the blanket `From` impl below coherent (same trick as the real
// anyhow crate).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs }
    }
}

/// `anyhow::Result<T>`: `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing file");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e: Result<()> = Err(io_err());
        let e = e.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
        assert_eq!(e.root_cause(), "missing file");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32> = Ok(7);
        let v = ok.with_context(|| -> String { panic!("context closure must not run") });
        assert_eq!(v.unwrap(), 7);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
        assert_eq!(Some(3).context("never").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big: {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big: 101");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }
}
