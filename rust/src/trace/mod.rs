//! End-to-end request tracing + live telemetry (zero dependencies).
//!
//! Three pieces, threaded through every serving seam:
//!
//! * **Per-request spans** — every classify carries a [`RequestTrace`]
//!   (id from the client's `X-Request-Id` header or generated, echoed
//!   back in the response). The HTTP layer records monotonic stage
//!   durations (`parse → route → queue → batch → forward → respond`,
//!   see [`STAGES`]) into a [`TraceSpan`] and hands it to the shared
//!   [`Tracer`]: a fixed-capacity ring buffer behind one short-lived
//!   mutex, head-sampled at `TraceConfig::sample_rate` with
//!   always-sample overrides on errors (status ≥ 400, so 504s and
//!   sheds are never lost) and on batches that recorded overflow
//!   events. `GET /v1/trace?n=K` serves the ring as JSON; per-stage
//!   [`HdrHistogram`] breakdowns ride `GET /v1/metrics`.
//! * **Accumulator headroom** — [`ModelHeadroom`] folds the engine's
//!   per-layer [`OverflowStats`] (`bits_hist`) into running counters
//!   per model × layer: planned width, max observed required width,
//!   min headroom in bits, overflow-event dots and near-saturation
//!   dots (within 1 bit of the plan). Exposed per row in
//!   `GET /v1/models` and as Prometheus gauges, so a layer drifting
//!   toward its budget is visible before it overflows.
//! * **Prometheus text exposition** — [`PromText`] renders counters,
//!   gauges and HDR-bucketed histograms in the text format 0.0.4
//!   served from `GET /metrics`; [`validate_exposition`] is the
//!   grammar checker the unit and wire tests hold the output against.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::overflow::OverflowReport;
use crate::util::json::{self, Json};
use crate::util::stats::HdrHistogram;

/// Span stage names, in request order. `parse` covers accept/read →
/// request decoded; `route` covers model resolution (breaker and
/// lazy-load waits included) through queue admission; `queue` is the
/// client-observed wait net of batch assembly and forward; `batch` is
/// batch assembly (expiry checks, width grouping, plan application);
/// `forward` is the engine forward of the batch the request rode;
/// `respond` is result → encoded response handed to the socket writer.
pub const STAGES: [&str; 6] = ["parse", "route", "queue", "batch", "forward", "respond"];

/// Longest `X-Request-Id` accepted from a client.
pub const MAX_REQUEST_ID_LEN: usize = 128;

/// Client-supplied request ids must be 1..=128 chars of
/// `[A-Za-z0-9._-]` — anything else is rejected with a 400 rather than
/// echoed back into a header.
pub fn valid_request_id(s: &str) -> bool {
    !s.is_empty()
        && s.len() <= MAX_REQUEST_ID_LEN
        && s.bytes().all(|b| b.is_ascii_alphanumeric() || matches!(b, b'.' | b'_' | b'-'))
}

fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Tracing knobs (rides [`crate::http::HttpConfig`], so it stays `Copy`).
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// master switch: when false no ids are generated, no spans recorded
    pub enabled: bool,
    /// head-sampling probability in [0,1] (`--trace-sample-rate`);
    /// errors and overflow batches are always sampled regardless
    pub sample_rate: f64,
    /// ring-buffer capacity (spans evict oldest-first past it)
    pub ring: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { enabled: true, sample_rate: 0.0, ring: 256 }
    }
}

/// Per-request trace context, created at HTTP parse time and carried
/// inside `ClassifyRequest` so both connection backends reach the
/// response path with the same identity and clock.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    /// echoed back as `X-Request-Id`
    pub id: String,
    /// head sampling decision (error/overflow override it at record time)
    pub sampled: bool,
    /// request arrival (first readable byte, or handler entry)
    pub start: Instant,
    /// arrival → request decoded and validated, µs
    pub parse_us: f64,
}

/// Stage durations of one span, µs. Derived from one monotonic clock
/// chain so they never sum past the honest request latency.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpanStages {
    pub parse_us: f64,
    pub route_us: f64,
    pub queue_us: f64,
    pub batch_us: f64,
    pub forward_us: f64,
    pub respond_us: f64,
}

impl SpanStages {
    pub fn as_array(&self) -> [f64; 6] {
        [
            self.parse_us,
            self.route_us,
            self.queue_us,
            self.batch_us,
            self.forward_us,
            self.respond_us,
        ]
    }

    pub fn sum_us(&self) -> f64 {
        self.as_array().iter().sum()
    }
}

/// One recorded event: a completed classify span, or a capacity shed.
#[derive(Clone, Debug)]
pub struct TraceSpan {
    pub id: String,
    pub model: Option<String>,
    pub status: u16,
    /// head sampling decision carried from [`RequestTrace`]
    pub sampled: bool,
    /// the batch this request rode recorded overflow events
    pub overflow: bool,
    /// set for shed events (`queue-full` / `max-connections` / `draining`)
    pub shed_reason: Option<&'static str>,
    pub total_us: f64,
    pub stages: SpanStages,
    /// per-layer forward timings of the ridden batch, µs
    pub layers: Vec<(String, f64)>,
}

impl TraceSpan {
    /// Why this span is in the ring.
    pub fn reason(&self) -> &'static str {
        if self.shed_reason.is_some() {
            "shed"
        } else if self.status >= 400 {
            "error"
        } else if self.overflow {
            "overflow"
        } else {
            "sampled"
        }
    }

    pub fn to_json(&self) -> Json {
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|(name, us)| {
                json::obj(vec![("layer", json::s(name)), ("us", json::num(*us))])
            })
            .collect();
        let mut fields = vec![
            ("id", json::s(&self.id)),
            (
                "model",
                self.model.as_deref().map(json::s).unwrap_or(Json::Null),
            ),
            ("status", json::num(self.status as f64)),
            ("reason", json::s(self.reason())),
            ("total_us", json::num(self.total_us)),
            (
                "stages",
                json::obj(
                    STAGES
                        .iter()
                        .zip(self.stages.as_array())
                        .map(|(name, us)| (*name, json::num(us)))
                        .collect(),
                ),
            ),
            ("layers", Json::Arr(layers)),
        ];
        if let Some(reason) = self.shed_reason {
            fields.push(("shed_reason", json::s(reason)));
        }
        json::obj(fields)
    }
}

/// The shared collector: sampling state, the span ring, and per-stage
/// latency histograms. One instance per HTTP front-end, behind an `Arc`.
#[derive(Debug)]
pub struct Tracer {
    cfg: TraceConfig,
    /// threshold on a 53-bit uniform draw; rate 1.0 ⇒ every draw passes
    threshold: u64,
    seq: AtomicU64,
    seed: u64,
    recorded: AtomicU64,
    dropped: AtomicU64,
    ring: Mutex<VecDeque<TraceSpan>>,
    stages: Mutex<[HdrHistogram; 6]>,
}

impl Tracer {
    pub fn new(cfg: TraceConfig) -> Tracer {
        let rate = cfg.sample_rate.clamp(0.0, 1.0);
        let seed = splitmix64(
            u64::from(std::process::id())
                ^ std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .map(|d| d.as_nanos() as u64)
                    .unwrap_or(0),
        );
        Tracer {
            cfg,
            threshold: (rate * (1u64 << 53) as f64) as u64,
            seq: AtomicU64::new(0),
            seed,
            recorded: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::with_capacity(cfg.ring.max(1))),
            stages: Mutex::new(std::array::from_fn(|_| HdrHistogram::new())),
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    pub fn sample_rate(&self) -> f64 {
        self.cfg.sample_rate
    }

    pub fn capacity(&self) -> usize {
        self.cfg.ring.max(1)
    }

    /// Generate a request id (`pqs-` + 16 hex digits).
    pub fn next_id(&self) -> String {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        format!("pqs-{:016x}", splitmix64(self.seed ^ seq))
    }

    /// Head sampling decision for one request.
    pub fn should_sample(&self) -> bool {
        if self.threshold == 0 {
            return false;
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        (splitmix64(self.seed.wrapping_add(seq)) >> 11) < self.threshold
    }

    /// Record one completed classify span: stage histograms always (the
    /// `/v1/metrics` breakdown covers every request, sampled or not),
    /// the ring only when head-sampled or error/overflow forces it.
    pub fn record(&self, span: TraceSpan) {
        if !self.cfg.enabled {
            return;
        }
        {
            let mut hists = self.stages.lock().unwrap();
            for (h, us) in hists.iter_mut().zip(span.stages.as_array()) {
                h.record(us.max(0.0) as u64);
            }
        }
        if span.sampled || span.status >= 400 || span.overflow {
            self.push(span);
        } else {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a capacity shed as a trace event (always kept: sheds are
    /// errors under the always-sample-on-error policy, and the bounded
    /// ring caps what a shed storm can occupy).
    pub fn record_shed(&self, reason: &'static str) {
        if !self.cfg.enabled {
            return;
        }
        self.push(TraceSpan {
            id: self.next_id(),
            model: None,
            status: 503,
            sampled: true,
            overflow: false,
            shed_reason: Some(reason),
            total_us: 0.0,
            stages: SpanStages::default(),
            layers: Vec::new(),
        });
    }

    fn push(&self, span: TraceSpan) {
        let mut ring = self.ring.lock().unwrap();
        while ring.len() >= self.capacity() {
            ring.pop_front();
        }
        ring.push_back(span);
        self.recorded.fetch_add(1, Ordering::Relaxed);
    }

    /// Up to `n` most recent spans, oldest first.
    pub fn recent(&self, n: usize) -> Vec<TraceSpan> {
        let ring = self.ring.lock().unwrap();
        let skip = ring.len().saturating_sub(n);
        ring.iter().skip(skip).cloned().collect()
    }

    /// (spans recorded into the ring, completed spans not sampled)
    pub fn counts(&self) -> (u64, u64) {
        (self.recorded.load(Ordering::Relaxed), self.dropped.load(Ordering::Relaxed))
    }

    /// Per-stage histogram clones, in [`STAGES`] order.
    pub fn stage_hists(&self) -> Vec<(&'static str, HdrHistogram)> {
        let hists = self.stages.lock().unwrap();
        STAGES.iter().zip(hists.iter()).map(|(n, h)| (*n, h.clone())).collect()
    }

    /// The `GET /v1/trace?n=K` body.
    pub fn trace_json(&self, n: usize) -> Json {
        let (recorded, dropped) = self.counts();
        let spans: Vec<Json> = self.recent(n).iter().map(TraceSpan::to_json).collect();
        json::obj(vec![
            ("enabled", Json::Bool(self.cfg.enabled)),
            ("sample_rate", json::num(self.cfg.sample_rate)),
            ("capacity", json::num(self.capacity() as f64)),
            ("recorded", json::num(recorded as f64)),
            ("dropped", json::num(dropped as f64)),
            ("spans", Json::Arr(spans)),
        ])
    }

    /// The `trace` section of `GET /v1/metrics`: per-stage quantiles.
    pub fn stages_json(&self) -> Json {
        let (recorded, dropped) = self.counts();
        let stages: Vec<(&str, Json)> = self
            .stage_hists()
            .into_iter()
            .map(|(name, h)| {
                (
                    name,
                    json::obj(vec![
                        ("count", json::num(h.count() as f64)),
                        ("p50_us", json::num(h.value_at(0.50) as f64)),
                        ("p99_us", json::num(h.value_at(0.99) as f64)),
                        ("p999_us", json::num(h.value_at(0.999) as f64)),
                        ("max_us", json::num(h.max() as f64)),
                    ]),
                )
            })
            .collect();
        json::obj(vec![
            ("enabled", Json::Bool(self.cfg.enabled)),
            ("sample_rate", json::num(self.cfg.sample_rate)),
            ("recorded", json::num(recorded as f64)),
            ("dropped", json::num(dropped as f64)),
            ("stages", json::obj(stages)),
        ])
    }
}

// ---- accumulator headroom -------------------------------------------------

/// Running per-layer headroom counters for one model.
#[derive(Clone, Debug)]
pub struct LayerHeadroom {
    pub layer: String,
    /// accumulator width the layer is serving at (plan / operating point)
    pub planned_bits: u32,
    /// widest per-dot requirement observed (`OverflowStats::bits_hist`)
    pub max_required_bits: u32,
    /// `planned - max_required`, minimum over every observed batch ×
    /// operating point — negative means a dot needed more than the plan
    pub min_headroom_bits: i64,
    pub dots: u64,
    /// dots with overflow events under the serving policy
    pub overflow_dots: u64,
    /// dots within 1 bit of the planned width (required ≥ planned − 1)
    pub near_saturation_dots: u64,
    pub batches: u64,
}

impl LayerHeadroom {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("layer", json::s(&self.layer)),
            ("planned_bits", json::num(self.planned_bits as f64)),
            ("max_required_bits", json::num(self.max_required_bits as f64)),
            ("min_headroom_bits", json::num(self.min_headroom_bits as f64)),
            ("dots", json::num(self.dots as f64)),
            ("overflow_dots", json::num(self.overflow_dots as f64)),
            ("near_saturation_dots", json::num(self.near_saturation_dots as f64)),
            ("batches", json::num(self.batches as f64)),
        ])
    }
}

/// JSON rows for a headroom snapshot (`GET /v1/models` per-model field).
pub fn headroom_json(layers: &[LayerHeadroom]) -> Json {
    Json::Arr(layers.iter().map(LayerHeadroom::to_json).collect())
}

/// Per-model headroom accumulator, updated once per served batch from
/// the worker's [`OverflowReport`] — one mutex lock per batch, never per
/// request. Lives on the serving `Server` so counters reset with the
/// incarnation (evict/reload starts a fresh observation window).
#[derive(Debug, Default)]
pub struct ModelHeadroom {
    layers: Mutex<BTreeMap<String, LayerHeadroom>>,
}

impl ModelHeadroom {
    pub fn new() -> ModelHeadroom {
        ModelHeadroom::default()
    }

    /// Fold one batch: `widths` are the effective per-layer accumulator
    /// bits the batch served at (`Engine::effective_layer_bits`);
    /// `default_bits` covers layers the width table does not name.
    pub fn record(&self, report: &OverflowReport, widths: &[(String, u32)], default_bits: u32) {
        let mut layers = self.layers.lock().unwrap();
        for (name, stats) in &report.layers {
            if stats.dots == 0 && stats.hist_dots() == 0 {
                continue;
            }
            let planned = widths
                .iter()
                .find(|(n, _)| n == name)
                .map(|&(_, b)| b)
                .unwrap_or(default_bits);
            let required = stats.max_required_bits();
            let headroom = planned as i64 - required as i64;
            // required ≥ planned − 1  ⇔  does not fit planned − 2 bits
            let near = stats.dots_over_width(planned.saturating_sub(2));
            let row = layers.entry(name.clone()).or_insert_with(|| LayerHeadroom {
                layer: name.clone(),
                planned_bits: planned,
                max_required_bits: 0,
                min_headroom_bits: i64::MAX,
                dots: 0,
                overflow_dots: 0,
                near_saturation_dots: 0,
                batches: 0,
            });
            row.planned_bits = planned;
            row.max_required_bits = row.max_required_bits.max(required);
            row.min_headroom_bits = row.min_headroom_bits.min(headroom);
            row.dots += stats.dots;
            row.overflow_dots += stats.policy_event_dots;
            row.near_saturation_dots += near;
            row.batches += 1;
        }
    }

    pub fn snapshot(&self) -> Vec<LayerHeadroom> {
        self.layers.lock().unwrap().values().cloned().collect()
    }
}

// ---- Prometheus text exposition -------------------------------------------

/// Hand-rolled Prometheus text format 0.0.4 encoder. Serve the result
/// with `Content-Type: text/plain; version=0.0.4`.
#[derive(Debug, Default)]
pub struct PromText {
    out: String,
}

fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

impl PromText {
    pub fn new() -> PromText {
        PromText::default()
    }

    /// Emit the `# HELP` / `# TYPE` pair for a metric family.
    pub fn family(&mut self, name: &str, kind: &str, help: &str) {
        self.out.push_str(&format!("# HELP {name} {}\n", escape_help(help)));
        self.out.push_str(&format!("# TYPE {name} {kind}\n"));
    }

    /// Emit one sample line, optionally labeled.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(&format!("{k}=\"{}\"", escape_label(v)));
            }
            self.out.push('}');
        }
        self.out.push(' ');
        self.out.push_str(&fmt_value(value));
        self.out.push('\n');
    }

    /// Family header + one unlabeled sample.
    pub fn metric(&mut self, name: &str, kind: &str, help: &str, value: f64) {
        self.family(name, kind, help);
        self.sample(name, &[], value);
    }

    /// Render an [`HdrHistogram`] as a Prometheus histogram: cumulative
    /// `le` buckets from the HDR layout (exact — every recorded value ≤
    /// the bucket's upper bound is counted), `+Inf`, `_count`, and a
    /// `_sum` reconstructed from bucket lower bounds (conservative,
    /// never overstated — the HDR layout does not keep an exact sum).
    pub fn histogram(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        h: &HdrHistogram,
    ) {
        self.family(name, "histogram", help);
        self.histogram_rows(name, labels, h);
    }

    /// Sample rows of an [`HdrHistogram`] without the family header —
    /// for histogram families with several label sets (one stage each),
    /// where `# TYPE` must appear exactly once: call [`Self::family`]
    /// once, then this per label set.
    pub fn histogram_rows(&mut self, name: &str, labels: &[(&str, &str)], h: &HdrHistogram) {
        let bucket = format!("{name}_bucket");
        for (hi, cum) in h.cumulative() {
            let le = hi.to_string();
            let mut row: Vec<(&str, &str)> = labels.to_vec();
            row.push(("le", &le));
            self.sample(&bucket, &row, cum as f64);
        }
        let mut inf_row: Vec<(&str, &str)> = labels.to_vec();
        inf_row.push(("le", "+Inf"));
        self.sample(&bucket, &inf_row, h.count() as f64);
        let sum: f64 = h.buckets().iter().map(|&(lo, c)| lo as f64 * c as f64).sum();
        self.sample(&format!("{name}_sum"), labels, sum);
        self.sample(&format!("{name}_count"), labels, h.count() as f64);
    }

    pub fn finish(self) -> String {
        self.out
    }
}

// ---- exposition grammar checker -------------------------------------------

fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Parse one sample line, returning the metric name. Grammar (text
/// format 0.0.4): `name ['{' label '=' '"' escaped '"' [',' ...] '}']
/// value [timestamp]`, value a float or `+Inf`/`-Inf`/`NaN`.
fn parse_sample_line(line: &str) -> Result<String, String> {
    let name_end = line
        .find(|c: char| c == '{' || c == ' ')
        .ok_or_else(|| format!("sample without value: {line:?}"))?;
    let name = &line[..name_end];
    if !valid_metric_name(name) {
        return Err(format!("bad metric name {name:?}"));
    }
    let mut rest = &line[name_end..];
    if let Some(after_brace) = rest.strip_prefix('{') {
        let close = after_brace
            .find('}')
            .ok_or_else(|| format!("unterminated label set: {line:?}"))?;
        let labels = &after_brace[..close];
        rest = &after_brace[close + 1..];
        for pair in labels.split(',') {
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| format!("label without '=': {pair:?}"))?;
            if !valid_label_name(k) {
                return Err(format!("bad label name {k:?}"));
            }
            let inner = v
                .strip_prefix('"')
                .and_then(|v| v.strip_suffix('"'))
                .ok_or_else(|| format!("unquoted label value {v:?}"))?;
            // reject raw quotes/backslashes that are not escape pairs
            let mut bytes = inner.bytes();
            while let Some(b) = bytes.next() {
                match b {
                    b'\\' => match bytes.next() {
                        Some(b'\\') | Some(b'"') | Some(b'n') => {}
                        other => return Err(format!("bad escape {other:?} in {pair:?}")),
                    },
                    b'"' => return Err(format!("unescaped quote in {pair:?}")),
                    _ => {}
                }
            }
        }
    }
    let rest = rest
        .strip_prefix(' ')
        .ok_or_else(|| format!("missing space before value: {line:?}"))?;
    let mut parts = rest.split(' ');
    let value = parts.next().unwrap_or("");
    let value_ok = matches!(value, "+Inf" | "-Inf" | "NaN") || value.parse::<f64>().is_ok();
    if !value_ok {
        return Err(format!("bad sample value {value:?}"));
    }
    if let Some(ts) = parts.next() {
        if ts.parse::<i64>().is_err() {
            return Err(format!("bad timestamp {ts:?}"));
        }
    }
    if parts.next().is_some() {
        return Err(format!("trailing tokens on sample line: {line:?}"));
    }
    Ok(name.to_string())
}

/// Check a full scrape body against the exposition grammar: every line
/// must be a well-formed `# HELP`/`# TYPE`/comment or sample, `TYPE`
/// declared at most once per family and *before* its samples, histogram
/// suffixes (`_bucket`/`_sum`/`_count`) tied to a histogram family
/// (`_sum`/`_count` also to a summary), and the body
/// newline-terminated. Used by the unit tests, the wire tests and the
/// bench observability gate.
pub fn validate_exposition(text: &str) -> Result<(), String> {
    if text.is_empty() {
        return Err("empty exposition".to_string());
    }
    if !text.ends_with('\n') {
        return Err("exposition must end with a newline".to_string());
    }
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        let ln = i + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split(' ').next().unwrap_or("");
            if !valid_metric_name(name) {
                return Err(format!("line {ln}: bad HELP metric name {name:?}"));
            }
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split(' ');
            let name = parts.next().unwrap_or("");
            let kind = parts.next().unwrap_or("");
            if !valid_metric_name(name) {
                return Err(format!("line {ln}: bad TYPE metric name {name:?}"));
            }
            if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                return Err(format!("line {ln}: bad metric type {kind:?}"));
            }
            if parts.next().is_some() {
                return Err(format!("line {ln}: trailing tokens after TYPE"));
            }
            if types.insert(name.to_string(), kind.to_string()).is_some() {
                return Err(format!("line {ln}: duplicate TYPE for {name}"));
            }
        } else if line.starts_with('#') {
            continue;
        } else {
            let name = parse_sample_line(line).map_err(|e| format!("line {ln}: {e}"))?;
            // a sample belongs to its family: exact name, or the
            // histogram suffixes of a declared histogram family
            let family = ["_bucket", "_sum", "_count"]
                .iter()
                .find_map(|suf| {
                    name.strip_suffix(suf).filter(|base| {
                        let kind = types.get(*base).map(String::as_str);
                        match *suf {
                            "_bucket" => kind == Some("histogram"),
                            _ => matches!(kind, Some("histogram") | Some("summary")),
                        }
                    })
                })
                .unwrap_or(&name);
            if !types.contains_key(family) {
                return Err(format!("line {ln}: sample {name} before its TYPE declaration"));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::overflow::OverflowStats;

    fn span(id: &str, status: u16, sampled: bool, overflow: bool) -> TraceSpan {
        TraceSpan {
            id: id.to_string(),
            model: Some("m".to_string()),
            status,
            sampled,
            overflow,
            shed_reason: None,
            total_us: 100.0,
            stages: SpanStages { parse_us: 1.0, forward_us: 50.0, ..Default::default() },
            layers: vec![("fc".to_string(), 50.0)],
        }
    }

    #[test]
    fn request_id_validation() {
        assert!(valid_request_id("abc-123_X.Y"));
        assert!(valid_request_id("a"));
        assert!(valid_request_id(&"x".repeat(MAX_REQUEST_ID_LEN)));
        assert!(!valid_request_id(""));
        assert!(!valid_request_id(&"x".repeat(MAX_REQUEST_ID_LEN + 1)));
        assert!(!valid_request_id("has space"));
        assert!(!valid_request_id("newline\n"));
        assert!(!valid_request_id("quote\""));
        assert!(!valid_request_id("héllo"));
    }

    #[test]
    fn sampling_rates_zero_and_one() {
        let never = Tracer::new(TraceConfig { sample_rate: 0.0, ..Default::default() });
        let always = Tracer::new(TraceConfig { sample_rate: 1.0, ..Default::default() });
        for _ in 0..256 {
            assert!(!never.should_sample());
            assert!(always.should_sample());
        }
    }

    #[test]
    fn generated_ids_are_unique_and_valid() {
        let t = Tracer::new(TraceConfig::default());
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..1000 {
            let id = t.next_id();
            assert!(valid_request_id(&id), "{id}");
            assert!(seen.insert(id), "duplicate generated id");
        }
    }

    #[test]
    fn ring_evicts_oldest_first() {
        let t = Tracer::new(TraceConfig { ring: 4, sample_rate: 1.0, ..Default::default() });
        for i in 0..7 {
            t.record(span(&format!("s{i}"), 200, true, false));
        }
        let recent = t.recent(10);
        let ids: Vec<&str> = recent.iter().map(|s| s.id.as_str()).collect();
        assert_eq!(ids, ["s3", "s4", "s5", "s6"], "oldest evicted, order kept");
        let last2: Vec<String> = t.recent(2).iter().map(|s| s.id.clone()).collect();
        assert_eq!(last2, ["s5", "s6"]);
        let (recorded, dropped) = t.counts();
        assert_eq!((recorded, dropped), (7, 0));
    }

    #[test]
    fn errors_and_overflow_bypass_sampling() {
        let t = Tracer::new(TraceConfig { sample_rate: 0.0, ..Default::default() });
        t.record(span("ok", 200, false, false)); // dropped
        t.record(span("err", 504, false, false)); // kept: error
        t.record(span("ovf", 200, false, true)); // kept: overflow
        t.record_shed("queue-full"); // kept: shed
        let spans = t.recent(10);
        let reasons: Vec<&str> = spans.iter().map(|s| s.reason()).collect();
        assert_eq!(reasons, ["error", "overflow", "shed"]);
        assert_eq!(spans[2].shed_reason, Some("queue-full"));
        assert_eq!(spans[2].status, 503);
        let (recorded, dropped) = t.counts();
        assert_eq!((recorded, dropped), (3, 1));
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::new(TraceConfig { enabled: false, sample_rate: 1.0, ..Default::default() });
        t.record(span("a", 500, true, true));
        t.record_shed("draining");
        assert!(t.recent(10).is_empty());
        assert_eq!(t.counts(), (0, 0));
        assert!(t.stage_hists().iter().all(|(_, h)| h.count() == 0));
    }

    #[test]
    fn stage_histograms_cover_every_request() {
        let t = Tracer::new(TraceConfig { sample_rate: 0.0, ..Default::default() });
        for _ in 0..10 {
            t.record(span("x", 200, false, false)); // unsampled, still histogrammed
        }
        let hists = t.stage_hists();
        assert_eq!(hists.len(), STAGES.len());
        for (name, h) in &hists {
            assert_eq!(h.count(), 10, "stage {name}");
        }
        let j = t.stages_json();
        let forward = j.get("stages").and_then(|s| s.get("forward")).unwrap();
        assert_eq!(forward.get("count").and_then(Json::as_usize), Some(10));
        assert_eq!(forward.get("max_us").and_then(Json::as_f64), Some(50.0));
    }

    #[test]
    fn trace_json_shape() {
        let t = Tracer::new(TraceConfig { sample_rate: 1.0, ring: 8, ..Default::default() });
        t.record(span("a", 200, true, false));
        let j = t.trace_json(5);
        assert_eq!(j.get("capacity").and_then(Json::as_usize), Some(8));
        let spans = j.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans.len(), 1);
        let s = &spans[0];
        assert_eq!(s.get("id").and_then(Json::as_str), Some("a"));
        assert_eq!(s.get("reason").and_then(Json::as_str), Some("sampled"));
        let stages = s.get("stages").unwrap();
        for name in STAGES {
            assert!(stages.get(name).is_some(), "stage {name} missing");
        }
        let layers = s.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers[0].get("layer").and_then(Json::as_str), Some("fc"));
    }

    #[test]
    fn span_stage_sum_never_exceeds_total() {
        let s = span("a", 200, true, false);
        assert!(s.stages.sum_us() <= s.total_us);
    }

    #[test]
    fn headroom_tracks_planned_vs_required() {
        let hr = ModelHeadroom::new();
        let mut report = OverflowReport::default();
        {
            let s: &mut OverflowStats = report.layer_mut("fc");
            s.dots = 100;
            for _ in 0..90 {
                s.record_required_bits(12);
            }
            for _ in 0..10 {
                s.record_required_bits(15);
            }
            s.policy_event_dots = 3;
        }
        hr.record(&report, &[("fc".to_string(), 16)], 32);
        let snap = hr.snapshot();
        assert_eq!(snap.len(), 1);
        let row = &snap[0];
        assert_eq!(row.layer, "fc");
        assert_eq!(row.planned_bits, 16);
        assert_eq!(row.max_required_bits, 15);
        assert_eq!(row.min_headroom_bits, 1);
        // within 1 bit of the 16-bit plan: the 10 dots needing 15 bits
        assert_eq!(row.near_saturation_dots, 10);
        assert_eq!(row.overflow_dots, 3);
        assert_eq!(row.dots, 100);
        assert_eq!(row.batches, 1);

        // a second batch at a wider operating point must not lose the min
        hr.record(&report, &[("fc".to_string(), 20)], 32);
        let row = &hr.snapshot()[0];
        assert_eq!(row.planned_bits, 20, "latest operating point");
        assert_eq!(row.min_headroom_bits, 1, "minimum survives wider batches");
        assert_eq!(row.batches, 2);
        // 20-bit plan: nothing within 1 bit
        assert_eq!(row.near_saturation_dots, 10);
    }

    #[test]
    fn headroom_default_width_covers_unplanned_layers() {
        let hr = ModelHeadroom::new();
        let mut report = OverflowReport::default();
        report.layer_mut("conv0").dots = 1;
        report.layer_mut("conv0").record_required_bits(10);
        hr.record(&report, &[], 16);
        let row = &hr.snapshot()[0];
        assert_eq!(row.planned_bits, 16);
        assert_eq!(row.min_headroom_bits, 6);
    }

    #[test]
    fn prometheus_output_passes_the_grammar() {
        let mut p = PromText::new();
        p.metric("pqs_http_accepted_total", "counter", "connections accepted", 42.0);
        p.family("pqs_http_shed_total", "counter", "connections shed by reason");
        p.sample("pqs_http_shed_total", &[("reason", "queue-full")], 1.0);
        p.sample("pqs_http_shed_total", &[("reason", "max-connections")], 0.0);
        p.family("pqs_headroom_min_bits", "gauge", "min accumulator headroom");
        p.sample(
            "pqs_headroom_min_bits",
            &[("model", "cnn \"v2\"\\prod"), ("layer", "fc")],
            3.0,
        );
        let mut h = HdrHistogram::new();
        for v in [3u64, 70, 900, 12_345] {
            h.record(v);
        }
        p.histogram("pqs_stage_forward_us", "forward stage latency", &[], &h);
        let text = p.finish();
        validate_exposition(&text).expect("generated exposition parses");
        assert!(text.contains("pqs_stage_forward_us_bucket{le=\"+Inf\"} 4"));
        assert!(text.contains("pqs_stage_forward_us_count 4"));
        assert!(text.contains("le=\"3\"") || text.contains("le=\"4\""));
        // escaped label value round-trips the grammar
        assert!(text.contains("model=\"cnn \\\"v2\\\"\\\\prod\""));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_exact() {
        let mut h = HdrHistogram::new();
        for v in 0..100u64 {
            h.record(v);
        }
        let mut p = PromText::new();
        p.histogram("m", "h", &[], &h);
        let text = p.finish();
        validate_exposition(&text).expect("parses");
        // cumulative counts are non-decreasing down the bucket list
        let mut last = 0.0;
        for line in text.lines().filter(|l| l.starts_with("m_bucket")) {
            let v: f64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "non-decreasing: {line}");
            last = v;
        }
        assert_eq!(last, 100.0, "+Inf bucket holds every sample");
    }

    #[test]
    fn grammar_rejects_malformed_lines() {
        for bad in [
            "no_newline_terminator 1",                         // missing trailing \n
            "# TYPE m wibble\nm 1\n",                          // unknown type
            "# TYPE m counter\n# TYPE m counter\nm 1\n",       // duplicate TYPE
            "m 1\n",                                           // sample before TYPE
            "# TYPE m counter\nm one\n",                       // non-numeric value
            "# TYPE m counter\nm{l=unquoted} 1\n",             // unquoted label
            "# TYPE m counter\nm{l=\"a\"b\"} 1\n",             // unescaped quote
            "# TYPE m counter\nm{0l=\"a\"} 1\n",               // bad label name
            "# TYPE m counter\n9m 1\n",                        // bad metric name
            "# TYPE m counter\nm 1 2 3\n",                     // trailing tokens
            "# TYPE m histogram\nother_bucket{le=\"1\"} 1\n",  // suffix of undeclared family
        ] {
            assert!(validate_exposition(bad).is_err(), "accepted: {bad:?}");
        }
        // timestamps are part of the grammar
        validate_exposition("# TYPE m counter\nm 1 1700000000\n").expect("timestamp ok");
        validate_exposition("# HELP m some help text\n# TYPE m gauge\nm{a=\"b\\n\"} -1.5\n")
            .expect("escaped newline ok");
    }
}
