//! Dataset loading + batching (DESIGN.md S16).
//!
//! `loader` reads the PQSD binaries exported by `python/compile/datasets.py`
//! so both layers evaluate byte-identical inputs; `batcher` iterates them.

pub mod loader;

pub use loader::Dataset;

/// Iterator over contiguous batches of a dataset.
pub struct Batches<'a> {
    ds: &'a Dataset,
    batch: usize,
    pos: usize,
}

impl<'a> Batches<'a> {
    pub fn new(ds: &'a Dataset, batch: usize) -> Self {
        assert!(batch > 0);
        Batches { ds, batch, pos: 0 }
    }
}

impl<'a> Iterator for Batches<'a> {
    /// (images f32 flattened [b, c*h*w], labels, global start index)
    type Item = (Vec<f32>, &'a [u8], usize);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.ds.n {
            return None;
        }
        let b = self.batch.min(self.ds.n - self.pos);
        let stride = self.ds.c * self.ds.h * self.ds.w;
        let imgs = self.ds.images_f32(self.pos, b);
        let labels = &self.ds.labels[self.pos..self.pos + b];
        let start = self.pos;
        self.pos += b;
        debug_assert_eq!(imgs.len(), b * stride);
        Some((imgs, labels, start))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_ds() -> Dataset {
        Dataset {
            n: 5,
            c: 1,
            h: 2,
            w: 2,
            pixels: (0..20).map(|i| (i * 12) as u8).collect(),
            labels: vec![0, 1, 2, 3, 4],
        }
    }

    #[test]
    fn batches_cover_all() {
        let ds = tiny_ds();
        let mut seen = 0;
        for (imgs, labels, start) in Batches::new(&ds, 2) {
            assert_eq!(imgs.len(), labels.len() * 4);
            assert_eq!(start, seen);
            seen += labels.len();
        }
        assert_eq!(seen, 5);
    }

    #[test]
    fn last_batch_ragged() {
        let ds = tiny_ds();
        let sizes: Vec<usize> = Batches::new(&ds, 2).map(|(_, l, _)| l.len()).collect();
        assert_eq!(sizes, vec![2, 2, 1]);
    }
}
