//! PQSD dataset container reader (written by `python/compile/datasets.py`).
//!
//! Layout: magic `PQSD1\0\0\0`, u32le n/c/h/w, n*c*h*w u8 pixels, n u8
//! labels. Pixels map to f32 as `v / 255.0` — identical to what python
//! training saw after its save/reload round-trip.

use anyhow::{bail, Context, Result};
use std::path::Path;

pub const MAGIC: &[u8; 8] = b"PQSD1\x00\x00\x00";

/// An in-memory image-classification dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub pixels: Vec<u8>,
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Dataset> {
        let raw = std::fs::read(path.as_ref())
            .with_context(|| format!("reading dataset {:?}", path.as_ref()))?;
        if raw.len() < 24 || &raw[0..8] != MAGIC {
            bail!("bad PQSD magic in {:?}", path.as_ref());
        }
        let rd = |o: usize| u32::from_le_bytes(raw[o..o + 4].try_into().unwrap()) as usize;
        let (n, c, h, w) = (rd(8), rd(12), rd(16), rd(20));
        let npix = n * c * h * w;
        if raw.len() != 24 + npix + n {
            bail!(
                "PQSD size mismatch: have {} want {}",
                raw.len(),
                24 + npix + n
            );
        }
        Ok(Dataset {
            n,
            c,
            h,
            w,
            pixels: raw[24..24 + npix].to_vec(),
            labels: raw[24 + npix..].to_vec(),
        })
    }

    /// Flattened image size.
    pub fn dim(&self) -> usize {
        self.c * self.h * self.w
    }

    /// Decode `count` images starting at `start` to f32 in [0,1].
    pub fn images_f32(&self, start: usize, count: usize) -> Vec<f32> {
        let stride = self.dim();
        let a = start * stride;
        let b = (start + count) * stride;
        self.pixels[a..b].iter().map(|&v| v as f32 / 255.0).collect()
    }

    /// Class frequency histogram (10 classes assumed by the tasks here).
    pub fn class_histogram(&self) -> Vec<usize> {
        let k = *self.labels.iter().max().unwrap_or(&0) as usize + 1;
        let mut h = vec![0usize; k];
        for &l in &self.labels {
            h[l as usize] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_tiny(path: &Path) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(MAGIC).unwrap();
        for v in [2u32, 1, 2, 2] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        f.write_all(&[0, 64, 128, 255, 10, 20, 30, 40]).unwrap(); // pixels
        f.write_all(&[3, 7]).unwrap(); // labels
    }

    #[test]
    fn load_roundtrip() {
        let dir = std::env::temp_dir().join("pqs_test_loader");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("tiny.bin");
        write_tiny(&p);
        let ds = Dataset::load(&p).unwrap();
        assert_eq!((ds.n, ds.c, ds.h, ds.w), (2, 1, 2, 2));
        assert_eq!(ds.labels, vec![3, 7]);
        let img = ds.images_f32(0, 1);
        assert_eq!(img[0], 0.0);
        assert_eq!(img[3], 1.0);
        assert!((img[1] - 64.0 / 255.0).abs() < 1e-7);
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir().join("pqs_test_loader");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"PQSD1\x00\x00\x00\x01").unwrap();
        assert!(Dataset::load(&p).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("pqs_test_loader");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("magic.bin");
        std::fs::write(&p, vec![0u8; 64]).unwrap();
        assert!(Dataset::load(&p).is_err());
    }

    #[test]
    fn histogram() {
        let ds = Dataset {
            n: 4,
            c: 1,
            h: 1,
            w: 1,
            pixels: vec![0; 4],
            labels: vec![1, 1, 2, 0],
        };
        assert_eq!(ds.class_histogram(), vec![1, 2, 1]);
    }
}
