//! Accumulator-budget projection + Pareto sweep (the inverse of
//! `crate::plan`).
//!
//! The planner (`plan::analytic`) *measures* a fixed model: given weights,
//! it reports the minimal accumulator width with a no-persistent-overflow
//! guarantee. This module runs the other direction — given a **width
//! budget**, it *makes the budget true* by editing the quantized weights,
//! then searches the (budget × N:M sparsity) grid for the accuracy/width
//! Pareto frontier, fig5-style, through the serving stack.
//!
//! # Projection math
//!
//! [`project`] enforces `analytic_layer_bits(layer, policy) <= budget` for
//! every q-layer, row by row. Two moves, applied in order:
//!
//! 1. **N:M sparsity knob** (optional): per group of `m` consecutive
//!    weights along the contraction axis, keep the `n` largest-magnitude
//!    entries (ties break to the lower index) and zero the rest — the
//!    paper's prune step. Zeroing a weight removes its term from the
//!    analytic bound, so tighter budgets are met by sparsity first.
//! 2. **Integer soft-thresholding**: for each row `w`, find the smallest
//!    integer `tau >= 0` such that the shrunk row
//!    `w'_j = sign(w_j) * max(|w_j| - tau, 0)` satisfies the bound, i.e.
//!    `plan::row_range(w', window, policy) ⊆ acc_range(budget)`. This is
//!    the integer-lattice analogue of the euclidean projection of the row
//!    onto an ℓ1 ball (soft-thresholding IS that projection's closed
//!    form), restricted to the thresholds where the analytic bound — a
//!    weighted ℓ1 norm of the row for final-sum policies — is what
//!    shrinks. Small weights are zeroed before large ones are clipped, so
//!    the A2Q-style "scale/clip rows" lands as "sparsify, then shave".
//!
//! Every per-weight magnitude is non-increasing in `tau`, so both the
//! final-sum bound and the `Clip`/`Wrap` prefix bound shrink termwise:
//! the fitting predicate is monotone and the binary search for the
//! minimal `tau` is exact. `tau = |w|_max` zeroes the row (bound `(0,0)`,
//! 2 bits), so any `budget >= 2` is feasible. The projection is
//! **idempotent** — a row that already fits takes `tau = 0`, and the N:M
//! step keeps exactly the surviving nonzeros — and **deterministic**, so
//! the Python exporter (`python/compile/plan.py`) reproduces it
//! bit-for-bit (pinned by known-answer tests on both sides).
//!
//! The projected model carries an embedded [`AccumPlan`] (planner
//! `Analytic`, per-layer `acc_bits` = post-projection analytic width ≤
//! budget) and fresh layer checksums, so `PqswModel::save` writes a
//! version-2 `.pqsw` that the existing router/serving path loads and
//! enforces unchanged.
//!
//! # Grid semantics
//!
//! [`pareto`] walks the full cartesian grid `budgets × nm`: each point
//! clones the model, projects it to that (budget, N:M) pair, and
//! evaluates accuracy through [`EvalService`] (all candidates share one
//! [`ComputePool`]). The **baseline** is the unprojected model, plan
//! stripped, at 32-bit accumulators. When `SweepConfig::budgets` is
//! empty the grid derives from the unprojected model's widest analytic
//! layer `M` as `[M, M-1, M-2]` — the no-op point plus two narrowing
//! steps. A point is **dominated** when another point has width ≤ its
//! width and accuracy ≥ its accuracy, strictly better in at least one;
//! the non-dominated rest is the Pareto frontier.
//!
//! Accuracy needs labels; [`reference_dataset`] builds a seeded synthetic
//! set labeled by the *unprojected* model at exact/32-bit arithmetic, so
//! baseline accuracy is 1.0 by construction and a candidate's accuracy
//! reads as agreement with the wide-accumulator reference. Callers with
//! real datasets pass them instead.
//!
//! # JSON schema (the `pqs sweep` output and the bench `sweep` section)
//!
//! ```text
//! {"tag": "sweep", "v": 1,
//!  "model": str, "policy": str, "samples": int, "tolerance": float,
//!  "baseline": {"acc_bits": 32, "accuracy": float,
//!               "analytic_bits_max": int},
//!  "points": [{"budget": int, "nm": "dense" | "n:m",
//!              "width_bits": int,        // enforced max plan width
//!              "accuracy": float,
//!              "accuracy_ok": bool,      // >= baseline - tolerance
//!              "budget_ok": bool,        // width_bits <= budget
//!              "persistent_dots": int,   // over the whole eval
//!              "policy_event_dots": int,
//!              "sparsity": float, "tau_max": int,
//!              "pruned": int, "clipped": int,
//!              "dominated": bool, "eval_ms": float}, ...],
//!  "frontier": [[width_bits, accuracy], ...]}  // non-dominated, width asc
//! ```

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::accum::{self, Policy};
use crate::coordinator::EvalService;
use crate::data::Dataset;
use crate::formats::pqsw::{PqswModel, Weights};
use crate::nn::engine::{Engine, EngineConfig};
use crate::nn::QLayer;
use crate::plan::{
    analytic_layer_bits, centered_input_range, max_row_nnz, row_range, AccumPlan, LayerPlan,
    PlannerKind,
};
use crate::util::json::{self, Json};
use crate::util::pool::{self, ComputePool};
use crate::util::rng::Pcg32;

/// Widest supported projection budget: `accum::acc_range` shifts `1i64`
/// by `budget - 1`, and 62 already exceeds any real accumulator.
pub const MAX_BUDGET_BITS: u32 = 62;

/// An N:M structured-sparsity spec: keep the `keep` largest-magnitude
/// weights per group of `m` consecutive weights.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NmSpec {
    pub keep: usize,
    pub m: usize,
}

impl NmSpec {
    /// Parse one grid token: `"dense"` (no pruning) or `"N:M"`.
    pub fn parse(s: &str) -> Result<Option<NmSpec>> {
        let t = s.trim();
        if t.eq_ignore_ascii_case("dense") {
            return Ok(None);
        }
        let (n, m) = t
            .split_once(':')
            .ok_or_else(|| anyhow!("N:M spec {t:?}: expected \"dense\" or \"N:M\" (e.g. 2:4)"))?;
        let keep: usize = n.trim().parse().map_err(|_| anyhow!("N:M spec {t:?}: bad N"))?;
        let m: usize = m.trim().parse().map_err(|_| anyhow!("N:M spec {t:?}: bad M"))?;
        if keep < 1 || m < 1 || keep > m {
            bail!("N:M spec {t:?}: need 1 <= N <= M");
        }
        Ok(Some(NmSpec { keep, m }))
    }

    pub fn label(nm: Option<NmSpec>) -> String {
        match nm {
            Some(s) => format!("{}:{}", s.keep, s.m),
            None => "dense".to_string(),
        }
    }
}

/// Knobs for a single projection (see the module docs).
#[derive(Clone, Copy, Debug)]
pub struct ProjectConfig {
    /// accumulation policy whose analytic bound the budget constrains
    pub policy: Policy,
    /// per-layer accumulator width to make true (>= 2)
    pub budget: u32,
    /// optional N:M sparsity applied before thresholding
    pub nm: Option<NmSpec>,
}

/// Per-layer record of what [`project`] did.
#[derive(Clone, Debug)]
pub struct LayerProjection {
    pub name: String,
    pub k: usize,
    /// analytic width before / after projection
    pub bits_before: u32,
    pub bits_after: u32,
    /// largest soft-threshold any row of the layer needed
    pub tau_max: u32,
    /// weights zeroed by the N:M knob
    pub pruned: usize,
    /// weights changed by soft-thresholding (shrunk or zeroed)
    pub clipped: usize,
}

/// What [`project`] did to the whole model.
#[derive(Clone, Debug)]
pub struct ProjectionReport {
    pub policy: Policy,
    pub budget: u32,
    pub nm: Option<NmSpec>,
    pub layers: Vec<LayerProjection>,
    pub sparsity_before: f64,
    pub sparsity_after: f64,
}

impl ProjectionReport {
    /// Did the projection edit any weight at all?
    pub fn changed(&self) -> bool {
        self.layers.iter().any(|l| l.pruned > 0 || l.clipped > 0)
    }

    pub fn tau_max(&self) -> u32 {
        self.layers.iter().map(|l| l.tau_max).max().unwrap_or(0)
    }

    pub fn pruned(&self) -> usize {
        self.layers.iter().map(|l| l.pruned).sum()
    }

    pub fn clipped(&self) -> usize {
        self.layers.iter().map(|l| l.clipped).sum()
    }

    /// The per-layer table `pqs project` prints.
    pub fn print(&self) {
        println!(
            "project: policy={} budget={} nm={} sparsity {:.3} -> {:.3}",
            self.policy.name(),
            self.budget,
            NmSpec::label(self.nm),
            self.sparsity_before,
            self.sparsity_after,
        );
        println!(
            "{:<14} {:>8} {:>8} {:>7} {:>5} {:>8} {:>8}",
            "layer", "k", "before", "after", "tau", "pruned", "clipped"
        );
        for l in &self.layers {
            println!(
                "{:<14} {:>8} {:>8} {:>7} {:>5} {:>8} {:>8}",
                l.name, l.k, l.bits_before, l.bits_after, l.tau_max, l.pruned, l.clipped
            );
        }
    }
}

/// Soft-threshold one weight toward zero by `tau` magnitude units.
#[inline]
fn soft(v: i8, tau: u32) -> i8 {
    let mag = (v as i32).abs() - tau as i32;
    if mag <= 0 {
        0
    } else if v > 0 {
        mag as i8
    } else {
        (-mag) as i8
    }
}

/// Keep the `keep` largest-magnitude weights per group of `m` consecutive
/// entries of `row` (ties break to the lower index — the order NumPy's
/// stable argsort of descending magnitudes produces, so the Python
/// exporter matches exactly); zero the rest. Returns how many weights
/// were newly zeroed. A trailing short group keeps up to `keep` entries.
pub fn nm_prune_row(row: &mut [i8], keep: usize, m: usize) -> usize {
    if m == 0 || keep >= m {
        return 0;
    }
    let mut zeroed = 0;
    let mut order: Vec<usize> = Vec::with_capacity(m);
    for g in row.chunks_mut(m) {
        order.clear();
        order.extend(0..g.len());
        order.sort_by(|&a, &b| (g[b] as i32).abs().cmp(&(g[a] as i32).abs()).then(a.cmp(&b)));
        for &i in order.iter().skip(keep) {
            if g[i] != 0 {
                g[i] = 0;
                zeroed += 1;
            }
        }
    }
    zeroed
}

/// Smallest integer `tau` whose soft-thresholded row fits
/// `acc_range(budget)` under `policy` over the centered input `window`.
/// Monotone predicate (every magnitude is non-increasing in `tau`), so
/// the binary search is exact; `tau = 128` zeroes any i8 row, so a
/// result always exists for `budget >= 2`.
fn smallest_fitting_tau(row: &[i8], window: (i64, i64), policy: Policy, budget: u32) -> u32 {
    let (blo, bhi) = accum::acc_range(budget);
    let mut scratch: Vec<i8> = Vec::with_capacity(row.len());
    let mut fits = |tau: u32| {
        scratch.clear();
        scratch.extend(row.iter().map(|&v| soft(v, tau)));
        let (lo, hi) = row_range(&scratch, window, policy);
        lo >= blo && hi <= bhi
    };
    if fits(0) {
        return 0;
    }
    // i8 magnitudes reach 128 (v = -128), so tau = 128 always zeroes
    let (mut lo, mut hi) = (1u32, 128u32);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if fits(mid) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    lo
}

fn count_zeros(model: &PqswModel) -> (usize, usize) {
    let (mut zeros, mut total) = (0usize, 0usize);
    for (_, q) in model.q_layers() {
        let w = q.wq.as_slice();
        zeros += w.iter().filter(|&&v| v == 0).count();
        total += w.len();
    }
    (zeros, total)
}

/// Project `model` in place so every q-layer satisfies
/// `analytic_layer_bits(layer, cfg.policy) <= cfg.budget` (see the module
/// docs for the math). Embeds the resulting analytic [`AccumPlan`] and
/// fresh layer checksums, so saving yields a version-2 `.pqsw` the
/// serving path enforces as-is.
pub fn project(model: &mut PqswModel, cfg: &ProjectConfig) -> Result<ProjectionReport> {
    if cfg.budget < 2 || cfg.budget > MAX_BUDGET_BITS {
        bail!("projection budget {} out of range 2..={MAX_BUDGET_BITS}", cfg.budget);
    }
    if let Some(nm) = cfg.nm {
        if nm.keep < 1 || nm.keep > nm.m {
            bail!("N:M spec {}:{}: need 1 <= N <= M", nm.keep, nm.m);
        }
    }
    let abits = model.abits;
    let group_m = cfg.nm.map(|s| s.m).unwrap_or(model.nm_m);
    let (zeros_before, total_w) = count_zeros(model);
    if total_w == 0 {
        bail!("model {:?} has no quantized layers to project", model.name);
    }

    let mut layers = Vec::new();
    let mut plan_rows = Vec::new();
    for node in model.graph.iter_mut() {
        let Some(meta) = node.q.as_mut() else { continue };
        let before = QLayer::from_meta(meta, abits, group_m);
        let window = centered_input_range(&before.x_qp);
        let bits_before = analytic_layer_bits(&before, cfg.policy);
        drop(before);

        let (oc, k) = (meta.oc, meta.k);
        let mut dense = meta.wq.to_owned_vec();
        let (mut pruned, mut clipped, mut tau_max) = (0usize, 0usize, 0u32);
        for r in 0..oc {
            let row = &mut dense[r * k..(r + 1) * k];
            if let Some(nm) = cfg.nm {
                pruned += nm_prune_row(row, nm.keep, nm.m);
            }
            let tau = smallest_fitting_tau(row, window, cfg.policy, cfg.budget);
            if tau > 0 {
                tau_max = tau_max.max(tau);
                for v in row.iter_mut() {
                    let nv = soft(*v, tau);
                    if nv != *v {
                        clipped += 1;
                        *v = nv;
                    }
                }
            }
        }
        meta.wq = Weights::Owned(dense);
        if cfg.nm.is_some() {
            meta.prune = true;
        }

        let after = QLayer::from_meta(meta, abits, group_m);
        let bits_after = analytic_layer_bits(&after, cfg.policy);
        if bits_after > cfg.budget {
            bail!(
                "internal: layer {:?} projected to {} bits > budget {}",
                meta.name,
                bits_after,
                cfg.budget
            );
        }
        plan_rows.push(LayerPlan {
            name: meta.name.clone(),
            k,
            nnz_max: max_row_nnz(&after),
            analytic_bits: bits_after,
            calibrated_bits: None,
            acc_bits: bits_after,
        });
        layers.push(LayerProjection {
            name: meta.name.clone(),
            k,
            bits_before,
            bits_after,
            tau_max,
            pruned,
            clipped,
        });
    }
    if plan_rows.is_empty() {
        bail!("model {:?} has no quantized layers to project", model.name);
    }

    model.plan = Some(AccumPlan {
        policy: cfg.policy,
        planner: PlannerKind::Analytic,
        budget: 0.0,
        margin: 0,
        samples: 0,
        per_layer: plan_rows,
    });
    if let Some(nm) = cfg.nm {
        model.nm_m = nm.m;
    }
    let (zeros_after, _) = count_zeros(model);
    model.achieved_sparsity = zeros_after as f64 / total_w as f64;
    // the weights changed: re-stamp the integrity digests so
    // verify_integrity (and the next save) see the live bytes
    model.attach_checksums();

    Ok(ProjectionReport {
        policy: cfg.policy,
        budget: cfg.budget,
        nm: cfg.nm,
        layers,
        sparsity_before: zeros_before as f64 / total_w as f64,
        sparsity_after: zeros_after as f64 / total_w as f64,
    })
}

/// Widest per-layer analytic width of the (unprojected) model under
/// `policy` — the grid's natural "no-op" budget anchor.
pub fn max_analytic_bits(model: &PqswModel, policy: Policy) -> Result<u32> {
    let mut max = None;
    for (_, meta) in model.q_layers() {
        let ql = QLayer::from_meta(meta, model.abits, model.nm_m);
        let b = analytic_layer_bits(&ql, policy);
        max = Some(max.map_or(b, |m: u32| m.max(b)));
    }
    max.ok_or_else(|| anyhow!("model {:?} has no quantized layers", model.name))
}

/// Build a seeded synthetic dataset labeled by `model` itself at
/// exact/32-bit arithmetic (plan stripped): a candidate's accuracy on it
/// is its agreement with the wide-accumulator reference, and the
/// unprojected baseline scores 1.0 by construction.
pub fn reference_dataset(model: &PqswModel, n: usize, seed: u64) -> Result<Dataset> {
    let (c, h, w) = match model.input_shape[..] {
        [c, h, w] => (c, h, w),
        [d] => (1, d, 1),
        _ => bail!("model {:?}: unsupported input shape {:?}", model.name, model.input_shape),
    };
    let dim = c * h * w;
    if n == 0 || dim == 0 {
        bail!("reference dataset needs n > 0 and a non-empty input shape");
    }
    let mut rng = Pcg32::new(seed);
    let pixels: Vec<u8> = (0..n * dim).map(|_| rng.below(256) as u8).collect();

    let mut reference = model.clone();
    reference.plan = None;
    let mut eng = Engine::new(
        &reference,
        EngineConfig { policy: Policy::Exact, acc_bits: 32, ..Default::default() },
    );
    let mut labels = Vec::with_capacity(n);
    let batch = 64usize;
    let mut start = 0;
    while start < n {
        let take = batch.min(n - start);
        let imgs: Vec<f32> = pixels[start * dim..(start + take) * dim]
            .iter()
            .map(|&v| v as f32 / 255.0)
            .collect();
        let out = eng.forward(&imgs, take)?;
        if out.classes > 256 {
            bail!("model {:?}: {} classes exceed u8 labels", model.name, out.classes);
        }
        for j in 0..take {
            labels.push(out.argmax(j) as u8);
        }
        start += take;
    }
    Ok(Dataset { n, c, h, w, pixels, labels })
}

/// Grid + evaluation knobs for [`pareto`].
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// accumulation policy for projection AND evaluation
    pub policy: Policy,
    /// width budgets to project to (empty = derive `[M, M-1, M-2]` from
    /// the unprojected model's widest analytic layer `M`)
    pub budgets: Vec<u32>,
    /// N:M axis (empty = dense only; `None` entries = dense)
    pub nm: Vec<Option<NmSpec>>,
    /// evaluation batch size / worker threads
    pub batch: usize,
    pub threads: usize,
    /// declared accuracy tolerance: a point is `accuracy_ok` when its
    /// accuracy >= baseline accuracy - tolerance
    pub tolerance: f64,
    /// evaluation sample cap (None = the whole dataset)
    pub limit: Option<usize>,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            policy: Policy::Sorted,
            budgets: Vec::new(),
            nm: vec![None],
            batch: 64,
            threads: pool::default_threads(),
            tolerance: 0.05,
            limit: None,
        }
    }
}

/// One evaluated grid point.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub budget: u32,
    pub nm: Option<NmSpec>,
    /// enforced operating width: the embedded plan's widest layer
    pub width_bits: u32,
    pub accuracy: f64,
    pub accuracy_ok: bool,
    pub budget_ok: bool,
    pub persistent_dots: u64,
    pub policy_event_dots: u64,
    pub sparsity: f64,
    pub tau_max: u32,
    pub pruned: usize,
    pub clipped: usize,
    pub dominated: bool,
    pub eval_ms: f64,
}

/// The sweep's full result (points carry dominance marks; see the module
/// docs for the JSON schema).
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub model: String,
    pub policy: Policy,
    pub samples: usize,
    pub tolerance: f64,
    pub baseline_accuracy: f64,
    /// the unprojected model's widest analytic layer
    pub analytic_bits_max: u32,
    pub points: Vec<SweepPoint>,
}

/// Mark every point dominated by another (width <=, accuracy >=, strictly
/// better in at least one).
fn mark_dominated(points: &mut [SweepPoint]) {
    let snap: Vec<(u32, f64)> = points.iter().map(|p| (p.width_bits, p.accuracy)).collect();
    for (i, p) in points.iter_mut().enumerate() {
        p.dominated = snap.iter().enumerate().any(|(j, &(w, a))| {
            j != i && w <= p.width_bits && a >= p.accuracy && (w < p.width_bits || a > p.accuracy)
        });
    }
}

impl SweepResult {
    /// Non-dominated points, narrowest first.
    pub fn frontier(&self) -> Vec<&SweepPoint> {
        let mut f: Vec<&SweepPoint> = self.points.iter().filter(|p| !p.dominated).collect();
        f.sort_by(|a, b| {
            let acc = a.accuracy.partial_cmp(&b.accuracy).unwrap_or(std::cmp::Ordering::Equal);
            a.width_bits.cmp(&b.width_bits).then(acc)
        });
        f
    }

    /// Every point within budget, overflow-free, and within tolerance?
    pub fn all_ok(&self) -> bool {
        self.points
            .iter()
            .all(|p| p.budget_ok && p.accuracy_ok && p.persistent_dots == 0)
    }

    /// Serialize as the `sweep` JSON (schema in the module docs).
    pub fn to_json(&self) -> Json {
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                json::obj(vec![
                    ("budget", json::num(p.budget as f64)),
                    ("nm", json::s(&NmSpec::label(p.nm))),
                    ("width_bits", json::num(p.width_bits as f64)),
                    ("accuracy", json::num(p.accuracy)),
                    ("accuracy_ok", Json::Bool(p.accuracy_ok)),
                    ("budget_ok", Json::Bool(p.budget_ok)),
                    ("persistent_dots", json::num(p.persistent_dots as f64)),
                    ("policy_event_dots", json::num(p.policy_event_dots as f64)),
                    ("sparsity", json::num(p.sparsity)),
                    ("tau_max", json::num(p.tau_max as f64)),
                    ("pruned", json::num(p.pruned as f64)),
                    ("clipped", json::num(p.clipped as f64)),
                    ("dominated", Json::Bool(p.dominated)),
                    ("eval_ms", json::num(p.eval_ms)),
                ])
            })
            .collect();
        let frontier: Vec<Json> = self
            .frontier()
            .iter()
            .map(|p| Json::Arr(vec![json::num(p.width_bits as f64), json::num(p.accuracy)]))
            .collect();
        json::obj(vec![
            ("tag", json::s("sweep")),
            ("v", json::num(1.0)),
            ("model", json::s(&self.model)),
            ("policy", json::s(self.policy.name())),
            ("samples", json::num(self.samples as f64)),
            ("tolerance", json::num(self.tolerance)),
            (
                "baseline",
                json::obj(vec![
                    ("acc_bits", json::num(32.0)),
                    ("accuracy", json::num(self.baseline_accuracy)),
                    ("analytic_bits_max", json::num(self.analytic_bits_max as f64)),
                ]),
            ),
            ("points", Json::Arr(points)),
            ("frontier", Json::Arr(frontier)),
        ])
    }

    /// The table `pqs sweep` prints.
    pub fn print(&self) {
        println!(
            "sweep: model={} policy={} samples={} tolerance={} baseline acc {:.4} @32b \
             (analytic max {} bits)",
            self.model,
            self.policy.name(),
            self.samples,
            self.tolerance,
            self.baseline_accuracy,
            self.analytic_bits_max,
        );
        println!(
            "{:>6} {:>6} {:>6} {:>9} {:>8} {:>8} {:>9} {:>5} {:>7}",
            "budget", "nm", "width", "accuracy", "d-acc", "persist", "sparsity", "tau", "pareto"
        );
        for p in &self.points {
            println!(
                "{:>6} {:>6} {:>6} {:>9.4} {:>+8.4} {:>8} {:>9.3} {:>5} {:>7}",
                p.budget,
                NmSpec::label(p.nm),
                p.width_bits,
                p.accuracy,
                p.accuracy - self.baseline_accuracy,
                p.persistent_dots,
                p.sparsity,
                p.tau_max,
                if p.dominated { "" } else { "*" },
            );
        }
    }
}

/// Walk the (budget × N:M) grid: project each candidate, serve it through
/// [`EvalService`] at its budget width (one shared [`ComputePool`] across
/// all candidates), and mark the accuracy/width Pareto frontier. See the
/// module docs for grid semantics and the JSON schema.
pub fn pareto(model: &PqswModel, ds: &Dataset, cfg: &SweepConfig) -> Result<SweepResult> {
    let analytic_max = max_analytic_bits(model, cfg.policy)?;
    let budgets: Vec<u32> = if cfg.budgets.is_empty() {
        let mut b: Vec<u32> = (0..3).map(|d| analytic_max.saturating_sub(d)).collect();
        b.retain(|&v| v >= 2);
        b.dedup();
        b
    } else {
        cfg.budgets.clone()
    };
    let nm_axis: &[Option<NmSpec>] = if cfg.nm.is_empty() { &[None] } else { &cfg.nm };
    let threads = cfg.threads.max(1);
    let pool = (threads > 1).then(|| Arc::new(ComputePool::new(threads)));

    let eval = |m: &PqswModel, bits: u32| {
        let ecfg = EngineConfig {
            policy: cfg.policy,
            acc_bits: bits,
            collect_stats: true,
            ..Default::default()
        };
        let mut svc = EvalService::new(m, ecfg).with_threads(threads).with_batch(cfg.batch);
        if let Some(p) = &pool {
            svc = svc.with_pool(Arc::clone(p));
        }
        svc.evaluate(ds, cfg.limit)
    };

    // baseline: the unprojected model, plan stripped, at 32 bits
    let mut base = model.clone();
    base.plan = None;
    let baseline = eval(&base, 32)?;

    let mut points = Vec::with_capacity(budgets.len() * nm_axis.len());
    for &budget in &budgets {
        for &nm in nm_axis {
            let mut cand = model.clone();
            cand.plan = None;
            let rep = project(&mut cand, &ProjectConfig { policy: cfg.policy, budget, nm })?;
            let out = eval(&cand, budget)?;
            let stats = out.report.total();
            let width = cand.plan.as_ref().map(|p| p.min_safe_bits()).unwrap_or(budget);
            points.push(SweepPoint {
                budget,
                nm,
                width_bits: width,
                accuracy: out.accuracy,
                accuracy_ok: out.accuracy >= baseline.accuracy - cfg.tolerance,
                budget_ok: width <= budget,
                persistent_dots: stats.persistent_dots,
                policy_event_dots: stats.policy_event_dots,
                sparsity: rep.sparsity_after,
                tau_max: rep.tau_max(),
                pruned: rep.pruned(),
                clipped: rep.clipped(),
                dominated: false,
                eval_ms: out.wall_ms,
            });
        }
    }
    mark_dominated(&mut points);
    Ok(SweepResult {
        model: model.name.clone(),
        policy: cfg.policy,
        samples: baseline.samples,
        tolerance: cfg.tolerance,
        baseline_accuracy: baseline.accuracy,
        analytic_bits_max: analytic_max,
        points,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn nm_spec_parses_and_rejects() {
        assert_eq!(NmSpec::parse("dense").unwrap(), None);
        assert_eq!(NmSpec::parse(" 2:4 ").unwrap(), Some(NmSpec { keep: 2, m: 4 }));
        assert_eq!(NmSpec::label(Some(NmSpec { keep: 2, m: 4 })), "2:4");
        assert_eq!(NmSpec::label(None), "dense");
        for bad in ["", "2", "0:4", "5:4", "a:b", "2:0"] {
            assert!(NmSpec::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn nm_prune_keeps_largest_with_stable_ties() {
        // magnitudes 3,5,5,1 keep 2 -> the two 5s? no: |3|,|5|,|5|,|1|;
        // keep 2 largest = both 5s; tie between equal magnitudes keeps
        // the lower index first (both survive here)
        let mut row = vec![3, -5, 5, 1];
        assert_eq!(nm_prune_row(&mut row, 2, 4), 2);
        assert_eq!(row, vec![0, -5, 5, 0]);
        // tie at the keep boundary: |2| vs |2| -> lower index survives
        let mut row = vec![-2, 2, 1, 0];
        assert_eq!(nm_prune_row(&mut row, 1, 4), 2);
        assert_eq!(row, vec![-2, 0, 0, 0]);
        // trailing short group prunes too; pre-existing zeros don't count
        let mut row = vec![4, 0, -1, 7, 6];
        assert_eq!(nm_prune_row(&mut row, 1, 3), 2);
        assert_eq!(row, vec![4, 0, 0, 7, 0]);
        // keep >= m is a no-op
        let mut row = vec![1, 2, 3];
        assert_eq!(nm_prune_row(&mut row, 3, 3), 0);
        assert_eq!(row, vec![1, 2, 3]);
    }

    #[test]
    fn soft_threshold_shrinks_toward_zero() {
        assert_eq!(soft(5, 0), 5);
        assert_eq!(soft(5, 2), 3);
        assert_eq!(soft(-5, 2), -3);
        assert_eq!(soft(2, 2), 0);
        assert_eq!(soft(-1, 2), 0);
        assert_eq!(soft(-128, 0), -128);
        assert_eq!(soft(-128, 127), -1);
        assert_eq!(soft(-128, 128), 0);
        assert_eq!(soft(127, 128), 0);
    }

    #[test]
    fn projection_noop_when_budget_is_loose() {
        let mut model = models::synthetic_linear(16, 4);
        let before: Vec<i8> = model.q_layers().next().unwrap().1.wq.to_owned_vec();
        let cfg = ProjectConfig { policy: Policy::Sorted, budget: 32, nm: None };
        let rep = project(&mut model, &cfg).unwrap();
        assert!(!rep.changed(), "{rep:?}");
        assert_eq!(model.q_layers().next().unwrap().1.wq.as_slice(), &before[..]);
        let plan = model.plan.as_ref().expect("plan embedded");
        assert!(plan.min_safe_bits() <= 32);
        assert_eq!(plan.per_layer.len(), 1);
        // integrity digests were re-stamped against the live bytes
        model.verify_integrity().unwrap();
    }

    #[test]
    fn projection_rejects_bad_budgets() {
        let mut model = models::synthetic_linear(8, 3);
        for budget in [0u32, 1, MAX_BUDGET_BITS + 1] {
            let cfg = ProjectConfig { policy: Policy::Sorted, budget, nm: None };
            assert!(project(&mut model, &cfg).is_err(), "budget {budget} accepted");
        }
    }

    #[test]
    fn reference_dataset_is_seed_deterministic_and_self_consistent() {
        let model = models::synthetic_conv(2, 6, 6, 4, 10);
        let a = reference_dataset(&model, 24, 7).unwrap();
        let b = reference_dataset(&model, 24, 7).unwrap();
        assert_eq!(a.pixels, b.pixels);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.n, 24);
        assert_eq!((a.c, a.h, a.w), (2, 6, 6));
        let c = reference_dataset(&model, 24, 8).unwrap();
        assert_ne!(a.pixels, c.pixels, "seed must matter");
    }

    #[test]
    fn dominance_marks_the_frontier() {
        let mk = |width: u32, acc: f64| SweepPoint {
            budget: width,
            nm: None,
            width_bits: width,
            accuracy: acc,
            accuracy_ok: true,
            budget_ok: true,
            persistent_dots: 0,
            policy_event_dots: 0,
            sparsity: 0.0,
            tau_max: 0,
            pruned: 0,
            clipped: 0,
            dominated: false,
            eval_ms: 0.0,
        };
        // (10, .9) dominates (12, .8); (8, .7) and (10, .9) are both on
        // the frontier; the duplicate of (10, .9) is NOT dominated (no
        // strict improvement exists)
        let mut pts = vec![mk(10, 0.9), mk(12, 0.8), mk(8, 0.7), mk(10, 0.9)];
        mark_dominated(&mut pts);
        assert!(!pts[0].dominated);
        assert!(pts[1].dominated);
        assert!(!pts[2].dominated);
        assert!(!pts[3].dominated);
        let res = SweepResult {
            model: "t".into(),
            policy: Policy::Sorted,
            samples: 0,
            tolerance: 0.0,
            baseline_accuracy: 1.0,
            analytic_bits_max: 12,
            points: pts,
        };
        let widths: Vec<u32> = res.frontier().iter().map(|p| p.width_bits).collect();
        assert_eq!(widths, vec![8, 10, 10]);
    }

    #[test]
    fn sweep_json_matches_documented_schema() {
        let res = SweepResult {
            model: "t".into(),
            policy: Policy::Sorted,
            samples: 5,
            tolerance: 0.1,
            baseline_accuracy: 1.0,
            analytic_bits_max: 14,
            points: vec![SweepPoint {
                budget: 14,
                nm: Some(NmSpec { keep: 2, m: 4 }),
                width_bits: 13,
                accuracy: 0.8,
                accuracy_ok: false,
                budget_ok: true,
                persistent_dots: 0,
                policy_event_dots: 2,
                sparsity: 0.5,
                tau_max: 1,
                pruned: 8,
                clipped: 3,
                dominated: false,
                eval_ms: 1.5,
            }],
        };
        let j = Json::parse(&res.to_json().to_string()).unwrap();
        assert_eq!(j.get("tag").and_then(Json::as_str), Some("sweep"));
        let base = j.get("baseline").unwrap();
        assert_eq!(base.get("acc_bits").and_then(Json::as_usize), Some(32));
        assert_eq!(base.get("analytic_bits_max").and_then(Json::as_usize), Some(14));
        let p = j.get("points").and_then(Json::as_arr).unwrap()[0].clone();
        let keys = "budget nm width_bits accuracy accuracy_ok budget_ok persistent_dots \
                    policy_event_dots sparsity tau_max pruned clipped dominated eval_ms";
        for key in keys.split_whitespace() {
            assert!(p.get(key).is_some(), "point missing {key}");
        }
        assert_eq!(p.get("nm").and_then(Json::as_str), Some("2:4"));
        let f = j.get("frontier").and_then(Json::as_arr).unwrap();
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].idx(0).and_then(Json::as_usize), Some(13));
    }
}
