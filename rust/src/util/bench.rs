//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Measures wall time over adaptive iteration counts with warmup, reports
//! mean / stddev / throughput, and prints criterion-like one-line summaries.
//! `cargo bench` binaries (rust/benches/*.rs, harness = false) use this.

use std::time::Instant;

use crate::util::stats;

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub samples: Vec<f64>,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<48} {:>12} ± {:>10}   ({} iters)",
            self.name,
            stats::fmt_ns(self.mean_ns),
            stats::fmt_ns(self.stddev_ns),
            self.iters
        );
    }

    pub fn print_throughput(&self, items: f64, unit: &str) {
        println!(
            "{:<48} {:>12} ± {:>10}   {:>14} {unit}",
            self.name,
            stats::fmt_ns(self.mean_ns),
            stats::fmt_ns(self.stddev_ns),
            stats::fmt_rate(items / (self.mean_ns / 1e9)),
        );
    }
}

/// Benchmark `f`, automatically choosing an iteration count so each sample
/// takes >= ~5ms, collecting `samples` samples after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_cfg(name, 3, 10, &mut f)
}

pub fn bench_cfg<F: FnMut()>(name: &str, warmup: u32, n_samples: u32, f: &mut F) -> BenchResult {
    // calibrate
    let mut iters = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed().as_nanos() as f64;
        if dt > 5e6 || iters >= 1 << 24 {
            break;
        }
        iters = (iters * 2).max((iters as f64 * 6e6 / dt.max(1.0)) as u64);
    }
    for _ in 0..warmup {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let _ = t0.elapsed();
    }
    let mut samples = Vec::with_capacity(n_samples as usize);
    for _ in 0..n_samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: stats::mean(&samples),
        stddev_ns: stats::stddev(&samples),
        samples,
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut acc = 0u64;
        let r = bench_cfg("noop-ish", 1, 3, &mut || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 100);
    }
}
