//! Small statistics helpers for reports and benches.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile (nearest-rank on a sorted copy), q in [0,100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((q / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// HDR-style latency histogram: log2 major buckets, each split into
/// `SUB_BUCKETS` linear sub-buckets, so relative error is bounded at
/// ~1/SUB_BUCKETS (±3%) across the whole range — record is O(1) with no
/// allocation, unlike [`percentile`]'s sort-a-copy, and quantiles over
/// millions of samples cost a single fixed-size scan. Values are
/// unit-agnostic integers (the bench records microseconds).
#[derive(Clone, Debug)]
pub struct HdrHistogram {
    counts: Vec<u64>,
    total: u64,
    max: u64,
}

/// Significant bits kept per value: the first 2^K values are exact, and
/// every later power-of-two octave splits into 2^(K-1) linear
/// sub-buckets, bounding relative error at 2^(1-K) ≈ 3%.
const HDR_SUB_BITS: u32 = 6;
const HDR_FIRST: usize = 1 << HDR_SUB_BITS; // exact range [0, 64)
const HDR_HALF: usize = HDR_FIRST / 2; // sub-buckets per later octave
const HDR_BUCKETS: usize = HDR_FIRST + (64 - HDR_SUB_BITS as usize) * HDR_HALF;

impl Default for HdrHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl HdrHistogram {
    pub fn new() -> HdrHistogram {
        HdrHistogram { counts: vec![0u64; HDR_BUCKETS], total: 0, max: 0 }
    }

    fn index_of(value: u64) -> usize {
        let msb = 63 - (value | 1).leading_zeros();
        // how many low bits to drop so the value fits in SUB_BITS bits
        let shift = (msb + 1).saturating_sub(HDR_SUB_BITS);
        if shift == 0 {
            value as usize // exact linear range
        } else {
            let top = (value >> shift) as usize; // in [HALF, FIRST)
            HDR_FIRST + (shift as usize - 1) * HDR_HALF + (top - HDR_HALF)
        }
    }

    /// Lowest value that maps into bucket `i` (the bucket's reported
    /// representative — quantiles are therefore conservative, never
    /// overstated).
    fn value_of(i: usize) -> u64 {
        if i < HDR_FIRST {
            return i as u64;
        }
        let j = i - HDR_FIRST;
        let shift = (j / HDR_HALF) as u32 + 1;
        let top = (j % HDR_HALF + HDR_HALF) as u64;
        top << shift
    }

    pub fn record(&mut self, value: u64) {
        self.counts[Self::index_of(value)] += 1;
        self.total += 1;
        self.max = self.max.max(value);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Value at quantile `q` in [0,1] (0 for an empty histogram). The
    /// exact recorded max is returned for the top of the distribution.
    pub fn value_at(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::value_of(i).min(self.max);
            }
        }
        self.max
    }

    /// Merge another histogram into this one (bucket-exact, not an
    /// approximation — both sides share the same fixed layout).
    pub fn merge(&mut self, other: &HdrHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.max = self.max.max(other.max);
    }

    /// Non-empty buckets as `(lower_bound, count)` rows, for compact
    /// JSON export.
    pub fn buckets(&self) -> Vec<(u64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(i, &c)| (Self::value_of(i), c))
            .collect()
    }

    /// Non-empty buckets as `(upper_bound, cumulative_count)` rows:
    /// every recorded value <= `upper_bound` is counted, so the rows
    /// translate exactly into Prometheus `le` histogram buckets.
    pub fn cumulative(&self) -> Vec<(u64, u64)> {
        let mut rows = Vec::new();
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                cum += c;
                let hi = if i + 1 < HDR_BUCKETS { Self::value_of(i + 1) - 1 } else { u64::MAX };
                rows.push((hi, cum));
            }
        }
        rows
    }
}

/// Human-readable duration from nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Human-readable rate.
pub fn fmt_rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:.2} G/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2} M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} K/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.1} /s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - 1.2909944487).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let p50 = percentile(&xs, 50.0);
        assert!((50.0..=51.0).contains(&p50), "{p50}"); // nearest-rank
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_rate(1.5e6), "1.50 M/s");
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn hdr_buckets_are_a_partition() {
        // index_of and value_of invert each other: value_of(i) is the
        // smallest value in bucket i, and consecutive buckets tile the
        // domain without gaps or overlaps
        for i in 0..HDR_BUCKETS {
            let lo = HdrHistogram::value_of(i);
            assert_eq!(HdrHistogram::index_of(lo), i, "lower bound of bucket {i}");
            if i + 1 < HDR_BUCKETS {
                let next = HdrHistogram::value_of(i + 1);
                assert!(next > lo, "bucket {i} not monotone");
                assert_eq!(HdrHistogram::index_of(next - 1), i, "upper bound of bucket {i}");
            }
        }
        assert_eq!(HdrHistogram::index_of(u64::MAX), HDR_BUCKETS - 1);
    }

    #[test]
    fn hdr_quantiles_bound_relative_error() {
        let mut h = HdrHistogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 100_000);
        assert_eq!(h.max(), 100_000);
        for (q, exact) in [(0.5, 50_000.0), (0.99, 99_000.0), (0.999, 99_900.0)] {
            let got = h.value_at(q) as f64;
            let rel = (got - exact).abs() / exact;
            assert!(rel < 0.04, "q={q}: got {got}, exact {exact}, rel err {rel}");
            assert!(got <= exact, "bucket lower bounds never overstate a quantile");
        }
        assert_eq!(h.value_at(1.0), 100_000, "top quantile reports the exact max");
        assert_eq!(HdrHistogram::new().value_at(0.5), 0, "empty histogram");
    }

    #[test]
    fn hdr_cumulative_rows_cover_and_bound_every_value() {
        let mut h = HdrHistogram::new();
        for v in [0u64, 3, 63, 64, 70, 900, 12_345] {
            h.record(v);
        }
        let rows = h.cumulative();
        assert_eq!(rows.len(), h.buckets().len());
        // upper bounds strictly increase, cumulative counts never decrease
        for w in rows.windows(2) {
            assert!(w[1].0 > w[0].0);
            assert!(w[1].1 >= w[0].1);
        }
        assert_eq!(rows.last().unwrap().1, h.count());
        // each row's cumulative count equals the number of recorded
        // values <= its upper bound — the `le` contract
        let values = [0u64, 3, 63, 64, 70, 900, 12_345];
        for &(hi, cum) in &rows {
            let exact = values.iter().filter(|&&v| v <= hi).count() as u64;
            assert_eq!(cum, exact, "le={hi}");
        }
        assert!(HdrHistogram::new().cumulative().is_empty());
    }

    #[test]
    fn hdr_merge_equals_recording_into_one() {
        let mut a = HdrHistogram::new();
        let mut b = HdrHistogram::new();
        let mut both = HdrHistogram::new();
        for v in [3u64, 70, 900, 12_345, 1 << 40] {
            a.record(v);
            both.record(v);
        }
        for v in [5u64, 64, 100_000] {
            b.record(v);
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.max(), both.max());
        assert_eq!(a.buckets(), both.buckets());
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.value_at(q), both.value_at(q), "q={q}");
        }
    }
}
