//! Small statistics helpers for reports and benches.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile (nearest-rank on a sorted copy), q in [0,100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((q / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Human-readable duration from nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Human-readable rate.
pub fn fmt_rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:.2} G/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2} M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} K/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.1} /s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - 1.2909944487).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let p50 = percentile(&xs, 50.0);
        assert!((50.0..=51.0).contains(&p50), "{p50}"); // nearest-rank
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_rate(1.5e6), "1.50 M/s");
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
