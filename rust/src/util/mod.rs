//! Substrate utilities built from scratch (this environment is offline:
//! no serde / clap / rand / criterion / tokio — see DESIGN.md §2 S20).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
