//! Hand-rolled property-testing harness (no proptest offline).
//!
//! `check(name, iters, gen, prop)` runs `prop` over `iters` generated cases
//! with a deterministic seed sequence; on failure it retries with a simple
//! shrink pass (re-generating "smaller" cases from derived seeds is left to
//! the generator — we report the failing seed so the case is reproducible).

use crate::util::rng::Pcg32;

/// Run a property over generated cases. Panics with the failing seed and
/// message on the first counterexample.
pub fn check<T: std::fmt::Debug, G, P>(name: &str, iters: u64, gen: G, prop: P)
where
    G: Fn(&mut Pcg32) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    for seed in 0..iters {
        let mut rng = Pcg32::new(0x5051_5EED ^ seed);
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!("property '{name}' failed at seed {seed}: {msg}\ncase: {case:?}");
        }
    }
}

/// Generator helpers for the common "vector of small ints" shape.
pub fn gen_prods(rng: &mut Pcg32, max_len: usize, bits: u32) -> Vec<i32> {
    let len = rng.below(max_len as u32 + 1) as usize;
    let lim = 1i64 << (bits - 1);
    (0..len)
        .map(|_| (rng.range_i64(-(lim - 1), lim - 1) * rng.range_i64(-lim, lim - 1)) as i32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("sum-commutes", 50, |r| r.ivec(10, -100, 100), |v| {
            let a: i64 = v.iter().map(|&x| x as i64).sum();
            let b: i64 = v.iter().rev().map(|&x| x as i64).sum();
            if a == b {
                Ok(())
            } else {
                Err(format!("{a} != {b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failures() {
        check("always-fails", 5, |r| r.next_u32(), |_| Err("nope".into()));
    }

    #[test]
    fn gen_prods_in_product_range() {
        let mut r = Pcg32::new(1);
        for _ in 0..100 {
            let v = gen_prods(&mut r, 64, 8);
            assert!(v.len() <= 64);
            for &p in &v {
                assert!((p as i64).abs() <= 127 * 128);
            }
        }
    }
}
