//! Minimal JSON codec (no serde offline). Parses the artifact headers,
//! manifests and goldens emitted by `python/compile/`, and serializes
//! reports. Supports the full JSON grammar incl. \uXXXX escapes.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Parse from raw bytes (HTTP bodies); the bytes must be valid UTF-8.
    pub fn parse_bytes(b: &[u8]) -> Result<Json, JsonError> {
        let s = std::str::from_utf8(b)
            .map_err(|e| JsonError { msg: "invalid utf-8".to_string(), pos: e.valid_up_to() })?;
        Json::parse(s)
    }

    // ---- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) => Some(*n as i64),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
    /// i64 vector from a numeric array.
    pub fn as_ivec(&self) -> Option<Vec<i64>> {
        self.as_arr()?.iter().map(|v| v.as_i64()).collect()
    }
    pub fn as_fvec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // ---- serialization ---------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report serialization.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(v: f64) -> Json {
    Json::Num(v)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("utf8"))?;
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        if self.peek() != Some(b'"') {
            return Err(self.err("expected string"));
        }
        self.i += 1;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("eof in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("eof escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let h = self.hex4()?;
                            // surrogate pairs
                            if (0xD800..0xDC00).contains(&h) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    if self.peek() == Some(b'u') {
                                        self.i += 1;
                                        let lo = self.hex4()?;
                                        let cp = 0x10000
                                            + ((h - 0xD800) << 10)
                                            + (lo - 0xDC00);
                                        out.push(
                                            char::from_u32(cp).ok_or_else(|| self.err("bad surrogate"))?,
                                        );
                                        continue;
                                    }
                                }
                                return Err(self.err("lone surrogate"));
                            }
                            out.push(char::from_u32(h).ok_or_else(|| self.err("bad \\u"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // raw utf8 byte run
                    let start = self.i - 1;
                    while self.i < self.b.len() && self.b[self.i] != b'"' && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    let chunk = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| self.err("utf8"))?;
                    out.push_str(chunk);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u"));
        }
        let txt = std::str::from_utf8(&self.b[self.i..self.i + 4]).map_err(|_| self.err("utf8"))?;
        let v = u32::from_str_radix(txt, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.i += 1; // [
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.i += 1; // {
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            if self.peek() != Some(b':') {
                return Err(self.err("expected :"));
            }
            self.i += 1;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_i64(), Some(2));
        assert!(j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().is_null());
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_unicode_escape() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str(), Some("é😀"));
    }

    #[test]
    fn roundtrip() {
        let txt = r#"{"arr":[1,2.5,-3],"nested":{"x":true},"s":"a\"b"}"#;
        let j = Json::parse(txt).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn big_ints_within_f64() {
        let j = Json::parse("1073741824").unwrap(); // 2^30
        assert_eq!(j.as_i64(), Some(1 << 30));
    }

    #[test]
    fn parse_bytes_matches_parse_and_rejects_bad_utf8() {
        let j = Json::parse_bytes(b"{\"a\": [1, 2]}").unwrap();
        assert_eq!(j.get("a").unwrap().as_ivec(), Some(vec![1, 2]));
        let err = Json::parse_bytes(&[b'"', 0xff, 0xfe, b'"']).unwrap_err();
        assert!(err.msg.contains("utf-8"), "msg: {}", err.msg);
    }
}
