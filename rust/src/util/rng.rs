//! Deterministic PRNG: PCG32 (O'Neill 2014) + SplitMix64 seeding.
//!
//! Used by the property-test harness, synthetic workload generators and the
//! coordinator's load generator. Not cryptographic.

/// PCG-XSH-RR 64/32.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to spread the seed into state + stream.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let mut r = Pcg32 { state: 0, inc: next() | 1 };
        r.state = next();
        r.next_u32();
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (n as u64);
            let l = m as u32;
            if l >= n || l >= (n.wrapping_neg() % n) {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform in [0, n) without modulo bias (Lemire, 64-bit widening).
    #[inline]
    pub fn below_u64(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let l = m as u64;
            if l >= n || l >= (n.wrapping_neg() % n) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive, via the same rejection
    /// sampling as [`Self::below`] (no modulo bias).
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        // Span as an unsigned count; `hi - lo` is computed wrapping so the
        // full-domain case (i64::MIN..=i64::MAX) doesn't overflow i64.
        let span = hi.wrapping_sub(lo) as u64;
        if span == u64::MAX {
            // 2^64 values: every u64 is already uniform over the domain.
            return self.next_u64() as i64;
        }
        lo.wrapping_add(self.below_u64(span + 1) as i64)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Random i32 vector in [lo, hi].
    pub fn ivec(&mut self, n: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..n).map(|_| self.range_i64(lo as i64, hi as i64) as i32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_range() {
        let mut r = Pcg32::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
        for _ in 0..1000 {
            let v = r.range_i64(-5, 5);
            assert!((-5..=5).contains(&v));
        }
    }

    #[test]
    fn below_u64_in_range() {
        let mut r = Pcg32::new(13);
        for _ in 0..1000 {
            assert!(r.below_u64(10) < 10);
        }
        // Spans past u32 exercise the 128-bit widening path.
        for _ in 0..1000 {
            assert!(r.below_u64(1 << 40) < (1 << 40));
        }
    }

    #[test]
    fn range_i64_covers_small_domain_uniformly() {
        // With rejection sampling every value of a tiny domain shows up,
        // and no value hogs the distribution (the old `% span` path biased
        // low residues for spans near a power-of-two boundary).
        let mut r = Pcg32::new(17);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[(r.range_i64(-1, 1) + 1) as usize] += 1;
        }
        for (i, c) in counts.iter().enumerate() {
            assert!((800..=1200).contains(c), "bucket {i}: {c}");
        }
    }

    #[test]
    fn range_i64_extreme_domains() {
        let mut r = Pcg32::new(19);
        // Full domain: every draw is valid; exercise the span == 2^64 path.
        for _ in 0..10 {
            let _ = r.range_i64(i64::MIN, i64::MAX);
        }
        // Degenerate single-value span.
        assert_eq!(r.range_i64(7, 7), 7);
        assert_eq!(r.range_i64(i64::MIN, i64::MIN), i64::MIN);
        // Spans wider than i64::MAX values (would overflow `hi - lo`).
        for _ in 0..100 {
            let v = r.range_i64(i64::MIN, 0);
            assert!(v <= 0);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Pcg32::new(9);
        let mut acc = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            acc += v;
        }
        let mean = acc / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(11);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
