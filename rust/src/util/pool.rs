//! Scoped thread-pool helpers (no tokio/rayon offline).
//!
//! `parallel_map` splits the index range `0..n` across `n_threads` scoped
//! workers. Workers claim *chunks* of consecutive indices from a shared
//! atomic cursor (one fetch-add per chunk, not per item), compute results
//! into a private buffer, and the buffers are stitched back into index
//! order after the scope joins — no per-item locking anywhere. The
//! evaluation coordinator and the engine's intra-forward parallelism build
//! on this.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (PQS_THREADS env or available cores).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PQS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Chunk of indices claimed per cursor fetch: large enough to amortize the
/// atomic, small enough (>= 8 chunks per worker) to balance uneven items.
fn chunk_size(n: usize, threads: usize) -> usize {
    (n / (threads * 8)).clamp(1, 1024)
}

/// Apply `f` to every index in 0..n on `threads` scoped workers, collecting
/// results in index order. `f` must be Sync; per-item state should live
/// inside `f` (construct scratch per call or use `parallel_map_init`).
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, threads: usize, f: F) -> Vec<T> {
    parallel_map_init(n, threads, || (), |_, i| f(i))
}

/// Like `parallel_map` but each worker gets its own state from `init`
/// (scratch buffers, engines) reused across all items it claims.
pub fn parallel_map_init<T, S, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        let mut st = init();
        return (0..n).map(|i| f(&mut st, i)).collect();
    }
    let chunk = chunk_size(n, threads);
    let next = AtomicUsize::new(0);
    let mut parts: Vec<Vec<(usize, T)>> = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut st = init();
                    let mut local: Vec<(usize, T)> = Vec::with_capacity(n / threads + chunk);
                    loop {
                        let start = next.fetch_add(chunk, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        let end = (start + chunk).min(n);
                        for i in start..end {
                            local.push((i, f(&mut st, i)));
                        }
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            parts.push(h.join().expect("pool worker panicked"));
        }
    });
    // stitch the per-worker runs back into index order
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for part in parts {
        for (i, v) in part {
            debug_assert!(out[i].is_none(), "index {i} produced twice");
            out[i] = Some(v);
        }
    }
    out.into_iter().map(|v| v.expect("pool missed an index")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn maps_in_order() {
        let v = parallel_map(100, 4, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty() {
        let v: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn single_thread_path() {
        let v = parallel_map(10, 1, |i| i + 1);
        assert_eq!(v[9], 10);
    }

    #[test]
    fn init_state_reused() {
        // each worker counts its own items; total must equal n
        let counts = parallel_map_init(
            1000,
            4,
            || 0usize,
            |st, i| {
                *st += 1;
                (i, *st)
            },
        );
        assert_eq!(counts.len(), 1000);
        // state is per-worker, so per-item counters are <= n
        assert!(counts.iter().all(|&(_, c)| c >= 1 && c <= 1000));
    }

    #[test]
    fn every_index_computed_exactly_once() {
        // sum over f(i)=1 must be n for ragged n/thread/chunk combinations
        for &(n, threads) in &[(1usize, 8usize), (7, 3), (64, 4), (1000, 7), (1025, 16)] {
            let calls = AtomicU64::new(0);
            let v = parallel_map(n, threads, |i| {
                calls.fetch_add(1, Ordering::Relaxed);
                i
            });
            assert_eq!(v, (0..n).collect::<Vec<_>>(), "n={n} threads={threads}");
            assert_eq!(calls.load(Ordering::Relaxed), n as u64, "n={n} threads={threads}");
        }
    }

    #[test]
    fn chunk_size_bounds() {
        assert_eq!(chunk_size(1, 8), 1);
        assert_eq!(chunk_size(100, 4), 3);
        assert!(chunk_size(1_000_000, 2) <= 1024);
    }
}
