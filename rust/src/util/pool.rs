//! Thread-pool helpers (no tokio/rayon offline).
//!
//! Three dispatch modes, one claiming discipline:
//!
//! * **Scoped index-range maps** — [`parallel_map`]/[`parallel_map_init`]
//!   split the index range `0..n` across `n_threads` scoped workers spawned
//!   per call. Workers claim *chunks* of consecutive indices from a shared
//!   atomic cursor (one fetch-add per chunk, not per item), compute results
//!   into a private buffer, and the buffers are stitched back into index
//!   order after the scope joins — no per-item locking anywhere. This is
//!   the fallback path: correct anywhere, but it pays a thread spawn+join
//!   per call, which dominates for small per-call work (one conv layer at
//!   batch 1).
//! * **Persistent index-range maps** — [`ComputePool`] keeps the same
//!   chunked-claiming semantics but serves them from long-lived workers.
//!   Workers park on a condvar between jobs; a dispatched job is an
//!   epoch-numbered broadcast (every worker runs the job body once, the
//!   body loops claiming chunks until the cursor is exhausted), and the
//!   dispatching caller participates as one more worker, so a pool sized
//!   `threads` applies exactly `threads` threads to each job. Per-layer
//!   dispatch cost is one lock round-trip + a condvar wakeup instead of
//!   `threads` thread spawns. One pool is meant to be *shared* (via `Arc`)
//!   by every engine in a process — N engines dispatching into one pool
//!   cannot oversubscribe the machine the way N private scoped maps can.
//!   Results are bit-identical to the scoped and serial paths: the same
//!   per-index closure runs exactly once per index and results are
//!   stitched in index order.
//!
//!   *Sizing*: `ComputePool::new(threads)` spawns `threads - 1` background
//!   workers (the caller is the remaining thread). *Contention*: jobs are
//!   serialized; a caller that finds the pool busy runs its job body
//!   inline (claiming every chunk itself — the serial path) instead of
//!   convoying behind the other job. *Shutdown*: dropping the pool parks
//!   no new jobs, wakes every worker and joins them; in-flight jobs finish
//!   first because the dispatcher holds the job until all workers
//!   acknowledge. *Panics*: a panicking job body is caught in the worker,
//!   re-raised on the dispatching caller after the job drains, and never
//!   kills a pool thread. Utilization counters (busy workers, dispatched
//!   jobs/chunks) are exported via [`ComputePool::stats`] — the serving
//!   stack surfaces them on `GET /v1/metrics`.
//! * **Item queues** — [`WorkerPool`]: long-lived workers drain a bounded
//!   queue of dispatched items, with `try_dispatch` handing the item back
//!   when the queue is full so callers can shed load. The HTTP front-end
//!   (`crate::http`) uses it as its bounded connection pool.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Number of worker threads to use (PQS_THREADS env or available cores).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PQS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Chunk of indices claimed per cursor fetch: large enough to amortize the
/// atomic, small enough (>= 8 chunks per worker) to balance uneven items.
fn chunk_size(n: usize, threads: usize) -> usize {
    (n / (threads * 8)).clamp(1, 1024)
}

/// Apply `f` to every index in 0..n on `threads` scoped workers, collecting
/// results in index order. `f` must be Sync; per-item state should live
/// inside `f` (construct scratch per call or use `parallel_map_init`).
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, threads: usize, f: F) -> Vec<T> {
    parallel_map_init(n, threads, || (), |_, i| f(i))
}

/// Like `parallel_map` but each worker gets its own state from `init`
/// (scratch buffers, engines) reused across all items it claims.
pub fn parallel_map_init<T, S, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        let mut st = init();
        return (0..n).map(|i| f(&mut st, i)).collect();
    }
    let chunk = chunk_size(n, threads);
    let next = AtomicUsize::new(0);
    let mut parts: Vec<Vec<(usize, T)>> = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut st = init();
                    let mut local: Vec<(usize, T)> = Vec::with_capacity(n / threads + chunk);
                    loop {
                        let start = next.fetch_add(chunk, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        let end = (start + chunk).min(n);
                        for i in start..end {
                            local.push((i, f(&mut st, i)));
                        }
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            parts.push(h.join().expect("pool worker panicked"));
        }
    });
    stitch(parts, n)
}

/// Reassemble per-worker `(index, value)` runs into index order.
fn stitch<T>(parts: Vec<Vec<(usize, T)>>, n: usize) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for part in parts {
        for (i, v) in part {
            debug_assert!(out[i].is_none(), "index {i} produced twice");
            out[i] = Some(v);
        }
    }
    out.into_iter().map(|v| v.expect("pool missed an index")).collect()
}

// ---- persistent compute pool ----------------------------------------------

/// Snapshot of a [`ComputePool`]'s utilization counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// threads the pool applies to a job (background workers + the
    /// participating dispatcher)
    pub threads: usize,
    /// threads currently executing a job body
    pub busy: usize,
    /// jobs broadcast to the workers since the pool started (one per
    /// `map`/`map_init` call that actually went parallel)
    pub jobs: u64,
    /// jobs that found the pool busy (or worker-less) and ran inline on
    /// the caller instead — the serialized fallback under contention
    pub inline_jobs: u64,
    /// index chunks claimed from job cursors since the pool started
    pub chunks: u64,
}

/// Type-erased pointer to a dispatched job body. Only valid while the
/// dispatching [`ComputePool::run`] call is blocked waiting for every
/// worker to finish — see the SAFETY notes at the two uses.
struct RawJob {
    body: *const (dyn Fn() + Sync),
}

// SAFETY: workers only ever take a `&dyn Fn` to the (Sync) pointee, and the
// dispatch protocol guarantees the pointee outlives every worker's use.
unsafe impl Send for RawJob {}

struct ComputeState {
    /// bumped per dispatched job; workers run each epoch exactly once
    epoch: u64,
    /// the current job; `Some` from dispatch until every worker finished
    job: Option<RawJob>,
    /// workers that have not yet finished the current epoch
    remaining: usize,
    /// a worker caught a panic from the current job body
    panicked: bool,
    shutdown: bool,
}

struct ComputeShared {
    state: Mutex<ComputeState>,
    /// workers park here between jobs
    work: Condvar,
    /// the dispatcher parks here until `remaining == 0`
    done: Condvar,
    busy: AtomicUsize,
    jobs: AtomicU64,
    inline_jobs: AtomicU64,
    chunks: AtomicU64,
}

/// Persistent, shareable worker pool for index-range maps. See the module
/// docs for the architecture (dispatch modes, sizing, contention,
/// shutdown). Cheap to share: wrap it in an `Arc` and hand one instance to
/// every engine in the process.
pub struct ComputePool {
    shared: Arc<ComputeShared>,
    handles: Vec<JoinHandle<()>>,
    /// serializes jobs; `try_lock` contention makes the caller run inline
    dispatch: Mutex<()>,
    threads: usize,
}

impl ComputePool {
    /// Build a pool that applies `threads` threads to each job:
    /// `threads - 1` parked background workers plus the dispatching caller.
    pub fn new(threads: usize) -> ComputePool {
        let threads = threads.max(1);
        let shared = Arc::new(ComputeShared {
            state: Mutex::new(ComputeState {
                epoch: 0,
                job: None,
                remaining: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            busy: AtomicUsize::new(0),
            jobs: AtomicU64::new(0),
            inline_jobs: AtomicU64::new(0),
            chunks: AtomicU64::new(0),
        });
        let handles = (0..threads - 1)
            .map(|_| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || compute_worker(&sh))
            })
            .collect();
        ComputePool { shared, handles, dispatch: Mutex::new(()), threads }
    }

    /// Threads applied to each job (background workers + caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Current utilization counters.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            threads: self.threads,
            busy: self.shared.busy.load(Ordering::Relaxed),
            jobs: self.shared.jobs.load(Ordering::Relaxed),
            inline_jobs: self.shared.inline_jobs.load(Ordering::Relaxed),
            chunks: self.shared.chunks.load(Ordering::Relaxed),
        }
    }

    /// [`parallel_map`] served from the persistent workers.
    pub fn map<T: Send, F: Fn(usize) -> T + Sync>(&self, n: usize, f: F) -> Vec<T> {
        self.map_init(n, || (), |_, i| f(i))
    }

    /// [`parallel_map_init`] served from the persistent workers: apply `f`
    /// to every index in `0..n`, collecting results in index order, with
    /// per-worker state from `init`. Bit-identical to the scoped and
    /// serial paths.
    pub fn map_init<T, S, I, F>(&self, n: usize, init: I, f: F) -> Vec<T>
    where
        T: Send,
        I: Fn() -> S + Sync,
        F: Fn(&mut S, usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        if self.threads == 1 || n == 1 {
            let mut st = init();
            return (0..n).map(|i| f(&mut st, i)).collect();
        }
        let chunk = chunk_size(n, self.threads.min(n));
        let next = AtomicUsize::new(0);
        let parts: Mutex<Vec<Vec<(usize, T)>>> = Mutex::new(Vec::with_capacity(self.threads));
        let chunks = &self.shared.chunks;
        let body = || {
            let mut st = init();
            let mut local: Vec<(usize, T)> = Vec::new();
            loop {
                let start = next.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                chunks.fetch_add(1, Ordering::Relaxed);
                let end = (start + chunk).min(n);
                if local.capacity() == 0 {
                    local.reserve(n / self.threads + chunk);
                }
                for i in start..end {
                    local.push((i, f(&mut st, i)));
                }
            }
            if !local.is_empty() {
                parts.lock().unwrap().push(local);
            }
        };
        self.run(&body);
        stitch(parts.into_inner().unwrap(), n)
    }

    /// Broadcast `body` to every pool thread (workers + this caller) and
    /// block until all of them finished running it.
    fn run(&self, body: &(dyn Fn() + Sync)) {
        // Serialize jobs. A contended (or poisoned) dispatch runs the body
        // inline on the caller — the body claims every chunk itself, which
        // is exactly the serial path — instead of convoying callers. The
        // two cases are counted separately so `jobs` vs `inline_jobs` on
        // the metrics surface shows how often contention serialized work.
        let guard = match self.dispatch.try_lock() {
            Ok(g) if !self.handles.is_empty() => g,
            _ => {
                self.shared.inline_jobs.fetch_add(1, Ordering::Relaxed);
                self.shared.busy.fetch_add(1, Ordering::Relaxed);
                body();
                self.shared.busy.fetch_sub(1, Ordering::Relaxed);
                return;
            }
        };
        self.shared.jobs.fetch_add(1, Ordering::Relaxed);
        {
            // SAFETY: the body pointer is only dereferenced by workers
            // between this publish and the `remaining == 0` acknowledgment
            // below; we do not return (or unwind) past that wait, so the
            // borrow never outlives the caller's frame.
            let body_static: &'static (dyn Fn() + Sync) =
                unsafe { std::mem::transmute::<&(dyn Fn() + Sync), _>(body) };
            let mut st = self.shared.state.lock().unwrap();
            st.epoch = st.epoch.wrapping_add(1);
            st.job = Some(RawJob { body: body_static });
            st.remaining = self.handles.len();
            st.panicked = false;
        }
        self.shared.work.notify_all();
        // the dispatcher participates in its own job
        self.shared.busy.fetch_add(1, Ordering::Relaxed);
        let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(body));
        self.shared.busy.fetch_sub(1, Ordering::Relaxed);
        let worker_panicked = {
            let mut st = self.shared.state.lock().unwrap();
            while st.remaining > 0 {
                st = self.shared.done.wait(st).unwrap();
            }
            st.job = None;
            st.panicked
        };
        drop(guard);
        if let Err(p) = caller {
            std::panic::resume_unwind(p);
        }
        if worker_panicked {
            panic!("compute pool worker panicked");
        }
    }
}

impl Drop for ComputePool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn compute_worker(shared: &ComputeShared) {
    let mut seen = 0u64;
    loop {
        let body = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    if let Some(job) = &st.job {
                        seen = st.epoch;
                        break job.body;
                    }
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        shared.busy.fetch_add(1, Ordering::Relaxed);
        // SAFETY: the dispatcher blocks until every worker decremented
        // `remaining` for this epoch, so the pointee is alive for the
        // whole call. Panics are caught so a bad job body cannot kill a
        // pool thread or poison the state lock.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe { (*body)() }));
        shared.busy.fetch_sub(1, Ordering::Relaxed);
        let mut st = shared.state.lock().unwrap();
        if r.is_err() {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done.notify_one();
        }
    }
}

struct PoolState<T> {
    items: VecDeque<T>,
    closed: bool,
}

struct PoolQueue<T> {
    state: Mutex<PoolState<T>>,
    not_empty: Condvar,
    cap: usize,
}

/// Persistent bounded task pool: `threads` long-lived workers drain a
/// queue of dispatched items. Unlike the scoped helpers above, workers
/// outlive any single call, so per-item dispatch is one lock round-trip
/// instead of a thread spawn. The queue is bounded: [`WorkerPool::try_dispatch`]
/// hands the item back when every worker is busy and the backlog is full,
/// letting the caller shed load instead of queueing without bound.
pub struct WorkerPool<T: Send + 'static> {
    shared: Arc<PoolQueue<T>>,
    workers: Vec<JoinHandle<()>>,
}

impl<T: Send + 'static> WorkerPool<T> {
    /// Spawn `threads` workers running `handler` over dispatched items.
    /// `cap` bounds the backlog of items waiting for a free worker.
    pub fn new<F>(threads: usize, cap: usize, handler: F) -> WorkerPool<T>
    where
        F: Fn(T) + Send + Sync + 'static,
    {
        let shared = Arc::new(PoolQueue {
            state: Mutex::new(PoolState { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            cap: cap.max(1),
        });
        let handler = Arc::new(handler);
        let workers = (0..threads.max(1))
            .map(|_| {
                let sh = Arc::clone(&shared);
                let h = Arc::clone(&handler);
                std::thread::spawn(move || loop {
                    let item = {
                        let mut st = sh.state.lock().unwrap();
                        loop {
                            if let Some(it) = st.items.pop_front() {
                                break it;
                            }
                            if st.closed {
                                return;
                            }
                            st = sh.not_empty.wait(st).unwrap();
                        }
                    };
                    h(item);
                })
            })
            .collect();
        WorkerPool { shared, workers }
    }

    /// Queue an item for the next free worker. `Err(item)` hands the item
    /// back when the backlog is at capacity or the pool is shutting down.
    pub fn try_dispatch(&self, item: T) -> Result<(), T> {
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.closed || st.items.len() >= self.shared.cap {
                return Err(item);
            }
            st.items.push_back(item);
        }
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Items dispatched but not yet claimed by a worker.
    pub fn backlog(&self) -> usize {
        self.shared.state.lock().unwrap().items.len()
    }

    /// Stop accepting new items, let workers finish every queued item,
    /// and join them.
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        self.shared.state.lock().unwrap().closed = true;
        self.shared.not_empty.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl<T: Send + 'static> Drop for WorkerPool<T> {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn maps_in_order() {
        let v = parallel_map(100, 4, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty() {
        let v: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn single_thread_path() {
        let v = parallel_map(10, 1, |i| i + 1);
        assert_eq!(v[9], 10);
    }

    #[test]
    fn init_state_reused() {
        // each worker counts its own items; total must equal n
        let counts = parallel_map_init(
            1000,
            4,
            || 0usize,
            |st, i| {
                *st += 1;
                (i, *st)
            },
        );
        assert_eq!(counts.len(), 1000);
        // state is per-worker, so per-item counters are <= n
        assert!(counts.iter().all(|&(_, c)| c >= 1 && c <= 1000));
    }

    #[test]
    fn every_index_computed_exactly_once() {
        // sum over f(i)=1 must be n for ragged n/thread/chunk combinations
        for &(n, threads) in &[(1usize, 8usize), (7, 3), (64, 4), (1000, 7), (1025, 16)] {
            let calls = AtomicU64::new(0);
            let v = parallel_map(n, threads, |i| {
                calls.fetch_add(1, Ordering::Relaxed);
                i
            });
            assert_eq!(v, (0..n).collect::<Vec<_>>(), "n={n} threads={threads}");
            assert_eq!(calls.load(Ordering::Relaxed), n as u64, "n={n} threads={threads}");
        }
    }

    #[test]
    fn chunk_size_bounds() {
        assert_eq!(chunk_size(1, 8), 1);
        assert_eq!(chunk_size(100, 4), 3);
        assert!(chunk_size(1_000_000, 2) <= 1024);
    }

    #[test]
    fn worker_pool_processes_every_dispatched_item() {
        let done = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&done);
        let pool = WorkerPool::new(4, 1024, move |v: u64| {
            d.fetch_add(v, Ordering::Relaxed);
        });
        let mut sum = 0u64;
        for i in 1..=500u64 {
            pool.try_dispatch(i).expect("queue has room");
            sum += i;
        }
        // shutdown drains the backlog before joining
        pool.shutdown();
        assert_eq!(done.load(Ordering::Relaxed), sum);
    }

    #[test]
    fn worker_pool_sheds_when_full() {
        // a single worker blocked on the first item; cap 2 means the 4th
        // dispatch (1 in flight + 2 queued) must hand the item back
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g = Arc::clone(&gate);
        let pool = WorkerPool::new(1, 2, move |_v: u32| {
            let (lock, cv) = &*g;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        });
        pool.try_dispatch(1).unwrap();
        // wait until the worker has claimed item 1 so the backlog is empty
        while pool.backlog() > 0 {
            std::thread::yield_now();
        }
        pool.try_dispatch(2).unwrap();
        pool.try_dispatch(3).unwrap();
        match pool.try_dispatch(4) {
            Err(item) => assert_eq!(item, 4, "rejected item is handed back"),
            Ok(()) => panic!("dispatch past the bound must shed"),
        }
        // open the gate so shutdown can drain and join
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
        pool.shutdown();
    }

    #[test]
    fn worker_pool_drop_joins_without_hanging() {
        let pool = WorkerPool::new(2, 8, |_: usize| {});
        pool.try_dispatch(1).unwrap();
        drop(pool);
    }

    // ---- ComputePool ------------------------------------------------------

    #[test]
    fn compute_pool_matches_serial_across_thread_counts() {
        // the ISSUE contract: pool results are bit-identical to the serial
        // path for every thread count and ragged n
        for threads in [1usize, 2, 3, 8] {
            let pool = ComputePool::new(threads);
            for n in [0usize, 1, 7, 64, 1000, 1025] {
                let want: Vec<usize> = (0..n).map(|i| i * i + 3).collect();
                let got = pool.map(n, |i| i * i + 3);
                assert_eq!(got, want, "threads={threads} n={n}");
            }
        }
    }

    #[test]
    fn compute_pool_every_index_computed_exactly_once() {
        let pool = ComputePool::new(4);
        for &n in &[1usize, 7, 64, 1000, 1025] {
            let calls = AtomicU64::new(0);
            let v = pool.map(n, |i| {
                calls.fetch_add(1, Ordering::Relaxed);
                i
            });
            assert_eq!(v, (0..n).collect::<Vec<_>>(), "n={n}");
            assert_eq!(calls.load(Ordering::Relaxed), n as u64, "n={n}");
        }
    }

    #[test]
    fn compute_pool_init_state_is_per_worker() {
        let pool = ComputePool::new(4);
        let counts = pool.map_init(
            1000,
            || 0usize,
            |st, i| {
                *st += 1;
                (i, *st)
            },
        );
        assert_eq!(counts.len(), 1000);
        assert!(counts.iter().all(|&(_, c)| c >= 1 && c <= 1000));
    }

    #[test]
    fn compute_pool_reusable_across_many_jobs() {
        // persistent workers must serve many back-to-back jobs without
        // leaking state between them
        let pool = ComputePool::new(4);
        for round in 0..50usize {
            let v = pool.map(round + 1, move |i| i + round);
            assert_eq!(v.len(), round + 1);
            assert_eq!(v[0], round);
        }
        let s = pool.stats();
        assert_eq!(s.threads, 4);
        assert!(s.jobs >= 49, "jobs dispatched: {}", s.jobs);
        assert_eq!(s.inline_jobs, 0, "a single caller can never contend the dispatch");
        assert!(s.chunks >= s.jobs, "chunks claimed: {}", s.chunks);
        assert_eq!(s.busy, 0, "idle pool must report zero busy workers");
    }

    #[test]
    fn compute_pool_concurrent_callers_all_complete() {
        // several threads share one pool; contended dispatches fall back to
        // inline execution and every caller still gets exact results
        let pool = Arc::new(ComputePool::new(4));
        let mut joins = Vec::new();
        for t in 0..6u64 {
            let p = Arc::clone(&pool);
            joins.push(std::thread::spawn(move || {
                for n in [5usize, 117, 1000] {
                    let v = p.map(n, move |i| i as u64 * 2 + t);
                    assert_eq!(v.len(), n);
                    for (i, &x) in v.iter().enumerate() {
                        assert_eq!(x, i as u64 * 2 + t);
                    }
                }
            }));
        }
        for j in joins {
            j.join().expect("caller thread panicked");
        }
    }

    #[test]
    fn compute_pool_propagates_job_panics_and_survives() {
        let pool = ComputePool::new(4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.map(100, |i| {
                if i == 57 {
                    panic!("boom");
                }
                i
            })
        }));
        assert!(r.is_err(), "panic in the job body must reach the caller");
        // the pool still works after a panicked job
        let v = pool.map(10, |i| i);
        assert_eq!(v, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn compute_pool_drop_joins_without_hanging() {
        let pool = ComputePool::new(8);
        let _ = pool.map(100, |i| i);
        drop(pool);
    }
}
