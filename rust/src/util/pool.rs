//! Scoped thread-pool helpers (no tokio/rayon offline).
//!
//! `parallel_map` splits work across `n_threads` scoped workers pulling
//! indices from a shared atomic counter (work stealing by chunk); results
//! land in order. The evaluation coordinator builds on this.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use (PQS_THREADS env or available cores).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PQS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every index in 0..n on `threads` scoped workers, collecting
/// results in index order. `f` must be Sync; per-item state should live
/// inside `f` (e.g. thread-locals are overkill — construct scratch per call
/// or use `parallel_map_init`).
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, threads: usize, f: F) -> Vec<T> {
    parallel_map_init(n, threads, || (), |_, i| f(i))
}

/// Like `parallel_map` but each worker gets its own state from `init`
/// (scratch buffers, engines) reused across its items.
pub fn parallel_map_init<T, S, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        let mut st = init();
        return (0..n).map(|i| f(&mut st, i)).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut st = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(&mut st, i);
                    *out[i].lock().unwrap() = Some(v);
                }
            });
        }
    });
    out.into_iter().map(|m| m.into_inner().unwrap().unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let v = parallel_map(100, 4, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty() {
        let v: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn single_thread_path() {
        let v = parallel_map(10, 1, |i| i + 1);
        assert_eq!(v[9], 10);
    }

    #[test]
    fn init_state_reused() {
        // each worker counts its own items; total must equal n
        let counts = parallel_map_init(
            1000,
            4,
            || 0usize,
            |st, i| {
                *st += 1;
                (i, *st)
            },
        );
        assert_eq!(counts.len(), 1000);
        // state is per-worker, so per-item counters are <= n
        assert!(counts.iter().all(|&(_, c)| c >= 1 && c <= 1000));
    }
}
