//! Thread-pool helpers (no tokio/rayon offline).
//!
//! `parallel_map` splits the index range `0..n` across `n_threads` scoped
//! workers. Workers claim *chunks* of consecutive indices from a shared
//! atomic cursor (one fetch-add per chunk, not per item), compute results
//! into a private buffer, and the buffers are stitched back into index
//! order after the scope joins — no per-item locking anywhere. The
//! evaluation coordinator and the engine's intra-forward parallelism build
//! on this.
//!
//! [`WorkerPool`] is the persistent counterpart: long-lived workers drain
//! a bounded queue of dispatched items, with `try_dispatch` handing the
//! item back when the queue is full so callers can shed load. The HTTP
//! front-end (`crate::http`) uses it as its bounded connection pool.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Number of worker threads to use (PQS_THREADS env or available cores).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PQS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Chunk of indices claimed per cursor fetch: large enough to amortize the
/// atomic, small enough (>= 8 chunks per worker) to balance uneven items.
fn chunk_size(n: usize, threads: usize) -> usize {
    (n / (threads * 8)).clamp(1, 1024)
}

/// Apply `f` to every index in 0..n on `threads` scoped workers, collecting
/// results in index order. `f` must be Sync; per-item state should live
/// inside `f` (construct scratch per call or use `parallel_map_init`).
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, threads: usize, f: F) -> Vec<T> {
    parallel_map_init(n, threads, || (), |_, i| f(i))
}

/// Like `parallel_map` but each worker gets its own state from `init`
/// (scratch buffers, engines) reused across all items it claims.
pub fn parallel_map_init<T, S, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        let mut st = init();
        return (0..n).map(|i| f(&mut st, i)).collect();
    }
    let chunk = chunk_size(n, threads);
    let next = AtomicUsize::new(0);
    let mut parts: Vec<Vec<(usize, T)>> = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut st = init();
                    let mut local: Vec<(usize, T)> = Vec::with_capacity(n / threads + chunk);
                    loop {
                        let start = next.fetch_add(chunk, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        let end = (start + chunk).min(n);
                        for i in start..end {
                            local.push((i, f(&mut st, i)));
                        }
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            parts.push(h.join().expect("pool worker panicked"));
        }
    });
    // stitch the per-worker runs back into index order
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for part in parts {
        for (i, v) in part {
            debug_assert!(out[i].is_none(), "index {i} produced twice");
            out[i] = Some(v);
        }
    }
    out.into_iter().map(|v| v.expect("pool missed an index")).collect()
}

struct PoolState<T> {
    items: VecDeque<T>,
    closed: bool,
}

struct PoolQueue<T> {
    state: Mutex<PoolState<T>>,
    not_empty: Condvar,
    cap: usize,
}

/// Persistent bounded task pool: `threads` long-lived workers drain a
/// queue of dispatched items. Unlike the scoped helpers above, workers
/// outlive any single call, so per-item dispatch is one lock round-trip
/// instead of a thread spawn. The queue is bounded: [`WorkerPool::try_dispatch`]
/// hands the item back when every worker is busy and the backlog is full,
/// letting the caller shed load instead of queueing without bound.
pub struct WorkerPool<T: Send + 'static> {
    shared: Arc<PoolQueue<T>>,
    workers: Vec<JoinHandle<()>>,
}

impl<T: Send + 'static> WorkerPool<T> {
    /// Spawn `threads` workers running `handler` over dispatched items.
    /// `cap` bounds the backlog of items waiting for a free worker.
    pub fn new<F>(threads: usize, cap: usize, handler: F) -> WorkerPool<T>
    where
        F: Fn(T) + Send + Sync + 'static,
    {
        let shared = Arc::new(PoolQueue {
            state: Mutex::new(PoolState { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            cap: cap.max(1),
        });
        let handler = Arc::new(handler);
        let workers = (0..threads.max(1))
            .map(|_| {
                let sh = Arc::clone(&shared);
                let h = Arc::clone(&handler);
                std::thread::spawn(move || loop {
                    let item = {
                        let mut st = sh.state.lock().unwrap();
                        loop {
                            if let Some(it) = st.items.pop_front() {
                                break it;
                            }
                            if st.closed {
                                return;
                            }
                            st = sh.not_empty.wait(st).unwrap();
                        }
                    };
                    h(item);
                })
            })
            .collect();
        WorkerPool { shared, workers }
    }

    /// Queue an item for the next free worker. `Err(item)` hands the item
    /// back when the backlog is at capacity or the pool is shutting down.
    pub fn try_dispatch(&self, item: T) -> Result<(), T> {
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.closed || st.items.len() >= self.shared.cap {
                return Err(item);
            }
            st.items.push_back(item);
        }
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Items dispatched but not yet claimed by a worker.
    pub fn backlog(&self) -> usize {
        self.shared.state.lock().unwrap().items.len()
    }

    /// Stop accepting new items, let workers finish every queued item,
    /// and join them.
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        self.shared.state.lock().unwrap().closed = true;
        self.shared.not_empty.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl<T: Send + 'static> Drop for WorkerPool<T> {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn maps_in_order() {
        let v = parallel_map(100, 4, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty() {
        let v: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn single_thread_path() {
        let v = parallel_map(10, 1, |i| i + 1);
        assert_eq!(v[9], 10);
    }

    #[test]
    fn init_state_reused() {
        // each worker counts its own items; total must equal n
        let counts = parallel_map_init(
            1000,
            4,
            || 0usize,
            |st, i| {
                *st += 1;
                (i, *st)
            },
        );
        assert_eq!(counts.len(), 1000);
        // state is per-worker, so per-item counters are <= n
        assert!(counts.iter().all(|&(_, c)| c >= 1 && c <= 1000));
    }

    #[test]
    fn every_index_computed_exactly_once() {
        // sum over f(i)=1 must be n for ragged n/thread/chunk combinations
        for &(n, threads) in &[(1usize, 8usize), (7, 3), (64, 4), (1000, 7), (1025, 16)] {
            let calls = AtomicU64::new(0);
            let v = parallel_map(n, threads, |i| {
                calls.fetch_add(1, Ordering::Relaxed);
                i
            });
            assert_eq!(v, (0..n).collect::<Vec<_>>(), "n={n} threads={threads}");
            assert_eq!(calls.load(Ordering::Relaxed), n as u64, "n={n} threads={threads}");
        }
    }

    #[test]
    fn chunk_size_bounds() {
        assert_eq!(chunk_size(1, 8), 1);
        assert_eq!(chunk_size(100, 4), 3);
        assert!(chunk_size(1_000_000, 2) <= 1024);
    }

    #[test]
    fn worker_pool_processes_every_dispatched_item() {
        let done = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&done);
        let pool = WorkerPool::new(4, 1024, move |v: u64| {
            d.fetch_add(v, Ordering::Relaxed);
        });
        let mut sum = 0u64;
        for i in 1..=500u64 {
            pool.try_dispatch(i).expect("queue has room");
            sum += i;
        }
        // shutdown drains the backlog before joining
        pool.shutdown();
        assert_eq!(done.load(Ordering::Relaxed), sum);
    }

    #[test]
    fn worker_pool_sheds_when_full() {
        // a single worker blocked on the first item; cap 2 means the 4th
        // dispatch (1 in flight + 2 queued) must hand the item back
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g = Arc::clone(&gate);
        let pool = WorkerPool::new(1, 2, move |_v: u32| {
            let (lock, cv) = &*g;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        });
        pool.try_dispatch(1).unwrap();
        // wait until the worker has claimed item 1 so the backlog is empty
        while pool.backlog() > 0 {
            std::thread::yield_now();
        }
        pool.try_dispatch(2).unwrap();
        pool.try_dispatch(3).unwrap();
        match pool.try_dispatch(4) {
            Err(item) => assert_eq!(item, 4, "rejected item is handed back"),
            Ok(()) => panic!("dispatch past the bound must shed"),
        }
        // open the gate so shutdown can drain and join
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
        pool.shutdown();
    }

    #[test]
    fn worker_pool_drop_joins_without_hanging() {
        let pool = WorkerPool::new(2, 8, |_: usize| {});
        pool.try_dispatch(1).unwrap();
        drop(pool);
    }
}
