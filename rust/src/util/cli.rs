//! Tiny CLI argument parser (no clap offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, *repeated*
//! flags (`--model a --model b`, read back with [`Args::get_all`]), and
//! positional arguments. Used by the `pqs` binary and the examples.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    /// last-wins view of the flags (the single-value accessors)
    pub flags: BTreeMap<String, String>,
    /// every flag occurrence in command-line order, for repeatable flags
    /// like `serve-http --model a --model b`
    pub occurrences: Vec<(String, String)>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — `std::env::args().skip(1)`.
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        fn set(out: &mut Args, k: String, v: String) {
            out.flags.insert(k.clone(), v.clone());
            out.occurrences.push((k, v));
        }
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    set(&mut out, k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    set(&mut out, rest.to_string(), v);
                } else {
                    set(&mut out, rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u32(&self, key: &str, default: u32) -> u32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Every value a repeatable flag was given, in command-line order
    /// (`--model a --model b` → `["a", "b"]`). Empty when absent.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.occurrences
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        // note: `--flag value`-style binds the next non-flag token, so pure
        // boolean flags must use `--flag` at the end or `--flag=true`.
        let a = parse(&["run", "--model", "mlp", "--acc-bits=14", "x", "--verbose"]);
        assert_eq!(a.positional, vec!["run", "x"]);
        assert_eq!(a.get("model"), Some("mlp"));
        assert_eq!(a.get_u32("acc-bits", 0), 14);
        assert!(a.has("verbose"));
        assert_eq!(a.get("verbose"), Some("true"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_or("x", "d"), "d");
        assert!(!a.has("q"));
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["--last"]);
        assert_eq!(a.get("last"), Some("true"));
    }

    #[test]
    fn repeated_flags_keep_every_occurrence_in_order() {
        let a = parse(&["serve-http", "--model", "a", "--model=b=conv:2x8x8x4x10", "--model", "c"]);
        // single-value accessors see the last occurrence
        assert_eq!(a.get("model"), Some("c"));
        // get_all sees them all, in command-line order, '=' payload intact
        assert_eq!(a.get_all("model"), vec!["a", "b=conv:2x8x8x4x10", "c"]);
        assert!(a.get_all("missing").is_empty());
    }
}
