//! The bit-accurate quantized inference engine — the paper's §5.0.1
//! "library for analyzing overflows", as a graph interpreter.
//!
//! Every conv/linear MAC flows through a width-limited accumulator under a
//! configurable `Policy`; the engine optionally classifies every dot
//! product (transient/persistent, paper §3.1) while it computes.
//!
//! ### Fast path for the full sorted policy
//! Algorithm 1 with exact 2b-bit pairing temporaries provably returns
//! `clamp(exact)` with zero accumulation overflows whenever the exact
//! result fits (the terminal phase is single-sign, hence monotone — see
//! `dot::sorted` property tests, which assert this equivalence against the
//! real multi-round implementation). The engine therefore evaluates
//! `Policy::Sorted` in O(K) instead of O(K log K); `Policy::Sorted1` and
//! the tiled variant run the real sorting machinery.
//!
//! ### Interpreter state
//! Values flow through an indexed arena (`Vec<Option<TensorF>>`, one slot
//! per graph node, ids remapped to dense slots at construction). Each value
//! is dropped at its statically computed last use, and single-consumer
//! ReLU/Add/Flatten steal their input buffer instead of cloning — the
//! interpreter allocates one tensor per producing node and nothing else.
//!
//! ### Intra-forward parallelism
//! `Engine::with_threads(n)` parallelizes the hot loops over `util::pool`
//! with per-worker scratch; `Engine::with_pool` serves the same splits from
//! a shared persistent [`ComputePool`] (no per-layer thread spawns, and N
//! engines sharing one pool cannot oversubscribe the machine). The split
//! adapts to the batch: large batches go image-/row-parallel, while small
//! batches — the batch-1 serving hot path — split *inside* the layer
//! (conv output positions in blocks, depthwise channels, linear output
//! rows). Results are bit-identical to the serial path on every split:
//! every dot product is an independent computation and overflow statistics
//! merge commutatively.
//!
//! ### Per-layer accumulator widths
//! A model carrying an embedded accumulator-bitwidth plan
//! ([`crate::plan::AccumPlan`], matched to q-layers by name) is enforced
//! automatically: each planned layer runs at its own `acc_bits`,
//! overriding the global [`EngineConfig::acc_bits`] default. Plan-free
//! models are bit-identical to the pre-plan engine — the override table
//! is all-`None` and the global config flows through untouched.
//! [`Engine::apply_plan`] / [`Engine::clear_plan`] adjust the overrides
//! after construction (the calibration planner uses `clear_plan` to
//! measure a model at the wide reference width).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::accum::{self, Policy};
use crate::dot::{tiled_sorted_dot, DotEngine};
use crate::formats::pqsw::{Op, PqswModel};
use crate::overflow::{OverflowReport, OverflowStats};
use crate::plan::AccumPlan;
use crate::quant;
use crate::tensor::{conv_out_dim, im2col, im2col_grouped, TensorF};
use crate::util::pool::{self, ComputePool};

use super::layer::QLayer;

/// Engine configuration: accumulation policy, width, optional k-tiling
/// (paper §6) and whether to collect overflow statistics.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    pub policy: Policy,
    pub acc_bits: u32,
    /// tile size for `Policy::Sorted1` (0 = full-width sort)
    pub tile: usize,
    /// classify every dot product (slower; needed for Figs. 2/5 analyses)
    pub collect_stats: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { policy: Policy::Sorted, acc_bits: 16, tile: 0, collect_stats: false }
    }
}

/// Result of one forward pass.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub logits: Vec<f32>,
    pub batch: usize,
    pub classes: usize,
    pub report: OverflowReport,
    /// wall time spent in each q-layer, graph order, µs (always
    /// populated — two clock reads per layer; feeds request traces)
    pub layer_us: Vec<(String, f64)>,
}

impl EvalResult {
    pub fn argmax(&self, i: usize) -> usize {
        let row = &self.logits[i * self.classes..(i + 1) * self.classes];
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap_or(0)
    }

    pub fn accuracy(&self, labels: &[u8]) -> f64 {
        let correct = (0..self.batch).filter(|&i| self.argmax(i) == labels[i] as usize).count();
        correct as f64 / self.batch.max(1) as f64
    }
}

/// Per-worker scratch for evaluating dot-product rows (allocation-free hot
/// path; one instance per pool worker on the parallel path).
#[derive(Default)]
struct RowScratch {
    dot: DotEngine,
    prods: Vec<i32>,
}

/// Scratch buffers for the serial path, shared across layers.
#[derive(Default)]
struct Scratch {
    row: RowScratch,
    qbuf: Vec<i32>,
    colbuf: Vec<i32>,
}

/// The graph-interpreting engine. Construct once per (model, config);
/// `forward` may be called repeatedly.
pub struct Engine {
    pub cfg: EngineConfig,
    pub model_name: String,
    input_shape: Vec<usize>,
    nodes: Vec<EngineNode>,
    /// node index of the last consumer of each slot's value
    /// (`usize::MAX` for the output slot: never freed mid-run)
    last_use: Vec<usize>,
    /// per-node accumulator-width override from the model's embedded plan
    /// (`None` = the global `cfg.acc_bits` applies; always `None` for
    /// non-q nodes and plan-free models)
    layer_bits: Vec<Option<u32>>,
    out_slot: usize,
    scratch: Scratch,
    threads: usize,
    /// shared persistent pool for the parallel splits (scoped spawns when
    /// absent)
    pool: Option<Arc<ComputePool>>,
}

/// Dispatch an index-range map on the engine's shared persistent pool when
/// it has one, else on per-call scoped threads. Same chunked claiming,
/// same index-order stitching — bit-identical either way.
fn pmap_init<T, S, I, F>(
    pool: Option<&ComputePool>,
    n: usize,
    threads: usize,
    init: I,
    f: F,
) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    match pool {
        Some(p) => p.map_init(n, init, f),
        None => pool::parallel_map_init(n, threads, init, f),
    }
}

struct EngineNode {
    op: Op,
    /// dense slot indices (graph ids are remapped at construction)
    inputs: Vec<usize>,
    layer: Option<QLayer>,
}

/// Evaluate one dot product under the config; updates stats when present.
///
/// Stats collection uses one fused scan computing the exact sum AND the
/// naive clipped accumulation simultaneously (perf pass: the separate
/// `classify` + policy scans cost ~1.5x; see EXPERIMENTS.md §Perf).
#[inline]
fn eval_dot(
    dot: &mut DotEngine,
    cfg: &EngineConfig,
    prods: &[i32],
    stats: Option<&mut OverflowStats>,
) -> i64 {
    let p = cfg.acc_bits;
    let (lo, hi) = accum::acc_range(p);

    if let Some(st) = stats {
        // fused exact + naive-clip scan, also tracking the index-order
        // prefix extremes of the exact sum (the width requirement of the
        // order-dependent policies)
        let mut exact = 0i64;
        let mut prefix_lo = 0i64;
        let mut prefix_hi = 0i64;
        let mut acc = 0i64;
        let mut naive_events = 0u32;
        for &v in prods {
            exact += v as i64;
            if exact < prefix_lo {
                prefix_lo = exact;
            } else if exact > prefix_hi {
                prefix_hi = exact;
            }
            let t = acc + v as i64;
            acc = if t < lo {
                naive_events += 1;
                lo
            } else if t > hi {
                naive_events += 1;
                hi
            } else {
                t
            };
        }
        let persistent = exact < lo || exact > hi;
        let (v, ev) = match cfg.policy {
            Policy::Exact => (exact, 0u32),
            Policy::Sorted | Policy::Oracle => {
                (exact.clamp(lo, hi), u32::from(persistent))
            }
            Policy::Clip => (acc, naive_events),
            Policy::Wrap => accum::wrap_accumulate(prods, p),
            Policy::Sorted1 => {
                if cfg.tile > 0 {
                    tiled_sorted_dot(dot, prods, p, cfg.tile)
                } else {
                    crate::dot::sorted1_dot(dot, prods, p)
                }
            }
        };
        st.dots += 1;
        st.products += prods.len() as u64;
        // per-dot required width (drives the calibration planner): the
        // width at which THIS policy's accumulation of this dot is
        // event-free. The sorting/exact policies return clamp(exact), so
        // the final value's width suffices; Clip/Wrap accumulate in index
        // order, so every prefix must fit — a final-value width would let
        // a cancelling dot (e.g. [+20000, -20000]) saturate mid-sum and
        // silently corrupt the output while reporting zero persistent
        // overflows. Mirrors the per-policy analytic bound
        // (`plan::analytic_layer_range`).
        let required = match cfg.policy {
            Policy::Clip | Policy::Wrap => accum::bits_for_range(prefix_lo, prefix_hi),
            _ => accum::bits_for_value(exact),
        };
        st.record_required_bits(required);
        if naive_events > 0 {
            st.naive_event_dots += 1;
        }
        st.naive_events += naive_events as u64;
        if naive_events > 0 && !persistent {
            st.transient_dots += 1;
        }
        if persistent {
            st.persistent_dots += 1;
        }
        if ev > 0 {
            st.policy_event_dots += 1;
        }
        return v;
    }

    let (v, _ev) = match cfg.policy {
        Policy::Exact => (accum::exact_dot(prods), 0u32),
        Policy::Sorted | Policy::Oracle => {
            // fast path: Algorithm 1 == clamp(exact), events iff persistent
            let exact = accum::exact_dot(prods);
            (exact.clamp(lo, hi), 0)
        }
        Policy::Sorted1 => {
            if cfg.tile > 0 {
                tiled_sorted_dot(dot, prods, p, cfg.tile)
            } else {
                crate::dot::sorted1_dot(dot, prods, p)
            }
        }
        Policy::Clip => accum::clip_accumulate(prods, p),
        Policy::Wrap => accum::wrap_accumulate(prods, p),
    };
    v
}

/// Evaluate one weight-row x activation dot product, using the fused
/// buffer-free paths when no statistics are collected (perf pass §Perf:
/// skipping the intermediate product buffer is worth ~25-40% end-to-end).
#[inline]
fn eval_row(
    layer: &QLayer,
    cfg: &EngineConfig,
    rs: &mut RowScratch,
    o: usize,
    x: &[i32],
    stats: Option<&mut OverflowStats>,
) -> i64 {
    if stats.is_none() {
        match cfg.policy {
            Policy::Exact => return layer.w.dot_exact(o, x),
            Policy::Sorted | Policy::Oracle => {
                // Algorithm 1 fast path (see module docs): clamp(exact)
                let exact = layer.w.dot_exact(o, x);
                let (lo, hi) = accum::acc_range(cfg.acc_bits);
                return exact.clamp(lo, hi);
            }
            Policy::Clip => return layer.w.dot_clip(o, x, cfg.acc_bits).0,
            _ => {}
        }
    }
    layer.w.dot_products_into(o, x, &mut rs.prods);
    let prods = std::mem::take(&mut rs.prods);
    let v = eval_dot(&mut rs.dot, cfg, &prods, stats);
    rs.prods = prods;
    v
}

impl Engine {
    pub fn new(model: &PqswModel, cfg: EngineConfig) -> Engine {
        let mut id_to_slot: BTreeMap<usize, usize> = BTreeMap::new();
        for (slot, n) in model.graph.iter().enumerate() {
            id_to_slot.insert(n.id, slot);
        }
        let nodes: Vec<EngineNode> = model
            .graph
            .iter()
            .map(|n| EngineNode {
                op: n.op,
                inputs: n
                    .inputs
                    .iter()
                    .map(|i| *id_to_slot.get(i).expect("dangling graph input id"))
                    .collect(),
                layer: n.q.as_ref().map(|q| QLayer::from_meta(q, model.abits, model.nm_m)),
            })
            .collect();
        // liveness: slot s may be freed after node last_use[s] executes
        let mut last_use: Vec<usize> = (0..nodes.len()).collect();
        for (ni, n) in nodes.iter().enumerate() {
            for &s in &n.inputs {
                last_use[s] = last_use[s].max(ni);
            }
        }
        let out_slot = nodes.len().saturating_sub(1);
        if !nodes.is_empty() {
            last_use[out_slot] = usize::MAX;
        }
        let mut eng = Engine {
            cfg,
            model_name: model.name.clone(),
            input_shape: model.input_shape.clone(),
            layer_bits: vec![None; nodes.len()],
            nodes,
            last_use,
            out_slot,
            scratch: Scratch::default(),
            threads: 1,
            pool: None,
        };
        // a model carrying an embedded plan is enforced from the start;
        // plan-free models keep the all-None table (bit-identical to the
        // pre-plan engine)
        if let Some(plan) = &model.plan {
            eng.apply_plan(plan);
        }
        eng
    }

    /// Enforce `plan`'s per-layer accumulator widths (matched to q-layers
    /// by name; layers the plan does not mention keep the global
    /// `cfg.acc_bits`). Replaces any previously applied plan.
    pub fn apply_plan(&mut self, plan: &AccumPlan) {
        for (ni, n) in self.nodes.iter().enumerate() {
            self.layer_bits[ni] = match &n.layer {
                Some(l) => plan.bits_for_layer(&l.name),
                None => None,
            };
        }
    }

    /// Enforce explicit per-layer accumulator widths (matched to q-layers
    /// by name, like [`Engine::apply_plan`]; unmentioned layers keep the
    /// global `cfg.acc_bits`). This is the per-request operating-point
    /// hook: the serving layer derives `widths` from the embedded plan
    /// via [`AccumPlan::operating_point`] and restores the plan after the
    /// request group runs.
    pub fn apply_layer_bits(&mut self, widths: &[(String, u32)]) {
        for (ni, n) in self.nodes.iter().enumerate() {
            self.layer_bits[ni] = match &n.layer {
                Some(l) => widths.iter().find(|(name, _)| *name == l.name).map(|&(_, b)| b),
                None => None,
            };
        }
    }

    /// Drop every per-layer width override; all layers run at the global
    /// `cfg.acc_bits` again (what a plan-free model does).
    pub fn clear_plan(&mut self) {
        for b in self.layer_bits.iter_mut() {
            *b = None;
        }
    }

    /// The effective accumulator width of every q-layer, in graph order
    /// (the plan override where present, else the global default).
    pub fn effective_layer_bits(&self) -> Vec<(String, u32)> {
        self.nodes
            .iter()
            .enumerate()
            .filter_map(|(ni, n)| {
                n.layer.as_ref().map(|l| {
                    (l.name.clone(), self.layer_bits[ni].unwrap_or(self.cfg.acc_bits))
                })
            })
            .collect()
    }

    /// Parallelize the hot loops of `forward` over `n` scoped pool workers
    /// (1 = serial). Results are bit-identical to serial.
    pub fn with_threads(mut self, threads: usize) -> Engine {
        self.set_threads(threads);
        self
    }

    /// Serve the parallel splits from a shared persistent [`ComputePool`]
    /// instead of spawning scoped threads per layer call. Overrides the
    /// thread count with the pool's width; results stay bit-identical.
    pub fn with_pool(mut self, pool: Arc<ComputePool>) -> Engine {
        self.set_pool(pool);
        self
    }

    pub fn set_pool(&mut self, pool: Arc<ComputePool>) {
        self.threads = pool.threads().max(1);
        self.pool = Some(pool);
    }

    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Forward a batch of images (flattened f32 in [0,1], row-major NCHW).
    pub fn forward(&mut self, images: &[f32], n: usize) -> Result<EvalResult> {
        let dim: usize = self.input_shape.iter().product();
        if images.len() != n * dim {
            bail!("input size {} != n*dim {}", images.len(), n * dim);
        }
        if self.nodes.is_empty() {
            return Err(anyhow!("empty graph"));
        }
        let mut report = OverflowReport::default();
        let mut layer_us: Vec<(String, f64)> = Vec::new();
        let mut vals: Vec<Option<TensorF>> = (0..self.nodes.len()).map(|_| None).collect();
        let mut in_shape = vec![n];
        in_shape.extend_from_slice(&self.input_shape);

        for ni in 0..self.nodes.len() {
            let node = &self.nodes[ni];
            let t = match node.op {
                Op::Input => TensorF::from_vec(&in_shape, images.to_vec()),
                Op::Relu => {
                    let a = node.inputs[0];
                    let mut t = if self.last_use[a] == ni {
                        vals[a].take().expect("relu input missing")
                    } else {
                        vals[a].as_ref().expect("relu input missing").clone()
                    };
                    t.relu_inplace();
                    t
                }
                Op::Add => {
                    let (a, b) = (node.inputs[0], node.inputs[1]);
                    if self.last_use[a] == ni && a != b {
                        // steal the left operand's buffer
                        let mut t = vals[a].take().expect("add lhs missing");
                        t.add_assign(vals[b].as_ref().expect("add rhs missing"));
                        t
                    } else {
                        vals[a]
                            .as_ref()
                            .expect("add lhs missing")
                            .add(vals[b].as_ref().expect("add rhs missing"))
                    }
                }
                Op::Gap => vals[node.inputs[0]].as_ref().expect("gap input missing").global_avg_pool(),
                Op::Flatten => {
                    let a = node.inputs[0];
                    let t = if self.last_use[a] == ni {
                        vals[a].take().expect("flatten input missing")
                    } else {
                        vals[a].as_ref().expect("flatten input missing").clone()
                    };
                    let rows = t.shape[0];
                    let cols = t.numel() / rows;
                    t.reshape(&[rows, cols])
                }
                Op::QLinear | Op::QConv | Op::QDwConv => {
                    let x = vals[node.inputs[0]].as_ref().expect("q-layer input missing");
                    let layer = self.nodes[ni].layer.as_ref().unwrap();
                    let mut stats = OverflowStats::default();
                    let collect = self.cfg.collect_stats;
                    let pool = self.pool.as_deref();
                    // the layer's planned accumulator width (when a plan
                    // is applied) overrides the global default
                    let lcfg = match self.layer_bits[ni] {
                        Some(bits) => EngineConfig { acc_bits: bits, ..self.cfg },
                        None => self.cfg,
                    };
                    let t0 = Instant::now();
                    let out = match node.op {
                        Op::QLinear => qlinear_forward(
                            layer, &lcfg, &mut self.scratch, self.threads, pool, x,
                            collect.then_some(&mut stats),
                        ),
                        Op::QConv => qconv_forward(
                            layer, &lcfg, &mut self.scratch, self.threads, pool, x, false,
                            collect.then_some(&mut stats),
                        ),
                        _ => qconv_forward(
                            layer, &lcfg, &mut self.scratch, self.threads, pool, x, true,
                            collect.then_some(&mut stats),
                        ),
                    };
                    layer_us.push((layer.name.clone(), t0.elapsed().as_secs_f64() * 1e6));
                    if collect {
                        report.layer_mut(&layer.name).merge(&stats);
                    }
                    out
                }
            };
            vals[ni] = Some(t);
            // free every value whose last consumer just ran (buffer reuse:
            // peak live memory is bounded by the widest graph cut, not the
            // whole graph)
            for (s, slot) in vals.iter_mut().enumerate().take(ni + 1) {
                if s != ni && self.last_use[s] <= ni {
                    *slot = None;
                }
            }
        }

        let out = vals[self.out_slot].take().ok_or_else(|| anyhow!("missing graph output"))?;
        let classes = out.shape[1];
        Ok(EvalResult { logits: out.data, batch: n, classes, report, layer_us })
    }

    /// Evaluate accuracy over a dataset slice. `limit` is exact: the final
    /// batch is truncated so that exactly `min(limit, ds.n)` samples count.
    pub fn evaluate(
        &mut self,
        ds: &crate::data::Dataset,
        batch: usize,
        limit: Option<usize>,
    ) -> Result<(f64, OverflowReport)> {
        let mut report = OverflowReport::default();
        let mut correct = 0usize;
        let mut total = 0usize;
        let dim = ds.dim();
        for (mut imgs, labels, _start) in crate::data::Batches::new(ds, batch) {
            let mut take = labels.len();
            if let Some(lim) = limit {
                if total >= lim {
                    break;
                }
                if total + take > lim {
                    take = lim - total;
                    imgs.truncate(take * dim);
                }
            }
            let r = self.forward(&imgs, take)?;
            correct += (0..take).filter(|&i| r.argmax(i) == labels[i] as usize).count();
            total += take;
            report.merge(&r.report);
            if let Some(lim) = limit {
                if total >= lim {
                    break;
                }
            }
        }
        Ok((correct as f64 / total.max(1) as f64, report))
    }
}

/// Quantized linear layer over (n, d) input.
#[allow(clippy::too_many_arguments)]
fn qlinear_forward(
    layer: &QLayer,
    cfg: &EngineConfig,
    s: &mut Scratch,
    threads: usize,
    pool: Option<&ComputePool>,
    x: &TensorF,
    mut stats: Option<&mut OverflowStats>,
) -> TensorF {
    let n = x.shape[0];
    let d = x.numel() / n;
    debug_assert_eq!(d, layer.k, "linear input dim");
    let collect = stats.is_some();

    if threads > 1 && n > 1 {
        // row-parallel: each worker quantizes and evaluates whole rows with
        // its own scratch; chunks are contiguous (row i -> out[i*oc..])
        let rows = pmap_init(
            pool,
            n,
            threads,
            || (RowScratch::default(), Vec::<i32>::new()),
            |(rs, qbuf), i| {
                quant::quantize_centered_slice_into(
                    &x.data[i * d..(i + 1) * d],
                    &layer.x_qp,
                    qbuf,
                );
                let mut st = OverflowStats::default();
                let mut row_out = vec![0f32; layer.oc];
                for (o, out) in row_out.iter_mut().enumerate() {
                    let acc = eval_row(
                        layer, cfg, rs, o, qbuf,
                        if collect { Some(&mut st) } else { None },
                    );
                    *out = layer.dequant(o, acc);
                }
                (row_out, st)
            },
        );
        let mut out = Vec::with_capacity(n * layer.oc);
        for (row, st) in rows {
            out.extend_from_slice(&row);
            if let Some(stats) = stats.as_deref_mut() {
                stats.merge(&st);
            }
        }
        return TensorF::from_vec(&[n, layer.oc], out);
    }

    if threads > 1 && n == 1 && layer.oc > 1 {
        // batch-1 serving hot path: quantize the single row once, then
        // split the output-row loop across workers
        quant::quantize_centered_slice_into(&x.data[..d], &layer.x_qp, &mut s.qbuf);
        let qbuf = &s.qbuf;
        let rows = pmap_init(pool, layer.oc, threads, RowScratch::default, |rs, o| {
            let mut st = OverflowStats::default();
            let acc =
                eval_row(layer, cfg, rs, o, qbuf, if collect { Some(&mut st) } else { None });
            (layer.dequant(o, acc), st)
        });
        let mut out = Vec::with_capacity(layer.oc);
        for (v, st) in rows {
            out.push(v);
            if let Some(stats) = stats.as_deref_mut() {
                stats.merge(&st);
            }
        }
        return TensorF::from_vec(&[1, layer.oc], out);
    }

    let mut out = vec![0f32; n * layer.oc];
    for i in 0..n {
        quant::quantize_centered_slice_into(&x.data[i * d..(i + 1) * d], &layer.x_qp, &mut s.qbuf);
        for o in 0..layer.oc {
            let acc = eval_row(layer, cfg, &mut s.row, o, &s.qbuf, stats.as_deref_mut());
            out[i * layer.oc + o] = layer.dequant(o, acc);
        }
    }
    TensorF::from_vec(&[n, layer.oc], out)
}

/// One image of (depthwise-)conv work: quantize, im2col, evaluate every
/// (channel/filter, position) dot product. Returns the image's output chunk
/// (layout `[oc, l]`) plus its overflow stats.
#[allow(clippy::too_many_arguments)]
fn qconv_image(
    layer: &QLayer,
    cfg: &EngineConfig,
    rs: &mut RowScratch,
    qbuf: &mut Vec<i32>,
    colbuf: &mut Vec<i32>,
    x_img: &[f32],
    dims: (usize, usize, usize, usize),
    depthwise: bool,
    collect: bool,
) -> (Vec<f32>, OverflowStats) {
    let (c, h, w, l) = dims;
    let mut st = OverflowStats::default();
    let mut out = vec![0f32; layer.oc * l];
    quant::quantize_centered_slice_into(x_img, &layer.x_qp, qbuf);
    if depthwise {
        for ch in 0..c {
            let (li, k) = im2col_grouped(
                qbuf, c, h, w, ch, layer.kh, layer.kw, layer.stride, layer.pad, layer.pad_q,
                colbuf,
            );
            debug_assert_eq!((li, k), (l, layer.k));
            for pos in 0..l {
                let acc = eval_row(
                    layer, cfg, rs, ch, &colbuf[pos * k..(pos + 1) * k],
                    if collect { Some(&mut st) } else { None },
                );
                out[ch * l + pos] = layer.dequant(ch, acc);
            }
        }
    } else {
        let (li, k) = im2col(
            qbuf, c, h, w, layer.kh, layer.kw, layer.stride, layer.pad, layer.pad_q, colbuf,
        );
        debug_assert_eq!((li, k), (l, layer.k));
        for pos in 0..l {
            let col = &colbuf[pos * k..(pos + 1) * k];
            for o in 0..layer.oc {
                let acc = eval_row(
                    layer, cfg, rs, o, col,
                    if collect { Some(&mut st) } else { None },
                );
                out[o * l + pos] = layer.dequant(o, acc);
            }
        }
    }
    (out, st)
}

/// One image of a standard conv with the *position loop* split across
/// workers: quantize + im2col run once on the caller, then each worker
/// evaluates a contiguous block of output positions with its own row
/// scratch against the shared im2col matrix. This is what gives a single
/// image (batch-1 serving) intra-conv parallelism. Bit-identical to
/// `qconv_image`: same dot products, commutative stat merges, results
/// stitched back in position order.
#[allow(clippy::too_many_arguments)]
fn qconv_image_positions(
    layer: &QLayer,
    cfg: &EngineConfig,
    s: &mut Scratch,
    threads: usize,
    pool: Option<&ComputePool>,
    x_img: &[f32],
    dims: (usize, usize, usize, usize),
    collect: bool,
) -> (Vec<f32>, OverflowStats) {
    let (c, h, w, l) = dims;
    quant::quantize_centered_slice_into(x_img, &layer.x_qp, &mut s.qbuf);
    let (li, k) = im2col(
        &s.qbuf, c, h, w, layer.kh, layer.kw, layer.stride, layer.pad, layer.pad_q,
        &mut s.colbuf,
    );
    debug_assert_eq!((li, k), (l, layer.k));
    let cols = &s.colbuf[..];
    let oc = layer.oc;
    // blocks of contiguous positions: enough per-worker work to amortize
    // dispatch, enough blocks to balance ragged position costs
    let blocks = (threads * 4).clamp(1, l.max(1));
    let bs = l.div_ceil(blocks);
    let results = pmap_init(pool, blocks, threads, RowScratch::default, |rs, b| {
        // ragged tail: the last blocks may be empty when bs rounds up
        let start = (b * bs).min(l);
        let end = ((b + 1) * bs).min(l);
        let mut st = OverflowStats::default();
        let mut vals = vec![0f32; (end - start) * oc];
        for pos in start..end {
            let col = &cols[pos * k..(pos + 1) * k];
            for o in 0..oc {
                let acc = eval_row(
                    layer, cfg, rs, o, col,
                    if collect { Some(&mut st) } else { None },
                );
                vals[(pos - start) * oc + o] = layer.dequant(o, acc);
            }
        }
        (start, vals, st)
    });
    let mut out = vec![0f32; oc * l];
    let mut stats = OverflowStats::default();
    for (start, vals, st) in results {
        for (j, &v) in vals.iter().enumerate() {
            let pos = start + j / oc;
            let o = j % oc;
            out[o * l + pos] = v;
        }
        stats.merge(&st);
    }
    (out, stats)
}

/// One image of a depthwise conv with the *channel loop* split across
/// workers: quantize runs once on the caller, then each worker owns
/// im2col + positions for the channels it claims. Bit-identical to the
/// serial path (channels are independent, stats merge commutatively).
#[allow(clippy::too_many_arguments)]
fn qconv_image_channels(
    layer: &QLayer,
    cfg: &EngineConfig,
    s: &mut Scratch,
    threads: usize,
    pool: Option<&ComputePool>,
    x_img: &[f32],
    dims: (usize, usize, usize, usize),
    collect: bool,
) -> (Vec<f32>, OverflowStats) {
    let (c, h, w, l) = dims;
    quant::quantize_centered_slice_into(x_img, &layer.x_qp, &mut s.qbuf);
    let q = &s.qbuf[..];
    let k = layer.k;
    let results = pmap_init(
        pool,
        c,
        threads,
        || (RowScratch::default(), Vec::<i32>::new()),
        |(rs, colbuf), ch| {
            let (li, kk) = im2col_grouped(
                q, c, h, w, ch, layer.kh, layer.kw, layer.stride, layer.pad, layer.pad_q,
                colbuf,
            );
            debug_assert_eq!((li, kk), (l, k));
            let mut st = OverflowStats::default();
            let mut vals = vec![0f32; l];
            for (pos, val) in vals.iter_mut().enumerate() {
                let acc = eval_row(
                    layer, cfg, rs, ch, &colbuf[pos * k..(pos + 1) * k],
                    if collect { Some(&mut st) } else { None },
                );
                *val = layer.dequant(ch, acc);
            }
            (vals, st)
        },
    );
    let mut out = Vec::with_capacity(c * l);
    let mut stats = OverflowStats::default();
    for (vals, st) in results {
        out.extend_from_slice(&vals);
        stats.merge(&st);
    }
    (out, stats)
}

/// Quantized (depthwise-)conv layer over (n, c, h, w) input via im2col.
#[allow(clippy::too_many_arguments)]
fn qconv_forward(
    layer: &QLayer,
    cfg: &EngineConfig,
    s: &mut Scratch,
    threads: usize,
    pool: Option<&ComputePool>,
    x: &TensorF,
    depthwise: bool,
    mut stats: Option<&mut OverflowStats>,
) -> TensorF {
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    debug_assert_eq!(c, layer.ic, "conv input channels");
    let oh = conv_out_dim(h, layer.kh, layer.stride, layer.pad);
    let ow = conv_out_dim(w, layer.kw, layer.stride, layer.pad);
    let l = oh * ow;
    let chw = c * h * w;
    let collect = stats.is_some();

    // is there exploitable parallelism *inside* one image?
    let intra = if depthwise { c > 1 } else { l > 1 };
    if threads > 1 && n > 1 && (n >= threads || !intra) {
        // image-parallel: each worker owns quantize + im2col + row scratch
        let chunks = pmap_init(
            pool,
            n,
            threads,
            || (RowScratch::default(), Vec::<i32>::new(), Vec::<i32>::new()),
            |(rs, qbuf, colbuf), i| {
                qconv_image(
                    layer, cfg, rs, qbuf, colbuf,
                    &x.data[i * chw..(i + 1) * chw],
                    (c, h, w, l),
                    depthwise,
                    collect,
                )
            },
        );
        let mut out = Vec::with_capacity(n * layer.oc * l);
        for (chunk, st) in chunks {
            out.extend_from_slice(&chunk);
            if let Some(stats) = stats.as_deref_mut() {
                stats.merge(&st);
            }
        }
        return TensorF::from_vec(&[n, layer.oc, oh, ow], out);
    }

    if threads > 1 && intra {
        // fewer images than workers (batch-1 serving): split inside each
        // image instead — output positions for standard conv, channels for
        // depthwise
        let mut out = Vec::with_capacity(n * layer.oc * l);
        for i in 0..n {
            let img = &x.data[i * chw..(i + 1) * chw];
            let (chunk, st) = if depthwise {
                qconv_image_channels(layer, cfg, s, threads, pool, img, (c, h, w, l), collect)
            } else {
                qconv_image_positions(layer, cfg, s, threads, pool, img, (c, h, w, l), collect)
            };
            out.extend_from_slice(&chunk);
            if let Some(stats) = stats.as_deref_mut() {
                stats.merge(&st);
            }
        }
        return TensorF::from_vec(&[n, layer.oc, oh, ow], out);
    }

    let mut out = Vec::with_capacity(n * layer.oc * l);
    for i in 0..n {
        let (chunk, st) = qconv_image(
            layer, cfg, &mut s.row, &mut s.qbuf, &mut s.colbuf,
            &x.data[i * chw..(i + 1) * chw],
            (c, h, w, l),
            depthwise,
            collect,
        );
        out.extend_from_slice(&chunk);
        if let Some(stats) = stats.as_deref_mut() {
            stats.merge(&st);
        }
    }
    TensorF::from_vec(&[n, layer.oc, oh, ow], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    #[test]
    fn sorted_fast_path_matches_real_algorithm() {
        // the engine's O(K) shortcut must equal dot::sorted_full_dot in
        // value, and agree on event-presence
        prop::check(
            "engine-sorted-shortcut",
            400,
            |r: &mut Pcg32| (prop::gen_prods(r, 256, 8), 12 + r.below(12)),
            |(prods, p)| {
                let cfg = EngineConfig { policy: Policy::Sorted, acc_bits: *p, ..Default::default() };
                let mut d = DotEngine::new();
                let fast = eval_dot(&mut d, &cfg, prods, None);
                let mut d2 = DotEngine::new();
                let (real, ev) = crate::dot::sorted_full_dot(&mut d2, prods, *p);
                if fast != real {
                    return Err(format!("fast {fast} != real {real} (ev {ev})"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn eval_dot_stats_classification() {
        let cfg = EngineConfig { policy: Policy::Clip, acc_bits: 16, collect_stats: true, ..Default::default() };
        let mut d = DotEngine::new();
        let mut st = OverflowStats::default();
        // transient case
        let prods = [16129, 16129, 16129, -16129, -16129, -16129];
        let v = eval_dot(&mut d, &cfg, &prods, Some(&mut st));
        assert_eq!(st.dots, 1);
        assert_eq!(st.transient_dots, 1);
        assert_eq!(st.persistent_dots, 0);
        assert_eq!(st.policy_event_dots, 1); // clip had events
        assert_ne!(v, 0); // clipped value is wrong
        // sorted policy resolves it
        let cfg = EngineConfig { policy: Policy::Sorted, acc_bits: 16, collect_stats: true, ..Default::default() };
        let mut st2 = OverflowStats::default();
        let v2 = eval_dot(&mut d, &cfg, &prods, Some(&mut st2));
        assert_eq!(v2, 0);
        assert_eq!(st2.policy_event_dots, 0);
        assert_eq!(st2.transient_dots, 1); // still classified transient
    }

    #[test]
    fn required_bits_are_policy_order_aware() {
        // a cancelling dot: exact = 0 (2 bits), but the index-order
        // prefix reaches 16129 (15 bits). The sorting policies need only
        // the final value; Clip/Wrap must record the prefix requirement,
        // or a calibrated plan would saturate them mid-sum.
        let prods = [16129, -16129];
        let prefix_bits = accum::bits_for_value(16129);
        for (policy, want) in [
            (Policy::Sorted, 2),
            (Policy::Exact, 2),
            (Policy::Clip, prefix_bits),
            (Policy::Wrap, prefix_bits),
        ] {
            let cfg = EngineConfig { policy, acc_bits: 32, collect_stats: true, ..Default::default() };
            let mut d = DotEngine::new();
            let mut st = OverflowStats::default();
            eval_dot(&mut d, &cfg, &prods, Some(&mut st));
            assert_eq!(st.hist_dots(), 1);
            assert_eq!(
                st.max_required_bits(),
                want,
                "{}: required-width recording",
                policy.name()
            );
        }
    }

    #[test]
    fn argmax_and_accuracy() {
        let r = EvalResult {
            logits: vec![0.1, 0.9, 0.0, 0.8, 0.1, 0.1],
            batch: 2,
            classes: 3,
            report: OverflowReport::default(),
            layer_us: Vec::new(),
        };
        assert_eq!(r.argmax(0), 1);
        assert_eq!(r.argmax(1), 0);
        assert!((r.accuracy(&[1, 2]) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn plan_overrides_are_per_layer_and_clearable() {
        let mut model = crate::models::synthetic_conv(2, 6, 6, 4, 10);
        let cfg = EngineConfig { policy: Policy::Sorted, acc_bits: 16, ..Default::default() };
        // no plan: every q-layer runs at the global default
        let eng = Engine::new(&model, cfg);
        let bits = eng.effective_layer_bits();
        assert_eq!(bits.len(), 3);
        assert!(bits.iter().all(|(_, b)| *b == 16));
        // embed a plan: the engine applies it automatically
        let plan = crate::plan::plan_model(&model, &crate::plan::PlannerConfig::default())
            .expect("planner runs");
        model.plan = Some(plan.clone());
        let mut eng = Engine::new(&model, cfg);
        for (name, b) in eng.effective_layer_bits() {
            assert_eq!(Some(b), plan.bits_for_layer(&name), "layer {name}");
        }
        // clear_plan restores the global default (the calibration path)
        eng.clear_plan();
        assert!(eng.effective_layer_bits().iter().all(|(_, b)| *b == 16));
        // re-applying after construction matches the embedded behaviour
        eng.apply_plan(&plan);
        for (name, b) in eng.effective_layer_bits() {
            assert_eq!(Some(b), plan.bits_for_layer(&name), "layer {name}");
        }
    }

    #[test]
    fn plan_at_global_width_is_bit_identical_to_plan_free() {
        // a plan that sets every layer to the global width must not change
        // a single logit or stat — the override path is exactly the
        // default path then
        let mut model = crate::models::synthetic_conv(2, 6, 6, 4, 10);
        let cfg = EngineConfig {
            policy: Policy::Clip,
            acc_bits: 14,
            collect_stats: true,
            ..Default::default()
        };
        let mut rng = Pcg32::new(0xB17);
        let img: Vec<f32> = (0..2 * 6 * 6).map(|_| rng.f32()).collect();
        let mut plain = Engine::new(&model, cfg);
        let want = plain.forward(&img, 1).unwrap();
        let base = crate::plan::plan_model(&model, &crate::plan::PlannerConfig::default()).unwrap();
        let pinned = crate::plan::AccumPlan {
            per_layer: base
                .per_layer
                .iter()
                .map(|l| crate::plan::LayerPlan { acc_bits: 14, ..l.clone() })
                .collect(),
            ..base
        };
        model.plan = Some(pinned);
        let mut planned = Engine::new(&model, cfg);
        let got = planned.forward(&img, 1).unwrap();
        assert_eq!(got.logits, want.logits);
        assert_eq!(got.report.total(), want.report.total());
    }

    // Parallel-vs-serial bit-identity over a synthetic model is covered in
    // rust/tests/server.rs (which builds tiny PqswModels without artifacts).
}
