//! The bit-accurate quantized inference engine — the paper's §5.0.1
//! "library for analyzing overflows", as a graph interpreter.
//!
//! Every conv/linear MAC flows through a width-limited accumulator under a
//! configurable `Policy`; the engine optionally classifies every dot
//! product (transient/persistent, paper §3.1) while it computes.
//!
//! ### Fast path for the full sorted policy
//! Algorithm 1 with exact 2b-bit pairing temporaries provably returns
//! `clamp(exact)` with zero accumulation overflows whenever the exact
//! result fits (the terminal phase is single-sign, hence monotone — see
//! `dot::sorted` property tests, which assert this equivalence against the
//! real multi-round implementation). The engine therefore evaluates
//! `Policy::Sorted` in O(K) instead of O(K log K); `Policy::Sorted1` and
//! the tiled variant run the real sorting machinery.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::accum::{self, Policy};
use crate::dot::{tiled_sorted_dot, DotEngine};
use crate::formats::pqsw::{Op, PqswModel};
use crate::overflow::{OverflowReport, OverflowStats};
use crate::quant;
use crate::tensor::{conv_out_dim, im2col, im2col_grouped, TensorF};

use super::layer::QLayer;

/// Engine configuration: accumulation policy, width, optional k-tiling
/// (paper §6) and whether to collect overflow statistics.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    pub policy: Policy,
    pub acc_bits: u32,
    /// tile size for `Policy::Sorted1` (0 = full-width sort)
    pub tile: usize,
    /// classify every dot product (slower; needed for Figs. 2/5 analyses)
    pub collect_stats: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { policy: Policy::Sorted, acc_bits: 16, tile: 0, collect_stats: false }
    }
}

/// Result of one forward pass.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub logits: Vec<f32>,
    pub batch: usize,
    pub classes: usize,
    pub report: OverflowReport,
}

impl EvalResult {
    pub fn argmax(&self, i: usize) -> usize {
        let row = &self.logits[i * self.classes..(i + 1) * self.classes];
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap_or(0)
    }

    pub fn accuracy(&self, labels: &[u8]) -> f64 {
        let correct = (0..self.batch).filter(|&i| self.argmax(i) == labels[i] as usize).count();
        correct as f64 / self.batch.max(1) as f64
    }
}

/// Scratch buffers shared across layers (allocation-free hot path).
#[derive(Default)]
struct Scratch {
    dot: DotEngine,
    qbuf: Vec<i32>,
    colbuf: Vec<i32>,
    prods: Vec<i32>,
}

/// The graph-interpreting engine. Construct once per (model, config);
/// `forward` may be called repeatedly.
pub struct Engine {
    pub cfg: EngineConfig,
    pub model_name: String,
    input_shape: Vec<usize>,
    nodes: Vec<EngineNode>,
    scratch: Scratch,
}

struct EngineNode {
    id: usize,
    op: Op,
    inputs: Vec<usize>,
    layer: Option<QLayer>,
}

/// Evaluate one dot product under the config; updates stats when present.
///
/// Stats collection uses one fused scan computing the exact sum AND the
/// naive clipped accumulation simultaneously (perf pass: the separate
/// `classify` + policy scans cost ~1.5x; see EXPERIMENTS.md §Perf).
#[inline]
fn eval_dot(
    dot: &mut DotEngine,
    cfg: &EngineConfig,
    prods: &[i32],
    stats: Option<&mut OverflowStats>,
) -> i64 {
    let p = cfg.acc_bits;
    let (lo, hi) = accum::acc_range(p);

    if let Some(st) = stats {
        // fused exact + naive-clip scan
        let mut exact = 0i64;
        let mut acc = 0i64;
        let mut naive_events = 0u32;
        for &v in prods {
            exact += v as i64;
            let t = acc + v as i64;
            acc = if t < lo {
                naive_events += 1;
                lo
            } else if t > hi {
                naive_events += 1;
                hi
            } else {
                t
            };
        }
        let persistent = exact < lo || exact > hi;
        let (v, ev) = match cfg.policy {
            Policy::Exact => (exact, 0u32),
            Policy::Sorted | Policy::Oracle => {
                (exact.clamp(lo, hi), u32::from(persistent))
            }
            Policy::Clip => (acc, naive_events),
            Policy::Wrap => accum::wrap_accumulate(prods, p),
            Policy::Sorted1 => {
                if cfg.tile > 0 {
                    tiled_sorted_dot(dot, prods, p, cfg.tile)
                } else {
                    crate::dot::sorted1_dot(dot, prods, p)
                }
            }
        };
        st.dots += 1;
        st.products += prods.len() as u64;
        if naive_events > 0 {
            st.naive_event_dots += 1;
        }
        st.naive_events += naive_events as u64;
        if naive_events > 0 && !persistent {
            st.transient_dots += 1;
        }
        if persistent {
            st.persistent_dots += 1;
        }
        if ev > 0 {
            st.policy_event_dots += 1;
        }
        return v;
    }

    let (v, _ev) = match cfg.policy {
        Policy::Exact => (accum::exact_dot(prods), 0u32),
        Policy::Sorted | Policy::Oracle => {
            // fast path: Algorithm 1 == clamp(exact), events iff persistent
            let exact = accum::exact_dot(prods);
            (exact.clamp(lo, hi), 0)
        }
        Policy::Sorted1 => {
            if cfg.tile > 0 {
                tiled_sorted_dot(dot, prods, p, cfg.tile)
            } else {
                crate::dot::sorted1_dot(dot, prods, p)
            }
        }
        Policy::Clip => accum::clip_accumulate(prods, p),
        Policy::Wrap => accum::wrap_accumulate(prods, p),
    };
    v
}

/// Evaluate one weight-row x activation dot product, using the fused
/// buffer-free paths when no statistics are collected (perf pass §Perf:
/// skipping the intermediate product buffer is worth ~25-40% end-to-end).
#[inline]
fn eval_row(
    layer: &QLayer,
    cfg: &EngineConfig,
    s: &mut Scratch,
    o: usize,
    x: &[i32],
    stats: Option<&mut OverflowStats>,
) -> i64 {
    if stats.is_none() {
        match cfg.policy {
            Policy::Exact => return layer.w.dot_exact(o, x),
            Policy::Sorted | Policy::Oracle => {
                // Algorithm 1 fast path (see module docs): clamp(exact)
                let exact = layer.w.dot_exact(o, x);
                let (lo, hi) = accum::acc_range(cfg.acc_bits);
                return exact.clamp(lo, hi);
            }
            Policy::Clip => return layer.w.dot_clip(o, x, cfg.acc_bits).0,
            _ => {}
        }
    }
    layer.w.dot_products_into(o, x, &mut s.prods);
    let prods = std::mem::take(&mut s.prods);
    let v = eval_dot(&mut s.dot, cfg, &prods, stats);
    s.prods = prods;
    v
}

impl Engine {
    pub fn new(model: &PqswModel, cfg: EngineConfig) -> Engine {
        let nodes = model
            .graph
            .iter()
            .map(|n| EngineNode {
                id: n.id,
                op: n.op,
                inputs: n.inputs.clone(),
                layer: n.q.as_ref().map(|q| QLayer::from_meta(q, model.abits, model.nm_m)),
            })
            .collect();
        Engine {
            cfg,
            model_name: model.name.clone(),
            input_shape: model.input_shape.clone(),
            nodes,
            scratch: Scratch::default(),
        }
    }

    /// Forward a batch of images (flattened f32 in [0,1], row-major NCHW).
    pub fn forward(&mut self, images: &[f32], n: usize) -> Result<EvalResult> {
        let dim: usize = self.input_shape.iter().product();
        if images.len() != n * dim {
            bail!("input size {} != n*dim {}", images.len(), n * dim);
        }
        let mut report = OverflowReport::default();
        let mut vals: BTreeMap<usize, TensorF> = BTreeMap::new();
        let mut in_shape = vec![n];
        in_shape.extend_from_slice(&self.input_shape);

        let out_id = self.nodes.last().map(|nd| nd.id).ok_or_else(|| anyhow!("empty graph"))?;
        for ni in 0..self.nodes.len() {
            let node = &self.nodes[ni];
            let t = match node.op {
                Op::Input => TensorF::from_vec(&in_shape, images.to_vec()),
                Op::Relu => {
                    let mut t = vals[&node.inputs[0]].clone();
                    t.relu_inplace();
                    t
                }
                Op::Add => vals[&node.inputs[0]].add(&vals[&node.inputs[1]]),
                Op::Gap => vals[&node.inputs[0]].global_avg_pool(),
                Op::Flatten => {
                    let t = vals[&node.inputs[0]].clone();
                    let rows = t.shape[0];
                    let cols = t.numel() / rows;
                    t.reshape(&[rows, cols])
                }
                Op::QLinear | Op::QConv | Op::QDwConv => {
                    let x = &vals[&node.inputs[0]];
                    let layer = self.nodes[ni].layer.as_ref().unwrap();
                    let mut stats = OverflowStats::default();
                    let out = match node.op {
                        Op::QLinear => qlinear_forward(
                            layer, &self.cfg, &mut self.scratch, x,
                            self.cfg.collect_stats.then_some(&mut stats),
                        ),
                        Op::QConv => qconv_forward(
                            layer, &self.cfg, &mut self.scratch, x, false,
                            self.cfg.collect_stats.then_some(&mut stats),
                        ),
                        _ => qconv_forward(
                            layer, &self.cfg, &mut self.scratch, x, true,
                            self.cfg.collect_stats.then_some(&mut stats),
                        ),
                    };
                    if self.cfg.collect_stats {
                        report.layer_mut(&layer.name).merge(&stats);
                    }
                    out
                }
            };
            vals.insert(node.id, t);
        }

        let out = vals.remove(&out_id).unwrap();
        let classes = out.shape[1];
        Ok(EvalResult { logits: out.data, batch: n, classes, report })
    }

    /// Evaluate accuracy over a dataset slice.
    pub fn evaluate(
        &mut self,
        ds: &crate::data::Dataset,
        batch: usize,
        limit: Option<usize>,
    ) -> Result<(f64, OverflowReport)> {
        let mut report = OverflowReport::default();
        let mut correct = 0usize;
        let mut total = 0usize;
        for (imgs, labels, _start) in crate::data::Batches::new(ds, batch) {
            let r = self.forward(&imgs, labels.len())?;
            correct += (0..r.batch).filter(|&i| r.argmax(i) == labels[i] as usize).count();
            total += r.batch;
            report.merge(&r.report);
            if let Some(lim) = limit {
                if total >= lim {
                    break;
                }
            }
        }
        Ok((correct as f64 / total.max(1) as f64, report))
    }
}

/// Quantized linear layer over (n, d) input.
fn qlinear_forward(
    layer: &QLayer,
    cfg: &EngineConfig,
    s: &mut Scratch,
    x: &TensorF,
    mut stats: Option<&mut OverflowStats>,
) -> TensorF {
    let n = x.shape[0];
    let d = x.numel() / n;
    debug_assert_eq!(d, layer.k, "linear input dim");
    let mut out = vec![0f32; n * layer.oc];
    for i in 0..n {
        quant::quantize_centered_slice_into(&x.data[i * d..(i + 1) * d], &layer.x_qp, &mut s.qbuf);
        for o in 0..layer.oc {
            let acc = {
                let qbuf = std::mem::take(&mut s.qbuf);
                let acc = eval_row(layer, cfg, s, o, &qbuf, stats.as_deref_mut());
                s.qbuf = qbuf;
                acc
            };
            out[i * layer.oc + o] = layer.dequant(o, acc);
        }
    }
    TensorF::from_vec(&[n, layer.oc], out)
}

/// Quantized (depthwise-)conv layer over (n, c, h, w) input via im2col.
fn qconv_forward(
    layer: &QLayer,
    cfg: &EngineConfig,
    s: &mut Scratch,
    x: &TensorF,
    depthwise: bool,
    mut stats: Option<&mut OverflowStats>,
) -> TensorF {
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    debug_assert_eq!(c, layer.ic, "conv input channels");
    let oh = conv_out_dim(h, layer.kh, layer.stride, layer.pad);
    let ow = conv_out_dim(w, layer.kw, layer.stride, layer.pad);
    let l = oh * ow;
    let chw = c * h * w;
    let mut out = vec![0f32; n * layer.oc * l];
    for i in 0..n {
        quant::quantize_centered_slice_into(&x.data[i * chw..(i + 1) * chw], &layer.x_qp, &mut s.qbuf);
        if depthwise {
            for ch in 0..c {
                let (li, k) = im2col_grouped(
                    &s.qbuf, c, h, w, ch, layer.kh, layer.kw, layer.stride, layer.pad,
                    layer.pad_q, &mut s.colbuf,
                );
                debug_assert_eq!((li, k), (l, layer.k));
                for pos in 0..l {
                    let acc = {
                        let colbuf = std::mem::take(&mut s.colbuf);
                        let acc = eval_row(
                            layer, cfg, s, ch, &colbuf[pos * k..(pos + 1) * k],
                            stats.as_deref_mut(),
                        );
                        s.colbuf = colbuf;
                        acc
                    };
                    out[(i * layer.oc + ch) * l + pos] = layer.dequant(ch, acc);
                }
            }
        } else {
            let (li, k) = im2col(
                &s.qbuf, c, h, w, layer.kh, layer.kw, layer.stride, layer.pad, layer.pad_q,
                &mut s.colbuf,
            );
            debug_assert_eq!((li, k), (l, layer.k));
            for pos in 0..l {
                let colbuf = std::mem::take(&mut s.colbuf);
                let col = &colbuf[pos * k..(pos + 1) * k];
                for o in 0..layer.oc {
                    let acc = eval_row(layer, cfg, s, o, col, stats.as_deref_mut());
                    out[(i * layer.oc + o) * l + pos] = layer.dequant(o, acc);
                }
                s.colbuf = colbuf;
            }
        }
    }
    TensorF::from_vec(&[n, layer.oc, oh, ow], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    #[test]
    fn sorted_fast_path_matches_real_algorithm() {
        // the engine's O(K) shortcut must equal dot::sorted_full_dot in
        // value, and agree on event-presence
        prop::check(
            "engine-sorted-shortcut",
            400,
            |r: &mut Pcg32| (prop::gen_prods(r, 256, 8), 12 + r.below(12)),
            |(prods, p)| {
                let cfg = EngineConfig { policy: Policy::Sorted, acc_bits: *p, ..Default::default() };
                let mut d = DotEngine::new();
                let fast = eval_dot(&mut d, &cfg, prods, None);
                let mut d2 = DotEngine::new();
                let (real, ev) = crate::dot::sorted_full_dot(&mut d2, prods, *p);
                if fast != real {
                    return Err(format!("fast {fast} != real {real} (ev {ev})"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn eval_dot_stats_classification() {
        let cfg = EngineConfig { policy: Policy::Clip, acc_bits: 16, collect_stats: true, ..Default::default() };
        let mut d = DotEngine::new();
        let mut st = OverflowStats::default();
        // transient case
        let prods = [16129, 16129, 16129, -16129, -16129, -16129];
        let v = eval_dot(&mut d, &cfg, &prods, Some(&mut st));
        assert_eq!(st.dots, 1);
        assert_eq!(st.transient_dots, 1);
        assert_eq!(st.persistent_dots, 0);
        assert_eq!(st.policy_event_dots, 1); // clip had events
        assert_ne!(v, 0); // clipped value is wrong
        // sorted policy resolves it
        let cfg = EngineConfig { policy: Policy::Sorted, acc_bits: 16, collect_stats: true, ..Default::default() };
        let mut st2 = OverflowStats::default();
        let v2 = eval_dot(&mut d, &cfg, &prods, Some(&mut st2));
        assert_eq!(v2, 0);
        assert_eq!(st2.policy_event_dots, 0);
        assert_eq!(st2.transient_dots, 1); // still classified transient
    }

    #[test]
    fn argmax_and_accuracy() {
        let r = EvalResult {
            logits: vec![0.1, 0.9, 0.0, 0.8, 0.1, 0.1],
            batch: 2,
            classes: 3,
            report: OverflowReport::default(),
        };
        assert_eq!(r.argmax(0), 1);
        assert_eq!(r.argmax(1), 0);
        assert!((r.accuracy(&[1, 2]) - 0.5).abs() < 1e-9);
    }
}
