//! Quantized neural-network graph execution (DESIGN.md S14).
//!
//! `layer` prepares per-layer state from the `.pqsw` metadata (sparse
//! weights, qparams, offset corrections); `engine` interprets the model
//! graph with bit-accurate width-limited accumulation.

pub mod engine;
pub mod layer;

pub use engine::{Engine, EngineConfig, EvalResult};
pub use layer::QLayer;
