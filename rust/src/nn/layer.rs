//! Prepared quantized layer: sparse weights + quantization constants.

use crate::formats::pqsw::QLayerMeta;
use crate::quant::QParams;
use crate::sparse::NmMatrix;

/// Engine-ready layer state derived from a `.pqsw` q-layer.
#[derive(Clone, Debug)]
pub struct QLayer {
    pub name: String,
    pub oc: usize,
    pub ic: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
    /// contraction length each accumulator sees
    pub k: usize,
    /// N:M sparse weights (oc x k)
    pub w: NmMatrix,
    pub w_scale: f32,
    pub x_qp: QParams,
    /// integer value that FP32 zero quantizes to (= padding value)
    pub pad_q: i32,
    pub bias: Vec<f32>,
    /// combined dequant scale s_w * s_x
    pub dq_scale: f32,
}

impl QLayer {
    pub fn from_meta(meta: &QLayerMeta, abits: u8, nm_m: usize) -> QLayer {
        let x_qp = QParams { scale: meta.x_scale, offset: meta.x_offset, bits: abits };
        let w = NmMatrix::from_dense(&meta.wq, meta.oc, meta.k, nm_m);
        // activations are quantized into the offset-free domain, where the
        // FP32 value 0.0 maps to integer 0 (guaranteed by Eq. 1)
        let pad_q = crate::quant::quantize_centered(0.0, &x_qp);
        debug_assert_eq!(pad_q, 0);
        QLayer {
            name: meta.name.clone(),
            oc: meta.oc,
            ic: meta.ic,
            kh: meta.kh,
            kw: meta.kw,
            stride: meta.stride,
            pad: meta.pad,
            k: meta.k,
            w,
            w_scale: meta.w_scale,
            x_qp,
            pad_q,
            bias: meta.bias.clone(),
            dq_scale: meta.w_scale * meta.x_scale,
        }
    }

    /// Dequantize one integer accumulator value for output row `o`.
    ///
    /// The engine accumulates offset-free products `w_q * (x_q - o_x)`
    /// (see `quant::quantize_centered_slice_into`), so Eq. 3 reduces to
    /// `z = s_w * s_x * acc + bias[o]` — no offset correction transits the
    /// narrow accumulator.
    #[inline]
    pub fn dequant(&self, o: usize, acc: i64) -> f32 {
        self.dq_scale * acc as f32 + self.bias[o]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::pqsw::QLayerMeta;

    fn meta() -> QLayerMeta {
        QLayerMeta {
            name: "t".into(),
            oc: 2,
            ic: 4,
            kh: 1,
            kw: 1,
            stride: 1,
            pad: 0,
            prune: true,
            w_scale: 0.5,
            x_scale: 0.25,
            x_offset: -8,
            wq: vec![1, 0, -2, 3, 0, 0, 4, -1].into(),
            k: 4,
            bias: vec![0.5, -0.5],
        }
    }

    #[test]
    fn build_and_dequant() {
        let l = QLayer::from_meta(&meta(), 4, 4);
        assert_eq!(l.w.nnz(), 5);
        assert_eq!(l.w.row_wsum, vec![2, 3]);
        // FP32 zero maps to integer 0 in the offset-free domain
        assert_eq!(l.pad_q, 0);
        // dequant: z = s_w*s_x*acc + bias = 0.125*10 + 0.5
        let z = l.dequant(0, 10);
        assert!((z - (0.125 * 10.0 + 0.5)).abs() < 1e-6);
    }
}
