//! Machine-readable performance snapshots (`pqs bench --json PATH`).
//!
//! One invocation measures the three layers of the inference hot path and
//! writes a single JSON report, so the repository can carry a perf
//! trajectory (`BENCH_PR*.json`) that CI and reviewers diff across PRs:
//!
//! * **dot** — ns/call and overflow events per accumulation policy,
//!   including the tiled path with the fused per-tile histogram pairing;
//! * **pool** — dispatch cost of a scoped `parallel_map` vs the persistent
//!   [`ComputePool`] at small and large index ranges (the per-layer
//!   dispatch overhead batch-1 serving pays);
//! * **forward** — batch-1 engine forward latency across thread counts on
//!   synthetic linear and CNN models, with a bit-identity check (logits,
//!   predicted class, overflow counters must match the serial path
//!   exactly — the report records the comparison, and `run` fails if it
//!   does not hold);
//! * **serve** — end-to-end `POST /v1/classify` latency through the real
//!   HTTP front-end + serving runtime over a loopback connection, with the
//!   shared engine pool off (`engine_threads = 1`, the pre-refactor
//!   behaviour) and on (`engine_threads = hw`);
//! * **connections** — connection-scale tails: enqueue→response latency
//!   of a probe client (p50/p99/p999, HDR-style log-linear buckets)
//!   while N idle keep-alive connections are parked on the event loop,
//!   swept over N. The section *fails* if the front-end sheds any
//!   connection below its `max_connections` cap — the event-loop scaling
//!   guarantee is smoke-gated in CI, not just reported;
//! * **router** — a TWO-model router in one process: both models hit over
//!   one loopback connection (routed by the `"model"` field), an unknown
//!   model answered 404, then `GET /v1/metrics` fetched over the wire and
//!   its per-model sections parsed back — the smoke proof that the
//!   multi-model surface works end to end (`requests` per model, lazy
//!   `loads`, `unknown_model`, `load_latency`);
//! * **plan** — the accumulator-bitwidth planner on both synthetic
//!   models: analytic + calibrated planner runtimes and the planned
//!   per-layer widths vs the 32-bit baseline. The section *fails* if any
//!   calibrated width exceeds its analytic bound, so planner soundness is
//!   smoke-gated in CI alongside the perf numbers;
//! * **memory** — zero-copy `.pqsw` loading: eager vs lazy load latency
//!   of one saved file, measured resident bytes in both modes, a
//!   two-entry router blob-dedup smoke (two registry names over one file
//!   must share one weight blob), and a lazy-vs-eager bit-identity check
//!   (logits AND overflow counters; the section fails on divergence);
//! * **faults** — seeded fault injection against a live router: every
//!   load fails until the circuit breaker opens (500s, then fast-fail
//!   503s), the faults are disarmed and the time to the first healthy
//!   200 is recorded (`recovery_ms`), then injected engine panics prove
//!   the worker answers the batch 500 and survives. The section *fails*
//!   if any request goes unanswered, the breaker never opens, or the
//!   fleet never recovers — loss of a request under faults breaks the
//!   bench, not just a dashboard.
//! * **sweep** — the accumulator-budget projection + Pareto sweep
//!   (`crate::sweep`): a (budgets × N:M) grid over the synthetic CNN,
//!   each candidate projected to its budget and evaluated through
//!   `EvalService` against a 32-bit reference. The section *fails* if
//!   any projected point's enforced width exceeds its budget, if any
//!   point records a persistent overflow at that width (both are broken
//!   guarantees), if any point's agreement falls more than the declared
//!   tolerance below the baseline, or if the no-op point (dense at the
//!   unprojected analytic max) is not *exactly* the baseline — sorted
//!   arithmetic at the analytic width must equal 32-bit exact.
//! * **observability** — the tracing overhead gate: alternating loopback
//!   rounds with tracing disabled vs enabled at sample rate 0 (the
//!   always-on production configuration: stage histograms + id echo, no
//!   ring traffic) must agree on p50 within 2% plus a 5 µs jitter floor
//!   — the section *fails* otherwise — then a sampling-1.0 functional
//!   pass: 100+ classifies each echoing its `X-Request-Id`, `/v1/trace`
//!   span stages summing within their totals, `/metrics` parsing under
//!   the Prometheus text grammar with the per-layer headroom gauges
//!   present.
//!
//! Everything runs on synthetic models so the report is reproducible on
//! any checkout, artifacts or not. `quick: true` shrinks sample counts and
//! request volumes for CI smoke runs.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use anyhow::{anyhow, Context, Result};

use crate::accum::Policy;
use crate::coordinator::{
    ModelRegistry, ModelSource, Router, RouterConfig, ServerConfig, SyntheticSpec,
};
use crate::dot::{tiled_sorted_dot, DotEngine};
use crate::http::{HttpConfig, HttpServer};
use crate::models;
use crate::nn::engine::{Engine, EngineConfig};
use crate::trace::{self, TraceConfig};
use crate::util::bench::{bench_cfg, black_box};
use crate::util::json::{self, Json};
use crate::util::pool::{self, ComputePool};
use crate::util::rng::Pcg32;

/// Knobs for one report run.
pub struct BenchOptions {
    /// shrink sample counts / request volumes (CI smoke)
    pub quick: bool,
    /// engine thread counts swept in the forward section
    pub threads: Vec<usize>,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions { quick: false, threads: vec![1, 2, 8] }
    }
}

impl BenchOptions {
    fn samples(&self) -> u32 {
        if self.quick {
            2
        } else {
            5
        }
    }

    fn warmup(&self) -> u32 {
        u32::from(!self.quick)
    }
}

/// Run every section and assemble the report. Fails if any bit-identity
/// check fails — a perf number from a wrong computation is worthless.
pub fn run(opts: &BenchOptions) -> Result<Json> {
    let unix_s = SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs()).unwrap_or(0);
    Ok(json::obj(vec![
        (
            "meta",
            json::obj(vec![
                ("unix_time_s", json::num(unix_s as f64)),
                ("hw_threads", json::num(pool::default_threads() as f64)),
                ("quick", Json::Bool(opts.quick)),
            ]),
        ),
        ("dot", dot_section(opts)),
        ("pool", pool_section(opts)),
        ("forward", forward_section(opts)?),
        ("serve", serve_section(opts)?),
        ("connections", connections_section(opts)?),
        ("router", router_section(opts)?),
        ("plan", plan_section(opts)?),
        ("memory", memory_section(opts)?),
        ("faults", faults_section(opts)?),
        ("sweep", sweep_section(opts)?),
        ("observability", observability_section(opts)?),
    ]))
}

/// Run and write the report to `path` (pretty enough: one JSON document +
/// trailing newline).
pub fn run_to_file(path: &str, opts: &BenchOptions) -> Result<Json> {
    let report = run(opts)?;
    std::fs::write(path, report.to_string() + "\n")
        .with_context(|| format!("writing bench report to {path}"))?;
    Ok(report)
}

// ---- dot ------------------------------------------------------------------

fn dot_row<F: FnMut() -> (i64, u32)>(
    opts: &BenchOptions,
    name: &str,
    len: usize,
    mut f: F,
) -> Json {
    let (_, events) = f();
    let r = bench_cfg(&format!("dot {name} k={len}"), opts.warmup(), opts.samples(), &mut || {
        black_box(f());
    });
    json::obj(vec![
        ("name", json::s(name)),
        ("k", json::num(len as f64)),
        ("mean_ns", json::num(r.mean_ns)),
        ("products_per_s", json::num(len as f64 / (r.mean_ns / 1e9))),
        ("overflow_events", json::num(events as f64)),
    ])
}

fn dot_section(opts: &BenchOptions) -> Json {
    let mut rng = Pcg32::new(0xD07);
    let lens: &[usize] = if opts.quick { &[256] } else { &[64, 256, 1024] };
    let mut rows = Vec::new();
    for &len in lens {
        // 8-bit product domain (|w·x| <= 127*128 with centered activations)
        let prods = rng.ivec(len, -16256, 16256);
        for policy in [Policy::Exact, Policy::Clip, Policy::Sorted, Policy::Sorted1] {
            let mut e = DotEngine::new();
            rows.push(dot_row(opts, policy.name(), len, || e.dot(&prods, 16, policy)));
        }
        for tile in [64usize, 256] {
            let mut e = DotEngine::new();
            rows.push(dot_row(opts, &format!("sorted1_tile{tile}"), len, || {
                tiled_sorted_dot(&mut e, &prods, 16, tile)
            }));
        }
    }
    Json::Arr(rows)
}

// ---- pool -----------------------------------------------------------------

fn pool_section(opts: &BenchOptions) -> Json {
    let threads = pool::default_threads().clamp(2, 8);
    let cpool = ComputePool::new(threads);
    let mut rows = Vec::new();
    for &n in if opts.quick { &[256usize][..] } else { &[64usize, 4096][..] } {
        let scoped = bench_cfg(
            &format!("scoped parallel_map n={n}"),
            opts.warmup(),
            opts.samples(),
            &mut || {
                black_box(pool::parallel_map(n, threads, |i| i as u64 * 31));
            },
        );
        let persistent = bench_cfg(
            &format!("ComputePool::map n={n}"),
            opts.warmup(),
            opts.samples(),
            &mut || {
                black_box(cpool.map(n, |i| i as u64 * 31));
            },
        );
        rows.push(json::obj(vec![
            ("n", json::num(n as f64)),
            ("threads", json::num(threads as f64)),
            ("scoped_mean_ns", json::num(scoped.mean_ns)),
            ("persistent_mean_ns", json::num(persistent.mean_ns)),
            (
                "dispatch_speedup",
                json::num(if persistent.mean_ns > 0.0 {
                    scoped.mean_ns / persistent.mean_ns
                } else {
                    0.0
                }),
            ),
        ]));
    }
    Json::Arr(rows)
}

// ---- forward --------------------------------------------------------------

struct ForwardCase {
    label: &'static str,
    model: crate::formats::pqsw::PqswModel,
    policy: Policy,
}

fn forward_cases(opts: &BenchOptions) -> Vec<ForwardCase> {
    if opts.quick {
        vec![ForwardCase {
            label: "synthetic_conv_small",
            model: models::synthetic_conv(2, 12, 12, 4, 10),
            policy: Policy::Sorted1,
        }]
    } else {
        vec![
            ForwardCase {
                label: "synthetic_linear_784x128",
                model: models::synthetic_linear(784, 128),
                policy: Policy::Sorted1,
            },
            ForwardCase {
                label: "synthetic_conv_3x28x28",
                model: models::synthetic_conv(3, 28, 28, 8, 10),
                policy: Policy::Sorted1,
            },
            ForwardCase {
                label: "synthetic_conv_3x28x28_sorted",
                model: models::synthetic_conv(3, 28, 28, 8, 10),
                policy: Policy::Sorted,
            },
        ]
    }
}

fn forward_section(opts: &BenchOptions) -> Result<Json> {
    let mut rows = Vec::new();
    for case in forward_cases(opts) {
        let dim: usize = case.model.input_shape.iter().product();
        let mut rng = Pcg32::new(0xF0);
        let img: Vec<f32> = (0..dim).map(|_| rng.f32()).collect();
        let cfg = EngineConfig { policy: case.policy, acc_bits: 16, tile: 0, collect_stats: false };
        let stats_cfg = EngineConfig { collect_stats: true, ..cfg };

        // serial reference: logits, class, overflow counters
        let mut serial = Engine::new(&case.model, stats_cfg);
        let ref_out = serial.forward(&img, 1)?;
        let ref_total = ref_out.report.total();

        let mut measured: Vec<(usize, f64)> = Vec::new();
        for &t in &opts.threads {
            let cpool = (t > 1).then(|| std::sync::Arc::new(ComputePool::new(t)));
            // bit-identity first: logits, predicted class and overflow
            // counters must equal the serial reference exactly
            let mut check = Engine::new(&case.model, stats_cfg);
            if let Some(p) = &cpool {
                check.set_pool(std::sync::Arc::clone(p));
            }
            let out = check.forward(&img, 1)?;
            let total = out.report.total();
            if out.logits != ref_out.logits
                || out.argmax(0) != ref_out.argmax(0)
                || total != ref_total
            {
                return Err(anyhow!(
                    "{} T={t}: parallel forward diverged from the serial path",
                    case.label
                ));
            }
            // then the timing run (stats off: the serving configuration)
            let mut eng = Engine::new(&case.model, cfg);
            if let Some(p) = &cpool {
                eng.set_pool(std::sync::Arc::clone(p));
            }
            let r = bench_cfg(
                &format!("forward {} T={t}", case.label),
                opts.warmup(),
                opts.samples(),
                &mut || {
                    black_box(eng.forward(black_box(&img), 1).unwrap());
                },
            );
            measured.push((t, r.mean_ns));
        }
        // speedups are computed after the sweep so they do not depend on
        // the order (or presence) of 1 in --threads; without a T=1 row the
        // baseline is the slowest measured configuration
        let base_ns = measured
            .iter()
            .find(|(t, _)| *t == 1)
            .map(|&(_, ns)| ns)
            .or_else(|| measured.iter().map(|&(_, ns)| ns).max_by(f64::total_cmp))
            .unwrap_or(0.0);
        let threads_rows: Vec<Json> = measured
            .iter()
            .map(|&(t, mean_ns)| {
                json::obj(vec![
                    ("threads", json::num(t as f64)),
                    ("mean_us", json::num(mean_ns / 1e3)),
                    ("images_per_s", json::num(1e9 / mean_ns)),
                    (
                        "speedup_vs_t1",
                        json::num(if mean_ns > 0.0 && base_ns > 0.0 {
                            base_ns / mean_ns
                        } else {
                            0.0
                        }),
                    ),
                    ("bit_identical_to_serial", Json::Bool(true)),
                ])
            })
            .collect();
        rows.push(json::obj(vec![
            ("model", json::s(case.label)),
            ("policy", json::s(case.policy.name())),
            ("batch", json::num(1.0)),
            ("overflow_dots", json::num(ref_total.dots as f64)),
            ("overflow_naive_events", json::num(ref_total.naive_events as f64)),
            ("overflow_policy_event_dots", json::num(ref_total.policy_event_dots as f64)),
            ("predicted_class", json::num(ref_out.argmax(0) as f64)),
            ("threads", Json::Arr(threads_rows)),
        ]));
    }
    Ok(Json::Arr(rows))
}

// ---- serve ----------------------------------------------------------------

/// Minimal blocking HTTP/1.1 client for the loopback latency measurement.
struct LoopbackClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl LoopbackClient {
    fn connect(addr: &str) -> Result<LoopbackClient> {
        let stream = TcpStream::connect(addr).context("connecting to the bench http server")?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(Duration::from_secs(30))).ok();
        Ok(LoopbackClient { stream, buf: Vec::new() })
    }

    /// POST one classify request and block for the full response; returns
    /// the status code.
    fn classify(&mut self, body: &str) -> Result<u16> {
        let req = format!(
            "POST /v1/classify HTTP/1.1\r\nHost: bench\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        self.stream.write_all(req.as_bytes())?;
        Ok(self.read_response()?.0)
    }

    /// GET `path` and return the status plus the parsed JSON body.
    fn get_json(&mut self, path: &str) -> Result<(u16, Json)> {
        let req = format!("GET {path} HTTP/1.1\r\nHost: bench\r\n\r\n");
        self.stream.write_all(req.as_bytes())?;
        let (status, body) = self.read_response()?;
        let json = Json::parse_bytes(&body).map_err(|e| anyhow!("bad json from {path}: {e}"))?;
        Ok((status, json))
    }

    /// GET `path` and return the status plus the raw text body (the
    /// Prometheus exposition is not JSON).
    fn get_text(&mut self, path: &str) -> Result<(u16, String)> {
        let req = format!("GET {path} HTTP/1.1\r\nHost: bench\r\n\r\n");
        self.stream.write_all(req.as_bytes())?;
        let (status, _head, body) = self.read_response_full()?;
        Ok((status, String::from_utf8_lossy(&body).into_owned()))
    }

    /// POST one classify request — optionally carrying an `X-Request-Id`
    /// header — and return the status plus the echoed id, if any.
    fn classify_traced(&mut self, body: &str, id: Option<&str>) -> Result<(u16, Option<String>)> {
        let id_header = id.map(|i| format!("X-Request-Id: {i}\r\n")).unwrap_or_default();
        let req = format!(
            "POST /v1/classify HTTP/1.1\r\nHost: bench\r\n{id_header}Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        self.stream.write_all(req.as_bytes())?;
        let (status, head, _body) = self.read_response_full()?;
        let echoed = head.lines().find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("x-request-id").then(|| v.trim().to_string())
        });
        Ok((status, echoed))
    }

    fn read_response(&mut self) -> Result<(u16, Vec<u8>)> {
        let (status, _head, body) = self.read_response_full()?;
        Ok((status, body))
    }

    /// Like [`Self::read_response`] but also returns the raw response
    /// head, so callers can inspect headers.
    fn read_response_full(&mut self) -> Result<(u16, String, Vec<u8>)> {
        let mut chunk = [0u8; 8192];
        loop {
            if let Some(head_end) = find_crlf2(&self.buf) {
                let head = String::from_utf8_lossy(&self.buf[..head_end]).into_owned();
                let status: u16 = head
                    .split_whitespace()
                    .nth(1)
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| anyhow!("malformed status line: {head:.60}"))?;
                let clen: usize = head
                    .lines()
                    .find_map(|l| {
                        let (k, v) = l.split_once(':')?;
                        k.eq_ignore_ascii_case("content-length")
                            .then(|| v.trim().parse().ok())?
                    })
                    .ok_or_else(|| anyhow!("response without content-length"))?;
                let total = head_end + 4 + clen;
                while self.buf.len() < total {
                    let n = self.stream.read(&mut chunk)?;
                    if n == 0 {
                        return Err(anyhow!("server closed mid-body"));
                    }
                    self.buf.extend_from_slice(&chunk[..n]);
                }
                let body = self.buf[head_end + 4..total].to_vec();
                self.buf.drain(..total);
                return Ok((status, head, body));
            }
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                return Err(anyhow!("server closed mid-head"));
            }
            self.buf.extend_from_slice(&chunk[..n]);
        }
    }
}

fn find_crlf2(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn serve_section(opts: &BenchOptions) -> Result<Json> {
    let (model, policy) = if opts.quick {
        (models::synthetic_conv(2, 12, 12, 4, 10), Policy::Sorted1)
    } else {
        (models::synthetic_conv(3, 28, 28, 8, 10), Policy::Sorted1)
    };
    let dim: usize = model.input_shape.iter().product();
    let mut rng = Pcg32::new(0x5E4E);
    let requests = if opts.quick { 25 } else { 150 };
    // one image reused for every request (latency, not cache variety, is
    // what this section measures)
    let img: Vec<f32> = (0..dim).map(|_| (rng.below(1000) as f32) / 1000.0).collect();
    let body = {
        let pixels: Vec<Json> = img.iter().map(|&v| json::num(v as f64)).collect();
        json::obj(vec![("image", Json::Arr(pixels))]).to_string()
    };

    let hw = pool::default_threads().max(2);
    let mut rows = Vec::new();
    for engine_threads in [1usize, hw] {
        let cfg = EngineConfig { policy, acc_bits: 16, tile: 0, collect_stats: false };
        let scfg = ServerConfig {
            threads: 2,
            max_batch: 8,
            queue_cap: 256,
            linger: Duration::from_micros(100),
            engine_threads,
            default_deadline: None,
        };
        let router = Router::single("default", &model, cfg, scfg);
        let http = HttpServer::start(router, "127.0.0.1:0", HttpConfig::default())
            .context("binding the bench http server")?;
        let addr = http.local_addr().to_string();
        let mut client = LoopbackClient::connect(&addr)?;
        // warm the engines (first forward pays allocations)
        for _ in 0..3 {
            let status = client.classify(&body)?;
            if status != 200 {
                return Err(anyhow!("bench classify returned {status}"));
            }
        }
        let t0 = Instant::now();
        let mut client_us = Vec::with_capacity(requests);
        for _ in 0..requests {
            let r0 = Instant::now();
            let status = client.classify(&body)?;
            if status != 200 {
                return Err(anyhow!("bench classify returned {status}"));
            }
            client_us.push(r0.elapsed().as_secs_f64() * 1e6);
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let metrics = http.shutdown().router.aggregate();
        client_us.sort_by(f64::total_cmp);
        let mean = client_us.iter().sum::<f64>() / client_us.len() as f64;
        let p50 = client_us[client_us.len() / 2];
        let p95 = client_us[(client_us.len() * 95 / 100).min(client_us.len() - 1)];
        rows.push(json::obj(vec![
            ("engine_threads", json::num(engine_threads as f64)),
            ("requests", json::num(requests as f64)),
            ("client_mean_us", json::num(mean)),
            ("client_p50_us", json::num(p50)),
            ("client_p95_us", json::num(p95)),
            ("throughput_rps", json::num(requests as f64 / wall_s)),
            ("server_latency_p50_us", json::num(metrics.latency.p50_us)),
            ("server_latency_p95_us", json::num(metrics.latency.p95_us)),
            ("server_compute_mean_us", json::num(metrics.compute.mean_us)),
            (
                "pool_jobs",
                json::num(metrics.pool.as_ref().map(|p| p.jobs as f64).unwrap_or(0.0)),
            ),
            (
                "pool_inline_jobs",
                json::num(metrics.pool.as_ref().map(|p| p.inline_jobs as f64).unwrap_or(0.0)),
            ),
            (
                "pool_chunks",
                json::num(metrics.pool.as_ref().map(|p| p.chunks as f64).unwrap_or(0.0)),
            ),
        ]));
    }
    Ok(Json::Arr(rows))
}

// ---- observability --------------------------------------------------------

/// Tracing overhead gate + sampling-1.0 functional pass; see the module
/// docs for the gate's exact terms.
fn observability_section(opts: &BenchOptions) -> Result<Json> {
    let model = models::synthetic_conv(2, 12, 12, 4, 10);
    let dim: usize = model.input_shape.iter().product();
    let mut rng = Pcg32::new(0x0B5E);
    let img: Vec<f32> = (0..dim).map(|_| (rng.below(1000) as f32) / 1000.0).collect();
    let body = {
        let pixels: Vec<Json> = img.iter().map(|&v| json::num(v as f64)).collect();
        json::obj(vec![("image", Json::Arr(pixels))]).to_string()
    };
    let requests = if opts.quick { 30 } else { 120 };

    let start_server = |trace: TraceConfig| -> Result<HttpServer> {
        let cfg = EngineConfig {
            policy: Policy::Sorted1,
            acc_bits: 16,
            tile: 0,
            collect_stats: false,
        };
        let scfg = ServerConfig {
            threads: 2,
            max_batch: 8,
            queue_cap: 256,
            linger: Duration::from_micros(100),
            engine_threads: 1,
            default_deadline: None,
        };
        let router = Router::single("default", &model, cfg, scfg);
        let hcfg = HttpConfig { trace, ..HttpConfig::default() };
        HttpServer::start(router, "127.0.0.1:0", hcfg).context("binding the bench http server")
    };

    // one timed round against a fresh server; p50 of per-request wall µs
    let run_round = |trace: TraceConfig| -> Result<f64> {
        let http = start_server(trace)?;
        let mut client = LoopbackClient::connect(&http.local_addr().to_string())?;
        for _ in 0..3 {
            let status = client.classify(&body)?;
            if status != 200 {
                return Err(anyhow!("bench classify returned {status}"));
            }
        }
        let mut us = Vec::with_capacity(requests);
        for _ in 0..requests {
            let r0 = Instant::now();
            let status = client.classify(&body)?;
            if status != 200 {
                return Err(anyhow!("bench classify returned {status}"));
            }
            us.push(r0.elapsed().as_secs_f64() * 1e6);
        }
        drop(client);
        let _ = http.shutdown();
        us.sort_by(f64::total_cmp);
        Ok(us[us.len() / 2])
    };

    // alternating rounds, best-of: scheduler noise hits both sides alike
    let off = TraceConfig { enabled: false, sample_rate: 0.0, ring: 256 };
    let on = TraceConfig { enabled: true, sample_rate: 0.0, ring: 256 };
    let pairs = if opts.quick { 2 } else { 3 };
    let (mut off_p50, mut on_p50) = (f64::INFINITY, f64::INFINITY);
    for _ in 0..pairs {
        off_p50 = off_p50.min(run_round(off)?);
        on_p50 = on_p50.min(run_round(on)?);
    }
    if on_p50 > off_p50 * 1.02 + 5.0 {
        return Err(anyhow!(
            "tracing-at-rate-0 overhead gate failed: p50 {on_p50:.1}us enabled vs \
             {off_p50:.1}us disabled (budget: 2% + 5us)"
        ));
    }

    // functional pass at sampling 1.0: id echo on every response, span
    // decomposition bounded by the honest total, a grammatical scrape
    let http = start_server(TraceConfig { enabled: true, sample_rate: 1.0, ring: 512 })?;
    let mut client = LoopbackClient::connect(&http.local_addr().to_string())?;
    let drive = 100usize;
    for i in 0..drive {
        let want = format!("bench-{i}");
        let (status, echoed) = client.classify_traced(&body, Some(&want))?;
        if status != 200 {
            return Err(anyhow!("traced classify returned {status}"));
        }
        if echoed.as_deref() != Some(want.as_str()) {
            return Err(anyhow!("X-Request-Id {want:?} not echoed (got {echoed:?})"));
        }
    }
    let (status, echoed) = client.classify_traced(&body, None)?;
    if status != 200 {
        return Err(anyhow!("traced classify returned {status}"));
    }
    if !echoed.as_deref().is_some_and(|id| id.starts_with("pqs-")) {
        return Err(anyhow!("generated request id missing or malformed: {echoed:?}"));
    }

    let (status, tr) = client.get_json("/v1/trace?n=100")?;
    if status != 200 {
        return Err(anyhow!("/v1/trace returned {status}"));
    }
    let spans = tr.get("spans").and_then(Json::as_arr).unwrap_or(&[]);
    if spans.is_empty() {
        return Err(anyhow!("/v1/trace returned no spans at sample rate 1.0"));
    }
    let mut max_ratio: f64 = 0.0;
    for span in spans {
        let total = span.get("total_us").and_then(Json::as_f64).unwrap_or(0.0);
        let sum: f64 = span
            .get("stages")
            .and_then(|s| match s {
                Json::Obj(o) => Some(o.values().filter_map(Json::as_f64).sum()),
                _ => None,
            })
            .unwrap_or(0.0);
        if total > 0.0 {
            max_ratio = max_ratio.max(sum / total);
        }
        if sum > total * (1.0 + 1e-9) {
            return Err(anyhow!("span stages sum {sum:.1}us past the total {total:.1}us"));
        }
    }

    let (status, text) = client.get_text("/metrics")?;
    if status != 200 {
        return Err(anyhow!("/metrics returned {status}"));
    }
    trace::validate_exposition(&text)
        .map_err(|e| anyhow!("/metrics violates the exposition grammar: {e}"))?;
    if !text.contains("pqs_headroom_min_bits{") {
        return Err(anyhow!("/metrics is missing the per-layer headroom gauges"));
    }

    // headroom snapshot over the driven traffic
    let (_, mj) = client.get_json("/v1/models")?;
    let headroom = mj
        .get("models")
        .and_then(Json::as_arr)
        .and_then(|rows| rows.first())
        .and_then(|row| row.get("headroom"))
        .and_then(Json::as_arr)
        .unwrap_or(&[]);
    if headroom.is_empty() {
        return Err(anyhow!("/v1/models carries no headroom rows after traffic"));
    }
    let min_headroom = headroom
        .iter()
        .filter_map(|l| l.get("min_headroom_bits").and_then(Json::as_f64))
        .fold(f64::INFINITY, f64::min);
    let layers = headroom.len();
    drop(client);
    let _ = http.shutdown();

    Ok(json::obj(vec![
        ("requests_per_round", json::num(requests as f64)),
        ("rounds", json::num((pairs * 2) as f64)),
        ("tracing_off_p50_us", json::num(off_p50)),
        ("tracing_on_p50_us", json::num(on_p50)),
        ("overhead_pct", json::num((on_p50 - off_p50) / off_p50 * 100.0)),
        ("traced_requests", json::num((drive + 1) as f64)),
        ("spans_checked", json::num(spans.len() as f64)),
        ("max_stage_sum_ratio", json::num(max_ratio)),
        ("prometheus_bytes", json::num(text.len() as f64)),
        ("headroom_layers", json::num(layers as f64)),
        ("min_headroom_bits", json::num(min_headroom)),
    ]))
}

// ---- connections ----------------------------------------------------------

/// Connection-scale section: park `open_connections` idle keep-alive
/// sockets on the front-end, then measure probe-request
/// enqueue→response latency through the same server — the event-loop
/// promise is that parked connections are (nearly) free, so the tail
/// must not grow with the fleet. Latencies are recorded into an
/// [`HdrHistogram`] (log-linear buckets, ≈3% relative error) so p999 is
/// honest without keeping every sample. Fails if the server sheds any
/// connection below its `max_connections` cap.
fn connections_section(opts: &BenchOptions) -> Result<Json> {
    let event_loop = cfg!(target_os = "linux");
    // without the event loop every parked connection pins a handler
    // thread, so only the zero-idle baseline is meaningful
    let idle_counts: &[usize] = if !event_loop {
        &[0]
    } else if opts.quick {
        &[0, 64]
    } else {
        &[0, 1024, 4096]
    };
    let probes = if opts.quick { 50 } else { 400 };
    let max_idle = idle_counts.iter().copied().max().unwrap_or(0);
    // client + server side of every parked socket, plus headroom
    let fd_limit = crate::http::server::raise_nofile_limit(max_idle as u64 * 2 + 512);
    let fd_budget = (fd_limit.saturating_sub(512) / 2).min(usize::MAX as u64) as usize;

    let model = models::synthetic_conv(2, 8, 8, 4, 10);
    let dim: usize = model.input_shape.iter().product();
    let mut rng = Pcg32::new(0xC0);
    let body = {
        let pixels: Vec<Json> =
            (0..dim).map(|_| json::num((rng.below(1000) as f64) / 1000.0)).collect();
        json::obj(vec![("image", Json::Arr(pixels))]).to_string()
    };

    let mut rows = Vec::new();
    for &want_idle in idle_counts {
        // scale down (with the row recording it) if the fd limit held
        let idle = want_idle.min(fd_budget);
        let ecfg =
            EngineConfig { policy: Policy::Sorted1, acc_bits: 16, tile: 0, collect_stats: false };
        let scfg = ServerConfig {
            threads: 2,
            max_batch: 8,
            queue_cap: 256,
            linger: Duration::from_micros(100),
            engine_threads: 1,
            default_deadline: None,
        };
        let router = Router::single("default", &model, ecfg, scfg);
        let hcfg = HttpConfig {
            // the parked fleet must stay open for the whole measurement
            keep_alive_timeout: Duration::from_secs(120),
            max_connections: idle + 64,
            ..HttpConfig::default()
        };
        let http = HttpServer::start(router, "127.0.0.1:0", hcfg)
            .context("binding the connections bench server")?;
        let addr = http.local_addr().to_string();

        let mut fleet = Vec::with_capacity(idle);
        for i in 0..idle {
            let s = TcpStream::connect(&addr)
                .with_context(|| format!("parking idle connection {i}/{idle}"))?;
            fleet.push(s);
        }

        let mut client = LoopbackClient::connect(&addr)?;
        for _ in 0..3 {
            let status = client.classify(&body)?;
            if status != 200 {
                return Err(anyhow!("connections bench warmup returned {status}"));
            }
        }
        let mut hist = crate::util::stats::HdrHistogram::new();
        let t0 = Instant::now();
        for _ in 0..probes {
            let r0 = Instant::now();
            let status = client.classify(&body)?;
            if status != 200 {
                return Err(anyhow!("connections bench classify returned {status}"));
            }
            hist.record(r0.elapsed().as_micros() as u64);
        }
        let wall_s = t0.elapsed().as_secs_f64();
        drop(fleet);
        let report = http.shutdown();
        // the scaling guarantee this section gates: every connection below
        // the cap is accepted, none shed
        if report.http.shed != 0 {
            return Err(anyhow!(
                "front-end shed {} connections below the {}-connection cap",
                report.http.shed,
                idle + 64
            ));
        }
        let buckets: Vec<Json> = hist
            .buckets()
            .into_iter()
            .map(|(lo, c)| Json::Arr(vec![json::num(lo as f64), json::num(c as f64)]))
            .collect();
        rows.push(json::obj(vec![
            ("open_connections", json::num(idle as f64 + 1.0)),
            ("requested_idle", json::num(want_idle as f64)),
            ("probes", json::num(probes as f64)),
            ("p50_us", json::num(hist.value_at(0.50) as f64)),
            ("p99_us", json::num(hist.value_at(0.99) as f64)),
            ("p999_us", json::num(hist.value_at(0.999) as f64)),
            ("max_us", json::num(hist.max() as f64)),
            ("throughput_rps", json::num(probes as f64 / wall_s.max(1e-9))),
            ("accepted", json::num(report.http.accepted as f64)),
            ("shed", json::num(report.http.shed as f64)),
            ("hdr_buckets_us", Json::Arr(buckets)),
        ]));
    }
    Ok(json::obj(vec![
        ("event_loop", Json::Bool(event_loop)),
        ("fd_limit", json::num(fd_limit as f64)),
        ("rows", Json::Arr(rows)),
    ]))
}

// ---- router ---------------------------------------------------------------

/// Two-model router smoke through the real HTTP front-end: route requests
/// to both models over one connection, hit an unknown name (404), then
/// parse the nested per-model sections out of `GET /v1/metrics` fetched
/// over the wire. Fails unless both per-model sections parse with the
/// exact request counts — a multi-model metrics regression breaks the
/// bench, not just a dashboard.
fn router_section(opts: &BenchOptions) -> Result<Json> {
    let lin_dim = if opts.quick { 64 } else { 256 };
    let requests_per_model = if opts.quick { 10 } else { 50 };
    let mut registry = ModelRegistry::new();
    registry.register(
        "lin",
        ModelSource::Synthetic(SyntheticSpec::Linear { dim: lin_dim, classes: 10 }),
    );
    registry.register(
        "cnn",
        ModelSource::Synthetic(SyntheticSpec::Conv { c: 2, h: 8, w: 8, oc: 4, classes: 10 }),
    );
    let cfg = EngineConfig { policy: Policy::Sorted1, acc_bits: 16, tile: 0, collect_stats: false };
    let scfg = ServerConfig {
        threads: 2,
        max_batch: 8,
        queue_cap: 256,
        linger: Duration::from_micros(100),
        engine_threads: 2,
        default_deadline: None,
    };
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: cfg,
        server: scfg,
        preload: Vec::new(),
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).context("building the bench router")?;
    let http = HttpServer::start(router, "127.0.0.1:0", HttpConfig::default())
        .context("binding the bench router http server")?;
    let addr = http.local_addr().to_string();
    let mut client = LoopbackClient::connect(&addr)?;

    let mut rng = Pcg32::new(0x7007);
    let body_for = |rng: &mut Pcg32, dim: usize, model: &str| {
        let pixels: Vec<Json> =
            (0..dim).map(|_| json::num((rng.below(1000) as f64) / 1000.0)).collect();
        json::obj(vec![("model", json::s(model)), ("image", Json::Arr(pixels))]).to_string()
    };
    let cnn_dim = 2 * 8 * 8;
    let t0 = Instant::now();
    for _ in 0..requests_per_model {
        for (model, dim) in [("lin", lin_dim), ("cnn", cnn_dim)] {
            let status = client.classify(&body_for(&mut rng, dim, model))?;
            if status != 200 {
                return Err(anyhow!("router bench classify({model}) returned {status}"));
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    // unknown model: must be answered 404 without disturbing the fleet
    let status = client.classify(&body_for(&mut rng, lin_dim, "missing-model"))?;
    if status != 404 {
        return Err(anyhow!("unknown model returned {status}, want 404"));
    }
    // the per-model metrics sections must round-trip over the wire
    let (status, metrics) = client.get_json("/v1/metrics")?;
    if status != 200 {
        return Err(anyhow!("GET /v1/metrics returned {status}"));
    }
    let mut model_rows = Vec::new();
    for name in ["lin", "cnn"] {
        let section = metrics
            .get("models")
            .and_then(|m| m.get(name))
            .ok_or_else(|| anyhow!("metrics missing the per-model section for {name}"))?;
        let served = section.get("requests").and_then(Json::as_usize).unwrap_or(0);
        if served != requests_per_model {
            return Err(anyhow!("model {name} served {served}, want {requests_per_model}"));
        }
        model_rows.push(json::obj(vec![
            ("name", json::s(name)),
            ("requests", json::num(served as f64)),
            (
                "latency_p50_us",
                section
                    .get("latency")
                    .and_then(|l| l.get("p50_us"))
                    .cloned()
                    .unwrap_or(Json::Null),
            ),
        ]));
    }
    let router_counters = metrics
        .get("router")
        .ok_or_else(|| anyhow!("metrics missing the router section"))?
        .clone();
    let report = http.shutdown();
    Ok(json::obj(vec![
        ("models", Json::Arr(model_rows)),
        ("requests_per_model", json::num(requests_per_model as f64)),
        ("throughput_rps", json::num(2.0 * requests_per_model as f64 / wall_s.max(1e-9))),
        ("loads", json::num(report.router.loads as f64)),
        ("evictions", json::num(report.router.evictions as f64)),
        ("unknown_model", json::num(report.router.unknown_model as f64)),
        ("load_latency_mean_us", json::num(report.router.load_latency.mean_us)),
        ("wire_router_section", router_counters),
    ]))
}

// ---- plan -----------------------------------------------------------------

/// Accumulator-bitwidth planner section: planner runtimes and
/// planned-vs-default widths for the two synthetic models. Fails — not
/// just reports — if a calibrated width exceeds its analytic bound, so a
/// planner soundness regression breaks the bench (and the CI smoke that
/// runs it), not just a table.
fn plan_section(opts: &BenchOptions) -> Result<Json> {
    use crate::plan::{plan_model, PlannerConfig};
    let samples = if opts.quick { 32 } else { 256 };
    let cases: Vec<(&str, crate::formats::pqsw::PqswModel)> = if opts.quick {
        vec![
            ("lin", models::synthetic_linear(64, 10)),
            ("cnn", models::synthetic_conv(2, 8, 8, 4, 10)),
        ]
    } else {
        vec![
            ("lin", models::synthetic_linear(784, 128)),
            ("cnn", models::synthetic_conv(3, 28, 28, 8, 10)),
        ]
    };
    let mut rows = Vec::new();
    for (label, model) in &cases {
        let t0 = Instant::now();
        let analytic = plan_model(model, &PlannerConfig::default())?;
        let analytic_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t0 = Instant::now();
        let calibrated = plan_model(
            model,
            &PlannerConfig { calibrate_samples: samples, ..Default::default() },
        )?;
        let calibrated_ms = t0.elapsed().as_secs_f64() * 1e3;
        for (a, c) in analytic.per_layer.iter().zip(calibrated.per_layer.iter()) {
            if c.acc_bits > a.analytic_bits {
                return Err(anyhow!(
                    "{label} layer {}: calibrated {} exceeds the analytic bound {}",
                    a.name,
                    c.acc_bits,
                    a.analytic_bits
                ));
            }
        }
        let asum = analytic.summary();
        let csum = calibrated.summary();
        rows.push(json::obj(vec![
            ("model", json::s(label)),
            ("layers", json::num(asum.layers as f64)),
            ("samples", json::num(samples as f64)),
            ("analytic_ms", json::num(analytic_ms)),
            ("calibrated_ms", json::num(calibrated_ms)),
            (
                "analytic_bits",
                json::obj(vec![
                    ("min", json::num(asum.min_bits as f64)),
                    ("max", json::num(asum.max_bits as f64)),
                    ("mean", json::num(asum.mean_bits)),
                ]),
            ),
            (
                "planned_bits",
                json::obj(vec![
                    ("min", json::num(csum.min_bits as f64)),
                    ("max", json::num(csum.max_bits as f64)),
                    ("mean", json::num(csum.mean_bits)),
                ]),
            ),
            ("total_bits_planned", json::num(calibrated.total_bits() as f64)),
            ("total_bits_baseline32", json::num(calibrated.baseline_bits() as f64)),
            (
                "reduction_vs_32",
                json::num(
                    calibrated.baseline_bits() as f64 / calibrated.total_bits().max(1) as f64,
                ),
            ),
        ]));
    }
    Ok(Json::Arr(rows))
}

// ---- memory ---------------------------------------------------------------

/// Zero-copy loading + byte-budget section: eager vs lazy load times over a
/// saved `.pqsw`, measured resident bytes per mode, forward bit-identity
/// between the two, and blob dedup across two fleet entries of the same
/// file. Fails — not just reports — on any divergence, so a lazy-loading
/// regression breaks the bench (and the CI smoke that runs it), not just a
/// table.
fn memory_section(opts: &BenchOptions) -> Result<Json> {
    use crate::formats::pqsw::PqswModel;
    let model = if opts.quick {
        models::synthetic_conv(2, 8, 8, 4, 10)
    } else {
        models::synthetic_conv(3, 28, 28, 8, 10)
    };
    let dim: usize = model.input_shape.iter().product();
    let path = std::env::temp_dir().join(format!("pqs_bench_mem_{}.pqsw", std::process::id()));
    model.save(&path)?;
    let file_bytes = std::fs::metadata(&path)?.len();

    // load-time sweep: eager decodes every blob up front; lazy parses the
    // header and borrows the weight sections from the shared file buffer
    let reps = opts.samples().max(2);
    let mut eager_us = 0.0;
    let mut lazy_us = 0.0;
    for _ in 0..reps {
        let t0 = Instant::now();
        black_box(PqswModel::load_eager(&path)?);
        eager_us += t0.elapsed().as_secs_f64() * 1e6;
        let t0 = Instant::now();
        black_box(PqswModel::load(&path)?);
        lazy_us += t0.elapsed().as_secs_f64() * 1e6;
    }
    let eager = PqswModel::load_eager(&path)?;
    let lazy = PqswModel::load(&path)?;
    if lazy.content_hash() != eager.content_hash() {
        return Err(anyhow!("lazy and eager content hashes diverge"));
    }

    // forward bit-identity: same logits AND the same overflow counters
    let ecfg = EngineConfig { policy: Policy::Sorted, acc_bits: 12, tile: 0, collect_stats: true };
    let mut rng = Pcg32::new(0x3E80);
    let imgs: Vec<f32> = (0..4 * dim).map(|_| rng.f32()).collect();
    let ra = Engine::new(&eager, ecfg).forward(&imgs, 4)?;
    let rb = Engine::new(&lazy, ecfg).forward(&imgs, 4)?;
    if ra.logits != rb.logits || ra.report.total() != rb.report.total() {
        return Err(anyhow!("lazy-loaded forward diverges from the eager load"));
    }

    // dedup: two fleet entries over the SAME file must share one blob
    let mut registry = ModelRegistry::new();
    registry.register("a", ModelSource::Path(path.clone()));
    registry.register("b", ModelSource::Path(path.clone()));
    let scfg = ServerConfig {
        threads: 1,
        max_batch: 4,
        queue_cap: 16,
        linger: Duration::from_micros(50),
        engine_threads: 1,
        default_deadline: None,
    };
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: ecfg,
        server: scfg,
        preload: vec!["a".into(), "b".into()],
        ..Default::default()
    };
    let router = Router::new(registry, rcfg).context("building the memory bench router")?;
    let rm = router.metrics();
    router.shutdown();
    std::fs::remove_file(&path).ok();
    if rm.dedup_hits != 1 {
        return Err(anyhow!(
            "two loads of one file produced {} dedup hits, want 1",
            rm.dedup_hits
        ));
    }
    if rm.resident_bytes >= 2 * lazy.resident_bytes() {
        return Err(anyhow!(
            "deduped fleet holds {} bytes, not less than two full copies",
            rm.resident_bytes
        ));
    }

    Ok(json::obj(vec![
        (
            "load",
            Json::Arr(vec![
                json::obj(vec![
                    ("mode", json::s("eager")),
                    ("mean_us", json::num(eager_us / reps as f64)),
                ]),
                json::obj(vec![
                    ("mode", json::s("lazy")),
                    ("mean_us", json::num(lazy_us / reps as f64)),
                ]),
            ]),
        ),
        (
            "resident_bytes",
            json::obj(vec![
                ("file", json::num(file_bytes as f64)),
                ("eager", json::num(eager.resident_bytes() as f64)),
                ("lazy", json::num(lazy.resident_bytes() as f64)),
            ]),
        ),
        (
            "dedup",
            json::obj(vec![
                ("entries", json::num(2.0)),
                ("dedup_hits", json::num(rm.dedup_hits as f64)),
                ("resident_bytes", json::num(rm.resident_bytes as f64)),
                ("single_load_bytes", json::num(lazy.resident_bytes() as f64)),
            ]),
        ),
        ("bit_identical_lazy_vs_eager", Json::Bool(true)),
    ]))
}

// ---- faults ---------------------------------------------------------------

/// Fault-injection + self-healing section over a live router: arm a
/// seeded [`FaultPlan`] that fails every load, drive requests until the
/// load circuit breaker opens (500s from failed loads, then fast-fail
/// 503s), disarm and measure the time to the first healthy 200, then
/// re-arm so injected engine panics hit resident forwards — the worker
/// must answer every rider 500 and keep serving. Fails — not just
/// reports — if any request goes unanswered, the breaker never opens,
/// or the fleet never recovers after the faults stop.
fn faults_section(opts: &BenchOptions) -> Result<Json> {
    use crate::coordinator::BreakerConfig;
    use crate::faults::{FaultPlan, FaultSpec};
    use std::sync::Arc;

    let mut registry = ModelRegistry::new();
    registry.register(
        "m",
        ModelSource::Synthetic(SyntheticSpec::Conv { c: 2, h: 8, w: 8, oc: 4, classes: 10 }),
    );
    // every load fails while armed; every 3rd resident forward panics
    let plan = Arc::new(FaultPlan::new(FaultSpec {
        seed: 0xFA17_BE4C,
        load_error: 1.0,
        panic_every: 3,
        ..Default::default()
    }));
    let ecfg = EngineConfig { policy: Policy::Sorted1, acc_bits: 16, tile: 0, collect_stats: false };
    let scfg = ServerConfig {
        threads: 2,
        max_batch: 4,
        queue_cap: 64,
        linger: Duration::from_micros(50),
        engine_threads: 1,
        default_deadline: None,
    };
    let rcfg = RouterConfig {
        max_loaded: 0,
        max_bytes: 0,
        engine: ecfg,
        server: scfg,
        preload: Vec::new(),
        // small windows so the whole open→half-open→closed round trip
        // fits in a bench run
        breaker: BreakerConfig {
            threshold: 2,
            base_backoff: Duration::from_millis(20),
            max_backoff: Duration::from_millis(80),
            ..Default::default()
        },
        faults: Some(Arc::clone(&plan)),
    };
    let router = Router::new(registry, rcfg).context("building the faults bench router")?;
    let http = HttpServer::start(router, "127.0.0.1:0", HttpConfig::default())
        .context("binding the faults bench server")?;
    let addr = http.local_addr().to_string();
    let mut client = LoopbackClient::connect(&addr)?;

    let dim = 2 * 8 * 8;
    let mut rng = Pcg32::new(0xFA17);
    let body = {
        let pixels: Vec<Json> =
            (0..dim).map(|_| json::num((rng.below(1000) as f64) / 1000.0)).collect();
        json::obj(vec![("image", Json::Arr(pixels))]).to_string()
    };

    let (mut sent, mut answered) = (0u64, 0u64);
    let (mut s200, mut s500, mut s503) = (0u64, 0u64, 0u64);

    // Phase 1: fault storm. Loads fail deterministically; after
    // `threshold` consecutive failures the breaker must open and start
    // fast-failing without touching the (still broken) source.
    let storm = if opts.quick { 6 } else { 12 };
    let mut breaker_opened = false;
    for _ in 0..storm {
        sent += 1;
        let status = client.classify(&body)?;
        answered += 1;
        match status {
            500 => s500 += 1,
            503 => {
                s503 += 1;
                breaker_opened = true;
            }
            other => return Err(anyhow!("fault storm: unexpected status {other}")),
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    if !breaker_opened {
        return Err(anyhow!(
            "breaker never opened: {storm} failed loads produced {s500}x500 and no 503"
        ));
    }

    // Phase 2: disarm and measure recovery — the next half-open probe
    // load succeeds, the breaker closes, traffic flows again.
    plan.disarm();
    let t0 = Instant::now();
    let mut recovery_ms = -1.0;
    for _ in 0..400 {
        sent += 1;
        let status = client.classify(&body)?;
        answered += 1;
        match status {
            200 => {
                s200 += 1;
                recovery_ms = t0.elapsed().as_secs_f64() * 1e3;
            }
            500 => s500 += 1,
            503 => s503 += 1,
            other => return Err(anyhow!("recovery: unexpected status {other}")),
        }
        if recovery_ms >= 0.0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    if recovery_ms < 0.0 {
        return Err(anyhow!("fleet never recovered after the faults were disarmed"));
    }

    // Phase 3: panic isolation. The model is resident, so re-arming only
    // injects forward panics; every rider must still get a response and
    // the worker must survive to serve the next request.
    plan.rearm();
    let volley = if opts.quick { 9 } else { 24 };
    for _ in 0..volley {
        sent += 1;
        let status = client.classify(&body)?;
        answered += 1;
        match status {
            200 => s200 += 1,
            500 => s500 += 1,
            other => return Err(anyhow!("panic volley: unexpected status {other}")),
        }
    }
    plan.disarm();
    sent += 1;
    let status = client.classify(&body)?;
    answered += 1;
    if status != 200 {
        return Err(anyhow!("worker did not survive injected panics: final status {status}"));
    }
    s200 += 1;

    let report = http.shutdown();
    let counts = plan.counts();
    if counts.panics == 0 {
        return Err(anyhow!("panic injection never fired over {volley} requests"));
    }
    let lost = sent - answered;
    if lost != 0 {
        return Err(anyhow!("{lost} of {sent} requests went unanswered under faults"));
    }
    Ok(json::obj(vec![
        ("requests", json::num(sent as f64)),
        ("responses", json::num(answered as f64)),
        ("lost", json::num(lost as f64)),
        ("status_200", json::num(s200 as f64)),
        ("status_500", json::num(s500 as f64)),
        ("status_503", json::num(s503 as f64)),
        (
            "injected",
            json::obj(vec![
                ("load_errors", json::num(counts.load_errors as f64)),
                ("slow_loads", json::num(counts.slow_loads as f64)),
                ("corruptions", json::num(counts.corruptions as f64)),
                ("panics", json::num(counts.panics as f64)),
                ("resets", json::num(counts.resets as f64)),
            ]),
        ),
        (
            "breaker",
            json::obj(vec![
                ("opens", json::num(report.router.breaker_opens as f64)),
                ("fast_fails", json::num(report.router.breaker_fast_fails as f64)),
                ("load_retries", json::num(report.router.load_retries as f64)),
                // opened under faults, closed after disarm — both gated
                // above, so a report that exists at all round-tripped
                ("round_trip", Json::Bool(true)),
            ]),
        ),
        ("recovery_ms", json::num(recovery_ms)),
        ("worker_panics_survived", json::num(report.router.aggregate().panics as f64)),
    ]))
}

// ---- sweep ----------------------------------------------------------------

/// Accumulator-budget projection + Pareto sweep smoke (`crate::sweep`): a
/// small (budgets × N:M) grid over the synthetic CNN, scored as agreement
/// with the unprojected model at 32-bit exact arithmetic on a seeded
/// reference set (baseline accuracy 1.0 by construction). Gates, in order
/// of strength:
///
/// * every point's enforced width fits its requested budget and serves
///   with ZERO persistent overflows — the projection guarantee, checked
///   through the real evaluation path;
/// * the no-op point (dense, budget = the unprojected analytic max) must
///   score *exactly* the baseline: projection edits nothing there, and
///   sorted accumulation at the analytic width returns the exact value;
/// * clipped/pruned points must stay within the declared tolerance of
///   the baseline. The whole run is seeded (deterministic), so this is a
///   wide catastrophe floor on a tiny synthetic agreement metric, not a
///   tight regression bound — real sweeps declare their own tolerance.
fn sweep_section(opts: &BenchOptions) -> Result<Json> {
    use crate::sweep::{self, NmSpec, SweepConfig};

    let model = if opts.quick {
        models::synthetic_conv(2, 8, 8, 4, 10)
    } else {
        models::synthetic_conv(3, 16, 16, 6, 10)
    };
    let policy = Policy::Sorted;
    let max = sweep::max_analytic_bits(&model, policy)?;
    let budgets: Vec<u32> = if opts.quick {
        vec![max, max.saturating_sub(1).max(2)]
    } else {
        vec![max, max.saturating_sub(1).max(2), max.saturating_sub(2).max(2)]
    };
    let samples = if opts.quick { 48 } else { 192 };
    let tolerance = if opts.quick { 0.9 } else { 0.5 };
    let ds = sweep::reference_dataset(&model, samples, 0x5EE9_D00D)?;
    let cfg = SweepConfig {
        policy,
        budgets,
        nm: vec![None, Some(NmSpec { keep: 3, m: 4 })],
        batch: 16,
        threads: opts.threads.iter().copied().max().unwrap_or(2),
        tolerance,
        limit: None,
    };
    let t0 = Instant::now();
    let res = sweep::pareto(&model, &ds, &cfg)?;
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    for p in &res.points {
        let label = format!("budget {} nm {}", p.budget, NmSpec::label(p.nm));
        if !p.budget_ok {
            return Err(anyhow!(
                "sweep {label}: enforced width {} exceeds the budget",
                p.width_bits
            ));
        }
        if p.persistent_dots > 0 {
            return Err(anyhow!(
                "sweep {label}: {} persistent overflows serving at the planned width",
                p.persistent_dots
            ));
        }
        if !p.accuracy_ok {
            return Err(anyhow!(
                "sweep {label}: accuracy {:.4} fell more than the declared tolerance \
                 {tolerance} below the baseline {:.4}",
                p.accuracy,
                res.baseline_accuracy
            ));
        }
    }
    let noop = res
        .points
        .iter()
        .find(|p| p.budget == max && p.nm.is_none())
        .ok_or_else(|| anyhow!("sweep grid lost its no-op point (budget {max}, dense)"))?;
    if noop.pruned != 0 || noop.clipped != 0 {
        return Err(anyhow!(
            "the dense point at the analytic max must be a no-op projection \
             (pruned {}, clipped {})",
            noop.pruned,
            noop.clipped
        ));
    }
    if noop.accuracy != res.baseline_accuracy {
        return Err(anyhow!(
            "no-op point accuracy {:.6} != baseline {:.6}: sorted arithmetic at the \
             analytic width must equal 32-bit exact",
            noop.accuracy,
            res.baseline_accuracy
        ));
    }

    let mut j = res.to_json();
    if let Json::Obj(map) = &mut j {
        map.insert("wall_ms".to_string(), json::num(wall_ms));
    }
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_report_is_well_formed() {
        // the CI smoke contract: a quick run produces a parseable report
        // with every section present and the forward bit-identity holding
        let opts = BenchOptions { quick: true, threads: vec![1, 2] };
        let report = run(&opts).expect("quick bench run");
        let txt = report.to_string();
        let parsed = Json::parse(&txt).expect("report round-trips");
        for key in [
            "meta", "dot", "pool", "forward", "serve", "connections", "router", "plan", "memory",
            "faults", "sweep", "observability",
        ] {
            assert!(parsed.get(key).is_some(), "missing section {key}");
        }
        let fwd = parsed.get("forward").unwrap().as_arr().unwrap();
        assert!(!fwd.is_empty());
        for case in fwd {
            for t in case.get("threads").unwrap().as_arr().unwrap() {
                assert_eq!(
                    t.get("bit_identical_to_serial").unwrap().as_bool(),
                    Some(true)
                );
            }
        }
        let serve = parsed.get("serve").unwrap().as_arr().unwrap();
        assert_eq!(serve.len(), 2, "engine_threads off + on");
        // the connections section carries the exact schema CI asserts on:
        // one row per idle-fleet size, ordered tail quantiles, zero sheds,
        // and non-empty HDR buckets that sum to the probe count
        let conns = parsed.get("connections").unwrap();
        assert!(conns.get("event_loop").unwrap().as_bool().is_some());
        assert!(conns.get("fd_limit").unwrap().as_f64().is_some());
        let rows = conns.get("rows").unwrap().as_arr().unwrap();
        let expect_rows = if cfg!(target_os = "linux") { 2 } else { 1 };
        assert_eq!(rows.len(), expect_rows, "one row per idle-fleet size");
        for row in rows {
            let probes = row.get("probes").unwrap().as_f64().unwrap();
            let p50 = row.get("p50_us").unwrap().as_f64().unwrap();
            let p99 = row.get("p99_us").unwrap().as_f64().unwrap();
            let p999 = row.get("p999_us").unwrap().as_f64().unwrap();
            let max = row.get("max_us").unwrap().as_f64().unwrap();
            assert!(p50 <= p99 && p99 <= p999 && p999 <= max, "quantiles ordered: {row:?}");
            assert_eq!(row.get("shed").and_then(Json::as_usize), Some(0), "no shedding");
            assert!(row.get("open_connections").unwrap().as_f64().unwrap() >= 1.0);
            assert!(row.get("throughput_rps").unwrap().as_f64().unwrap() > 0.0);
            let buckets = row.get("hdr_buckets_us").unwrap().as_arr().unwrap();
            assert!(!buckets.is_empty(), "HDR buckets present");
            let total: f64 = buckets
                .iter()
                .map(|b| b.as_arr().unwrap()[1].as_f64().unwrap())
                .sum();
            assert_eq!(total, probes, "bucket counts sum to the probe count");
        }
        // the router section carries BOTH per-model rows with exact counts
        let router = parsed.get("router").unwrap();
        let models = router.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models.len(), 2, "two registered models");
        let want = router.get("requests_per_model").unwrap().as_usize().unwrap();
        for m in models {
            assert_eq!(m.get("requests").and_then(Json::as_usize), Some(want));
        }
        assert_eq!(router.get("unknown_model").and_then(Json::as_usize), Some(1));
        assert_eq!(router.get("loads").and_then(Json::as_usize), Some(2));
        // the plan section carries BOTH synthetic-model rows with
        // calibrated widths no wider than the analytic bound (the
        // generator fails otherwise; this re-checks over the wire format)
        let plan = parsed.get("plan").unwrap().as_arr().unwrap();
        assert_eq!(plan.len(), 2, "lin + cnn planner rows");
        for row in plan {
            let a = row.get("analytic_bits").unwrap();
            let p = row.get("planned_bits").unwrap();
            assert!(
                p.get("max").unwrap().as_f64().unwrap() <= a.get("max").unwrap().as_f64().unwrap(),
                "planned max must not exceed analytic max: {row:?}"
            );
            assert!(row.get("reduction_vs_32").unwrap().as_f64().unwrap() >= 1.0);
            assert!(row.get("analytic_ms").unwrap().as_f64().unwrap() >= 0.0);
            assert!(row.get("calibrated_ms").unwrap().as_f64().unwrap() >= 0.0);
        }
        // the faults section gates the robustness invariants: zero lost
        // requests, the breaker opened (and, because the section exists,
        // closed again), panics injected and survived
        let faults = parsed.get("faults").unwrap();
        assert_eq!(faults.get("lost").and_then(Json::as_usize), Some(0), "no lost requests");
        assert_eq!(
            faults.get("requests").and_then(Json::as_usize),
            faults.get("responses").and_then(Json::as_usize),
            "every request answered exactly once"
        );
        assert!(
            faults.get("breaker").unwrap().get("opens").unwrap().as_f64().unwrap() >= 1.0,
            "breaker opened under the load-fault storm"
        );
        assert!(
            faults.get("injected").unwrap().get("panics").unwrap().as_f64().unwrap() >= 1.0,
            "engine panics were injected"
        );
        assert!(faults.get("recovery_ms").unwrap().as_f64().unwrap() >= 0.0);
        // the sweep section gates the projection guarantees over the wire
        // format: a 2x2 grid (quick mode), every point within budget with
        // zero persistent overflows, the baseline at exactly 1.0 on the
        // self-labeled reference set, and a non-empty Pareto frontier
        let sweep = parsed.get("sweep").unwrap();
        assert_eq!(sweep.get("tag").and_then(Json::as_str), Some("sweep"));
        let baseline = sweep.get("baseline").unwrap();
        assert_eq!(baseline.get("acc_bits").and_then(Json::as_usize), Some(32));
        assert_eq!(baseline.get("accuracy").and_then(Json::as_f64), Some(1.0));
        let max = baseline.get("analytic_bits_max").unwrap().as_usize().unwrap();
        let points = sweep.get("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 4, "quick mode sweeps a 2x2 grid");
        for p in points {
            assert_eq!(p.get("budget_ok").and_then(Json::as_bool), Some(true), "{p:?}");
            assert_eq!(p.get("accuracy_ok").and_then(Json::as_bool), Some(true), "{p:?}");
            assert_eq!(p.get("persistent_dots").and_then(Json::as_usize), Some(0), "{p:?}");
            let budget = p.get("budget").unwrap().as_usize().unwrap();
            let width = p.get("width_bits").unwrap().as_usize().unwrap();
            assert!(width <= budget && budget <= max, "{p:?}");
        }
        let frontier = sweep.get("frontier").unwrap().as_arr().unwrap();
        assert!(!frontier.is_empty(), "Pareto frontier present");
        assert!(sweep.get("wall_ms").unwrap().as_f64().unwrap() >= 0.0);
        // the observability section ran its own hard gates (overhead,
        // grammar, id echo) inside run(); re-check the reported shape
        let obs = parsed.get("observability").unwrap();
        assert!(obs.get("tracing_off_p50_us").unwrap().as_f64().unwrap() > 0.0);
        assert!(obs.get("tracing_on_p50_us").unwrap().as_f64().unwrap() > 0.0);
        assert!(obs.get("spans_checked").unwrap().as_f64().unwrap() > 0.0);
        let ratio = obs.get("max_stage_sum_ratio").unwrap().as_f64().unwrap();
        assert!(ratio > 0.0 && ratio <= 1.0 + 1e-9, "stage sums bounded by totals: {ratio}");
        assert!(obs.get("headroom_layers").unwrap().as_f64().unwrap() >= 1.0);
        assert!(obs.get("min_headroom_bits").unwrap().as_f64().unwrap().is_finite());
        assert!(obs.get("prometheus_bytes").unwrap().as_f64().unwrap() > 0.0);
    }
}
