//! Readers for the bit-exactness goldens exported by `python/compile/aot.py`
//! (`artifacts/goldens/*.json`). These are the cross-layer contracts: the
//! Rust engine must reproduce the NumPy/Pallas integer semantics exactly.

use anyhow::{anyhow, Context, Result};
use std::path::Path;

use crate::util::json::Json;

/// One dot-product golden case.
#[derive(Clone, Debug)]
pub struct DotCase {
    pub w: Vec<i32>,
    pub x: Vec<i32>,
    /// accumulator bits -> policy name -> (value, events)
    pub results: Vec<(u32, Vec<(String, i64, i64)>)>,
    /// accumulator bits -> (exact, persistent, naive_events, transient)
    pub classify: Vec<(u32, (i64, bool, i64, bool))>,
}

pub fn load_dot_goldens<P: AsRef<Path>>(path: P) -> Result<Vec<DotCase>> {
    let txt = std::fs::read_to_string(path.as_ref()).context("reading dot goldens")?;
    let j = Json::parse(&txt)?;
    let mut out = Vec::new();
    for c in j.get("cases").and_then(Json::as_arr).ok_or_else(|| anyhow!("cases"))? {
        let w: Vec<i32> = c.get("w").and_then(Json::as_ivec).ok_or_else(|| anyhow!("w"))?
            .into_iter().map(|v| v as i32).collect();
        let x: Vec<i32> = c.get("x").and_then(Json::as_ivec).ok_or_else(|| anyhow!("x"))?
            .into_iter().map(|v| v as i32).collect();
        let mut results = Vec::new();
        let mut classify = Vec::new();
        if let Some(Json::Obj(res)) = c.get("results") {
            for (pbits, table) in res {
                let p: u32 = pbits.parse().context("p bits key")?;
                let mut pol = Vec::new();
                if let Json::Obj(t) = table {
                    for (name, val) in t {
                        if name == "classify" {
                            let v = val.as_ivec().ok_or_else(|| anyhow!("classify"))?;
                            classify.push((p, (v[0], v[1] != 0, v[2], v[3] != 0)));
                        } else {
                            let v = val.as_ivec().ok_or_else(|| anyhow!("policy vals"))?;
                            pol.push((name.clone(), v[0], v[1]));
                        }
                    }
                }
                results.push((p, pol));
            }
        }
        out.push(DotCase { w, x, results, classify });
    }
    Ok(out)
}

/// Matmul golden (pallas kernel contract).
#[derive(Clone, Debug)]
pub struct MatmulCase {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub p: u32,
    pub policy: String,
    pub x: Vec<i32>,
    pub w: Vec<i32>,
    pub y: Vec<i64>,
    pub ovf: Vec<i64>,
}

pub fn load_matmul_goldens<P: AsRef<Path>>(path: P) -> Result<Vec<MatmulCase>> {
    let txt = std::fs::read_to_string(path.as_ref()).context("reading matmul goldens")?;
    let j = Json::parse(&txt)?;
    let mut out = Vec::new();
    for c in j.get("cases").and_then(Json::as_arr).ok_or_else(|| anyhow!("cases"))? {
        let iv = |k: &str| -> Result<Vec<i64>> {
            c.get(k).and_then(Json::as_ivec).ok_or_else(|| anyhow!("field {k}"))
        };
        out.push(MatmulCase {
            m: c.get("m").and_then(Json::as_usize).ok_or_else(|| anyhow!("m"))?,
            k: c.get("k").and_then(Json::as_usize).ok_or_else(|| anyhow!("k"))?,
            n: c.get("n").and_then(Json::as_usize).ok_or_else(|| anyhow!("n"))?,
            p: c.get("p").and_then(Json::as_i64).ok_or_else(|| anyhow!("p"))? as u32,
            policy: c.get("policy").and_then(Json::as_str).unwrap_or("").to_string(),
            x: iv("x")?.into_iter().map(|v| v as i32).collect(),
            w: iv("w")?.into_iter().map(|v| v as i32).collect(),
            y: iv("y")?,
            ovf: iv("ovf")?,
        });
    }
    Ok(out)
}

/// End-to-end model golden (mlp1): quantized inputs, exact accumulators,
/// offset corrections and final logits for 8 test images.
#[derive(Clone, Debug)]
pub struct ModelGolden {
    pub model: String,
    pub batch: usize,
    pub ic: usize,
    pub oc: usize,
    pub xq: Vec<i32>,
    pub acc_exact: Vec<i64>,
    pub logits: Vec<f64>,
}

pub fn load_model_golden<P: AsRef<Path>>(path: P) -> Result<ModelGolden> {
    let txt = std::fs::read_to_string(path.as_ref()).context("reading model golden")?;
    let j = Json::parse(&txt)?;
    let shape = j.get("shape").and_then(Json::as_ivec).ok_or_else(|| anyhow!("shape"))?;
    Ok(ModelGolden {
        model: j.get("model").and_then(Json::as_str).unwrap_or("").to_string(),
        batch: shape[0] as usize,
        ic: shape[1] as usize,
        oc: shape[2] as usize,
        xq: j.get("xq").and_then(Json::as_ivec).ok_or_else(|| anyhow!("xq"))?
            .into_iter().map(|v| v as i32).collect(),
        acc_exact: j.get("acc_exact").and_then(Json::as_ivec).ok_or_else(|| anyhow!("acc"))?,
        logits: j.get("logits").and_then(Json::as_fvec).ok_or_else(|| anyhow!("logits"))?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_synthetic_dot_golden() {
        let dir = std::env::temp_dir().join("pqs_test_goldens");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("dot.json");
        std::fs::write(
            &p,
            r#"{"cases":[{"w":[1,-2],"x":[3,4],
                "results":{"14":{"exact":[-5,0],"classify":[-5,0,0,0]}}}]}"#,
        )
        .unwrap();
        let cases = load_dot_goldens(&p).unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].w, vec![1, -2]);
        assert_eq!(cases[0].results[0].0, 14);
        assert_eq!(cases[0].results[0].1[0], ("exact".to_string(), -5, 0));
        assert_eq!(cases[0].classify[0], (14, (-5, false, 0, false)));
    }
}
