//! `.pqsw` model container reader (written by `python/compile/pqsw.py`).
//!
//! Layout: magic `PQSW1\0\0\0`, u32le header length, JSON header, zero pad
//! to 8 bytes, then 8-aligned blobs. The header carries the model graph IR
//! shared with `python/compile/model.py` (see that module's docstring).

use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

use crate::util::json::Json;

pub const MAGIC: &[u8; 8] = b"PQSW1\x00\x00\x00";

/// Graph operation kinds (mirrors the python IR).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Input,
    Relu,
    Add,
    Gap,
    Flatten,
    QLinear,
    QConv,
    QDwConv,
}

impl Op {
    pub fn from_str(s: &str) -> Result<Op> {
        Ok(match s {
            "input" => Op::Input,
            "relu" => Op::Relu,
            "add" => Op::Add,
            "gap" => Op::Gap,
            "flatten" => Op::Flatten,
            "qlinear" => Op::QLinear,
            "qconv" => Op::QConv,
            "qdwconv" => Op::QDwConv,
            other => bail!("unknown op {other:?}"),
        })
    }

    pub fn is_q_layer(&self) -> bool {
        matches!(self, Op::QLinear | Op::QConv | Op::QDwConv)
    }
}

/// Quantized-layer metadata + weights.
#[derive(Clone, Debug)]
pub struct QLayerMeta {
    pub name: String,
    pub oc: usize,
    pub ic: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
    pub prune: bool,
    pub w_scale: f32,
    pub x_scale: f32,
    pub x_offset: i32,
    /// int8 weights, (oc, K) row-major; K = ic*kh*kw (kh*kw for depthwise)
    pub wq: Vec<i8>,
    /// contraction length
    pub k: usize,
    pub bias: Vec<f32>,
}

/// One node of the model graph.
#[derive(Clone, Debug)]
pub struct GraphNode {
    pub id: usize,
    pub op: Op,
    pub inputs: Vec<usize>,
    pub q: Option<QLayerMeta>,
}

/// A parsed `.pqsw` model.
#[derive(Clone, Debug)]
pub struct PqswModel {
    pub name: String,
    pub arch: String,
    pub schedule: String,
    pub wbits: u8,
    pub abits: u8,
    pub nm_m: usize,
    pub target_sparsity: f64,
    pub achieved_sparsity: f64,
    pub acc_bits_trained: Option<u32>,
    pub lowrank_k: Option<usize>,
    pub acc_q: f64,
    pub acc_fp32: f64,
    pub input_shape: Vec<usize>,
    pub graph: Vec<GraphNode>,
}

struct Blob {
    offset: usize,
    len: usize,
    dtype: String,
}

impl PqswModel {
    pub fn load<P: AsRef<Path>>(path: P) -> Result<PqswModel> {
        let raw = std::fs::read(path.as_ref())
            .with_context(|| format!("reading model {:?}", path.as_ref()))?;
        if raw.len() < 12 || &raw[0..8] != MAGIC {
            bail!("bad PQSW magic in {:?}", path.as_ref());
        }
        let hlen = u32::from_le_bytes(raw[8..12].try_into().unwrap()) as usize;
        let hdr_txt = std::str::from_utf8(&raw[12..12 + hlen]).context("header utf8")?;
        let h = Json::parse(hdr_txt).context("header json")?;
        let blob_base = (12 + hlen + 7) & !7;

        let blobs: Vec<Blob> = h
            .get("blobs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing blobs"))?
            .iter()
            .map(|b| {
                Ok(Blob {
                    offset: b.get("offset").and_then(Json::as_usize).ok_or_else(|| anyhow!("blob offset"))?,
                    len: b.get("len").and_then(Json::as_usize).ok_or_else(|| anyhow!("blob len"))?,
                    dtype: b.get("dtype").and_then(Json::as_str).unwrap_or("").to_string(),
                })
            })
            .collect::<Result<_>>()?;

        let blob_bytes = |i: usize| -> Result<&[u8]> {
            let b = blobs.get(i).ok_or_else(|| anyhow!("blob index {i}"))?;
            let a = blob_base + b.offset;
            raw.get(a..a + b.len).ok_or_else(|| anyhow!("blob {i} out of bounds"))
        };

        let mut graph = Vec::new();
        for n in h.get("graph").and_then(Json::as_arr).ok_or_else(|| anyhow!("missing graph"))? {
            let op = Op::from_str(n.get("op").and_then(Json::as_str).unwrap_or(""))?;
            let id = n.get("id").and_then(Json::as_usize).ok_or_else(|| anyhow!("node id"))?;
            let inputs = n
                .get("inputs")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default();
            let q = if op.is_q_layer() {
                let geti = |k: &str, d: usize| n.get(k).and_then(Json::as_usize).unwrap_or(d);
                let oc = geti("oc", 0);
                let ic = geti("ic", 0);
                let kh = geti("kh", 1);
                let kw = geti("kw", 1);
                let wq_raw = blob_bytes(geti("wq_blob", usize::MAX))?;
                let bias_raw = blob_bytes(geti("bias_blob", usize::MAX))?;
                if blobs[geti("wq_blob", 0)].dtype != "i8" {
                    bail!("weight blob dtype");
                }
                let wq: Vec<i8> = wq_raw.iter().map(|&b| b as i8).collect();
                let bias: Vec<f32> = bias_raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let k = if op == Op::QDwConv { kh * kw } else { ic * kh * kw };
                if wq.len() != oc * k {
                    bail!("weight blob size {} != oc*k {}", wq.len(), oc * k);
                }
                if bias.len() != oc {
                    bail!("bias blob size {} != oc {}", bias.len(), oc);
                }
                Some(QLayerMeta {
                    name: n.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                    oc,
                    ic,
                    kh,
                    kw,
                    stride: geti("stride", 1),
                    pad: geti("pad", 0),
                    prune: n.get("prune").and_then(Json::as_bool).unwrap_or(false),
                    w_scale: n.get("w_scale").and_then(Json::as_f64).unwrap_or(1.0) as f32,
                    x_scale: n.get("x_scale").and_then(Json::as_f64).unwrap_or(1.0) as f32,
                    x_offset: n.get("x_offset").and_then(Json::as_i64).unwrap_or(0) as i32,
                    wq,
                    k,
                    bias,
                })
            } else {
                None
            };
            graph.push(GraphNode { id, op, inputs, q });
        }

        let gets = |k: &str| h.get(k).and_then(Json::as_str).unwrap_or("").to_string();
        Ok(PqswModel {
            name: gets("name"),
            arch: gets("arch"),
            schedule: gets("schedule"),
            wbits: h.get("wbits").and_then(Json::as_i64).unwrap_or(8) as u8,
            abits: h.get("abits").and_then(Json::as_i64).unwrap_or(8) as u8,
            nm_m: h.get("nm_m").and_then(Json::as_usize).unwrap_or(0),
            target_sparsity: h.get("target_sparsity").and_then(Json::as_f64).unwrap_or(0.0),
            achieved_sparsity: h.get("achieved_sparsity").and_then(Json::as_f64).unwrap_or(0.0),
            acc_bits_trained: h
                .get("acc_bits_trained")
                .and_then(Json::as_i64)
                .map(|v| v as u32),
            lowrank_k: h.get("lowrank_k").and_then(Json::as_usize),
            acc_q: h.get("acc_q").and_then(Json::as_f64).unwrap_or(0.0),
            acc_fp32: h.get("acc_fp32").and_then(Json::as_f64).unwrap_or(0.0),
            input_shape: h
                .get("input_shape")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            graph,
        })
    }

    /// All quantized layers in graph order.
    pub fn q_layers(&self) -> impl Iterator<Item = (&GraphNode, &QLayerMeta)> {
        self.graph.iter().filter_map(|n| n.q.as_ref().map(|q| (n, q)))
    }

    /// Total / nonzero weight counts over prunable layers.
    pub fn weight_sparsity(&self) -> f64 {
        let (mut z, mut t) = (0usize, 0usize);
        for (_, q) in self.q_layers() {
            if !q.prune {
                continue;
            }
            t += q.wq.len();
            z += q.wq.iter().filter(|&&v| v == 0).count();
        }
        if t == 0 {
            0.0
        } else {
            z as f64 / t as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_parsing() {
        assert_eq!(Op::from_str("qconv").unwrap(), Op::QConv);
        assert!(Op::from_str("conv3d").is_err());
        assert!(Op::QLinear.is_q_layer());
        assert!(!Op::Relu.is_q_layer());
    }

    // Full-file parsing is covered by integration tests against real
    // artifacts (rust/tests/artifacts.rs); here we test the error paths.
    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("pqs_test_pqsw");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.pqsw");
        std::fs::write(&p, b"NOTPQSW0rest").unwrap();
        assert!(PqswModel::load(&p).is_err());
    }
}
