//! `.pqsw` model container reader/writer (format shared with
//! `python/compile/pqsw.py`).
//!
//! Layout: magic `PQSW1\0\0\0`, u32le header length, JSON header, zero pad
//! to 8 bytes, then 8-aligned blobs. The header carries the model graph IR
//! shared with `python/compile/model.py` (see that module's docstring).
//!
//! ### Versioned optional sections (format version 2)
//! The header may carry a `"format_version"` (absent = 1) and a
//! `"sections"` array of tagged objects. Known tags are parsed into the
//! model; an **unknown** tag fails the load with an error naming the tag
//! and the file's format version, so future format evolutions fail
//! diagnosably instead of being silently dropped. Version-1 files (no
//! sections) load exactly as before. This build understands two tags:
//! `"plan"` — a per-layer accumulator-bitwidth plan
//! ([`crate::plan::AccumPlan`]) that `nn::Engine` applies automatically —
//! and `"checksums"` — per-q-layer FNV-1a digests of the weight+bias
//! bytes, verified on **both** the lazy and eager load paths so a
//! corrupted file surfaces as a diagnosable [`verify_integrity`]
//! error (which the fleet router turns into a quarantine), never as a
//! panic and never as silently wrong logits. Integrity errors carry the
//! [`INTEGRITY_MARKER`] context so callers can classify them without
//! downcasting ([`is_integrity_error`]); `save` refreshes the digests
//! from the bytes it writes whenever it emits a version-2 header, and
//! plan-free checksum-free models still serialize as version-1 files,
//! byte-identical to python exports.
//!
//! [`verify_integrity`]: PqswModel::verify_integrity
//!
//! ### Zero-copy loading
//! [`PqswModel::load`] keeps the raw file bytes alive as one shared
//! `Arc<[u8]>`, parses only the JSON header, and hands each quantized
//! layer a [`Weights::Borrowed`] view straight into the 8-aligned blob
//! section — no per-layer copy, and the layout is mmap-friendly should a
//! platform mmap backend land later. [`PqswModel::load_eager`] is the old
//! decode-everything path; both are bit-identical through the engine
//! because [`Weights`] derefs to the same `[i8]` either way. Every model
//! additionally exposes [`PqswModel::content_hash`] (an FNV-1a digest of
//! its quantized layers, independent of how the bytes are hosted) and
//! [`PqswModel::resident_bytes`] (exact owned-plus-shared accounting,
//! each distinct backing blob counted once) so callers like the fleet
//! router can budget and dedup resident weight memory.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use crate::plan::AccumPlan;
use crate::util::json::{self, Json};

pub const MAGIC: &[u8; 8] = b"PQSW1\x00\x00\x00";

/// Newest header format this build writes/understands.
pub const FORMAT_VERSION: i64 = 2;

/// Section tags this build can parse.
pub const KNOWN_SECTION_TAGS: &[&str] = &["plan", "checksums"];

/// The only checksum algorithm this build writes or verifies.
pub const CHECKSUM_ALGO: &str = "fnv1a64";

/// Context marker every integrity-failure error carries (the vendored
/// `anyhow` shim has no downcasting, so classification is by marker).
pub const INTEGRITY_MARKER: &str = "model integrity";

/// Does this error chain contain an integrity failure (checksum
/// mismatch, plan/shape inconsistency)? The fleet router quarantines on
/// these instead of retrying: the bytes are bad, not the I/O.
pub fn is_integrity_error(e: &anyhow::Error) -> bool {
    e.chain().any(|m| m.contains(INTEGRITY_MARKER))
}

/// Graph operation kinds (mirrors the python IR).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Input,
    Relu,
    Add,
    Gap,
    Flatten,
    QLinear,
    QConv,
    QDwConv,
}

impl Op {
    pub fn from_str(s: &str) -> Result<Op> {
        Ok(match s {
            "input" => Op::Input,
            "relu" => Op::Relu,
            "add" => Op::Add,
            "gap" => Op::Gap,
            "flatten" => Op::Flatten,
            "qlinear" => Op::QLinear,
            "qconv" => Op::QConv,
            "qdwconv" => Op::QDwConv,
            other => bail!("unknown op {other:?}"),
        })
    }

    pub fn is_q_layer(&self) -> bool {
        matches!(self, Op::QLinear | Op::QConv | Op::QDwConv)
    }

    /// The IR string this op serializes as (inverse of [`Op::from_str`]).
    pub fn name(&self) -> &'static str {
        match self {
            Op::Input => "input",
            Op::Relu => "relu",
            Op::Add => "add",
            Op::Gap => "gap",
            Op::Flatten => "flatten",
            Op::QLinear => "qlinear",
            Op::QConv => "qconv",
            Op::QDwConv => "qdwconv",
        }
    }
}

/// Streaming FNV-1a (64-bit) — the dependency-free content digest used
/// for [`PqswModel::content_hash`] and the router's blob dedup map.
#[derive(Clone, Copy, Debug)]
pub struct Fnv1a(u64);

impl Fnv1a {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;

    pub fn new() -> Fnv1a {
        Fnv1a(Self::OFFSET)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Fnv1a {
        Fnv1a::new()
    }
}

/// A layer's int8 weights: either an owned `Vec<i8>` (eager loads,
/// programmatic models) or a borrowed window into a shared `Arc<[u8]>`
/// file blob (lazy loads). Both deref to `&[i8]`, so every consumer —
/// the engine, `save`, sparsity stats — sees the identical slice either
/// way; the variant only changes who owns the bytes.
#[derive(Clone)]
pub enum Weights {
    Owned(Vec<i8>),
    Borrowed {
        blob: Arc<[u8]>,
        offset: usize,
        len: usize,
    },
}

impl Weights {
    pub fn as_slice(&self) -> &[i8] {
        match self {
            Weights::Owned(v) => v,
            Weights::Borrowed { blob, offset, len } => {
                let bytes = &blob[*offset..*offset + *len];
                // SAFETY: i8 and u8 have identical size, alignment, and
                // validity; reinterpreting a byte slice is lossless.
                unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const i8, bytes.len()) }
            }
        }
    }

    pub fn is_borrowed(&self) -> bool {
        matches!(self, Weights::Borrowed { .. })
    }

    /// The shared file blob backing a borrowed view (`None` when owned).
    pub fn backing_blob(&self) -> Option<&Arc<[u8]>> {
        match self {
            Weights::Owned(_) => None,
            Weights::Borrowed { blob, .. } => Some(blob),
        }
    }

    pub fn to_owned_vec(&self) -> Vec<i8> {
        self.as_slice().to_vec()
    }

    /// Re-point a borrowed view at `canonical` when the backing bytes are
    /// byte-identical, so duplicate loads share one allocation. Returns
    /// whether the view now borrows from `canonical`.
    pub fn rehost(&mut self, canonical: &Arc<[u8]>) -> bool {
        match self {
            Weights::Owned(_) => false,
            Weights::Borrowed { blob, .. } => {
                if Arc::ptr_eq(blob, canonical) {
                    return true;
                }
                if **blob == **canonical {
                    *blob = Arc::clone(canonical);
                    return true;
                }
                false
            }
        }
    }
}

impl std::ops::Deref for Weights {
    type Target = [i8];

    fn deref(&self) -> &[i8] {
        self.as_slice()
    }
}

impl From<Vec<i8>> for Weights {
    fn from(v: Vec<i8>) -> Weights {
        Weights::Owned(v)
    }
}

impl PartialEq for Weights {
    fn eq(&self, other: &Weights) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl std::fmt::Debug for Weights {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Weights::Owned(v) => write!(f, "Weights::Owned({} values)", v.len()),
            Weights::Borrowed { len, offset, .. } => {
                write!(f, "Weights::Borrowed({len} values @ blob+{offset})")
            }
        }
    }
}

/// Quantized-layer metadata + weights.
#[derive(Clone, Debug)]
pub struct QLayerMeta {
    pub name: String,
    pub oc: usize,
    pub ic: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
    pub prune: bool,
    pub w_scale: f32,
    pub x_scale: f32,
    pub x_offset: i32,
    /// int8 weights, (oc, K) row-major; K = ic*kh*kw (kh*kw for depthwise)
    pub wq: Weights,
    /// contraction length
    pub k: usize,
    pub bias: Vec<f32>,
}

/// One node of the model graph.
#[derive(Clone, Debug)]
pub struct GraphNode {
    pub id: usize,
    pub op: Op,
    pub inputs: Vec<usize>,
    pub q: Option<QLayerMeta>,
}

/// A parsed `.pqsw` model.
#[derive(Clone, Debug)]
pub struct PqswModel {
    pub name: String,
    pub arch: String,
    pub schedule: String,
    pub wbits: u8,
    pub abits: u8,
    pub nm_m: usize,
    pub target_sparsity: f64,
    pub achieved_sparsity: f64,
    pub acc_bits_trained: Option<u32>,
    pub lowrank_k: Option<usize>,
    pub acc_q: f64,
    pub acc_fp32: f64,
    pub input_shape: Vec<usize>,
    pub graph: Vec<GraphNode>,
    /// Embedded per-layer accumulator-bitwidth plan (format-version-2
    /// `"plan"` section; `None` for plan-free files). `nn::Engine` applies
    /// it automatically on construction.
    pub plan: Option<AccumPlan>,
    /// Per-q-layer FNV-1a weight digests (format-version-2 `"checksums"`
    /// section, graph order; `None` for files without one). Verified
    /// against the decoded layers on load and by
    /// [`PqswModel::verify_integrity`].
    pub checksums: Option<Vec<u64>>,
}

struct Blob {
    offset: usize,
    len: usize,
    dtype: String,
}

impl PqswModel {
    /// Parse a `.pqsw` file *lazily*: the JSON header is decoded, but each
    /// layer's int8 weights stay in the shared file blob (`Arc<[u8]>`) as
    /// [`Weights::Borrowed`] views — one allocation for the whole file,
    /// no per-layer copies.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<PqswModel> {
        Self::load_impl(path.as_ref(), false)
    }

    /// Parse a `.pqsw` file *eagerly*: every layer's weights are decoded
    /// into owned `Vec<i8>`s and the file buffer is dropped. Bit-identical
    /// to [`PqswModel::load`]; kept for callers that want to release the
    /// (padded, header-carrying) file bytes after load.
    pub fn load_eager<P: AsRef<Path>>(path: P) -> Result<PqswModel> {
        Self::load_impl(path.as_ref(), true)
    }

    fn load_impl(path: &Path, eager: bool) -> Result<PqswModel> {
        let raw: Arc<[u8]> = std::fs::read(path)
            .with_context(|| format!("reading model {path:?}"))?
            .into();
        if raw.len() < 12 || &raw[0..8] != MAGIC {
            bail!("bad PQSW magic in {path:?}");
        }
        let hlen = u32::from_le_bytes(raw[8..12].try_into().unwrap()) as usize;
        // a truncated file (or a corrupted length field) must surface as
        // an error, never a slice panic
        let hdr = raw.get(12..12 + hlen).ok_or_else(|| {
            anyhow!("header length {hlen} overruns the {}-byte file {path:?}", raw.len())
        })?;
        let hdr_txt = std::str::from_utf8(hdr).context("header utf8")?;
        let h = Json::parse(hdr_txt).context("header json")?;
        let blob_base = (12 + hlen + 7) & !7;

        let blobs: Vec<Blob> = h
            .get("blobs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing blobs"))?
            .iter()
            .map(|b| {
                Ok(Blob {
                    offset: b.get("offset").and_then(Json::as_usize).ok_or_else(|| anyhow!("blob offset"))?,
                    len: b.get("len").and_then(Json::as_usize).ok_or_else(|| anyhow!("blob len"))?,
                    dtype: b.get("dtype").and_then(Json::as_str).unwrap_or("").to_string(),
                })
            })
            .collect::<Result<_>>()?;

        // absolute (offset, len) of blob i, bounds-checked against the file
        let blob_span = |i: usize| -> Result<(usize, usize)> {
            let b = blobs.get(i).ok_or_else(|| anyhow!("blob index {i}"))?;
            // header-supplied offsets/lengths are untrusted: checked
            // arithmetic so corrupt values error instead of overflowing
            let a = blob_base.checked_add(b.offset).ok_or_else(|| anyhow!("blob {i} offset"))?;
            let end = a.checked_add(b.len).ok_or_else(|| anyhow!("blob {i} out of bounds"))?;
            if raw.get(a..end).is_none() {
                bail!("blob {i} out of bounds");
            }
            Ok((a, b.len))
        };
        let blob_bytes = |i: usize| -> Result<&[u8]> {
            let (a, len) = blob_span(i)?;
            Ok(&raw[a..a + len])
        };

        let mut graph = Vec::new();
        for n in h.get("graph").and_then(Json::as_arr).ok_or_else(|| anyhow!("missing graph"))? {
            let op = Op::from_str(n.get("op").and_then(Json::as_str).unwrap_or(""))?;
            let id = n.get("id").and_then(Json::as_usize).ok_or_else(|| anyhow!("node id"))?;
            let inputs = n
                .get("inputs")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default();
            let q = if op.is_q_layer() {
                let geti = |k: &str, d: usize| n.get(k).and_then(Json::as_usize).unwrap_or(d);
                let oc = geti("oc", 0);
                let ic = geti("ic", 0);
                let kh = geti("kh", 1);
                let kw = geti("kw", 1);
                let (wq_off, wq_len) = blob_span(geti("wq_blob", usize::MAX))?;
                let bias_raw = blob_bytes(geti("bias_blob", usize::MAX))?;
                if blobs[geti("wq_blob", 0)].dtype != "i8" {
                    bail!("weight blob dtype");
                }
                let wq: Weights = if eager {
                    Weights::Owned(raw[wq_off..wq_off + wq_len].iter().map(|&b| b as i8).collect())
                } else {
                    Weights::Borrowed { blob: Arc::clone(&raw), offset: wq_off, len: wq_len }
                };
                let bias: Vec<f32> = bias_raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let k = if op == Op::QDwConv {
                    kh.checked_mul(kw)
                } else {
                    ic.checked_mul(kh).and_then(|v| v.checked_mul(kw))
                }
                .ok_or_else(|| anyhow!("layer {id}: shape overflow"))?;
                let expect =
                    oc.checked_mul(k).ok_or_else(|| anyhow!("layer {id}: shape overflow"))?;
                if wq.len() != expect {
                    bail!("weight blob size {} != oc*k {expect}", wq.len());
                }
                if bias.len() != oc {
                    bail!("bias blob size {} != oc {}", bias.len(), oc);
                }
                Some(QLayerMeta {
                    name: n.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                    oc,
                    ic,
                    kh,
                    kw,
                    stride: geti("stride", 1),
                    pad: geti("pad", 0),
                    prune: n.get("prune").and_then(Json::as_bool).unwrap_or(false),
                    w_scale: n.get("w_scale").and_then(Json::as_f64).unwrap_or(1.0) as f32,
                    x_scale: n.get("x_scale").and_then(Json::as_f64).unwrap_or(1.0) as f32,
                    x_offset: n.get("x_offset").and_then(Json::as_i64).unwrap_or(0) as i32,
                    wq,
                    k,
                    bias,
                })
            } else {
                None
            };
            graph.push(GraphNode { id, op, inputs, q });
        }

        // versioned optional sections (format version 2+). Unknown tags
        // fail the load *naming the tag and the file's format version*:
        // a future format evolution must surface as a diagnosable error,
        // never as silently dropped data.
        let format_version = h.get("format_version").and_then(Json::as_i64).unwrap_or(1);
        let mut plan = None;
        let mut checksums = None;
        if let Some(sections) = h.get("sections").and_then(Json::as_arr) {
            for sec in sections {
                match sec.get("tag").and_then(Json::as_str) {
                    Some("plan") => {
                        plan = Some(AccumPlan::from_json(sec).with_context(|| {
                            format!(
                                "parsing the plan section of {:?} (format version \
                                 {format_version})",
                                path.as_ref()
                            )
                        })?);
                    }
                    Some("checksums") => {
                        checksums = Some(parse_checksums_section(sec).with_context(|| {
                            format!(
                                "parsing the checksums section of {:?} (format version \
                                 {format_version})",
                                path.as_ref()
                            )
                        })?);
                    }
                    Some(other) => bail!(
                        "unknown .pqsw section tag {other:?} in {:?} (file format version \
                         {format_version}; this build understands: {})",
                        path.as_ref(),
                        KNOWN_SECTION_TAGS.join(", "),
                    ),
                    None => bail!(
                        "untagged .pqsw section in {:?} (file format version {format_version})",
                        path.as_ref()
                    ),
                }
            }
        }

        let gets = |k: &str| h.get(k).and_then(Json::as_str).unwrap_or("").to_string();
        let model = PqswModel {
            name: gets("name"),
            arch: gets("arch"),
            schedule: gets("schedule"),
            wbits: h.get("wbits").and_then(Json::as_i64).unwrap_or(8) as u8,
            abits: h.get("abits").and_then(Json::as_i64).unwrap_or(8) as u8,
            nm_m: h.get("nm_m").and_then(Json::as_usize).unwrap_or(0),
            target_sparsity: h.get("target_sparsity").and_then(Json::as_f64).unwrap_or(0.0),
            achieved_sparsity: h.get("achieved_sparsity").and_then(Json::as_f64).unwrap_or(0.0),
            acc_bits_trained: h
                .get("acc_bits_trained")
                .and_then(Json::as_i64)
                .map(|v| v as u32),
            lowrank_k: h.get("lowrank_k").and_then(Json::as_usize),
            acc_q: h.get("acc_q").and_then(Json::as_f64).unwrap_or(0.0),
            acc_fp32: h.get("acc_fp32").and_then(Json::as_f64).unwrap_or(0.0),
            input_shape: h
                .get("input_shape")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            graph,
            plan,
            checksums,
        };
        // End-to-end integrity: both the lazy and the eager path funnel
        // through here, so a checksum-carrying file is always verified
        // against its decoded layers before anyone can run it.
        model
            .verify_integrity()
            .with_context(|| format!("verifying model {path:?}"))?;
        Ok(model)
    }

    /// Write the model as a `.pqsw` file the loader (and the python
    /// toolchain) accepts: same magic/header/blob layout as
    /// `python/compile/pqsw.py`, plus — when a plan is embedded — the
    /// format-version-2 `"sections"` array. Plan-free models are written
    /// as plain version-1 files, indistinguishable from python exports.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let align8 = |n: usize| (n + 7) & !7;
        // (dtype, raw bytes) per blob, indexed by the graph nodes
        let mut blobs: Vec<(&'static str, Vec<u8>)> = Vec::new();
        let mut graph_rows: Vec<Json> = Vec::new();
        for n in &self.graph {
            let mut row: BTreeMap<String, Json> = BTreeMap::new();
            row.insert("id".into(), json::num(n.id as f64));
            row.insert("op".into(), json::s(n.op.name()));
            row.insert(
                "inputs".into(),
                Json::Arr(n.inputs.iter().map(|&i| json::num(i as f64)).collect()),
            );
            if let Some(q) = &n.q {
                row.insert("name".into(), json::s(&q.name));
                row.insert("oc".into(), json::num(q.oc as f64));
                row.insert("ic".into(), json::num(q.ic as f64));
                row.insert("kh".into(), json::num(q.kh as f64));
                row.insert("kw".into(), json::num(q.kw as f64));
                row.insert("stride".into(), json::num(q.stride as f64));
                row.insert("pad".into(), json::num(q.pad as f64));
                row.insert("prune".into(), Json::Bool(q.prune));
                row.insert("w_scale".into(), json::num(q.w_scale as f64));
                row.insert("x_scale".into(), json::num(q.x_scale as f64));
                row.insert("x_offset".into(), json::num(q.x_offset as f64));
                row.insert("wq_blob".into(), json::num(blobs.len() as f64));
                blobs.push(("i8", q.wq.iter().map(|&v| v as u8).collect()));
                row.insert("bias_blob".into(), json::num(blobs.len() as f64));
                blobs.push((
                    "f32",
                    q.bias.iter().flat_map(|v| v.to_le_bytes()).collect(),
                ));
            }
            graph_rows.push(Json::Obj(row));
        }
        // blob offsets are relative to the 8-aligned blob-section start
        let mut blobs_meta: Vec<Json> = Vec::new();
        let mut off = 0usize;
        for (dtype, raw) in &blobs {
            blobs_meta.push(json::obj(vec![
                ("offset", json::num(off as f64)),
                ("len", json::num(raw.len() as f64)),
                ("dtype", json::s(dtype)),
            ]));
            off = align8(off + raw.len());
        }
        let opt_num = |v: Option<f64>| match v {
            Some(x) => json::num(x),
            None => Json::Null,
        };
        let mut header: BTreeMap<String, Json> = BTreeMap::new();
        header.insert("name".into(), json::s(&self.name));
        header.insert("arch".into(), json::s(&self.arch));
        header.insert("schedule".into(), json::s(&self.schedule));
        header.insert("wbits".into(), json::num(self.wbits as f64));
        header.insert("abits".into(), json::num(self.abits as f64));
        header.insert("nm_m".into(), json::num(self.nm_m as f64));
        header.insert("target_sparsity".into(), json::num(self.target_sparsity));
        header.insert("achieved_sparsity".into(), json::num(self.achieved_sparsity));
        header.insert(
            "acc_bits_trained".into(),
            opt_num(self.acc_bits_trained.map(|v| v as f64)),
        );
        header.insert("lowrank_k".into(), opt_num(self.lowrank_k.map(|v| v as f64)));
        header.insert("acc_q".into(), json::num(self.acc_q));
        header.insert("acc_fp32".into(), json::num(self.acc_fp32));
        header.insert(
            "input_shape".into(),
            Json::Arr(self.input_shape.iter().map(|&d| json::num(d as f64)).collect()),
        );
        header.insert("graph".into(), Json::Arr(graph_rows));
        header.insert("blobs".into(), Json::Arr(blobs_meta));
        if self.plan.is_some() || self.checksums.is_some() {
            let mut sections = Vec::new();
            if let Some(plan) = &self.plan {
                sections.push(plan.to_json());
            }
            // checksums are a property of the bytes being written, so a
            // version-2 save always refreshes them from the live weights
            sections.push(checksums_section(&self.layer_checksums()));
            header.insert("format_version".into(), json::num(FORMAT_VERSION as f64));
            header.insert("sections".into(), Json::Arr(sections));
        }
        let hdr = Json::Obj(header).to_string().into_bytes();

        let mut out: Vec<u8> = Vec::with_capacity(12 + hdr.len() + off + 16);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(hdr.len() as u32).to_le_bytes());
        out.extend_from_slice(&hdr);
        out.resize(align8(out.len()), 0); // pad header to the blob base
        for (_, raw) in &blobs {
            out.extend_from_slice(raw);
            out.resize(align8(out.len()), 0); // keep every blob 8-aligned
        }
        std::fs::write(path.as_ref(), &out)
            .with_context(|| format!("writing model {:?}", path.as_ref()))
    }

    /// All quantized layers in graph order.
    pub fn q_layers(&self) -> impl Iterator<Item = (&GraphNode, &QLayerMeta)> {
        self.graph.iter().filter_map(|n| n.q.as_ref().map(|q| (n, q)))
    }

    /// Fresh per-q-layer digests (graph order) of the live bytes — the
    /// unit the `"checksums"` section stores.
    pub fn layer_checksums(&self) -> Vec<u64> {
        self.q_layers().map(|(_, q)| layer_checksum(q)).collect()
    }

    /// Stamp the model with digests of its current bytes, upgrading the
    /// next [`PqswModel::save`] to a checksum-carrying version-2 file.
    pub fn attach_checksums(&mut self) {
        self.checksums = Some(self.layer_checksums());
    }

    /// Cross-check the model against its own metadata: every embedded
    /// checksum must match the live layer bytes, and an embedded plan may
    /// only reference layers the graph actually has. Failures carry
    /// [`INTEGRITY_MARKER`] (classify with [`is_integrity_error`]); a
    /// model without checksums or plan trivially passes. The fleet
    /// router quarantines a model on any error from here — retrying
    /// cannot fix bad bytes.
    pub fn verify_integrity(&self) -> Result<()> {
        if let Some(plan) = &self.plan {
            for lp in &plan.per_layer {
                if !self.q_layers().any(|(_, q)| q.name == lp.name) {
                    bail!(
                        "{INTEGRITY_MARKER}: plan references layer {:?} but the graph has no \
                         such q-layer",
                        lp.name
                    );
                }
            }
        }
        if let Some(sums) = &self.checksums {
            let n = self.q_layers().count();
            if sums.len() != n {
                bail!(
                    "{INTEGRITY_MARKER}: header carries {} checksums for {n} q-layers",
                    sums.len()
                );
            }
            for (i, ((_, q), &want)) in self.q_layers().zip(sums.iter()).enumerate() {
                let got = layer_checksum(q);
                if got != want {
                    bail!(
                        "{INTEGRITY_MARKER}: checksum mismatch on q-layer {i} ({:?}): computed \
                         {got:016x}, header says {want:016x}",
                        q.name
                    );
                }
            }
        }
        Ok(())
    }

    /// Total / nonzero weight counts over prunable layers.
    pub fn weight_sparsity(&self) -> f64 {
        let (mut z, mut t) = (0usize, 0usize);
        for (_, q) in self.q_layers() {
            if !q.prune {
                continue;
            }
            t += q.wq.len();
            z += q.wq.iter().filter(|&&v| v == 0).count();
        }
        if t == 0 {
            0.0
        } else {
            z as f64 / t as f64
        }
    }

    /// FNV-1a digest over the quantized layers — shape, weights, bias —
    /// independent of whether the weights are owned or borrowed (and of
    /// header padding, scales cosmetics, or an embedded plan), so two
    /// loads of byte-identical weight content hash equal.
    pub fn content_hash(&self) -> u64 {
        let mut h = Fnv1a::new();
        for (_, q) in self.q_layers() {
            h.write(&(q.oc as u64).to_le_bytes());
            h.write(&(q.k as u64).to_le_bytes());
            let w = q.wq.as_slice();
            // SAFETY: i8 and u8 have identical size, alignment, validity.
            let bytes =
                unsafe { std::slice::from_raw_parts(w.as_ptr() as *const u8, w.len()) };
            h.write(bytes);
            for b in &q.bias {
                h.write(&b.to_le_bytes());
            }
        }
        h.finish()
    }

    /// Exact bytes this model keeps resident: owned weights + biases in
    /// full, plus each *distinct* shared backing blob counted once (so a
    /// lazily-loaded model is charged its whole file buffer exactly once,
    /// and models rehosted onto a common blob can be net-charged zero by
    /// a caller that tracks blobs separately).
    pub fn resident_bytes(&self) -> u64 {
        let mut total = 0u64;
        let mut seen: Vec<*const u8> = Vec::new();
        for (_, q) in self.q_layers() {
            match &q.wq {
                Weights::Owned(v) => total += v.len() as u64,
                Weights::Borrowed { blob, .. } => {
                    let p = blob.as_ptr();
                    if !seen.contains(&p) {
                        seen.push(p);
                        total += blob.len() as u64;
                    }
                }
            }
            total += (q.bias.len() * 4) as u64;
        }
        total
    }

    /// The shared file blob backing this model's borrowed weights, if any
    /// (the first one found; a single `load` only ever creates one).
    pub fn backing_blob(&self) -> Option<Arc<[u8]>> {
        self.graph
            .iter()
            .filter_map(|n| n.q.as_ref())
            .find_map(|q| q.wq.backing_blob().map(Arc::clone))
    }

    /// Convert every borrowed weight view into an owned copy, releasing
    /// the shared file blob.
    pub fn materialize(&mut self) {
        for n in &mut self.graph {
            if let Some(q) = &mut n.q {
                if q.wq.is_borrowed() {
                    q.wq = Weights::Owned(q.wq.to_owned_vec());
                }
            }
        }
    }

    /// Re-point every borrowed weight view at `canonical` when byte-
    /// identical (see [`Weights::rehost`]); returns whether any view now
    /// borrows from `canonical`.
    pub fn rehost(&mut self, canonical: &Arc<[u8]>) -> bool {
        let mut any = false;
        for n in &mut self.graph {
            if let Some(q) = &mut n.q {
                any |= q.wq.rehost(canonical);
            }
        }
        any
    }
}

/// FNV-1a digest of one q-layer's shape + weights + bias (the per-layer
/// slice of [`PqswModel::content_hash`]; `python/compile/pqsw.py`
/// computes the identical value when exporting).
fn layer_checksum(q: &QLayerMeta) -> u64 {
    let mut h = Fnv1a::new();
    h.write(&(q.oc as u64).to_le_bytes());
    h.write(&(q.k as u64).to_le_bytes());
    let w = q.wq.as_slice();
    // SAFETY: i8 and u8 have identical size, alignment, validity.
    let bytes = unsafe { std::slice::from_raw_parts(w.as_ptr() as *const u8, w.len()) };
    h.write(bytes);
    for b in &q.bias {
        h.write(&b.to_le_bytes());
    }
    h.finish()
}

/// The `"checksums"` section object for a header's `sections` array.
fn checksums_section(sums: &[u64]) -> Json {
    json::obj(vec![
        ("tag", json::s("checksums")),
        ("algo", json::s(CHECKSUM_ALGO)),
        // hex strings: JSON numbers travel as f64 and would round 64-bit
        // hashes above 2^53
        ("layers", Json::Arr(sums.iter().map(|s| json::s(&format!("{s:016x}"))).collect())),
    ])
}

fn parse_checksums_section(sec: &Json) -> Result<Vec<u64>> {
    let algo = sec.get("algo").and_then(Json::as_str).unwrap_or("");
    if algo != CHECKSUM_ALGO {
        bail!("unknown checksum algorithm {algo:?} (this build understands: {CHECKSUM_ALGO})");
    }
    sec.get("layers")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("checksums section missing its layers array"))?
        .iter()
        .map(|v| {
            let s = v.as_str().ok_or_else(|| anyhow!("checksum is not a hex string"))?;
            u64::from_str_radix(s, 16).map_err(|_| anyhow!("bad checksum hex {s:?}"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_parsing() {
        assert_eq!(Op::from_str("qconv").unwrap(), Op::QConv);
        assert!(Op::from_str("conv3d").is_err());
        assert!(Op::QLinear.is_q_layer());
        assert!(!Op::Relu.is_q_layer());
    }

    #[test]
    fn layer_checksum_matches_the_python_exporter() {
        // Known-answer vector shared with python/compile/pqsw.py
        // (_layer_checksum): oc=2, k=2, wq=[[1,-2],[3,4]], bias=[0.5,-1.25].
        // If either side changes its byte stream, this pin catches it.
        let q = QLayerMeta {
            name: "kat".into(),
            oc: 2,
            ic: 2,
            kh: 1,
            kw: 1,
            stride: 1,
            pad: 0,
            prune: false,
            w_scale: 1.0,
            x_scale: 1.0,
            x_offset: 0,
            wq: Weights::Owned(vec![1, -2, 3, 4]),
            k: 2,
            bias: vec![0.5, -1.25],
        };
        assert_eq!(layer_checksum(&q), 0xf5235afad1153101);
    }

    // Full-file parsing is covered by integration tests against real
    // artifacts (rust/tests/artifacts.rs); here we test the error paths.
    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("pqs_test_pqsw");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.pqsw");
        std::fs::write(&p, b"NOTPQSW0rest").unwrap();
        assert!(PqswModel::load(&p).is_err());
    }

    fn write_header_only(path: &std::path::Path, header: &str) {
        let hdr = header.as_bytes();
        let mut raw = Vec::new();
        raw.extend_from_slice(MAGIC);
        raw.extend_from_slice(&(hdr.len() as u32).to_le_bytes());
        raw.extend_from_slice(hdr);
        std::fs::write(path, raw).unwrap();
    }

    #[test]
    fn unknown_section_tag_errors_with_the_format_version() {
        let dir = std::env::temp_dir().join("pqs_test_pqsw_sections");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("future.pqsw");
        write_header_only(
            &p,
            r#"{"name":"f","graph":[],"blobs":[],
                "format_version":7,"sections":[{"tag":"wibble"}]}"#,
        );
        let err = format!("{:#}", PqswModel::load(&p).unwrap_err());
        assert!(err.contains("wibble"), "names the unknown tag: {err}");
        assert!(err.contains('7'), "includes the file's format version: {err}");
        assert!(err.contains("plan"), "lists the known tags: {err}");
        // an untagged section is just as diagnosable
        let p2 = dir.join("untagged.pqsw");
        write_header_only(&p2, r#"{"name":"f","graph":[],"blobs":[],"sections":[{}]}"#);
        let err = format!("{:#}", PqswModel::load(&p2).unwrap_err());
        assert!(err.contains("untagged"), "{err}");
        assert!(err.contains('1'), "sections without a version default to 1: {err}");
    }

    #[test]
    fn save_load_roundtrip_preserves_model_and_plan() {
        let dir = std::env::temp_dir().join("pqs_test_pqsw_roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let mut model = crate::models::synthetic_conv(2, 6, 6, 4, 10);
        // plan-free files round-trip as version-1 (no sections key at all)
        let p0 = dir.join("planfree.pqsw");
        model.save(&p0).unwrap();
        let raw = std::fs::read(&p0).unwrap();
        let hlen = u32::from_le_bytes(raw[8..12].try_into().unwrap()) as usize;
        let hdr = std::str::from_utf8(&raw[12..12 + hlen]).unwrap();
        assert!(!hdr.contains("sections"), "plan-free writes stay version 1");
        let back = PqswModel::load(&p0).unwrap();
        assert_eq!(back.plan, None);
        assert_eq!(back.name, model.name);
        assert_eq!(back.input_shape, model.input_shape);
        assert_eq!(back.graph.len(), model.graph.len());
        for (a, b) in back.graph.iter().zip(model.graph.iter()) {
            assert_eq!(a.op, b.op);
            assert_eq!(a.inputs, b.inputs);
            match (&a.q, &b.q) {
                (Some(qa), Some(qb)) => {
                    assert_eq!(qa.wq, qb.wq);
                    assert_eq!(qa.bias, qb.bias);
                    assert_eq!(qa.name, qb.name);
                    assert_eq!((qa.oc, qa.ic, qa.kh, qa.kw), (qb.oc, qb.ic, qb.kh, qb.kw));
                    assert_eq!((qa.stride, qa.pad, qa.k), (qb.stride, qb.pad, qb.k));
                    assert_eq!(qa.w_scale, qb.w_scale);
                    assert_eq!(qa.x_scale, qb.x_scale);
                    assert_eq!(qa.x_offset, qb.x_offset);
                }
                (None, None) => {}
                other => panic!("q mismatch: {other:?}"),
            }
        }
        // a planned model round-trips its section
        let plan =
            crate::plan::plan_model(&model, &crate::plan::PlannerConfig::default()).unwrap();
        model.plan = Some(plan.clone());
        let p1 = dir.join("planned.pqsw");
        model.save(&p1).unwrap();
        let back = PqswModel::load(&p1).unwrap();
        assert_eq!(back.plan.as_ref(), Some(&plan));
    }

    #[test]
    fn lazy_load_borrows_eager_load_owns_both_identical() {
        let dir = std::env::temp_dir().join("pqs_test_pqsw_lazy");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("lazy.pqsw");
        let model = crate::models::synthetic_conv(2, 6, 6, 4, 10);
        model.save(&p).unwrap();

        let lazy = PqswModel::load(&p).unwrap();
        let eager = PqswModel::load_eager(&p).unwrap();
        let blob = lazy.backing_blob().expect("lazy load keeps a shared blob");
        assert!(eager.backing_blob().is_none(), "eager load owns everything");
        for ((_, ql), (_, qe)) in lazy.q_layers().zip(eager.q_layers()) {
            assert!(ql.wq.is_borrowed());
            assert!(!qe.wq.is_borrowed());
            assert_eq!(ql.wq, qe.wq, "weight views bit-identical");
            assert!(
                Arc::ptr_eq(ql.wq.backing_blob().unwrap(), &blob),
                "one blob backs every layer"
            );
        }
        assert_eq!(lazy.content_hash(), eager.content_hash());
        assert_eq!(lazy.content_hash(), model.content_hash(), "hash is storage-independent");

        // resident accounting: lazy is charged the file once; eager the
        // decoded vectors
        let bias: u64 = model.q_layers().map(|(_, q)| q.bias.len() as u64 * 4).sum();
        let wq: u64 = model.q_layers().map(|(_, q)| q.wq.len() as u64).sum();
        assert_eq!(lazy.resident_bytes(), blob.len() as u64 + bias);
        assert_eq!(eager.resident_bytes(), wq + bias);

        // materialize releases the blob and changes nothing observable
        let mut owned = lazy.clone();
        owned.materialize();
        assert!(owned.backing_blob().is_none());
        assert_eq!(owned.content_hash(), lazy.content_hash());
        assert_eq!(owned.resident_bytes(), eager.resident_bytes());
    }

    #[test]
    fn rehost_dedups_byte_identical_blobs() {
        let dir = std::env::temp_dir().join("pqs_test_pqsw_rehost");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rehost.pqsw");
        let model = crate::models::synthetic_linear(32, 8);
        model.save(&p).unwrap();
        let canonical = PqswModel::load(&p).unwrap();
        let canon_blob = canonical.backing_blob().unwrap();
        let mut dup = PqswModel::load(&p).unwrap();
        let dup_blob = dup.backing_blob().unwrap();
        assert!(!Arc::ptr_eq(&canon_blob, &dup_blob), "separate loads, separate buffers");
        assert!(dup.rehost(&canon_blob), "byte-identical bytes rehost");
        assert!(Arc::ptr_eq(&dup.backing_blob().unwrap(), &canon_blob));
        for ((_, qa), (_, qb)) in dup.q_layers().zip(canonical.q_layers()) {
            assert_eq!(qa.wq, qb.wq);
        }
        // a different file must refuse
        let other = crate::models::synthetic_linear(32, 9);
        let p2 = dir.join("other.pqsw");
        other.save(&p2).unwrap();
        let mut other = PqswModel::load(&p2).unwrap();
        assert!(!other.rehost(&canon_blob), "different bytes must not rehost");
        // owned weights never rehost
        let mut owned = canonical.clone();
        owned.materialize();
        assert!(!owned.rehost(&canon_blob));
    }

    #[test]
    fn planfree_lazy_load_resaves_byte_identical() {
        // v1 files (no plan) round-trip byte-for-byte through a *lazy*
        // load + save: borrowed weight views must serialize exactly like
        // the owned originals
        let dir = std::env::temp_dir().join("pqs_test_pqsw_resave");
        std::fs::create_dir_all(&dir).unwrap();
        let p0 = dir.join("orig.pqsw");
        let p1 = dir.join("resaved.pqsw");
        let model = crate::models::synthetic_conv(2, 6, 6, 4, 10);
        model.save(&p0).unwrap();
        let loaded = PqswModel::load(&p0).unwrap();
        assert!(loaded.q_layers().all(|(_, q)| q.wq.is_borrowed()));
        loaded.save(&p1).unwrap();
        let a = std::fs::read(&p0).unwrap();
        let b = std::fs::read(&p1).unwrap();
        assert_eq!(a, b, "plan-free lazy round-trip is byte-identical");
    }

    #[test]
    fn checksums_round_trip_and_catch_tampering() {
        let dir = std::env::temp_dir().join("pqs_test_pqsw_checksums");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("summed.pqsw");
        let mut model = crate::models::synthetic_conv(2, 6, 6, 4, 10);
        model.attach_checksums();
        model.save(&p).unwrap();

        // both load paths verify and keep the section
        let lazy = PqswModel::load(&p).unwrap();
        let eager = PqswModel::load_eager(&p).unwrap();
        assert_eq!(lazy.checksums, Some(model.layer_checksums()));
        assert_eq!(lazy.checksums, eager.checksums);
        lazy.verify_integrity().unwrap();

        // flip one bit inside the first weight blob: the load must fail
        // with a diagnosable integrity error, not wrong logits
        let raw = std::fs::read(&p).unwrap();
        let hlen = u32::from_le_bytes(raw[8..12].try_into().unwrap()) as usize;
        let blob_base = (12 + hlen + 7) & !7;
        let bp = dir.join("flipped.pqsw");
        let mut bad = raw.clone();
        bad[blob_base] ^= 0x10;
        std::fs::write(&bp, &bad).unwrap();
        let e = PqswModel::load(&bp).unwrap_err();
        assert!(is_integrity_error(&e), "classified as integrity: {e:#}");
        assert!(format!("{e:#}").contains("checksum mismatch"), "{e:#}");
        let e = PqswModel::load_eager(&bp).unwrap_err();
        assert!(is_integrity_error(&e), "eager path verifies too: {e:#}");

        // planned saves get checksums refreshed automatically
        let mut planned = crate::models::synthetic_linear(16, 4);
        planned.plan = Some(
            crate::plan::plan_model(&planned, &crate::plan::PlannerConfig::default()).unwrap(),
        );
        let p2 = dir.join("planned.pqsw");
        planned.save(&p2).unwrap();
        let back = PqswModel::load(&p2).unwrap();
        assert_eq!(back.checksums, Some(planned.layer_checksums()));
    }

    #[test]
    fn verify_integrity_rejects_plan_graph_mismatch() {
        let mut model = crate::models::synthetic_linear(16, 4);
        let mut plan =
            crate::plan::plan_model(&model, &crate::plan::PlannerConfig::default()).unwrap();
        plan.per_layer[0].name = "not_a_layer".into();
        model.plan = Some(plan);
        let e = model.verify_integrity().unwrap_err();
        assert!(is_integrity_error(&e), "{e:#}");
        assert!(format!("{e:#}").contains("not_a_layer"), "{e:#}");
    }
}
