//! `.pqsw` model container reader/writer (format shared with
//! `python/compile/pqsw.py`).
//!
//! Layout: magic `PQSW1\0\0\0`, u32le header length, JSON header, zero pad
//! to 8 bytes, then 8-aligned blobs. The header carries the model graph IR
//! shared with `python/compile/model.py` (see that module's docstring).
//!
//! ### Versioned optional sections (format version 2)
//! The header may carry a `"format_version"` (absent = 1) and a
//! `"sections"` array of tagged objects. Known tags are parsed into the
//! model; an **unknown** tag fails the load with an error naming the tag
//! and the file's format version, so future format evolutions fail
//! diagnosably instead of being silently dropped. Version-1 files (no
//! sections) load exactly as before. The only tag this build understands
//! is `"plan"` — a per-layer accumulator-bitwidth plan
//! ([`crate::plan::AccumPlan`]) that `nn::Engine` applies automatically.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

use crate::plan::AccumPlan;
use crate::util::json::{self, Json};

pub const MAGIC: &[u8; 8] = b"PQSW1\x00\x00\x00";

/// Newest header format this build writes/understands.
pub const FORMAT_VERSION: i64 = 2;

/// Section tags this build can parse.
pub const KNOWN_SECTION_TAGS: &[&str] = &["plan"];

/// Graph operation kinds (mirrors the python IR).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Input,
    Relu,
    Add,
    Gap,
    Flatten,
    QLinear,
    QConv,
    QDwConv,
}

impl Op {
    pub fn from_str(s: &str) -> Result<Op> {
        Ok(match s {
            "input" => Op::Input,
            "relu" => Op::Relu,
            "add" => Op::Add,
            "gap" => Op::Gap,
            "flatten" => Op::Flatten,
            "qlinear" => Op::QLinear,
            "qconv" => Op::QConv,
            "qdwconv" => Op::QDwConv,
            other => bail!("unknown op {other:?}"),
        })
    }

    pub fn is_q_layer(&self) -> bool {
        matches!(self, Op::QLinear | Op::QConv | Op::QDwConv)
    }

    /// The IR string this op serializes as (inverse of [`Op::from_str`]).
    pub fn name(&self) -> &'static str {
        match self {
            Op::Input => "input",
            Op::Relu => "relu",
            Op::Add => "add",
            Op::Gap => "gap",
            Op::Flatten => "flatten",
            Op::QLinear => "qlinear",
            Op::QConv => "qconv",
            Op::QDwConv => "qdwconv",
        }
    }
}

/// Quantized-layer metadata + weights.
#[derive(Clone, Debug)]
pub struct QLayerMeta {
    pub name: String,
    pub oc: usize,
    pub ic: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
    pub prune: bool,
    pub w_scale: f32,
    pub x_scale: f32,
    pub x_offset: i32,
    /// int8 weights, (oc, K) row-major; K = ic*kh*kw (kh*kw for depthwise)
    pub wq: Vec<i8>,
    /// contraction length
    pub k: usize,
    pub bias: Vec<f32>,
}

/// One node of the model graph.
#[derive(Clone, Debug)]
pub struct GraphNode {
    pub id: usize,
    pub op: Op,
    pub inputs: Vec<usize>,
    pub q: Option<QLayerMeta>,
}

/// A parsed `.pqsw` model.
#[derive(Clone, Debug)]
pub struct PqswModel {
    pub name: String,
    pub arch: String,
    pub schedule: String,
    pub wbits: u8,
    pub abits: u8,
    pub nm_m: usize,
    pub target_sparsity: f64,
    pub achieved_sparsity: f64,
    pub acc_bits_trained: Option<u32>,
    pub lowrank_k: Option<usize>,
    pub acc_q: f64,
    pub acc_fp32: f64,
    pub input_shape: Vec<usize>,
    pub graph: Vec<GraphNode>,
    /// Embedded per-layer accumulator-bitwidth plan (format-version-2
    /// `"plan"` section; `None` for plan-free files). `nn::Engine` applies
    /// it automatically on construction.
    pub plan: Option<AccumPlan>,
}

struct Blob {
    offset: usize,
    len: usize,
    dtype: String,
}

impl PqswModel {
    pub fn load<P: AsRef<Path>>(path: P) -> Result<PqswModel> {
        let raw = std::fs::read(path.as_ref())
            .with_context(|| format!("reading model {:?}", path.as_ref()))?;
        if raw.len() < 12 || &raw[0..8] != MAGIC {
            bail!("bad PQSW magic in {:?}", path.as_ref());
        }
        let hlen = u32::from_le_bytes(raw[8..12].try_into().unwrap()) as usize;
        let hdr_txt = std::str::from_utf8(&raw[12..12 + hlen]).context("header utf8")?;
        let h = Json::parse(hdr_txt).context("header json")?;
        let blob_base = (12 + hlen + 7) & !7;

        let blobs: Vec<Blob> = h
            .get("blobs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing blobs"))?
            .iter()
            .map(|b| {
                Ok(Blob {
                    offset: b.get("offset").and_then(Json::as_usize).ok_or_else(|| anyhow!("blob offset"))?,
                    len: b.get("len").and_then(Json::as_usize).ok_or_else(|| anyhow!("blob len"))?,
                    dtype: b.get("dtype").and_then(Json::as_str).unwrap_or("").to_string(),
                })
            })
            .collect::<Result<_>>()?;

        let blob_bytes = |i: usize| -> Result<&[u8]> {
            let b = blobs.get(i).ok_or_else(|| anyhow!("blob index {i}"))?;
            let a = blob_base + b.offset;
            raw.get(a..a + b.len).ok_or_else(|| anyhow!("blob {i} out of bounds"))
        };

        let mut graph = Vec::new();
        for n in h.get("graph").and_then(Json::as_arr).ok_or_else(|| anyhow!("missing graph"))? {
            let op = Op::from_str(n.get("op").and_then(Json::as_str).unwrap_or(""))?;
            let id = n.get("id").and_then(Json::as_usize).ok_or_else(|| anyhow!("node id"))?;
            let inputs = n
                .get("inputs")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default();
            let q = if op.is_q_layer() {
                let geti = |k: &str, d: usize| n.get(k).and_then(Json::as_usize).unwrap_or(d);
                let oc = geti("oc", 0);
                let ic = geti("ic", 0);
                let kh = geti("kh", 1);
                let kw = geti("kw", 1);
                let wq_raw = blob_bytes(geti("wq_blob", usize::MAX))?;
                let bias_raw = blob_bytes(geti("bias_blob", usize::MAX))?;
                if blobs[geti("wq_blob", 0)].dtype != "i8" {
                    bail!("weight blob dtype");
                }
                let wq: Vec<i8> = wq_raw.iter().map(|&b| b as i8).collect();
                let bias: Vec<f32> = bias_raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let k = if op == Op::QDwConv { kh * kw } else { ic * kh * kw };
                if wq.len() != oc * k {
                    bail!("weight blob size {} != oc*k {}", wq.len(), oc * k);
                }
                if bias.len() != oc {
                    bail!("bias blob size {} != oc {}", bias.len(), oc);
                }
                Some(QLayerMeta {
                    name: n.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                    oc,
                    ic,
                    kh,
                    kw,
                    stride: geti("stride", 1),
                    pad: geti("pad", 0),
                    prune: n.get("prune").and_then(Json::as_bool).unwrap_or(false),
                    w_scale: n.get("w_scale").and_then(Json::as_f64).unwrap_or(1.0) as f32,
                    x_scale: n.get("x_scale").and_then(Json::as_f64).unwrap_or(1.0) as f32,
                    x_offset: n.get("x_offset").and_then(Json::as_i64).unwrap_or(0) as i32,
                    wq,
                    k,
                    bias,
                })
            } else {
                None
            };
            graph.push(GraphNode { id, op, inputs, q });
        }

        // versioned optional sections (format version 2+). Unknown tags
        // fail the load *naming the tag and the file's format version*:
        // a future format evolution must surface as a diagnosable error,
        // never as silently dropped data.
        let format_version = h.get("format_version").and_then(Json::as_i64).unwrap_or(1);
        let mut plan = None;
        if let Some(sections) = h.get("sections").and_then(Json::as_arr) {
            for sec in sections {
                match sec.get("tag").and_then(Json::as_str) {
                    Some("plan") => {
                        plan = Some(AccumPlan::from_json(sec).with_context(|| {
                            format!(
                                "parsing the plan section of {:?} (format version \
                                 {format_version})",
                                path.as_ref()
                            )
                        })?);
                    }
                    Some(other) => bail!(
                        "unknown .pqsw section tag {other:?} in {:?} (file format version \
                         {format_version}; this build understands: {})",
                        path.as_ref(),
                        KNOWN_SECTION_TAGS.join(", "),
                    ),
                    None => bail!(
                        "untagged .pqsw section in {:?} (file format version {format_version})",
                        path.as_ref()
                    ),
                }
            }
        }

        let gets = |k: &str| h.get(k).and_then(Json::as_str).unwrap_or("").to_string();
        Ok(PqswModel {
            name: gets("name"),
            arch: gets("arch"),
            schedule: gets("schedule"),
            wbits: h.get("wbits").and_then(Json::as_i64).unwrap_or(8) as u8,
            abits: h.get("abits").and_then(Json::as_i64).unwrap_or(8) as u8,
            nm_m: h.get("nm_m").and_then(Json::as_usize).unwrap_or(0),
            target_sparsity: h.get("target_sparsity").and_then(Json::as_f64).unwrap_or(0.0),
            achieved_sparsity: h.get("achieved_sparsity").and_then(Json::as_f64).unwrap_or(0.0),
            acc_bits_trained: h
                .get("acc_bits_trained")
                .and_then(Json::as_i64)
                .map(|v| v as u32),
            lowrank_k: h.get("lowrank_k").and_then(Json::as_usize),
            acc_q: h.get("acc_q").and_then(Json::as_f64).unwrap_or(0.0),
            acc_fp32: h.get("acc_fp32").and_then(Json::as_f64).unwrap_or(0.0),
            input_shape: h
                .get("input_shape")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            graph,
            plan,
        })
    }

    /// Write the model as a `.pqsw` file the loader (and the python
    /// toolchain) accepts: same magic/header/blob layout as
    /// `python/compile/pqsw.py`, plus — when a plan is embedded — the
    /// format-version-2 `"sections"` array. Plan-free models are written
    /// as plain version-1 files, indistinguishable from python exports.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let align8 = |n: usize| (n + 7) & !7;
        // (dtype, raw bytes) per blob, indexed by the graph nodes
        let mut blobs: Vec<(&'static str, Vec<u8>)> = Vec::new();
        let mut graph_rows: Vec<Json> = Vec::new();
        for n in &self.graph {
            let mut row: BTreeMap<String, Json> = BTreeMap::new();
            row.insert("id".into(), json::num(n.id as f64));
            row.insert("op".into(), json::s(n.op.name()));
            row.insert(
                "inputs".into(),
                Json::Arr(n.inputs.iter().map(|&i| json::num(i as f64)).collect()),
            );
            if let Some(q) = &n.q {
                row.insert("name".into(), json::s(&q.name));
                row.insert("oc".into(), json::num(q.oc as f64));
                row.insert("ic".into(), json::num(q.ic as f64));
                row.insert("kh".into(), json::num(q.kh as f64));
                row.insert("kw".into(), json::num(q.kw as f64));
                row.insert("stride".into(), json::num(q.stride as f64));
                row.insert("pad".into(), json::num(q.pad as f64));
                row.insert("prune".into(), Json::Bool(q.prune));
                row.insert("w_scale".into(), json::num(q.w_scale as f64));
                row.insert("x_scale".into(), json::num(q.x_scale as f64));
                row.insert("x_offset".into(), json::num(q.x_offset as f64));
                row.insert("wq_blob".into(), json::num(blobs.len() as f64));
                blobs.push(("i8", q.wq.iter().map(|&v| v as u8).collect()));
                row.insert("bias_blob".into(), json::num(blobs.len() as f64));
                blobs.push((
                    "f32",
                    q.bias.iter().flat_map(|v| v.to_le_bytes()).collect(),
                ));
            }
            graph_rows.push(Json::Obj(row));
        }
        // blob offsets are relative to the 8-aligned blob-section start
        let mut blobs_meta: Vec<Json> = Vec::new();
        let mut off = 0usize;
        for (dtype, raw) in &blobs {
            blobs_meta.push(json::obj(vec![
                ("offset", json::num(off as f64)),
                ("len", json::num(raw.len() as f64)),
                ("dtype", json::s(dtype)),
            ]));
            off = align8(off + raw.len());
        }
        let opt_num = |v: Option<f64>| match v {
            Some(x) => json::num(x),
            None => Json::Null,
        };
        let mut header: BTreeMap<String, Json> = BTreeMap::new();
        header.insert("name".into(), json::s(&self.name));
        header.insert("arch".into(), json::s(&self.arch));
        header.insert("schedule".into(), json::s(&self.schedule));
        header.insert("wbits".into(), json::num(self.wbits as f64));
        header.insert("abits".into(), json::num(self.abits as f64));
        header.insert("nm_m".into(), json::num(self.nm_m as f64));
        header.insert("target_sparsity".into(), json::num(self.target_sparsity));
        header.insert("achieved_sparsity".into(), json::num(self.achieved_sparsity));
        header.insert(
            "acc_bits_trained".into(),
            opt_num(self.acc_bits_trained.map(|v| v as f64)),
        );
        header.insert("lowrank_k".into(), opt_num(self.lowrank_k.map(|v| v as f64)));
        header.insert("acc_q".into(), json::num(self.acc_q));
        header.insert("acc_fp32".into(), json::num(self.acc_fp32));
        header.insert(
            "input_shape".into(),
            Json::Arr(self.input_shape.iter().map(|&d| json::num(d as f64)).collect()),
        );
        header.insert("graph".into(), Json::Arr(graph_rows));
        header.insert("blobs".into(), Json::Arr(blobs_meta));
        if let Some(plan) = &self.plan {
            header.insert("format_version".into(), json::num(FORMAT_VERSION as f64));
            header.insert("sections".into(), Json::Arr(vec![plan.to_json()]));
        }
        let hdr = Json::Obj(header).to_string().into_bytes();

        let mut out: Vec<u8> = Vec::with_capacity(12 + hdr.len() + off + 16);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(hdr.len() as u32).to_le_bytes());
        out.extend_from_slice(&hdr);
        out.resize(align8(out.len()), 0); // pad header to the blob base
        for (_, raw) in &blobs {
            out.extend_from_slice(raw);
            out.resize(align8(out.len()), 0); // keep every blob 8-aligned
        }
        std::fs::write(path.as_ref(), &out)
            .with_context(|| format!("writing model {:?}", path.as_ref()))
    }

    /// All quantized layers in graph order.
    pub fn q_layers(&self) -> impl Iterator<Item = (&GraphNode, &QLayerMeta)> {
        self.graph.iter().filter_map(|n| n.q.as_ref().map(|q| (n, q)))
    }

    /// Total / nonzero weight counts over prunable layers.
    pub fn weight_sparsity(&self) -> f64 {
        let (mut z, mut t) = (0usize, 0usize);
        for (_, q) in self.q_layers() {
            if !q.prune {
                continue;
            }
            t += q.wq.len();
            z += q.wq.iter().filter(|&&v| v == 0).count();
        }
        if t == 0 {
            0.0
        } else {
            z as f64 / t as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_parsing() {
        assert_eq!(Op::from_str("qconv").unwrap(), Op::QConv);
        assert!(Op::from_str("conv3d").is_err());
        assert!(Op::QLinear.is_q_layer());
        assert!(!Op::Relu.is_q_layer());
    }

    // Full-file parsing is covered by integration tests against real
    // artifacts (rust/tests/artifacts.rs); here we test the error paths.
    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("pqs_test_pqsw");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.pqsw");
        std::fs::write(&p, b"NOTPQSW0rest").unwrap();
        assert!(PqswModel::load(&p).is_err());
    }

    fn write_header_only(path: &std::path::Path, header: &str) {
        let hdr = header.as_bytes();
        let mut raw = Vec::new();
        raw.extend_from_slice(MAGIC);
        raw.extend_from_slice(&(hdr.len() as u32).to_le_bytes());
        raw.extend_from_slice(hdr);
        std::fs::write(path, raw).unwrap();
    }

    #[test]
    fn unknown_section_tag_errors_with_the_format_version() {
        let dir = std::env::temp_dir().join("pqs_test_pqsw_sections");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("future.pqsw");
        write_header_only(
            &p,
            r#"{"name":"f","graph":[],"blobs":[],
                "format_version":7,"sections":[{"tag":"wibble"}]}"#,
        );
        let err = format!("{:#}", PqswModel::load(&p).unwrap_err());
        assert!(err.contains("wibble"), "names the unknown tag: {err}");
        assert!(err.contains('7'), "includes the file's format version: {err}");
        assert!(err.contains("plan"), "lists the known tags: {err}");
        // an untagged section is just as diagnosable
        let p2 = dir.join("untagged.pqsw");
        write_header_only(&p2, r#"{"name":"f","graph":[],"blobs":[],"sections":[{}]}"#);
        let err = format!("{:#}", PqswModel::load(&p2).unwrap_err());
        assert!(err.contains("untagged"), "{err}");
        assert!(err.contains('1'), "sections without a version default to 1: {err}");
    }

    #[test]
    fn save_load_roundtrip_preserves_model_and_plan() {
        let dir = std::env::temp_dir().join("pqs_test_pqsw_roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let mut model = crate::models::synthetic_conv(2, 6, 6, 4, 10);
        // plan-free files round-trip as version-1 (no sections key at all)
        let p0 = dir.join("planfree.pqsw");
        model.save(&p0).unwrap();
        let raw = std::fs::read(&p0).unwrap();
        let hlen = u32::from_le_bytes(raw[8..12].try_into().unwrap()) as usize;
        let hdr = std::str::from_utf8(&raw[12..12 + hlen]).unwrap();
        assert!(!hdr.contains("sections"), "plan-free writes stay version 1");
        let back = PqswModel::load(&p0).unwrap();
        assert_eq!(back.plan, None);
        assert_eq!(back.name, model.name);
        assert_eq!(back.input_shape, model.input_shape);
        assert_eq!(back.graph.len(), model.graph.len());
        for (a, b) in back.graph.iter().zip(model.graph.iter()) {
            assert_eq!(a.op, b.op);
            assert_eq!(a.inputs, b.inputs);
            match (&a.q, &b.q) {
                (Some(qa), Some(qb)) => {
                    assert_eq!(qa.wq, qb.wq);
                    assert_eq!(qa.bias, qb.bias);
                    assert_eq!(qa.name, qb.name);
                    assert_eq!((qa.oc, qa.ic, qa.kh, qa.kw), (qb.oc, qb.ic, qb.kh, qb.kw));
                    assert_eq!((qa.stride, qa.pad, qa.k), (qb.stride, qb.pad, qb.k));
                    assert_eq!(qa.w_scale, qb.w_scale);
                    assert_eq!(qa.x_scale, qb.x_scale);
                    assert_eq!(qa.x_offset, qb.x_offset);
                }
                (None, None) => {}
                other => panic!("q mismatch: {other:?}"),
            }
        }
        // a planned model round-trips its section
        let plan =
            crate::plan::plan_model(&model, &crate::plan::PlannerConfig::default()).unwrap();
        model.plan = Some(plan.clone());
        let p1 = dir.join("planned.pqsw");
        model.save(&p1).unwrap();
        let back = PqswModel::load(&p1).unwrap();
        assert_eq!(back.plan.as_ref(), Some(&plan));
    }
}
