//! `artifacts/manifest.json` reader: the experiment index written by
//! `python/compile/aot.py` that maps each paper figure to its trained
//! models (DESIGN.md §3).

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::plan::{PlanSummary, PlannerKind};
use crate::util::json::Json;

/// Summary of one trained model.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub file: String,
    pub arch: String,
    pub schedule: String,
    pub wbits: u8,
    pub abits: u8,
    pub target_sparsity: f64,
    pub achieved_sparsity: f64,
    pub acc_bits_trained: Option<u32>,
    pub lowrank_k: Option<usize>,
    pub acc_q: f64,
    pub acc_fp32: f64,
    /// Accumulator-bitwidth plan summary of the exported `.pqsw`, when
    /// the manifest carries one (optional `"plan"` object per model:
    /// `{"planner", "layers", "min_bits", "max_bits", "mean_bits"}`).
    /// Lets `pqs list` and the registry surface planned widths without
    /// opening every model file.
    pub plan: Option<PlanSummary>,
}

/// Parse the optional per-model `"plan"` summary object. Malformed or
/// absent objects yield `None` (the manifest stays loadable).
fn parse_plan_summary(j: Option<&Json>) -> Option<PlanSummary> {
    let j = j?;
    let planner = PlannerKind::from_name(j.get("planner").and_then(Json::as_str)?)?;
    Some(PlanSummary {
        layers: j.get("layers").and_then(Json::as_usize)?,
        min_bits: j.get("min_bits").and_then(Json::as_usize)? as u32,
        max_bits: j.get("max_bits").and_then(Json::as_usize)? as u32,
        mean_bits: j.get("mean_bits").and_then(Json::as_f64)?,
        planner,
    })
}

/// Dataset pointers.
#[derive(Clone, Debug)]
pub struct DatasetEntry {
    pub train: String,
    pub test: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub quick: bool,
    pub experiments: BTreeMap<String, Vec<String>>,
    pub models: BTreeMap<String, ModelEntry>,
    pub datasets: BTreeMap<String, DatasetEntry>,
}

impl Manifest {
    pub fn load_dir<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let txt = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?}"))?;
        let j = Json::parse(&txt).context("manifest json")?;

        let mut experiments = BTreeMap::new();
        if let Some(Json::Obj(exps)) = j.get("experiments") {
            for (k, v) in exps {
                let names = v
                    .as_arr()
                    .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
                    .unwrap_or_default();
                experiments.insert(k.clone(), names);
            }
        }

        let mut models = BTreeMap::new();
        for m in j.get("models").and_then(Json::as_arr).ok_or_else(|| anyhow!("models"))? {
            let gets = |k: &str| m.get(k).and_then(Json::as_str).unwrap_or("").to_string();
            let e = ModelEntry {
                name: gets("name"),
                file: gets("file"),
                arch: gets("arch"),
                schedule: gets("schedule"),
                wbits: m.get("wbits").and_then(Json::as_i64).unwrap_or(8) as u8,
                abits: m.get("abits").and_then(Json::as_i64).unwrap_or(8) as u8,
                target_sparsity: m.get("target_sparsity").and_then(Json::as_f64).unwrap_or(0.0),
                achieved_sparsity: m.get("achieved_sparsity").and_then(Json::as_f64).unwrap_or(0.0),
                acc_bits_trained: m.get("acc_bits_trained").and_then(Json::as_i64).map(|v| v as u32),
                lowrank_k: m.get("lowrank_k").and_then(Json::as_usize),
                acc_q: m.get("acc_q").and_then(Json::as_f64).unwrap_or(0.0),
                acc_fp32: m.get("acc_fp32").and_then(Json::as_f64).unwrap_or(0.0),
                plan: parse_plan_summary(m.get("plan")),
            };
            models.insert(e.name.clone(), e);
        }

        let mut datasets = BTreeMap::new();
        if let Some(Json::Obj(ds)) = j.get("datasets") {
            for (k, v) in ds {
                datasets.insert(
                    k.clone(),
                    DatasetEntry {
                        train: v.get("train").and_then(Json::as_str).unwrap_or("").to_string(),
                        test: v.get("test").and_then(Json::as_str).unwrap_or("").to_string(),
                        shape: v
                            .get("shape")
                            .and_then(Json::as_arr)
                            .map(|a| a.iter().filter_map(Json::as_usize).collect())
                            .unwrap_or_default(),
                    },
                );
            }
        }

        Ok(Manifest {
            dir,
            quick: j.get("quick").and_then(Json::as_bool).unwrap_or(false),
            experiments,
            models,
            datasets,
        })
    }

    /// Load from the default artifacts location.
    pub fn load_default() -> Result<Manifest> {
        Self::load_dir(crate::artifacts_dir())
    }

    pub fn model_path(&self, name: &str) -> PathBuf {
        self.dir.join("models").join(format!("{name}.pqsw"))
    }

    /// Every model name in the manifest (sorted; `BTreeMap` order). Used
    /// by error messages and the multi-model registry.
    pub fn model_names(&self) -> Vec<&str> {
        self.models.keys().map(|k| k.as_str()).collect()
    }

    pub fn dataset_path(&self, file: &str) -> PathBuf {
        self.dir.join("datasets").join(file)
    }

    /// Test dataset for an architecture (mlp* -> mnist, else cifar).
    pub fn test_dataset_for(&self, arch: &str) -> Result<&DatasetEntry> {
        let key = if arch.starts_with("mlp") { "mnist" } else { "cifar" };
        self.datasets.get(key).ok_or_else(|| anyhow!("no dataset {key}"))
    }

    /// Models of one experiment, resolved.
    pub fn experiment_models(&self, exp: &str) -> Vec<&ModelEntry> {
        self.experiments
            .get(exp)
            .map(|names| names.iter().filter_map(|n| self.models.get(n)).collect())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_synthetic_manifest() {
        let dir = std::env::temp_dir().join("pqs_test_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"quick":true,
                "experiments":{"fig2":["m1"]},
                "models":[{"name":"m1","file":"m1.pqsw","arch":"mlp1","schedule":"pq",
                           "wbits":8,"abits":8,"target_sparsity":0.5,
                           "achieved_sparsity":0.49,"acc_bits_trained":null,
                           "lowrank_k":null,"acc_q":0.9,"acc_fp32":0.91}],
                "datasets":{"mnist":{"train":"a.bin","test":"b.bin","shape":[1,28,28]}}}"#,
        )
        .unwrap();
        let m = Manifest::load_dir(&dir).unwrap();
        assert!(m.quick);
        assert_eq!(m.experiments["fig2"], vec!["m1"]);
        let e = &m.models["m1"];
        assert_eq!(e.arch, "mlp1");
        assert_eq!(e.acc_bits_trained, None);
        assert_eq!(e.plan, None, "entries without a plan object parse plan-free");
        assert_eq!(m.test_dataset_for("mlp1").unwrap().test, "b.bin");
        assert_eq!(m.experiment_models("fig2").len(), 1);
        assert!(m.model_path("m1").ends_with("models/m1.pqsw"));
    }

    #[test]
    fn parse_model_entry_plan_summary() {
        let dir = std::env::temp_dir().join("pqs_test_manifest_plan");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"models":[
                 {"name":"p1","file":"p1.pqsw","arch":"mlp1","schedule":"pq",
                  "plan":{"planner":"calibrated","layers":3,"min_bits":11,
                          "max_bits":14,"mean_bits":12.5}},
                 {"name":"p2","file":"p2.pqsw","arch":"mlp1","schedule":"pq",
                  "plan":{"planner":"martian","layers":1,"min_bits":8,
                          "max_bits":8,"mean_bits":8}}]}"#,
        )
        .unwrap();
        let m = Manifest::load_dir(&dir).unwrap();
        let p = m.models["p1"].plan.expect("plan summary parses");
        assert_eq!(p.planner, PlannerKind::Calibrated);
        assert_eq!((p.layers, p.min_bits, p.max_bits), (3, 11, 14));
        assert!((p.mean_bits - 12.5).abs() < 1e-12);
        // an unknown planner degrades to plan-free instead of failing the
        // whole manifest
        assert_eq!(m.models["p2"].plan, None);
    }
}
