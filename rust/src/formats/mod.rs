//! Artifact container readers: `.pqsw` models, the experiment manifest, and
//! the bit-exactness goldens (DESIGN.md S17).

pub mod goldens;
pub mod manifest;
pub mod pqsw;

pub use manifest::Manifest;
pub use pqsw::{GraphNode, Op, PqswModel, QLayerMeta};
