//! Multi-model serving: [`ModelRegistry`] (named model sources) +
//! [`Router`] (one process, many engines, one shared compute pool).
//!
//! PQS models are small by construction — pruned, ≤8-bit weights, short
//! dot products — so the natural production shape is *many* models served
//! from one process: several accumulator-bitwidth/accuracy variants of one
//! task (A2Q, A2Q+, different `acc_bits` budgets) live side by side and
//! requests pick one per call. The registry names the fleet; the router
//! owns it:
//!
//! * **Sources, not models** — a registered [`ModelSource`] is *how to get*
//!   the model (an in-memory [`PqswModel`], a synthetic builder, a manifest
//!   entry, a `.pqsw` path). Nothing is loaded at registration time.
//! * **Lazy load** — the first request naming a model pays its load (timed
//!   into `load_latency`); everyone after routes to the live server. Loads
//!   run *outside* the router lock: a slow disk read for one cold model
//!   never stalls traffic to the loaded fleet, and a per-name in-flight
//!   marker dedups concurrent loads of the same model.
//! * **LRU eviction** — with [`RouterConfig::max_loaded`] set, loading a
//!   model past the cap drains the least-recently-used server first
//!   (graceful: queued requests are answered, not dropped). A model's
//!   metrics survive eviction: the final [`ServeMetrics`] of each
//!   incarnation — full recorders, reservoir + HDR histogram — is
//!   folded into a per-model accumulator, so [`Router::metrics`]
//!   reports lifetime totals whose quantiles stay pooled (≤3% HDR
//!   error) across evict/reload cycles.
//! * **Byte-budgeted memory** — with [`RouterConfig::max_bytes`] set the
//!   router charges every loaded model its measured
//!   [`PqswModel::resident_bytes`] and LRU-evicts until a newcomer fits
//!   (a model too large for even an empty fleet is refused, not
//!   admitted). Identical weight content — matched by
//!   [`PqswModel::content_hash`], verified byte-for-byte — is rehosted
//!   onto one canonical `Arc<[u8]>` blob across entries, so N registry
//!   names over one file cost one buffer; `resident_bytes` / `budget` /
//!   `dedup_hits` are reported in [`RouterMetrics`] and `GET /v1/models`.
//! * **Per-model engine overrides** — [`ModelRegistry::set_overrides`]
//!   attaches a [`ModelOverrides`] (accumulator width, engine threads) to
//!   one name; its server is built with those instead of the fleet-wide
//!   [`RouterConfig::engine`] template (CLI:
//!   `--model name=spec,acc_bits=N,threads=M`).
//! * **Eager preload** — [`RouterConfig::preload`] names models to load
//!   at construction time (hot models skip the first-request latency);
//!   each preload flows through the regular load path and counters.
//! * **Cheap snapshots** — [`Router::metrics`] assembles the fleet view
//!   in two phases: counters + bounded clones under the router lock,
//!   per-server metrics reads and histogram-exact recorder merges
//!   outside it. A `/v1/metrics` scrape never touches a per-server
//!   metrics mutex under the router lock and never blocks (or is
//!   blocked by) an in-flight model load.
//! * **One compute pool** — with `server.engine_threads > 1` the router
//!   builds ONE [`ComputePool`] and injects it into every per-model
//!   [`Server`] (via [`crate::coordinator::ServerBuilder::shared_pool`]),
//!   so N loaded models never oversubscribe the machine.
//! * **Self-healing** — every model load runs behind a per-name
//!   **circuit breaker**: [`BreakerConfig::threshold`] consecutive
//!   `LoadFailed`s trip it Open, and while Open requests fast-fail with
//!   [`RouteError::BreakerOpen`] (HTTP `503` + `Retry-After`) instead of
//!   hammering a broken source. The Open period backs off exponentially
//!   with decorrelated jitter; once it elapses the breaker goes
//!   Half-Open and admits exactly ONE probe load (the regular `loading`
//!   marker serializes same-name requests behind it) — success closes
//!   the breaker, failure re-opens it with a longer backoff. Integrity
//!   failures (checksum mismatch, plan/graph inconsistency — see
//!   [`crate::formats::pqsw::is_integrity_error`]) are different in
//!   kind: time will not heal corrupted bytes, so the model is
//!   **quarantined** ([`RouteError::Quarantined`], HTTP `503` with no
//!   retry hint) until an explicit [`Router::reload`]. Breaker state and
//!   counters ride each fleet row as [`ModelHealth`].
//! * **Fault injection** — [`RouterConfig::faults`] optionally arms a
//!   [`FaultPlan`] whose load seams (injected delay / error / bit-flip
//!   corruption) run inside the router's load path, and which is handed
//!   to every per-model server for forward-panic injection. `None` in
//!   production: each seam is one skipped `if let`.
//! * **Routing** — [`ClassifyRequest`] carries an optional model name;
//!   `None` routes to the default (first registered unless overridden).
//!   Unknown names fail fast with [`RouteError::UnknownModel`] carrying a
//!   message that lists the registered fleet — the HTTP front-end returns
//!   it verbatim as the 404 body.
//!
//! The HTTP front-end (`crate::http`) exposes all of this as
//! `POST /v1/classify {"model": ...}`, `GET /v1/models` and the nested
//! per-model sections of `GET /v1/metrics`.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::faults::{FaultPlan, LoadDecision};
use crate::formats::manifest::Manifest;
use crate::formats::pqsw::{is_integrity_error, PqswModel};
use crate::models;
use crate::nn::engine::EngineConfig;
use crate::plan::PlanSummary;
use crate::util::pool::{ComputePool, PoolStats};
use crate::util::rng::Pcg32;

use super::metrics::{LatencyRecorder, LatencySummary, ServeMetrics, ServeSummary};
use super::server::{PendingResponse, Server, ServerConfig, SubmitError};
use crate::trace::{LayerHeadroom, RequestTrace};

/// Deterministic synthetic architectures buildable without artifacts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SyntheticSpec {
    /// `models::synthetic_linear(dim, classes)`
    Linear { dim: usize, classes: usize },
    /// `models::synthetic_conv(c, h, w, oc, classes)`
    Conv { c: usize, h: usize, w: usize, oc: usize, classes: usize },
}

impl SyntheticSpec {
    fn build(&self) -> PqswModel {
        match *self {
            SyntheticSpec::Linear { dim, classes } => models::synthetic_linear(dim, classes),
            SyntheticSpec::Conv { c, h, w, oc, classes } => {
                models::synthetic_conv(c, h, w, oc, classes)
            }
        }
    }

    fn input_shape(&self) -> Vec<usize> {
        match *self {
            SyntheticSpec::Linear { dim, .. } => vec![1, dim, 1],
            SyntheticSpec::Conv { c, h, w, .. } => vec![c, h, w],
        }
    }
}

/// Build-on-demand model source backed by an arbitrary closure. Mainly a
/// test fixture: the scrape-vs-load isolation tests use it to make a load
/// block on a barrier and prove metrics snapshots never serialize behind
/// it.
pub struct SourceFactory {
    build: Box<dyn Fn() -> Result<PqswModel> + Send + Sync>,
}

impl std::fmt::Debug for SourceFactory {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SourceFactory(<closure>)")
    }
}

/// Where a registered model comes from. Loading is deferred until the
/// router needs the model (first request naming it, a preload at startup,
/// or a reload after eviction); `Memory` sources only pay a clone.
#[derive(Clone, Debug)]
pub enum ModelSource {
    /// An already-built model held in memory.
    Memory(PqswModel),
    /// A synthetic model built on demand (no artifacts needed).
    Synthetic(SyntheticSpec),
    /// A named entry of an artifacts manifest (`<dir>/models/<name>.pqsw`),
    /// read from disk on first use via [`models::load`] — unknown names
    /// produce its manifest-dir + available-entries error.
    Manifest { manifest: Manifest, name: String },
    /// A `.pqsw` file path, read from disk on first use.
    Path(PathBuf),
    /// A closure invoked on every load (see [`SourceFactory`]).
    Factory(Arc<SourceFactory>),
}

impl ModelSource {
    /// A [`ModelSource::Factory`] from a closure.
    pub fn factory<F>(build: F) -> ModelSource
    where
        F: Fn() -> Result<PqswModel> + Send + Sync + 'static,
    {
        ModelSource::Factory(Arc::new(SourceFactory { build: Box::new(build) }))
    }

    /// Materialize the model (disk read for `Manifest`/`Path` sources).
    pub fn load(&self) -> Result<PqswModel> {
        match self {
            ModelSource::Memory(m) => Ok(m.clone()),
            ModelSource::Synthetic(spec) => Ok(spec.build()),
            ModelSource::Manifest { manifest, name } => models::load(manifest, name),
            ModelSource::Path(p) => PqswModel::load(p)
                .with_context(|| format!("loading model file {}", p.display())),
            ModelSource::Factory(f) => (f.build)(),
        }
    }

    /// Input shape when it is knowable without touching disk.
    pub fn input_shape(&self) -> Option<Vec<usize>> {
        match self {
            ModelSource::Memory(m) => Some(m.input_shape.clone()),
            ModelSource::Synthetic(spec) => Some(spec.input_shape()),
            ModelSource::Manifest { .. } | ModelSource::Path(_) | ModelSource::Factory(_) => None,
        }
    }

    /// Embedded accumulator-plan summary when knowable without touching
    /// disk (loaded models report their live plan instead).
    pub fn plan_summary(&self) -> Option<PlanSummary> {
        match self {
            ModelSource::Memory(m) => m.plan.as_ref().map(|p| p.summary()),
            _ => None,
        }
    }

    /// Parse a CLI model spec (`pqs serve-http --model name[=SPEC]`):
    ///
    /// * `linear:<dim>x<classes>` — synthetic linear model;
    /// * `conv:<c>x<h>x<w>x<oc>x<classes>` — synthetic CNN;
    /// * anything containing `/` or ending in `.pqsw` — a model file path;
    /// * anything else — a manifest entry name (requires artifacts).
    pub fn parse(spec: &str, manifest: Option<&Manifest>) -> Result<ModelSource> {
        fn dims(s: &str, n: usize, spec: &str) -> Result<Vec<usize>> {
            let parts: Vec<usize> = s.split('x').map(|p| p.trim().parse().unwrap_or(0)).collect();
            if parts.len() != n || parts.iter().any(|&v| v == 0) {
                return Err(anyhow!(
                    "bad synthetic model spec {spec:?}: want {n} positive dims separated by 'x'"
                ));
            }
            Ok(parts)
        }
        if let Some(rest) = spec.strip_prefix("linear:") {
            let d = dims(rest, 2, spec)?;
            return Ok(ModelSource::Synthetic(SyntheticSpec::Linear { dim: d[0], classes: d[1] }));
        }
        if let Some(rest) = spec.strip_prefix("conv:") {
            let d = dims(rest, 5, spec)?;
            return Ok(ModelSource::Synthetic(SyntheticSpec::Conv {
                c: d[0],
                h: d[1],
                w: d[2],
                oc: d[3],
                classes: d[4],
            }));
        }
        if spec.contains('/') || spec.ends_with(".pqsw") {
            return Ok(ModelSource::Path(PathBuf::from(spec)));
        }
        match manifest {
            Some(man) => Ok(ModelSource::Manifest { manifest: man.clone(), name: spec.into() }),
            None => Err(anyhow!(
                "model spec {spec:?} names a manifest entry but no artifacts manifest is \
                 available (run `make artifacts`, set PQS_ARTIFACTS, or use a \
                 linear:/conv:/path spec)"
            )),
        }
    }
}

/// Per-model engine knobs overriding the fleet-wide
/// [`RouterConfig::engine`] / [`RouterConfig::server`] templates for one
/// registered name (CLI: `--model name=spec,acc_bits=N,threads=M`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ModelOverrides {
    /// Global accumulator width for this model's engines (an embedded
    /// plan still takes per-layer precedence, exactly as with the fleet
    /// template).
    pub acc_bits: Option<u32>,
    /// Intra-layer engine threads for this model. `> 1` gives the model
    /// its OWN compute pool of that size instead of the router-shared
    /// one; `1` forces single-threaded engines.
    pub engine_threads: Option<usize>,
}

impl ModelOverrides {
    pub fn is_default(&self) -> bool {
        *self == ModelOverrides::default()
    }
}

/// Named model sources plus a default. Registration order is preserved
/// (it drives `GET /v1/models` and the default choice).
#[derive(Clone, Debug, Default)]
pub struct ModelRegistry {
    entries: BTreeMap<String, ModelSource>,
    order: Vec<String>,
    default: Option<String>,
    overrides: BTreeMap<String, ModelOverrides>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Register `source` under `name`. The first registered model is the
    /// default unless [`ModelRegistry::set_default`] overrides it.
    /// Re-registering a name replaces its source (order position kept).
    pub fn register(&mut self, name: &str, source: ModelSource) -> &mut ModelRegistry {
        if self.entries.insert(name.to_string(), source).is_none() {
            self.order.push(name.to_string());
        }
        self
    }

    /// Make `name` the default route for requests without a model field.
    pub fn set_default(&mut self, name: &str) -> Result<()> {
        if !self.entries.contains_key(name) {
            return Err(anyhow!(self.unknown_message(name)));
        }
        self.default = Some(name.to_string());
        Ok(())
    }

    /// The default model name (explicit, else first registered).
    pub fn default_name(&self) -> Option<&str> {
        self.default.as_deref().or_else(|| self.order.first().map(|s| s.as_str()))
    }

    /// Registered names in registration order.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.order.iter().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn source(&self, name: &str) -> Option<&ModelSource> {
        self.entries.get(name)
    }

    /// Attach per-model engine overrides to a registered name (replacing
    /// any previous overrides for it).
    pub fn set_overrides(&mut self, name: &str, overrides: ModelOverrides) -> Result<()> {
        if !self.entries.contains_key(name) {
            return Err(anyhow!(self.unknown_message(name)));
        }
        self.overrides.insert(name.to_string(), overrides);
        Ok(())
    }

    /// The overrides for `name` (default = inherit the fleet templates).
    pub fn overrides(&self, name: &str) -> ModelOverrides {
        self.overrides.get(name).copied().unwrap_or_default()
    }

    /// The message an unknown name routes back to the client (the HTTP
    /// front-end serves it verbatim in the 404 body): names the miss and
    /// lists the registered fleet.
    pub fn unknown_message(&self, name: &str) -> String {
        let avail: Vec<&str> = self.names().collect();
        let fleet = if avail.is_empty() {
            "(none)".to_string()
        } else {
            avail.join(", ")
        };
        format!("unknown model {name:?}; registered models: {fleet}")
    }
}

/// Per-model load circuit-breaker tuning (see the module docs'
/// *Self-healing* bullet for the Closed → Open → Half-Open lifecycle).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BreakerConfig {
    /// Consecutive load failures that trip the breaker Open for a model.
    /// `0` disables the breaker: every request retries the load.
    pub threshold: u32,
    /// Floor of the Open backoff window (the first trip waits at least
    /// this long before admitting a probe).
    pub base_backoff: Duration,
    /// Ceiling of the Open backoff window: decorrelated jitter grows the
    /// wait (`uniform[base, 3 * previous]`) but never past this.
    pub max_backoff: Duration,
    /// Seed of the jitter RNG, so a test's backoff schedule replays.
    pub seed: u64,
}

impl Default for BreakerConfig {
    fn default() -> BreakerConfig {
        BreakerConfig {
            threshold: 3,
            base_backoff: Duration::from_millis(100),
            max_backoff: Duration::from_secs(5),
            seed: 0x5EED_0B0F,
        }
    }
}

/// Router tuning knobs.
#[derive(Clone, Debug, Default)]
pub struct RouterConfig {
    /// How many models may be loaded (live `Server` + pinned engines) at
    /// once; loading past the cap evicts the least-recently-used model
    /// first. `0` = unlimited.
    pub max_loaded: usize,
    /// Resident weight-byte budget for the loaded fleet (measured
    /// [`PqswModel::resident_bytes`], deduped blobs counted once);
    /// loading past it LRU-evicts until the newcomer fits, and a model
    /// that cannot fit even alone is refused with `LoadFailed`.
    /// `0` = unlimited. CLI: `serve-http --max-bytes`.
    pub max_bytes: u64,
    /// Engine configuration applied to every model's workers.
    pub engine: EngineConfig,
    /// Per-model server template (worker threads, batching, queue bound,
    /// deadlines). `engine_threads > 1` sizes the ONE compute pool the
    /// router shares across every loaded model's engines.
    pub server: ServerConfig,
    /// Model names to load eagerly at router construction instead of on
    /// first request (hot-model preload; CLI `serve-http --preload`).
    /// Each preload counts in `RouterMetrics::loads` like a lazy load;
    /// an unknown name fails [`Router::new`]. Preloading more names than
    /// `max_loaded` LRU-evicts the earliest ones, like any other load.
    pub preload: Vec<String>,
    /// Per-model load circuit breaker (failure threshold + backoff
    /// bounds). The default trips after 3 consecutive load failures.
    pub breaker: BreakerConfig,
    /// Optional fault-injection plan, threaded through the load path and
    /// every per-model server. `None` (the default) is production: each
    /// injection seam costs one skipped `if let`.
    pub faults: Option<Arc<FaultPlan>>,
}

/// One classification request at the routing surface.
#[derive(Clone, Debug)]
pub struct ClassifyRequest {
    pub id: u64,
    /// Route target; `None` uses the registry default.
    pub model: Option<String>,
    pub image: Vec<f32>,
    /// Per-request deadline (falls back to the server template's
    /// `default_deadline`).
    pub deadline: Option<Duration>,
    /// Per-request accumulator operating point: run this request's batch
    /// at accumulator width `min(acc_bits, analytic bound)` per layer
    /// instead of the embedded plan's widths. Requires the target model
    /// to carry a plan, and `acc_bits` must cover the plan's widest
    /// layer; otherwise the request fails with `BadRequest` (HTTP 400).
    pub acc_bits: Option<u32>,
    /// Per-request trace context (`X-Request-Id`, arrival timestamp,
    /// sampling decision — see [`crate::trace::RequestTrace`]). The HTTP
    /// front-end takes it back out before submitting, so the router and
    /// servers never touch it; `None` everywhere tracing is off.
    pub trace: Option<RequestTrace>,
}

/// Why a request could not be routed.
#[derive(Debug)]
pub enum RouteError {
    /// The name is not registered. Carries the client-facing message
    /// (miss + registered fleet) — HTTP maps this to `404`.
    UnknownModel(String),
    /// The model is registered but its source failed to load (missing
    /// file, bad manifest entry). HTTP maps this to `500`.
    LoadFailed(String),
    /// The model's load circuit breaker is Open: recent loads kept
    /// failing, so requests fast-fail without touching the source until
    /// the backoff elapses. HTTP maps this to `503` with a `Retry-After`
    /// derived from `retry_after` (time remaining until the probe).
    BreakerOpen { model: String, retry_after: Duration },
    /// The model failed an integrity check (checksum mismatch,
    /// plan/graph inconsistency) and is quarantined until an explicit
    /// [`Router::reload`]. HTTP maps this to `503` *without* a
    /// `Retry-After`: waiting will not fix corrupted bytes.
    Quarantined { model: String, reason: String },
    /// The target model's queue rejected the submission (full / shutting
    /// down). HTTP maps this to `503`.
    Rejected(SubmitError),
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::UnknownModel(m) => write!(f, "{m}"),
            RouteError::LoadFailed(m) => write!(f, "model load failed: {m}"),
            RouteError::BreakerOpen { model, retry_after } => write!(
                f,
                "model {model:?} load circuit breaker is open \
                 (recent loads failed); retry in {:.3}s",
                retry_after.as_secs_f64()
            ),
            RouteError::Quarantined { model, reason } => {
                write!(f, "model {model:?} is quarantined: {reason}")
            }
            RouteError::Rejected(SubmitError::Full(_)) => {
                write!(f, "request queue is full; retry later")
            }
            RouteError::Rejected(SubmitError::Closed(_)) => {
                write!(f, "server is shutting down")
            }
        }
    }
}

impl std::error::Error for RouteError {}

/// Circuit-breaker position as reported in snapshots. An Open breaker
/// whose backoff has already elapsed still reports `Open` (with a zero
/// `retry_after_s`) until the next request flips it Half-Open — the
/// transition happens on the request path, not on a timer.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BreakerSnapshot {
    #[default]
    Closed,
    Open,
    HalfOpen,
}

impl BreakerSnapshot {
    pub fn as_str(&self) -> &'static str {
        match self {
            BreakerSnapshot::Closed => "closed",
            BreakerSnapshot::Open => "open",
            BreakerSnapshot::HalfOpen => "half-open",
        }
    }
}

/// One model's self-healing snapshot: breaker position + lifetime
/// counters + quarantine. Rides every fleet row ([`ModelStatus::health`],
/// `GET /v1/models`); the fleet totals are on [`RouterMetrics`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ModelHealth {
    pub breaker: BreakerSnapshot,
    /// Seconds until an Open breaker admits its probe (`0` otherwise).
    pub retry_after_s: f64,
    /// Current failed-load streak (reset by any successful load).
    pub consecutive_failures: u32,
    /// Lifetime failed load attempts for this model.
    pub load_retries: u64,
    /// Lifetime Closed/Half-Open → Open transitions.
    pub breaker_opens: u64,
    /// Requests fast-failed while Open or quarantined.
    pub fast_fails: u64,
    /// The integrity failure that quarantined this model; `Some` until an
    /// explicit [`Router::reload`].
    pub quarantined: Option<String>,
}

/// Internal breaker position for one model (see [`BreakerSnapshot`] for
/// the reported view).
#[derive(Clone, Debug, Default, PartialEq)]
enum BreakerState {
    #[default]
    Closed,
    /// Fast-fail until `until`; `backoff` is this Open period's length
    /// (feeds the next decorrelated-jitter draw).
    Open { until: Instant, backoff: Duration },
    /// Backoff elapsed: exactly one probe load is in (or about to be in)
    /// flight. Its outcome closes or re-opens the breaker.
    HalfOpen,
}

/// Per-model self-healing bookkeeping (lives in `RouterInner::health`,
/// created lazily on a model's first load failure).
#[derive(Clone, Debug, Default)]
struct ModelHealthState {
    state: BreakerState,
    consecutive_failures: u32,
    load_retries: u64,
    opens: u64,
    fast_fails: u64,
    /// last Open period's backoff (decorrelated jitter's `previous`)
    last_backoff: Option<Duration>,
    quarantined: Option<String>,
}

impl ModelHealthState {
    fn snapshot(&self) -> ModelHealth {
        let (breaker, retry) = match self.state {
            BreakerState::Closed => (BreakerSnapshot::Closed, Duration::ZERO),
            BreakerState::HalfOpen => (BreakerSnapshot::HalfOpen, Duration::ZERO),
            BreakerState::Open { until, .. } => {
                (BreakerSnapshot::Open, until.saturating_duration_since(Instant::now()))
            }
        };
        ModelHealth {
            breaker,
            retry_after_s: retry.as_secs_f64(),
            consecutive_failures: self.consecutive_failures,
            load_retries: self.load_retries,
            breaker_opens: self.opens,
            fast_fails: self.fast_fails,
            quarantined: self.quarantined.clone(),
        }
    }
}

/// One decorrelated-jitter backoff draw: `uniform[base, 3 * previous]`
/// clamped to `[base, max]` (the AWS "decorrelated jitter" schedule —
/// grows exponentially in expectation, desynchronizes retry storms).
fn next_backoff(cfg: &BreakerConfig, prev: Option<Duration>, rng: &mut Pcg32) -> Duration {
    let base = cfg.base_backoff.as_secs_f64().max(1e-9);
    let hi = (prev.unwrap_or(cfg.base_backoff).as_secs_f64() * 3.0).max(base);
    let drawn = base + rng.f64() * (hi - base);
    Duration::from_secs_f64(drawn.min(cfg.max_backoff.as_secs_f64()).max(base))
}

/// One model's row in [`RouterMetrics`] and `GET /v1/models`.
#[derive(Clone, Debug)]
pub struct ModelStatus {
    pub name: String,
    /// Whether this is the default route.
    pub default: bool,
    /// Whether a live `Server` currently holds the model.
    pub loaded: bool,
    /// Input shape when known (always known once loaded; known without
    /// loading for in-memory and synthetic sources).
    pub input_shape: Option<Vec<usize>>,
    /// The model's embedded accumulator-bitwidth plan summary, when known
    /// (always known once loaded; known without loading for in-memory
    /// sources). `None` = no plan: the global `acc_bits` applies.
    pub plan: Option<PlanSummary>,
    /// Measured resident weight bytes of the live incarnation (owned
    /// weights + its shared file blob), `None` while unloaded.
    pub resident_bytes: Option<u64>,
    /// Lifetime serving metrics: the live incarnation merged with every
    /// evicted one. A quantile *summary* — snapshots never carry
    /// reservoirs (see [`ServeSummary`]).
    pub metrics: ServeSummary,
    /// Self-healing state: breaker position, failure counters,
    /// quarantine reason.
    pub health: ModelHealth,
    /// Live accumulator-headroom telemetry of the loaded incarnation:
    /// per-layer planned width vs max observed required width, min
    /// headroom bits, overflow/near-saturation dot counts (see
    /// [`crate::trace::ModelHeadroom`]). `Some` while loaded (empty
    /// until a batch has run), `None` while unloaded — headroom counters
    /// describe a live engine, not history.
    pub headroom: Option<Vec<LayerHeadroom>>,
}

/// Router-level counters + the per-model fleet snapshot.
#[derive(Clone, Debug, Default)]
pub struct RouterMetrics {
    /// Requests routed to a loaded model server (known names only).
    pub routed: u64,
    /// Requests naming an unregistered model (answered 404, never queued).
    pub unknown_model: u64,
    /// Lazy + preload loads performed (first requests, preloads,
    /// post-eviction reloads).
    pub loads: u64,
    /// Models drained out under the `max_loaded` / `max_bytes` caps.
    pub evictions: u64,
    /// Resident weight bytes currently charged to the loaded fleet
    /// (deduped: each shared blob counted once).
    pub resident_bytes: u64,
    /// The configured `max_bytes` budget (`0` = unlimited).
    pub budget: u64,
    /// Loads that found byte-identical weights already resident and
    /// rehosted onto the canonical blob instead of keeping their own.
    pub dedup_hits: u64,
    /// Failed load attempts across the fleet (lifetime; integrity
    /// failures included).
    pub load_retries: u64,
    /// Circuit-breaker trips to Open across the fleet (lifetime).
    pub breaker_opens: u64,
    /// Requests fast-failed by an Open breaker or a quarantine.
    pub breaker_fast_fails: u64,
    /// Models currently quarantined by an integrity failure.
    pub quarantined: u64,
    /// Wall time of each load (source read + server spawn), µs.
    pub load_latency: LatencySummary,
    pub wall_s: f64,
    /// Per-model rows in registration order.
    pub models: Vec<ModelStatus>,
    /// Fleet-wide totals pooled at snapshot time from every
    /// incarnation's FULL latency recorders (live, draining and evicted
    /// alike merged histogram-exactly before summarizing), so its
    /// p50/p99/p999 are pooled quantiles within HDR bucket error (≤3%)
    /// — not count-weighted averages of per-model quantiles.
    /// [`RouterMetrics::aggregate`] serves this with the router's wall
    /// clock and pool stats attached.
    pub fleet: ServeSummary,
    /// The shared compute pool's counters (`None` when engines run
    /// single-threaded).
    pub pool: Option<PoolStats>,
}

impl RouterMetrics {
    /// Row for one model, if registered.
    pub fn model(&self, name: &str) -> Option<&ModelStatus> {
        self.models.iter().find(|m| m.name == name)
    }

    /// Fleet-wide totals: every incarnation's metrics pooled into one
    /// [`ServeSummary`] (counters sum; `wall_s` is the router's wall
    /// clock, so `throughput_rps` is fleet throughput). Counters, means
    /// and maxima are exact, and — because the snapshot merged FULL
    /// latency recorders (histogram-exact) before summarizing — the
    /// aggregate p50/p99/p999 are pooled quantiles within HDR bucket
    /// error (≤3%), even across evict/reload cycles and heterogeneous
    /// fleets.
    pub fn aggregate(&self) -> ServeSummary {
        let mut out = self.fleet;
        out.wall_s = self.wall_s;
        out.throughput_rps = out.requests as f64 / out.wall_s.max(1e-9);
        out.pool = self.pool;
        out
    }

    pub fn print(&self) {
        println!(
            "router: routed={} unknown_model={} loads={} evictions={} \
             resident={}B budget={} dedup_hits={} load mean={:.1}us max={:.1}us",
            self.routed,
            self.unknown_model,
            self.loads,
            self.evictions,
            self.resident_bytes,
            if self.budget == 0 { "unlimited".to_string() } else { format!("{}B", self.budget) },
            self.dedup_hits,
            self.load_latency.mean_us,
            self.load_latency.max_us,
        );
        if self.load_retries + self.breaker_opens + self.breaker_fast_fails + self.quarantined > 0 {
            println!(
                "  health: load_retries={} breaker_opens={} fast_fails={} quarantined={}",
                self.load_retries, self.breaker_opens, self.breaker_fast_fails, self.quarantined,
            );
        }
        for m in &self.models {
            let plan = match &m.plan {
                Some(p) => format!(
                    " plan[{} {}..{} bits]",
                    p.planner.name(),
                    p.min_bits,
                    p.max_bits
                ),
                None => String::new(),
            };
            let health = if m.health.quarantined.is_some() {
                " [QUARANTINED]".to_string()
            } else if m.health.breaker != BreakerSnapshot::Closed {
                format!(" [breaker {}]", m.health.breaker.as_str())
            } else {
                String::new()
            };
            println!(
                "model {}{}{}{health}{plan}: requests={} errors={} expired={} \
                 p50={:.1}us p99={:.1}us",
                m.name,
                if m.default { " (default)" } else { "" },
                if m.loaded { " [loaded]" } else { "" },
                m.metrics.requests,
                m.metrics.errors,
                m.metrics.expired,
                m.metrics.latency.p50_us,
                m.metrics.latency.p99_us,
            );
        }
        if let Some(p) = &self.pool {
            println!(
                "  compute pool threads={} busy={} jobs={} inline_jobs={} chunks={}",
                p.threads, p.busy, p.jobs, p.inline_jobs, p.chunks,
            );
        }
    }
}

struct LoadedModel {
    server: Arc<Server>,
    input_shape: Vec<usize>,
    /// the loaded model's embedded plan summary (reported per fleet row)
    plan: Option<PlanSummary>,
    /// monotone use tick; smallest = least recently used
    last_used: u64,
    /// bytes this model is charged beyond its shared blob (owned weight
    /// vectors + biases)
    own_bytes: u64,
    /// measured `resident_bytes()` at load time (own + backing blob),
    /// reported per fleet row
    bytes: u64,
    /// key into `RouterInner::blobs` when the model borrows a shared
    /// file blob
    blob_ptr: Option<usize>,
}

/// One refcounted shared weight blob in the router's dedup map.
struct BlobEntry {
    data: Arc<[u8]>,
    /// content hash of the (sole) model content these bytes back —
    /// dedup lookups match on it, then verify bytes before rehosting
    hash: u64,
    /// loaded models borrowing this blob
    refs: usize,
}

#[derive(Default)]
struct RouterInner {
    /// shared weight blobs keyed by buffer address; each is charged to
    /// `resident` exactly once while any loaded model borrows it
    blobs: BTreeMap<usize, BlobEntry>,
    /// resident weight bytes currently charged to the loaded fleet
    /// (`own_bytes` of every loaded model + each blob once). Eviction
    /// decrements at the *decision*, while the victim drains shortly
    /// after — the counter tracks the budget commitment, not the
    /// instantaneous allocator state.
    resident: u64,
    dedup_hits: u64,
    loaded: BTreeMap<String, LoadedModel>,
    /// names whose lazy load is in flight on some thread — other requests
    /// for the *same* name wait on `load_done`; every other model keeps
    /// routing (the load itself happens outside the router lock)
    loading: BTreeSet<String>,
    /// evicted servers still answering their queued requests; kept
    /// visible here so metrics snapshots never lose a model's traffic
    /// mid-drain (folded into `past` when the drain completes)
    draining: Vec<(String, Arc<Server>)>,
    /// accumulated metrics of evicted incarnations, per model — FULL
    /// recorders (reservoir + HDR histogram), so quantiles merged across
    /// evict/reload cycles stay pooled (≤3% HDR error) instead of
    /// count-weighted averages. Bounded memory per model
    /// (`RESERVOIR_CAP` + fixed histogram), cloned — never locked
    /// against — by snapshots
    past: BTreeMap<String, ServeMetrics>,
    tick: u64,
    routed: u64,
    unknown: u64,
    loads: u64,
    evictions: u64,
    load_latency: LatencyRecorder,
    /// per-model breaker/quarantine state, created on first load failure
    /// (absent = healthy, Closed breaker)
    health: BTreeMap<String, ModelHealthState>,
    /// decorrelated-jitter RNG for breaker backoffs; lazily seeded from
    /// [`BreakerConfig::seed`] so `RouterInner` stays `Default`
    breaker_rng: Option<Pcg32>,
}

/// Multi-model request router. Owns one [`Server`] per *loaded* model (all
/// dispatching into one shared [`ComputePool`]) and routes
/// [`ClassifyRequest`]s by name. See the module docs for the lifecycle
/// (lazy load, LRU eviction, metrics continuity).
pub struct Router {
    registry: ModelRegistry,
    cfg: RouterConfig,
    pool: Option<Arc<ComputePool>>,
    inner: Mutex<RouterInner>,
    /// signalled when an in-flight lazy load finishes (either way)
    load_done: Condvar,
    started: Instant,
}

impl Router {
    /// Build a router over `registry`. Models named in
    /// [`RouterConfig::preload`] are loaded eagerly before this returns
    /// (each counted in `loads`; an unknown preload name is an error);
    /// everything else loads lazily on its first request. Fails on an
    /// empty registry.
    pub fn new(registry: ModelRegistry, cfg: RouterConfig) -> Result<Router> {
        if registry.is_empty() {
            return Err(anyhow!("router needs at least one registered model"));
        }
        let pool = (cfg.server.engine_threads > 1)
            .then(|| Arc::new(ComputePool::new(cfg.server.engine_threads)));
        let preload = cfg.preload.clone();
        let router = Router {
            registry,
            cfg,
            pool,
            inner: Mutex::new(RouterInner::default()),
            load_done: Condvar::new(),
            started: Instant::now(),
        };
        for name in &preload {
            // the regular load path (so dedup/eviction/metrics semantics
            // are identical to a lazy load), without counting a route
            router
                .resolve_counted(Some(name.as_str()), false)
                .map_err(|e| anyhow!("preloading model {name:?}: {e}"))?;
        }
        Ok(router)
    }

    /// Convenience: a single-model router (the pre-multi-model surface).
    pub fn single(
        name: &str,
        model: &PqswModel,
        engine: EngineConfig,
        server: ServerConfig,
    ) -> Router {
        let mut registry = ModelRegistry::new();
        registry.register(name, ModelSource::Memory(model.clone()));
        Router::new(
            registry,
            RouterConfig { engine, server, ..RouterConfig::default() },
        )
        .expect("registry has one model")
    }

    /// The name requests without a model field route to.
    pub fn default_model(&self) -> &str {
        self.registry.default_name().expect("router registry is never empty")
    }

    /// The registry this router serves.
    pub fn registry(&self) -> &ModelRegistry {
        &self.registry
    }

    /// Route and enqueue, blocking while the target queue is full
    /// (backpressure). Loads the model first if needed.
    ///
    /// A `Closed` rejection from the resolved server usually means the
    /// model was LRU-evicted between resolve and submit, not that the
    /// process is shutting down — so the route is retried once (the
    /// second resolve reloads the model); only a second `Closed` is
    /// reported to the caller.
    pub fn submit(&self, req: ClassifyRequest) -> Result<PendingResponse, RouteError> {
        let ClassifyRequest { id, model, mut image, deadline, acc_bits, trace: _ } = req;
        let mut retried = false;
        loop {
            // the retry resolve must not re-count `routed`: one request,
            // one tally, even when an eviction race makes it route twice
            let server = self.resolve_counted(model.as_deref(), !retried)?;
            match server.submit_with(id, image, deadline, acc_bits) {
                Ok(p) => return Ok(p),
                Err(SubmitError::Closed(img)) if !retried => {
                    retried = true;
                    image = img;
                }
                Err(e) => return Err(RouteError::Rejected(e)),
            }
        }
    }

    /// Route and enqueue without blocking; `Rejected(Full)` sheds when the
    /// target queue is at capacity. Loads the model first if needed.
    /// Eviction races retry once, as in [`Router::submit`].
    pub fn try_submit(&self, req: ClassifyRequest) -> Result<PendingResponse, RouteError> {
        let ClassifyRequest { id, model, mut image, deadline, acc_bits, trace: _ } = req;
        let mut retried = false;
        loop {
            let server = self.resolve_counted(model.as_deref(), !retried)?;
            match server.try_submit_with(id, image, deadline, acc_bits) {
                Ok(p) => return Ok(p),
                Err(SubmitError::Closed(img)) if !retried => {
                    retried = true;
                    image = img;
                }
                Err(e) => return Err(RouteError::Rejected(e)),
            }
        }
    }

    /// Resolve `name` (default when `None`) to a live server, lazily
    /// loading and LRU-evicting as needed.
    ///
    /// The load itself runs WITHOUT the router lock: a slow disk read for
    /// one cold model never stalls traffic to loaded models. A per-name
    /// `loading` marker plus the `load_done` condvar dedups concurrent
    /// loads of the same model. The request that triggers an eviction
    /// pays the victim's graceful drain before its own submit — a
    /// deliberate pacing choice so evictions cannot pile up faster than
    /// queues empty.
    ///
    /// `count_routed` controls the `routed` tally: the submit retry after
    /// an eviction race resolves again but must not count the same
    /// request twice.
    fn resolve_counted(
        &self,
        name: Option<&str>,
        count_routed: bool,
    ) -> Result<Arc<Server>, RouteError> {
        let name = match name {
            Some(n) => n,
            None => self.default_model(),
        };
        // fast path: route to a loaded server, or claim the load
        let mut guard = self.inner.lock().unwrap();
        loop {
            let inner = &mut *guard;
            if !self.registry.entries.contains_key(name) {
                inner.unknown += 1;
                return Err(RouteError::UnknownModel(self.registry.unknown_message(name)));
            }
            // self-healing gate: a quarantined model never loads again
            // until an explicit reload; an Open breaker fast-fails until
            // its backoff elapses, then flips Half-Open and this request
            // becomes the single probe (the `loading` marker below
            // serializes everyone else behind it)
            if let Some(h) = inner.health.get_mut(name) {
                if let Some(reason) = &h.quarantined {
                    h.fast_fails += 1;
                    return Err(RouteError::Quarantined {
                        model: name.to_string(),
                        reason: reason.clone(),
                    });
                }
                if let BreakerState::Open { until, .. } = h.state {
                    let now = Instant::now();
                    if now < until {
                        h.fast_fails += 1;
                        return Err(RouteError::BreakerOpen {
                            model: name.to_string(),
                            retry_after: until - now,
                        });
                    }
                    h.state = BreakerState::HalfOpen;
                }
            }
            if let Some(lm) = inner.loaded.get_mut(name) {
                inner.tick += 1;
                lm.last_used = inner.tick;
                if count_routed {
                    inner.routed += 1;
                }
                return Ok(Arc::clone(&lm.server));
            }
            if inner.loading.contains(name) {
                // someone else is loading this very model: wait for their
                // result instead of loading it twice
                guard = self.load_done.wait(guard).unwrap();
                continue;
            }
            inner.loading.insert(name.to_string());
            break;
        }
        drop(guard);

        // Unwind safety: if the load below panics (e.g. a worker thread
        // fails to spawn), the `loading` marker MUST still come out and
        // waiters MUST be woken, or every future request for this name
        // would block forever on `load_done`. The guard does exactly that
        // on drop; the normal paths disarm it and clean up themselves.
        struct LoadGuard<'a> {
            router: &'a Router,
            name: &'a str,
            armed: bool,
        }
        impl Drop for LoadGuard<'_> {
            fn drop(&mut self) {
                if self.armed {
                    let mut inner = self.router.inner.lock().unwrap();
                    inner.loading.remove(self.name);
                    drop(inner);
                    self.router.load_done.notify_all();
                }
            }
        }
        let mut load_guard = LoadGuard { router: self, name, armed: true };

        // the load, unlocked: every other model keeps routing meanwhile
        let t0 = Instant::now();
        let overrides = self.registry.overrides(name);
        let mut engine_cfg = self.cfg.engine;
        if let Some(bits) = overrides.acc_bits {
            engine_cfg.acc_bits = bits;
        }
        let (server_cfg, model_pool) = match overrides.engine_threads {
            // a per-model thread override gives this model its OWN pool
            // (or none) instead of the router-shared one
            Some(t) => (
                ServerConfig { engine_threads: t, ..self.cfg.server },
                (t > 1).then(|| Arc::new(ComputePool::new(t))),
            ),
            None => (self.cfg.server, self.pool.clone()),
        };
        let built = self.faulty_load(name).map(|mut model| {
            let hash = model.content_hash();
            // dedup: when byte-identical weights are already resident,
            // re-point this model's borrowed views at the canonical blob
            // BEFORE the server clones the model into its workers
            let mut deduped = false;
            if model.backing_blob().is_some() {
                let canonical = {
                    let inner = self.inner.lock().unwrap();
                    inner
                        .blobs
                        .values()
                        .find(|e| e.hash == hash)
                        .map(|e| Arc::clone(&e.data))
                };
                if let Some(canonical) = canonical {
                    deduped = model.rehost(&canonical);
                }
            }
            let bytes = model.resident_bytes();
            let blob = model.backing_blob();
            let own_bytes = bytes - blob.as_ref().map_or(0, |b| b.len() as u64);
            let server = Server::builder()
                .engine(engine_cfg)
                .config(server_cfg)
                .maybe_shared_pool(model_pool)
                .maybe_faults(self.cfg.faults.clone())
                .start(&model);
            let plan = model.plan.as_ref().map(|p| p.summary());
            let shape = model.input_shape.clone();
            (Arc::new(server), shape, plan, hash, bytes, own_bytes, blob, deduped)
        });
        let load_us = t0.elapsed().as_secs_f64() * 1e6;

        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        load_guard.armed = false;
        inner.loading.remove(name);
        let (server, input_shape, plan, hash, bytes, own_bytes, blob, deduped) = match built {
            Ok(v) => {
                // a successful load (incl. a Half-Open probe) closes the
                // breaker and clears the failure streak
                if let Some(h) = inner.health.get_mut(name) {
                    h.state = BreakerState::Closed;
                    h.consecutive_failures = 0;
                    h.last_backoff = None;
                }
                v
            }
            Err(e) => {
                let err = self.record_load_failure(inner, name, &e);
                // wake same-name waiters so one of them can retry the
                // load (or observe the breaker/quarantine we just set)
                self.load_done.notify_all();
                return Err(err);
            }
        };
        // bytes the newcomer would add to `resident` right now: its own
        // bytes, plus its blob unless that exact buffer is already charged
        let needed = |inner: &RouterInner| -> u64 {
            own_bytes
                + blob.as_ref().map_or(0, |b| {
                    if inner.blobs.contains_key(&(b.as_ptr() as usize)) {
                        0
                    } else {
                        b.len() as u64
                    }
                })
        };
        // over a cap: move LRU victims into `draining` (still visible to
        // metrics snapshots) until the newcomer fits by count AND bytes
        let mut evicted: Vec<(String, Arc<Server>)> = Vec::new();
        loop {
            let count_over =
                self.cfg.max_loaded > 0 && inner.loaded.len() + 1 > self.cfg.max_loaded;
            let bytes_over =
                self.cfg.max_bytes > 0 && inner.resident + needed(inner) > self.cfg.max_bytes;
            if !count_over && !bytes_over {
                break;
            }
            let victim = inner
                .loaded
                .iter()
                .min_by_key(|(_, lm)| lm.last_used)
                .map(|(n, _)| n.clone());
            match victim {
                Some(v) => match evict_locked(inner, &v) {
                    Some(pair) => evicted.push(pair),
                    None => break,
                },
                None => break,
            }
        }
        if self.cfg.max_bytes > 0 && inner.resident + needed(inner) > self.cfg.max_bytes {
            // even an empty fleet cannot host this model within the
            // budget: refuse it (never admit past `max_bytes`)
            let total = own_bytes + blob.as_ref().map_or(0, |b| b.len() as u64);
            self.load_done.notify_all();
            drop(guard);
            let _ = server.drain();
            self.drain_evicted(evicted);
            return Err(RouteError::LoadFailed(format!(
                "model {name:?} needs {total} resident bytes but --max-bytes is {}",
                self.cfg.max_bytes
            )));
        }
        inner.load_latency.record(load_us);
        inner.loads += 1;
        if deduped {
            inner.dedup_hits += 1;
        }
        if count_routed {
            inner.routed += 1;
        }
        inner.tick += 1;
        let tick = inner.tick;
        // charge the newcomer: own bytes always; the blob once per buffer
        inner.resident += own_bytes;
        let blob_ptr = blob.as_ref().map(|b| b.as_ptr() as usize);
        if let Some(b) = &blob {
            let p = b.as_ptr() as usize;
            match inner.blobs.get_mut(&p) {
                Some(entry) => entry.refs += 1,
                None => {
                    inner.resident += b.len() as u64;
                    inner.blobs.insert(p, BlobEntry { data: Arc::clone(b), hash, refs: 1 });
                }
            }
        }
        inner.loaded.insert(
            name.to_string(),
            LoadedModel {
                server: Arc::clone(&server),
                input_shape,
                plan,
                last_used: tick,
                own_bytes,
                bytes,
                blob_ptr,
            },
        );
        self.load_done.notify_all();
        drop(guard);

        self.drain_evicted(evicted);
        Ok(server)
    }

    /// Drain evicted servers outside the lock (graceful: their queued
    /// requests are answered; racing submits fail with Closed → 503).
    /// Only once the final metrics are folded into `past` does a victim
    /// leave `draining`, so snapshots never under-report a model
    /// mid-drain. The final metrics are taken before re-taking the lock;
    /// `past` keeps the FULL recorders so quantiles survive eviction
    /// histogram-exactly instead of as count-weighted summary averages.
    fn drain_evicted(&self, evicted: Vec<(String, Arc<Server>)>) {
        for (victim, srv) in evicted {
            let final_metrics = srv.drain();
            let mut inner = self.inner.lock().unwrap();
            inner.past.entry(victim).or_default().merge_from(&final_metrics);
            inner.draining.retain(|(_, a)| !Arc::ptr_eq(a, &srv));
        }
    }

    /// Load `name` from its source through the fault plan's load seams
    /// (injected delay / I/O error / bit-flip corruption), then through
    /// the integrity gate: a model whose embedded checksums don't match
    /// its bytes — or whose plan names layers its graph lacks — is never
    /// hosted. File loads already verified themselves in
    /// [`PqswModel::load`]; this re-check covers in-memory, synthetic
    /// and factory sources plus anything the fault plan corrupted after
    /// the read.
    fn faulty_load(&self, name: &str) -> Result<PqswModel> {
        let decision = match &self.cfg.faults {
            Some(f) => f.on_load(),
            None => LoadDecision::default(),
        };
        if let Some(delay) = decision.delay {
            std::thread::sleep(delay);
        }
        if decision.error {
            return Err(anyhow!("injected fault: load of model {name:?} failed"));
        }
        let mut model = self.registry.entries[name].load()?;
        if decision.corrupt {
            if let Some(f) = &self.cfg.faults {
                f.corrupt_model(&mut model);
            }
        }
        model.verify_integrity().with_context(|| format!("hosting model {name:?}"))?;
        Ok(model)
    }

    /// Classify one load failure into the model's health state (under
    /// the router lock) and build the client-facing error. Integrity
    /// failures quarantine the model; anything else advances the
    /// breaker, tripping it Open with a decorrelated-jitter backoff at
    /// [`BreakerConfig::threshold`] consecutive failures (a failed
    /// Half-Open probe is already past the threshold, so it re-opens
    /// with a longer backoff).
    fn record_load_failure(
        &self,
        inner: &mut RouterInner,
        name: &str,
        e: &anyhow::Error,
    ) -> RouteError {
        let cfg = &self.cfg.breaker;
        let rng = inner.breaker_rng.get_or_insert_with(|| Pcg32::new(cfg.seed));
        let health = inner.health.entry(name.to_string()).or_default();
        health.load_retries += 1;
        if is_integrity_error(e) {
            let reason = format!("{e:#}");
            health.quarantined = Some(reason.clone());
            health.state = BreakerState::Closed;
            health.consecutive_failures = 0;
            health.last_backoff = None;
            return RouteError::Quarantined { model: name.to_string(), reason };
        }
        health.consecutive_failures += 1;
        if cfg.threshold > 0 && health.consecutive_failures >= cfg.threshold {
            let backoff = next_backoff(cfg, health.last_backoff, rng);
            health.state = BreakerState::Open { until: Instant::now() + backoff, backoff };
            health.last_backoff = Some(backoff);
            health.opens += 1;
        }
        RouteError::LoadFailed(format!("{e:#}"))
    }

    /// The router's fault-injection plan, when one is armed (`None` in
    /// production). The HTTP accept loops consult it for connection
    /// resets; `pqs bench` reads its counters.
    pub fn faults(&self) -> Option<&Arc<FaultPlan>> {
        self.cfg.faults.as_ref()
    }

    /// Self-healing snapshot for one registered model (`None` means
    /// healthy: no failure has ever been recorded for it).
    pub fn health(&self, name: &str) -> Option<ModelHealth> {
        let inner = self.inner.lock().unwrap();
        inner.health.get(name).map(|h| h.snapshot())
    }

    /// Clear `name`'s quarantine and breaker state, drop any stale
    /// incarnation, and load it afresh from its source. This is the
    /// explicit operator action that ends a quarantine — time alone
    /// never does. Counts as a load (not a route) in the metrics.
    pub fn reload(&self, name: &str) -> Result<(), RouteError> {
        let evicted = {
            let mut guard = self.inner.lock().unwrap();
            let inner = &mut *guard;
            if !self.registry.entries.contains_key(name) {
                inner.unknown += 1;
                return Err(RouteError::UnknownModel(self.registry.unknown_message(name)));
            }
            inner.health.remove(name);
            evict_locked(inner, name).into_iter().collect::<Vec<_>>()
        };
        self.drain_evicted(evicted);
        self.resolve_counted(Some(name), false).map(|_| ())
    }

    /// Whether the default model can take traffic: neither quarantined
    /// nor behind an Open breaker that is still backing off. (Unloaded
    /// but loadable is ready — the first request pays the load.) The
    /// HTTP `GET /readyz` combines this with its own drain state and
    /// queue high-watermark.
    pub fn ready(&self) -> bool {
        let name = self.default_model();
        let inner = self.inner.lock().unwrap();
        match inner.health.get(name) {
            Some(h) => {
                h.quarantined.is_none()
                    && !matches!(h.state, BreakerState::Open { until, .. }
                        if Instant::now() < until)
            }
            None => true,
        }
    }

    /// Queue occupancy `(len, cap)` of the default model's live server;
    /// `None` while it is not loaded. Feeds the readiness probe's
    /// high-watermark check without snapshotting the whole fleet.
    pub fn default_queue_depth(&self) -> Option<(usize, usize)> {
        let name = self.default_model();
        let server = {
            let inner = self.inner.lock().unwrap();
            inner.loaded.get(name).map(|lm| Arc::clone(&lm.server))
        };
        server.map(|s| (s.queue_len(), self.cfg.server.queue_cap))
    }

    /// Snapshot of router counters + the per-model fleet.
    ///
    /// Two phases, so a scrape never blocks behind — or holds up — a
    /// lazy load or a server's own metrics mutex (routing and loads
    /// proceed concurrently with a scrape; see the
    /// `metrics_scrape_does_not_serialize_behind_a_blocked_load` test):
    ///
    /// 1. **Under the router lock**: plain counters, clones of the
    ///    evicted-incarnation accumulators (bounded memcpys — reservoir
    ///    cap + fixed histograms, usually empty — touching no other
    ///    lock), and `Arc` handles to live/draining servers.
    /// 2. **Unlocked**: each live/draining server is asked for its full
    ///    metrics (the one place per-server metrics mutexes are taken),
    ///    recorders merge histogram-exactly into per-model and
    ///    fleet-wide totals, and the rows are summarized.
    pub fn metrics(&self) -> RouterMetrics {
        struct RowSeed {
            name: String,
            past: ServeMetrics,
            live: Option<(Arc<Server>, Vec<usize>, Option<PlanSummary>, u64)>,
            draining: Vec<Arc<Server>>,
            health: ModelHealth,
        }
        // phase 1: under the lock — counters and handles only
        let (mut rm, seeds) = {
            let inner = self.inner.lock().unwrap();
            let health_totals = health_totals(&inner.health);
            let rm = RouterMetrics {
                routed: inner.routed,
                unknown_model: inner.unknown,
                loads: inner.loads,
                evictions: inner.evictions,
                resident_bytes: inner.resident,
                budget: self.cfg.max_bytes,
                dedup_hits: inner.dedup_hits,
                load_retries: health_totals.0,
                breaker_opens: health_totals.1,
                breaker_fast_fails: health_totals.2,
                quarantined: health_totals.3,
                // loads are rare (each pays a model read), so this
                // recorder stays tiny; summarizing it here is O(loads)
                load_latency: inner.load_latency.summary(),
                wall_s: self.started.elapsed().as_secs_f64(),
                models: Vec::new(),
                fleet: ServeSummary::default(),
                pool: self.pool.as_deref().map(|p| p.stats()),
            };
            let seeds: Vec<RowSeed> = self
                .registry
                .names()
                .map(|name| RowSeed {
                    name: name.to_string(),
                    past: inner.past.get(name).cloned().unwrap_or_default(),
                    live: inner.loaded.get(name).map(|lm| {
                        (Arc::clone(&lm.server), lm.input_shape.clone(), lm.plan, lm.bytes)
                    }),
                    // evicted-but-still-draining incarnations stay
                    // visible, so a model's counters never dip
                    // mid-eviction
                    draining: inner
                        .draining
                        .iter()
                        .filter(|(n, _)| *n == name)
                        .map(|(_, s)| Arc::clone(s))
                        .collect(),
                    health: inner.health.get(name).map(|h| h.snapshot()).unwrap_or_default(),
                })
                .collect();
            (rm, seeds)
        };
        // phase 2: unlocked — merge full recorders, assemble rows
        let default = self.registry.default_name().unwrap_or_default().to_string();
        let mut fleet = ServeMetrics::default();
        for seed in seeds {
            let mut metrics = seed.past;
            for srv in &seed.draining {
                metrics.merge_from(&srv.metrics());
            }
            let (loaded, known, headroom) = match seed.live {
                Some((srv, shape, plan, bytes)) => {
                    metrics.merge_from(&srv.metrics());
                    let headroom = srv.headroom_snapshot();
                    (true, Some((shape, plan, bytes)), Some(headroom))
                }
                None => (false, None, None),
            };
            fleet.merge_from(&metrics);
            rm.models.push(model_status(
                &self.registry,
                &default,
                seed.name,
                loaded,
                known,
                metrics.summary(),
                seed.health,
                headroom,
            ));
        }
        rm.fleet = fleet.summary();
        rm
    }

    /// Per-model rows only (the `GET /v1/models` payload).
    pub fn models(&self) -> Vec<ModelStatus> {
        self.metrics().models
    }

    /// Graceful shutdown: drain every loaded model's server (queued
    /// requests are answered), fold final metrics, and return the lifetime
    /// [`RouterMetrics`].
    pub fn shutdown(self) -> RouterMetrics {
        let Router { registry, cfg, pool, inner, load_done: _, started } = self;
        let mut inner = inner.into_inner().unwrap();
        // `shutdown(self)` cannot race a `resolve(&self)`, so `draining`
        // is normally empty here; fold defensively anyway
        for (name, srv) in std::mem::take(&mut inner.draining) {
            let final_metrics = srv.drain();
            inner.past.entry(name).or_default().merge_from(&final_metrics);
        }
        // remember what the loaded incarnations knew (shape, plan) so the
        // final report keeps reporting it
        let mut known: BTreeMap<String, (Vec<usize>, Option<PlanSummary>, u64)> = BTreeMap::new();
        for (name, lm) in std::mem::take(&mut inner.loaded) {
            let final_metrics = lm.server.drain();
            inner.past.entry(name.clone()).or_default().merge_from(&final_metrics);
            known.insert(name, (lm.input_shape, lm.plan, lm.bytes));
        }
        let default = registry.default_name().unwrap_or_default().to_string();
        let names: Vec<String> = registry.names().map(|n| n.to_string()).collect();
        let mut fleet = ServeMetrics::default();
        let models = names
            .into_iter()
            .map(|name| {
                let metrics = inner.past.get(&name).cloned().unwrap_or_default();
                fleet.merge_from(&metrics);
                let known = known.remove(&name);
                let health =
                    inner.health.get(&name).map(|h| h.snapshot()).unwrap_or_default();
                let metrics = metrics.summary();
                // every engine was just drained, so there is no live
                // incarnation left for headroom to describe
                model_status(&registry, &default, name, false, known, metrics, health, None)
            })
            .collect();
        let totals = health_totals(&inner.health);
        RouterMetrics {
            routed: inner.routed,
            unknown_model: inner.unknown,
            loads: inner.loads,
            evictions: inner.evictions,
            // every incarnation was just drained: nothing stays resident
            resident_bytes: 0,
            budget: cfg.max_bytes,
            dedup_hits: inner.dedup_hits,
            load_retries: totals.0,
            breaker_opens: totals.1,
            breaker_fast_fails: totals.2,
            quarantined: totals.3,
            load_latency: inner.load_latency.summary(),
            wall_s: started.elapsed().as_secs_f64(),
            models,
            fleet: fleet.summary(),
            pool: pool.as_deref().map(|p| p.stats()),
        }
    }
}

/// Remove `name` from the loaded fleet under the router lock, returning
/// it for an unlocked graceful drain. Decrements `resident` and the
/// blob refcount and parks the server in `draining` so metrics
/// snapshots keep seeing its traffic mid-drain. Shared by the LRU
/// eviction loop and [`Router::reload`] so the byte accounting cannot
/// drift between the two paths. `None` when `name` is not loaded.
fn evict_locked(inner: &mut RouterInner, name: &str) -> Option<(String, Arc<Server>)> {
    let lm = inner.loaded.remove(name)?;
    inner.evictions += 1;
    inner.resident -= lm.own_bytes;
    if let Some(p) = lm.blob_ptr {
        if let Some(entry) = inner.blobs.get_mut(&p) {
            entry.refs -= 1;
            if entry.refs == 0 {
                inner.resident -= entry.data.len() as u64;
                inner.blobs.remove(&p);
            }
        }
    }
    inner.draining.push((name.to_string(), Arc::clone(&lm.server)));
    Some((name.to_string(), lm.server))
}

/// Assemble one fleet row. `known` carries what a live (or
/// just-drained) incarnation knew — input shape + plan summary;
/// otherwise fall back to what the source can say without loading.
/// Shared by [`Router::metrics`] and [`Router::shutdown`] so the two
/// snapshot paths cannot drift as `ModelStatus` grows fields.
#[allow(clippy::too_many_arguments)]
fn model_status(
    registry: &ModelRegistry,
    default: &str,
    name: String,
    loaded: bool,
    known: Option<(Vec<usize>, Option<PlanSummary>, u64)>,
    metrics: ServeSummary,
    health: ModelHealth,
    headroom: Option<Vec<LayerHeadroom>>,
) -> ModelStatus {
    let (input_shape, plan, bytes) = match known {
        // a drained incarnation still reports shape/plan, but holds no bytes
        Some((shape, plan, bytes)) => (Some(shape), plan, loaded.then_some(bytes)),
        None => {
            let src = registry.entries.get(&name);
            (
                src.and_then(|s| s.input_shape()),
                src.and_then(|s| s.plan_summary()),
                None,
            )
        }
    };
    ModelStatus {
        default: name == default,
        name,
        loaded,
        input_shape,
        plan,
        resident_bytes: bytes,
        metrics,
        health,
        headroom,
    }
}

/// Fleet-wide health sums for [`RouterMetrics`]:
/// `(load_retries, breaker_opens, fast_fails, quarantined)`.
fn health_totals(health: &BTreeMap<String, ModelHealthState>) -> (u64, u64, u64, u64) {
    let mut totals = (0u64, 0u64, 0u64, 0u64);
    for h in health.values() {
        totals.0 += h.load_retries;
        totals.1 += h.opens;
        totals.2 += h.fast_fails;
        totals.3 += u64::from(h.quarantined.is_some());
    }
    totals
}
