//! Persistent serving runtime (the production-shaped front of the stack).
//!
//! `Server` owns a pool of long-lived worker threads, each with a pinned
//! `Engine` instance built once at startup. Requests enter a bounded FIFO
//! queue (`submit` blocks for backpressure, `try_submit` fails fast);
//! workers drain it with *streaming dynamic batching*: grab the first
//! available request, then keep filling the batch up to `max_batch`,
//! lingering at most `linger` for stragglers before running the engine.
//!
//! Failure semantics are per-request: a malformed request (wrong image
//! size) or an engine error produces an error *response* on that request's
//! channel — it never panics a worker and never affects batch-mates.
//!
//! Latency accounting is per-request and honest: `queue_us` (enqueue →
//! batch assembly), `compute_us` (the engine invocation the request rode
//! in), and `latency_us` (enqueue → response, which is what a client
//! experiences). `shutdown` closes the queue, lets workers drain every
//! queued request, joins them, and returns the final [`ServeMetrics`].
//!
//! Deadlines and cancellation live in the queue itself: `submit` takes an
//! optional per-request deadline (falling back to
//! [`ServerConfig::default_deadline`]), and workers *skip* any job whose
//! deadline has passed at batch-assembly time — the job is answered with
//! [`ServeError::Expired`] and counted in `ServeMetrics::expired` without
//! ever touching an engine. A slow or abandoned client can therefore never
//! hold a pinned engine hostage; the HTTP front-end (`crate::http`) maps
//! expiry to `504 Gateway Timeout`.
//!
//! The legacy one-shot front-ends (`coordinator::serve_requests`) are thin
//! shims over this type.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::faults::FaultPlan;
use crate::formats::pqsw::PqswModel;
use crate::nn::engine::{Engine, EngineConfig};
use crate::trace::{LayerHeadroom, ModelHeadroom};
use crate::util::pool::{self, ComputePool};

use super::metrics::{LatencyRecorder, ServeMetrics};

/// Serving-layer error carried inside a [`ServeResponse`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The request itself was malformed (e.g. wrong image size).
    BadRequest(String),
    /// The engine failed on the batch this request rode in.
    Internal(String),
    /// The request's deadline passed before a worker assembled it into a
    /// batch; it was cancelled without touching an engine.
    Expired {
        /// how long the request sat in the queue before being skipped
        waited_us: u64,
    },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::BadRequest(m) => write!(f, "bad request: {m}"),
            ServeError::Internal(m) => write!(f, "internal error: {m}"),
            ServeError::Expired { waited_us } => {
                write!(f, "deadline exceeded: expired after {waited_us}us in queue")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Why a submission was not accepted. The image is handed back so the
/// caller can retry or shed load.
#[derive(Debug)]
pub enum SubmitError {
    /// Bounded queue is at capacity (only from [`Server::try_submit`]).
    Full(Vec<f32>),
    /// Server is shutting down; no new work is accepted.
    Closed(Vec<f32>),
}

/// One served response with per-request latency accounting (microseconds).
#[derive(Clone, Debug)]
pub struct ServeResponse {
    pub id: u64,
    /// Predicted class, or the per-request serving error.
    pub result: Result<usize, ServeError>,
    /// enqueue -> batch assembly (time spent waiting in the queue)
    pub queue_us: f64,
    /// wall time of the engine invocation this request was batched into
    pub compute_us: f64,
    /// enqueue -> response: what a client actually experiences
    pub latency_us: f64,
    /// how many requests shared the engine invocation (0 for pre-engine
    /// rejections)
    pub batch_size: usize,
    /// batch validation/grouping/plan-apply time ahead of this request's
    /// engine invocation (0 for pre-engine rejections); a trace span stage
    pub batch_us: f64,
    /// per-layer wall time of the engine invocation this request rode,
    /// graph order, µs — shared by every batch-mate (empty for rejections
    /// and engine failures)
    pub layer_us: Arc<Vec<(String, f64)>>,
    /// the ridden batch recorded overflow events (policy events or
    /// persistent overflows); forces trace sampling for this request
    pub overflow: bool,
}

/// Handle to a response that has not been produced yet.
pub struct PendingResponse {
    pub id: u64,
    rx: mpsc::Receiver<ServeResponse>,
}

impl PendingResponse {
    /// Block until the response arrives. Never panics: if the serving side
    /// vanished, an `Internal` error response is synthesized.
    pub fn wait(self) -> ServeResponse {
        self.rx.recv().unwrap_or_else(|_| ServeResponse {
            id: self.id,
            result: Err(ServeError::Internal("server dropped the request channel".into())),
            queue_us: 0.0,
            compute_us: 0.0,
            latency_us: 0.0,
            batch_size: 0,
            batch_us: 0.0,
            layer_us: Arc::new(Vec::new()),
            overflow: false,
        })
    }

    /// Block until the response arrives or `timeout` elapses; `None` on
    /// timeout (the request stays in flight server-side). Tests use this
    /// instead of [`PendingResponse::wait`] so a queue-logic regression
    /// fails fast instead of hanging the suite; the HTTP front-end uses it
    /// to bound how long a connection handler can be held.
    pub fn wait_timeout(self, timeout: Duration) -> Option<ServeResponse> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Some(r),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => Some(ServeResponse {
                id: self.id,
                result: Err(ServeError::Internal("server dropped the request channel".into())),
                queue_us: 0.0,
                compute_us: 0.0,
                latency_us: 0.0,
                batch_size: 0,
                batch_us: 0.0,
                layer_us: Arc::new(Vec::new()),
                overflow: false,
            }),
        }
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<ServeResponse> {
        self.rx.try_recv().ok()
    }
}

/// Server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// worker threads, each with a pinned engine
    pub threads: usize,
    /// dynamic-batching cap per engine invocation
    pub max_batch: usize,
    /// bounded queue capacity (backpressure bound)
    pub queue_cap: usize,
    /// how long a worker lingers for stragglers once it holds a partial
    /// batch (0 = never wait; serve whatever is immediately available)
    pub linger: Duration,
    /// width of the *shared* intra-forward compute pool. With a value > 1
    /// the server builds one persistent [`ComputePool`] of this many
    /// threads and every worker's engine dispatches into it — batch-1
    /// requests get intra-layer parallelism without N workers × T threads
    /// oversubscribing the machine (keep 1 when worker-level parallelism
    /// already saturates the cores)
    pub engine_threads: usize,
    /// deadline applied to requests submitted without one (`None` =
    /// requests never expire). Expired requests are skipped by workers and
    /// answered with [`ServeError::Expired`] before reaching an engine.
    pub default_deadline: Option<Duration>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            threads: pool::default_threads(),
            max_batch: 32,
            queue_cap: 1024,
            linger: Duration::from_micros(200),
            engine_threads: 1,
            default_deadline: None,
        }
    }
}

struct Job {
    id: u64,
    image: Vec<f32>,
    enqueued: Instant,
    deadline: Option<Instant>,
    /// per-request accumulator operating point (validated against the
    /// model's embedded plan at batch-assembly time)
    acc_bits: Option<u32>,
    tx: mpsc::Sender<ServeResponse>,
}

struct QueueState {
    jobs: VecDeque<Job>,
    closed: bool,
}

#[derive(Default)]
struct MetricsState {
    completed: usize,
    errors: usize,
    expired: usize,
    panics: usize,
    batches: usize,
    batched_requests: usize,
    latency: LatencyRecorder,
    queue: LatencyRecorder,
    compute: LatencyRecorder,
}

struct Shared {
    model: PqswModel,
    cfg: EngineConfig,
    scfg: ServerConfig,
    queue: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    metrics: Mutex<MetricsState>,
    started: Instant,
    /// one persistent compute pool shared by every worker's engine
    /// (`None` when `engine_threads <= 1`)
    pool: Option<Arc<ComputePool>>,
    /// injected-fault plan the workers consult before each forward
    /// (`None` in production: the seam costs one `if let`)
    faults: Option<Arc<FaultPlan>>,
    /// per-layer accumulator-headroom counters fed by every served batch
    /// (one mutex touch per engine invocation); counters are per
    /// incarnation — evict/reload starts a fresh observation window
    headroom: ModelHeadroom,
}

/// Persistent worker-pool serving runtime. See the module docs.
pub struct Server {
    shared: Arc<Shared>,
    /// behind a mutex so [`Server::drain`] can close and join from
    /// `&self` (the router drains evicted servers it only holds in an
    /// `Arc`); a second concurrent drainer blocks until the first one
    /// finished joining, so post-drain metrics are always final
    workers: Mutex<Vec<JoinHandle<()>>>,
}

/// Builds a [`Server`]. This is the primary construction surface: the
/// multi-model [`crate::coordinator::Router`] drives it to put N servers
/// over ONE shared [`ComputePool`] (`shared_pool`), and single-model
/// callers get the same defaults through the [`Server::start`] shorthand.
///
/// ```ignore
/// let srv = Server::builder()
///     .engine(engine_cfg)
///     .config(server_cfg)
///     .shared_pool(pool)       // optional: share one pool across servers
///     .start(&model);
/// ```
#[derive(Default)]
pub struct ServerBuilder {
    cfg: EngineConfig,
    scfg: ServerConfig,
    pool: Option<Arc<ComputePool>>,
    faults: Option<Arc<FaultPlan>>,
}

impl ServerBuilder {
    pub fn new() -> ServerBuilder {
        ServerBuilder {
            cfg: EngineConfig::default(),
            scfg: ServerConfig::default(),
            pool: None,
            faults: None,
        }
    }

    /// Engine configuration every pinned worker engine is built from.
    pub fn engine(mut self, cfg: EngineConfig) -> ServerBuilder {
        self.cfg = cfg;
        self
    }

    /// Server tuning knobs (threads, batching, queue bound, deadlines).
    pub fn config(mut self, scfg: ServerConfig) -> ServerBuilder {
        self.scfg = scfg;
        self
    }

    /// Dispatch every worker engine into an externally owned compute pool
    /// instead of building a private one. This is how the router keeps N
    /// model servers from oversubscribing the machine: they all share one
    /// pool. Overrides `ServerConfig::engine_threads` (the pool's own
    /// width applies).
    pub fn shared_pool(mut self, pool: Arc<ComputePool>) -> ServerBuilder {
        self.pool = Some(pool);
        self
    }

    /// [`ServerBuilder::shared_pool`] when the caller may or may not have
    /// a pool (the router's engines run single-threaded without one).
    pub fn maybe_shared_pool(mut self, pool: Option<Arc<ComputePool>>) -> ServerBuilder {
        self.pool = pool;
        self
    }

    /// Arm a deterministic fault plan (chaos testing): workers consult it
    /// before every forward, so injected engine panics exercise the same
    /// `catch_unwind` isolation a real engine bug would hit.
    pub fn maybe_faults(mut self, faults: Option<Arc<FaultPlan>>) -> ServerBuilder {
        self.faults = faults;
        self
    }

    /// Spawn the worker pool. The model is copied once into the server;
    /// each worker builds its own pinned `Engine` from it.
    pub fn start(self, model: &PqswModel) -> Server {
        let scfg = ServerConfig {
            threads: self.scfg.threads.max(1),
            max_batch: self.scfg.max_batch.max(1),
            queue_cap: self.scfg.queue_cap.max(1),
            engine_threads: self.scfg.engine_threads.max(1),
            ..self.scfg
        };
        let mut pool = self.pool;
        if pool.is_none() && scfg.engine_threads > 1 {
            pool = Some(Arc::new(ComputePool::new(scfg.engine_threads)));
        }
        let shared = Arc::new(Shared {
            model: model.clone(),
            cfg: self.cfg,
            scfg,
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            metrics: Mutex::new(MetricsState::default()),
            started: Instant::now(),
            pool,
            faults: self.faults,
            headroom: ModelHeadroom::new(),
        });
        let workers = (0..scfg.threads)
            .map(|_| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&sh))
            })
            .collect();
        Server { shared, workers: Mutex::new(workers) }
    }
}

#[inline]
fn dur_us(d: Duration) -> f64 {
    d.as_secs_f64() * 1e6
}

impl Server {
    /// Start building a server (the full construction surface).
    pub fn builder() -> ServerBuilder {
        ServerBuilder::new()
    }

    /// Shorthand for the common single-model case:
    /// `Server::builder().engine(cfg).config(scfg).start(model)`.
    pub fn start(model: &PqswModel, cfg: EngineConfig, scfg: ServerConfig) -> Server {
        Server::builder().engine(cfg).config(scfg).start(model)
    }

    /// Input dimension (flattened) the served model expects.
    pub fn input_dim(&self) -> usize {
        self.shared.model.input_shape.iter().product()
    }

    /// Input shape of the served model.
    pub fn input_shape(&self) -> &[usize] {
        &self.shared.model.input_shape
    }

    /// Enqueue a request, blocking while the bounded queue is full
    /// (backpressure). Fails only once the server is shutting down.
    ///
    /// `deadline` bounds how long the request may wait for batch assembly;
    /// `None` falls back to [`ServerConfig::default_deadline`]. A request
    /// whose deadline passes before a worker picks it up is answered with
    /// [`ServeError::Expired`] without touching an engine.
    pub fn submit(
        &self,
        id: u64,
        image: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<PendingResponse, SubmitError> {
        self.submit_with(id, image, deadline, None)
    }

    /// [`Server::submit`] with a per-request accumulator operating point:
    /// the request's batch group runs at `min(acc_bits, analytic bound)`
    /// per layer instead of the embedded plan's widths. Validation happens
    /// at batch-assembly time — a plan-free model, or an `acc_bits` below
    /// the plan's widest layer, answers with [`ServeError::BadRequest`].
    pub fn submit_with(
        &self,
        id: u64,
        image: Vec<f32>,
        deadline: Option<Duration>,
        acc_bits: Option<u32>,
    ) -> Result<PendingResponse, SubmitError> {
        let deadline = self.resolve_deadline(deadline);
        let (tx, rx) = mpsc::channel();
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            if q.closed {
                return Err(SubmitError::Closed(image));
            }
            if q.jobs.len() < self.shared.scfg.queue_cap {
                q.jobs.push_back(Job {
                    id,
                    image,
                    enqueued: Instant::now(),
                    deadline,
                    acc_bits,
                    tx,
                });
                drop(q);
                self.shared.not_empty.notify_one();
                return Ok(PendingResponse { id, rx });
            }
            q = self.shared.not_full.wait(q).unwrap();
        }
    }

    /// Enqueue without blocking; `Full` hands the image back when the
    /// backpressure bound is hit. Deadline semantics match [`Server::submit`].
    pub fn try_submit(
        &self,
        id: u64,
        image: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<PendingResponse, SubmitError> {
        self.try_submit_with(id, image, deadline, None)
    }

    /// [`Server::try_submit`] with a per-request accumulator operating
    /// point (see [`Server::submit_with`]).
    pub fn try_submit_with(
        &self,
        id: u64,
        image: Vec<f32>,
        deadline: Option<Duration>,
        acc_bits: Option<u32>,
    ) -> Result<PendingResponse, SubmitError> {
        let deadline = self.resolve_deadline(deadline);
        let (tx, rx) = mpsc::channel();
        let mut q = self.shared.queue.lock().unwrap();
        if q.closed {
            return Err(SubmitError::Closed(image));
        }
        if q.jobs.len() >= self.shared.scfg.queue_cap {
            return Err(SubmitError::Full(image));
        }
        q.jobs.push_back(Job { id, image, enqueued: Instant::now(), deadline, acc_bits, tx });
        drop(q);
        self.shared.not_empty.notify_one();
        Ok(PendingResponse { id, rx })
    }

    fn resolve_deadline(&self, deadline: Option<Duration>) -> Option<Instant> {
        deadline.or(self.shared.scfg.default_deadline).map(|d| Instant::now() + d)
    }

    /// Requests currently waiting in the queue.
    pub fn queue_len(&self) -> usize {
        self.shared.queue.lock().unwrap().jobs.len()
    }

    /// Snapshot of the serving metrics so far.
    pub fn metrics(&self) -> ServeMetrics {
        snapshot(&self.shared)
    }

    /// Per-layer accumulator-headroom counters observed by this server
    /// incarnation (planned width vs max required width, min headroom,
    /// overflow and near-saturation dots — see
    /// [`crate::trace::ModelHeadroom`]). Empty until a batch has run.
    pub fn headroom_snapshot(&self) -> Vec<LayerHeadroom> {
        self.shared.headroom.snapshot()
    }

    /// Quantile-summary snapshot (`Copy`, no reservoirs). The recorder
    /// copies happen under this server's own metrics mutex (a memcpy) and
    /// the percentile sorts outside any lock — this is what the router's
    /// fleet snapshot calls per model, *after* releasing the router lock.
    pub fn metrics_summary(&self) -> crate::coordinator::ServeSummary {
        snapshot(&self.shared).summary()
    }

    /// Graceful shutdown: stop accepting work, let workers drain every
    /// queued request, join them, and return the final metrics.
    pub fn shutdown(self) -> ServeMetrics {
        self.close_and_join();
        snapshot(&self.shared)
    }

    /// [`Server::shutdown`] through a shared handle: closes the queue,
    /// drains it, joins the workers and returns the final metrics — but
    /// takes `&self`, so the multi-model router can drain an evicted
    /// server it only holds in an `Arc` (no busy-wait for uniqueness).
    /// Afterwards `submit`/`try_submit` fail with `Closed`.
    pub fn drain(&self) -> ServeMetrics {
        self.close_and_join();
        snapshot(&self.shared)
    }

    fn close_and_join(&self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.closed = true;
        }
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
        // joining under the lock makes concurrent drainers wait for the
        // first one to finish, so everyone observes fully-final metrics
        let mut workers = self.workers.lock().unwrap();
        for h in workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

fn snapshot(shared: &Shared) -> ServeMetrics {
    let m = shared.metrics.lock().unwrap();
    let wall_s = shared.started.elapsed().as_secs_f64();
    let requests = m.completed + m.errors + m.expired;
    ServeMetrics {
        requests,
        errors: m.errors,
        expired: m.expired,
        panics: m.panics,
        wall_s,
        throughput_rps: requests as f64 / wall_s.max(1e-9),
        batches: m.batches,
        mean_batch: if m.batches == 0 {
            0.0
        } else {
            m.batched_requests as f64 / m.batches as f64
        },
        latency: m.latency.clone(),
        queue: m.queue.clone(),
        compute: m.compute.clone(),
        pool: shared.pool.as_ref().map(|p| p.stats()),
    }
}

fn worker_loop(shared: &Shared) {
    // serving engines always collect overflow statistics: the live
    // headroom telemetry (`Shared::headroom`) is fed from every batch, and
    // because the flag never depends on tracing state, logits and overflow
    // counters are bit-identical with tracing enabled or disabled (the
    // stats scan computes the same accumulator values as the fast path)
    let ecfg = EngineConfig { collect_stats: true, ..shared.cfg };
    let mut engine = Engine::new(&shared.model, ecfg);
    match &shared.pool {
        Some(p) => engine.set_pool(Arc::clone(p)),
        None => engine.set_threads(shared.scfg.engine_threads),
    }
    let dim: usize = shared.model.input_shape.iter().product();
    loop {
        let mut batch: Vec<Job> = Vec::new();
        {
            let mut q = shared.queue.lock().unwrap();
            // block for the first request (or exit once closed and drained)
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    batch.push(j);
                    break;
                }
                if q.closed {
                    return;
                }
                q = shared.not_empty.wait(q).unwrap();
            }
            // streaming dynamic batching: fill up to max_batch, lingering
            // briefly for stragglers
            let deadline = Instant::now() + shared.scfg.linger;
            while batch.len() < shared.scfg.max_batch {
                if let Some(j) = q.jobs.pop_front() {
                    batch.push(j);
                    continue;
                }
                if q.closed {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (qq, timeout) = shared.not_empty.wait_timeout(q, deadline - now).unwrap();
                q = qq;
                if timeout.timed_out() && q.jobs.is_empty() {
                    break;
                }
            }
        }
        // queue capacity was freed
        shared.not_full.notify_all();
        // Panic isolation: a panicking engine (or any bug downstream of
        // batch assembly) must cost exactly its own batch, never the
        // worker thread — before this guard a single panic silently shrank
        // the pool by one pinned engine forever. `process_batch` answers
        // every job in the panicked group with an `Internal` error itself;
        // if the unwind escaped it anyway, the dropped senders make each
        // pending `wait()` synthesize the same error, so no client hangs.
        let engine_ok =
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                process_batch(&mut engine, shared, dim, batch)
            })) {
                Ok(ok) => ok,
                Err(_) => {
                    shared.metrics.lock().unwrap().panics += 1;
                    false
                }
            };
        if !engine_ok {
            // the unwound engine's scratch arena may hold arbitrary state:
            // rebuild from the pristine model (re-applies any embedded plan)
            engine = Engine::new(&shared.model, ecfg);
            match &shared.pool {
                Some(p) => engine.set_pool(Arc::clone(p)),
                None => engine.set_threads(shared.scfg.engine_threads),
            }
        }
    }
}

/// Returns whether the engine is still trustworthy (`false` after a
/// caught panic — the caller rebuilds it).
fn process_batch(engine: &mut Engine, shared: &Shared, dim: usize, jobs: Vec<Job>) -> bool {
    // per-request validation: an expired or malformed request answers with
    // an error and never reaches the engine (one bad request cannot hurt
    // batch-mates, and a dead client cannot pin an engine). Requests that
    // survive are grouped by their accumulator operating point — `None`
    // (the embedded plan / global width) plus one group per requested
    // `acc_bits` — and each group gets its own engine invocation.
    let now = Instant::now();
    let rejected = GroupStamp::rejected();
    let mut groups: BTreeMap<Option<u32>, Vec<Job>> = BTreeMap::new();
    for j in jobs {
        if j.deadline.is_some_and(|d| now >= d) {
            let waited_us = dur_us(j.enqueued.elapsed()) as u64;
            respond(shared, &j, Err(ServeError::Expired { waited_us }), &rejected);
        } else if j.image.len() != dim {
            let err = ServeError::BadRequest(format!(
                "image size {} != model input {dim}",
                j.image.len()
            ));
            respond(shared, &j, Err(err), &rejected);
        } else if let Some(w) = j.acc_bits {
            match &shared.model.plan {
                None => {
                    let err = ServeError::BadRequest(
                        "acc_bits override requires a model with an embedded \
                         accumulator plan (save one with `pqs plan`)"
                            .into(),
                    );
                    respond(shared, &j, Err(err), &rejected);
                }
                Some(plan) if w < plan.min_safe_bits() => {
                    let err = ServeError::BadRequest(format!(
                        "acc_bits {w} is below the plan's safe minimum {} \
                         (widest planned layer)",
                        plan.min_safe_bits()
                    ));
                    respond(shared, &j, Err(err), &rejected);
                }
                Some(_) => groups.entry(Some(w)).or_default().push(j),
            }
        } else {
            groups.entry(None).or_default().push(j);
        }
    }
    // `None` sorts first, so plan-width requests run before any override
    // re-programs the engine's per-layer widths
    let mut overridden = false;
    let mut engine_ok = true;
    for (width, valid) in groups {
        if let Some(w) = width {
            let plan = shared.model.plan.as_ref().expect("validated above");
            engine.apply_layer_bits(&plan.operating_point(w));
            overridden = true;
        }
        engine_ok &= run_group(engine, shared, dim, &valid, now);
    }
    if overridden && engine_ok {
        // restore the embedded plan for the next batch on this engine
        // (skipped after a panic: the caller rebuilds the engine anyway)
        if let Some(plan) = &shared.model.plan {
            engine.apply_plan(plan);
        }
    }
    engine_ok
}

/// Per-invocation accounting shared by every response of one engine run.
struct GroupStamp {
    compute_us: f64,
    batch_size: usize,
    batch_us: f64,
    layer_us: Arc<Vec<(String, f64)>>,
    overflow: bool,
}

impl GroupStamp {
    /// Pre-engine rejections: all-zero, so the queue/compute recorders
    /// keep describing real engine invocations only.
    fn rejected() -> GroupStamp {
        GroupStamp {
            compute_us: 0.0,
            batch_size: 0,
            batch_us: 0.0,
            layer_us: Arc::new(Vec::new()),
            overflow: false,
        }
    }
}

/// One engine invocation over an already-validated group of jobs.
/// Returns whether the engine survived (`false` = it panicked and every
/// job was answered with an `Internal` error).
fn run_group(
    engine: &mut Engine,
    shared: &Shared,
    dim: usize,
    valid: &[Job],
    assembled: Instant,
) -> bool {
    if valid.is_empty() {
        return true;
    }
    let n = valid.len();
    let mut flat = Vec::with_capacity(n * dim);
    for j in valid {
        flat.extend_from_slice(&j.image);
    }
    let t0 = Instant::now();
    let batch_us = dur_us(t0.duration_since(assembled));
    // the forward itself runs under `catch_unwind` so a panicking kernel
    // (or an injected chaos fault) is indistinguishable from an engine
    // `Err` from the client's point of view: one 500 per batch-mate
    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        if let Some(f) = &shared.faults {
            f.before_forward();
        }
        engine.forward(&flat, n)
    }));
    let compute_us = dur_us(t0.elapsed());
    {
        let mut m = shared.metrics.lock().unwrap();
        m.batches += 1;
        m.batched_requests += n;
    }
    match out {
        Ok(Ok(mut out)) => {
            // the batch ran at the engine's current per-layer widths (the
            // embedded plan, or this group's operating point): fold its
            // overflow report into the live headroom counters
            shared.headroom.record(
                &out.report,
                &engine.effective_layer_bits(),
                shared.cfg.acc_bits,
            );
            let totals = out.report.total();
            let stamp = GroupStamp {
                compute_us,
                batch_size: n,
                batch_us,
                layer_us: Arc::new(std::mem::take(&mut out.layer_us)),
                overflow: totals.policy_event_dots > 0 || totals.persistent_dots > 0,
            };
            for (bi, j) in valid.iter().enumerate() {
                respond(shared, j, Ok(out.argmax(bi)), &stamp);
            }
            true
        }
        Ok(Err(e)) => {
            // engine failure: per-request error responses, service survives
            let msg = format!("forward failed: {e:#}");
            let stamp = GroupStamp {
                compute_us,
                batch_size: n,
                batch_us,
                layer_us: Arc::new(Vec::new()),
                overflow: false,
            };
            for j in valid {
                respond(shared, j, Err(ServeError::Internal(msg.clone())), &stamp);
            }
            true
        }
        Err(payload) => {
            // engine panic: count it, answer every batch-mate, poison-flag
            // the engine so the worker rebuilds it
            let what = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "unknown panic payload".into());
            shared.metrics.lock().unwrap().panics += 1;
            let msg = format!("engine panicked: {what}");
            let stamp = GroupStamp {
                compute_us,
                batch_size: n,
                batch_us,
                layer_us: Arc::new(Vec::new()),
                overflow: false,
            };
            for j in valid {
                respond(shared, j, Err(ServeError::Internal(msg.clone())), &stamp);
            }
            false
        }
    }
}

fn respond(shared: &Shared, job: &Job, result: Result<usize, ServeError>, stamp: &GroupStamp) {
    let total_us = dur_us(job.enqueued.elapsed());
    let resp = ServeResponse {
        id: job.id,
        queue_us: (total_us - stamp.compute_us).max(0.0),
        compute_us: stamp.compute_us,
        latency_us: total_us,
        batch_size: stamp.batch_size,
        batch_us: stamp.batch_us,
        layer_us: Arc::clone(&stamp.layer_us),
        overflow: stamp.overflow,
        result,
    };
    {
        let mut m = shared.metrics.lock().unwrap();
        match &resp.result {
            Ok(_) => m.completed += 1,
            Err(ServeError::Expired { .. }) => m.expired += 1,
            Err(_) => m.errors += 1,
        }
        m.latency.record(resp.latency_us);
        // pre-engine rejections (batch_size == 0) never ran the engine:
        // keep them out of the queue/compute distributions so those
        // recorders describe real engine invocations only
        if stamp.batch_size > 0 {
            m.queue.record(resp.queue_us);
            m.compute.record(resp.compute_us);
        }
    }
    let _ = job.tx.send(resp);
}
