//! Latency/throughput metrics for the serving front-end.

use crate::util::pool::PoolStats;
use crate::util::stats;
use crate::util::stats::HdrHistogram;

/// Reservoir size: memory stays bounded (~512 KiB of f64) no matter how
/// long the server runs; percentiles beyond this many samples are computed
/// over a uniform reservoir (Algorithm R), mean/max/count stay exact.
const RESERVOIR_CAP: usize = 65_536;

#[inline]
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Streaming latency recorder (microseconds). Bounded memory: a uniform
/// reservoir of at most [`RESERVOIR_CAP`] samples keeps quantiles *exact*
/// while every sample is retained, and a fixed-size [`HdrHistogram`]
/// shadows the stream so quantiles stay within the HDR bucket error
/// (±3%) once the reservoir saturates or recorders merge — count, mean
/// and max are tracked exactly throughout. Safe for a long-lived
/// production `Server` serving unbounded request streams.
#[derive(Clone, Debug, Default)]
pub struct LatencyRecorder {
    samples: Vec<f64>,
    seen: u64,
    sum: f64,
    max: f64,
    hist: HdrHistogram,
}

impl LatencyRecorder {
    pub fn record(&mut self, us: f64) {
        self.sum += us;
        if us > self.max {
            self.max = us;
        }
        self.hist.record(us.max(0.0) as u64);
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(us);
        } else {
            // Algorithm R with a deterministic splitmix64 draw
            let j = (splitmix64(self.seen) % (self.seen + 1)) as usize;
            if j < RESERVOIR_CAP {
                self.samples[j] = us;
            }
        }
        self.seen += 1;
    }

    /// Percentiles come from the reservoir while it still holds every
    /// sample (exact, order-free), and from the HDR histogram once the
    /// stream outgrew it — the histogram merge is bucket-exact, so
    /// quantiles stay ≤3%-accurate across evict/reload merges instead of
    /// drifting with spliced reservoirs.
    fn pct(&self, q: f64) -> f64 {
        if self.samples.len() as u64 == self.seen {
            stats::percentile(&self.samples, q)
        } else {
            self.hist.value_at(q / 100.0) as f64
        }
    }

    pub fn count(&self) -> usize {
        self.seen as usize
    }

    pub fn mean_us(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.sum / self.seen as f64
        }
    }

    pub fn p50_us(&self) -> f64 {
        self.pct(50.0)
    }

    pub fn p95_us(&self) -> f64 {
        self.pct(95.0)
    }

    pub fn p99_us(&self) -> f64 {
        self.pct(99.0)
    }

    pub fn p999_us(&self) -> f64 {
        self.pct(99.9)
    }

    pub fn max_us(&self) -> f64 {
        self.max
    }

    /// Fold `other` into this recorder. `count`, `mean` and `max` stay
    /// exact; the shadow histograms merge bucket-exactly, so post-merge
    /// percentiles hold HDR accuracy (≤3%) even when the combined streams
    /// exceed the reservoir (the reservoir is still spliced up to the cap
    /// and keeps serving exact quantiles while it holds every sample).
    /// Used by the router to carry a model's metrics across load/evict
    /// incarnations.
    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.sum += other.sum;
        self.seen += other.seen;
        if other.max > self.max {
            self.max = other.max;
        }
        self.hist.merge(&other.hist);
        let room = RESERVOIR_CAP.saturating_sub(self.samples.len());
        self.samples.extend(other.samples.iter().take(room));
    }

    /// The shadow histogram (for Prometheus bucket export).
    pub fn histogram(&self) -> &HdrHistogram {
        &self.hist
    }

    /// Seven-number summary of the stream so far. This is what metrics
    /// *snapshots* carry (`/v1/metrics` scrapes, per-model fleet rows):
    /// a `Copy` struct instead of a reservoir clone, so assembling a
    /// snapshot never copies or splices up to 64Ki samples per recorder.
    pub fn summary(&self) -> LatencySummary {
        LatencySummary {
            count: self.count(),
            mean_us: self.mean_us(),
            p50_us: self.p50_us(),
            p95_us: self.p95_us(),
            p99_us: self.p99_us(),
            p999_us: self.p999_us(),
            max_us: self.max_us(),
        }
    }
}

/// Quantile summary of one latency stream (microseconds). `Copy`, so
/// fleet snapshots move seven floats per recorder instead of reservoirs.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// p99.9 — the connection-scale tail the event-loop bench gates on;
    /// over a uniform reservoir it needs ~1000+ samples to be meaningful
    pub p999_us: f64,
    pub max_us: f64,
}

impl LatencySummary {
    /// Fold `other` in: `count`, `mean` and `max` stay exact; quantiles
    /// are count-weighted averages — NOT pooled quantiles. That is the
    /// accepted trade for never touching reservoirs on the snapshot path
    /// (the spliced-reservoir merge this replaces was approximate past
    /// the cap too). It is a tight approximation when the merged streams
    /// are near-identically distributed (incarnations of one model
    /// across evict/reload cycles) and a coarse one when they are not
    /// (fleet-wide totals over heterogeneous models, where a true pooled
    /// p99 can sit anywhere between the per-model p99s — read the
    /// per-model sections for real tails). `max_us` is exact either way
    /// and is the trustworthy fleet-wide tail bound.
    pub fn merge_from(&mut self, other: &LatencySummary) {
        let (a, b) = (self.count as f64, other.count as f64);
        if a + b == 0.0 {
            return;
        }
        self.mean_us = (self.mean_us * a + other.mean_us * b) / (a + b);
        self.p50_us = (self.p50_us * a + other.p50_us * b) / (a + b);
        self.p95_us = (self.p95_us * a + other.p95_us * b) / (a + b);
        self.p99_us = (self.p99_us * a + other.p99_us * b) / (a + b);
        self.p999_us = (self.p999_us * a + other.p999_us * b) / (a + b);
        if other.max_us > self.max_us {
            self.max_us = other.max_us;
        }
        self.count += other.count;
    }
}

/// Aggregate serving metrics.
///
/// All latency recorders are *per-request*: `latency` is the end-to-end
/// enqueue→response time each client saw, decomposed into `queue`
/// (time waiting for batch assembly) and `compute` (the engine invocation
/// the request was batched into). `requests` counts every response,
/// including the `errors` answered with a per-request error.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub requests: usize,
    pub errors: usize,
    /// requests whose deadline passed before batch assembly; they were
    /// skipped by the workers without touching an engine (counted in
    /// `requests`, separate from `errors`)
    pub expired: usize,
    /// engine panics caught by the worker's `catch_unwind` isolation;
    /// every job in the panicked batch was answered with an `Internal`
    /// error (those responses are counted in `errors`), the engine was
    /// rebuilt and the worker kept running
    pub panics: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    /// engine invocations (dynamic batches) executed
    pub batches: usize,
    /// mean requests per engine invocation
    pub mean_batch: f64,
    /// per-request enqueue -> response (every response, incl. errors)
    pub latency: LatencyRecorder,
    /// per-request enqueue -> batch assembly (queue wait); excludes
    /// pre-engine rejections, which never waited for an engine
    pub queue: LatencyRecorder,
    /// per-request engine invocation wall time; excludes pre-engine
    /// rejections so it describes real engine invocations only
    pub compute: LatencyRecorder,
    /// utilization of the shared intra-forward compute pool (`None` when
    /// the server runs engines single-threaded)
    pub pool: Option<PoolStats>,
}

impl ServeMetrics {
    /// Fold `other` into this snapshot: counters sum, recorders merge (see
    /// [`LatencyRecorder::merge`]), `mean_batch` is re-weighted by batch
    /// count, and `wall_s` accumulates (incarnations of one model are
    /// sequential in time, so their wall clocks add). `throughput_rps` is
    /// recomputed from the merged totals. The router uses this to carry a
    /// model's serving history across lazy-load/evict cycles and to build
    /// fleet-wide aggregates.
    pub fn merge_from(&mut self, other: &ServeMetrics) {
        let batched =
            self.mean_batch * self.batches as f64 + other.mean_batch * other.batches as f64;
        self.requests += other.requests;
        self.errors += other.errors;
        self.expired += other.expired;
        self.panics += other.panics;
        self.batches += other.batches;
        self.mean_batch = if self.batches == 0 {
            0.0
        } else {
            batched / self.batches as f64
        };
        self.wall_s += other.wall_s;
        self.throughput_rps = self.requests as f64 / self.wall_s.max(1e-9);
        self.latency.merge(&other.latency);
        self.queue.merge(&other.queue);
        self.compute.merge(&other.compute);
        if self.pool.is_none() {
            self.pool = other.pool;
        }
    }

    /// The snapshot form fleet surfaces carry (see [`ServeSummary`]).
    pub fn summary(&self) -> ServeSummary {
        ServeSummary {
            requests: self.requests,
            errors: self.errors,
            expired: self.expired,
            panics: self.panics,
            wall_s: self.wall_s,
            throughput_rps: self.throughput_rps,
            batches: self.batches,
            mean_batch: self.mean_batch,
            latency: self.latency.summary(),
            queue: self.queue.summary(),
            compute: self.compute.summary(),
            pool: self.pool,
        }
    }

    pub fn print(&self) {
        println!(
            "requests={} errors={} expired={} panics={} wall={:.2}s throughput={:.1} req/s  batches={} (mean {:.1} req/batch)",
            self.requests, self.errors, self.expired, self.panics, self.wall_s,
            self.throughput_rps, self.batches, self.mean_batch,
        );
        println!(
            "  e2e latency  mean={:.1}us p50={:.1}us p95={:.1}us p99={:.1}us p999={:.1}us",
            self.latency.mean_us(),
            self.latency.p50_us(),
            self.latency.p95_us(),
            self.latency.p99_us(),
            self.latency.p999_us(),
        );
        println!(
            "  queue wait   mean={:.1}us p50={:.1}us p95={:.1}us p99={:.1}us p999={:.1}us",
            self.queue.mean_us(),
            self.queue.p50_us(),
            self.queue.p95_us(),
            self.queue.p99_us(),
            self.queue.p999_us(),
        );
        println!(
            "  compute      mean={:.1}us p50={:.1}us p95={:.1}us p99={:.1}us p999={:.1}us",
            self.compute.mean_us(),
            self.compute.p50_us(),
            self.compute.p95_us(),
            self.compute.p99_us(),
            self.compute.p999_us(),
        );
        if let Some(p) = &self.pool {
            println!(
                "  compute pool threads={} busy={} jobs={} inline_jobs={} chunks={}",
                p.threads, p.busy, p.jobs, p.inline_jobs, p.chunks,
            );
        }
    }
}

/// Snapshot form of [`ServeMetrics`]: same counters, latency streams as
/// [`LatencySummary`] six-number summaries. `Copy`, cheap to hold under
/// locks — the router's per-model fleet rows, `aggregate()` totals and
/// the evicted-incarnation accumulator all use this, so a `/v1/metrics`
/// scrape never clones or splices a reservoir while holding the router
/// lock (ROADMAP follow-on from PR 4).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeSummary {
    pub requests: usize,
    pub errors: usize,
    pub expired: usize,
    /// engine panics caught and isolated (see [`ServeMetrics::panics`])
    pub panics: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub batches: usize,
    pub mean_batch: f64,
    pub latency: LatencySummary,
    pub queue: LatencySummary,
    pub compute: LatencySummary,
    pub pool: Option<PoolStats>,
}

impl ServeSummary {
    /// Fold `other` in: counters sum, `mean_batch` re-weights by batch
    /// count, `wall_s` accumulates (incarnations are sequential in time),
    /// throughput is recomputed, summaries merge per
    /// [`LatencySummary::merge_from`].
    pub fn merge_from(&mut self, other: &ServeSummary) {
        let batched =
            self.mean_batch * self.batches as f64 + other.mean_batch * other.batches as f64;
        self.requests += other.requests;
        self.errors += other.errors;
        self.expired += other.expired;
        self.panics += other.panics;
        self.batches += other.batches;
        self.mean_batch = if self.batches == 0 {
            0.0
        } else {
            batched / self.batches as f64
        };
        self.wall_s += other.wall_s;
        self.throughput_rps = self.requests as f64 / self.wall_s.max(1e-9);
        self.latency.merge_from(&other.latency);
        self.queue.merge_from(&other.queue);
        self.compute.merge_from(&other.compute);
        if self.pool.is_none() {
            self.pool = other.pool;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::stats;

    #[test]
    fn recorder_percentiles() {
        let mut r = LatencyRecorder::default();
        for i in 1..=100 {
            r.record(i as f64);
        }
        assert_eq!(r.count(), 100);
        assert!((r.mean_us() - 50.5).abs() < 1e-9);
        assert!(r.p95_us() >= 94.0 && r.p95_us() <= 96.0);
        assert!(r.p99_us() >= 98.0);
        assert_eq!(r.max_us(), 100.0);
    }

    #[test]
    fn recorder_memory_is_bounded() {
        // far more samples than the reservoir holds: count/mean/max stay
        // exact, percentiles remain plausible, memory stays capped
        let mut r = LatencyRecorder::default();
        let n = RESERVOIR_CAP + 50_000;
        for i in 0..n {
            r.record((i % 1000) as f64);
        }
        assert_eq!(r.count(), n);
        assert_eq!(r.max_us(), 999.0);
        assert!((r.mean_us() - 499.5).abs() < 2.0);
        assert!(r.samples.len() <= RESERVOIR_CAP);
        let p50 = r.p50_us();
        assert!((400.0..=600.0).contains(&p50), "p50 {p50}");
    }

    #[test]
    fn empty_recorder() {
        let r = LatencyRecorder::default();
        assert_eq!(r.mean_us(), 0.0);
        assert_eq!(r.p99_us(), 0.0);
        assert_eq!(r.max_us(), 0.0);
    }

    #[test]
    fn default_metrics_are_empty() {
        let m = ServeMetrics::default();
        assert_eq!(m.requests, 0);
        assert_eq!(m.errors, 0);
        assert_eq!(m.expired, 0);
        assert_eq!(m.batches, 0);
        assert_eq!(m.latency.count(), 0);
    }

    #[test]
    fn reservoir_replay_is_deterministic() {
        // the reservoir draw is a pure function of the sample index, so two
        // recorders fed the same seeded stream agree exactly, even well past
        // capacity — percentile summaries are reproducible across runs
        let feed = |seed: u64| {
            let mut rng = Pcg32::new(seed);
            let mut r = LatencyRecorder::default();
            for _ in 0..RESERVOIR_CAP + 10_000 {
                r.record(rng.below(1_000_000) as f64);
            }
            r
        };
        let a = feed(42);
        let b = feed(42);
        assert_eq!(a.count(), b.count());
        assert_eq!(a.mean_us(), b.mean_us());
        assert_eq!(a.max_us(), b.max_us());
        assert_eq!(a.p50_us(), b.p50_us());
        assert_eq!(a.p95_us(), b.p95_us());
        assert_eq!(a.p99_us(), b.p99_us());
        // a different stream produces a different summary
        let c = feed(43);
        assert_ne!(a.mean_us(), c.mean_us());
    }

    #[test]
    fn reservoir_stays_bounded_exactly_at_capacity() {
        let mut r = LatencyRecorder::default();
        for i in 0..RESERVOIR_CAP + 1 {
            r.record(i as f64);
        }
        assert_eq!(r.samples.len(), RESERVOIR_CAP, "reservoir must not grow past its cap");
        assert_eq!(r.count(), RESERVOIR_CAP + 1, "count stays exact");
        for _ in 0..10_000 {
            r.record(1.0);
        }
        assert_eq!(r.samples.len(), RESERVOIR_CAP);
    }

    #[test]
    fn recorder_merge_keeps_exact_count_mean_max() {
        let mut a = LatencyRecorder::default();
        let mut b = LatencyRecorder::default();
        for i in 1..=100 {
            a.record(i as f64);
        }
        for i in 101..=300 {
            b.record(i as f64);
        }
        a.merge(&b);
        assert_eq!(a.count(), 300);
        assert!((a.mean_us() - 150.5).abs() < 1e-9);
        assert_eq!(a.max_us(), 300.0);
        // below the reservoir cap the merge keeps every sample: exact p50
        let all: Vec<f64> = (1..=300).map(|i| i as f64).collect();
        assert_eq!(a.p50_us(), stats::percentile(&all, 50.0));
        // merging an empty recorder is a no-op
        let before = (a.count(), a.mean_us(), a.max_us());
        a.merge(&LatencyRecorder::default());
        assert_eq!((a.count(), a.mean_us(), a.max_us()), before);
    }

    #[test]
    fn serve_metrics_merge_sums_counters_and_reweights_batches() {
        let mut a = ServeMetrics {
            requests: 10,
            errors: 1,
            expired: 2,
            batches: 5,
            mean_batch: 2.0, // 10 batched requests
            wall_s: 1.0,
            ..Default::default()
        };
        for _ in 0..10 {
            a.latency.record(100.0);
        }
        let mut b = ServeMetrics {
            requests: 30,
            batches: 5,
            mean_batch: 6.0, // 30 batched requests
            wall_s: 3.0,
            ..Default::default()
        };
        for _ in 0..30 {
            b.latency.record(200.0);
        }
        a.merge_from(&b);
        assert_eq!(a.requests, 40);
        assert_eq!(a.errors, 1);
        assert_eq!(a.expired, 2);
        assert_eq!(a.batches, 10);
        assert!((a.mean_batch - 4.0).abs() < 1e-9, "40 batched over 10 batches");
        assert!((a.wall_s - 4.0).abs() < 1e-9);
        assert!((a.throughput_rps - 10.0).abs() < 1e-9);
        assert_eq!(a.latency.count(), 40);
        assert!((a.latency.mean_us() - 175.0).abs() < 1e-9);
    }

    #[test]
    fn summary_matches_recorder_and_merges_sanely() {
        let mut r = LatencyRecorder::default();
        for i in 1..=100 {
            r.record(i as f64);
        }
        let s = r.summary();
        assert_eq!(s.count, 100);
        assert_eq!(s.mean_us, r.mean_us());
        assert_eq!(s.p50_us, r.p50_us());
        assert_eq!(s.p99_us, r.p99_us());
        assert_eq!(s.max_us, 100.0);
        // merge: exact count/mean/max, count-weighted quantiles
        let mut a = LatencySummary {
            count: 10,
            mean_us: 100.0,
            p50_us: 100.0,
            p95_us: 110.0,
            p99_us: 120.0,
            p999_us: 130.0,
            max_us: 150.0,
        };
        let b = LatencySummary {
            count: 30,
            mean_us: 200.0,
            p50_us: 200.0,
            p95_us: 210.0,
            p99_us: 220.0,
            p999_us: 230.0,
            max_us: 400.0,
        };
        a.merge_from(&b);
        assert_eq!(a.count, 40);
        assert!((a.mean_us - 175.0).abs() < 1e-9);
        assert!((a.p50_us - 175.0).abs() < 1e-9);
        assert!((a.p999_us - 205.0).abs() < 1e-9);
        assert_eq!(a.max_us, 400.0);
        // merging an empty summary is a no-op
        let before = a;
        a.merge_from(&LatencySummary::default());
        assert_eq!(a, before);
        // into-empty adopts the other side
        let mut e = LatencySummary::default();
        e.merge_from(&b);
        assert_eq!(e, b);
    }

    #[test]
    fn serve_summary_merge_mirrors_serve_metrics_merge() {
        let mut a = ServeMetrics {
            requests: 10,
            errors: 1,
            batches: 5,
            mean_batch: 2.0,
            wall_s: 1.0,
            ..Default::default()
        };
        for _ in 0..10 {
            a.latency.record(100.0);
        }
        let mut b = ServeMetrics {
            requests: 30,
            batches: 5,
            mean_batch: 6.0,
            wall_s: 3.0,
            ..Default::default()
        };
        for _ in 0..30 {
            b.latency.record(200.0);
        }
        let mut sum = a.summary();
        sum.merge_from(&b.summary());
        a.merge_from(&b);
        assert_eq!(sum.requests, a.requests);
        assert_eq!(sum.errors, a.errors);
        assert_eq!(sum.batches, a.batches);
        assert!((sum.mean_batch - a.mean_batch).abs() < 1e-9);
        assert!((sum.wall_s - a.wall_s).abs() < 1e-9);
        assert!((sum.throughput_rps - a.throughput_rps).abs() < 1e-9);
        assert_eq!(sum.latency.count, a.latency.count());
        assert!((sum.latency.mean_us - a.latency.mean_us()).abs() < 1e-9);
    }

    #[test]
    fn merged_quantiles_hold_hdr_accuracy_past_capacity() {
        // two incarnations, each past the reservoir cap, with disjoint
        // latency ranges: a spliced reservoir would keep only the first
        // stream's samples and report its p50/p99 for the union, but the
        // histogram-backed merge stays within HDR bucket error (≤3%) of
        // the true pooled quantiles
        let mut a = LatencyRecorder::default();
        let mut b = LatencyRecorder::default();
        let n = RESERVOIR_CAP + 10_000;
        for i in 0..n {
            a.record(100.0 + (i % 100) as f64); // ~[100, 200)
            b.record(10_000.0 + (i % 100) as f64); // ~[10_000, 10_100)
        }
        a.merge(&b);
        assert_eq!(a.count(), 2 * n);
        assert_eq!(a.max_us(), 10_099.0);
        // true pooled quantiles: p50 at the boundary (lower half from a),
        // p99/p999 deep inside b's range
        let p50 = a.p50_us();
        assert!((p50 - 199.0).abs() / 199.0 < 0.04, "p50 {p50}");
        for (q, exact) in [(a.p99_us(), 10_098.0), (a.p999_us(), 10_099.0)] {
            assert!((q - exact).abs() / exact < 0.04, "tail {q} vs {exact}");
            assert!(q <= exact, "HDR lower bounds never overstate");
        }
        // summaries built from the merged recorder inherit the accuracy
        let s = a.summary();
        assert_eq!(s.count, 2 * n);
        assert!((s.p999_us - 10_099.0).abs() / 10_099.0 < 0.04);
    }

    #[test]
    fn quantiles_exact_below_capacity() {
        // below capacity every sample is retained, so quantiles are exact
        // and insertion order is irrelevant
        let mut vals: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let mut rng = Pcg32::new(7);
        rng.shuffle(&mut vals);
        let mut r = LatencyRecorder::default();
        for &v in &vals {
            r.record(v);
        }
        let sorted: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        assert_eq!(r.p50_us(), stats::percentile(&sorted, 50.0));
        assert_eq!(r.p95_us(), stats::percentile(&sorted, 95.0));
        assert_eq!(r.p99_us(), stats::percentile(&sorted, 99.0));
        assert_eq!(r.mean_us(), 500.5);
        assert_eq!(r.max_us(), 1000.0);
        assert_eq!(r.count(), 1000);
    }
}
