//! Latency/throughput metrics for the serving front-end.

use crate::util::stats;

/// Streaming latency recorder (microseconds).
#[derive(Clone, Debug, Default)]
pub struct LatencyRecorder {
    samples: Vec<f64>,
}

impl LatencyRecorder {
    pub fn record(&mut self, us: f64) {
        self.samples.push(us);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean_us(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn p50_us(&self) -> f64 {
        stats::percentile(&self.samples, 50.0)
    }

    pub fn p95_us(&self) -> f64 {
        stats::percentile(&self.samples, 95.0)
    }

    pub fn p99_us(&self) -> f64 {
        stats::percentile(&self.samples, 99.0)
    }
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub requests: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub latency: LatencyRecorder,
}

impl ServeMetrics {
    pub fn print(&self) {
        println!(
            "requests={} wall={:.2}s throughput={:.1} req/s  latency mean={:.1}us p50={:.1}us p95={:.1}us p99={:.1}us",
            self.requests,
            self.wall_s,
            self.throughput_rps,
            self.latency.mean_us(),
            self.latency.p50_us(),
            self.latency.p95_us(),
            self.latency.p99_us(),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_percentiles() {
        let mut r = LatencyRecorder::default();
        for i in 1..=100 {
            r.record(i as f64);
        }
        assert_eq!(r.count(), 100);
        assert!((r.mean_us() - 50.5).abs() < 1e-9);
        assert!(r.p95_us() >= 94.0 && r.p95_us() <= 96.0);
        assert!(r.p99_us() >= 98.0);
    }

    #[test]
    fn empty_recorder() {
        let r = LatencyRecorder::default();
        assert_eq!(r.mean_us(), 0.0);
        assert_eq!(r.p99_us(), 0.0);
    }
}
