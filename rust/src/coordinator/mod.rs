//! Threaded evaluation coordinator (DESIGN.md S19).
//!
//! The paper's contribution lives at the numeric level, so L3 coordination
//! is an *evaluation service*: it owns a pool of worker threads, each with
//! its own `Engine` instance, shards dataset batches across them with a
//! work queue, applies backpressure via the queue bound, and aggregates
//! accuracy + overflow statistics and latency metrics.
//!
//! Two front-ends build on it:
//! * `EvalService::evaluate` — whole-dataset sweeps used by the figure
//!   harnesses;
//! * `serve_requests` — a request/response loop used by `examples/serve.rs`
//!   to demonstrate batched online inference with latency accounting.

pub mod metrics;

use anyhow::Result;

use crate::data::{Batches, Dataset};
use crate::formats::pqsw::PqswModel;
use crate::nn::engine::{Engine, EngineConfig};
use crate::overflow::OverflowReport;
use crate::util::pool;

pub use metrics::{LatencyRecorder, ServeMetrics};

/// Outcome of a coordinated evaluation.
#[derive(Clone, Debug)]
pub struct EvalOutcome {
    pub accuracy: f64,
    pub samples: usize,
    pub report: OverflowReport,
    pub wall_ms: f64,
    pub throughput_ips: f64,
}

/// Evaluation coordinator: fan batches out over engines.
pub struct EvalService<'m> {
    model: &'m PqswModel,
    cfg: EngineConfig,
    threads: usize,
    batch: usize,
}

impl<'m> EvalService<'m> {
    pub fn new(model: &'m PqswModel, cfg: EngineConfig) -> Self {
        EvalService { model, cfg, threads: pool::default_threads(), batch: 64 }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch.max(1);
        self
    }

    /// Evaluate up to `limit` samples of `ds`, sharded over worker engines.
    pub fn evaluate(&self, ds: &Dataset, limit: Option<usize>) -> Result<EvalOutcome> {
        let t0 = std::time::Instant::now();
        // materialize the batch index (start, len)
        let mut shards: Vec<(Vec<f32>, Vec<u8>)> = Vec::new();
        let mut taken = 0usize;
        for (imgs, labels, _s) in Batches::new(ds, self.batch) {
            let mut lab = labels.to_vec();
            let mut im = imgs;
            if let Some(lim) = limit {
                if taken >= lim {
                    break;
                }
                if taken + lab.len() > lim {
                    let keep = lim - taken;
                    lab.truncate(keep);
                    im.truncate(keep * ds.dim());
                }
            }
            taken += lab.len();
            shards.push((im, lab));
        }

        let model = self.model;
        let cfg = self.cfg;
        let results = pool::parallel_map_init(
            shards.len(),
            self.threads,
            || Engine::new(model, cfg),
            |eng, i| {
                let (imgs, labels) = &shards[i];
                let r = eng.forward(imgs, labels.len()).expect("forward");
                let correct =
                    (0..r.batch).filter(|&j| r.argmax(j) == labels[j] as usize).count();
                (correct, labels.len(), r.report)
            },
        );

        let mut report = OverflowReport::default();
        let (mut correct, mut total) = (0usize, 0usize);
        for (c, n, rep) in &results {
            correct += c;
            total += n;
            report.merge(rep);
        }
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        Ok(EvalOutcome {
            accuracy: correct as f64 / total.max(1) as f64,
            samples: total,
            report,
            wall_ms,
            throughput_ips: total as f64 / (wall_ms / 1e3).max(1e-9),
        })
    }
}

/// A single inference request for the serve front-end.
pub struct Request {
    pub id: u64,
    pub image: Vec<f32>,
}

/// Response with latency accounting.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub class: usize,
    pub latency_us: f64,
}

/// Online batched serving: drain `requests` in arrival order, grouping up
/// to `max_batch` per engine invocation (dynamic batching). Returns
/// responses + metrics. Single-node, thread-per-worker design.
pub fn serve_requests(
    model: &PqswModel,
    cfg: EngineConfig,
    requests: Vec<Request>,
    max_batch: usize,
    threads: usize,
) -> Result<(Vec<Response>, ServeMetrics)> {
    let t_start = std::time::Instant::now();
    let dim: usize = model.input_shape.iter().product();
    // group into dynamic batches
    let mut groups: Vec<Vec<Request>> = Vec::new();
    let mut cur: Vec<Request> = Vec::new();
    for r in requests {
        assert_eq!(r.image.len(), dim, "request image size");
        cur.push(r);
        if cur.len() >= max_batch {
            groups.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        groups.push(cur);
    }

    let results = pool::parallel_map_init(
        groups.len(),
        threads.max(1),
        || Engine::new(model, cfg),
        |eng, gi| {
            let group = &groups[gi];
            let mut flat = Vec::with_capacity(group.len() * dim);
            for r in group {
                flat.extend_from_slice(&r.image);
            }
            let t0 = std::time::Instant::now();
            let out = eng.forward(&flat, group.len()).expect("forward");
            let us = t0.elapsed().as_secs_f64() * 1e6;
            group
                .iter()
                .enumerate()
                .map(|(j, r)| Response {
                    id: r.id,
                    class: out.argmax(j),
                    latency_us: us, // batch latency attributed to each member
                })
                .collect::<Vec<_>>()
        },
    );

    let mut responses: Vec<Response> = results.into_iter().flatten().collect();
    responses.sort_by_key(|r| r.id);
    let mut lat = LatencyRecorder::default();
    for r in &responses {
        lat.record(r.latency_us);
    }
    let wall_s = t_start.elapsed().as_secs_f64();
    let metrics = ServeMetrics {
        requests: responses.len(),
        wall_s,
        throughput_rps: responses.len() as f64 / wall_s.max(1e-9),
        latency: lat,
    };
    Ok((responses, metrics))
}

#[cfg(test)]
mod tests {
    // Coordinator paths over real models are exercised in
    // rust/tests/coordinator.rs (needs artifacts). Metrics unit tests live
    // in metrics.rs.
}
