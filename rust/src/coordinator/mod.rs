//! Threaded evaluation + serving coordinator (DESIGN.md S19).
//!
//! The paper's contribution lives at the numeric level, so L3 coordination
//! provides the deployment-shaped fronts around the engine:
//!
//! * [`registry::Router`] + [`registry::ModelRegistry`] — the multi-model
//!   serving surface: named model sources loaded lazily on first request,
//!   LRU eviction under a loaded-model cap, one [`server::Server`] per
//!   loaded model over ONE shared compute pool, per-model metrics that
//!   survive eviction, and router-level counters (routed / unknown-model /
//!   loads / evictions / load latency). The HTTP/1.1 front-end
//!   (`crate::http`) routes `POST /v1/classify {"model": ...}` through it;
//! * [`server::Server`] — the per-model persistent serving runtime:
//!   long-lived workers with pinned engines, a bounded request queue with
//!   backpressure, streaming dynamic batching with a linger window,
//!   per-request deadlines (expired jobs are skipped before reaching an
//!   engine), per-request error responses and latency accounting,
//!   graceful draining shutdown. Built through [`server::ServerBuilder`]
//!   (which is how the router injects the shared pool);
//! * `EvalService::evaluate` — whole-dataset sweeps used by the figure
//!   harnesses and `sweep::pareto`. Batches shard over a scoped pool by
//!   default, or over a caller-supplied shared [`ComputePool`]
//!   ([`EvalService::with_pool`]) so back-to-back sweeps reuse warm
//!   workers — both paths are bit-identical (results merge in shard
//!   index order either way; property-tested in `rust/tests/sweep.rs`);
//! * `serve_requests` — the legacy one-shot request/response front-end,
//!   kept as a thin compatibility shim over [`server::Server`].

pub mod metrics;
pub mod registry;
pub mod server;

use anyhow::Result;

use std::sync::Arc;

use crate::data::{Batches, Dataset};
use crate::formats::pqsw::PqswModel;
use crate::nn::engine::{Engine, EngineConfig};
use crate::overflow::OverflowReport;
use crate::util::pool::{self, ComputePool};

pub use metrics::{LatencyRecorder, LatencySummary, ServeMetrics, ServeSummary};
pub use registry::{
    BreakerConfig, BreakerSnapshot, ClassifyRequest, ModelHealth, ModelOverrides, ModelRegistry,
    ModelSource, ModelStatus, RouteError, Router, RouterConfig, RouterMetrics, SourceFactory,
    SyntheticSpec,
};
pub use server::{
    PendingResponse, ServeError, ServeResponse, Server, ServerBuilder, ServerConfig, SubmitError,
};

/// Outcome of a coordinated evaluation.
#[derive(Clone, Debug)]
pub struct EvalOutcome {
    pub accuracy: f64,
    pub samples: usize,
    pub report: OverflowReport,
    pub wall_ms: f64,
    pub throughput_ips: f64,
}

/// Evaluation coordinator: fan batches out over engines.
pub struct EvalService<'m> {
    model: &'m PqswModel,
    cfg: EngineConfig,
    threads: usize,
    batch: usize,
    pool: Option<Arc<ComputePool>>,
}

impl<'m> EvalService<'m> {
    pub fn new(model: &'m PqswModel, cfg: EngineConfig) -> Self {
        EvalService { model, cfg, threads: pool::default_threads(), batch: 64, pool: None }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch.max(1);
        self
    }

    /// Shard over `pool`'s persistent workers instead of spawning a
    /// scoped pool per call (`ComputePool::map_init` is bit-identical to
    /// `pool::parallel_map_init`; results merge in shard index order on
    /// both paths). Callers running many evaluations back to back — the
    /// Pareto sweep, the router's bench sections — share one pool so the
    /// fleet's workers stay warm instead of idling.
    pub fn with_pool(mut self, pool: Arc<ComputePool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Evaluate up to `limit` samples of `ds`, sharded over worker engines.
    pub fn evaluate(&self, ds: &Dataset, limit: Option<usize>) -> Result<EvalOutcome> {
        let t0 = std::time::Instant::now();
        // materialize the batch index (start, len)
        let mut shards: Vec<(Vec<f32>, Vec<u8>)> = Vec::new();
        let mut taken = 0usize;
        for (imgs, labels, _s) in Batches::new(ds, self.batch) {
            let mut lab = labels.to_vec();
            let mut im = imgs;
            if let Some(lim) = limit {
                if taken >= lim {
                    break;
                }
                if taken + lab.len() > lim {
                    let keep = lim - taken;
                    lab.truncate(keep);
                    im.truncate(keep * ds.dim());
                }
            }
            taken += lab.len();
            shards.push((im, lab));
        }

        let model = self.model;
        let cfg = self.cfg;
        let init = || Engine::new(model, cfg);
        let work = |eng: &mut Engine, i: usize| {
            let (imgs, labels) = &shards[i];
            let r = eng.forward(imgs, labels.len()).expect("forward");
            let correct = (0..r.batch).filter(|&j| r.argmax(j) == labels[j] as usize).count();
            (correct, labels.len(), r.report)
        };
        // both paths produce results in shard index order, so the merge
        // below is bit-identical regardless of which pool ran the work
        let results = match &self.pool {
            Some(p) => p.map_init(shards.len(), init, work),
            None => pool::parallel_map_init(shards.len(), self.threads, init, work),
        };

        let mut report = OverflowReport::default();
        let (mut correct, mut total) = (0usize, 0usize);
        for (c, n, rep) in &results {
            correct += c;
            total += n;
            report.merge(rep);
        }
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        Ok(EvalOutcome {
            accuracy: correct as f64 / total.max(1) as f64,
            samples: total,
            report,
            wall_ms,
            throughput_ips: total as f64 / (wall_ms / 1e3).max(1e-9),
        })
    }
}

/// A single inference request for the serve front-end.
pub struct Request {
    pub id: u64,
    pub image: Vec<f32>,
}

/// Response of the legacy one-shot front-end.
///
/// `latency_us` is the *per-request* enqueue→response time (queue wait +
/// compute), not the batch's forward time. A malformed request sets
/// `error` (and `class` is meaningless); it never panics the service.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub class: usize,
    pub latency_us: f64,
    pub error: Option<String>,
}

/// Online batched serving over the persistent [`Server`]: drain `requests`,
/// grouping up to `max_batch` per engine invocation (streaming dynamic
/// batching). Returns per-request responses + metrics. Compatibility shim —
/// long-running callers should drive [`Server`] directly.
pub fn serve_requests(
    model: &PqswModel,
    cfg: EngineConfig,
    requests: Vec<Request>,
    max_batch: usize,
    threads: usize,
) -> Result<(Vec<Response>, ServeMetrics)> {
    let threads = threads.max(1);
    let max_batch = max_batch.max(1);
    let scfg = ServerConfig {
        threads,
        max_batch,
        // bounded, but roomy enough that the one-shot path is not the
        // bottleneck; submit() blocks when it fills (backpressure)
        queue_cap: (threads * max_batch * 4).max(64),
        linger: std::time::Duration::from_micros(100),
        engine_threads: 1,
        default_deadline: None,
    };
    let srv = Server::start(model, cfg, scfg);
    let mut pending = Vec::with_capacity(requests.len());
    let mut responses: Vec<Response> = Vec::with_capacity(requests.len());
    for r in requests {
        match srv.submit(r.id, r.image, None) {
            Ok(p) => pending.push(p),
            Err(SubmitError::Full(_)) | Err(SubmitError::Closed(_)) => {
                // cannot happen here (submit blocks; we have not closed),
                // but answer rather than panic if it ever does
                responses.push(Response {
                    id: r.id,
                    class: 0,
                    latency_us: 0.0,
                    error: Some("server rejected the request".into()),
                });
            }
        }
    }
    for p in pending {
        let sr = p.wait();
        let (class, error) = match sr.result {
            Ok(c) => (c, None),
            Err(e) => (0, Some(e.to_string())),
        };
        responses.push(Response { id: sr.id, class, latency_us: sr.latency_us, error });
    }
    let metrics = srv.shutdown();
    responses.sort_by_key(|r| r.id);
    Ok((responses, metrics))
}

#[cfg(test)]
mod tests {
    // Coordinator paths over real models are exercised in
    // rust/tests/coordinator.rs (needs artifacts); artifact-free server
    // tests over synthetic models live in rust/tests/server.rs. Metrics
    // unit tests live in metrics.rs.
}
