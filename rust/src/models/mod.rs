//! Model zoo helpers: locate, load and describe the trained `.pqsw`
//! models exported by the build (DESIGN.md S15).
//!
//! The architectures themselves (mlp1, mlp2, resnet_tiny, mbv2_tiny) are
//! generic graphs — the engine interprets whatever graph the artifact
//! carries, so this module is lookup + summary convenience.

use anyhow::{anyhow, Context, Result};

use crate::formats::manifest::Manifest;
use crate::formats::pqsw::{GraphNode, Op, PqswModel, QLayerMeta};

/// Load a model by manifest name.
///
/// An unknown name fails *before* touching the filesystem, with an error
/// that names the manifest directory and lists the available entries —
/// the multi-model router serves this message verbatim as its 404 body,
/// so a client typo surfaces the fix, not just "not found".
pub fn load(manifest: &Manifest, name: &str) -> Result<PqswModel> {
    if !manifest.models.contains_key(name) {
        let avail = manifest.model_names();
        let listing = if avail.is_empty() {
            "none".to_string()
        } else {
            avail.join(", ")
        };
        return Err(anyhow!(
            "model {name:?} not found in manifest {} (available: {listing})",
            manifest.dir.display(),
        ));
    }
    PqswModel::load(manifest.model_path(name)).with_context(|| format!("loading model {name}"))
}

/// Build a tiny deterministic synthetic model (no artifacts needed): one
/// quantized linear layer `dim -> classes` behind a flatten. The weights
/// are a fixed mixed-sign pattern so predictions depend on the input.
/// Used by `examples/serve.rs`, the serving benches and the artifact-free
/// integration tests to exercise the engine + serving stack end to end.
pub fn synthetic_linear(dim: usize, classes: usize) -> PqswModel {
    let mut wq = Vec::with_capacity(classes * dim);
    for o in 0..classes {
        for k in 0..dim {
            wq.push((((o * 31 + k * 7) % 11) as i8) - 5);
        }
    }
    let q = QLayerMeta {
        name: "fc".into(),
        oc: classes,
        ic: dim,
        kh: 1,
        kw: 1,
        stride: 1,
        pad: 0,
        prune: false,
        w_scale: 0.05,
        x_scale: 1.0 / 255.0,
        x_offset: -128,
        wq: wq.into(),
        k: dim,
        bias: vec![0.0; classes],
    };
    PqswModel {
        name: format!("synthetic_linear_{dim}x{classes}"),
        arch: "mlp1".into(),
        schedule: "pq".into(),
        wbits: 8,
        abits: 8,
        nm_m: 0,
        target_sparsity: 0.0,
        achieved_sparsity: 0.0,
        acc_bits_trained: None,
        lowrank_k: None,
        acc_q: 0.0,
        acc_fp32: 0.0,
        input_shape: vec![1, dim, 1],
        graph: vec![
            GraphNode { id: 0, op: Op::Input, inputs: vec![], q: None },
            GraphNode { id: 1, op: Op::Flatten, inputs: vec![0], q: None },
            GraphNode { id: 2, op: Op::QLinear, inputs: vec![1], q: Some(q) },
        ],
        plan: None,
        checksums: None,
    }
}

/// Build a tiny deterministic synthetic CNN (no artifacts needed):
/// `QConv(3x3, pad 1) -> ReLU -> QDwConv(3x3, pad 1) -> ReLU -> Flatten ->
/// QLinear(classes)`. The graph exercises every parallel split of the
/// engine offline — the conv position loop, the depthwise channel loop and
/// the linear output-row loop — which is what the batch-1 serving path and
/// its benches need on checkouts without artifacts.
pub fn synthetic_conv(c: usize, h: usize, w: usize, oc: usize, classes: usize) -> PqswModel {
    let conv_k = c * 9;
    let wq_conv: Vec<i8> = (0..oc * conv_k).map(|i| ((i * 13 + 5) % 15) as i8 - 7).collect();
    let q_conv = QLayerMeta {
        name: "conv1".into(),
        oc,
        ic: c,
        kh: 3,
        kw: 3,
        stride: 1,
        pad: 1,
        prune: false,
        w_scale: 0.02,
        x_scale: 1.0 / 255.0,
        x_offset: -128,
        wq: wq_conv.into(),
        k: conv_k,
        bias: vec![0.02; oc],
    };
    let wq_dw: Vec<i8> = (0..oc * 9).map(|i| ((i * 7 + 3) % 13) as i8 - 6).collect();
    let q_dw = QLayerMeta {
        name: "dw2".into(),
        oc,
        ic: oc,
        kh: 3,
        kw: 3,
        stride: 1,
        pad: 1,
        prune: false,
        w_scale: 0.03,
        x_scale: 0.02,
        x_offset: -128,
        wq: wq_dw.into(),
        k: 9,
        bias: vec![0.01; oc],
    };
    let fc_k = oc * h * w;
    let wq_fc: Vec<i8> = (0..classes * fc_k).map(|i| ((i * 31 + 11) % 11) as i8 - 5).collect();
    let q_fc = QLayerMeta {
        name: "fc".into(),
        oc: classes,
        ic: fc_k,
        kh: 1,
        kw: 1,
        stride: 1,
        pad: 0,
        prune: false,
        w_scale: 0.05,
        x_scale: 0.05,
        x_offset: -128,
        wq: wq_fc.into(),
        k: fc_k,
        bias: vec![0.0; classes],
    };
    PqswModel {
        name: format!("synthetic_conv_{c}x{h}x{w}_oc{oc}x{classes}"),
        arch: "cnn_tiny".into(),
        schedule: "pq".into(),
        wbits: 8,
        abits: 8,
        nm_m: 0,
        target_sparsity: 0.0,
        achieved_sparsity: 0.0,
        acc_bits_trained: None,
        lowrank_k: None,
        acc_q: 0.0,
        acc_fp32: 0.0,
        input_shape: vec![c, h, w],
        graph: vec![
            GraphNode { id: 0, op: Op::Input, inputs: vec![], q: None },
            GraphNode { id: 1, op: Op::QConv, inputs: vec![0], q: Some(q_conv) },
            GraphNode { id: 2, op: Op::Relu, inputs: vec![1], q: None },
            GraphNode { id: 3, op: Op::QDwConv, inputs: vec![2], q: Some(q_dw) },
            GraphNode { id: 4, op: Op::Relu, inputs: vec![3], q: None },
            GraphNode { id: 5, op: Op::Flatten, inputs: vec![4], q: None },
            GraphNode { id: 6, op: Op::QLinear, inputs: vec![5], q: Some(q_fc) },
        ],
        plan: None,
        checksums: None,
    }
}

/// Human-readable one-line summary.
pub fn describe(m: &PqswModel) -> String {
    let layers = m.q_layers().count();
    let params: usize = m.q_layers().map(|(_, q)| q.wq.len()).sum();
    let dots: Vec<usize> = m.q_layers().map(|(_, q)| q.k).collect();
    format!(
        "{} [{}] {} q-layers, {} weights, sparsity {:.1}%, w{}a{}, dot lengths {:?}, python acc {:.3}",
        m.name,
        m.schedule,
        layers,
        params,
        100.0 * m.achieved_sparsity,
        m.wbits,
        m.abits,
        dots,
        m.acc_q,
    )
}

/// Longest dot product in the model (drives the persistent-overflow
/// threshold K* = 2^(p-2b), paper §3).
pub fn max_dot_length(m: &PqswModel) -> usize {
    m.q_layers().map(|(_, q)| q.k).max().unwrap_or(0)
}

/// Effective (post-pruning) max nonzeros per dot.
pub fn max_effective_dot_length(m: &PqswModel) -> usize {
    m.q_layers()
        .map(|(_, q)| {
            (0..q.oc)
                .map(|o| q.wq[o * q.k..(o + 1) * q.k].iter().filter(|&&v| v != 0).count())
                .max()
                .unwrap_or(0)
        })
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    // manifest-backed paths are exercised end-to-end by
    // rust/tests/artifacts.rs against real models
    use super::*;

    #[test]
    fn synthetic_model_is_well_formed() {
        let m = synthetic_linear(64, 10);
        assert_eq!(m.q_layers().count(), 1);
        let (_, q) = m.q_layers().next().unwrap();
        assert_eq!(q.wq.len(), 640);
        assert_eq!(max_dot_length(&m), 64);
        assert!(max_effective_dot_length(&m) <= 64);
        assert_eq!(m.input_shape.iter().product::<usize>(), 64);
        // engine accepts it
        let mut eng = crate::nn::Engine::new(&m, crate::nn::EngineConfig::default());
        let out = eng.forward(&vec![0.5; 2 * 64], 2).unwrap();
        assert_eq!(out.classes, 10);
        assert_eq!(out.logits.len(), 20);
    }

    #[test]
    fn load_unknown_model_names_manifest_dir_and_entries() {
        let dir = std::env::temp_dir().join("pqs_test_models_load_err");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"models":[{"name":"mlp1_w8a8","file":"mlp1_w8a8.pqsw","arch":"mlp1",
                          "schedule":"pq"}]}"#,
        )
        .unwrap();
        let man = Manifest::load_dir(&dir).unwrap();
        let err = format!("{:#}", load(&man, "mlp1_w9a9").unwrap_err());
        assert!(err.contains("mlp1_w9a9"), "names the miss: {err}");
        assert!(err.contains("mlp1_w8a8"), "lists the available entries: {err}");
        assert!(err.contains("pqs_test_models_load_err"), "names the manifest dir: {err}");
    }

    #[test]
    fn synthetic_conv_is_well_formed() {
        let m = synthetic_conv(2, 8, 8, 4, 10);
        assert_eq!(m.q_layers().count(), 3);
        assert_eq!(m.input_shape.iter().product::<usize>(), 2 * 8 * 8);
        let mut eng = crate::nn::Engine::new(&m, crate::nn::EngineConfig::default());
        let out = eng.forward(&vec![0.5; 2 * 8 * 8], 1).unwrap();
        assert_eq!(out.classes, 10);
        assert_eq!(out.logits.len(), 10);
        // predictions depend on the input (weights are mixed-sign)
        let mut rng = crate::util::rng::Pcg32::new(3);
        let img: Vec<f32> = (0..2 * 8 * 8).map(|_| rng.f32()).collect();
        let out2 = eng.forward(&img, 1).unwrap();
        assert_ne!(out.logits, out2.logits);
    }
}
