//! Model zoo helpers: locate, load and describe the trained `.pqsw`
//! models exported by the build (DESIGN.md S15).
//!
//! The architectures themselves (mlp1, mlp2, resnet_tiny, mbv2_tiny) are
//! generic graphs — the engine interprets whatever graph the artifact
//! carries, so this module is lookup + summary convenience.

use anyhow::{Context, Result};

use crate::formats::manifest::Manifest;
use crate::formats::pqsw::PqswModel;

/// Load a model by manifest name.
pub fn load(manifest: &Manifest, name: &str) -> Result<PqswModel> {
    PqswModel::load(manifest.model_path(name)).with_context(|| format!("loading model {name}"))
}

/// Human-readable one-line summary.
pub fn describe(m: &PqswModel) -> String {
    let layers = m.q_layers().count();
    let params: usize = m.q_layers().map(|(_, q)| q.wq.len()).sum();
    let dots: Vec<usize> = m.q_layers().map(|(_, q)| q.k).collect();
    format!(
        "{} [{}] {} q-layers, {} weights, sparsity {:.1}%, w{}a{}, dot lengths {:?}, python acc {:.3}",
        m.name,
        m.schedule,
        layers,
        params,
        100.0 * m.achieved_sparsity,
        m.wbits,
        m.abits,
        dots,
        m.acc_q,
    )
}

/// Longest dot product in the model (drives the persistent-overflow
/// threshold K* = 2^(p-2b), paper §3).
pub fn max_dot_length(m: &PqswModel) -> usize {
    m.q_layers().map(|(_, q)| q.k).max().unwrap_or(0)
}

/// Effective (post-pruning) max nonzeros per dot.
pub fn max_effective_dot_length(m: &PqswModel) -> usize {
    m.q_layers()
        .map(|(_, q)| {
            (0..q.oc)
                .map(|o| q.wq[o * q.k..(o + 1) * q.k].iter().filter(|&&v| v != 0).count())
                .max()
                .unwrap_or(0)
        })
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    // exercised end-to-end by rust/tests/artifacts.rs against real models
}
