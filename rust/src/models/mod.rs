//! Model zoo helpers: locate, load and describe the trained `.pqsw`
//! models exported by the build (DESIGN.md S15).
//!
//! The architectures themselves (mlp1, mlp2, resnet_tiny, mbv2_tiny) are
//! generic graphs — the engine interprets whatever graph the artifact
//! carries, so this module is lookup + summary convenience.

use anyhow::{Context, Result};

use crate::formats::manifest::Manifest;
use crate::formats::pqsw::{GraphNode, Op, PqswModel, QLayerMeta};

/// Load a model by manifest name.
pub fn load(manifest: &Manifest, name: &str) -> Result<PqswModel> {
    PqswModel::load(manifest.model_path(name)).with_context(|| format!("loading model {name}"))
}

/// Build a tiny deterministic synthetic model (no artifacts needed): one
/// quantized linear layer `dim -> classes` behind a flatten. The weights
/// are a fixed mixed-sign pattern so predictions depend on the input.
/// Used by `examples/serve.rs`, the serving benches and the artifact-free
/// integration tests to exercise the engine + serving stack end to end.
pub fn synthetic_linear(dim: usize, classes: usize) -> PqswModel {
    let mut wq = Vec::with_capacity(classes * dim);
    for o in 0..classes {
        for k in 0..dim {
            wq.push((((o * 31 + k * 7) % 11) as i8) - 5);
        }
    }
    let q = QLayerMeta {
        name: "fc".into(),
        oc: classes,
        ic: dim,
        kh: 1,
        kw: 1,
        stride: 1,
        pad: 0,
        prune: false,
        w_scale: 0.05,
        x_scale: 1.0 / 255.0,
        x_offset: -128,
        wq,
        k: dim,
        bias: vec![0.0; classes],
    };
    PqswModel {
        name: format!("synthetic_linear_{dim}x{classes}"),
        arch: "mlp1".into(),
        schedule: "pq".into(),
        wbits: 8,
        abits: 8,
        nm_m: 0,
        target_sparsity: 0.0,
        achieved_sparsity: 0.0,
        acc_bits_trained: None,
        lowrank_k: None,
        acc_q: 0.0,
        acc_fp32: 0.0,
        input_shape: vec![1, dim, 1],
        graph: vec![
            GraphNode { id: 0, op: Op::Input, inputs: vec![], q: None },
            GraphNode { id: 1, op: Op::Flatten, inputs: vec![0], q: None },
            GraphNode { id: 2, op: Op::QLinear, inputs: vec![1], q: Some(q) },
        ],
    }
}

/// Human-readable one-line summary.
pub fn describe(m: &PqswModel) -> String {
    let layers = m.q_layers().count();
    let params: usize = m.q_layers().map(|(_, q)| q.wq.len()).sum();
    let dots: Vec<usize> = m.q_layers().map(|(_, q)| q.k).collect();
    format!(
        "{} [{}] {} q-layers, {} weights, sparsity {:.1}%, w{}a{}, dot lengths {:?}, python acc {:.3}",
        m.name,
        m.schedule,
        layers,
        params,
        100.0 * m.achieved_sparsity,
        m.wbits,
        m.abits,
        dots,
        m.acc_q,
    )
}

/// Longest dot product in the model (drives the persistent-overflow
/// threshold K* = 2^(p-2b), paper §3).
pub fn max_dot_length(m: &PqswModel) -> usize {
    m.q_layers().map(|(_, q)| q.k).max().unwrap_or(0)
}

/// Effective (post-pruning) max nonzeros per dot.
pub fn max_effective_dot_length(m: &PqswModel) -> usize {
    m.q_layers()
        .map(|(_, q)| {
            (0..q.oc)
                .map(|o| q.wq[o * q.k..(o + 1) * q.k].iter().filter(|&&v| v != 0).count())
                .max()
                .unwrap_or(0)
        })
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    // manifest-backed paths are exercised end-to-end by
    // rust/tests/artifacts.rs against real models
    use super::*;

    #[test]
    fn synthetic_model_is_well_formed() {
        let m = synthetic_linear(64, 10);
        assert_eq!(m.q_layers().count(), 1);
        let (_, q) = m.q_layers().next().unwrap();
        assert_eq!(q.wq.len(), 640);
        assert_eq!(max_dot_length(&m), 64);
        assert!(max_effective_dot_length(&m) <= 64);
        assert_eq!(m.input_shape.iter().product::<usize>(), 64);
        // engine accepts it
        let mut eng = crate::nn::Engine::new(&m, crate::nn::EngineConfig::default());
        let out = eng.forward(&vec![0.5; 2 * 64], 2).unwrap();
        assert_eq!(out.classes, 10);
        assert_eq!(out.logits.len(), 20);
    }
}
