//! Unstructured CSR baseline (paper §2.2, refs [9][35]).
//!
//! Functionally equivalent to `NmMatrix` but with u32 column indices and no
//! group structure — used by `bench_sparse` to reproduce the paper's
//! argument that unstructured formats pay index-storage and irregular-access
//! overheads that N:M avoids.

/// Compressed sparse row matrix over i8 values.
#[derive(Clone, Debug)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub val: Vec<i8>,
}

impl CsrMatrix {
    pub fn from_dense(dense: &[i8], rows: usize, cols: usize) -> Self {
        assert_eq!(dense.len(), rows * cols);
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::new();
        let mut val = Vec::new();
        row_ptr.push(0u32);
        for r in 0..rows {
            for c in 0..cols {
                let v = dense[r * cols + c];
                if v != 0 {
                    col_idx.push(c as u32);
                    val.push(v);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        CsrMatrix { rows, cols, row_ptr, col_idx, val }
    }

    pub fn nnz(&self) -> usize {
        self.val.len()
    }

    /// SpMV in exact i64 arithmetic: y = A x (x dense, len cols).
    pub fn spmv_exact(&self, x: &[i32], y: &mut Vec<i64>) {
        debug_assert_eq!(x.len(), self.cols);
        y.clear();
        y.reserve(self.rows);
        for r in 0..self.rows {
            let a = self.row_ptr[r] as usize;
            let b = self.row_ptr[r + 1] as usize;
            let mut acc = 0i64;
            for i in a..b {
                acc += self.val[i] as i64 * x[self.col_idx[i] as usize] as i64;
            }
            y.push(acc);
        }
    }

    /// Index + pointer storage overhead in bytes (the dCSR complaint).
    pub fn footprint_bytes(&self) -> usize {
        self.val.len() + 4 * self.col_idx.len() + 4 * self.row_ptr.len()
    }

    pub fn to_dense(&self) -> Vec<i8> {
        let mut out = vec![0i8; self.rows * self.cols];
        for r in 0..self.rows {
            for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                out[r * self.cols + self.col_idx[i] as usize] = self.val[i];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::nm::NmMatrix;
    use crate::util::rng::Pcg32;

    fn random_dense(rng: &mut Pcg32, rows: usize, cols: usize, density: f64) -> Vec<i8> {
        (0..rows * cols)
            .map(|_| {
                if rng.f64() < density {
                    let v = rng.range_i64(-127, 127) as i8;
                    if v == 0 {
                        3
                    } else {
                        v
                    }
                } else {
                    0
                }
            })
            .collect()
    }

    #[test]
    fn roundtrip() {
        let mut rng = Pcg32::new(8);
        let d = random_dense(&mut rng, 7, 33, 0.3);
        let csr = CsrMatrix::from_dense(&d, 7, 33);
        assert_eq!(csr.to_dense(), d);
    }

    #[test]
    fn spmv_matches_dense() {
        let mut rng = Pcg32::new(9);
        let d = random_dense(&mut rng, 5, 40, 0.25);
        let x = rng.ivec(40, -100, 100);
        let csr = CsrMatrix::from_dense(&d, 5, 40);
        let mut y = Vec::new();
        csr.spmv_exact(&x, &mut y);
        for r in 0..5 {
            let want: i64 = (0..40).map(|c| d[r * 40 + c] as i64 * x[c] as i64).sum();
            assert_eq!(y[r], want);
        }
    }

    #[test]
    fn csr_footprint_larger_than_nm() {
        // the paper's §2.2 point: 4-byte indices make unstructured sparse
        // formats heavier than semi-structured ones at equal nnz
        let mut rng = Pcg32::new(10);
        let d = random_dense(&mut rng, 16, 256, 0.125);
        let csr = CsrMatrix::from_dense(&d, 16, 256);
        let nm = NmMatrix::from_dense(&d, 16, 256, 16);
        assert_eq!(csr.nnz(), nm.nnz());
        assert!(csr.footprint_bytes() > nm.footprint_bytes() - 4 * (nm.rows + 1));
    }
}
