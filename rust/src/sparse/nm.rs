//! N:M semi-structured sparse weight matrix (paper §2.2).
//!
//! Built from the dense int8 rows exported in `.pqsw` files (zeros are the
//! pruned positions). Storage keeps, per row, the nonzero (column, value)
//! pairs in column order — since N:M sparsity bounds nonzeros per group,
//! indices within a group fit a u8 and the structure is predictable; we
//! store absolute u16 columns for simplicity (K <= 65535 everywhere).
//!
//! `dot_products_into` emits only the partial products of *nonzero* weights:
//! pruning shortens the dot products the accumulator sees, which is exactly
//! how PQS reduces persistent overflows (paper §3.1).

/// One sparse row-major weight matrix (O rows, K columns).
#[derive(Clone, Debug)]
pub struct NmMatrix {
    pub rows: usize,
    pub cols: usize,
    /// group size M used at pruning time (metadata; 0 = unknown/dense)
    pub m: usize,
    /// per-row start offsets into idx/val (len rows+1)
    pub row_ptr: Vec<u32>,
    pub idx: Vec<u16>,
    pub val: Vec<i8>,
    /// per-row sum of weights (for the o_x * sum(w) dequant correction)
    pub row_wsum: Vec<i32>,
}

impl NmMatrix {
    /// Build from a dense row-major i8 matrix; zeros become implicit.
    pub fn from_dense(dense: &[i8], rows: usize, cols: usize, m: usize) -> Self {
        assert_eq!(dense.len(), rows * cols);
        assert!(cols <= u16::MAX as usize + 1, "cols too large for u16 indices");
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut idx = Vec::new();
        let mut val = Vec::new();
        let mut row_wsum = Vec::with_capacity(rows);
        row_ptr.push(0u32);
        for r in 0..rows {
            let mut wsum = 0i32;
            for c in 0..cols {
                let v = dense[r * cols + c];
                wsum += v as i32;
                if v != 0 {
                    idx.push(c as u16);
                    val.push(v);
                }
            }
            row_wsum.push(wsum);
            row_ptr.push(idx.len() as u32);
        }
        NmMatrix { rows, cols, m, row_ptr, idx, val, row_wsum }
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.val.len()
    }

    /// Achieved sparsity fraction.
    pub fn sparsity(&self) -> f64 {
        if self.rows * self.cols == 0 {
            return 0.0;
        }
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Nonzeros of one row as (columns, values).
    #[inline]
    pub fn row(&self, r: usize) -> (&[u16], &[i8]) {
        let a = self.row_ptr[r] as usize;
        let b = self.row_ptr[r + 1] as usize;
        (&self.idx[a..b], &self.val[a..b])
    }

    /// Emit the partial products of row `r` against activation vector `x`
    /// (length `cols`) into `out` — only nonzero-weight positions.
    #[inline]
    pub fn dot_products_into(&self, r: usize, x: &[i32], out: &mut Vec<i32>) {
        debug_assert_eq!(x.len(), self.cols);
        let (cols, vals) = self.row(r);
        out.clear();
        out.reserve(cols.len());
        for (c, v) in cols.iter().zip(vals) {
            out.push(*v as i32 * x[*c as usize]);
        }
    }

    /// Fused exact dot product of row `r` with `x` (no product buffer) —
    /// the engine's hot path for the Exact/Sorted/Oracle policies.
    #[inline]
    pub fn dot_exact(&self, r: usize, x: &[i32]) -> i64 {
        let (cols, vals) = self.row(r);
        let mut acc = 0i64;
        for (c, v) in cols.iter().zip(vals) {
            acc += (*v as i32 * x[*c as usize]) as i64;
        }
        acc
    }

    /// Fused saturating accumulation in index order (policy Clip).
    /// Returns (value, overflow events). Identical semantics to
    /// `accum::clip_accumulate` over the nonzero products.
    #[inline]
    pub fn dot_clip(&self, r: usize, x: &[i32], p: u32) -> (i64, u32) {
        let (lo, hi) = crate::accum::acc_range(p);
        let (cols, vals) = self.row(r);
        let mut acc = 0i64;
        let mut ovf = 0u32;
        for (c, v) in cols.iter().zip(vals) {
            let t = acc + (*v as i32 * x[*c as usize]) as i64;
            acc = if t < lo {
                ovf += 1;
                lo
            } else if t > hi {
                ovf += 1;
                hi
            } else {
                t
            };
        }
        (acc, ovf)
    }

    /// Verify the N:M structural invariant: each consecutive group of M has
    /// at most `max_keep` nonzeros. Returns worst group occupancy.
    pub fn check_group_bound(&self, max_keep: usize) -> Result<usize, String> {
        if self.m == 0 {
            return Ok(0);
        }
        let mut worst = 0usize;
        for r in 0..self.rows {
            let (cols, _) = self.row(r);
            let mut i = 0;
            while i < cols.len() {
                let g = cols[i] as usize / self.m;
                let mut n = 0;
                while i < cols.len() && (cols[i] as usize) / self.m == g {
                    n += 1;
                    i += 1;
                }
                worst = worst.max(n);
                if n > max_keep {
                    return Err(format!("row {r} group {g} has {n} > {max_keep} nonzeros"));
                }
            }
        }
        Ok(worst)
    }

    /// Dense reconstruction (tests).
    pub fn to_dense(&self) -> Vec<i8> {
        let mut out = vec![0i8; self.rows * self.cols];
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                out[r * self.cols + *c as usize] = *v;
            }
        }
        out
    }

    /// Approximate in-memory footprint in bytes (values + indices + ptrs).
    pub fn footprint_bytes(&self) -> usize {
        self.val.len() + 2 * self.idx.len() + 4 * self.row_ptr.len() + 4 * self.row_wsum.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    fn random_nm(rng: &mut Pcg32, rows: usize, cols: usize, m: usize, keep: usize) -> Vec<i8> {
        let mut dense = vec![0i8; rows * cols];
        for r in 0..rows {
            for g0 in (0..cols).step_by(m) {
                let glen = m.min(cols - g0);
                let mut positions: Vec<usize> = (0..glen).collect();
                rng.shuffle(&mut positions);
                for &p in positions.iter().take(keep.min(glen)) {
                    let mut v = rng.range_i64(-127, 127) as i8;
                    if v == 0 {
                        v = 1;
                    }
                    dense[r * cols + g0 + p] = v;
                }
            }
        }
        dense
    }

    #[test]
    fn roundtrip_dense() {
        prop::check(
            "nm-roundtrip",
            50,
            |r: &mut Pcg32| random_nm(r, 4, 32, 8, 3),
            |dense| {
                let nm = NmMatrix::from_dense(dense, 4, 32, 8);
                if nm.to_dense() != *dense {
                    return Err("roundtrip mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn group_bound_checked() {
        let mut rng = Pcg32::new(5);
        let dense = random_nm(&mut rng, 8, 64, 16, 4);
        let nm = NmMatrix::from_dense(&dense, 8, 64, 16);
        assert!(nm.check_group_bound(4).is_ok());
        assert!(nm.check_group_bound(0).is_err() || nm.nnz() == 0);
    }

    #[test]
    fn sparsity_and_nnz() {
        let dense = vec![0i8, 5, 0, 0, -3, 0, 0, 0];
        let nm = NmMatrix::from_dense(&dense, 2, 4, 4);
        assert_eq!(nm.nnz(), 2);
        assert!((nm.sparsity() - 0.75).abs() < 1e-12);
        assert_eq!(nm.row_wsum, vec![5, -3]);
    }

    #[test]
    fn products_skip_zeros() {
        let dense = vec![2i8, 0, -1, 0];
        let nm = NmMatrix::from_dense(&dense, 1, 4, 4);
        let mut out = Vec::new();
        nm.dot_products_into(0, &[10, 20, 30, 40], &mut out);
        assert_eq!(out, vec![20, -30]);
    }

    #[test]
    fn sparse_dot_equals_dense_dot() {
        prop::check(
            "nm-dot-matches-dense",
            100,
            |r: &mut Pcg32| {
                let dense = random_nm(r, 3, 48, 16, 5);
                let x = r.ivec(48, -128, 127);
                (dense, x)
            },
            |(dense, x)| {
                let nm = NmMatrix::from_dense(dense, 3, 48, 16);
                let mut out = Vec::new();
                for r in 0..3 {
                    nm.dot_products_into(r, x, &mut out);
                    let sp: i64 = out.iter().map(|&v| v as i64).sum();
                    let dn: i64 = (0..48)
                        .map(|c| dense[r * 48 + c] as i64 * x[c] as i64)
                        .sum();
                    if sp != dn {
                        return Err(format!("row {r}: {sp} != {dn}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn footprint_smaller_when_sparse() {
        let mut rng = Pcg32::new(6);
        let sparse = random_nm(&mut rng, 16, 256, 16, 2); // 87.5% sparse
        let nm = NmMatrix::from_dense(&sparse, 16, 256, 16);
        assert!(nm.footprint_bytes() < 16 * 256); // beats dense i8
    }
}
