//! Sparse weight formats (DESIGN.md S12/S13).
//!
//! * `nm` — the paper's N:M semi-structured format: within each consecutive
//!   group of M weights along the contraction axis only a bounded number are
//!   nonzero; storage is (group -> [ (idx_in_group, value) ]) flattened with
//!   per-row offsets. Predictable structure, cheap skipping.
//! * `csr` — classic unstructured CSR baseline for the overhead comparison
//!   the paper makes in §2.2.

pub mod csr;
pub mod nm;

pub use csr::CsrMatrix;
pub use nm::NmMatrix;

/// Fraction of zero entries in a dense row-major matrix.
pub fn density_stats(w: &[i8]) -> (usize, usize) {
    let nz = w.iter().filter(|&&v| v != 0).count();
    (nz, w.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn density() {
        assert_eq!(density_stats(&[0, 1, 0, -3]), (2, 4));
        assert_eq!(density_stats(&[]), (0, 0));
    }
}
