//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a seeded source of *injected* failures threaded
//! through the seams where real ones happen: model loads (I/O errors,
//! slow disks, bit-flip corruption of weight bytes), engine forwards
//! (panics), and socket accepts (resets). Everything a plan does is
//! driven by one [`Pcg32`](crate::util::rng::Pcg32) stream seeded from
//! [`FaultSpec::seed`], so a chaos run replays from its seed: the same
//! decision sequence fires in the same call order.
//!
//! The plan is carried as an `Option<Arc<FaultPlan>>` on
//! [`RouterConfig`](crate::coordinator::RouterConfig); when `None`
//! (the default, and the only state production configs should ship)
//! every seam is a skipped `if let` — zero work, zero allocation. When
//! armed, each seam draws from the shared stream and counts what it
//! injected, so a soak can assert "everything the plan fired was
//! observed downstream" ([`FaultPlan::counts`]).
//!
//! The CLI exposes this as `pqs serve-http --fault-seed N
//! --fault-spec "load_error=0.5,panic_every=100,..."` (see
//! [`FaultSpec::parse`]); `rust/tests/chaos.rs` is the canonical
//! consumer.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::formats::pqsw::PqswModel;
use crate::util::rng::Pcg32;

/// What a [`FaultPlan`] may inject, with what probability.
///
/// Probabilities are per-event in `[0, 1]`; `panic_every` is a period
/// (every Nth forward panics, `0` = never).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    /// seed for the shared decision stream
    pub seed: u64,
    /// probability a model load fails with an injected I/O error
    pub load_error: f64,
    /// probability a model load sleeps `load_delay` first
    pub slow_load: f64,
    /// how long an injected slow load sleeps
    pub load_delay: Duration,
    /// probability a *successful* load comes back with one weight bit
    /// flipped (caught by `.pqsw` checksum verification → quarantine)
    pub corrupt: f64,
    /// panic on every Nth engine forward (0 = never)
    pub panic_every: u64,
    /// probability an accepted connection is reset before being read
    pub accept_reset: f64,
}

impl Default for FaultSpec {
    fn default() -> FaultSpec {
        FaultSpec {
            seed: 0x5EED_FA17,
            load_error: 0.0,
            slow_load: 0.0,
            load_delay: Duration::from_millis(10),
            corrupt: 0.0,
            panic_every: 0,
            accept_reset: 0.0,
        }
    }
}

impl FaultSpec {
    /// Parse a `--fault-spec` string: comma-separated `key=value` pairs.
    ///
    /// Keys: `seed=N`, `load_error=P`, `slow_load=P`, `load_delay_ms=N`,
    /// `corrupt=P`, `panic_every=N`, `accept_reset=P`. Unknown keys fail
    /// listing the supported ones (same contract as `--model` options).
    pub fn parse(spec: &str) -> Result<FaultSpec> {
        let mut out = FaultSpec::default();
        for kv in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (key, val) = kv
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault-spec option {kv:?} is not key=value"))?;
            let prob = |v: &str| -> Result<f64> {
                let p: f64 = v.parse().map_err(|_| anyhow::anyhow!("bad probability {v:?}"))?;
                if !(0.0..=1.0).contains(&p) {
                    bail!("probability {p} outside [0, 1]");
                }
                Ok(p)
            };
            match key {
                "seed" => out.seed = val.parse()?,
                "load_error" => out.load_error = prob(val)?,
                "slow_load" => out.slow_load = prob(val)?,
                "load_delay_ms" => out.load_delay = Duration::from_millis(val.parse()?),
                "corrupt" => out.corrupt = prob(val)?,
                "panic_every" => out.panic_every = val.parse()?,
                "accept_reset" => out.accept_reset = prob(val)?,
                other => bail!(
                    "unknown fault-spec option {other:?} (supported: seed=N, load_error=P, \
                     slow_load=P, load_delay_ms=N, corrupt=P, panic_every=N, accept_reset=P)"
                ),
            }
        }
        Ok(out)
    }

    /// True when nothing can ever fire (the all-zero spec).
    pub fn is_noop(&self) -> bool {
        self.load_error == 0.0
            && self.slow_load == 0.0
            && self.corrupt == 0.0
            && self.panic_every == 0
            && self.accept_reset == 0.0
    }
}

/// What [`FaultPlan::on_load`] decided for one load attempt.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LoadDecision {
    /// sleep this long before loading (injected slow disk)
    pub delay: Option<Duration>,
    /// fail the load with an injected I/O error
    pub error: bool,
    /// flip one weight bit in the loaded model (injected corruption)
    pub corrupt: bool,
}

/// Counters of everything a plan actually injected.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounts {
    pub load_errors: u64,
    pub slow_loads: u64,
    pub corruptions: u64,
    pub panics: u64,
    pub resets: u64,
}

impl FaultCounts {
    pub fn total(&self) -> u64 {
        self.load_errors + self.slow_loads + self.corruptions + self.panics + self.resets
    }
}

/// A live, seeded fault injector (see the module docs).
///
/// Thread-safe: decisions serialize on one internal RNG so the stream
/// stays a pure function of the seed and the call sequence.
pub struct FaultPlan {
    spec: FaultSpec,
    rng: Mutex<Pcg32>,
    armed: AtomicBool,
    forwards: AtomicU64,
    load_errors: AtomicU64,
    slow_loads: AtomicU64,
    corruptions: AtomicU64,
    panics: AtomicU64,
    resets: AtomicU64,
}

impl FaultPlan {
    pub fn new(spec: FaultSpec) -> FaultPlan {
        FaultPlan {
            rng: Mutex::new(Pcg32::new(spec.seed)),
            spec,
            armed: AtomicBool::new(true),
            forwards: AtomicU64::new(0),
            load_errors: AtomicU64::new(0),
            slow_loads: AtomicU64::new(0),
            corruptions: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            resets: AtomicU64::new(0),
        }
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Stop injecting (the chaos soak's "faults end, fleet must recover"
    /// phase). Counters keep their values.
    pub fn disarm(&self) {
        self.armed.store(false, Ordering::SeqCst);
    }

    pub fn rearm(&self) {
        self.armed.store(true, Ordering::SeqCst);
    }

    pub fn armed(&self) -> bool {
        self.armed.load(Ordering::SeqCst)
    }

    /// Decide the fate of one model-load attempt. Always burns the same
    /// three draws so the stream doesn't depend on which probabilities
    /// are zero.
    pub fn on_load(&self) -> LoadDecision {
        if !self.armed() {
            return LoadDecision::default();
        }
        let (u_slow, u_err, u_cor) = {
            let mut rng = self.rng.lock().unwrap();
            (rng.f64(), rng.f64(), rng.f64())
        };
        let d = LoadDecision {
            delay: (u_slow < self.spec.slow_load).then_some(self.spec.load_delay),
            error: u_err < self.spec.load_error,
            corrupt: u_cor < self.spec.corrupt,
        };
        if d.delay.is_some() {
            self.slow_loads.fetch_add(1, Ordering::SeqCst);
        }
        if d.error {
            self.load_errors.fetch_add(1, Ordering::SeqCst);
        }
        if d.corrupt && !d.error {
            self.corruptions.fetch_add(1, Ordering::SeqCst);
        }
        d
    }

    /// Flip one pseudo-random bit in one q-layer's weights. The model is
    /// given fresh checksums *first* (when it carries none), so the
    /// corruption is detectable by [`PqswModel::verify_integrity`]
    /// exactly as post-checksum file corruption would be.
    pub fn corrupt_model(&self, model: &mut PqswModel) {
        if model.checksums.is_none() {
            model.attach_checksums();
        }
        model.materialize(); // borrowed views are immutable shared bytes
        let layers: Vec<usize> = model
            .graph
            .iter()
            .enumerate()
            .filter(|(_, n)| n.q.is_some())
            .map(|(i, _)| i)
            .collect();
        if layers.is_empty() {
            return;
        }
        let (li, byte, bit) = {
            let mut rng = self.rng.lock().unwrap();
            let li = layers[rng.below(layers.len() as u32) as usize];
            let len = model.graph[li].q.as_ref().unwrap().wq.len().max(1);
            (li, rng.below_u64(len as u64) as usize, rng.below(8) as u8)
        };
        let q = model.graph[li].q.as_mut().unwrap();
        let mut w = q.wq.to_owned_vec();
        if let Some(v) = w.get_mut(byte) {
            *v = (*v as u8 ^ (1 << bit)) as i8;
        }
        q.wq = w.into();
    }

    /// Count and raise an injected engine panic when this is the Nth
    /// forward. Call from inside the coordinator's `catch_unwind` scope.
    pub fn before_forward(&self) {
        if self.spec.panic_every == 0 || !self.armed() {
            return;
        }
        let n = self.forwards.fetch_add(1, Ordering::SeqCst) + 1;
        if n % self.spec.panic_every == 0 {
            self.panics.fetch_add(1, Ordering::SeqCst);
            panic!("injected fault: engine panic on forward #{n}");
        }
    }

    /// Should this freshly accepted connection be reset before reading?
    pub fn reset_accept(&self) -> bool {
        if self.spec.accept_reset == 0.0 || !self.armed() {
            return false;
        }
        let hit = self.rng.lock().unwrap().f64() < self.spec.accept_reset;
        if hit {
            self.resets.fetch_add(1, Ordering::SeqCst);
        }
        hit
    }

    /// Snapshot of everything injected so far.
    pub fn counts(&self) -> FaultCounts {
        FaultCounts {
            load_errors: self.load_errors.load(Ordering::SeqCst),
            slow_loads: self.slow_loads.load(Ordering::SeqCst),
            corruptions: self.corruptions.load(Ordering::SeqCst),
            panics: self.panics.load(Ordering::SeqCst),
            resets: self.resets.load(Ordering::SeqCst),
        }
    }
}

impl std::fmt::Debug for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultPlan")
            .field("spec", &self.spec)
            .field("armed", &self.armed())
            .field("counts", &self.counts())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_every_key_and_rejects_unknowns() {
        let s = FaultSpec::parse(
            "seed=7, load_error=0.5, slow_load=0.25, load_delay_ms=3, corrupt=0.1, \
             panic_every=100, accept_reset=0.05",
        )
        .unwrap();
        assert_eq!(s.seed, 7);
        assert_eq!(s.load_error, 0.5);
        assert_eq!(s.slow_load, 0.25);
        assert_eq!(s.load_delay, Duration::from_millis(3));
        assert_eq!(s.corrupt, 0.1);
        assert_eq!(s.panic_every, 100);
        assert_eq!(s.accept_reset, 0.05);
        assert!(!s.is_noop());
        assert!(FaultSpec::parse("").unwrap().is_noop());

        let err = format!("{:#}", FaultSpec::parse("frobnicate=1").unwrap_err());
        assert!(err.contains("frobnicate") && err.contains("panic_every"), "{err}");
        assert!(FaultSpec::parse("load_error=1.5").is_err(), "probability range enforced");
        assert!(FaultSpec::parse("load_error").is_err(), "key=value enforced");
    }

    #[test]
    fn decisions_replay_from_the_seed() {
        let spec = FaultSpec { load_error: 0.4, slow_load: 0.3, corrupt: 0.2, ..Default::default() };
        let a = FaultPlan::new(spec);
        let b = FaultPlan::new(spec);
        let da: Vec<LoadDecision> = (0..256).map(|_| a.on_load()).collect();
        let db: Vec<LoadDecision> = (0..256).map(|_| b.on_load()).collect();
        assert_eq!(da, db, "same seed, same decision stream");
        assert!(da.iter().any(|d| d.error) && da.iter().any(|d| !d.error));
        assert_eq!(a.counts(), b.counts());
        assert_eq!(a.counts().load_errors, da.iter().filter(|d| d.error).count() as u64);

        let c = FaultPlan::new(FaultSpec { seed: spec.seed + 1, ..spec });
        let dc: Vec<LoadDecision> = (0..256).map(|_| c.on_load()).collect();
        assert_ne!(da, dc, "different seed, different stream");
    }

    #[test]
    fn disarm_silences_every_seam() {
        let plan = FaultPlan::new(FaultSpec {
            load_error: 1.0,
            slow_load: 1.0,
            corrupt: 1.0,
            panic_every: 1,
            accept_reset: 1.0,
            ..Default::default()
        });
        plan.disarm();
        assert_eq!(plan.on_load(), LoadDecision::default());
        assert!(!plan.reset_accept());
        plan.before_forward(); // would panic if armed
        assert_eq!(plan.counts().total(), 0);
        plan.rearm();
        assert!(plan.on_load().error);
    }

    #[test]
    fn panic_every_fires_on_schedule() {
        let plan = FaultPlan::new(FaultSpec { panic_every: 3, ..Default::default() });
        let mut panicked = Vec::new();
        for i in 1..=9u64 {
            let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                plan.before_forward();
            }))
            .is_err();
            if hit {
                panicked.push(i);
            }
        }
        assert_eq!(panicked, vec![3, 6, 9]);
        assert_eq!(plan.counts().panics, 3);
    }

    #[test]
    fn corrupt_model_flips_exactly_one_bit_and_checksums_catch_it() {
        let plan = FaultPlan::new(FaultSpec::default());
        let pristine = crate::models::synthetic_linear(16, 4);
        let mut model = pristine.clone();
        plan.corrupt_model(&mut model);
        assert!(model.checksums.is_some(), "corruption attaches pristine checksums first");
        assert_ne!(model.content_hash(), pristine.content_hash(), "a weight changed");
        let err = format!("{:#}", model.verify_integrity().unwrap_err());
        assert!(err.contains("checksum mismatch"), "{err}");
    }
}
