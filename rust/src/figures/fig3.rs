//! Figure 3 — P->Q vs Q->P under low-rank weight approximation
//! (2-layer MLP, N:M with M=32).
//!
//! The comparison is a *training-schedule* property, so the accuracies come
//! from the python QAT runs recorded in the manifest; the rust engine
//! re-verifies a subset end-to-end (wide accumulator) to confirm the
//! exported artifacts reproduce the python numbers.

use anyhow::Result;

use crate::accum::Policy;
use crate::coordinator::EvalService;
use crate::formats::manifest::{Manifest, ModelEntry};
use crate::models;
use crate::nn::engine::EngineConfig;

#[derive(Clone, Debug)]
pub struct Fig3Row {
    pub schedule: String,
    pub rank: String,
    pub sparsity: f64,
    pub acc_python: f64,
    /// engine accuracy at wide accumulator (verification; NaN if skipped)
    pub acc_rust: f64,
}

pub fn run(man: &Manifest, limit: usize, verify_every: usize) -> Result<Vec<Fig3Row>> {
    let mut rows = Vec::new();
    let entries: Vec<&ModelEntry> = man.experiment_models("fig3");
    for (i, e) in entries.iter().enumerate() {
        let rank = e.lowrank_k.map(|k| k.to_string()).unwrap_or_else(|| "full".into());
        let mut acc_rust = f64::NAN;
        if verify_every > 0 && i % verify_every == 0 {
            let model = models::load(man, &e.name)?;
            let ds = super::test_dataset(man, &model.arch)?;
            let svc = EvalService::new(
                &model,
                EngineConfig { policy: Policy::Exact, acc_bits: 32, ..Default::default() },
            );
            acc_rust = svc.evaluate(&ds, Some(limit))?.accuracy;
        }
        rows.push(Fig3Row {
            schedule: e.schedule.clone(),
            rank,
            sparsity: e.target_sparsity,
            acc_python: e.acc_q,
            acc_rust,
        });
    }
    rows.sort_by(|a, b| {
        (a.schedule.clone(), a.rank.clone(), a.sparsity)
            .partial_cmp(&(b.schedule.clone(), b.rank.clone(), b.sparsity))
            .unwrap()
    });
    Ok(rows)
}

pub fn print(rows: &[Fig3Row]) {
    println!("\n=== Fig. 3 — P->Q vs Q->P under low-rank approximation (MLP-2) ===");
    let out: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.schedule.clone(),
                r.rank.clone(),
                format!("{:.0}%", 100.0 * r.sparsity),
                format!("{:.3}", r.acc_python),
                if r.acc_rust.is_nan() { "-".into() } else { format!("{:.3}", r.acc_rust) },
            ]
        })
        .collect();
    super::print_table(&["schedule", "rank", "sparsity", "acc(python)", "acc(rust-engine)"], &out);
}
