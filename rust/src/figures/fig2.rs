//! Figure 2 — overflow profile of a 1-layer MLP (8-bit w/act) vs
//! accumulator bitwidth.
//!
//! (a) fraction of overflowing dot products that are transient vs
//!     persistent, per accumulator width;
//! (b) test accuracy when clipping all overflows vs resolving only the
//!     transient ones (oracle) vs the PQS sorted dot product, against the
//!     FP32 baseline.

use anyhow::Result;

use crate::accum::Policy;
use crate::coordinator::EvalService;
use crate::formats::manifest::Manifest;
use crate::models;
use crate::nn::engine::EngineConfig;

#[derive(Clone, Debug)]
pub struct Fig2Row {
    pub acc_bits: u32,
    pub dots: u64,
    pub overflow_dots: u64,
    pub transient_dots: u64,
    pub persistent_dots: u64,
    pub transient_pct: f64,
    pub acc_clip: f64,
    pub acc_oracle: f64,
    pub acc_sorted: f64,
}

pub struct Fig2Result {
    pub model: String,
    pub fp32_baseline: f64,
    pub rows: Vec<Fig2Row>,
}

pub fn run(man: &Manifest, limit: usize, bit_range: std::ops::RangeInclusive<u32>) -> Result<Fig2Result> {
    let name = &man.experiments["fig2"][0];
    let model = models::load(man, name)?;
    let ds = super::test_dataset(man, &model.arch)?;
    let fp32_baseline = model.acc_fp32;

    let mut rows = Vec::new();
    for p in bit_range {
        // one stats pass (clip policy) gives the overflow profile + clip acc
        let svc = EvalService::new(
            &model,
            EngineConfig { policy: Policy::Clip, acc_bits: p, collect_stats: true, tile: 0 },
        );
        let clip = svc.evaluate(&ds, Some(limit))?;
        let st = clip.report.total();

        let oracle = EvalService::new(
            &model,
            EngineConfig { policy: Policy::Oracle, acc_bits: p, ..Default::default() },
        )
        .evaluate(&ds, Some(limit))?;
        let sorted = EvalService::new(
            &model,
            EngineConfig { policy: Policy::Sorted, acc_bits: p, ..Default::default() },
        )
        .evaluate(&ds, Some(limit))?;

        let overflow_dots = st.transient_dots + st.persistent_dots;
        rows.push(Fig2Row {
            acc_bits: p,
            dots: st.dots,
            overflow_dots,
            transient_dots: st.transient_dots,
            persistent_dots: st.persistent_dots,
            transient_pct: 100.0 * st.transient_fraction(),
            acc_clip: clip.accuracy,
            acc_oracle: oracle.accuracy,
            acc_sorted: sorted.accuracy,
        });
    }
    Ok(Fig2Result { model: name.clone(), fp32_baseline, rows })
}

pub fn print(r: &Fig2Result) {
    println!("\n=== Fig. 2 — overflow profile, model {} (fp32 baseline {:.3}) ===", r.model, r.fp32_baseline);
    let rows: Vec<Vec<String>> = r
        .rows
        .iter()
        .map(|w| {
            vec![
                w.acc_bits.to_string(),
                w.dots.to_string(),
                w.overflow_dots.to_string(),
                w.transient_dots.to_string(),
                w.persistent_dots.to_string(),
                format!("{:.1}%", w.transient_pct),
                format!("{:.3}", w.acc_clip),
                format!("{:.3}", w.acc_oracle),
                format!("{:.3}", w.acc_sorted),
            ]
        })
        .collect();
    super::print_table(
        &["p", "dots", "ovf", "transient", "persistent", "trans%", "acc(clip)", "acc(oracle)", "acc(sorted)"],
        &rows,
    );
}
