//! Section 6 studies:
//! * §3.2 claim — a single sorting round resolves ~99.8% of transient
//!   overflows during MobileNetV2 inference;
//! * §6 claim — tiled sorting (tile k=256) still eliminates ~99% of
//!   transient overflows (software-scheduling compatibility).

use anyhow::Result;

use crate::accum::Policy;
use crate::coordinator::EvalService;
use crate::formats::manifest::Manifest;
use crate::models;
use crate::nn::engine::EngineConfig;

#[derive(Clone, Debug)]
pub struct TileRow {
    pub tile: usize, // 0 = full width
    pub transient_dots: u64,
    pub unresolved: u64,
    pub resolved_pct: f64,
    pub accuracy: f64,
}

pub struct Sec6Result {
    pub model: String,
    pub acc_bits: u32,
    pub rows: Vec<TileRow>,
}

/// Pick the default study model: a pruned P->Q MobileNetV2-tiny.
pub fn default_model(man: &Manifest) -> Option<String> {
    man.experiment_models("fig4")
        .iter()
        .filter(|e| e.arch == "mbv2_tiny" && e.schedule == "pq")
        .max_by(|a, b| a.target_sparsity.partial_cmp(&b.target_sparsity).unwrap())
        .map(|e| e.name.clone())
}

pub fn run(
    man: &Manifest,
    model_name: &str,
    acc_bits: u32,
    tiles: &[usize],
    limit: usize,
) -> Result<Sec6Result> {
    let model = models::load(man, model_name)?;
    let ds = super::test_dataset(man, &model.arch)?;
    let mut rows = Vec::new();
    for &tile in tiles {
        let svc = EvalService::new(
            &model,
            EngineConfig { policy: Policy::Sorted1, acc_bits, tile, collect_stats: true },
        );
        let out = svc.evaluate(&ds, Some(limit))?;
        let st = out.report.total();
        let unresolved = st.policy_event_dots.saturating_sub(st.persistent_dots);
        let resolved_pct = if st.transient_dots == 0 {
            100.0
        } else {
            100.0 * (1.0 - unresolved.min(st.transient_dots) as f64 / st.transient_dots as f64)
        };
        rows.push(TileRow {
            tile,
            transient_dots: st.transient_dots,
            unresolved,
            resolved_pct,
            accuracy: out.accuracy,
        });
    }
    Ok(Sec6Result { model: model_name.to_string(), acc_bits, rows })
}

pub fn print(r: &Sec6Result) {
    println!(
        "\n=== §3.2/§6 — sorted-round transient resolution, model {} (p={}) ===",
        r.model, r.acc_bits
    );
    let rows: Vec<Vec<String>> = r
        .rows
        .iter()
        .map(|t| {
            vec![
                if t.tile == 0 { "full".into() } else { t.tile.to_string() },
                t.transient_dots.to_string(),
                t.unresolved.to_string(),
                format!("{:.2}%", t.resolved_pct),
                format!("{:.3}", t.accuracy),
            ]
        })
        .collect();
    super::print_table(&["tile", "transient", "unresolved", "resolved", "accuracy"], &rows);
}
