//! Figure/table reproduction harnesses (DESIGN.md §3, experiment index).
//!
//! Each `figN` module computes the rows behind the corresponding figure of
//! the paper; `examples/figN_*.rs` print them and `rust/benches/
//! bench_figures.rs` times them. Sample limits are tunable via
//! `PQS_EVAL_LIMIT` (default keeps full-figure regeneration in minutes on
//! one core).

pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod sec6;

use anyhow::Result;

use crate::data::Dataset;
use crate::formats::manifest::Manifest;

/// Default per-model evaluation sample cap (override: PQS_EVAL_LIMIT).
pub fn eval_limit(default: usize) -> usize {
    std::env::var("PQS_EVAL_LIMIT").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Load the test dataset for an architecture.
pub fn test_dataset(man: &Manifest, arch: &str) -> Result<Dataset> {
    let entry = man.test_dataset_for(arch)?;
    Ok(Dataset::load(man.dataset_path(&entry.test))?)
}

/// Render a simple aligned table.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut width: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, c) in r.iter().enumerate() {
            if i < width.len() {
                width[i] = width[i].max(c.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:>w$}  ", c, w = width[i]));
        }
        println!("{}", s.trim_end());
    };
    line(headers.iter().map(|h| h.to_string()).collect());
    line(width.iter().map(|w| "-".repeat(*w)).collect());
    for r in rows {
        line(r.clone());
    }
}
