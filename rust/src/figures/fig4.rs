//! Figure 4 — P->Q vs Q->P vs structured filter pruning on the CNNs
//! (ResNet-tiny / MobileNetV2-tiny, N:M with M=16).

use anyhow::Result;

use crate::accum::Policy;
use crate::coordinator::EvalService;
use crate::formats::manifest::Manifest;
use crate::models;
use crate::nn::engine::EngineConfig;

#[derive(Clone, Debug)]
pub struct Fig4Row {
    pub arch: String,
    pub schedule: String,
    pub sparsity: f64,
    pub acc_python: f64,
    pub acc_rust: f64,
    pub fp32_baseline: f64,
}

pub fn run(man: &Manifest, limit: usize, verify_every: usize) -> Result<Vec<Fig4Row>> {
    let mut rows = Vec::new();
    for (i, e) in man.experiment_models("fig4").iter().enumerate() {
        let fp32 = man
            .experiment_models("fp32")
            .iter()
            .find(|b| b.arch == e.arch)
            .map(|b| b.acc_fp32)
            .unwrap_or(f64::NAN);
        let mut acc_rust = f64::NAN;
        if verify_every > 0 && i % verify_every == 0 {
            let model = models::load(man, &e.name)?;
            let ds = super::test_dataset(man, &model.arch)?;
            let svc = EvalService::new(
                &model,
                EngineConfig { policy: Policy::Exact, acc_bits: 32, ..Default::default() },
            );
            acc_rust = svc.evaluate(&ds, Some(limit))?.accuracy;
        }
        rows.push(Fig4Row {
            arch: e.arch.clone(),
            schedule: e.schedule.clone(),
            sparsity: e.target_sparsity,
            acc_python: e.acc_q,
            acc_rust,
            fp32_baseline: fp32,
        });
    }
    rows.sort_by(|a, b| {
        (a.arch.clone(), a.schedule.clone(), a.sparsity)
            .partial_cmp(&(b.arch.clone(), b.schedule.clone(), b.sparsity))
            .unwrap()
    });
    Ok(rows)
}

pub fn print(rows: &[Fig4Row]) {
    println!("\n=== Fig. 4 — pruning/quantization schedules on CNNs ===");
    let out: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.arch.clone(),
                r.schedule.clone(),
                format!("{:.0}%", 100.0 * r.sparsity),
                format!("{:.3}", r.acc_python),
                if r.acc_rust.is_nan() { "-".into() } else { format!("{:.3}", r.acc_rust) },
                format!("{:.3}", r.fp32_baseline),
            ]
        })
        .collect();
    super::print_table(
        &["arch", "schedule", "sparsity", "acc(python)", "acc(rust)", "fp32-baseline"],
        &out,
    );
}
