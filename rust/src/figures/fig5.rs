//! Figure 5 — accuracy vs accumulator bitwidth: the PQS pareto frontier
//! against A2Q and against clipping the (sparse) dot products.
//!
//! For every candidate model the rust engine sweeps the accumulator width
//! with the full sorted policy (PQS, blue) and with saturating clipping
//! (magenta), producing the paper's central claim: sorting buys ~4 bits of
//! accumulator and pushes below the A2Q frontier.

use anyhow::Result;

use crate::accum::Policy;
use crate::coordinator::EvalService;
use crate::formats::manifest::{Manifest, ModelEntry};
use crate::models;
use crate::nn::engine::EngineConfig;

#[derive(Clone, Debug)]
pub struct Fig5Point {
    pub model: String,
    pub arch: String,
    pub family: String, // "pqs" | "a2q"
    pub wbits: u8,
    pub sparsity: f64,
    pub acc_bits: u32,
    pub acc_sorted: f64,
    pub acc_clip: f64,
    pub fp32_baseline: f64,
}

/// Candidate models: PQS = all P->Q pruned models (fig4/fig5 pq + fig2),
/// A2Q = the a2q schedule runs.
fn candidates<'m>(man: &'m Manifest, arch_filter: Option<&str>) -> Vec<&'m ModelEntry> {
    let mut names: Vec<&String> = Vec::new();
    for exp in ["fig2", "fig4", "fig5"] {
        if let Some(v) = man.experiments.get(exp) {
            names.extend(v.iter());
        }
    }
    names.sort();
    names.dedup();
    names
        .into_iter()
        .filter_map(|n| man.models.get(n))
        .filter(|e| e.schedule == "pq" || e.schedule == "a2q")
        .filter(|e| arch_filter.map(|a| e.arch == a).unwrap_or(true))
        .collect()
}

pub fn run(
    man: &Manifest,
    limit: usize,
    acc_bits: &[u32],
    arch_filter: Option<&str>,
) -> Result<Vec<Fig5Point>> {
    let mut points = Vec::new();
    for e in candidates(man, arch_filter) {
        let model = models::load(man, &e.name)?;
        let ds = super::test_dataset(man, &model.arch)?;
        let fp32 = man
            .experiment_models("fp32")
            .iter()
            .find(|b| b.arch == e.arch)
            .map(|b| b.acc_fp32)
            .unwrap_or(f64::NAN);
        // A2Q models are evaluated at their trained accumulator width only
        // (their guarantee is specific to it); PQS models sweep the range.
        let widths: Vec<u32> = match e.acc_bits_trained {
            Some(p) => vec![p],
            None => acc_bits.to_vec(),
        };
        for p in widths {
            let sorted = EvalService::new(
                &model,
                EngineConfig { policy: Policy::Sorted, acc_bits: p, ..Default::default() },
            )
            .evaluate(&ds, Some(limit))?;
            let clip = EvalService::new(
                &model,
                EngineConfig { policy: Policy::Clip, acc_bits: p, ..Default::default() },
            )
            .evaluate(&ds, Some(limit))?;
            points.push(Fig5Point {
                model: e.name.clone(),
                arch: e.arch.clone(),
                family: if e.schedule == "a2q" { "a2q".into() } else { "pqs".into() },
                wbits: e.wbits,
                sparsity: e.achieved_sparsity,
                acc_bits: p,
                acc_sorted: sorted.accuracy,
                acc_clip: clip.accuracy,
                fp32_baseline: fp32,
            });
        }
    }
    Ok(points)
}

/// Pareto frontier per family: for each accumulator width, the best
/// accuracy achieved by any model of that family.
pub fn frontier(points: &[Fig5Point], arch: &str, family: &str) -> Vec<(u32, f64)> {
    let mut best: std::collections::BTreeMap<u32, f64> = Default::default();
    for p in points.iter().filter(|p| p.arch == arch && p.family == family) {
        let acc = if family == "a2q" { p.acc_clip } else { p.acc_sorted };
        let e = best.entry(p.acc_bits).or_insert(f64::MIN);
        if acc > *e {
            *e = acc;
        }
    }
    best.into_iter().collect()
}

pub fn print(points: &[Fig5Point]) {
    println!("\n=== Fig. 5 — accuracy vs accumulator bitwidth (per point) ===");
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.model.clone(),
                p.family.clone(),
                p.acc_bits.to_string(),
                format!("{:.3}", p.acc_sorted),
                format!("{:.3}", p.acc_clip),
                format!("{:.3}", p.fp32_baseline),
            ]
        })
        .collect();
    super::print_table(
        &["model", "family", "p", "acc(sorted)", "acc(clip)", "fp32"],
        &rows,
    );
    // frontiers
    let mut archs: Vec<&str> = points.iter().map(|p| p.arch.as_str()).collect();
    archs.sort();
    archs.dedup();
    for arch in archs {
        println!("\n--- {arch} pareto frontiers ---");
        for fam in ["pqs", "a2q"] {
            let f = frontier(points, arch, fam);
            let line: Vec<String> =
                f.iter().map(|(p, a)| format!("p{p}:{a:.3}")).collect();
            println!("{fam:>4}: {}", line.join("  "));
        }
    }
}

/// Headline metric: lowest accumulator width at which the best PQS model
/// stays within `tol` of the FP32 baseline (paper: 2.5x reduction vs 32b).
pub fn min_width_within(points: &[Fig5Point], arch: &str, tol: f64) -> Option<(u32, f64, f64)> {
    let base = points.iter().find(|p| p.arch == arch)?.fp32_baseline;
    frontier(points, arch, "pqs")
        .into_iter()
        .filter(|(_, acc)| *acc >= base - tol)
        .min_by_key(|(p, _)| *p)
        .map(|(p, acc)| (p, acc, base))
}
