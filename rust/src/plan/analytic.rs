//! Analytic per-layer accumulator bound (A2Q-style, generalized).
//!
//! For a quantized layer the engine accumulates offset-free products
//! `w_j * x~_j` where `x~_j = x_q - o_x` ranges over the *centered* input
//! window `[xlo, xhi]` (see `quant::quantize_centered_slice_into`; the
//! window always contains 0 because FP32 zero quantizes to integer 0).
//! Treating every input coordinate adversarially and independently, the
//! worst-case contribution of weight `w_j` to the running sum is
//!
//! ```text
//!   m_j = max(w_j * xlo, w_j * xhi)   (>= 0 when 0 in [xlo, xhi])
//!   n_j = min(w_j * xlo, w_j * xhi)   (<= 0 when 0 in [xlo, xhi])
//! ```
//!
//! * **Final-sum bound** (policies `Exact`/`Sorted`/`Sorted1`/`Oracle`):
//!   the exact dot product lies in `[Σ n_j, Σ m_j]`; a width holding that
//!   interval guarantees **zero persistent overflows** — and since the
//!   sorted policies return `clamp(exact)`, their outputs are then exact.
//!   For ReLU-positive inputs (`[0, 2^a - 1]`) this reduces to the A2Q
//!   ℓ1-norm-over-rows bound: `Σ m_j = (2^a - 1) * Σ w_j^+`,
//!   `Σ n_j = -(2^a - 1) * Σ w_j^-`.
//! * **Prefix bound** (policies `Clip`/`Wrap`, which accumulate in index
//!   order): every index-order prefix sum lies in
//!   `[min_i Σ_{j<=i} n_j, max_i Σ_{j<=i} m_j]`; a width holding that
//!   interval guarantees **zero overflow events of any kind**, so the
//!   clipped/wrapped value equals the exact sum. Because the centered
//!   window spans zero (`m_j >= 0 >= n_j`), the prefix extremes coincide
//!   with the final sums — the code still tracks true prefixes so the
//!   guarantee is honest for any window.
//!
//! Pruning only removes terms (a zero weight contributes `m_j = n_j = 0`),
//! so both bounds are monotone non-increasing in sparsity: prune more,
//! plan a narrower accumulator (property-tested below).

use crate::accum::{self, Policy};
use crate::nn::QLayer;
use crate::quant::QParams;

/// The centered integer window `[qlo - o, qhi - o]` the accumulator sees.
pub fn centered_input_range(qp: &QParams) -> (i64, i64) {
    let (qlo, qhi) = qp.qrange();
    ((qlo - qp.offset) as i64, (qhi - qp.offset) as i64)
}

/// Worst-case accumulator interval one weight row contributes under
/// `policy`, over the centered input window `(xlo, xhi)`: the final-sum
/// interval for the sorting policies, the index-order prefix interval for
/// `Clip`/`Wrap`. `vals` are the row's weights in accumulation order;
/// zeros contribute nothing, so passing a dense row or only its nonzeros
/// (in column order) gives the same answer. Always contains 0 (the
/// accumulator's start value). This is the row-level primitive the
/// budget *projection* inverts (`crate::sweep::project` shrinks row
/// magnitudes until this interval fits the requested width).
pub fn row_range(vals: &[i8], (xlo, xhi): (i64, i64), policy: Policy) -> (i64, i64) {
    let sequential = matches!(policy, Policy::Clip | Policy::Wrap);
    // running worst-case sums over the row's products, in the exact
    // order the engine accumulates them (dense column order)
    let (mut lo, mut hi) = (0i64, 0i64);
    let (mut row_lo, mut row_hi) = (0i64, 0i64);
    for &v in vals {
        let a = v as i64 * xlo;
        let b = v as i64 * xhi;
        hi += a.max(b);
        lo += a.min(b);
        if sequential {
            row_hi = row_hi.max(hi);
            row_lo = row_lo.min(lo);
        }
    }
    if !sequential {
        row_lo = lo.min(0);
        row_hi = hi.max(0);
    }
    (row_lo, row_hi)
}

/// Minimal accumulator width holding [`row_range`] of one row.
pub fn row_bits(vals: &[i8], window: (i64, i64), policy: Policy) -> u32 {
    let (lo, hi) = row_range(vals, window, policy);
    accum::bits_for_range(lo, hi)
}

/// Worst-case accumulator interval of `layer` under `policy` (see the
/// module docs: final-sum interval for the sorting policies, index-order
/// prefix interval for `Clip`/`Wrap`). Always contains 0 (the
/// accumulator's start value).
pub fn analytic_layer_range(layer: &QLayer, policy: Policy) -> (i64, i64) {
    let window = centered_input_range(&layer.x_qp);
    let (mut worst_lo, mut worst_hi) = (0i64, 0i64);
    for r in 0..layer.w.rows {
        let (_, vals) = layer.w.row(r);
        let (row_lo, row_hi) = row_range(vals, window, policy);
        worst_lo = worst_lo.min(row_lo);
        worst_hi = worst_hi.max(row_hi);
    }
    (worst_lo, worst_hi)
}

/// Minimal accumulator width with the per-policy guarantee of
/// [`analytic_layer_range`]: zero persistent overflows for the sorting
/// policies, zero overflow events at all for `Clip`/`Wrap`.
pub fn analytic_layer_bits(layer: &QLayer, policy: Policy) -> u32 {
    let (lo, hi) = analytic_layer_range(layer, policy);
    accum::bits_for_range(lo, hi)
}

/// Largest number of nonzero weights any single output row (dot product)
/// of `layer` carries — the effective dot length after pruning.
pub fn max_row_nnz(layer: &QLayer) -> usize {
    (0..layer.w.rows).map(|r| layer.w.row(r).0.len()).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dot::DotEngine;
    use crate::formats::pqsw::QLayerMeta;
    use crate::util::rng::Pcg32;

    fn layer_from(wq: Vec<i8>, oc: usize, k: usize, x_offset: i32, abits: u8) -> QLayer {
        let meta = QLayerMeta {
            name: "t".into(),
            oc,
            ic: k,
            kh: 1,
            kw: 1,
            stride: 1,
            pad: 0,
            prune: true,
            w_scale: 0.1,
            x_scale: 0.01,
            x_offset,
            wq: wq.into(),
            k,
            bias: vec![0.0; oc],
        };
        QLayer::from_meta(&meta, abits, 0)
    }

    #[test]
    fn hand_computed_relu_bound_matches_l1_norm() {
        // ReLU window [0, 255]: hi = 255 * sum(w+), lo = -255 * sum(w-)
        let l = layer_from(vec![3, -2, 0, 5], 1, 4, -128, 8);
        let (lo, hi) = analytic_layer_range(&l, Policy::Sorted);
        assert_eq!(hi, 255 * (3 + 5));
        assert_eq!(lo, -255 * 2);
        assert_eq!(analytic_layer_bits(&l, Policy::Sorted), accum::bits_for_range(lo, hi));
        // clip's prefix bound coincides when the window spans zero
        assert_eq!(analytic_layer_range(&l, Policy::Clip), (lo, hi));
        assert_eq!(max_row_nnz(&l), 3);
    }

    #[test]
    fn row_range_is_zero_insensitive_and_matches_layer() {
        // the exposed row primitive: zeros are no-ops, so a dense row and
        // its nonzeros (column order) bound identically, and a 1-row layer
        // reduces to it exactly
        let dense: Vec<i8> = vec![3, 0, -2, 0, 0, 5];
        let nonzeros: Vec<i8> = vec![3, -2, 5];
        let l = layer_from(dense.clone(), 1, 6, -128, 8);
        let window = centered_input_range(&l.x_qp);
        for policy in Policy::ALL {
            assert_eq!(row_range(&dense, window, policy), row_range(&nonzeros, window, policy));
            assert_eq!(row_range(&dense, window, policy), analytic_layer_range(&l, policy));
            assert_eq!(row_bits(&dense, window, policy), analytic_layer_bits(&l, policy));
        }
        // empty row: the accumulator never leaves 0
        assert_eq!(row_range(&[], window, Policy::Sorted), (0, 0));
        assert_eq!(row_bits(&[], window, Policy::Clip), 2);
    }

    #[test]
    fn planned_width_has_zero_persistent_and_clean_clip_prop() {
        // random sparse layers x random inputs in the centered window:
        // at the analytic width, the exact value always fits (no
        // persistent overflow) for every policy, and Clip/Wrap see zero
        // events (their prefix guarantee)
        let mut rng = Pcg32::new(0x9_1A_17);
        let mut eng = DotEngine::new();
        for case in 0..60 {
            let k = 8 + rng.below(96) as usize;
            let oc = 1 + rng.below(4) as usize;
            let wq: Vec<i8> = (0..oc * k)
                .map(|_| {
                    if rng.below(3) == 0 {
                        0
                    } else {
                        rng.range_i64(-127, 127) as i8
                    }
                })
                .collect();
            let x_offset = if rng.below(2) == 0 { -128 } else { 0 };
            let l = layer_from(wq, oc, k, x_offset, 8);
            let (xlo, xhi) = centered_input_range(&l.x_qp);
            for policy in Policy::ALL {
                let p = analytic_layer_bits(&l, policy);
                let (lo, hi) = accum::acc_range(p);
                for trial in 0..20 {
                    let x: Vec<i32> =
                        (0..k).map(|_| rng.range_i64(xlo, xhi) as i32).collect();
                    for o in 0..oc {
                        let mut prods = Vec::new();
                        l.w.dot_products_into(o, &x, &mut prods);
                        let exact = accum::exact_dot(&prods);
                        assert!(
                            exact >= lo && exact <= hi,
                            "case {case} trial {trial} {}: exact {exact} escapes \
                             [{lo},{hi}] at planned p={p}",
                            policy.name()
                        );
                        if matches!(policy, Policy::Clip | Policy::Wrap) {
                            let (v, ev) = eng.dot(&prods, p, policy);
                            assert_eq!(ev, 0, "case {case}: {} events at p={p}", policy.name());
                            assert_eq!(v, exact, "case {case}: clean {} must be exact", policy.name());
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn planned_width_is_monotone_in_sparsity() {
        // zeroing weights (pruning harder) never widens the plan
        let mut rng = Pcg32::new(0x5_9A_25);
        for _ in 0..40 {
            let k = 16 + rng.below(64) as usize;
            let mut wq: Vec<i8> = (0..2 * k).map(|_| rng.range_i64(-127, 127) as i8).collect();
            let l = layer_from(wq.clone(), 2, k, -128, 8);
            let mut prev: Vec<u32> =
                Policy::ALL.iter().map(|&p| analytic_layer_bits(&l, p)).collect();
            // prune in 4 rounds, checking monotonicity at each step
            for _ in 0..4 {
                for v in wq.iter_mut() {
                    if rng.below(3) == 0 {
                        *v = 0;
                    }
                }
                let l = layer_from(wq.clone(), 2, k, -128, 8);
                let now: Vec<u32> =
                    Policy::ALL.iter().map(|&p| analytic_layer_bits(&l, p)).collect();
                for (i, (&n, &pv)) in now.iter().zip(prev.iter()).enumerate() {
                    assert!(
                        n <= pv,
                        "{}: pruning widened the plan {pv} -> {n}",
                        Policy::ALL[i].name()
                    );
                }
                prev = now;
            }
        }
    }
}
