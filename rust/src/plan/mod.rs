//! Accumulator-bitwidth planning (the paper's headline 2.5× accumulator
//! reduction as a first-class, serving-integrated subsystem).
//!
//! `EngineConfig::acc_bits` is one global number; this module derives a
//! **per-layer** width plan with explicit guarantees and threads it
//! through the whole stack:
//!
//! * [`analytic`] — the worst-case bound. Given the quantized weights and
//!   the layer's centered input window, it computes the minimal width
//!   that *guarantees* no persistent overflow (sorting policies) or no
//!   overflow events at all (`Clip`/`Wrap`, via an index-order prefix
//!   bound). See the module docs there for the derivation; for
//!   ReLU-positive inputs it reduces to the A2Q ℓ1-norm-over-rows bound.
//! * [`calibrate`] — the empirical tightener. A deterministic sample set
//!   streams through the instrumented engine at a wide reference width;
//!   each layer's stats record a histogram of the width every dot needs
//!   to run event-free under the target policy (final exact value for
//!   the sorting policies, index-order prefix extremes for `Clip`/`Wrap`
//!   — mirroring the per-policy analytic guarantee), and the planner
//!   binary-searches it for the smallest width whose observed overflow
//!   fraction stays within
//!   [`PlannerConfig::budget`]. [`PlannerConfig::margin`] safety bits are
//!   then added on top (headroom for inputs the sample set missed), and
//!   the result is capped at the analytic width — calibration can only
//!   ever *tighten* the guarantee, never loosen it. PQS's sort-then-clip
//!   policies make this empirical width markedly tighter than the
//!   worst-case bound (transient overflows are resolved by sorting, so
//!   only the final-sum distribution matters).
//!
//! The output [`AccumPlan`] is persisted as a versioned optional section
//! of the `.pqsw` container (old files keep loading; see
//! `formats::pqsw`), surfaced in manifests, applied automatically by
//! `nn::Engine` (per-layer widths override the global `acc_bits`;
//! behaviour is bit-identical when no plan is present), and reported per
//! model by `GET /v1/models`. The `pqs plan` CLI subcommand runs both
//! planners and prints the per-layer table plus the total
//! accumulator-bit savings versus a 32-bit baseline.

pub mod analytic;
pub mod calibrate;

use anyhow::{anyhow, Result};

use crate::accum::Policy;
use crate::formats::pqsw::PqswModel;
use crate::nn::QLayer;
use crate::util::json::{self, Json};

pub use analytic::{
    analytic_layer_bits, analytic_layer_range, centered_input_range, max_row_nnz, row_bits,
    row_range,
};
pub use calibrate::{observe, observe_batches, CALIBRATION_BITS};

/// Which planner produced a plan's enforced widths.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlannerKind {
    /// Worst-case widths only (guaranteed, input-independent).
    Analytic,
    /// Calibrated widths (empirical + margin, capped at the analytic
    /// bound).
    Calibrated,
}

impl PlannerKind {
    pub fn name(self) -> &'static str {
        match self {
            PlannerKind::Analytic => "analytic",
            PlannerKind::Calibrated => "calibrated",
        }
    }

    pub fn from_name(s: &str) -> Option<PlannerKind> {
        match s {
            "analytic" => Some(PlannerKind::Analytic),
            "calibrated" => Some(PlannerKind::Calibrated),
            _ => None,
        }
    }
}

/// One layer's row in an [`AccumPlan`].
#[derive(Clone, Debug, PartialEq)]
pub struct LayerPlan {
    /// q-layer name (plans match engine layers by name).
    pub name: String,
    /// contraction length (dot-product length before pruning)
    pub k: usize,
    /// largest effective (post-pruning) dot length of any output row
    pub nnz_max: usize,
    /// worst-case analytic width (the guarantee)
    pub analytic_bits: u32,
    /// calibrated width incl. safety margin (`None` = analytic-only plan)
    pub calibrated_bits: Option<u32>,
    /// the width the engine enforces for this layer
    pub acc_bits: u32,
}

/// Compact per-model plan description for the serving surfaces
/// (`GET /v1/models`, manifests, `RouterMetrics`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlanSummary {
    pub layers: usize,
    pub min_bits: u32,
    pub max_bits: u32,
    pub mean_bits: f64,
    pub planner: PlannerKind,
}

/// A per-layer accumulator-bitwidth plan (see the module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct AccumPlan {
    /// accumulation policy the widths were planned for
    pub policy: Policy,
    pub planner: PlannerKind,
    /// allowed fraction of dots overflowing at the calibrated width
    pub budget: f64,
    /// safety bits added on top of the raw calibrated width
    pub margin: u32,
    /// calibration samples observed (0 for analytic-only plans)
    pub samples: usize,
    /// rows in model graph order
    pub per_layer: Vec<LayerPlan>,
}

impl AccumPlan {
    /// Enforced width for layer `name`, if planned.
    pub fn bits_for_layer(&self, name: &str) -> Option<u32> {
        self.per_layer.iter().find(|l| l.name == name).map(|l| l.acc_bits)
    }

    /// Smallest request-level `acc_bits` that covers every planned layer
    /// (the widest enforced width). A per-request operating point below
    /// this would narrow some layer past its planned guarantee, so the
    /// serving layer rejects it with `BadRequest`.
    pub fn min_safe_bits(&self) -> u32 {
        self.per_layer.iter().map(|l| l.acc_bits).max().unwrap_or(2)
    }

    /// Per-layer widths for a requested operating point `width` (>=
    /// [`AccumPlan::min_safe_bits`]): each layer runs at
    /// `min(width, analytic_bits)` — at least its planned width, never
    /// past its analytic guarantee, so wider requests trade accumulator
    /// narrowness for overflow headroom on the SAME resident weights.
    pub fn operating_point(&self, width: u32) -> Vec<(String, u32)> {
        self.per_layer
            .iter()
            .map(|l| (l.name.clone(), width.min(l.analytic_bits)))
            .collect()
    }

    /// Sum of enforced per-layer widths.
    pub fn total_bits(&self) -> u64 {
        self.per_layer.iter().map(|l| l.acc_bits as u64).sum()
    }

    /// The 32-bit-per-layer baseline the savings are quoted against.
    pub fn baseline_bits(&self) -> u64 {
        32 * self.per_layer.len() as u64
    }

    pub fn summary(&self) -> PlanSummary {
        let n = self.per_layer.len();
        PlanSummary {
            layers: n,
            min_bits: self.per_layer.iter().map(|l| l.acc_bits).min().unwrap_or(0),
            max_bits: self.per_layer.iter().map(|l| l.acc_bits).max().unwrap_or(0),
            mean_bits: if n == 0 {
                0.0
            } else {
                self.total_bits() as f64 / n as f64
            },
            planner: self.planner,
        }
    }

    /// The per-layer table + savings line the `pqs plan` CLI prints.
    pub fn print(&self) {
        println!(
            "plan: policy={} planner={} samples={} budget={} margin={}",
            self.policy.name(),
            self.planner.name(),
            self.samples,
            self.budget,
            self.margin,
        );
        println!(
            "{:<14} {:>8} {:>8} {:>9} {:>11} {:>8}",
            "layer", "k", "nnz/row", "analytic", "calibrated", "planned"
        );
        for l in &self.per_layer {
            let cal = match l.calibrated_bits {
                Some(c) => c.to_string(),
                None => "-".to_string(),
            };
            println!(
                "{:<14} {:>8} {:>8} {:>9} {:>11} {:>8}",
                l.name, l.k, l.nnz_max, l.analytic_bits, cal, l.acc_bits
            );
        }
        let total = self.total_bits();
        let base = self.baseline_bits();
        if base > 0 {
            println!(
                "total accumulator bits: {total} planned vs {base} at the 32-bit baseline \
                 ({:.2}x reduction, mean {:.1} bits/layer)",
                base as f64 / total.max(1) as f64,
                self.summary().mean_bits,
            );
        }
    }

    /// Serialize as the `.pqsw` `"plan"` section (tag included).
    pub fn to_json(&self) -> Json {
        let layers: Vec<Json> = self
            .per_layer
            .iter()
            .map(|l| {
                json::obj(vec![
                    ("name", json::s(&l.name)),
                    ("k", json::num(l.k as f64)),
                    ("nnz_max", json::num(l.nnz_max as f64)),
                    ("analytic_bits", json::num(l.analytic_bits as f64)),
                    (
                        "calibrated_bits",
                        match l.calibrated_bits {
                            Some(c) => json::num(c as f64),
                            None => Json::Null,
                        },
                    ),
                    ("acc_bits", json::num(l.acc_bits as f64)),
                ])
            })
            .collect();
        json::obj(vec![
            ("tag", json::s("plan")),
            ("v", json::num(1.0)),
            ("policy", json::s(self.policy.name())),
            ("planner", json::s(self.planner.name())),
            ("budget", json::num(self.budget)),
            ("margin", json::num(self.margin as f64)),
            ("samples", json::num(self.samples as f64)),
            ("layers", Json::Arr(layers)),
        ])
    }

    /// Parse a `"plan"` section back (inverse of [`AccumPlan::to_json`]).
    pub fn from_json(j: &Json) -> Result<AccumPlan> {
        let policy_name = j.get("policy").and_then(Json::as_str).unwrap_or("");
        let policy = Policy::from_name(policy_name)
            .ok_or_else(|| anyhow!("plan section: unknown policy {policy_name:?}"))?;
        let planner_name = j.get("planner").and_then(Json::as_str).unwrap_or("");
        let planner = PlannerKind::from_name(planner_name)
            .ok_or_else(|| anyhow!("plan section: unknown planner {planner_name:?}"))?;
        let mut per_layer = Vec::new();
        for l in j
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("plan section: missing layers array"))?
        {
            let name = l
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("plan layer: missing name"))?
                .to_string();
            let acc_bits = l
                .get("acc_bits")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("plan layer {name:?}: missing acc_bits"))?
                as u32;
            per_layer.push(LayerPlan {
                name,
                k: l.get("k").and_then(Json::as_usize).unwrap_or(0),
                nnz_max: l.get("nnz_max").and_then(Json::as_usize).unwrap_or(0),
                analytic_bits: l
                    .get("analytic_bits")
                    .and_then(Json::as_usize)
                    .unwrap_or(acc_bits as usize) as u32,
                calibrated_bits: l
                    .get("calibrated_bits")
                    .and_then(Json::as_usize)
                    .map(|v| v as u32),
                acc_bits,
            });
        }
        Ok(AccumPlan {
            policy,
            planner,
            budget: j.get("budget").and_then(Json::as_f64).unwrap_or(0.0),
            margin: j.get("margin").and_then(Json::as_usize).unwrap_or(0) as u32,
            samples: j.get("samples").and_then(Json::as_usize).unwrap_or(0),
            per_layer,
        })
    }
}

/// Planner knobs (see the module docs for semantics).
#[derive(Clone, Copy, Debug)]
pub struct PlannerConfig {
    /// accumulation policy the plan targets
    pub policy: Policy,
    /// calibration samples to stream (0 = analytic-only plan)
    pub calibrate_samples: usize,
    /// allowed fraction of dots whose exact value may exceed the
    /// calibrated width (0.0 = no observed overflow tolerated)
    pub budget: f64,
    /// safety bits added to the raw calibrated width (headroom for inputs
    /// the sample set missed); never pushes past the analytic bound
    pub margin: u32,
    /// calibration forward batch size
    pub batch: usize,
    /// calibration input stream seed
    pub seed: u64,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            policy: Policy::Sorted,
            calibrate_samples: 0,
            budget: 0.0,
            margin: 1,
            batch: 32,
            seed: 0x9A17,
        }
    }
}

/// Run the planner(s) over `model` and assemble its [`AccumPlan`]:
/// analytic widths always, calibrated widths when
/// `cfg.calibrate_samples > 0` (capped at the analytic bound, floored at
/// 2 bits). Layers are matched by q-layer name, in graph order. The
/// calibration stream is the synthetic seeded-uniform one; callers with
/// real data observe it themselves ([`calibrate::observe_batches`]) and
/// pass the report to [`plan_model_observed`].
pub fn plan_model(model: &PqswModel, cfg: &PlannerConfig) -> Result<AccumPlan> {
    plan_model_observed(model, cfg, None)
}

/// [`plan_model`] with an externally observed calibration report (real
/// data fed through [`calibrate::observe_batches`]); set
/// `cfg.calibrate_samples` to the number of samples the report saw. With
/// `report = None` and `cfg.calibrate_samples > 0` the synthetic uniform
/// stream is observed here (the offline fallback).
pub fn plan_model_observed(
    model: &PqswModel,
    cfg: &PlannerConfig,
    report: Option<&crate::overflow::OverflowReport>,
) -> Result<AccumPlan> {
    let mut per_layer = Vec::new();
    for (_, meta) in model.q_layers() {
        let ql = QLayer::from_meta(meta, model.abits, model.nm_m);
        let analytic_bits = analytic_layer_bits(&ql, cfg.policy);
        per_layer.push(LayerPlan {
            name: ql.name.clone(),
            k: ql.k,
            nnz_max: max_row_nnz(&ql),
            analytic_bits,
            calibrated_bits: None,
            acc_bits: analytic_bits,
        });
    }
    if per_layer.is_empty() {
        return Err(anyhow!("model {:?} has no quantized layers to plan", model.name));
    }
    let mut planner = PlannerKind::Analytic;
    let observed_report;
    let report = match report {
        Some(r) => Some(r),
        None if cfg.calibrate_samples > 0 => {
            observed_report = calibrate::observe(
                model,
                cfg.policy,
                cfg.calibrate_samples,
                cfg.batch,
                cfg.seed,
            )?;
            Some(&observed_report)
        }
        None => None,
    };
    if let Some(report) = report {
        planner = PlannerKind::Calibrated;
        for lp in per_layer.iter_mut() {
            let observed = report
                .layer(&lp.name)
                .and_then(|st| st.calibrated_bits(cfg.budget))
                .ok_or_else(|| {
                    anyhow!(
                        "calibration observed no dots for layer {:?} (duplicate or \
                         renamed layer?)",
                        lp.name
                    )
                })?;
            let cal = (observed + cfg.margin).clamp(2, lp.analytic_bits);
            lp.calibrated_bits = Some(cal);
            lp.acc_bits = cal;
        }
    }
    Ok(AccumPlan {
        policy: cfg.policy,
        planner,
        budget: cfg.budget,
        margin: cfg.margin,
        samples: cfg.calibrate_samples,
        per_layer,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn analytic_plan_covers_every_q_layer_in_order() {
        let model = models::synthetic_conv(2, 8, 8, 4, 10);
        let plan = plan_model(&model, &PlannerConfig::default()).unwrap();
        let names: Vec<&str> = plan.per_layer.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(names, vec!["conv1", "dw2", "fc"]);
        assert_eq!(plan.planner, PlannerKind::Analytic);
        for l in &plan.per_layer {
            assert!(l.analytic_bits >= 2 && l.analytic_bits <= 33, "{:?}", l);
            assert_eq!(l.acc_bits, l.analytic_bits);
            assert_eq!(l.calibrated_bits, None);
            assert!(l.nnz_max <= l.k);
        }
        let s = plan.summary();
        assert_eq!(s.layers, 3);
        assert!(s.min_bits <= s.max_bits);
        assert!(s.mean_bits >= s.min_bits as f64 && s.mean_bits <= s.max_bits as f64);
    }

    #[test]
    fn calibrated_plan_is_at_most_the_analytic_bound() {
        let model = models::synthetic_linear(64, 10);
        let cfg = PlannerConfig { calibrate_samples: 64, ..Default::default() };
        let plan = plan_model(&model, &cfg).unwrap();
        assert_eq!(plan.planner, PlannerKind::Calibrated);
        for l in &plan.per_layer {
            let cal = l.calibrated_bits.expect("calibration ran");
            assert!(cal <= l.analytic_bits, "calibrated {cal} > analytic {}", l.analytic_bits);
            assert_eq!(l.acc_bits, cal);
            assert!(cal >= 2);
        }
    }

    #[test]
    fn plan_json_roundtrips() {
        let model = models::synthetic_conv(2, 6, 6, 4, 10);
        let cfg = PlannerConfig { calibrate_samples: 16, margin: 2, budget: 0.001, ..Default::default() };
        let plan = plan_model(&model, &cfg).unwrap();
        let txt = plan.to_json().to_string();
        let back = AccumPlan::from_json(&Json::parse(&txt).unwrap()).unwrap();
        assert_eq!(back, plan);
        // bits_for_layer resolves by name
        assert_eq!(plan.bits_for_layer("fc"), Some(plan.per_layer[2].acc_bits));
        assert_eq!(plan.bits_for_layer("nope"), None);
        // savings arithmetic
        assert_eq!(plan.baseline_bits(), 96);
        assert!(plan.total_bits() < plan.baseline_bits());
    }

    #[test]
    fn bad_plan_sections_are_rejected() {
        let bad = Json::parse(r#"{"tag":"plan","policy":"bogus","planner":"analytic","layers":[]}"#)
            .unwrap();
        assert!(AccumPlan::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"tag":"plan","policy":"sorted","planner":"x","layers":[]}"#)
            .unwrap();
        assert!(AccumPlan::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"tag":"plan","policy":"sorted","planner":"analytic"}"#).unwrap();
        assert!(AccumPlan::from_json(&bad).is_err());
    }
}
