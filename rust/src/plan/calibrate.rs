//! Calibration runs for the accumulator-bitwidth planner.
//!
//! Protocol: stream a deterministic sample set through the instrumented
//! engine at a **wide reference width** ([`CALIBRATION_BITS`]) with the
//! target policy. At that width nothing overflows, so every layer's
//! activations match the overflow-free behaviour the planned model should
//! exhibit — and the stats path records, per layer, the histogram of the
//! signed width each dot product requires to run *event-free under that
//! policy* (`OverflowStats::bits_hist`): the final exact value's width
//! for the sorting/exact policies, the index-order prefix extremes for
//! `Clip`/`Wrap` (whose saturation is order-dependent — a cancelling dot
//! can need a far wider accumulator than its final value suggests). The
//! planner then binary-searches each histogram for the smallest width
//! whose observed overflow fraction stays within the configured budget
//! (`OverflowStats::calibrated_bits`). With a zero budget, replaying the
//! calibration inputs at the calibrated widths is therefore event-free
//! end to end, for every policy.
//!
//! Samples are uniform pixels in `[0, 1]` from a seeded PCG stream, so a
//! calibration run is reproducible on any checkout without artifacts.
//! Callers with real data can pass their own batches through
//! [`observe_batches`].

use anyhow::Result;

use crate::accum::Policy;
use crate::formats::pqsw::PqswModel;
use crate::nn::engine::{Engine, EngineConfig};
use crate::overflow::OverflowReport;
use crate::util::rng::Pcg32;

/// Wide reference width used during calibration: comfortably above the
/// 33-bit worst case of 8-bit products over `u16`-indexed dots, so the
/// observation run itself never overflows.
pub const CALIBRATION_BITS: u32 = 40;

/// Build the instrumented wide-reference engine for `model`.
fn reference_engine(model: &PqswModel, policy: Policy) -> Engine {
    let cfg = EngineConfig {
        policy,
        acc_bits: CALIBRATION_BITS,
        tile: 0,
        collect_stats: true,
    };
    let mut eng = Engine::new(model, cfg);
    // calibration measures the model itself, not a previously embedded
    // plan: drop any per-layer overrides so the run is genuinely wide
    eng.clear_plan();
    eng
}

/// Stream `samples` deterministic uniform-random inputs through the
/// instrumented engine and return the merged per-layer report (with the
/// required-width histograms populated).
pub fn observe(
    model: &PqswModel,
    policy: Policy,
    samples: usize,
    batch: usize,
    seed: u64,
) -> Result<OverflowReport> {
    let dim: usize = model.input_shape.iter().product();
    let mut rng = Pcg32::new(seed);
    let batch = batch.max(1);
    let mut eng = reference_engine(model, policy);
    let mut report = OverflowReport::default();
    let mut done = 0usize;
    while done < samples {
        let n = batch.min(samples - done);
        let imgs: Vec<f32> = (0..n * dim).map(|_| rng.f32()).collect();
        let out = eng.forward(&imgs, n)?;
        report.merge(&out.report);
        done += n;
    }
    Ok(report)
}

/// [`observe`] over caller-provided image batches (each `(images, n)` with
/// `images.len() == n * input_dim`) — the real-data calibration path.
pub fn observe_batches<'a, I>(
    model: &PqswModel,
    policy: Policy,
    batches: I,
) -> Result<OverflowReport>
where
    I: IntoIterator<Item = (&'a [f32], usize)>,
{
    let mut eng = reference_engine(model, policy);
    let mut report = OverflowReport::default();
    for (imgs, n) in batches {
        let out = eng.forward(imgs, n)?;
        report.merge(&out.report);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn observation_is_deterministic_and_wide() {
        let model = models::synthetic_linear(32, 4);
        let a = observe(&model, Policy::Sorted, 20, 8, 7).unwrap();
        let b = observe(&model, Policy::Sorted, 20, 8, 7).unwrap();
        assert_eq!(a.layers, b.layers, "same seed, same observation");
        let t = a.total();
        assert_eq!(t.dots, 20 * 4);
        assert_eq!(t.persistent_dots, 0, "the reference run must be overflow-free");
        assert_eq!(t.hist_dots(), t.dots, "every dot lands in the width histogram");
        assert!(t.max_required_bits() >= 2);
    }

    #[test]
    fn batches_path_matches_generated_path() {
        let model = models::synthetic_linear(16, 3);
        let dim = 16;
        let mut rng = Pcg32::new(3);
        let imgs: Vec<f32> = (0..10 * dim).map(|_| rng.f32()).collect();
        let via_batches = observe_batches(
            &model,
            Policy::Clip,
            [(&imgs[..4 * dim], 4usize), (&imgs[4 * dim..], 6usize)],
        )
        .unwrap();
        let mut eng = reference_engine(&model, Policy::Clip);
        let whole = eng.forward(&imgs, 10).unwrap();
        assert_eq!(via_batches.total(), whole.report.total());
    }
}
