//! Minimal dense tensor substrate: shapes, f32/i32 storage, matmul and
//! im2col convolution lowering (DESIGN.md S11).
//!
//! Convolutions are lowered to matmul via im2col so that *every* MAC in the
//! network flows through the same dot-product machinery the paper analyzes:
//! a conv output element is a length C*kh*kw dot product, a depthwise
//! output element a length kh*kw dot product.

pub mod im2col;

pub use im2col::{conv_out_dim, im2col, im2col_grouped};

/// Dense row-major tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor<T> {
    pub shape: Vec<usize>,
    pub data: Vec<T>,
}

impl<T: Clone + Default> Tensor<T> {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![T::default(); n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }
}

pub type TensorF = Tensor<f32>;
pub type TensorI = Tensor<i32>;

impl TensorF {
    /// ReLU in place.
    pub fn relu_inplace(&mut self) {
        for v in &mut self.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    /// Elementwise add (shapes must match).
    pub fn add(&self, other: &TensorF) -> TensorF {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    /// Elementwise add in place (shapes must match) — lets the engine's
    /// value arena steal a residual branch's buffer instead of allocating.
    pub fn add_assign(&mut self, other: &TensorF) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Global average pool over the last two axes: (N,C,H,W) -> (N,C).
    pub fn global_avg_pool(&self) -> TensorF {
        assert_eq!(self.shape.len(), 4);
        let (n, c, h, w) = (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        let hw = h * w;
        let mut out = vec![0f32; n * c];
        for i in 0..n {
            for j in 0..c {
                let base = (i * c + j) * hw;
                let s: f32 = self.data[base..base + hw].iter().sum();
                out[i * c + j] = s / hw as f32;
            }
        }
        Tensor::from_vec(&[n, c], out)
    }
}

/// f32 matmul: a (m,k) @ b (k,n) -> (m,n). Reference (non-hot-path) impl.
pub fn matmul_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..kk * n + n];
            let orow = &mut out[i * n..i * n + n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_strides() {
        let t = TensorF::zeros(&[2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn relu_and_add() {
        let mut t = TensorF::from_vec(&[4], vec![-1.0, 0.0, 2.0, -3.0]);
        t.relu_inplace();
        assert_eq!(t.data, vec![0.0, 0.0, 2.0, 0.0]);
        let u = t.add(&TensorF::from_vec(&[4], vec![1.0; 4]));
        assert_eq!(u.data, vec![1.0, 1.0, 3.0, 1.0]);
        let mut v = t.clone();
        v.add_assign(&TensorF::from_vec(&[4], vec![1.0; 4]));
        assert_eq!(v.data, u.data);
    }

    #[test]
    fn gap() {
        let t = TensorF::from_vec(&[1, 2, 2, 2], vec![1., 2., 3., 4., 10., 20., 30., 40.]);
        let g = t.global_avg_pool();
        assert_eq!(g.shape, vec![1, 2]);
        assert_eq!(g.data, vec![2.5, 25.0]);
    }

    #[test]
    fn matmul_small() {
        let r = matmul_f32(&[1., 2., 3., 4.], &[1., 1., 1., 1.], 2, 2, 2);
        assert_eq!(r, vec![3., 3., 7., 7.]);
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_shape() {
        let _ = TensorF::from_vec(&[2, 2], vec![1.0; 3]);
    }
}
