//! im2col lowering of 2-D convolution to matmul (quantized domain).
//!
//! A conv over a quantized activation map becomes: for each output spatial
//! position, gather the receptive field into one row of length K = C*kh*kw,
//! then every output channel is a dot product of that row with the filter
//! row — exactly the dot products the paper's accumulator analysis studies.
//!
//! **Padding note:** padding happens in FP32 space with value 0.0, which in
//! the affine quantized domain is the *offset* `o_x`, not integer 0. The
//! caller passes `pad_q = quantize(0.0)`.

/// Output spatial dimension for a conv axis.
pub fn conv_out_dim(input: usize, k: usize, stride: usize, pad: usize) -> usize {
    (input + 2 * pad - k) / stride + 1
}

/// Lower one image (C,H,W as a flat slice) to the im2col matrix with layout
/// (L, K): L = oh*ow rows, K = c*kh*kw columns; each row is the receptive
/// field of one output position (channel-major, then kernel row/col —
/// matching the (O, I*kh*kw) weight layout exported by `pqsw.py`).
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    x: &[i32],
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    pad_q: i32,
    out: &mut Vec<i32>,
) -> (usize, usize) {
    debug_assert_eq!(x.len(), c * h * w);
    let oh = conv_out_dim(h, kh, stride, pad);
    let ow = conv_out_dim(w, kw, stride, pad);
    let k = c * kh * kw;
    out.clear();
    out.reserve(oh * ow * k);
    if pad == 0 && stride == 1 {
        // fast path: no bounds checks and every kernel row is a contiguous
        // kw-run of the input, copied whole instead of per element
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..c {
                    let base = ch * h * w;
                    for ky in 0..kh {
                        let row = base + (oy + ky) * w + ox;
                        out.extend_from_slice(&x[row..row + kw]);
                    }
                }
            }
        }
        return (oh * ow, k);
    }
    for oy in 0..oh {
        for ox in 0..ow {
            let iy0 = (oy * stride) as isize - pad as isize;
            let ix0 = (ox * stride) as isize - pad as isize;
            for ch in 0..c {
                let base = ch * h * w;
                for ky in 0..kh {
                    let iy = iy0 + ky as isize;
                    for kx in 0..kw {
                        let ix = ix0 + kx as isize;
                        if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize {
                            out.push(pad_q);
                        } else {
                            out.push(x[base + iy as usize * w + ix as usize]);
                        }
                    }
                }
            }
        }
    }
    (oh * ow, k)
}

/// Depthwise variant: lower only channel `ch` to (L, kh*kw).
#[allow(clippy::too_many_arguments)]
pub fn im2col_grouped(
    x: &[i32],
    c: usize,
    h: usize,
    w: usize,
    ch: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    pad_q: i32,
    out: &mut Vec<i32>,
) -> (usize, usize) {
    debug_assert!(ch < c);
    let oh = conv_out_dim(h, kh, stride, pad);
    let ow = conv_out_dim(w, kw, stride, pad);
    let k = kh * kw;
    out.clear();
    out.reserve(oh * ow * k);
    let base = ch * h * w;
    if pad == 0 && stride == 1 {
        // fast path: contiguous kw-runs (see `im2col`)
        for oy in 0..oh {
            for ox in 0..ow {
                for ky in 0..kh {
                    let row = base + (oy + ky) * w + ox;
                    out.extend_from_slice(&x[row..row + kw]);
                }
            }
        }
        return (oh * ow, k);
    }
    for oy in 0..oh {
        for ox in 0..ow {
            let iy0 = (oy * stride) as isize - pad as isize;
            let ix0 = (ox * stride) as isize - pad as isize;
            for ky in 0..kh {
                let iy = iy0 + ky as isize;
                for kx in 0..kw {
                    let ix = ix0 + kx as isize;
                    if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize {
                        out.push(pad_q);
                    } else {
                        out.push(x[base + iy as usize * w + ix as usize]);
                    }
                }
            }
        }
    }
    (oh * ow, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_dims() {
        assert_eq!(conv_out_dim(28, 3, 1, 1), 28);
        assert_eq!(conv_out_dim(20, 3, 2, 1), 10);
        assert_eq!(conv_out_dim(5, 1, 1, 0), 5);
    }

    #[test]
    fn identity_1x1() {
        // 1x1 conv im2col is just the pixels, channel-major per position
        let x: Vec<i32> = (0..2 * 2 * 2).collect(); // (2,2,2)
        let mut out = Vec::new();
        let (l, k) = im2col(&x, 2, 2, 2, 1, 1, 1, 0, 0, &mut out);
        assert_eq!((l, k), (4, 2));
        // position (0,0): ch0 val 0, ch1 val 4
        assert_eq!(&out[0..2], &[0, 4]);
        // position (1,1): ch0 val 3, ch1 val 7
        assert_eq!(&out[6..8], &[3, 7]);
    }

    #[test]
    fn conv3x3_matches_naive() {
        // compare im2col dot against a naive conv loop
        let (c, h, w) = (2, 5, 5);
        let x: Vec<i32> = (0..c * h * w).map(|i| (i as i32 * 7) % 11 - 5).collect();
        let weights: Vec<i32> = (0..c * 9).map(|i| (i as i32 * 3) % 7 - 3).collect(); // one filter
        let (stride, pad, pad_q) = (1, 1, -2);
        let mut cols = Vec::new();
        let (l, k) = im2col(&x, c, h, w, 3, 3, stride, pad, pad_q, &mut cols);
        assert_eq!((l, k), (25, 18));
        for oy in 0..5usize {
            for ox in 0..5usize {
                // naive
                let mut acc = 0i64;
                for ch in 0..c {
                    for ky in 0..3usize {
                        for kx in 0..3usize {
                            let iy = oy as isize + ky as isize - 1;
                            let ix = ox as isize + kx as isize - 1;
                            let v = if iy < 0 || iy >= 5 || ix < 0 || ix >= 5 {
                                pad_q
                            } else {
                                x[ch * 25 + iy as usize * 5 + ix as usize]
                            };
                            acc += (v * weights[ch * 9 + ky * 3 + kx]) as i64;
                        }
                    }
                }
                let row = &cols[(oy * 5 + ox) * k..(oy * 5 + ox + 1) * k];
                let dot: i64 = row.iter().zip(&weights).map(|(&a, &b)| (a * b) as i64).sum();
                assert_eq!(dot, acc, "at ({oy},{ox})");
            }
        }
    }

    #[test]
    fn grouped_matches_full_on_single_channel() {
        let (c, h, w) = (3, 4, 4);
        let x: Vec<i32> = (0..c * h * w).map(|i| i as i32 % 9 - 4).collect();
        let mut full = Vec::new();
        im2col(&x[16..32].to_vec(), 1, h, w, 3, 3, 1, 1, 0, &mut full);
        let mut grp = Vec::new();
        let (l, k) = im2col_grouped(&x, c, h, w, 1, 3, 3, 1, 1, 0, &mut grp);
        assert_eq!((l, k), (16, 9));
        assert_eq!(full, grp);
    }

    /// The general gather loop (the pre-fast-path implementation), used to
    /// prove the contiguous-run fast path is bit-identical.
    #[allow(clippy::too_many_arguments)]
    fn reference_im2col(
        x: &[i32],
        c: usize,
        h: usize,
        w: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
        pad_q: i32,
    ) -> Vec<i32> {
        let oh = conv_out_dim(h, kh, stride, pad);
        let ow = conv_out_dim(w, kw, stride, pad);
        let mut out = Vec::new();
        for oy in 0..oh {
            for ox in 0..ow {
                let iy0 = (oy * stride) as isize - pad as isize;
                let ix0 = (ox * stride) as isize - pad as isize;
                for ch in 0..c {
                    let base = ch * h * w;
                    for ky in 0..kh {
                        let iy = iy0 + ky as isize;
                        for kx in 0..kw {
                            let ix = ix0 + kx as isize;
                            if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize {
                                out.push(pad_q);
                            } else {
                                out.push(x[base + iy as usize * w + ix as usize]);
                            }
                        }
                    }
                }
            }
        }
        out
    }

    #[test]
    fn fast_path_bit_identical_to_general_gather() {
        // the ISSUE contract: the pad==0 && stride==1 contiguous-run copy
        // must equal the general per-element gather exactly
        let mut rng = crate::util::rng::Pcg32::new(0x132C);
        for case in 0..50 {
            let c = 1 + rng.below(4) as usize;
            let h = 3 + rng.below(8) as usize;
            let w = 3 + rng.below(8) as usize;
            let kh = 1 + rng.below(3.min(h as u32)) as usize;
            let kw = 1 + rng.below(3.min(w as u32)) as usize;
            let x = rng.ivec(c * h * w, -120, 120);
            let mut fast = Vec::new();
            let (l, k) = im2col(&x, c, h, w, kh, kw, 1, 0, 7, &mut fast);
            let want = reference_im2col(&x, c, h, w, kh, kw, 1, 0, 7);
            assert_eq!(fast.len(), l * k, "case {case}");
            assert_eq!(fast, want, "case {case}: c={c} h={h} w={w} kh={kh} kw={kw}");
        }
    }

    #[test]
    fn grouped_fast_path_bit_identical_to_general_gather() {
        let mut rng = crate::util::rng::Pcg32::new(0x6270);
        for case in 0..50 {
            let c = 1 + rng.below(4) as usize;
            let ch = rng.below(c as u32) as usize;
            let h = 3 + rng.below(8) as usize;
            let w = 3 + rng.below(8) as usize;
            let kh = 1 + rng.below(3.min(h as u32)) as usize;
            let kw = 1 + rng.below(3.min(w as u32)) as usize;
            let x = rng.ivec(c * h * w, -120, 120);
            let mut fast = Vec::new();
            let (l, k) = im2col_grouped(&x, c, h, w, ch, kh, kw, 1, 0, 7, &mut fast);
            // general gather over the single channel == grouped fast path
            let img = &x[ch * h * w..(ch + 1) * h * w];
            let want = reference_im2col(img, 1, h, w, kh, kw, 1, 0, 7);
            assert_eq!(fast.len(), l * k, "case {case}");
            assert_eq!(fast, want, "case {case}: c={c} ch={ch} h={h} w={w} kh={kh} kw={kw}");
        }
    }

    #[test]
    fn stride_two_downsamples() {
        let x: Vec<i32> = (0..36).collect(); // (1,6,6)
        let mut out = Vec::new();
        let (l, k) = im2col(&x, 1, 6, 6, 3, 3, 2, 1, 99, &mut out);
        assert_eq!((l, k), (9, 9));
        // first row, first element is padding
        assert_eq!(out[0], 99);
    }
}
