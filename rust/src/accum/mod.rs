//! p-bit accumulator simulation (paper §3).
//!
//! A signed p-bit accumulator holds values in `[-2^(p-1), 2^(p-1)-1]`. An
//! *overflow event* is any step where the exact running sum would leave
//! that range before the policy (clip / wrap) brings it back. Mirrors
//! `python/compile/kernels/ref.py` bit-for-bit (the contract is enforced by
//! `rust/tests/golden_dot.rs` against exported goldens).

/// Accumulation policy for a dot product (paper terminology).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Policy {
    /// Wide accumulator: exact integer sum, never overflows.
    Exact,
    /// Saturating arithmetic in index order (what CMSIS-NN-class kernels do).
    Clip,
    /// Two's-complement wraparound in index order (WrapNet-style).
    Wrap,
    /// Single sorting round then clipped accumulation (the Pallas kernel).
    Sorted1,
    /// Full Algorithm 1: repeated sort/pair rounds, then monotone
    /// accumulation (the PQS inference algorithm).
    Sorted,
    /// Oracle that resolves every transient overflow (Fig. 2b red line).
    Oracle,
}

impl Policy {
    pub const ALL: [Policy; 6] =
        [Policy::Exact, Policy::Clip, Policy::Wrap, Policy::Sorted1, Policy::Sorted, Policy::Oracle];

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Exact => "exact",
            Policy::Clip => "clip",
            Policy::Wrap => "wrap",
            Policy::Sorted1 => "sorted1",
            Policy::Sorted => "sorted",
            Policy::Oracle => "oracle",
        }
    }

    pub fn from_name(s: &str) -> Option<Policy> {
        Policy::ALL.iter().copied().find(|p| p.name() == s)
    }
}

/// Inclusive [lo, hi] range of a signed p-bit accumulator.
#[inline]
pub fn acc_range(p: u32) -> (i64, i64) {
    (-(1i64 << (p - 1)), (1i64 << (p - 1)) - 1)
}

/// Clamp a wide value into the p-bit range.
#[inline]
pub fn clamp(v: i64, p: u32) -> i64 {
    let (lo, hi) = acc_range(p);
    v.clamp(lo, hi)
}

/// Sequential saturating accumulation in index order.
/// Returns `(final value, overflow events)`.
pub fn clip_accumulate(prods: &[i32], p: u32) -> (i64, u32) {
    let (lo, hi) = acc_range(p);
    let mut acc = 0i64;
    let mut ovf = 0u32;
    for &v in prods {
        let t = acc + v as i64;
        acc = if t < lo {
            ovf += 1;
            lo
        } else if t > hi {
            ovf += 1;
            hi
        } else {
            t
        };
    }
    (acc, ovf)
}

/// Sequential two's-complement wraparound accumulation in index order.
pub fn wrap_accumulate(prods: &[i32], p: u32) -> (i64, u32) {
    let (lo, hi) = acc_range(p);
    let span = 1i64 << p;
    let mut acc = 0i64;
    let mut ovf = 0u32;
    for &v in prods {
        let mut t = acc + v as i64;
        if t < lo || t > hi {
            ovf += 1;
            t = (t - lo).rem_euclid(span) + lo;
        }
        acc = t;
    }
    (acc, ovf)
}

/// Exact (wide) sum.
#[inline]
pub fn exact_dot(prods: &[i32]) -> i64 {
    prods.iter().map(|&v| v as i64).sum()
}

/// Smallest signed accumulator width that holds `v`: the minimal `p` with
/// `-2^(p-1) <= v <= 2^(p-1)-1`, floored at 2. This is the per-dot
/// "required width" the accumulator-bitwidth planner histograms
/// (`crate::plan`).
#[inline]
pub fn bits_for_value(v: i64) -> u32 {
    // two's complement: a non-negative v needs its magnitude bits + sign;
    // a negative v needs the bits of !v (its offset-by-one magnitude) + sign
    let mag = if v >= 0 { v as u64 } else { !(v as u64) };
    (64 - mag.leading_zeros() + 1).max(2)
}

/// Smallest signed accumulator width whose range contains `[lo, hi]`.
#[inline]
pub fn bits_for_range(lo: i64, hi: i64) -> u32 {
    bits_for_value(lo).max(bits_for_value(hi))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    #[test]
    fn ranges() {
        assert_eq!(acc_range(8), (-128, 127));
        assert_eq!(acc_range(16), (-32768, 32767));
        assert_eq!(acc_range(32), (i32::MIN as i64, i32::MAX as i64));
    }

    #[test]
    fn clip_saturates_matches_python() {
        // mirror python test_ref: [120,10,5] at p=8 -> 127 with 2 events
        assert_eq!(clip_accumulate(&[120, 10, 5], 8), (127, 2));
        assert_eq!(clip_accumulate(&[-120, -10, -5], 8), (-128, 2));
    }

    #[test]
    fn wrap_matches_twos_complement() {
        assert_eq!(wrap_accumulate(&[120, 10], 8), (130 - 256, 1));
        assert_eq!(wrap_accumulate(&[-120, -10], 8), (-130 + 256, 1));
    }

    #[test]
    fn no_overflow_means_exact_prop() {
        prop::check(
            "clip-exact-when-clean",
            300,
            |r: &mut Pcg32| (prop::gen_prods(r, 128, 8), 12 + r.below(16)),
            |(prods, p)| {
                let (v, e) = clip_accumulate(prods, *p);
                if e == 0 && v != exact_dot(prods) {
                    return Err(format!("clean but {v} != exact"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn wide_accumulator_never_overflows_prop() {
        prop::check(
            "wide-never-overflows",
            200,
            |r: &mut Pcg32| prop::gen_prods(r, 512, 8),
            |prods| {
                let (v, e) = clip_accumulate(prods, 48);
                if e != 0 || v != exact_dot(prods) {
                    return Err("48-bit accumulator overflowed?!".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn wrap_value_always_in_range_prop() {
        prop::check(
            "wrap-in-range",
            300,
            |r: &mut Pcg32| (prop::gen_prods(r, 128, 8), 12 + r.below(10)),
            |(prods, p)| {
                let (v, _) = wrap_accumulate(prods, *p);
                let (lo, hi) = acc_range(*p);
                if v < lo || v > hi {
                    return Err(format!("{v} outside [{lo},{hi}]"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn bits_for_value_boundaries() {
        assert_eq!(bits_for_value(0), 2);
        assert_eq!(bits_for_value(1), 2);
        assert_eq!(bits_for_value(-1), 2);
        assert_eq!(bits_for_value(-2), 2);
        assert_eq!(bits_for_value(2), 3);
        assert_eq!(bits_for_value(-3), 3);
        assert_eq!(bits_for_value(127), 8);
        assert_eq!(bits_for_value(128), 9);
        assert_eq!(bits_for_value(-128), 8);
        assert_eq!(bits_for_value(-129), 9);
        assert_eq!(bits_for_value(i32::MAX as i64), 32);
        assert_eq!(bits_for_value(i32::MIN as i64), 32);
        assert_eq!(bits_for_range(-128, 127), 8);
        assert_eq!(bits_for_range(-129, 0), 9);
    }

    #[test]
    fn bits_for_value_matches_acc_range_prop() {
        prop::check(
            "bits-for-value",
            500,
            |r: &mut Pcg32| r.range_i64(-(1 << 40), 1 << 40),
            |&v| {
                let p = bits_for_value(v);
                let (lo, hi) = acc_range(p);
                if v < lo || v > hi {
                    return Err(format!("{v} does not fit its own width {p}"));
                }
                if p > 2 {
                    let (plo, phi) = acc_range(p - 1);
                    if v >= plo && v <= phi {
                        return Err(format!("{v} also fits {} bits, width {p} not minimal", p - 1));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in Policy::ALL {
            assert_eq!(Policy::from_name(p.name()), Some(p));
        }
        assert_eq!(Policy::from_name("bogus"), None);
    }
}
