//! # PQS — Prune, Quantize, and Sort
//!
//! Rust reproduction of *"PQS: Low-Bitwidth Accumulation of Dot Products in
//! Neural Network Computations"* (Natesh & Kung, 2025): a bit-accurate
//! quantized inference engine with fine-grained control over dot-product
//! accumulation (the paper §5.0.1 "library for analyzing overflows"),
//! plus every substrate it needs — tensors, quantizers, N:M sparse formats,
//! synthetic datasets, a PJRT runtime for AOT-compiled JAX/Pallas artifacts,
//! and a threaded evaluation coordinator.
//!
//! The three-layer architecture (see DESIGN.md):
//! * **L1** Pallas kernel (`python/compile/kernels/pqs_matmul.py`) — sorted
//!   low-bitwidth accumulation, AOT-lowered to HLO text.
//! * **L2** JAX model + training schedules (`python/compile/`), build-time
//!   only.
//! * **L3** this crate — loads the exported `.pqsw` models and HLO
//!   artifacts and runs every experiment in the paper.

pub mod accum;
pub mod benchreport;
pub mod coordinator;
pub mod data;
pub mod dot;
pub mod faults;
pub mod figures;
pub mod formats;
pub mod http;
pub mod models;
pub mod nn;
pub mod overflow;
pub mod plan;
pub mod quant;
pub mod runtime;
pub mod sparse;
pub mod sweep;
pub mod tensor;
pub mod trace;
pub mod util;

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: honours `PQS_ARTIFACTS`, else walks up
/// from the current dir looking for an `artifacts/` folder.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("PQS_ARTIFACTS") {
        return p.into();
    }
    let mut d = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = d.join(ARTIFACTS_DIR);
        if cand.is_dir() {
            return cand;
        }
        if !d.pop() {
            return ARTIFACTS_DIR.into();
        }
    }
}
